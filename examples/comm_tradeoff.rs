//! Communication–performance tradeoff explorer.
//!
//! Sweeps the communication interval τ for Algorithm 1 on one preset and
//! reports, per interconnect, the simulated time-to-final-loss breakdown
//! — reproducing the paper's core motivation: as links get slower, larger
//! τ wins even though each round makes slightly less optimization
//! progress.
//!
//!     cargo run --release --example comm_tradeoff [--preset nano] [--budget 120]

use anyhow::Result;

use dsm::comm::CommModel;
use dsm::config::{default_peak_lr, RunConfig};
use dsm::outer::OuterConfig;
use dsm::runtime::{Artifacts, ModelBundle, Runtime};
use dsm::train::schedule::ScheduleConfig;
use dsm::train::Trainer;
use dsm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let preset = args.str_or("preset", "nano");
    let budget = args.usize_or("budget", 120).map_err(anyhow::Error::msg)?;
    let workers = 4usize;

    let rt = Runtime::cpu()?;
    let arts = Artifacts::load(&Artifacts::default_dir())?;
    let bundle = std::sync::Arc::new(ModelBundle::load(&rt, arts.preset(&preset)?)?);
    let bytes = bundle.info.param_count as u64 * 4;

    println!("comm_tradeoff: preset={preset}, n={workers}, budget={budget} local steps\n");
    let mut rows = Vec::new();
    for tau in [1usize, 4, 12, 24, 36] {
        let rounds = (budget / tau).max(1);
        let mut cfg = RunConfig::paper_default(&preset);
        cfg.tau = tau;
        cfg.rounds = rounds;
        cfg.n_workers = workers;
        cfg.outer = OuterConfig::sign_momentum_paper(12.0);
        cfg.schedule =
            ScheduleConfig::cosine_paper(default_peak_lr(&preset), (rounds * tau) as u64);
        cfg.eval_every = 0; // final eval only
        cfg.tag = format!("tradeoff-tau{tau}");
        let mut trainer = Trainer::with_bundle(cfg, bundle.clone(), &rt, &arts)?;
        let res = trainer.run()?;
        println!(
            "tau {tau:>3}: val {:.4} | {} comm rounds | compute {:.1}s",
            res.final_val, res.clock.comm_rounds, res.clock.compute_s
        );
        rows.push((tau, res));
    }

    println!("\nsimulated total seconds (compute + modeled comm):");
    print!("{:>10}", "net\\tau");
    for (tau, _) in &rows {
        print!("{tau:>10}");
    }
    println!();
    for net in ["nvlink", "infiniband", "ethernet", "wan"] {
        let m = CommModel::preset(net).unwrap();
        print!("{net:>10}");
        let totals: Vec<f64> = rows
            .iter()
            .map(|(_, r)| {
                r.clock.compute_s + r.clock.comm_rounds as f64 * m.allreduce_time(workers, bytes)
            })
            .collect();
        for t in &totals {
            print!("{t:>10.2}");
        }
        // best tau for this net
        let best = rows
            .iter()
            .zip(&totals)
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|((tau, _), _)| *tau)
            .unwrap();
        println!("   <- best tau = {best}");
    }
    println!("\ncomm_tradeoff OK");
    Ok(())
}
