//! Communication–performance tradeoff explorer.
//!
//! Sweeps the communication interval τ for Algorithm 1 on one preset and
//! reports, per interconnect, the simulated time-to-final-loss breakdown
//! — reproducing the paper's core motivation: as links get slower, larger
//! τ wins even though each round makes slightly less optimization
//! progress. A second sweep varies the round's WIRE FORMAT at fixed τ
//! (dense f32 vs the 8-bit quantized exchange), the payload-level axis
//! the typed `WirePayload` contract opens.
//!
//!     cargo run --release --example comm_tradeoff [--preset nano] [--budget 120]

use anyhow::Result;

use dsm::comm::CommModel;
use dsm::config::{default_peak_lr, RunConfig};
use dsm::dist::WireFormat;
use dsm::outer::OuterConfig;
use dsm::runtime::{Artifacts, ModelBundle, Runtime};
use dsm::train::schedule::ScheduleConfig;
use dsm::train::Trainer;
use dsm::util::cli::Args;

/// Modeled seconds of one round exchange in `wire` format — mirrors
/// `SimClock::charge_exchange`'s topology choice.
fn exchange_time(m: &CommModel, n: usize, wire: WireFormat, p: usize) -> f64 {
    let bytes = wire.wire_bytes(p);
    if wire.ring_reducible() {
        m.allreduce_time(n, bytes)
    } else {
        m.gather_time(n, bytes) + m.broadcast_time(n, bytes)
    }
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let preset = args.str_or("preset", "nano");
    let budget = args.usize_or("budget", 120).map_err(anyhow::Error::msg)?;
    let workers = 4usize;

    let rt = Runtime::cpu()?;
    let arts = Artifacts::load(&Artifacts::default_dir())?;
    let bundle = std::sync::Arc::new(ModelBundle::load(&rt, arts.preset(&preset)?)?);
    let p = bundle.info.param_count;
    let bytes = p as u64 * 4;

    let make_cfg = |tau: usize, wire: Option<WireFormat>| {
        let rounds = (budget / tau).max(1);
        let mut cfg = RunConfig::paper_default(&preset);
        cfg.tau = tau;
        cfg.rounds = rounds;
        cfg.n_workers = workers;
        cfg.outer = OuterConfig::sign_momentum_paper(12.0);
        cfg.schedule =
            ScheduleConfig::cosine_paper(default_peak_lr(&preset), (rounds * tau) as u64);
        cfg.eval_every = 0; // final eval only
        cfg.wire = wire;
        cfg.tag = format!("tradeoff-tau{tau}-{}", wire.map(|w| w.name()).unwrap_or("dense"));
        cfg
    };

    println!("comm_tradeoff: preset={preset}, n={workers}, budget={budget} local steps\n");
    let mut rows = Vec::new();
    for tau in [1usize, 4, 12, 24, 36] {
        let mut trainer = Trainer::with_bundle(make_cfg(tau, None), bundle.clone(), &rt, &arts)?;
        let res = trainer.run()?;
        println!(
            "tau {tau:>3}: val {:.4} | {} comm rounds | compute {:.1}s",
            res.final_val, res.clock.comm_rounds, res.clock.compute_s
        );
        rows.push((tau, res));
    }

    println!("\nsimulated total seconds (compute + modeled comm):");
    print!("{:>10}", "net\\tau");
    for (tau, _) in &rows {
        print!("{tau:>10}");
    }
    println!();
    for net in ["nvlink", "infiniband", "ethernet", "wan"] {
        let m = CommModel::preset(net).unwrap();
        print!("{net:>10}");
        let totals: Vec<f64> = rows
            .iter()
            .map(|(_, r)| {
                r.clock.compute_s + r.clock.comm_rounds as f64 * m.allreduce_time(workers, bytes)
            })
            .collect();
        for t in &totals {
            print!("{t:>10.2}");
        }
        // best tau for this net
        let best = rows
            .iter()
            .zip(&totals)
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|((tau, _), _)| *tau)
            .unwrap();
        println!("   <- best tau = {best}");
    }

    // ---- wire-format sweep at fixed tau = 12 -------------------------
    // Same algorithm, same schedule; only the round payload changes:
    // dense f32 (ring) vs 8-bit quantized differences (gather+broadcast,
    // 4x smaller messages, bounded rounding error in the exchange).
    let fixed_tau = 12usize;
    let dense_res = rows
        .iter()
        .find(|(tau, _)| *tau == fixed_tau)
        .map(|(_, r)| r)
        .expect("tau=12 is in the sweep");
    let mut q8_trainer = Trainer::with_bundle(
        make_cfg(fixed_tau, Some(WireFormat::QuantizedI8)),
        bundle.clone(),
        &rt,
        &arts,
    )?;
    let q8_res = q8_trainer.run()?;

    println!("\nwire-format tradeoff at tau = {fixed_tau} (Algorithm 1, simulated total seconds):");
    println!("{:>10}{:>12}{:>12}", "net", "dense", "q8");
    for net in ["nvlink", "infiniband", "ethernet", "wan"] {
        let m = CommModel::preset(net).unwrap();
        let total = |res: &dsm::train::RunResult, wire: WireFormat| {
            res.clock.compute_s
                + res.clock.comm_rounds as f64 * exchange_time(&m, workers, wire, p)
        };
        println!(
            "{net:>10}{:>12.2}{:>12.2}",
            total(dense_res, WireFormat::DenseF32),
            total(&q8_res, WireFormat::QuantizedI8),
        );
    }
    println!(
        "final val: dense {:.4} | q8 {:.4}  (per-rank message: {} vs {} bytes)",
        dense_res.final_val,
        q8_res.final_val,
        WireFormat::DenseF32.wire_bytes(p),
        WireFormat::QuantizedI8.wire_bytes(p),
    );

    println!("\ncomm_tradeoff OK");
    Ok(())
}
