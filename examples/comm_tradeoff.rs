//! Communication–performance tradeoff explorer.
//!
//! Sweeps the communication interval τ for Algorithm 1 on one preset and
//! reports, per interconnect, the simulated time-to-final-loss breakdown
//! — reproducing the paper's core motivation: as links get slower, larger
//! τ wins even though each round makes slightly less optimization
//! progress. A second sweep varies the round's WIRE FORMAT at fixed τ
//! (dense f32 vs the 8-bit quantized exchange, per-message `q8` and
//! layout-aware per-tensor `q8pt`, vs the DeMo-style sparse `topk`
//! residual-momentum wire), the payload-level axis the typed
//! `WirePayload` contract opens, plus the per-segment breakdown of where
//! the bits go.
//!
//!     cargo run --release --example comm_tradeoff \
//!         [--preset nano] [--budget 120] [--native] [--quick] [--out FILE]
//!
//! With `--native` — or automatically when no `artifacts/manifest.json`
//! exists (e.g. the CI smoke job) — the sweep runs on the pure-Rust
//! multi-layer transformer `NativeBundle`, whose per-block layout gives
//! `q8pt` real segments to resolve. `--quick` shrinks the budget for
//! smoke runs; `--out` also writes the rendered tables to a file (CI
//! uploads it as an artifact).

use std::fmt::Write as _;
use std::sync::Arc;

use anyhow::Result;

use dsm::comm::CommModel;
use dsm::config::{default_peak_lr, RunConfig};
use dsm::dist::WireFormat;
use dsm::outer::OuterConfig;
use dsm::runtime::{Artifacts, ModelBundle, NativeBundle, Runtime, StepBackend};
use dsm::train::metrics::render_segment_norms;
use dsm::train::schedule::ScheduleConfig;
use dsm::train::Trainer;
use dsm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_with_bools(std::env::args().skip(1), &["native", "quick"])
        .map_err(anyhow::Error::msg)?;
    let quick = args.has("quick");
    let default_budget = if quick { 24 } else { 120 };
    let budget = args.usize_or("budget", default_budget).map_err(anyhow::Error::msg)?;
    let workers = 4usize;

    // Backend selection: PJRT artifacts when requested/available, the
    // pure-Rust multi-layer transformer under --native — and only
    // auto-fall back to it when the user did NOT name a preset (an
    // explicit --preset against missing artifacts stays a loud load
    // error rather than a silent toy-model substitution).
    let explicit_preset = args.get("preset").map(str::to_string);
    let have_artifacts = Artifacts::default_dir().join("manifest.json").exists();
    let native = args.has("native") || (!have_artifacts && explicit_preset.is_none());
    match &explicit_preset {
        Some(p) if native => {
            eprintln!("note: --native overrides --preset {p}; running the native transformer");
        }
        _ => {}
    }
    let preset = if native {
        "native".to_string()
    } else {
        explicit_preset.unwrap_or_else(|| "nano".to_string())
    };
    // keep the runtime/artifacts alive next to the compiled bundle
    let pjrt: Option<(Runtime, Artifacts)> = if native {
        None
    } else {
        Some((Runtime::cpu()?, Artifacts::load(&Artifacts::default_dir())?))
    };
    let backend: Arc<dyn StepBackend> = match &pjrt {
        Some((rt, arts)) => Arc::new(ModelBundle::load(rt, arts.preset(&preset)?)?),
        // 2 transformer blocks, 15 named layout segments
        None => Arc::new(NativeBundle::transformer(&preset, 2, 24, 16, 2)),
    };
    let p = backend.info().param_count;
    let segments = backend.layout().len();

    let make_cfg = |tau: usize, wire: Option<WireFormat>| {
        let rounds = (budget / tau).max(1);
        let mut cfg = RunConfig::paper_default(&preset);
        cfg.tau = tau;
        cfg.rounds = rounds;
        cfg.n_workers = workers;
        cfg.outer = OuterConfig::sign_momentum_paper(12.0);
        cfg.schedule =
            ScheduleConfig::cosine_paper(default_peak_lr(&preset), (rounds * tau) as u64);
        cfg.eval_every = 0; // final eval only
        cfg.wire = wire;
        if quick {
            cfg.corpus_bytes = 1 << 18;
            cfg.eval_batches = 2;
        }
        cfg.tag = format!("tradeoff-tau{tau}-{}", wire.map(|w| w.name()).unwrap_or("dense"));
        cfg
    };

    let mut report = String::new();
    writeln!(
        report,
        "comm_tradeoff: preset={preset} (P={p}, {segments} layout segments), \
         n={workers}, budget={budget} local steps\n"
    )?;
    let mut rows = Vec::new();
    for tau in [1usize, 4, 12, 24, 36] {
        let mut trainer = Trainer::with_backend(make_cfg(tau, None), backend.clone())?;
        let res = trainer.run()?;
        writeln!(
            report,
            "tau {tau:>3}: val {:.4} | {} comm rounds | compute {:.1}s",
            res.final_val, res.clock.comm_rounds, res.clock.compute_s
        )?;
        rows.push((tau, res));
    }

    writeln!(report, "\nsimulated total seconds (compute + modeled comm):")?;
    write!(report, "{:>10}", "net\\tau")?;
    for (tau, _) in &rows {
        write!(report, "{tau:>10}")?;
    }
    writeln!(report)?;
    for net in ["nvlink", "infiniband", "ethernet", "wan"] {
        let m = CommModel::preset(net).unwrap();
        write!(report, "{net:>10}")?;
        // dense re-cost through the same helper the clock's rule lives in
        let dense_s = WireFormat::DenseF32.exchange_time(&m, workers, p, 1);
        let totals: Vec<f64> = rows
            .iter()
            .map(|(_, r)| r.clock.compute_s + r.clock.comm_rounds as f64 * dense_s)
            .collect();
        for t in &totals {
            write!(report, "{t:>10.2}")?;
        }
        // best tau for this net
        let best = rows
            .iter()
            .zip(&totals)
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|((tau, _), _)| *tau)
            .unwrap();
        writeln!(report, "   <- best tau = {best}")?;
    }

    // ---- wire-format sweep at fixed tau = 12 -------------------------
    // Same algorithm, same schedule; only the round payload changes:
    // dense f32 (ring) vs 8-bit quantized differences (gather+broadcast,
    // 4x smaller messages, bounded rounding error in the exchange) —
    // with one scale per message (q8) or one per layout segment (q8pt) —
    // vs sparse top-k residual momentum (topk: 8 bytes per kept
    // component, untransmitted mass banked in a decaying residual).
    let fixed_tau = 12usize;
    let dense_res = rows
        .iter()
        .find(|(tau, _)| *tau == fixed_tau)
        .map(|(_, r)| r)
        .expect("tau=12 is in the sweep");
    let q8_cfg = make_cfg(fixed_tau, Some(WireFormat::QuantizedI8));
    let mut q8_trainer = Trainer::with_backend(q8_cfg, backend.clone())?;
    let q8_res = q8_trainer.run()?;
    let q8pt_cfg = make_cfg(fixed_tau, Some(WireFormat::QuantizedI8PerTensor));
    let mut q8pt_trainer = Trainer::with_backend(q8pt_cfg, backend.clone())?;
    let q8pt_res = q8pt_trainer.run()?;
    let topk_cfg = make_cfg(fixed_tau, Some(WireFormat::TOPK_DEFAULT));
    let mut topk_trainer = Trainer::with_backend(topk_cfg, backend.clone())?;
    let topk_res = topk_trainer.run()?;

    writeln!(
        report,
        "\nwire-format tradeoff at tau = {fixed_tau} (Algorithm 1, simulated total seconds):"
    )?;
    writeln!(report, "{:>10}{:>12}{:>12}{:>12}{:>12}", "net", "dense", "q8", "q8pt", "topk")?;
    for net in ["nvlink", "infiniband", "ethernet", "wan"] {
        let m = CommModel::preset(net).unwrap();
        // re-cost through WireFormat::exchange_time — the same byte ×
        // topology rule SimClock::charge_exchange billed with
        let total = |res: &dsm::train::RunResult, wire: WireFormat| {
            res.clock.compute_s
                + res.clock.comm_rounds as f64 * wire.exchange_time(&m, workers, p, segments)
        };
        writeln!(
            report,
            "{net:>10}{:>12.2}{:>12.2}{:>12.2}{:>12.2}",
            total(dense_res, WireFormat::DenseF32),
            total(&q8_res, WireFormat::QuantizedI8),
            total(&q8pt_res, WireFormat::QuantizedI8PerTensor),
            total(&topk_res, WireFormat::TOPK_DEFAULT),
        )?;
    }
    writeln!(
        report,
        "final val: dense {:.4} | q8 {:.4} | q8pt {:.4} | topk {:.4}\n\
         per-rank message bytes: dense {} | q8 {} | q8pt {} | topk {} \
         ({} segments; q8pt pays 4-byte scales, topk 8 bytes per kept\n\
         component at the default 1/16 keep fraction)",
        dense_res.final_val,
        q8_res.final_val,
        q8pt_res.final_val,
        topk_res.final_val,
        WireFormat::DenseF32.wire_bytes(p, segments),
        WireFormat::QuantizedI8.wire_bytes(p, segments),
        WireFormat::QuantizedI8PerTensor.wire_bytes(p, segments),
        WireFormat::TOPK_DEFAULT.wire_bytes(p, segments),
        segments,
    )?;

    // where the bits go: the q8pt run's last-round update, per segment
    if !q8pt_res.segment_norms.is_empty() {
        writeln!(
            report,
            "\nlast-round global update per layout segment (q8pt run — hetero\n\
             per-segment magnitudes are why per-tensor scales exist):\n{}",
            render_segment_norms(&q8pt_res.segment_norms)
        )?;
    }

    writeln!(report, "\ncomm_tradeoff OK")?;
    print!("{report}");
    if let Some(out) = args.get("out") {
        std::fs::write(out, &report)?;
        eprintln!("wrote {out}");
    }
    Ok(())
}
