//! Thousand-rank fleet explorer: aggregation topology and fault plan.
//!
//! Part A prices one round exchange per (wire format × fleet size) under
//! the α-β model and shows which topology the selector routes — the
//! two-level hierarchy is what keeps the compressed formats viable at
//! thousand-rank scale (O(√n) message times instead of the flat
//! gather's O(n)), while dense f32 always ring-reduces.
//!
//! Part B trains the pure-Rust transformer fleet through the fault
//! plan: heavy-tailed stragglers, dropped payloads (the round degrades
//! to whatever arrived), corrupted payloads (bit flips survive with
//! bounded error; NaN scales are rejected and counted, never averaged
//! in), and elastic membership churn. Every configuration reports its
//! final loss next to the fault counters, so "the fleet held" is a
//! number, not a vibe.
//!
//!     cargo run --release --example fleet_faults [--quick] [--out FILE]
//!
//! Runs entirely on the native backend — no PJRT artifacts needed.
//! `--quick` shrinks rounds/corpus for smoke runs; `--out` writes the
//! machine-readable report (JSON: modeled exchange times per
//! topology × format × n, plus the loss-under-faults rows) that CI
//! uploads as `BENCH_fleet.json`.

use std::fmt::Write as _;
use std::sync::Arc;

use anyhow::Result;

use dsm::comm::{CommModel, FaultStats, Topology};
use dsm::config::RunConfig;
use dsm::dist::WireFormat;
use dsm::outer::OuterConfig;
use dsm::runtime::{NativeBundle, StepBackend};
use dsm::train::Trainer;
use dsm::util::cli::Args;

fn topo_label(t: Topology) -> String {
    match t {
        Topology::Ring => "ring".to_string(),
        Topology::FlatGatherBroadcast => "flat".to_string(),
        Topology::Hierarchical { groups } => format!("hier(g={groups})"),
    }
}

struct FaultRow {
    name: &'static str,
    final_val: f64,
    straggler_s: f64,
    stats: FaultStats,
}

fn main() -> Result<()> {
    let args = Args::parse_with_bools(std::env::args().skip(1), &["quick"])
        .map_err(anyhow::Error::msg)?;
    let quick = args.has("quick");

    let preset = "native";
    // 2 transformer blocks — a real multi-segment layout for q8pt
    let backend: Arc<NativeBundle> = if quick {
        Arc::new(NativeBundle::transformer(preset, 2, 12, 8, 2))
    } else {
        Arc::new(NativeBundle::transformer(preset, 2, 24, 16, 2))
    };
    let p = backend.info().param_count;
    let segments = backend.layout().len();

    let mut report = String::new();
    writeln!(report, "fleet_faults: preset={preset} (P={p}, {segments} layout segments)\n")?;

    // ---- Part A: exchange topology and cost vs fleet size ------------
    let m = CommModel::preset("ethernet").unwrap();
    let formats = [
        WireFormat::DenseF32,
        WireFormat::PackedSigns,
        WireFormat::QuantizedI8,
        WireFormat::QuantizedI8PerTensor,
    ];
    // (n, format name, topology label, modeled seconds)
    let mut modeled: Vec<(usize, &str, String, f64)> = Vec::new();
    writeln!(report, "one-round exchange on ethernet, modeled seconds (topology):")?;
    writeln!(report, "{:>8}{:>22}{:>22}{:>22}{:>22}", "n", "dense", "signs", "q8", "q8pt")?;
    for n in [8usize, 64, 1024] {
        write!(report, "{n:>8}")?;
        for w in formats {
            let t = w.exchange_time(&m, n, p, segments);
            let topo = topo_label(Topology::select(w.ring_reducible(), n));
            write!(report, "{:>22}", format!("{t:.3}s {topo}"))?;
            modeled.push((n, w.name(), topo, t));
        }
        writeln!(report)?;
    }
    // the headline number: what the two-level hierarchy buys at n=1024
    let n_big = 1024;
    let flat = dsm::comm::topology::flat_message_count(n_big);
    let g = dsm::comm::topology::best_group_count(n_big);
    let hier = dsm::comm::topology::hierarchical_message_count(n_big, g);
    writeln!(
        report,
        "\nat n={n_big}: flat gather+broadcast costs {flat} serial message times,\n\
         the selected hierarchy (g={g}) costs {hier} — {:.1}x fewer; same total\n\
         volume 2(n-1)·b either way, the hierarchy only reorders who talks.\n",
        flat as f64 / hier as f64
    )?;

    // ---- Part B: train the fleet through the fault plan --------------
    let rounds = if quick { 4 } else { 12 };
    let base = |tag: &str| {
        let mut cfg = RunConfig::paper_default(preset);
        cfg.rounds = rounds;
        cfg.tau = 3;
        cfg.n_workers = 4;
        cfg.corpus_bytes = if quick { 1 << 16 } else { 1 << 18 };
        cfg.eval_every = 0; // final eval only
        cfg.eval_batches = 2;
        cfg.comm = CommModel::preset("ethernet").unwrap();
        cfg.tag = format!("fleet-{tag}");
        cfg
    };
    let mv = OuterConfig::MvSignSgd { eta: 1e-3, beta: 0.9, alpha: 0.1, bound: 50.0 };

    let mut runs: Vec<(&'static str, RunConfig)> = Vec::new();
    let mut cfg = base("mv-clean");
    cfg.outer = mv.clone();
    runs.push(("majority vote, clean", cfg));

    let mut cfg = base("mv-drops");
    cfg.outer = mv.clone();
    cfg.faults.drop_prob = 0.10;
    runs.push(("majority vote, 10% drops", cfg));

    let mut cfg = base("mv-storm");
    cfg.outer = mv;
    cfg.faults.churn_prob = 0.25;
    cfg.faults.drop_prob = 0.10;
    cfg.faults.tail_prob = 0.3;
    cfg.faults.tail_scale_s = 2.0;
    runs.push(("majority vote, churn+drops+tails", cfg));

    let mut cfg = base("dense-corrupt");
    cfg.faults.corrupt_prob = 0.30;
    runs.push(("dense mean, 30% corruption", cfg));

    let mut cfg = base("q8-corrupt");
    cfg.wire = Some(WireFormat::QuantizedI8);
    cfg.faults.corrupt_prob = 0.30;
    runs.push(("q8 mean, 30% corruption", cfg));

    writeln!(report, "fleet of 4 under faults ({rounds} rounds x tau=3, native transformer):")?;
    writeln!(
        report,
        "{:<34}{:>9}{:>8}{:>8}{:>9}{:>9}{:>9}{:>11}",
        "run", "val", "absent", "dropped", "corrupt", "rejected", "noquorum", "straggler"
    )?;
    let mut fault_rows: Vec<FaultRow> = Vec::new();
    for (name, cfg) in runs {
        let mut t = Trainer::with_backend(cfg, backend.clone())?;
        let res = t.run()?;
        let f = res.faults;
        writeln!(
            report,
            "{name:<34}{:>9.4}{:>8}{:>8}{:>9}{:>9}{:>9}{:>10.1}s",
            res.final_val,
            f.absent_ranks,
            f.dropped_payloads,
            f.corrupted_payloads,
            f.rejected_payloads,
            f.no_quorum_rounds,
            res.clock.straggler_s,
        )?;
        fault_rows.push(FaultRow {
            name,
            final_val: res.final_val,
            straggler_s: res.clock.straggler_s,
            stats: f,
        });
    }
    writeln!(
        report,
        "\n(corrupt vs rejected: dense NaN poison is always caught; a flipped\n\
         q8 byte is a valid encoding and survives with bounded error —\n\
         only NaN scales are rejected.)"
    )?;

    writeln!(report, "\nfleet_faults OK")?;
    print!("{report}");

    if let Some(out) = args.get("out") {
        // hand-rolled JSON (no serde in-tree), shaped for the CI artifact
        let mut j = String::from("{\n");
        writeln!(j, "  \"preset\": \"{preset}\", \"params\": {p}, \"segments\": {segments},")?;
        writeln!(j, "  \"comm_model\": \"ethernet\",")?;
        writeln!(j, "  \"modeled_exchange\": [")?;
        for (i, (n, fmt, topo, t)) in modeled.iter().enumerate() {
            let sep = if i + 1 == modeled.len() { "" } else { "," };
            writeln!(
                j,
                "    {{\"n\": {n}, \"format\": \"{fmt}\", \"topology\": \"{topo}\", \
                 \"seconds\": {t:.6}}}{sep}"
            )?;
        }
        writeln!(j, "  ],")?;
        writeln!(j, "  \"loss_under_faults\": [")?;
        for (i, r) in fault_rows.iter().enumerate() {
            let sep = if i + 1 == fault_rows.len() { "" } else { "," };
            let s = r.stats;
            writeln!(
                j,
                "    {{\"run\": \"{}\", \"final_val\": {:.6}, \"absent_ranks\": {}, \
                 \"dropped_payloads\": {}, \"corrupted_payloads\": {}, \
                 \"rejected_payloads\": {}, \"no_quorum_rounds\": {}, \
                 \"straggler_s\": {:.3}}}{sep}",
                r.name,
                r.final_val,
                s.absent_ranks,
                s.dropped_payloads,
                s.corrupted_payloads,
                s.rejected_payloads,
                s.no_quorum_rounds,
                r.straggler_s,
            )?;
        }
        writeln!(j, "  ]\n}}")?;
        std::fs::write(out, &j)?;
        eprintln!("wrote {out}");
    }
    Ok(())
}
