//! End-to-end validation driver (the repository's headline example).
//!
//! Trains the `medium` repro-scale GPT-2 analogue from scratch on the
//! synthetic corpus with n = 4 workers under THREE algorithms — per-step
//! AdamW, SlowMo, and the paper's Algorithm 1 — for a few hundred local
//! steps each, logging loss curves, communication rounds, and simulated
//! wall-clock per interconnect.  This is the Figure-1 comparison run as
//! one self-contained binary; results land in runs/pretrain_e2e/.
//!
//!     make artifacts && cargo run --release --example pretrain_e2e
//!         [--preset medium] [--budget 240] [--workers 4]

use anyhow::Result;

use dsm::comm::CommModel;
use dsm::config::{default_peak_lr, RunConfig, TrainMode};
use dsm::optim::BaseOptConfig;
use dsm::outer::OuterConfig;
use dsm::runtime::{Artifacts, ModelBundle, Runtime};
use dsm::train::metrics::{ascii_chart, Axis};
use dsm::train::schedule::ScheduleConfig;
use dsm::train::Trainer;
use dsm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let preset = args.str_or("preset", "medium");
    let budget = args.usize_or("budget", 240).map_err(anyhow::Error::msg)?;
    let workers = args.usize_or("workers", 4).map_err(anyhow::Error::msg)?;
    let tau = 12usize;

    let rt = Runtime::cpu()?;
    let arts = Artifacts::load(&Artifacts::default_dir())?;
    let bundle = std::sync::Arc::new(ModelBundle::load(&rt, arts.preset(&preset)?)?);
    println!(
        "pretrain_e2e: preset={preset} ({} params), n={workers}, tau={tau}, {budget} local steps/alg\n",
        bundle.info.param_count
    );

    let make_cfg = |name: &str, mode: TrainMode, tau: usize, outer: OuterConfig| -> RunConfig {
        let rounds = (budget / tau).max(1);
        let mut cfg = RunConfig::paper_default(&preset);
        cfg.mode = mode;
        cfg.tau = tau;
        cfg.rounds = rounds;
        cfg.n_workers = workers;
        cfg.base = BaseOptConfig::adamw_paper();
        cfg.outer = outer;
        cfg.schedule =
            ScheduleConfig::cosine_paper(default_peak_lr(&preset), (rounds * tau) as u64);
        cfg.eval_every = (rounds / 12).max(1);
        cfg.eval_batches = 6;
        cfg.tag = format!("e2e-{name}");
        cfg
    };

    let configs = [
        ("AdamW", make_cfg("adamw", TrainMode::Standalone, 1, OuterConfig::LocalAvg)),
        (
            "SlowMo",
            make_cfg(
                "slowmo",
                TrainMode::LocalSteps,
                tau,
                OuterConfig::SlowMo { alpha: 1.0, beta: 0.5 },
            ),
        ),
        (
            "Algorithm 1",
            make_cfg(
                "alg1",
                TrainMode::LocalSteps,
                tau,
                OuterConfig::sign_momentum_paper(12.0), // tuned at repro scale (see gpt.rs)
            ),
        ),
    ];

    let mut results = Vec::new();
    for (name, cfg) in configs {
        println!("=== {name}: {} ===", cfg.describe());
        let t0 = std::time::Instant::now();
        let mut trainer = Trainer::with_bundle(cfg.clone(), bundle.clone(), &rt, &arts)?;
        let res = trainer.run_with_progress(|row| {
            if !row.val_loss.is_nan() {
                println!(
                    "  round {:>3}  steps {:>5}  train {:.4}  val {:.4}",
                    row.round, row.local_steps, row.train_loss, row.val_loss
                );
            }
        })?;
        println!(
            "  -> final val {:.4} in {:.0}s wall ({} comm rounds, {:.0} MB)\n",
            res.final_val,
            t0.elapsed().as_secs_f64(),
            res.clock.comm_rounds,
            res.clock.bytes_communicated as f64 / 1e6
        );
        res.log.write_csv(&std::path::PathBuf::from(format!("runs/pretrain_e2e/{name}.csv")))?;
        results.push((name, res));
    }

    // loss-vs-compute chart (the Figure 2 view)
    let curves: Vec<(&str, Vec<(f64, f64)>)> =
        results.iter().map(|(n, r)| (*n, r.log.val_curve(Axis::LocalSteps))).collect();
    println!("{}", ascii_chart("validation loss vs local steps", &curves, 64, 14));

    // time-to-result on two interconnects (the paper's motivation)
    println!("simulated total time (compute measured, comm modeled):");
    let bytes = bundle.info.param_count as u64 * 4;
    for net in ["nvlink", "ethernet", "wan"] {
        let m = CommModel::preset(net).unwrap();
        print!("  {net:>9}: ");
        for (name, r) in &results {
            let total = r.clock.compute_s
                + r.clock.comm_rounds as f64 * m.allreduce_time(workers, bytes);
            print!("{name} {total:>7.1}s   ");
        }
        println!();
    }

    // sanity: every method must have learned something substantial
    for (name, r) in &results {
        assert!(r.final_val < 4.5, "{name} failed to learn: {}", r.final_val);
    }
    println!("\npretrain_e2e OK");
    Ok(())
}
