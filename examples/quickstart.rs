//! Quickstart: the smallest end-to-end use of the public API.
//!
//! Loads the AOT'd `nano` GPT-2 artifacts, trains with **Algorithm 1**
//! (distributed sign momentum, 4 workers, τ = 12) for a handful of
//! communication rounds, and prints the loss curve.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;

use dsm::config::RunConfig;
use dsm::runtime::{Artifacts, Runtime};
use dsm::train::Trainer;

fn main() -> Result<()> {
    // 1. PJRT CPU client + AOT artifacts (produced once by `make artifacts`).
    let rt = Runtime::cpu()?;
    let arts = Artifacts::load(&Artifacts::default_dir())?;
    println!("platform = {}, presets = {:?}", rt.platform(), arts.presets.keys());

    // 2. A run configuration: the paper's defaults on the nano preset.
    let mut cfg = RunConfig::paper_default("nano");
    cfg.rounds = 8; // 8 communication rounds x tau=12 local steps x 4 workers
    cfg.tag = "quickstart".into();
    println!("config: {}", cfg.describe());

    // 3. Train, watching validation loss fall from ~ln(256) = 5.55.
    let mut trainer = Trainer::new(cfg, &rt, &arts)?;
    let result = trainer.run_with_progress(|row| {
        println!(
            "round {:>2}  local steps {:>4}  train loss {:.4}  val loss {:.4}",
            row.round, row.local_steps, row.train_loss, row.val_loss
        );
    })?;

    println!(
        "\nfinal validation loss {:.4} after {} comm rounds \
         ({:.1} MB moved, {:.2}s simulated wall-clock)",
        result.final_val,
        result.clock.comm_rounds,
        result.clock.bytes_communicated as f64 / 1e6,
        result.clock.total_s(),
    );
    assert!(result.final_val < 5.0, "model should beat the uniform baseline");
    println!("quickstart OK");
    Ok(())
}
