//! Byzantine fleet explorer: attack × defense × adversary-fraction grid.
//!
//! Trains the pure-Rust transformer fleet (n = 16) with ⌊frac·n⌋
//! adversarial ranks corrupting their own contributions at the source —
//! sign flips, ×64 scale inflation, fixed-point collusion, or flaky
//! intermittent lying — against one server-side defense per row:
//!
//! * the undefended mean (the baseline the attacks are built to poison),
//! * coordinate-wise trimmed mean / median on the dense wire,
//! * trimmed mean composed with the `q8pt` and sparse `topk` wires,
//! * MV-sto-signSGD's 1-bit majority tally (robust by construction),
//! * the undefended mean plus the reputation/quarantine supervisor.
//!
//! Every cell reports final validation loss, a divergence flag, and the
//! fault counters (quarantined ranks, re-admissions, Byzantine rounds
//! survived), so "the defense held" is a number, not a vibe.
//!
//!     cargo run --release --example robust_agg [--quick] [--out FILE]
//!
//! Runs entirely on the native backend — no PJRT artifacts needed.
//! `--quick` shrinks the grid to the collusion attack at one adversary
//! fraction for smoke runs; `--out` writes the machine-readable report
//! that CI uploads as `BENCH_robust.json`.

use std::fmt::Write as _;
use std::sync::Arc;

use anyhow::Result;

use dsm::comm::{Attack, CommModel, FaultStats};
use dsm::config::RunConfig;
use dsm::dist::{AggPolicy, WireFormat};
use dsm::outer::OuterConfig;
use dsm::runtime::{NativeBundle, StepBackend};
use dsm::train::Trainer;
use dsm::util::cli::Args;

/// Loss of the uniform distribution over bytes — a run at or above this
/// has learned nothing (or un-learned everything); together with a
/// mid-run finiteness trip it defines the `diverged` flag.
const RANDOM_LOSS: f64 = 5.545; // ln 256

struct Defense {
    name: &'static str,
    wire: Option<WireFormat>,
    agg: AggPolicy,
    mv: bool,
    quarantine: bool,
}

struct Cell {
    defense: &'static str,
    attack: &'static str,
    frac: f64,
    final_val: f64,
    diverged: bool,
    stats: FaultStats,
}

fn main() -> Result<()> {
    let args = Args::parse_with_bools(std::env::args().skip(1), &["quick"])
        .map_err(anyhow::Error::msg)?;
    let quick = args.has("quick");

    let preset = "native";
    let n = 16usize;
    // 2 transformer blocks — a real multi-segment layout, so the q8pt
    // and topk defenses exercise their per-segment paths
    let backend: Arc<NativeBundle> = if quick {
        Arc::new(NativeBundle::transformer(preset, 2, 12, 8, 2))
    } else {
        Arc::new(NativeBundle::transformer(preset, 2, 24, 16, 2))
    };
    let p = backend.info().param_count;
    let rounds = if quick { 3 } else { 8 };

    let defenses: &[Defense] = &[
        Defense {
            name: "dense + mean (undefended)",
            wire: None,
            agg: AggPolicy::Mean,
            mv: false,
            quarantine: false,
        },
        Defense {
            name: "dense + trimmed",
            wire: None,
            agg: AggPolicy::Trimmed,
            mv: false,
            quarantine: false,
        },
        Defense {
            name: "dense + median",
            wire: None,
            agg: AggPolicy::Median,
            mv: false,
            quarantine: false,
        },
        Defense {
            name: "q8pt + trimmed",
            wire: Some(WireFormat::QuantizedI8PerTensor),
            agg: AggPolicy::Trimmed,
            mv: false,
            quarantine: false,
        },
        Defense {
            name: "topk + trimmed",
            wire: Some(WireFormat::TOPK_DEFAULT),
            agg: AggPolicy::Trimmed,
            mv: false,
            quarantine: false,
        },
        Defense {
            name: "signs + MV tally",
            wire: None,
            agg: AggPolicy::Mean,
            mv: true,
            quarantine: false,
        },
        Defense {
            name: "dense + mean + quarantine",
            wire: None,
            agg: AggPolicy::Mean,
            mv: false,
            quarantine: true,
        },
    ];
    // collusion is the attack the undefended mean cannot shrug off at
    // any fraction — the quick grid keeps exactly that contrast
    let attacks: &[Attack] = if quick {
        &[Attack::ColludeFixed]
    } else {
        &[Attack::SignFlip, Attack::ScaleInflate, Attack::ColludeFixed, Attack::Flaky]
    };
    let fracs: &[f64] = if quick { &[0.125] } else { &[1.0 / 16.0, 0.125, 0.25] };

    let base = |tag: &str| {
        let mut cfg = RunConfig::paper_default(preset);
        cfg.rounds = rounds;
        cfg.tau = 3;
        cfg.n_workers = n;
        cfg.corpus_bytes = if quick { 1 << 16 } else { 1 << 18 };
        cfg.eval_every = 0; // final eval only
        cfg.eval_batches = 2;
        cfg.comm = CommModel::preset("ethernet").unwrap();
        cfg.tag = format!("robust-{tag}");
        cfg
    };
    let configure = |d: &Defense, tag: &str| {
        let mut cfg = base(tag);
        cfg.wire = d.wire;
        cfg.agg = d.agg;
        if d.mv {
            cfg.outer = OuterConfig::MvSignSgd { eta: 1e-3, beta: 0.9, alpha: 0.1, bound: 50.0 };
        } else {
            // plain averaging: the paper-default sign-momentum outer
            // would neutralize scale attacks for free (the sign bounds
            // every coordinate), hiding exactly the contrast this grid
            // exists to show
            cfg.outer = OuterConfig::LocalAvg;
        }
        cfg
    };

    let mut report = String::new();
    writeln!(
        report,
        "robust_agg: preset={preset} (P={p}), fleet of {n}, {rounds} rounds x tau=3\n"
    )?;
    writeln!(
        report,
        "{:<27}{:<15}{:>6}{:>10}{:>5}{:>6}{:>6}{:>6}",
        "defense", "attack", "frac", "val", "div", "quar", "readm", "byzrd"
    )?;

    let mut cells: Vec<Cell> = Vec::new();
    for d in defenses {
        // the fault-free baseline row for this defense (frac = 0)
        let mut grid: Vec<(&'static str, f64)> = vec![("none", 0.0)];
        for a in attacks {
            for &f in fracs {
                grid.push((a.name(), f));
            }
        }
        for (attack_name, frac) in grid {
            let tag = format!("{}-{}-f{:.4}", d.name.replace(' ', ""), attack_name, frac);
            let mut cfg = configure(d, &tag);
            if frac > 0.0 {
                cfg.faults.byzantine_frac = frac;
                cfg.faults.attack = Attack::parse(attack_name).unwrap();
                // quarantine needs adversaries to hunt — validation
                // rejects the flag on a clean fleet
                cfg.faults.quarantine = d.quarantine;
            }
            let mut t = Trainer::with_backend(cfg, backend.clone())?;
            // a poisoned mean tripping the finiteness guard mid-run IS
            // the result — record it as a divergence, don't abort
            let (final_val, diverged, stats) = match t.run() {
                Ok(res) => {
                    let div = !res.final_val.is_finite() || res.final_val >= RANDOM_LOSS;
                    (res.final_val, div, res.faults)
                }
                Err(_) => (f64::NAN, true, *t.fault_stats()),
            };
            writeln!(
                report,
                "{:<27}{:<15}{:>6.3}{:>10}{:>5}{:>6}{:>6}{:>6}",
                d.name,
                attack_name,
                frac,
                if final_val.is_nan() { "-".into() } else { format!("{final_val:.4}") },
                if diverged { "yes" } else { "" },
                stats.quarantined_ranks,
                stats.readmissions,
                stats.byzantine_rounds_survived,
            )?;
            cells.push(Cell {
                defense: d.name,
                attack: attack_name,
                frac,
                final_val,
                diverged,
                stats,
            });
        }
    }
    writeln!(
        report,
        "\n(expected shape: the undefended mean diverges under scale_inflate\n\
         and collude_fixed while every trimmed/median/tally row stays near\n\
         its frac=0 baseline; the quarantine row starts poisoned, freezes\n\
         the liars within a few rounds, and recovers.)"
    )?;
    writeln!(report, "\nrobust_agg OK")?;
    print!("{report}");

    if let Some(out) = args.get("out") {
        // hand-rolled JSON (no serde in-tree), shaped for the CI artifact
        let mut j = String::from("{\n");
        writeln!(j, "  \"preset\": \"{preset}\", \"params\": {p}, \"workers\": {n},")?;
        writeln!(j, "  \"rounds\": {rounds}, \"quick\": {quick},")?;
        writeln!(j, "  \"grid\": [")?;
        for (i, c) in cells.iter().enumerate() {
            let sep = if i + 1 == cells.len() { "" } else { "," };
            let val = if c.final_val.is_finite() {
                format!("{:.6}", c.final_val)
            } else {
                "null".into()
            };
            let s = c.stats;
            writeln!(
                j,
                "    {{\"defense\": \"{}\", \"attack\": \"{}\", \"frac\": {:.6}, \
                 \"final_val\": {val}, \"diverged\": {}, \"quarantined_ranks\": {}, \
                 \"readmissions\": {}, \"byzantine_rounds_survived\": {}, \
                 \"retried_payloads\": {}, \"no_quorum_rounds\": {}}}{sep}",
                c.defense,
                c.attack,
                c.frac,
                c.diverged,
                s.quarantined_ranks,
                s.readmissions,
                s.byzantine_rounds_survived,
                s.retried_payloads,
                s.no_quorum_rounds,
            )?;
        }
        writeln!(j, "  ]\n}}")?;
        std::fs::write(out, &j)?;
        eprintln!("wrote {out}");
    }
    Ok(())
}
