//! Theory playground: Algorithm 1 with SGD base on analytic problems
//! (no PJRT needed), sweeping the knobs of Theorems 1-3 interactively.
//!
//!     cargo run --release --example theory_validation
//!         [--dim 64] [--workers 8] [--tau 4] [--sigma 0.5] [--delta 0.5]
//!
//! Prints, for each sign operator (exact / eq.9 / eq.10), the decay of
//! the theorem-bounded quantities over a grid of horizons T, with the
//! fitted log-log rate exponent next to the theoretical guarantee.

use anyhow::Result;

use dsm::sign::SignOp;
use dsm::sim::{loglog_slope, run_sign_momentum, HeterogeneousQuadratic, SimSpec};
use dsm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let dim = args.usize_or("dim", 64).map_err(anyhow::Error::msg)?;
    let n = args.usize_or("workers", 8).map_err(anyhow::Error::msg)?;
    let tau = args.usize_or("tau", 4).map_err(anyhow::Error::msg)?;
    let sigma = args.f32_or("sigma", 0.5).map_err(anyhow::Error::msg)?;
    let delta = args.f32_or("delta", 0.5).map_err(anyhow::Error::msg)?;

    let problem = HeterogeneousQuadratic::new(dim, n, sigma, delta, 11);
    println!(
        "theory_validation: quadratic d={dim}, n={n}, tau={tau}, sigma={sigma}, delta={delta}\n"
    );

    for op in [SignOp::Exact, SignOp::RandPm, SignOp::RandZero] {
        let mut pts_sq = Vec::new();
        let mut pts_l1 = Vec::new();
        println!("sign operator: {op:?}");
        println!("{:>8} {:>10} {:>16} {:>16}", "T", "gamma", "mean||g||^2", "mean||g||_1");
        for rounds in [64usize, 256, 1024, 4096] {
            let gamma = 0.25 * ((n * tau) as f32 / rounds as f32).sqrt();
            let spec = SimSpec {
                n_workers: n,
                tau,
                rounds,
                gamma,
                eta: 4.0 * tau as f32,
                beta1: 0.9,
                beta2: 0.9,
                sign_op: op,
                sign_bound: 4.0 * tau as f32,
                seed: 5,
            };
            let res = run_sign_momentum(&problem, &spec);
            println!(
                "{rounds:>8} {gamma:>10.4} {:>16.4e} {:>16.4}",
                res.mean_sq_grad_norm, res.mean_l1_grad_norm
            );
            pts_sq.push((rounds as f64, res.mean_sq_grad_norm));
            pts_l1.push((rounds as f64, res.mean_l1_grad_norm));
        }
        println!(
            "  fitted: ||g||^2 ~ T^{:.3} (Thm 1/2 bound: -0.5) | ||g||_1 ~ T^{:.3} (Thm 3 bound: -0.25)\n",
            loglog_slope(&pts_sq),
            loglog_slope(&pts_l1)
        );
    }
    println!("theory_validation OK");
    Ok(())
}
