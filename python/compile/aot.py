"""AOT compile path: lower the L2 model (+ L1 kernels) to HLO *text*.

This is the only place Python touches the system; it runs once under
`make artifacts` and never on the training hot path.  For every model
preset it emits three executables plus one shared kernel artifact:

    artifacts/<preset>_init.hlo.txt    init_step(seed u32[]) -> f32[P]
    artifacts/<preset>_train.hlo.txt   train_step(params, tok, tgt) -> (loss, grads)
    artifacts/<preset>_eval.hlo.txt    eval_step(params, tok, tgt) -> loss
    artifacts/sign_update.hlo.txt      fused Algorithm-1 global step (chunked)
    artifacts/manifest.json            shapes, param layout, file index

Interchange format is HLO **text**, NOT a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
Lowering goes through stablehlo -> XlaComputation with return_tuple=True;
the Rust runtime unwraps the tuple.
"""

import argparse
import hashlib
import json
import pathlib
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import PRESETS, SIGN_UPDATE_BLOCK, SIGN_UPDATE_CHUNK
from .kernels.sign_update import sign_update_chunk

MANIFEST_VERSION = 1
DEFAULT_PRESETS = ["nano", "small", "medium", "large"]


def to_hlo_text(lowered) -> str:
    """Lowered jax function -> HLO text via stablehlo -> XlaComputation."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path: pathlib.Path, text: str) -> dict:
    path.write_text(text)
    return {
        "file": path.name,
        "bytes": len(text),
        "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
    }


def emit_preset(name: str, out: pathlib.Path, verbose: bool = True) -> dict:
    cfg = PRESETS[name]
    p = model.param_count(cfg)
    fspec = jax.ShapeDtypeStruct((p,), jnp.float32)
    tspec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)
    sspec = jax.ShapeDtypeStruct((), jnp.uint32)

    entry = {"config": cfg.to_dict(), "param_count": p, "artifacts": {}}
    lowerings = {
        "init": jax.jit(lambda s: (model.init_step(cfg, s),)).lower(sspec),
        "train": jax.jit(lambda f, a, b: model.train_step(cfg, f, a, b)).lower(
            fspec, tspec, tspec
        ),
        "eval": jax.jit(lambda f, a, b: (model.eval_step(cfg, f, a, b),)).lower(
            fspec, tspec, tspec
        ),
    }
    for kind, lowered in lowerings.items():
        t0 = time.time()
        info = _write(out / f"{name}_{kind}.hlo.txt", to_hlo_text(lowered))
        entry["artifacts"][kind] = info
        if verbose:
            print(
                f"  {name}_{kind}: {info['bytes'] / 1e6:.2f} MB "
                f"({time.time() - t0:.1f}s)"
            )
    entry["param_layout"] = [
        {"name": n, "offset": off, "shape": list(shape)}
        for n, (off, shape) in model.param_offsets(cfg).items()
    ]
    return entry


def emit_sign_update(out: pathlib.Path) -> dict:
    vspec = jax.ShapeDtypeStruct((SIGN_UPDATE_CHUNK,), jnp.float32)
    sspec = jax.ShapeDtypeStruct((8,), jnp.float32)
    lowered = jax.jit(
        lambda x, m, d, s: sign_update_chunk(x, m, d, s)
    ).lower(vspec, vspec, vspec, sspec)
    info = _write(out / "sign_update.hlo.txt", to_hlo_text(lowered))
    info.update({"chunk": SIGN_UPDATE_CHUNK, "block": SIGN_UPDATE_BLOCK})
    return info


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument(
        "--presets",
        default=",".join(DEFAULT_PRESETS),
        help="comma-separated preset names (see configs.PRESETS); 'all' "
        "includes the full-size gpt2s proof-of-AOT",
    )
    args = ap.parse_args()
    names = (
        list(PRESETS) if args.presets == "all" else args.presets.split(",")
    )
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    manifest = {
        "version": MANIFEST_VERSION,
        "jax_version": jax.__version__,
        "presets": {},
    }
    for name in names:
        print(f"preset {name} ...")
        manifest["presets"][name] = emit_preset(name, out)
    print("sign_update kernel ...")
    manifest["sign_update"] = emit_sign_update(out)

    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {out / 'manifest.json'}")


if __name__ == "__main__":
    main()
