"""Model presets shared by model.py, aot.py, and the pytest suite.

Each preset fixes the *static* shapes baked into the AOT artifacts:
(batch B, sequence S) for the train/eval steps, and the transformer
dimensions.  The Rust side reads these back from artifacts/manifest.json.

The `nano`..`large` presets are the scaled-down analogues of the paper's
GPT-2 Small/Medium/Large (Table 1) sized for a single-CPU-core testbed;
`gpt2s` is the paper's actual Small config (used to prove the full-size
model AOTs; not swept in experiments). See DESIGN.md §3 "Scale".
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_head: int
    n_layer: int
    seq: int
    batch: int
    # Pallas attention block sizes (queries / keys per tile).
    block_q: int = 32
    block_k: int = 32

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_head == 0
        return self.d_model // self.n_head

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    def to_dict(self) -> dict:
        d = asdict(self)
        d["d_head"] = self.d_head
        d["d_ff"] = self.d_ff
        return d


PRESETS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        # CI / unit-test scale.
        ModelConfig("nano", vocab=256, d_model=96, n_head=3, n_layer=3, seq=64, batch=8),
        # Paper-analogue sweep presets (Small / Medium / Large stand-ins).
        ModelConfig("small", vocab=256, d_model=128, n_head=4, n_layer=4, seq=64, batch=8),
        ModelConfig("medium", vocab=256, d_model=192, n_head=6, n_layer=6, seq=64, batch=8),
        ModelConfig("large", vocab=256, d_model=256, n_head=8, n_layer=8, seq=64, batch=8),
        # Paper's GPT-2 Small (Table 1); AOT-proof only on this testbed.
        ModelConfig(
            "gpt2s", vocab=50257, d_model=768, n_head=12, n_layer=12, seq=256, batch=1,
            block_q=64, block_k=64,
        ),
    ]
}

# Chunk length for the fused sign-momentum update artifact: the Rust
# coordinator applies the update over the flat parameter vector in chunks
# of this many f32s (last chunk zero-padded), so ONE artifact serves every
# model preset.
SIGN_UPDATE_CHUNK = 65536
SIGN_UPDATE_BLOCK = 4096
