"""L1 Pallas kernel: fused causal flash attention (forward + backward).

The paper trains GPT-2; its compute hot-spot is causal self-attention.
The authors ran CUDA/PyTorch — here the kernel is re-thought for the TPU
execution model per DESIGN.md §6: instead of threadblocks staging tiles
through shared memory, `BlockSpec`s express the HBM->VMEM schedule, the
grid walks (batch, head, query-block), and the inner loop streams
key/value tiles through VMEM with an online-softmax accumulator (the
standard flash decomposition).  All matmuls are f32 `jnp.dot`s that map
onto the MXU at full scale.

`pallas_call` is not differentiable by default, so the public entry point
`flash_attention` carries a custom VJP: the forward kernel saves the
per-row logsumexp, and two backward kernels (one gridded over query
blocks for dQ, one over key blocks for dK/dV) recompute probabilities
flash-style instead of materializing the S x S matrix.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that the Rust
runtime loads.  Correctness is pinned to kernels/ref.py by pytest.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, block_q, block_k, scale):
    """One (batch, head, q-block) program of the flash forward pass."""
    qi = pl.program_id(2)
    q = q_ref[0, 0] * scale  # (block_q, d_head)
    seq = k_ref.shape[2]
    d_head = q_ref.shape[3]
    num_kb = seq // block_k

    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    def body(j, carry):
        acc, m_i, l_i = carry
        k = k_ref[0, 0, pl.dslice(j * block_k, block_k), :]  # (block_k, d)
        v = v_ref[0, 0, pl.dslice(j * block_k, block_k), :]
        s = jnp.dot(q, k.T)  # (block_q, block_k)
        k_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = q_pos[:, None] >= k_pos[None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        # Fully-masked tiles contribute exp(NEG_INF - finite) == 0; keeping
        # the loop bound static (num_kb, not qi+1) costs nothing under
        # interpret and keeps the lowered HLO a fixed-trip-count loop.  On
        # real TPU the bound would be qi+1 to skip above-diagonal tiles.
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(p, v)
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, d_head), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, m_i, l_i = jax.lax.fori_loop(0, num_kb, body, (acc0, m0, l0))

    o_ref[0, 0] = acc / l_i[:, None]
    lse_ref[0, 0] = m_i + jnp.log(l_i)


def _bwd_dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, *, block_q, block_k, scale
):
    """dQ for one (batch, head, q-block): stream K/V tiles, recompute P."""
    qi = pl.program_id(2)
    q = q_ref[0, 0]
    do = do_ref[0, 0]
    lse = lse_ref[0, 0]  # (block_q,)
    delta = delta_ref[0, 0]
    seq = k_ref.shape[2]
    num_kb = seq // block_k
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    def body(j, dq):
        k = k_ref[0, 0, pl.dslice(j * block_k, block_k), :]
        v = v_ref[0, 0, pl.dslice(j * block_k, block_k), :]
        s = jnp.dot(q, k.T) * scale
        k_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = q_pos[:, None] >= k_pos[None, :]
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])  # masked entries -> 0
        dp = jnp.dot(do, v.T)
        ds = p * (dp - delta[:, None]) * scale
        return dq + jnp.dot(ds, k)

    dq = jax.lax.fori_loop(
        0, num_kb, body, jnp.zeros((block_q, q_ref.shape[3]), jnp.float32)
    )
    dq_ref[0, 0] = dq


def _bwd_dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref, *, block_q, block_k, scale
):
    """dK/dV for one (batch, head, k-block): stream Q/dO tiles."""
    kj = pl.program_id(2)
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    seq = q_ref.shape[2]
    num_qb = seq // block_q
    k_pos = kj * block_k + jax.lax.iota(jnp.int32, block_k)

    def body(i, carry):
        dk, dv = carry
        q = q_ref[0, 0, pl.dslice(i * block_q, block_q), :]
        do = do_ref[0, 0, pl.dslice(i * block_q, block_q), :]
        lse = lse_ref[0, 0, pl.dslice(i * block_q, block_q)]
        delta = delta_ref[0, 0, pl.dslice(i * block_q, block_q)]
        s = jnp.dot(q, k.T) * scale  # (block_q, block_k)
        q_pos = i * block_q + jax.lax.iota(jnp.int32, block_q)
        mask = q_pos[:, None] >= k_pos[None, :]
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dv = dv + jnp.dot(p.T, do)
        dp = jnp.dot(do, v.T)
        ds = p * (dp - delta[:, None]) * scale
        dk = dk + jnp.dot(ds.T, q)
        return dk, dv

    d_head = k_ref.shape[3]
    dk0 = jnp.zeros((block_k, d_head), jnp.float32)
    dv0 = jnp.zeros((block_k, d_head), jnp.float32)
    dk, dv = jax.lax.fori_loop(0, num_qb, body, (dk0, dv0))
    dk_ref[0, 0] = dk
    dv_ref[0, 0] = dv


def _flash_fwd(q, k, v, block_q, block_k):
    b, h, s, d = q.shape
    scale = 1.0 / (d**0.5)
    grid = (b, h, s // block_q)
    qspec = pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0))
    kvspec = pl.BlockSpec((1, 1, s, d), lambda bi, hi, qi: (bi, hi, 0, 0))
    o, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, block_q=block_q, block_k=block_k, scale=scale),
        grid=grid,
        in_specs=[qspec, kvspec, kvspec],
        out_specs=[
            qspec,
            pl.BlockSpec((1, 1, block_q), lambda bi, hi, qi: (bi, hi, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, s), jnp.float32),
        ],
        interpret=True,
    )(q, k, v)
    return o, lse


def _flash_bwd(q, k, v, o, lse, do, block_q, block_k):
    b, h, s, d = q.shape
    scale = 1.0 / (d**0.5)
    delta = jnp.sum(do * o, axis=-1)  # (b, h, s)

    full = pl.BlockSpec((1, 1, s, d), lambda bi, hi, i: (bi, hi, 0, 0))
    full_row = pl.BlockSpec((1, 1, s), lambda bi, hi, i: (bi, hi, 0))
    qblk = pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, i: (bi, hi, i, 0))
    qrow = pl.BlockSpec((1, 1, block_q), lambda bi, hi, i: (bi, hi, i))
    kblk = pl.BlockSpec((1, 1, block_k, d), lambda bi, hi, i: (bi, hi, i, 0))

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, block_q=block_q, block_k=block_k, scale=scale),
        grid=(b, h, s // block_q),
        in_specs=[qblk, full, full, qblk, qrow, qrow],
        out_specs=qblk,
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), jnp.float32),
        interpret=True,
    )(q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, block_q=block_q, block_k=block_k, scale=scale),
        grid=(b, h, s // block_k),
        in_specs=[full, kblk, kblk, full, full_row, full_row],
        out_specs=[kblk, kblk],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, s, d), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q, k, v, block_q=32, block_k=32):
    """Causal flash attention. q/k/v: f32[B, H, S, Dh] -> f32[B, H, S, Dh].

    S must be a multiple of both block sizes (model presets guarantee it).
    """
    o, _ = _flash_fwd(q, k, v, block_q, block_k)
    return o


def _vjp_fwd(q, k, v, block_q, block_k):
    o, lse = _flash_fwd(q, k, v, block_q, block_k)
    return o, (q, k, v, o, lse)


def _vjp_bwd(block_q, block_k, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = _flash_bwd(q, k, v, o, lse, do, block_q, block_k)
    return dq, dk, dv


flash_attention.defvjp(_vjp_fwd, _vjp_bwd)
