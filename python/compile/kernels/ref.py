"""Pure-jnp oracles for the Pallas kernels.

These are the CORRECTNESS ground truth: every Pallas kernel in this
package must match its oracle here to float32 tolerance (pytest +
hypothesis sweeps in python/tests/test_kernels.py). They are also what
the kernels' performance is judged against in the L1 perf pass.
"""

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Causal multi-head attention, materialized-softmax reference.

    Args:
      q, k, v: f32[B, H, S, Dh]
    Returns:
      f32[B, H, S, Dh]
    """
    s = q.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def attention_lse_ref(q: jax.Array, k: jax.Array, v: jax.Array):
    """Reference that also returns the per-row logsumexp (flash residual)."""
    s = q.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    probs = jnp.exp(logits - lse[..., None])
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v), lse


def sign_update_ref(x, m, diff, gamma, eta, lam, beta1, beta2):
    """Oracle for the fused global sign-momentum step (paper eqs. (6)-(8)).

    u      = beta1 * m + (1 - beta1) / gamma * diff
    x_new  = x - eta * gamma * (sign(u) + lam * x)
    m_new  = beta2 * m + (1 - beta2) / gamma * diff

    where diff = x_{t,0} - x_{t,tau} (aggregated local-step differences).
    """
    u = beta1 * m + (1.0 - beta1) / gamma * diff
    x_new = x - eta * gamma * (jnp.sign(u) + lam * x)
    m_new = beta2 * m + (1.0 - beta2) / gamma * diff
    return x_new, m_new
