"""L1 Pallas kernel: fused global sign-momentum parameter update.

This is the paper's own contribution rendered as a single fused kernel —
eqs. (6)-(8) of Algorithm 1 (the Lion-style global step over aggregated
local differences):

    u     = beta1 * m + (1 - beta1) / gamma * diff
    x_new = x - eta * gamma * (sign(u) + lambda * x)
    m_new = beta2 * m + (1 - beta2) / gamma * diff

One kernel performs the whole step with x, m, diff streamed through VMEM
exactly once (three reads, two writes per element) — on TPU this is the
memory-bandwidth-optimal schedule; a naive composition of elementwise ops
would traverse HBM five-plus times unless XLA happens to fuse it.

The artifact is chunked: it operates on a fixed-length f32[CHUNK] slab so
one compiled executable serves every model size; the Rust coordinator
walks the flat parameter vector in CHUNK-sized windows (zero-padding the
tail).  Scalars arrive as an f32[8] operand so learning-rate schedules do
not force recompilation.

The production hot path in Rust implements the same update natively
(rust/src/outer/sign_momentum.rs); this kernel is the TPU story plus a
three-way equivalence anchor (pallas == jnp ref == rust, tested).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..configs import SIGN_UPDATE_BLOCK, SIGN_UPDATE_CHUNK


def _kernel(s_ref, x_ref, m_ref, d_ref, xo_ref, mo_ref):
    gamma = s_ref[0]
    eta = s_ref[1]
    lam = s_ref[2]
    beta1 = s_ref[3]
    beta2 = s_ref[4]
    x = x_ref[...]
    m = m_ref[...]
    d = d_ref[...]
    u = beta1 * m + (1.0 - beta1) / gamma * d
    xo_ref[...] = x - eta * gamma * (jnp.sign(u) + lam * x)
    mo_ref[...] = beta2 * m + (1.0 - beta2) / gamma * d


def sign_update(x, m, diff, scalars, *, block=SIGN_UPDATE_BLOCK):
    """Fused Algorithm-1 global step over one chunk.

    Args:
      x, m, diff: f32[N] with N % block == 0.
      scalars: f32[8] = [gamma, eta, lambda, beta1, beta2, pad, pad, pad].
    Returns:
      (x_new, m_new): f32[N] each.
    """
    n = x.shape[0]
    assert n % block == 0, (n, block)
    vspec = pl.BlockSpec((block,), lambda i: (i,))
    sspec = pl.BlockSpec((8,), lambda i: (0,))
    return pl.pallas_call(
        _kernel,
        grid=(n // block,),
        in_specs=[sspec, vspec, vspec, vspec],
        out_specs=[vspec, vspec],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(scalars, x, m, diff)


@functools.partial(jax.jit, static_argnames=("chunk",))
def sign_update_chunk(x, m, diff, scalars, chunk=SIGN_UPDATE_CHUNK):
    """The AOT entry point: fixed-size chunk used by the Rust runtime."""
    assert x.shape == (chunk,)
    return sign_update(x, m, diff, scalars)
