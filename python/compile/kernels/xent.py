"""L1 Pallas kernel: fused softmax cross-entropy over vocab blocks.

The LM-head loss is the other memory-bound hot spot of GPT-2 training:
materializing log-softmax over [B*S, V] writes the full logits tensor
twice.  This kernel fuses the three passes flash-style — one grid
program per row-block streams vocab tiles through VMEM keeping only the
running (max, sumexp, picked-logit) triple, so the [rows, V] logits are
read exactly once and nothing of that size is written.

Used by `model.loss_fn` when a preset opts in (`use_xent_kernel`, an
extension knob — default artifacts keep the jnp path so existing run
caches stay valid); correctness is pinned to ref.py by pytest either
way.  Forward-only by design: the backward of cross-entropy
(softmax - onehot) is formed by XLA from the same streamed quantities
via the custom VJP below.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fwd_kernel(logits_ref, targets_ref, nll_ref, lse_ref, *, block_v):
    """One row-block program: stream vocab tiles, keep (max, sum, picked)."""
    rows = logits_ref.shape[0]
    v = logits_ref.shape[1]
    num_vb = v // block_v
    tgt = targets_ref[...]  # (rows,)

    def body(j, carry):
        m, s, picked = carry
        tile = logits_ref[:, pl.dslice(j * block_v, block_v)]  # (rows, bv)
        m_new = jnp.maximum(m, jnp.max(tile, axis=-1))
        s = s * jnp.exp(m - m_new) + jnp.sum(jnp.exp(tile - m_new[:, None]), axis=-1)
        # pick the target logit if it lives in this tile
        col = tgt - j * block_v
        in_tile = (col >= 0) & (col < block_v)
        idx = jnp.clip(col, 0, block_v - 1)
        val = jnp.take_along_axis(tile, idx[:, None], axis=1)[:, 0]
        picked = jnp.where(in_tile, val, picked)
        return m_new, s, picked

    m0 = jnp.full((rows,), NEG_INF, jnp.float32)
    s0 = jnp.zeros((rows,), jnp.float32)
    p0 = jnp.full((rows,), NEG_INF, jnp.float32)
    m, s, picked = jax.lax.fori_loop(0, num_vb, body, (m0, s0, p0))
    lse = m + jnp.log(s)
    nll_ref[...] = lse - picked
    lse_ref[...] = lse


def _xent_fwd(logits, targets, block_rows, block_v):
    rows, v = logits.shape
    grid = (rows // block_rows,)
    nll, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, block_v=block_v),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, v), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows,), jnp.float32),
            jax.ShapeDtypeStruct((rows,), jnp.float32),
        ],
        interpret=True,
    )(logits, targets)
    return nll, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def softmax_xent(logits, targets, block_rows=64, block_v=128):
    """Per-row NLL: f32[R, V], i32[R] -> f32[R].

    R must be a multiple of block_rows and V of block_v (model presets
    pad the row count; byte vocab 256 = 2 x 128).
    """
    nll, _ = _xent_fwd(logits, targets, block_rows, block_v)
    return nll


def _vjp_fwd(logits, targets, block_rows, block_v):
    nll, lse = _xent_fwd(logits, targets, block_rows, block_v)
    return nll, (logits, targets, lse)


def _vjp_bwd(block_rows, block_v, res, g):
    logits, targets, lse = res
    # d/dlogits = softmax(logits) - onehot(target), scaled by upstream g
    probs = jnp.exp(logits - lse[:, None])
    onehot = jax.nn.one_hot(targets, logits.shape[1], dtype=logits.dtype)
    return (g[:, None] * (probs - onehot), None)


softmax_xent.defvjp(_vjp_fwd, _vjp_bwd)
