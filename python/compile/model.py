"""L2: GPT-2 forward/backward in JAX, calling the L1 Pallas kernels.

The model follows the paper's experimental setup (GPT-2, Radford et al.
2019, as in nanoGPT): learned token + position embeddings, pre-LayerNorm
blocks of (causal self-attention, 4x GELU MLP) with residual connections,
final LayerNorm and a weight-tied LM head; attention is the Pallas flash
kernel from kernels/attention.py.

**Flat-parameter ABI.**  Everything the Rust coordinator touches is ONE
f32[P] vector.  `param_spec` fixes a deterministic (name, shape, offset)
layout; `unflatten` slices it back into tensors *inside* the traced
function, so the split is free after XLA compilation.  This is what makes
the paper's algorithms trivial on the Rust side: every optimizer in
rust/src/{optim,outer} is an elementwise loop over that vector.

AOT surface (lowered to HLO text by aot.py):
  init_step(seed: u32[])                          -> f32[P]
  train_step(params: f32[P], tok, tgt: i32[B,S])  -> (loss f32[], grads f32[P])
  eval_step (params: f32[P], tok, tgt: i32[B,S])  -> loss f32[]
"""

import functools

import math

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.attention import flash_attention

LN_EPS = 1e-5


# --------------------------------------------------------------------------
# Flat-parameter layout
# --------------------------------------------------------------------------


def param_spec(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Deterministic (name, shape) layout of the flat parameter vector."""
    d, ff, v, s = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq
    spec: list[tuple[str, tuple[int, ...]]] = [
        ("wte", (v, d)),
        ("wpe", (s, d)),
    ]
    for layer in range(cfg.n_layer):
        p = f"h{layer}."
        spec += [
            (p + "ln1_g", (d,)),
            (p + "ln1_b", (d,)),
            (p + "qkv_w", (d, 3 * d)),
            (p + "qkv_b", (3 * d,)),
            (p + "proj_w", (d, d)),
            (p + "proj_b", (d,)),
            (p + "ln2_g", (d,)),
            (p + "ln2_b", (d,)),
            (p + "fc_w", (d, ff)),
            (p + "fc_b", (ff,)),
            (p + "fc2_w", (ff, d)),
            (p + "fc2_b", (d,)),
        ]
    spec += [("lnf_g", (d,)), ("lnf_b", (d,))]
    return spec


def param_count(cfg: ModelConfig) -> int:
    return sum(math.prod(shape) for _, shape in param_spec(cfg))


def param_offsets(cfg: ModelConfig) -> dict[str, tuple[int, tuple[int, ...]]]:
    """name -> (offset, shape) for the manifest and the Rust inspector."""
    out, off = {}, 0
    for name, shape in param_spec(cfg):
        out[name] = (off, shape)
        off += math.prod(shape)
    return out


def unflatten(cfg: ModelConfig, flat: jax.Array) -> dict[str, jax.Array]:
    params, off = {}, 0
    for name, shape in param_spec(cfg):
        n = math.prod(shape)
        params[name] = flat[off : off + n].reshape(shape)
        off += n
    return params


def flatten(cfg: ModelConfig, params: dict[str, jax.Array]) -> jax.Array:
    return jnp.concatenate(
        [params[name].reshape(-1) for name, _ in param_spec(cfg)]
    )


# --------------------------------------------------------------------------
# Initialization (GPT-2 scheme, nanoGPT-compatible)
# --------------------------------------------------------------------------


def init_step(cfg: ModelConfig, seed: jax.Array) -> jax.Array:
    """GPT-2 init as one flat vector; `seed` is a traced uint32 scalar so
    the Rust launcher re-seeds without re-AOT-ing."""
    key = jax.random.key(seed)
    spec = param_spec(cfg)
    keys = jax.random.split(key, len(spec))
    # Residual-branch output projections get the 1/sqrt(2*n_layer) shrink.
    resid_scale = 0.02 / jnp.sqrt(2.0 * cfg.n_layer)
    parts = []
    for (name, shape), k in zip(spec, keys):
        base = name.split(".")[-1]
        if base in ("ln1_g", "ln2_g", "lnf_g"):
            t = jnp.ones(shape, jnp.float32)
        elif base.endswith("_b") or base in ("qkv_b", "fc_b", "fc2_b", "proj_b"):
            t = jnp.zeros(shape, jnp.float32)
        elif base in ("proj_w", "fc2_w"):
            t = jax.random.normal(k, shape, jnp.float32) * resid_scale
        elif base == "wpe":
            t = jax.random.normal(k, shape, jnp.float32) * 0.01
        else:  # wte, qkv_w, fc_w
            t = jax.random.normal(k, shape, jnp.float32) * 0.02
        parts.append(t.reshape(-1))
    return jnp.concatenate(parts)


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------


def _layer_norm(x: jax.Array, g: jax.Array, b: jax.Array) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + LN_EPS) * g + b


def _block(cfg: ModelConfig, p: dict, prefix: str, x: jax.Array) -> jax.Array:
    """One pre-LN transformer block. x: f32[B, S, D]."""
    b, s, d = x.shape
    h, dh = cfg.n_head, cfg.d_head

    # --- attention sub-block ---
    a = _layer_norm(x, p[prefix + "ln1_g"], p[prefix + "ln1_b"])
    qkv = a @ p[prefix + "qkv_w"] + p[prefix + "qkv_b"]  # (B,S,3D)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):  # (B,S,D) -> (B,H,S,Dh)
        return t.reshape(b, s, h, dh).transpose(0, 2, 1, 3)

    o = flash_attention(heads(q), heads(k), heads(v), cfg.block_q, cfg.block_k)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, d)
    x = x + o @ p[prefix + "proj_w"] + p[prefix + "proj_b"]

    # --- MLP sub-block ---
    m = _layer_norm(x, p[prefix + "ln2_g"], p[prefix + "ln2_b"])
    m = jax.nn.gelu(m @ p[prefix + "fc_w"] + p[prefix + "fc_b"], approximate=True)
    return x + m @ p[prefix + "fc2_w"] + p[prefix + "fc2_b"]


def logits_fn(cfg: ModelConfig, flat: jax.Array, tokens: jax.Array) -> jax.Array:
    """tokens: i32[B, S] -> logits f32[B, S, V] (weight-tied head)."""
    p = unflatten(cfg, flat)
    x = p["wte"][tokens] + p["wpe"][None, : tokens.shape[1]]
    for layer in range(cfg.n_layer):
        x = _block(cfg, p, f"h{layer}.", x)
    x = _layer_norm(x, p["lnf_g"], p["lnf_b"])
    return x @ p["wte"].T


def loss_fn(
    cfg: ModelConfig, flat: jax.Array, tokens: jax.Array, targets: jax.Array
) -> jax.Array:
    """Mean token-level cross entropy (the paper's validation metric is
    exactly this: token-level log perplexity)."""
    logits = logits_fn(cfg, flat, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def train_step(cfg: ModelConfig, flat, tokens, targets):
    """(loss, grads) — the only thing a worker's local step needs."""
    return jax.value_and_grad(functools.partial(loss_fn, cfg))(flat, tokens, targets)


def eval_step(cfg: ModelConfig, flat, tokens, targets):
    return loss_fn(cfg, flat, tokens, targets)
