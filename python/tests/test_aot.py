"""AOT interchange: HLO text artifacts + manifest integrity.

The heavy cross-language check (load artifact in Rust via PJRT, execute,
compare numerics against jax) lives in rust/tests/runtime_roundtrip.rs;
here we verify the python side of the contract.
"""

import json
import pathlib

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.configs import PRESETS, SIGN_UPDATE_CHUNK

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


def test_to_hlo_text_basic_lowering():
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "f32[4]" in text


def test_train_step_hlo_signature():
    cfg = PRESETS["nano"]
    p = model.param_count(cfg)
    fspec = jax.ShapeDtypeStruct((p,), jnp.float32)
    tspec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)
    lowered = jax.jit(lambda f, a, b: model.train_step(cfg, f, a, b)).lower(
        fspec, tspec, tspec
    )
    text = aot.to_hlo_text(lowered)
    # flat params in, (loss, grads) tuple out — the ABI the Rust runtime assumes.
    assert f"f32[{p}]" in text
    assert f"s32[{cfg.batch},{cfg.seq}]" in text
    assert "->(f32[], f32[%d]" % p in text.replace(" ", "").replace(
        "{0}", ""
    ) or "(f32[]" in text


@pytest.mark.skipif(not (ART / "manifest.json").exists(), reason="run `make artifacts` first")
def test_manifest_matches_emitted_files():
    manifest = json.loads((ART / "manifest.json").read_text())
    assert manifest["version"] == aot.MANIFEST_VERSION
    for name, entry in manifest["presets"].items():
        cfg = PRESETS[name]
        assert entry["param_count"] == model.param_count(cfg)
        assert entry["config"]["vocab"] == cfg.vocab
        for kind in ("init", "train", "eval"):
            f = ART / entry["artifacts"][kind]["file"]
            assert f.exists(), f
            assert f.stat().st_size == entry["artifacts"][kind]["bytes"]
        layout = {e["name"]: (e["offset"], tuple(e["shape"])) for e in entry["param_layout"]}
        assert layout == model.param_offsets(cfg)
    su = manifest["sign_update"]
    assert su["chunk"] == SIGN_UPDATE_CHUNK
    assert (ART / su["file"]).exists()


@pytest.mark.skipif(not (ART / "manifest.json").exists(), reason="run `make artifacts` first")
def test_artifact_hlo_text_is_parseable_header():
    manifest = json.loads((ART / "manifest.json").read_text())
    for entry in manifest["presets"].values():
        for kind in ("init", "train", "eval"):
            head = (ART / entry["artifacts"][kind]["file"]).read_text()[:200]
            assert head.startswith("HloModule"), head
