"""L1 kernel correctness: Pallas vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes/blocks/values; this is the core correctness
signal for the AOT'd compute (the Rust integration tests then pin the
same numbers through the PJRT path).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import flash_attention, _flash_fwd
from compile.kernels.sign_update import sign_update

jax.config.update("jax_enable_x64", False)

SETTINGS = dict(max_examples=12, deadline=None)


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(key, shape, jnp.float32)


# --------------------------------------------------------------------------
# attention forward
# --------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 2),
    h=st.integers(1, 3),
    s=st.sampled_from([32, 64, 128]),
    d=st.sampled_from([8, 16, 32]),
    blk=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_fwd_matches_ref(b, h, s, d, blk, seed):
    keys = jax.random.split(jax.random.key(seed), 3)
    q, k, v = (rand(kk, (b, h, s, d)) for kk in keys)
    out = flash_attention(q, k, v, blk, blk)
    expect = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


@settings(**SETTINGS)
@given(
    bq=st.sampled_from([16, 32, 64]),
    bk=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_mixed_block_sizes(bq, bk, seed):
    keys = jax.random.split(jax.random.key(seed), 3)
    q, k, v = (rand(kk, (1, 2, 64, 16)) for kk in keys)
    out = flash_attention(q, k, v, bq, bk)
    np.testing.assert_allclose(out, ref.attention_ref(q, k, v), atol=2e-5, rtol=2e-5)


def test_attention_logsumexp_residual():
    keys = jax.random.split(jax.random.key(7), 3)
    q, k, v = (rand(kk, (2, 2, 64, 16)) for kk in keys)
    o, lse = _flash_fwd(q, k, v, 32, 32)
    o_ref, lse_ref = ref.attention_lse_ref(q, k, v)
    np.testing.assert_allclose(o, o_ref, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(lse, lse_ref, atol=2e-5, rtol=2e-5)


def test_attention_large_logits_stable():
    # Online softmax must not overflow where a naive exp() would.
    keys = jax.random.split(jax.random.key(3), 3)
    q, k, v = (rand(kk, (1, 1, 64, 16), scale=30.0) for kk in keys)
    out = flash_attention(q, k, v)
    assert bool(jnp.all(jnp.isfinite(out)))
    # values are O(30); tolerance scales with the data magnitude.
    np.testing.assert_allclose(out, ref.attention_ref(q, k, v), atol=1e-2, rtol=1e-3)


def test_attention_is_causal():
    # Perturbing position j must not change outputs at positions < j.
    keys = jax.random.split(jax.random.key(11), 3)
    q, k, v = (rand(kk, (1, 2, 64, 16)) for kk in keys)
    out = flash_attention(q, k, v)
    j = 40
    k2 = k.at[:, :, j:].set(rand(jax.random.key(99), (1, 2, 64 - j, 16)))
    v2 = v.at[:, :, j:].set(rand(jax.random.key(98), (1, 2, 64 - j, 16)))
    out2 = flash_attention(q, k2, v2)
    np.testing.assert_allclose(out[:, :, :j], out2[:, :, :j], atol=1e-6)
    # ... and MUST change something at >= j (sanity that the test bites).
    assert float(jnp.max(jnp.abs(out[:, :, j:] - out2[:, :, j:]))) > 1e-3


def test_attention_first_row_attends_self_only():
    keys = jax.random.split(jax.random.key(5), 3)
    q, k, v = (rand(kk, (1, 1, 32, 8)) for kk in keys)
    out = flash_attention(q, k, v, 16, 16)
    np.testing.assert_allclose(out[0, 0, 0], v[0, 0, 0], atol=1e-5, rtol=1e-5)


# --------------------------------------------------------------------------
# attention backward (custom VJP)
# --------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 2),
    h=st.integers(1, 2),
    s=st.sampled_from([32, 64]),
    d=st.sampled_from([8, 16]),
    blk=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_grads_match_ref(b, h, s, d, blk, seed):
    keys = jax.random.split(jax.random.key(seed), 4)
    q, k, v = (rand(kk, (b, h, s, d)) for kk in keys[:3])
    ct = rand(keys[3], (b, h, s, d))

    def f(q, k, v):
        return jnp.vdot(flash_attention(q, k, v, blk, blk), ct)

    def f_ref(q, k, v):
        return jnp.vdot(ref.attention_ref(q, k, v), ct)

    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, e in zip(g, g_ref):
        np.testing.assert_allclose(a, e, atol=5e-5, rtol=5e-4)


def test_attention_grad_under_jit_and_vmap_composition():
    keys = jax.random.split(jax.random.key(13), 3)
    q, k, v = (rand(kk, (2, 2, 32, 8)) for kk in keys)

    @jax.jit
    def f(q, k, v):
        return jnp.sum(flash_attention(q, k, v, 16, 16) ** 2)

    g = jax.grad(f)(q, k, v)
    assert g.shape == q.shape and bool(jnp.all(jnp.isfinite(g)))


# --------------------------------------------------------------------------
# fused sign-momentum update kernel (paper eqs. (6)-(8))
# --------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.sampled_from([4096, 8192, 65536]),
    gamma=st.floats(1e-5, 1.0),
    eta=st.floats(0.01, 5.0),
    lam=st.floats(0.0, 0.5),
    beta1=st.floats(0.0, 0.99),
    beta2=st.floats(0.0, 0.99),
    seed=st.integers(0, 2**31 - 1),
)
def test_sign_update_matches_ref(n, gamma, eta, lam, beta1, beta2, seed):
    keys = jax.random.split(jax.random.key(seed), 3)
    x, m, d = (rand(kk, (n,)) for kk in keys)
    sc = jnp.array([gamma, eta, lam, beta1, beta2, 0, 0, 0], jnp.float32)
    xn, mn = sign_update(x, m, d, sc)
    xr, mr = ref.sign_update_ref(x, m, d, gamma, eta, lam, beta1, beta2)
    np.testing.assert_allclose(xn, xr, atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(mn, mr, atol=1e-4, rtol=1e-4)


def test_sign_update_zero_momentum_is_pure_sign_step():
    # beta1 = beta2 = 0, lam = 0: x' = x - eta*gamma*sign(diff/gamma).
    x = jnp.zeros((4096,))
    m = jnp.zeros((4096,))
    d = jnp.concatenate([jnp.full((2048,), 2.0), jnp.full((2048,), -3.0)])
    sc = jnp.array([0.5, 1.5, 0.0, 0.0, 0.0, 0, 0, 0], jnp.float32)
    xn, mn = sign_update(x, m, d, sc)
    np.testing.assert_allclose(xn[:2048], -1.5 * 0.5, rtol=1e-6)
    np.testing.assert_allclose(xn[2048:], 1.5 * 0.5, rtol=1e-6)
    np.testing.assert_allclose(mn, d / 0.5, rtol=1e-6)


def test_sign_update_magnitude_invariance():
    # sign step ignores |diff| when momentum is off: scaling diff by 100
    # must not change x' (only m'). This is the defining sign property.
    keys = jax.random.split(jax.random.key(21), 2)
    x, d = (rand(kk, (4096,)) for kk in keys)
    m = jnp.zeros_like(x)
    sc = jnp.array([0.1, 1.0, 0.0, 0.0, 0.9, 0, 0, 0], jnp.float32)
    x1, _ = sign_update(x, m, d, sc)
    x2, _ = sign_update(x, m, 100.0 * d, sc)
    np.testing.assert_allclose(x1, x2, atol=1e-7)


def test_sign_update_decoupled_weight_decay():
    # With diff = 0 and m = 0, sign(u) = 0: pure decay x' = x(1 - eta*gamma*lam).
    x = rand(jax.random.key(2), (4096,))
    z = jnp.zeros_like(x)
    sc = jnp.array([0.5, 2.0, 0.1, 0.9, 0.9, 0, 0, 0], jnp.float32)
    xn, mn = sign_update(x, z, z, sc)
    np.testing.assert_allclose(xn, x * (1.0 - 2.0 * 0.5 * 0.1), rtol=1e-5)
    np.testing.assert_allclose(mn, z, atol=0)
