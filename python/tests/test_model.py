"""L2 model correctness: flat-parameter ABI, init scheme, loss/grads."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import PRESETS, ModelConfig

CFG = PRESETS["nano"]


@pytest.fixture(scope="module")
def flat():
    return model.init_step(CFG, jnp.uint32(42))


@pytest.fixture(scope="module")
def batch():
    k1, k2 = jax.random.split(jax.random.key(0))
    tok = jax.random.randint(k1, (CFG.batch, CFG.seq), 0, CFG.vocab)
    tgt = jax.random.randint(k2, (CFG.batch, CFG.seq), 0, CFG.vocab)
    return tok, tgt


# ---- flat-parameter layout ------------------------------------------------


@pytest.mark.parametrize("name", ["nano", "small", "medium", "large"])
def test_param_spec_offsets_are_contiguous(name):
    cfg = PRESETS[name]
    off = 0
    for pname, (o, shape) in model.param_offsets(cfg).items():
        assert o == off, pname
        off += math.prod(shape)
    assert off == model.param_count(cfg)


def test_flatten_unflatten_roundtrip(flat):
    params = model.unflatten(CFG, flat)
    back = model.flatten(CFG, params)
    np.testing.assert_array_equal(flat, back)


def test_param_counts_scale_with_preset():
    counts = [model.param_count(PRESETS[n]) for n in ["nano", "small", "medium", "large"]]
    assert counts == sorted(counts) and len(set(counts)) == 4


def test_gpt2s_preset_matches_paper_size():
    # Paper Table 1: GPT-2 Small is ~124M params (we have no dropout /
    # bias-free variations, so allow a few percent).
    p = model.param_count(PRESETS["gpt2s"])
    # wpe differs (seq 256 vs 1024) - compensate before comparing.
    p += (1024 - 256) * 768
    assert abs(p - 124e6) / 124e6 < 0.02, p


# ---- init scheme -----------------------------------------------------------


def test_init_layernorm_gains_and_biases(flat):
    p = model.unflatten(CFG, flat)
    np.testing.assert_array_equal(p["lnf_g"], jnp.ones_like(p["lnf_g"]))
    np.testing.assert_array_equal(p["h0.ln1_b"], jnp.zeros_like(p["h0.ln1_b"]))
    np.testing.assert_array_equal(p["h0.qkv_b"], jnp.zeros_like(p["h0.qkv_b"]))


def test_init_weight_scales(flat):
    p = model.unflatten(CFG, flat)
    assert abs(float(jnp.std(p["wte"])) - 0.02) < 0.002
    resid = 0.02 / math.sqrt(2 * CFG.n_layer)
    assert abs(float(jnp.std(p["h0.proj_w"])) - resid) < 0.002


def test_init_is_deterministic_and_seed_sensitive():
    a = model.init_step(CFG, jnp.uint32(7))
    b = model.init_step(CFG, jnp.uint32(7))
    c = model.init_step(CFG, jnp.uint32(8))
    np.testing.assert_array_equal(a, b)
    assert float(jnp.max(jnp.abs(a - c))) > 1e-3


# ---- forward / loss --------------------------------------------------------


def test_initial_loss_near_uniform(flat, batch):
    tok, tgt = batch
    loss = model.loss_fn(CFG, flat, tok, tgt)
    assert abs(float(loss) - math.log(CFG.vocab)) < 0.2


def test_logits_shape_and_finite(flat, batch):
    tok, _ = batch
    logits = model.logits_fn(CFG, flat, tok)
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_model_is_causal(flat):
    # Changing token at position j must not change logits before j.
    tok = jax.random.randint(jax.random.key(1), (1, CFG.seq), 0, CFG.vocab)
    logits = model.logits_fn(CFG, flat, tok)
    j = CFG.seq // 2
    tok2 = tok.at[0, j:].set((tok[0, j:] + 1) % CFG.vocab)
    logits2 = model.logits_fn(CFG, flat, tok2)
    np.testing.assert_allclose(logits[0, :j], logits2[0, :j], atol=1e-5)
    assert float(jnp.max(jnp.abs(logits[0, j:] - logits2[0, j:]))) > 1e-3


def test_train_step_grads_finite_and_nonzero(flat, batch):
    tok, tgt = batch
    loss, g = model.train_step(CFG, flat, tok, tgt)
    assert g.shape == flat.shape
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.linalg.norm(g)) > 1e-3
    assert float(loss) > 0


def test_eval_step_equals_loss_of_train_step(flat, batch):
    tok, tgt = batch
    loss_t, _ = model.train_step(CFG, flat, tok, tgt)
    loss_e = model.eval_step(CFG, flat, tok, tgt)
    np.testing.assert_allclose(loss_t, loss_e, rtol=1e-6)


def test_one_sgd_step_reduces_loss(flat, batch):
    tok, tgt = batch
    loss0, g = model.train_step(CFG, flat, tok, tgt)
    loss1 = model.eval_step(CFG, flat - 0.5 * g, tok, tgt)
    assert float(loss1) < float(loss0)


def test_weight_tying_head_uses_wte(flat, batch):
    # Scaling wte rescales logits through BOTH embedding and head.
    tok, _ = batch
    p = model.unflatten(CFG, flat)
    p2 = dict(p)
    p2["wte"] = p["wte"] * 1.5
    l1 = model.logits_fn(CFG, flat, tok)
    l2 = model.logits_fn(CFG, model.flatten(CFG, p2), tok)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-2


def test_custom_seq_config_lowers():
    cfg = ModelConfig("tmp", vocab=64, d_model=32, n_head=2, n_layer=1,
                      seq=32, batch=2, block_q=16, block_k=16)
    flat = model.init_step(cfg, jnp.uint32(0))
    tok = jnp.zeros((2, 32), jnp.int32)
    loss, g = model.train_step(cfg, flat, tok, tok)
    assert g.shape == flat.shape and bool(jnp.isfinite(loss))
