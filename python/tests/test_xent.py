"""Fused softmax-cross-entropy kernel vs jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.xent import softmax_xent

SETTINGS = dict(max_examples=12, deadline=None)


def ref_nll(logits, targets):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, targets[:, None], axis=1)[:, 0]


@settings(**SETTINGS)
@given(
    rows=st.sampled_from([64, 128, 256]),
    v=st.sampled_from([256, 512]),
    block_v=st.sampled_from([64, 128, 256]),
    scale=st.floats(0.1, 20.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_xent_fwd_matches_ref(rows, v, block_v, scale, seed):
    k1, k2 = jax.random.split(jax.random.key(seed))
    logits = scale * jax.random.normal(k1, (rows, v), jnp.float32)
    targets = jax.random.randint(k2, (rows,), 0, v)
    out = softmax_xent(logits, targets, 64, block_v)
    np.testing.assert_allclose(out, ref_nll(logits, targets), atol=2e-4, rtol=1e-4)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_xent_grads_match_ref(seed):
    k1, k2 = jax.random.split(jax.random.key(seed))
    logits = 2.0 * jax.random.normal(k1, (64, 256), jnp.float32)
    targets = jax.random.randint(k2, (64,), 0, 256)
    g = jax.grad(lambda l: jnp.mean(softmax_xent(l, targets)))(logits)
    gr = jax.grad(lambda l: jnp.mean(ref_nll(l, targets)))(logits)
    np.testing.assert_allclose(g, gr, atol=1e-5, rtol=1e-4)


def test_xent_extreme_logits_stable():
    # online-max must survive +-1e4 logits where naive exp overflows
    logits = jnp.zeros((64, 256)).at[:, 0].set(1e4).at[:, 1].set(-1e4)
    targets = jnp.zeros((64,), jnp.int32)  # the huge-logit class
    out = softmax_xent(logits, targets)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(out, 0.0, atol=1e-3)  # prob ~ 1 -> nll ~ 0


def test_xent_uniform_logits_give_log_v():
    logits = jnp.zeros((64, 256))
    targets = jnp.arange(64, dtype=jnp.int32)
    out = softmax_xent(logits, targets)
    np.testing.assert_allclose(out, jnp.log(256.0), rtol=1e-6)


def test_xent_grad_rows_sum_to_zero():
    # softmax - onehot has zero row-sum; mean-scaled too.
    k1, k2 = jax.random.split(jax.random.key(3))
    logits = jax.random.normal(k1, (64, 256))
    targets = jax.random.randint(k2, (64,), 0, 256)
    g = jax.grad(lambda l: jnp.sum(softmax_xent(l, targets)))(logits)
    np.testing.assert_allclose(jnp.sum(g, axis=1), 0.0, atol=1e-4)
