//! Collective-arithmetic + comm-model benches: both reduction backends
//! (sequential reference vs chunked threads), the packed-sign codec,
//! and the analytic comm model.
//!
//!     cargo bench --bench collectives

use dsm::comm::CommModel;
use dsm::dist::codec;
use dsm::dist::collectives::{self, Backend};
use dsm::util::bench::{black_box, Bencher};
use dsm::util::rng::Rng;

fn main() {
    let mut b = Bencher::default();
    let mut rng = Rng::new(3);

    for &(n, p) in &[(4usize, 1usize << 20), (8, 1 << 20), (8, 1 << 22)] {
        let workers: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; p];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect();
        let mut out = vec![0.0f32; p];
        b.bench_with_bytes(
            &format!("allreduce_mean n={n} P={p}"),
            Some((n as u64 + 1) * p as u64 * 4),
            || collectives::allreduce_mean(black_box(&workers), |w| w.as_slice(), &mut out),
        );
    }

    println!("\n== backends (n=8, P=4M) ==");
    let n = 8usize;
    let p = 1usize << 22;
    let workers: Vec<Vec<f32>> = (0..n)
        .map(|_| {
            let mut v = vec![0.0f32; p];
            rng.fill_normal(&mut v, 1.0);
            v
        })
        .collect();
    let mut out = vec![0.0f32; p];
    let bytes = Some((n as u64 + 1) * p as u64 * 4);
    b.bench_with_bytes("allreduce sequential reference", bytes, || {
        collectives::allreduce_mean_with(
            Backend::Sequential,
            black_box(&workers),
            |w| w.as_slice(),
            &mut out,
        )
    });
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    for threads in [2usize, 4, cores] {
        b.bench_with_bytes(&format!("allreduce threaded x{threads}"), bytes, || {
            collectives::allreduce_mean_with(
                Backend::Threaded { threads },
                black_box(&workers),
                |w| w.as_slice(),
                &mut out,
            )
        });
    }

    println!("\n== packed-sign codec (P=4M, 32x payload compression) ==");
    let mut signs = vec![0.0f32; p];
    rng.fill_normal(&mut signs, 1.0);
    b.bench_with_bytes("pack_signs", Some(p as u64 * 4), || {
        black_box(codec::pack_signs(black_box(&signs)));
    });
    let packed = codec::pack_signs(&signs);
    b.bench_with_bytes("unpack_signs", Some(p as u64 * 4), || {
        black_box(codec::unpack_signs(black_box(&packed), p));
    });

    let votes: Vec<Vec<f32>> = (0..8)
        .map(|i| (0..1 << 20).map(|j| if (i + j) % 3 == 0 { 1.0 } else { -1.0 }).collect())
        .collect();
    let mut out = vec![0.0f32; 1 << 20];
    b.bench_with_bytes("majority_vote n=8 P=1M", Some(9 << 22), || {
        collectives::majority_vote(black_box(&votes), &mut out)
    });

    println!("\n== comm model (analytic, ns-scale) ==");
    let m = CommModel::preset("ethernet").unwrap();
    b.bench("allreduce_time()", || {
        black_box(m.allreduce_time(black_box(8), black_box(500 << 20)));
    });
    let mut r = Rng::new(5);
    b.bench("straggler_delay(n=16)", || {
        black_box(m.straggler_delay(16, &mut r));
    });
}
