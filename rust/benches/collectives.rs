//! Collective-arithmetic + comm-model benches: both reduction backends
//! (sequential reference vs pooled threads, plus the historical
//! spawn-per-call baseline), the packed-sign codec, the word-level
//! packed majority tally vs the f32 vote, and the analytic comm model.
//!
//!     cargo bench --bench collectives

use dsm::comm::CommModel;
use dsm::dist::codec;
use dsm::dist::collectives::{self, Backend};
use dsm::dist::pool;
use dsm::dist::votes::{self, PackedVotes};
use dsm::util::bench::{black_box, Bencher};
use dsm::util::rng::Rng;

fn main() {
    let mut b = Bencher::default();
    let mut rng = Rng::new(3);

    for &(n, p) in &[(4usize, 1usize << 20), (8, 1 << 20), (8, 1 << 22)] {
        let workers: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; p];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect();
        let mut out = vec![0.0f32; p];
        b.bench_with_bytes(
            &format!("allreduce_mean n={n} P={p}"),
            Some((n as u64 + 1) * p as u64 * 4),
            || collectives::allreduce_mean(black_box(&workers), |w| w.as_slice(), &mut out),
        );
    }

    println!("\n== backends (n=8, P=4M) ==");
    let n = 8usize;
    let p = 1usize << 22;
    let workers: Vec<Vec<f32>> = (0..n)
        .map(|_| {
            let mut v = vec![0.0f32; p];
            rng.fill_normal(&mut v, 1.0);
            v
        })
        .collect();
    let mut out = vec![0.0f32; p];
    let bytes = Some((n as u64 + 1) * p as u64 * 4);
    b.bench_with_bytes("allreduce sequential reference", bytes, || {
        collectives::allreduce_mean_with(
            Backend::Sequential,
            black_box(&workers),
            |w| w.as_slice(),
            &mut out,
        )
    });
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    for threads in [2usize, 4, cores] {
        b.bench_with_bytes(&format!("allreduce threaded x{threads}"), bytes, || {
            collectives::allreduce_mean_with(
                Backend::Threaded { threads },
                black_box(&workers),
                |w| w.as_slice(),
                &mut out,
            )
        });
    }

    println!("\n== packed-sign codec (P=4M, 32x payload compression) ==");
    let mut signs = vec![0.0f32; p];
    rng.fill_normal(&mut signs, 1.0);
    b.bench_with_bytes("pack_signs", Some(p as u64 * 4), || {
        black_box(codec::pack_signs(black_box(&signs)));
    });
    let packed = codec::pack_signs(&signs);
    b.bench_with_bytes("unpack_signs", Some(p as u64 * 4), || {
        black_box(codec::unpack_signs(black_box(&packed), p));
    });

    println!("\n== packed tally vs f32 majority vote (n=8) ==");
    let n_votes = 8usize;
    for &p in &[1usize << 16, 1 << 20] {
        let raw: Vec<Vec<f32>> = (0..n_votes)
            .map(|i| (0..p).map(|j| if (i + j) % 3 == 0 { 1.0 } else { -1.0 }).collect())
            .collect();
        let packed: Vec<PackedVotes> =
            raw.iter().map(|v| PackedVotes::pack(v)).collect();
        let mut out = vec![0.0f32; p];
        let f32_bytes = Some((n_votes as u64 + 1) * p as u64 * 4);
        b.bench_with_bytes(&format!("majority_vote f32 n=8 P={p}"), f32_bytes, || {
            collectives::majority_vote(black_box(&raw), &mut out)
        });
        // reads n packed payloads, writes P f32s
        let packed_bytes = Some(n_votes as u64 * (p as u64 / 8) + p as u64 * 4);
        b.bench_with_bytes(
            &format!("majority_vote_packed n=8 P={p}"),
            packed_bytes,
            || votes::majority_vote_packed(black_box(&packed), &mut out),
        );
        // ROADMAP (e): the word-level tally chunks onto the persistent
        // pool — the sequential/threaded delta is the pooled win
        b.bench_with_bytes(
            &format!("majority_vote_packed seq-ref n=8 P={p}"),
            packed_bytes,
            || {
                votes::majority_vote_packed_with(
                    Backend::Sequential,
                    black_box(&packed),
                    &mut out,
                )
            },
        );
        b.bench_with_bytes(
            &format!("majority_vote_packed pooled x4 n=8 P={p}"),
            packed_bytes,
            || {
                votes::majority_vote_packed_with(
                    Backend::Threaded { threads: 4 },
                    black_box(&packed),
                    &mut out,
                )
            },
        );
    }

    println!("\n== vote packing: fresh allocation vs persistent buffer (P=1M) ==");
    {
        let p = 1usize << 20;
        let mut signs = vec![0.0f32; p];
        rng.fill_normal(&mut signs, 1.0);
        b.bench_with_bytes("PackedVotes::pack (alloc/round)", Some(p as u64 * 4), || {
            black_box(PackedVotes::pack(black_box(&signs)));
        });
        let mut buf = PackedVotes::empty();
        buf.pack_into(&signs);
        b.bench_with_bytes("PackedVotes::pack_into (persistent)", Some(p as u64 * 4), || {
            buf.pack_into(black_box(&signs));
        });
        black_box(&buf);
    }

    println!("\n== persistent pool vs spawn-per-call (allreduce, 4 threads) ==");
    for &p in &[1usize << 16, 1 << 20] {
        let workers: Vec<Vec<f32>> = (0..8)
            .map(|_| {
                let mut v = vec![0.0f32; p];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect();
        let slices: Vec<&[f32]> = workers.iter().map(|w| w.as_slice()).collect();
        let mut out = vec![0.0f32; p];
        let bytes = Some(9 * p as u64 * 4);
        // identical chunk body through both executors, so the delta is
        // pure dispatch cost (pool hand-off vs per-call thread spawn)
        let inv_n = 1.0f64 / slices.len() as f64;
        let mean_body = |base: usize, chunk: &mut [f32]| {
            for (j, o) in chunk.iter_mut().enumerate() {
                let idx = base + j;
                let mut acc = 0.0f64;
                for s in black_box(&slices) {
                    acc += s[idx] as f64;
                }
                *o = (acc * inv_n) as f32;
            }
        };
        b.bench_with_bytes(&format!("allreduce pooled x4 P={p}"), bytes, || {
            pool::run_chunked_mut(4, 1, &mut out, mean_body)
        });
        b.bench_with_bytes(&format!("allreduce spawned x4 P={p}"), bytes, || {
            pool::run_chunked_mut_spawn(4, 1, &mut out, mean_body)
        });
    }

    println!("\n== comm model (analytic, ns-scale) ==");
    let m = CommModel::preset("ethernet").unwrap();
    b.bench("allreduce_time()", || {
        black_box(m.allreduce_time(black_box(8), black_box(500 << 20)));
    });
    let mut r = Rng::new(5);
    b.bench("straggler_delay(n=16)", || {
        black_box(m.straggler_delay(16, &mut r));
    });
}
