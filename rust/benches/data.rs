//! Data-pipeline benches: corpus synthesis, tokenizers, batch sampling.
//!
//!     cargo bench --bench data

use dsm::data::corpus::{generate, CorpusConfig};
use dsm::data::dataset::TokenDataset;
use dsm::data::{Bpe, ByteTokenizer, Tokenizer};
use dsm::util::bench::{black_box, Bencher};
use dsm::util::rng::Rng;

fn main() {
    let mut b = Bencher::default();

    let cfg = CorpusConfig { bytes: 1 << 20, ..Default::default() };
    b.bench_with_bytes("corpus::generate 1MB", Some(1 << 20), || {
        black_box(generate(black_box(&cfg)));
    });

    let corpus = generate(&CorpusConfig { bytes: 4 << 20, ..Default::default() });
    let byte_tok = ByteTokenizer;
    b.bench_with_bytes("byte_tokenizer::encode 1MB", Some(1 << 20), || {
        black_box(byte_tok.encode(black_box(&corpus[..1 << 20])));
    });

    let bpe = Bpe::train(&corpus[..256 << 10], 512);
    b.bench_with_bytes("bpe(512)::encode 64KB", Some(64 << 10), || {
        black_box(bpe.encode(black_box(&corpus[..64 << 10])));
    });
    let toks = bpe.encode(&corpus[..256 << 10]);
    b.bench_with_bytes("bpe(512)::decode 256KB-of-text", Some(256 << 10), || {
        black_box(bpe.decode(black_box(&toks)));
    });

    let ds = TokenDataset::from_text(&ByteTokenizer, &corpus, 0.05);
    let mut rng = Rng::new(1);
    b.bench_with_bytes("dataset::sample_train B=8 S=64", Some(8 * 64 * 8), || {
        black_box(ds.sample_train(0, 4, 8, 64, &mut rng));
    });
    b.bench("dataset::val_batches(8)", || {
        black_box(ds.val_batches(8, 64, 8));
    });
}
