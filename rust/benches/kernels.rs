//! Scalar-vs-kernel microbenches for the hot-path word/byte kernels
//! (`dist::kernels`) and the blocked matmul (`runtime::gemm`) — the
//! recorded before/after trajectory of the raw-speed pass.
//!
//!     cargo bench --bench kernels              # human-readable table
//!     cargo bench --bench kernels -- --json    # also write BENCH_kernels.json
//!     cargo bench --bench kernels -- --quick   # shorter budget (CI)
//!
//! Every `scalar` baseline is the pre-kernel implementation preserved
//! verbatim in-tree (`tally_word_ref`, `quantize_diff_ref`,
//! `topk_partition_ref`, `matmul_naive`); the differential tests in
//! `dist/kernels.rs` and `runtime/gemm.rs` prove each pair
//! bitwise-identical, so these rows measure *only* speed. Rows cover
//! P ∈ {2^16, 2^20}; `BENCH_kernels.json` lands at the workspace root
//! and is uploaded as a CI artifact by the `kernels-bench` job.

use dsm::dist::{codec, kernels};
use dsm::runtime::gemm;
use dsm::util::bench::{black_box, Bencher};
use dsm::util::rng::Rng;

struct Row {
    name: &'static str,
    p: usize,
    scalar_ns: f64,
    kernel_ns: f64,
}

impl Row {
    fn speedup(&self) -> f64 {
        self.scalar_ns / self.kernel_ns
    }
}

fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v, 1.0);
    v
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let json = args.iter().any(|a| a == "--json");
    let quick = args.iter().any(|a| a == "--quick");
    let mut b = if quick { Bencher::quick() } else { Bencher::default() };
    let mut rng = Rng::new(17);
    let mut rows: Vec<Row> = Vec::new();

    for &p in &[1usize << 16, 1 << 20] {
        println!("== P = {p} ==");

        // ---- packed-sign majority tally (bit-sliced strip kernel) ----
        let n_ranks = 16usize;
        let levels = 5usize; // counters cover 16 ranks
        let threshold = (n_ranks / 2) as u64;
        let packed_len = codec::packed_len(p);
        // P is a power of two ≥ 2^16, so the packed byte count is an
        // exact multiple of 8 and the word count needs no rounding.
        let n_words = packed_len / 8;
        let packed: Vec<Vec<u8>> =
            (0..n_ranks).map(|_| codec::pack_signs(&randn(&mut rng, p))).collect();
        let slices: Vec<&[u8]> = packed.iter().map(|v| v.as_slice()).collect();
        let tally_bytes = Some((n_ranks * packed_len) as u64);
        let scalar_ns = b
            .bench_with_bytes(&format!("tally/scalar P={p}"), tally_bytes, || {
                let mut acc = 0u64;
                for wi in 0..n_words {
                    acc ^= kernels::tally_word_ref(&slices, wi, levels, threshold);
                }
                black_box(acc);
            })
            .mean_ns;
        let kernel_ns = b
            .bench_with_bytes(&format!("tally/kernel P={p}"), tally_bytes, || {
                let mut winners = [0u64; kernels::STRIP_WORDS];
                let mut acc = 0u64;
                let mut base = 0usize;
                while base < n_words {
                    let nw = kernels::STRIP_WORDS.min(n_words - base);
                    kernels::tally_strip(&slices, base, nw, levels, threshold, &mut winners);
                    for w in &winners[..nw] {
                        acc ^= w;
                    }
                    base += nw;
                }
                black_box(acc);
            })
            .mean_ns;
        rows.push(Row { name: "tally", p, scalar_ns, kernel_ns });

        // ---- q8 quantize (lane-split abs-max + scaled rounding) ----
        let start = randn(&mut rng, p);
        let delta = randn(&mut rng, p);
        let end: Vec<f32> = start.iter().zip(&delta).map(|(s, d)| s - 0.01 * d).collect();
        let mut out = vec![0u8; p];
        let q_bytes = Some((9 * p) as u64); // two f32 reads + one byte write
        let scalar_ns = b
            .bench_with_bytes(&format!("q8_quantize/scalar P={p}"), q_bytes, || {
                black_box(kernels::quantize_diff_ref(&start, &end, &mut out));
            })
            .mean_ns;
        let kernel_ns = b
            .bench_with_bytes(&format!("q8_quantize/kernel P={p}"), q_bytes, || {
                black_box(codec::quantize_diff_slice(&start, &end, &mut out));
            })
            .mean_ns;
        rows.push(Row { name: "q8_quantize", p, scalar_ns, kernel_ns });

        // ---- q8 dequantize-accumulate (the mean-decode inner loop) ----
        let scale = 0.0123f32;
        let qbytes: Vec<u8> = out.clone();
        let mut acc = vec![0.0f64; p];
        let dq_bytes = Some((9 * p) as u64); // one byte read + one f64 rmw
        let scalar_ns = b
            .bench_with_bytes(&format!("q8_dequant/scalar P={p}"), dq_bytes, || {
                for (a, &byte) in acc.iter_mut().zip(&qbytes) {
                    *a += codec::dequantize_i8(byte, scale) as f64;
                }
                black_box(&acc);
            })
            .mean_ns;
        acc.fill(0.0);
        let kernel_ns = b
            .bench_with_bytes(&format!("q8_dequant/kernel P={p}"), dq_bytes, || {
                kernels::dequant_accumulate(&qbytes, scale, &mut acc);
                black_box(&acc);
            })
            .mean_ns;
        rows.push(Row { name: "q8_dequant", p, scalar_ns, kernel_ns });

        // ---- top-k select (packed-key partition, k = P/16) ----
        let k = p / 16;
        let residual = randn(&mut rng, p);
        let mut scratch: Vec<u32> = Vec::new();
        let scalar_ns = b
            .bench_with_bytes(&format!("topk_select/scalar P={p}"), Some((4 * p) as u64), || {
                kernels::topk_partition_ref(&residual, k, &mut scratch);
                black_box(scratch[0]);
            })
            .mean_ns;
        let kernel_ns = b
            .bench_with_bytes(&format!("topk_select/kernel P={p}"), Some((4 * p) as u64), || {
                kernels::topk_partition(&residual, k, &mut scratch);
                black_box(scratch[0]);
            })
            .mean_ns;
        rows.push(Row { name: "topk_select", p, scalar_ns, kernel_ns });

        // ---- blocked matmul (m = n = √P, k = 64) ----
        let m = (p as f64).sqrt() as usize;
        let kdim = 64usize;
        let a = randn(&mut rng, m * kdim);
        let bmat = randn(&mut rng, kdim * m);
        let mut prod = vec![0.0f32; m * m];
        let mm_bytes = Some(((m * kdim + kdim * m + m * m) * 4) as u64);
        let scalar_ns = b
            .bench_with_bytes(&format!("matmul/naive {m}x{kdim}x{m}"), mm_bytes, || {
                gemm::matmul_naive(&mut prod, &a, &bmat, m, kdim, m);
                black_box(prod[0]);
            })
            .mean_ns;
        let kernel_ns = b
            .bench_with_bytes(&format!("matmul/blocked {m}x{kdim}x{m}"), mm_bytes, || {
                gemm::matmul_blocked(&mut prod, &a, &bmat, m, kdim, m);
                black_box(prod[0]);
            })
            .mean_ns;
        rows.push(Row { name: "matmul", p, scalar_ns, kernel_ns });
    }

    println!("\n== speedups (scalar / kernel) ==");
    for r in &rows {
        println!("{:>12} P={:<8} {:>6.2}x", r.name, r.p, r.speedup());
    }

    if json {
        let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
        let body: Vec<String> = rows
            .iter()
            .map(|r| {
                format!(
                    "    {{\"name\": \"{}\", \"p\": {}, \"scalar_ns\": {:.1}, \
                     \"kernel_ns\": {:.1}, \"speedup\": {:.3}}}",
                    r.name,
                    r.p,
                    r.scalar_ns,
                    r.kernel_ns,
                    r.speedup()
                )
            })
            .collect();
        let text = format!(
            "{{\n  \"bench\": \"kernels\",\n  \"host_cores\": {cores},\n  \
             \"quick\": {quick},\n  \"rows\": [\n{}\n  ]\n}}\n",
            body.join(",\n")
        );
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("workspace root")
            .join("BENCH_kernels.json");
        std::fs::write(&path, text).expect("writing BENCH_kernels.json");
        println!("wrote {path:?}");
    }
}
