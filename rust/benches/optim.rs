//! L3 hot-path benches: base optimizers, outer optimizers, sign ops.
//!
//! These are the per-element loops that run between PJRT executions;
//! target is memory-bandwidth-bound behaviour (see EXPERIMENTS.md §Perf).
//!
//!     cargo bench --bench optim

use dsm::optim::BaseOptConfig;
use dsm::outer::{run_synthetic_round, OuterConfig};
use dsm::sign::SignOp;
use dsm::util::bench::{black_box, Bencher};
use dsm::util::rng::Rng;

const P: usize = 1 << 20; // 1M params ~ small preset

fn main() {
    let mut b = Bencher::default();
    let mut rng = Rng::new(7);
    let mut params = vec![0.0f32; P];
    let mut grads = vec![0.0f32; P];
    rng.fill_normal(&mut params, 0.02);
    rng.fill_normal(&mut grads, 0.5);

    println!("== base optimizers (P = {P}) ==");
    for cfg in [
        BaseOptConfig::sgd_plain(),
        BaseOptConfig::Sgd { momentum: 0.9, nesterov: false, weight_decay: 0.0 },
        BaseOptConfig::adamw_paper(),
        BaseOptConfig::lion_paper(),
        BaseOptConfig::sophia_paper(),
    ] {
        let mut opt = cfg.build(P);
        let name = format!("{}::step", opt.name());
        // bytes touched: params rw + grads r + state rw
        let state_bufs = opt.state().len() as u64;
        let bytes = (P as u64 * 4) * (3 + 2 * state_bufs.min(2));
        b.bench_with_bytes(&name, Some(bytes), || {
            opt.step(black_box(&mut params), black_box(&grads), 1e-4);
        });
    }

    println!("\n== outer optimizers (one communication round, P = {P}) ==");
    let diff = vec![1e-3f32; P];
    for cfg in [
        OuterConfig::sign_momentum_paper(1.0),
        OuterConfig::SlowMo { alpha: 1.0, beta: 0.5 },
        OuterConfig::SignedSlowMo { eta: 1.0, beta: 0.5 },
        OuterConfig::GlobalAdamW {
            eta: 1.0,
            beta1: 0.9,
            beta2: 0.95,
            eps: 1e-8,
            weight_decay: 0.1,
        },
        OuterConfig::LocalAvg,
    ] {
        let mut opt = cfg.build(P);
        let mut global = params.clone();
        let name = format!("outer::{}", cfg.name());
        let mut round = 0u64;
        b.bench_with_bytes(&name, Some(P as u64 * 4 * 5), || {
            run_synthetic_round(opt.as_mut(), black_box(&mut global), &diff, 1e-4, round);
            round += 1;
        });
    }

    println!("\n== sign operators (P = {P}) ==");
    let mut out = vec![0.0f32; P];
    let v = grads.clone();
    for op in [SignOp::Exact, SignOp::RandPm, SignOp::RandZero] {
        let mut r = Rng::new(1);
        b.bench_with_bytes(&format!("sign::{op:?}"), Some(P as u64 * 8), || {
            op.apply_into(black_box(&mut out), black_box(&v), 10.0, &mut r);
        });
    }

    println!("\n== tensor primitives (P = {P}) ==");
    let a = grads.clone();
    b.bench_with_bytes("tensor::axpy", Some(P as u64 * 12), || {
        dsm::tensor::axpy(black_box(&mut params), 1e-6, black_box(&a));
    });
    b.bench_with_bytes("tensor::ema", Some(P as u64 * 12), || {
        dsm::tensor::ema(black_box(&mut params), 0.99, black_box(&a));
    });
    b.bench_with_bytes("tensor::dot(f64-acc)", Some(P as u64 * 8), || {
        black_box(dsm::tensor::dot(black_box(&a), black_box(&grads)));
    });
}
