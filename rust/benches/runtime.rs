//! PJRT runtime benches: the real compute hot path (AOT'd GPT-2 steps,
//! fused Pallas sign-update kernel, host<->device literal overhead).
//!
//! Requires `make artifacts`.  cargo bench --bench runtime

use std::time::Duration;

use dsm::data::corpus::{generate, CorpusConfig};
use dsm::data::dataset::TokenDataset;
use dsm::data::ByteTokenizer;
use dsm::runtime::{Artifacts, ModelBundle, Runtime, SignUpdateKernel, SignUpdateScalars};
use dsm::util::bench::{black_box, Bencher};
use dsm::util::rng::Rng;

fn main() {
    let arts = match Artifacts::load(&Artifacts::default_dir()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("skipping runtime bench: {e:#} (run `make artifacts`)");
            return;
        }
    };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    // long-ish budget: each iteration is an entire fwd+bwd
    let mut b = Bencher::new(Duration::from_secs(3), Duration::from_millis(500));

    let corpus = generate(&CorpusConfig { bytes: 1 << 20, ..Default::default() });
    let ds = TokenDataset::from_text(&ByteTokenizer, &corpus, 0.1);
    let mut rng = Rng::new(1);

    for preset in ["nano", "small", "medium"] {
        let Ok(info) = arts.preset(preset) else { continue };
        let bundle = ModelBundle::load(&rt, info).expect("compile");
        let params = bundle.init_params(42).expect("init");
        let batch = ds.sample_train(0, 1, info.batch, info.seq, &mut rng);
        let tokens = (info.batch * info.seq) as u64;
        // report tokens/s via bytes field (1 "byte" == 1 token)
        b.bench_with_bytes(
            &format!("{preset}::train_step ({}p, {} tok)", info.param_count, tokens),
            None,
            || {
                black_box(bundle.train_step(black_box(&params), &batch).unwrap());
            },
        );
        b.bench(&format!("{preset}::eval_loss"), || {
            black_box(bundle.eval_loss(black_box(&params), &batch).unwrap());
        });
    }

    // fused Pallas sign-update kernel vs the native Rust implementation
    println!("\n== Algorithm-1 global step: Pallas kernel vs native Rust ==");
    let kernel = SignUpdateKernel::load(&rt, &arts).expect("sign kernel");
    let p = 1 << 20;
    let mut rngk = Rng::new(9);
    let mut x = vec![0.0f32; p];
    let mut m = vec![0.0f32; p];
    let mut d = vec![0.0f32; p];
    rngk.fill_normal(&mut x, 0.02);
    rngk.fill_normal(&mut d, 0.001);
    let s = SignUpdateScalars {
        gamma: 1e-3,
        eta: 1.0,
        weight_decay: 0.1,
        beta1: 0.95,
        beta2: 0.98,
    };
    b.bench_with_bytes(&format!("pallas sign_update P={p}"), Some(p as u64 * 20), || {
        kernel.apply(black_box(&mut x), &mut m, &d, s).unwrap();
    });
    let mut opt = dsm::outer::SignMomentum::new(
        p,
        1.0,
        0.95,
        0.98,
        0.1,
        dsm::sign::SignOp::Exact,
        1.0,
    );
    let mut global = x.clone();
    let mut round = 0u64;
    b.bench_with_bytes(&format!("rust   sign_update P={p}"), Some(p as u64 * 20), || {
        dsm::outer::run_synthetic_round(&mut opt, black_box(&mut global), &d, 1e-3, round);
        round += 1;
    });
}
