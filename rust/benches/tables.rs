//! End-to-end per-table benches: one *communication round* of every
//! configuration the paper's tables compare, on the nano preset — i.e.
//! the full system latency (τ local PJRT steps + all-reduce + global
//! step) per outer algorithm.  One bench group per paper table.
//!
//! Requires `make artifacts`.  cargo bench --bench tables

use std::time::Duration;

use dsm::config::{RunConfig, TrainMode};
use dsm::optim::BaseOptConfig;
use dsm::outer::OuterConfig;
use dsm::runtime::{Artifacts, ModelBundle, Runtime};
use dsm::train::Trainer;
use dsm::util::bench::Bencher;

fn bench_round(
    b: &mut Bencher,
    rt: &Runtime,
    arts: &Artifacts,
    bundle: std::sync::Arc<ModelBundle>,
    name: &str,
    mode: TrainMode,
    tau: usize,
    base: BaseOptConfig,
    outer: OuterConfig,
) {
    let mut cfg = RunConfig::paper_default("nano");
    cfg.mode = mode;
    cfg.tau = tau;
    cfg.rounds = 1_000_000; // bench drives rounds manually
    cfg.n_workers = 4;
    cfg.base = base;
    cfg.outer = outer;
    cfg.eval_every = 0;
    cfg.corpus_bytes = 1 << 20;
    cfg.tag = name.to_string();
    let mut trainer = Trainer::with_bundle(cfg, bundle, rt, arts).expect("trainer");
    b.bench(name, || {
        trainer.step_round().expect("round");
    });
}

fn main() {
    let arts = match Artifacts::load(&Artifacts::default_dir()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("skipping tables bench: {e:#} (run `make artifacts`)");
            return;
        }
    };
    let rt = Runtime::cpu().expect("client");
    let bundle =
        std::sync::Arc::new(ModelBundle::load(&rt, arts.preset("nano").expect("nano")).unwrap());
    let mut b = Bencher::new(Duration::from_secs(4), Duration::from_millis(600));
    let adamw = BaseOptConfig::adamw_paper;

    println!("== Table 2 / Figures 1-2: main methods, one comm round (nano, n=4) ==");
    bench_round(
        &mut b, &rt, &arts, bundle.clone(),
        "tab2/adamw-standalone (tau=1)",
        TrainMode::Standalone, 1, adamw(), OuterConfig::LocalAvg,
    );
    for tau in [12usize, 24] {
        bench_round(
            &mut b, &rt, &arts, bundle.clone(),
            &format!("tab2/slowmo tau={tau}"),
            TrainMode::LocalSteps, tau, adamw(), OuterConfig::SlowMo { alpha: 1.0, beta: 0.5 },
        );
        bench_round(
            &mut b, &rt, &arts, bundle.clone(),
            &format!("tab2/algorithm1 tau={tau}"),
            TrainMode::LocalSteps, tau, adamw(), OuterConfig::sign_momentum_paper(1.0),
        );
    }

    println!("\n== Table 3: Sophia base ==");
    bench_round(
        &mut b, &rt, &arts, bundle.clone(),
        "tab3/algorithm1+sophia tau=12",
        TrainMode::LocalSteps, 12, BaseOptConfig::sophia_paper(),
        OuterConfig::sign_momentum_paper(1.0),
    );

    println!("\n== Tables 4-5: n=1 Lookahead variants ==");
    for (name, signed) in [("tab4/lookahead", false), ("tab5/signed-lookahead", true)] {
        let mut cfg = RunConfig::paper_default("nano");
        cfg.tau = 12;
        cfg.rounds = 1_000_000;
        cfg.n_workers = 1;
        cfg.outer = OuterConfig::Lookahead { eta: 1.0, beta: 0.2, signed };
        cfg.eval_every = 0;
        cfg.corpus_bytes = 1 << 20;
        cfg.tag = name.to_string();
        let mut trainer = Trainer::with_bundle(cfg, bundle.clone(), &rt, &arts).unwrap();
        b.bench(&format!("{name} tau=12 (n=1)"), || {
            trainer.step_round().unwrap();
        });
    }

    println!("\n== Table 6: ablation outer steps ==");
    bench_round(
        &mut b, &rt, &arts, bundle.clone(),
        "tab6/signed-slowmo tau=12",
        TrainMode::LocalSteps, 12, adamw(), OuterConfig::SignedSlowMo { eta: 1.0, beta: 0.5 },
    );
    bench_round(
        &mut b, &rt, &arts, bundle.clone(),
        "tab6/global-adamw tau=12",
        TrainMode::LocalSteps, 12, adamw(),
        OuterConfig::GlobalAdamW {
            eta: 1e-3,
            beta1: 0.9,
            beta2: 0.95,
            eps: 1e-8,
            weight_decay: 0.1,
        },
    );

    println!("\n== Figure 3: local averaging ==");
    bench_round(
        &mut b, &rt, &arts, bundle,
        "fig3/local-avg tau=12",
        TrainMode::LocalSteps, 12, adamw(), OuterConfig::LocalAvg,
    );
}
