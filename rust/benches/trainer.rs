//! Round wall-clock of the worker fleet — sequential reference vs
//! parallel execution on the persistent pool, at n ∈ {4, 8} — plus the
//! eval pass (serial `eval_loss_many` vs batches fanned across the
//! pool) and the quantized pack path (per-message `q8` vs per-tensor
//! `q8pt` over a real multi-segment transformer layout).
//!
//!     cargo bench --bench trainer              # human-readable table
//!     cargo bench --bench trainer -- --json    # also write BENCH_trainer.json
//!     cargo bench --bench trainer -- --quick   # fewer timed rounds (CI)
//!
//! Runs on the pure-Rust [`NativeBundle`] backends, so no PJRT
//! artifacts are required — this is the repo's recorded perf trajectory
//! for the fleet fan-out (`BENCH_trainer.json` at the workspace root).
//! Both execution modes of either pass compute bit-identical results
//! (rust/tests/parallel_fleet.rs); only wall-clock differs.

use std::sync::Arc;
use std::time::Instant;

use dsm::config::RunConfig;
use dsm::dist::{pool, WireFormat, WirePayload};
use dsm::runtime::{NativeBundle, StepBackend};
use dsm::train::Trainer;

const PRESET: &str = "native";

/// Heavier than the test backend so per-rank compute dominates pool
/// dispatch: batch 4 × seq 32 × d_model 48 -> P = 24576, ~128 positions
/// of a 48×256 MLP per step.
fn backend() -> Arc<NativeBundle> {
    Arc::new(NativeBundle::new(PRESET, 4, 32, 48))
}

fn cfg(n: usize, tau: usize, sequential: bool) -> RunConfig {
    let mut cfg = RunConfig::paper_default(PRESET);
    cfg.n_workers = n;
    cfg.tau = tau;
    cfg.rounds = 1_000_000; // the bench drives rounds manually
    cfg.eval_every = 0;
    cfg.corpus_bytes = 1 << 18;
    cfg.sequential_workers = sequential;
    cfg.tag = format!("bench-n{n}-{}", if sequential { "seq" } else { "par" });
    cfg
}

/// Mean seconds per outer round over `rounds` timed rounds (after one
/// warmup round that also faults in the pool and page cache).
fn time_rounds(n: usize, tau: usize, sequential: bool, rounds: usize) -> f64 {
    let mut trainer = Trainer::with_backend(cfg(n, tau, sequential), backend()).unwrap();
    trainer.step_round().expect("warmup round");
    let t0 = Instant::now();
    for _ in 0..rounds {
        trainer.step_round().expect("timed round");
    }
    t0.elapsed().as_secs_f64() / rounds as f64
}

/// Mean seconds per full eval pass (`eval_batches` batches): serial
/// reference vs batches fanned across the persistent pool.
fn time_eval(eval_batches: usize, sequential: bool, reps: usize) -> f64 {
    let mut c = cfg(4, 1, sequential);
    c.eval_batches = eval_batches;
    let mut trainer = Trainer::with_backend(c, backend()).unwrap();
    trainer.evaluate().expect("warmup eval");
    let t0 = Instant::now();
    for _ in 0..reps {
        trainer.evaluate().expect("timed eval");
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

/// Mean seconds per `pack_end` of a P-coordinate difference into a
/// quantized payload — per-message scale vs per-tensor scales over the
/// 4-block transformer layout (27 segments). Same bytes written either
/// way; the per-tensor path additionally resolves segment boundaries
/// and computes one max per segment instead of one global max.
fn time_quantize(reps: usize) -> (f64, f64, usize, usize) {
    let tb = NativeBundle::transformer("bench-tf", 1, 32, 64, 4);
    let layout = Arc::new(tb.layout().clone());
    let p = layout.param_count();
    let segments = layout.len();
    // deterministic hetero-magnitude difference: each segment moves at
    // its own scale, the case q8pt exists for
    let start = vec![0.0f32; p];
    let mut end = vec![0.0f32; p];
    for (si, e) in layout.entries().iter().enumerate() {
        let scale = 10f32.powi(-((si % 4) as i32));
        for i in e.offset..e.offset + e.numel() {
            end[i] = scale * ((i as f32) * 0.37).sin();
        }
    }
    let mut q8 = WirePayload::with_len(WireFormat::QuantizedI8, p);
    let mut q8pt = WirePayload::with_layout(WireFormat::QuantizedI8PerTensor, &layout);
    q8.pack_end(&start, &end);
    q8pt.pack_end(&start, &end);
    let t0 = Instant::now();
    for _ in 0..reps {
        q8.pack_end(&start, &end);
    }
    let q8_s = t0.elapsed().as_secs_f64() / reps as f64;
    let t0 = Instant::now();
    for _ in 0..reps {
        q8pt.pack_end(&start, &end);
    }
    let q8pt_s = t0.elapsed().as_secs_f64() / reps as f64;
    (q8_s, q8pt_s, p, segments)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let json = args.iter().any(|a| a == "--json");
    let quick = args.iter().any(|a| a == "--quick");
    let rounds = if quick { 3 } else { 8 };
    let tau = 6;

    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let threads = pool::global().helpers() + 1;
    println!(
        "fleet round wall-clock (native backend, tau={tau}, {rounds} timed rounds, \
         {cores} cores, pool {threads} threads)"
    );

    let mut entries = Vec::new();
    for n in [4usize, 8] {
        let seq_s = time_rounds(n, tau, true, rounds);
        let par_s = time_rounds(n, tau, false, rounds);
        let speedup = seq_s / par_s;
        println!(
            "n={n}: sequential {:>8.2} ms/round | parallel {:>8.2} ms/round | speedup {speedup:.2}x",
            seq_s * 1e3,
            par_s * 1e3
        );
        entries.push(format!(
            "    {{\"n\": {n}, \"tau\": {tau}, \"sequential_round_s\": {seq_s:.6}, \
             \"parallel_round_s\": {par_s:.6}, \"speedup\": {speedup:.3}}}"
        ));
    }

    // eval pass: serial vs pooled over the same validation batches
    let eval_batches = 16usize;
    let eval_reps = if quick { 3 } else { 8 };
    let eval_seq_s = time_eval(eval_batches, true, eval_reps);
    let eval_par_s = time_eval(eval_batches, false, eval_reps);
    let eval_speedup = eval_seq_s / eval_par_s;
    println!(
        "eval ({eval_batches} batches): sequential {:>8.2} ms | pooled {:>8.2} ms | speedup {eval_speedup:.2}x",
        eval_seq_s * 1e3,
        eval_par_s * 1e3
    );

    // quantized pack path: per-message scale vs per-tensor scales
    let quant_reps = if quick { 20 } else { 200 };
    let (q8_s, q8pt_s, quant_p, quant_segments) = time_quantize(quant_reps);
    println!(
        "quantize (P={quant_p}, {quant_segments} segments): q8 {:>8.3} ms | q8pt {:>8.3} ms | ratio {:.2}x",
        q8_s * 1e3,
        q8pt_s * 1e3,
        q8pt_s / q8_s
    );

    if json {
        let body = format!(
            "{{\n  \"bench\": \"trainer_fleet_round\",\n  \"backend\": \"native\",\n  \
             \"host_cores\": {cores},\n  \"pool_threads\": {threads},\n  \
             \"timed_rounds\": {rounds},\n  \"results\": [\n{}\n  ],\n  \
             \"eval\": {{\"batches\": {eval_batches}, \"sequential_s\": {eval_seq_s:.6}, \
             \"pooled_s\": {eval_par_s:.6}, \"speedup\": {eval_speedup:.3}}},\n  \
             \"quantize\": {{\"p\": {quant_p}, \"segments\": {quant_segments}, \
             \"q8_pack_s\": {q8_s:.6}, \"q8pt_pack_s\": {q8pt_s:.6}, \
             \"q8pt_over_q8\": {:.3}}}\n}}\n",
            entries.join(",\n"),
            q8pt_s / q8_s
        );
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("workspace root")
            .join("BENCH_trainer.json");
        std::fs::write(&path, body).expect("writing BENCH_trainer.json");
        println!("wrote {path:?}");
    }
}
