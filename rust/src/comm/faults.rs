//! Fault injection plan and bookkeeping for the simulated fleet.
//!
//! [`FaultPlan`] is a config knob: probabilities for the four modeled
//! *honest* failure modes of a round exchange plus the Byzantine
//! adversary model, all driven by the trainer's dedicated, checkpointed
//! fault RNG stream (never the training stream, so toggling faults
//! cannot shift optimization draws):
//!
//! * **Elastic membership** (`churn_prob`) — each rank independently
//!   sits the round out before the local phase starts (left/not-yet-
//!   joined); at least one rank is always kept. Absent ranks run no
//!   local steps, consume none of their worker RNG, and rejoin
//!   automatically next round from the broadcast global.
//! * **Heavy-tailed stragglers** (`tail_prob`, `tail_scale_s`,
//!   `tail_alpha`) — with probability `tail_prob` per round, one rank
//!   stalls for a Pareto(α)-distributed extra delay on top of the
//!   lognormal jitter the [`super::CommModel`] already bills.
//! * **Dropped payloads** (`drop_prob`) — a participating rank's packed
//!   payload is lost in transit: it never reaches the aggregation point
//!   (not billed, not aggregated) and the round proceeds over the
//!   `n_effective` survivors. With `retry_limit > 0` each dropped rank
//!   retransmits up to that many times (each attempt an independent
//!   `drop_prob` draw on the fault stream, counted in
//!   [`FaultStats::retried_payloads`]); a recovered payload rejoins the
//!   arrived set and is billed through the degraded gather.
//! * **Corrupted payloads** (`corrupt_prob`) — a payload arrives
//!   damaged: a bit-flipped quantized byte or sign word (a valid
//!   encoding — survived, with bounded error) or a NaN-poisoned scale /
//!   dense coordinate (detected by the finiteness check and rejected
//!   from the aggregate, loudly counted).
//!
//! # Byzantine ranks
//!
//! `byzantine_frac` promotes `⌊frac·n⌋` ranks to adversaries. The
//! membership is drawn **once per run** at trainer construction from the
//! checkpointed fault stream (a fresh substream is seed-determined, so a
//! resumed run recomputes the identical set), and per-round behavior
//! draws ride the same stream — membership and behavior are
//! bit-reproducible. Adversaries train honestly but mutate their
//! payload after packing ([`crate::dist::WirePayload::byzantine`]);
//! every attack produces *finite* payloads, so the PR-6 finiteness gate
//! never catches them — that is the point.
//!
//! Attack × defense breakdown points (n ranks, f adversaries, trim
//! depth k = max(1, n/4), see [`crate::dist::wire`] for the policies):
//!
//! | attack          | `mean`            | `trimmed`     | `median`      | MV tally (signs) |
//! |-----------------|-------------------|---------------|---------------|------------------|
//! | `sign_flip`     | biased (f/n)      | holds f ≤ k   | holds f < n/2 | holds f < n/2    |
//! | `scale_inflate` | poisoned at any f | holds f ≤ k   | holds f < n/2 | immune (no magnitude on the wire) |
//! | `collude_fixed` | poisoned at any f | holds f ≤ k   | holds f < n/2 | holds f < n/2    |
//! | `flaky`         | poisoned at any f | holds f ≤ k   | holds f < n/2 | holds f < n/2    |
//!
//! # Reputation / quarantine lifecycle
//!
//! With `quarantine = true` the trainer scores every arrived payload
//! each round (update-norm z-score against the survivor median, sign
//! agreement against the applied global update), folds the verdict into
//! an exponentially-decayed per-rank reputation, and quarantines ranks
//! whose reputation falls below threshold: a quarantined rank is frozen
//! exactly like a churn-absent rank (no local steps, no worker RNG, no
//! payload, billed as absent) for a backoff that doubles on each
//! relapse, then re-admitted **on probation** — its reputation restarts
//! just above threshold, so one more bad round re-quarantines it
//! immediately. Reputations, backoff state, and the counters below ride
//! in the checkpoint, so a faulty resume is bit-identical.
//!
//! [`FaultStats`] counts what actually happened, rides in the
//! checkpoint (a tagged, versioned f32-limb encoding; the untagged
//! 20-word layout of earlier checkpoints still loads), and is surfaced
//! on the run result so experiments can report survival.

use anyhow::{ensure, Result};

/// Per-round behavior of a Byzantine rank. Every attack emits *finite*
/// payloads (the finiteness gate must not catch them) and none of them
/// consumes RNG on its own — only `flaky`'s honest/lie coin does, one
/// draw per adversary per round on the fault stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Attack {
    /// Negate the rank's local difference (votes flip on the 1-bit wire).
    SignFlip,
    /// Inflate the difference magnitude by a large fixed factor
    /// (direction-preserving; sign wires are immune — no magnitude).
    ScaleInflate,
    /// All adversaries push the identical fixed direction: +1 on every
    /// transmitted coordinate (all-plus votes on the sign wire).
    ColludeFixed,
    /// Honest with probability 1/2 per round, else `SignFlip` — the
    /// intermittent liar that reputation decay is tuned to catch.
    Flaky,
}

impl Attack {
    pub fn parse(s: &str) -> Option<Attack> {
        Some(match s {
            "sign_flip" => Attack::SignFlip,
            "scale_inflate" => Attack::ScaleInflate,
            "collude_fixed" => Attack::ColludeFixed,
            "flaky" => Attack::Flaky,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Attack::SignFlip => "sign_flip",
            Attack::ScaleInflate => "scale_inflate",
            Attack::ColludeFixed => "collude_fixed",
            Attack::Flaky => "flaky",
        }
    }
}

/// Per-round fault injection probabilities. `FaultPlan::none()` (the
/// default) disables every mode and keeps the trainer on the exact
/// fault-free code path, preserving all bit-identity invariants.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    /// Per-rank probability of sitting a round out entirely.
    pub churn_prob: f64,
    /// Per-payload probability of being dropped in transit.
    pub drop_prob: f64,
    /// Per-payload probability of arriving corrupted.
    pub corrupt_prob: f64,
    /// Per-round probability of one heavy-tail straggler event.
    pub tail_prob: f64,
    /// Pareto scale (seconds) of the heavy-tail stall.
    pub tail_scale_s: f64,
    /// Pareto shape α; smaller is heavier-tailed (α ≤ 1 has no mean).
    pub tail_alpha: f64,
    /// Fraction of ranks promoted to adversaries (⌊frac·n⌋, drawn once
    /// per run on the fault stream).
    pub byzantine_frac: f64,
    /// What the adversaries send. Only meaningful with
    /// `byzantine_frac > 0`.
    pub attack: Attack,
    /// Retransmission attempts per dropped payload (0 = PR-6 semantics:
    /// dropped is gone).
    pub retry_limit: u32,
    /// Enable the reputation/quarantine supervisor.
    pub quarantine: bool,
}

impl FaultPlan {
    /// No faults: the trainer takes the exact pre-fault code path.
    pub fn none() -> FaultPlan {
        FaultPlan {
            churn_prob: 0.0,
            drop_prob: 0.0,
            corrupt_prob: 0.0,
            tail_prob: 0.0,
            tail_scale_s: 1.0,
            tail_alpha: 1.5,
            byzantine_frac: 0.0,
            attack: Attack::SignFlip,
            retry_limit: 0,
            quarantine: false,
        }
    }

    /// Whether any fault mode can fire.
    pub fn is_active(&self) -> bool {
        self.churn_prob > 0.0
            || self.drop_prob > 0.0
            || self.corrupt_prob > 0.0
            || self.tail_prob > 0.0
            || self.byzantine_frac > 0.0
    }

    pub fn validate(&self) -> Result<()> {
        for (name, p) in [
            ("churn_prob", self.churn_prob),
            ("drop_prob", self.drop_prob),
            ("corrupt_prob", self.corrupt_prob),
            ("tail_prob", self.tail_prob),
        ] {
            ensure!((0.0..=1.0).contains(&p) && p.is_finite(), "faults.{name} = {p} not in [0, 1]");
        }
        ensure!(self.churn_prob < 1.0, "faults.churn_prob = 1 would empty every round");
        ensure!(
            self.tail_scale_s.is_finite() && self.tail_scale_s >= 0.0,
            "faults.tail_scale_s = {} must be finite and >= 0",
            self.tail_scale_s
        );
        ensure!(
            self.tail_alpha.is_finite() && self.tail_alpha > 0.0,
            "faults.tail_alpha = {} must be finite and > 0",
            self.tail_alpha
        );
        ensure!(
            (0.0..1.0).contains(&self.byzantine_frac) && self.byzantine_frac.is_finite(),
            "faults.byzantine_frac = {} not in [0, 1) — a fully adversarial fleet has no honest \
             signal to recover",
            self.byzantine_frac
        );
        // knob hygiene: a modifier without the mode it modifies is a
        // config mistake, not a silent no-op
        ensure!(
            self.retry_limit == 0 || self.drop_prob > 0.0,
            "faults.retry_limit = {} without drop_prob > 0 retries nothing",
            self.retry_limit
        );
        ensure!(
            !self.quarantine || self.byzantine_frac > 0.0,
            "faults.quarantine = true without byzantine_frac > 0 supervises nothing"
        );
        Ok(())
    }

    /// One-token summary for run descriptions / cache keys; empty when
    /// inactive so fault-free keys are unchanged, and the Byzantine /
    /// retry segments only appear when those knobs are on so pre-PR-8
    /// fault strings are unchanged too.
    pub fn describe(&self) -> String {
        if !self.is_active() {
            return String::new();
        }
        let mut s = format!(
            " faults[churn={},drop={},corrupt={},tail={}x{}s@a{}",
            self.churn_prob,
            self.drop_prob,
            self.corrupt_prob,
            self.tail_prob,
            self.tail_scale_s,
            self.tail_alpha
        );
        if self.byzantine_frac > 0.0 {
            s.push_str(&format!(",byz={}@{}", self.byzantine_frac, self.attack.name()));
            if self.quarantine {
                s.push_str(",quarantine");
            }
        }
        if self.retry_limit > 0 {
            s.push_str(&format!(",retry={}", self.retry_limit));
        }
        s.push(']');
        s
    }
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan::none()
    }
}

/// What the injected faults actually did, accumulated over a run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Ranks that sat a round out (elastic membership + quarantine).
    pub absent_ranks: u64,
    /// Payloads lost in transit (and not recovered by a retry).
    pub dropped_payloads: u64,
    /// Payloads that arrived corrupted (survived or rejected).
    pub corrupted_payloads: u64,
    /// Corrupted payloads the finiteness check excluded from the round.
    pub rejected_payloads: u64,
    /// Rounds where no payload survived; the global stays put.
    pub no_quorum_rounds: u64,
    /// Retransmission attempts drawn for dropped payloads (both the
    /// attempt that recovered the payload and attempts that were
    /// themselves dropped).
    pub retried_payloads: u64,
    /// Quarantine entries issued by the supervisor.
    pub quarantined_ranks: u64,
    /// Applied rounds in which at least one adversarial payload reached
    /// the aggregation point.
    pub byzantine_rounds_survived: u64,
    /// Quarantined ranks re-admitted on probation.
    pub readmissions: u64,
}

impl FaultStats {
    /// Tagged checkpoint encoding: `[TAG, n_counters]` then 9 counters
    /// × four exact 16-bit limbs. The tag word distinguishes the
    /// layout from the legacy untagged 20-word encoding (which
    /// [`Self::from_f32_words`] still accepts, zeroing the counters
    /// that did not exist yet); any other length errors loudly instead
    /// of silently dropping the stats.
    pub const F32_WORDS: usize = 2 + 9 * 4;

    /// Layout tag of the current encoding (exactly representable in f32).
    const TAG: f32 = 9002.0;
    /// Word count of the pre-PR-8 untagged encoding (5 counters).
    const LEGACY_F32_WORDS: usize = 20;

    fn fields(&self) -> [u64; 9] {
        [
            self.absent_ranks,
            self.dropped_payloads,
            self.corrupted_payloads,
            self.rejected_payloads,
            self.no_quorum_rounds,
            self.retried_payloads,
            self.quarantined_ranks,
            self.byzantine_rounds_survived,
            self.readmissions,
        ]
    }

    pub fn to_f32_words(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(Self::F32_WORDS);
        out.push(Self::TAG);
        out.push(9.0);
        for v in self.fields() {
            for shift in [0u32, 16, 32, 48] {
                out.push(((v >> shift) & 0xFFFF) as f32);
            }
        }
        out
    }

    /// Decode either encoding; a malformed buffer is a loud error (a
    /// resume must never silently zero its fault history).
    pub fn from_f32_words(words: &[f32]) -> Result<FaultStats, String> {
        let counters = match words.len() {
            Self::LEGACY_F32_WORDS => &words[..],
            Self::F32_WORDS => {
                if words[0] != Self::TAG || words[1] != 9.0 {
                    return Err(format!(
                        "fault-stats buffer has tag {}/{}, expected {}/9",
                        words[0],
                        words[1],
                        Self::TAG
                    ));
                }
                &words[2..]
            }
            n => {
                return Err(format!(
                    "fault-stats buffer has {n} words; expected {} (tagged) or {} (legacy)",
                    Self::F32_WORDS,
                    Self::LEGACY_F32_WORDS
                ))
            }
        };
        let mut vals = [0u64; 9];
        for (i, v) in vals.iter_mut().enumerate().take(counters.len() / 4) {
            for (j, shift) in [0u32, 16, 32, 48].iter().enumerate() {
                let x = counters[i * 4 + j] as f64;
                if !(0.0..65536.0).contains(&x) || x.fract() != 0.0 {
                    return Err(format!("fault-stats limb {} = {x} is not a 16-bit value", i * 4 + j));
                }
                *v |= (x as u64) << shift;
            }
        }
        Ok(FaultStats {
            absent_ranks: vals[0],
            dropped_payloads: vals[1],
            corrupted_payloads: vals[2],
            rejected_payloads: vals[3],
            no_quorum_rounds: vals[4],
            retried_payloads: vals[5],
            quarantined_ranks: vals[6],
            byzantine_rounds_survived: vals[7],
            readmissions: vals[8],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_inactive_and_valid() {
        let p = FaultPlan::none();
        assert!(!p.is_active());
        assert!(p.validate().is_ok());
        assert!(p.describe().is_empty());
    }

    #[test]
    fn any_nonzero_knob_activates() {
        for f in [
            |p: &mut FaultPlan| p.churn_prob = 0.1,
            |p: &mut FaultPlan| p.drop_prob = 0.1,
            |p: &mut FaultPlan| p.corrupt_prob = 0.1,
            |p: &mut FaultPlan| p.tail_prob = 0.1,
            |p: &mut FaultPlan| p.byzantine_frac = 0.25,
        ] {
            let mut p = FaultPlan::none();
            f(&mut p);
            assert!(p.is_active());
            assert!(p.validate().is_ok());
            assert!(p.describe().contains("faults["));
        }
    }

    #[test]
    fn validate_rejects_bad_probabilities() {
        let mut p = FaultPlan::none();
        p.drop_prob = 1.5;
        assert!(p.validate().is_err());
        let mut p = FaultPlan::none();
        p.churn_prob = 1.0;
        assert!(p.validate().is_err(), "churn=1 empties every round");
        let mut p = FaultPlan::none();
        p.corrupt_prob = f64::NAN;
        assert!(p.validate().is_err());
        let mut p = FaultPlan::none();
        p.tail_alpha = 0.0;
        assert!(p.validate().is_err());
        let mut p = FaultPlan::none();
        p.byzantine_frac = 1.0;
        assert!(p.validate().is_err(), "a fully adversarial fleet is rejected");
    }

    #[test]
    fn modifier_knobs_require_their_mode() {
        let mut p = FaultPlan::none();
        p.retry_limit = 3;
        assert!(p.validate().is_err(), "retry without drops");
        p.drop_prob = 0.1;
        assert!(p.validate().is_ok());
        let mut p = FaultPlan::none();
        p.quarantine = true;
        assert!(p.validate().is_err(), "quarantine without adversaries");
        p.byzantine_frac = 0.125;
        assert!(p.validate().is_ok());
    }

    #[test]
    fn attack_names_roundtrip() {
        for a in [Attack::SignFlip, Attack::ScaleInflate, Attack::ColludeFixed, Attack::Flaky] {
            assert_eq!(Attack::parse(a.name()), Some(a));
        }
        assert_eq!(Attack::parse("dos"), None);
    }

    #[test]
    fn describe_extends_but_never_rewrites_the_honest_segment() {
        let mut p = FaultPlan::none();
        p.drop_prob = 0.1;
        let honest = p.describe();
        p.byzantine_frac = 0.125;
        p.attack = Attack::ScaleInflate;
        p.quarantine = true;
        p.retry_limit = 2;
        let full = p.describe();
        // the honest prefix is intact — pre-PR-8 cache keys for runs
        // without the new knobs cannot shift
        assert!(full.starts_with(honest.trim_end_matches(']')), "{honest} vs {full}");
        assert!(full.contains("byz=0.125@scale_inflate"));
        assert!(full.contains("quarantine"));
        assert!(full.contains("retry=2"));
    }

    #[test]
    fn stats_roundtrip_exactly_through_f32_words() {
        let s = FaultStats {
            absent_ranks: u64::MAX,
            dropped_payloads: 1 << 40,
            corrupted_payloads: 3,
            rejected_payloads: 0,
            no_quorum_rounds: 65535,
            retried_payloads: 7,
            quarantined_ranks: 2,
            byzantine_rounds_survived: 1 << 33,
            readmissions: 1,
        };
        let words = s.to_f32_words();
        assert_eq!(words.len(), FaultStats::F32_WORDS);
        assert_eq!(FaultStats::from_f32_words(&words), Ok(s));
        assert!(FaultStats::from_f32_words(&[1.0]).is_err());
        let mut bad = words.clone();
        bad[2] = 0.5;
        assert!(FaultStats::from_f32_words(&bad).is_err());
        let mut wrong_tag = words;
        wrong_tag[0] = 1.0;
        assert!(FaultStats::from_f32_words(&wrong_tag).is_err());
    }

    #[test]
    fn legacy_untagged_encoding_still_loads() {
        // the pre-PR-8 layout: 5 counters × 4 limbs, no tag word
        let legacy = FaultStats {
            absent_ranks: 3,
            dropped_payloads: 1 << 20,
            corrupted_payloads: 9,
            rejected_payloads: 4,
            no_quorum_rounds: 70000,
            ..FaultStats::default()
        };
        let mut words = Vec::new();
        for v in [3u64, 1 << 20, 9, 4, 70000] {
            for shift in [0u32, 16, 32, 48] {
                words.push(((v >> shift) & 0xFFFF) as f32);
            }
        }
        assert_eq!(words.len(), 20);
        assert_eq!(FaultStats::from_f32_words(&words), Ok(legacy));
    }
}
