//! Fault injection plan and bookkeeping for the simulated fleet.
//!
//! [`FaultPlan`] is a config knob: probabilities for the four modeled
//! failure modes of a round exchange, all driven by the trainer's
//! dedicated, checkpointed fault RNG stream (never the training stream,
//! so toggling faults cannot shift optimization draws):
//!
//! * **Elastic membership** (`churn_prob`) — each rank independently
//!   sits the round out before the local phase starts (left/not-yet-
//!   joined); at least one rank is always kept. Absent ranks run no
//!   local steps, consume none of their worker RNG, and rejoin
//!   automatically next round from the broadcast global.
//! * **Heavy-tailed stragglers** (`tail_prob`, `tail_scale_s`,
//!   `tail_alpha`) — with probability `tail_prob` per round, one rank
//!   stalls for a Pareto(α)-distributed extra delay on top of the
//!   lognormal jitter the [`super::CommModel`] already bills.
//! * **Dropped payloads** (`drop_prob`) — a participating rank's packed
//!   payload is lost in transit: it never reaches the aggregation point
//!   (not billed, not aggregated) and the round proceeds over the
//!   `n_effective` survivors.
//! * **Corrupted payloads** (`corrupt_prob`) — a payload arrives
//!   damaged: a bit-flipped quantized byte or sign word (a valid
//!   encoding — survived, with bounded error) or a NaN-poisoned scale /
//!   dense coordinate (detected by the finiteness check and rejected
//!   from the aggregate, loudly counted).
//!
//! [`FaultStats`] counts what actually happened, rides in the
//! checkpoint (same exact 16-bit-limb f32 encoding as the clock), and
//! is surfaced on the run result so experiments can report survival.

use anyhow::{ensure, Result};

/// Per-round fault injection probabilities. `FaultPlan::none()` (the
/// default) disables every mode and keeps the trainer on the exact
/// fault-free code path, preserving all bit-identity invariants.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    /// Per-rank probability of sitting a round out entirely.
    pub churn_prob: f64,
    /// Per-payload probability of being dropped in transit.
    pub drop_prob: f64,
    /// Per-payload probability of arriving corrupted.
    pub corrupt_prob: f64,
    /// Per-round probability of one heavy-tail straggler event.
    pub tail_prob: f64,
    /// Pareto scale (seconds) of the heavy-tail stall.
    pub tail_scale_s: f64,
    /// Pareto shape α; smaller is heavier-tailed (α ≤ 1 has no mean).
    pub tail_alpha: f64,
}

impl FaultPlan {
    /// No faults: the trainer takes the exact pre-fault code path.
    pub fn none() -> FaultPlan {
        FaultPlan {
            churn_prob: 0.0,
            drop_prob: 0.0,
            corrupt_prob: 0.0,
            tail_prob: 0.0,
            tail_scale_s: 1.0,
            tail_alpha: 1.5,
        }
    }

    /// Whether any fault mode can fire.
    pub fn is_active(&self) -> bool {
        self.churn_prob > 0.0
            || self.drop_prob > 0.0
            || self.corrupt_prob > 0.0
            || self.tail_prob > 0.0
    }

    pub fn validate(&self) -> Result<()> {
        for (name, p) in [
            ("churn_prob", self.churn_prob),
            ("drop_prob", self.drop_prob),
            ("corrupt_prob", self.corrupt_prob),
            ("tail_prob", self.tail_prob),
        ] {
            ensure!((0.0..=1.0).contains(&p) && p.is_finite(), "faults.{name} = {p} not in [0, 1]");
        }
        ensure!(self.churn_prob < 1.0, "faults.churn_prob = 1 would empty every round");
        ensure!(
            self.tail_scale_s.is_finite() && self.tail_scale_s >= 0.0,
            "faults.tail_scale_s = {} must be finite and >= 0",
            self.tail_scale_s
        );
        ensure!(
            self.tail_alpha.is_finite() && self.tail_alpha > 0.0,
            "faults.tail_alpha = {} must be finite and > 0",
            self.tail_alpha
        );
        Ok(())
    }

    /// One-token summary for run descriptions / cache keys; empty when
    /// inactive so fault-free keys are unchanged.
    pub fn describe(&self) -> String {
        if !self.is_active() {
            return String::new();
        }
        format!(
            " faults[churn={},drop={},corrupt={},tail={}x{}s@a{}]",
            self.churn_prob,
            self.drop_prob,
            self.corrupt_prob,
            self.tail_prob,
            self.tail_scale_s,
            self.tail_alpha
        )
    }
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan::none()
    }
}

/// What the injected faults actually did, accumulated over a run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Ranks that sat a round out (elastic membership).
    pub absent_ranks: u64,
    /// Payloads lost in transit.
    pub dropped_payloads: u64,
    /// Payloads that arrived corrupted (survived or rejected).
    pub corrupted_payloads: u64,
    /// Corrupted payloads the finiteness check excluded from the round.
    pub rejected_payloads: u64,
    /// Rounds where no payload survived; the global stays put.
    pub no_quorum_rounds: u64,
}

impl FaultStats {
    /// Checkpoint encoding: 5 counters × four exact 16-bit limbs.
    pub const F32_WORDS: usize = 20;

    fn fields(&self) -> [u64; 5] {
        [
            self.absent_ranks,
            self.dropped_payloads,
            self.corrupted_payloads,
            self.rejected_payloads,
            self.no_quorum_rounds,
        ]
    }

    pub fn to_f32_words(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(Self::F32_WORDS);
        for v in self.fields() {
            for shift in [0u32, 16, 32, 48] {
                out.push(((v >> shift) & 0xFFFF) as f32);
            }
        }
        out
    }

    pub fn from_f32_words(words: &[f32]) -> Option<FaultStats> {
        if words.len() != Self::F32_WORDS {
            return None;
        }
        let mut vals = [0u64; 5];
        for (i, v) in vals.iter_mut().enumerate() {
            for (j, shift) in [0u32, 16, 32, 48].iter().enumerate() {
                let x = words[i * 4 + j] as f64;
                if !(0.0..65536.0).contains(&x) || x.fract() != 0.0 {
                    return None;
                }
                *v |= (x as u64) << shift;
            }
        }
        Some(FaultStats {
            absent_ranks: vals[0],
            dropped_payloads: vals[1],
            corrupted_payloads: vals[2],
            rejected_payloads: vals[3],
            no_quorum_rounds: vals[4],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_inactive_and_valid() {
        let p = FaultPlan::none();
        assert!(!p.is_active());
        assert!(p.validate().is_ok());
        assert!(p.describe().is_empty());
    }

    #[test]
    fn any_nonzero_knob_activates() {
        for f in [
            |p: &mut FaultPlan| p.churn_prob = 0.1,
            |p: &mut FaultPlan| p.drop_prob = 0.1,
            |p: &mut FaultPlan| p.corrupt_prob = 0.1,
            |p: &mut FaultPlan| p.tail_prob = 0.1,
        ] {
            let mut p = FaultPlan::none();
            f(&mut p);
            assert!(p.is_active());
            assert!(p.validate().is_ok());
            assert!(p.describe().contains("faults["));
        }
    }

    #[test]
    fn validate_rejects_bad_probabilities() {
        let mut p = FaultPlan::none();
        p.drop_prob = 1.5;
        assert!(p.validate().is_err());
        let mut p = FaultPlan::none();
        p.churn_prob = 1.0;
        assert!(p.validate().is_err(), "churn=1 empties every round");
        let mut p = FaultPlan::none();
        p.corrupt_prob = f64::NAN;
        assert!(p.validate().is_err());
        let mut p = FaultPlan::none();
        p.tail_alpha = 0.0;
        assert!(p.validate().is_err());
    }

    #[test]
    fn stats_roundtrip_exactly_through_f32_words() {
        let s = FaultStats {
            absent_ranks: u64::MAX,
            dropped_payloads: 1 << 40,
            corrupted_payloads: 3,
            rejected_payloads: 0,
            no_quorum_rounds: 65535,
        };
        let words = s.to_f32_words();
        assert_eq!(words.len(), FaultStats::F32_WORDS);
        assert_eq!(FaultStats::from_f32_words(&words), Some(s));
        assert_eq!(FaultStats::from_f32_words(&[1.0]), None);
        let mut bad = words.clone();
        bad[0] = 0.5;
        assert_eq!(FaultStats::from_f32_words(&bad), None);
    }
}
