//! Communication cost model — the substrate replacing the paper's GPU
//! cluster interconnect (DESIGN.md §5.2).
//!
//! The paper's motivation is that the per-step all-reduce dominates
//! wall-clock on slow interconnects, so methods with τ local steps save
//! ~τ× communication.  To reproduce the time-axis plots and
//! communication-reduction tables on a single-node testbed, every
//! collective charges simulated time from the standard α-β (latency-
//! bandwidth) model of a ring all-reduce:
//!
//! ```text
//!     T(n, bytes) = 2 (n-1) α  +  2 (n-1)/n · bytes / β
//! ```
//!
//! plus an optional straggler term: per round, the slowest of n i.i.d.
//! log-normal worker delays (Dean et al. 2012's tail-latency story).
//! Compute time is *measured* (the PJRT executions are real); comm time
//! is *modeled*; the trainer adds both onto a [`SimClock`].

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CommModel {
    /// Per-message latency α, seconds.
    pub latency_s: f64,
    /// Bandwidth β, bytes/second.
    pub bandwidth_bps: f64,
    /// Log-normal sigma of per-worker per-round delay (0 = no stragglers).
    pub straggler_sigma: f64,
    /// Median per-worker compute jitter in seconds (scale of the delay).
    pub straggler_scale_s: f64,
}

impl CommModel {
    /// Named presets spanning the regimes the paper targets (§1: NVLink
    /// intra-node vs slow inter-node / inter-cluster links).
    pub fn preset(name: &str) -> Option<CommModel> {
        Some(match name {
            // NVLink-class: 300 GB/s, ~5 µs
            "nvlink" => CommModel {
                latency_s: 5e-6,
                bandwidth_bps: 300e9,
                straggler_sigma: 0.0,
                straggler_scale_s: 0.0,
            },
            // InfiniBand HDR-class: 25 GB/s, ~20 µs
            "infiniband" | "ib" => CommModel {
                latency_s: 2e-5,
                bandwidth_bps: 25e9,
                straggler_sigma: 0.1,
                straggler_scale_s: 1e-4,
            },
            // Datacenter 10GbE: 1.25 GB/s, ~100 µs, visible stragglers
            "ethernet" | "eth" => CommModel {
                latency_s: 1e-4,
                bandwidth_bps: 1.25e9,
                straggler_sigma: 0.3,
                straggler_scale_s: 1e-3,
            },
            // Cross-region WAN: 50 MB/s, 30 ms, heavy tail
            "wan" | "cross_region" => CommModel {
                latency_s: 3e-2,
                bandwidth_bps: 5e7,
                straggler_sigma: 0.5,
                straggler_scale_s: 1e-2,
            },
            "none" | "free" => CommModel {
                latency_s: 0.0,
                bandwidth_bps: f64::INFINITY,
                straggler_sigma: 0.0,
                straggler_scale_s: 0.0,
            },
            _ => return None,
        })
    }

    /// Ring all-reduce time for `bytes` over `n` workers.
    pub fn allreduce_time(&self, n: usize, bytes: u64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let n = n as f64;
        2.0 * (n - 1.0) * self.latency_s + 2.0 * (n - 1.0) / n * bytes as f64 / self.bandwidth_bps
    }

    /// Broadcast (one-to-all over a binomial tree).
    pub fn broadcast_time(&self, n: usize, bytes: u64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let rounds = (n as f64).log2().ceil();
        rounds * (self.latency_s + bytes as f64 / self.bandwidth_bps)
    }

    /// Synchronization-barrier penalty: max of n log-normal delays.
    pub fn straggler_delay(&self, n: usize, rng: &mut Rng) -> f64 {
        if self.straggler_sigma == 0.0 || self.straggler_scale_s == 0.0 {
            return 0.0;
        }
        (0..n)
            .map(|_| self.straggler_scale_s * rng.lognormal(0.0, self.straggler_sigma))
            .fold(0.0, f64::max)
    }
}

/// Simulated wall clock: measured compute + modeled communication.
#[derive(Clone, Debug, Default)]
pub struct SimClock {
    pub compute_s: f64,
    pub comm_s: f64,
    pub straggler_s: f64,
    pub comm_rounds: u64,
    pub bytes_communicated: u64,
}

impl SimClock {
    pub fn total_s(&self) -> f64 {
        self.compute_s + self.comm_s + self.straggler_s
    }

    /// Charge one *sign-compressed* all-reduce over `n` workers: the
    /// payload is 1 bit per coordinate plus a small header
    /// ([`crate::dist::codec::sign_allreduce_bytes`]) instead of 4
    /// bytes per f32 — the wire cost of majority-vote sign exchange
    /// (MV-sto-signSGD and other signSGD-style methods).
    ///
    /// Deliberately optimistic: it reuses the ring α-β formula, i.e. an
    /// idealized lower bound. A real majority vote is not ring-reducible
    /// bit-by-bit — practical topologies pay a gather+broadcast (~n·P/8
    /// server bytes) or ship ⌈log2(n+1)⌉-bit tallies — so at large n
    /// this *understates* sign-vote traffic; refining the topology model
    /// is a ROADMAP follow-up.
    pub fn charge_sign_allreduce(
        &mut self,
        model: &CommModel,
        n: usize,
        n_params: usize,
        rng: &mut Rng,
    ) {
        let bytes = crate::dist::codec::sign_allreduce_bytes(n_params);
        self.charge_vote_allreduce(model, n, bytes, rng);
    }

    /// Charge a vote exchange whose per-message wire payload is
    /// `wire_bytes` — the packed data path bills the byte count of the
    /// [`crate::dist::PackedVotes`] buffers actually exchanged
    /// ([`crate::dist::PackedVotes::wire_bytes`]), so accounting and
    /// data path cannot drift apart.
    pub fn charge_vote_allreduce(
        &mut self,
        model: &CommModel,
        n: usize,
        wire_bytes: u64,
        rng: &mut Rng,
    ) {
        self.charge_allreduce(model, n, wire_bytes, rng);
    }

    /// Charge one all-reduce of `bytes` over `n` workers.
    pub fn charge_allreduce(&mut self, model: &CommModel, n: usize, bytes: u64, rng: &mut Rng) {
        self.comm_s += model.allreduce_time(n, bytes);
        self.straggler_s += model.straggler_delay(n, rng);
        self.comm_rounds += 1;
        if n > 1 {
            let moved = (bytes as u128) * 2 * (n as u128 - 1) / n as u128;
            self.bytes_communicated = self
                .bytes_communicated
                .saturating_add(moved.min(u64::MAX as u128) as u64);
        }
    }

    /// Charge measured compute time.  In the data-parallel simulation all
    /// n workers compute concurrently on real hardware sequentially, so
    /// the simulated elapsed time for one "parallel" local step is the
    /// max over workers ≈ the mean single-worker time (workers are
    /// homogeneous here); the caller passes the per-worker measurement.
    pub fn charge_parallel_compute(&mut self, per_worker_s: &[f64]) {
        self.compute_s += per_worker_s.iter().copied().fold(0.0, f64::max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_exist_and_rank_by_bandwidth() {
        let nv = CommModel::preset("nvlink").unwrap();
        let ib = CommModel::preset("ib").unwrap();
        let eth = CommModel::preset("eth").unwrap();
        let wan = CommModel::preset("wan").unwrap();
        assert!(CommModel::preset("bogus").is_none());
        let bytes = 100 * 1024 * 1024;
        let t = |m: &CommModel| m.allreduce_time(8, bytes);
        assert!(t(&nv) < t(&ib) && t(&ib) < t(&eth) && t(&eth) < t(&wan));
    }

    #[test]
    fn allreduce_alpha_beta_formula() {
        let m = CommModel {
            latency_s: 1e-3,
            bandwidth_bps: 1e9,
            straggler_sigma: 0.0,
            straggler_scale_s: 0.0,
        };
        // n=2: 2*1*1ms + 2*(1/2)*1e9B/1e9 = 2ms + 1s
        let t = m.allreduce_time(2, 1_000_000_000);
        assert!((t - 1.002).abs() < 1e-9, "{t}");
        assert_eq!(m.allreduce_time(1, 123), 0.0);
    }

    #[test]
    fn allreduce_monotone_in_n_and_bytes() {
        let m = CommModel::preset("eth").unwrap();
        assert!(m.allreduce_time(4, 1 << 20) < m.allreduce_time(8, 1 << 20));
        assert!(m.allreduce_time(8, 1 << 20) < m.allreduce_time(8, 1 << 24));
    }

    #[test]
    fn bandwidth_term_saturates_with_n() {
        // 2(n-1)/n -> 2: large-n all-reduce transfers at most ~2x the data.
        let m = CommModel {
            latency_s: 0.0,
            bandwidth_bps: 1e9,
            straggler_sigma: 0.0,
            straggler_scale_s: 0.0,
        };
        let t_inf = 2.0 * 1e9 / 1e9;
        assert!(m.allreduce_time(1024, 1_000_000_000) < t_inf);
        assert!(m.allreduce_time(1024, 1_000_000_000) > 0.99 * t_inf);
    }

    #[test]
    fn straggler_max_grows_with_n() {
        let m = CommModel::preset("wan").unwrap();
        let mut rng = Rng::new(1);
        let avg = |n: usize, rng: &mut Rng| -> f64 {
            (0..2000).map(|_| m.straggler_delay(n, rng)).sum::<f64>() / 2000.0
        };
        let d2 = avg(2, &mut rng);
        let d16 = avg(16, &mut rng);
        assert!(d16 > d2, "max of more draws should be larger: {d16} vs {d2}");
    }

    #[test]
    fn clock_accumulates() {
        let m = CommModel::preset("eth").unwrap();
        let mut clock = SimClock::default();
        let mut rng = Rng::new(0);
        clock.charge_parallel_compute(&[0.1, 0.2, 0.15]);
        clock.charge_allreduce(&m, 4, 1 << 20, &mut rng);
        assert_eq!(clock.comm_rounds, 1);
        assert!(clock.compute_s == 0.2);
        assert!(clock.comm_s > 0.0);
        assert!(clock.total_s() >= clock.compute_s + clock.comm_s);
        assert!(clock.bytes_communicated > 1 << 20);
    }

    #[test]
    fn sign_allreduce_charges_packed_bytes() {
        use crate::dist::codec;
        let m = CommModel::preset("eth").unwrap();
        let mut rng = Rng::new(2);
        let p = 1 << 20;
        let n = 4;

        let mut compressed = SimClock::default();
        compressed.charge_sign_allreduce(&m, n, p, &mut rng);
        // payload is ~P/8 bytes plus the fixed header ...
        let payload = codec::sign_allreduce_bytes(p);
        assert_eq!(payload, (p as u64) / 8 + codec::HEADER_BYTES);
        // ... and the ring all-reduce moves 2(n-1)/n of it.
        let expected_moved = payload * 2 * (n as u64 - 1) / n as u64;
        assert_eq!(compressed.bytes_communicated, expected_moved);
        assert_eq!(compressed.comm_rounds, 1);

        // ~32x cheaper than the uncompressed f32 exchange in both bytes
        // and modeled time (same latency term, 1/32 the bandwidth term).
        let mut full = SimClock::default();
        full.charge_allreduce(&m, n, p as u64 * 4, &mut rng);
        assert!(compressed.bytes_communicated * 30 < full.bytes_communicated);
        assert!(compressed.comm_s < full.comm_s);
    }

    #[test]
    fn bytes_communicated_is_monotone() {
        let m = CommModel::preset("wan").unwrap();
        let mut clock = SimClock::default();
        let mut rng = Rng::new(9);
        let mut prev_bytes = 0;
        let mut prev_rounds = 0;
        for i in 0..20 {
            if i % 2 == 0 {
                clock.charge_sign_allreduce(&m, 2 + i % 5, 1000 + 100 * i, &mut rng);
            } else {
                clock.charge_allreduce(&m, 2 + i % 5, (4000 + i) as u64, &mut rng);
            }
            assert!(clock.bytes_communicated > prev_bytes, "step {i}: bytes must grow");
            assert!(clock.comm_rounds > prev_rounds, "step {i}: rounds must grow");
            prev_bytes = clock.bytes_communicated;
            prev_rounds = clock.comm_rounds;
        }
    }

    #[test]
    fn free_network_charges_nothing() {
        let m = CommModel::preset("none").unwrap();
        let mut clock = SimClock::default();
        let mut rng = Rng::new(0);
        clock.charge_allreduce(&m, 64, u64::MAX / 4, &mut rng);
        assert_eq!(clock.comm_s, 0.0);
        assert_eq!(clock.straggler_s, 0.0);
    }
}
