//! Communication cost model — the substrate replacing the paper's GPU
//! cluster interconnect (DESIGN.md §5.2).
//!
//! The paper's motivation is that the per-step all-reduce dominates
//! wall-clock on slow interconnects, so methods with τ local steps save
//! ~τ× communication.  To reproduce the time-axis plots and
//! communication-reduction tables on a single-node testbed, every
//! collective charges simulated time from the standard α-β (latency-
//! bandwidth) model of a ring all-reduce:
//!
//! ```text
//!     T(n, bytes) = 2 (n-1) α  +  2 (n-1)/n · bytes / β
//! ```
//!
//! plus an optional straggler term: per round, the slowest of n i.i.d.
//! log-normal worker delays (Dean et al. 2012's tail-latency story).
//! Compressed rounds are the exception: a majority tally, a
//! per-rank-scaled i8 sum, and a sparse top-k index union are none of
//! them ring-reducible in their own wire format, so
//! they bill a server topology instead — the flat gather+broadcast
//! ([`SimClock::charge_vote_allreduce`]) at small n, and the two-level
//! hierarchical aggregation ([`SimClock::charge_hierarchical`], group
//! heads pre-aggregate and exchange among themselves) once the fleet is
//! large enough for √n levels to beat the flat gather's linear cost.
//! Which applies is decided by [`topology::Topology::select`], a pure
//! function of (format, n) shared with the wire-format cost helper and
//! the trainer's data path.
//!
//! Round billing is payload-driven: the trainer hands
//! [`SimClock::charge_exchange`] the [`crate::dist::WirePayload`] the
//! ranks exchange, and the clock reads the byte count and topology off
//! the payload itself — accounting and data path cannot drift apart.
//! Under an active [`faults::FaultPlan`] a round may lose payloads in
//! transit; [`SimClock::charge_exchange_among`] then bills exactly what
//! moved — `arrived − 1` messages up, `n_active − 1` down — so billing
//! and data path stay consistent under failure too. Compute time is
//! *measured* (the PJRT executions are real); comm time is *modeled*;
//! the trainer adds both onto a [`SimClock`].
//!
//! Stream hygiene: [`CommModel::straggler_delay`] consumes no RNG draws
//! when stragglers are disabled (`sigma == 0`), so callers must feed it
//! a **dedicated** stream — the trainer uses its checkpointed
//! `fault_rng`, never the training stream — or toggling stragglers
//! would silently shift every downstream optimization draw.

pub mod faults;
pub mod topology;

pub use faults::{Attack, FaultPlan, FaultStats};
pub use topology::Topology;

use crate::dist::WirePayload;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CommModel {
    /// Per-message latency α, seconds.
    pub latency_s: f64,
    /// Bandwidth β, bytes/second.
    pub bandwidth_bps: f64,
    /// Log-normal sigma of per-worker per-round delay (0 = no stragglers).
    pub straggler_sigma: f64,
    /// Median per-worker compute jitter in seconds (scale of the delay).
    pub straggler_scale_s: f64,
}

impl CommModel {
    /// Named presets spanning the regimes the paper targets (§1: NVLink
    /// intra-node vs slow inter-node / inter-cluster links).
    pub fn preset(name: &str) -> Option<CommModel> {
        Some(match name {
            // NVLink-class: 300 GB/s, ~5 µs
            "nvlink" => CommModel {
                latency_s: 5e-6,
                bandwidth_bps: 300e9,
                straggler_sigma: 0.0,
                straggler_scale_s: 0.0,
            },
            // InfiniBand HDR-class: 25 GB/s, ~20 µs
            "infiniband" | "ib" => CommModel {
                latency_s: 2e-5,
                bandwidth_bps: 25e9,
                straggler_sigma: 0.1,
                straggler_scale_s: 1e-4,
            },
            // Datacenter 10GbE: 1.25 GB/s, ~100 µs, visible stragglers
            "ethernet" | "eth" => CommModel {
                latency_s: 1e-4,
                bandwidth_bps: 1.25e9,
                straggler_sigma: 0.3,
                straggler_scale_s: 1e-3,
            },
            // Cross-region WAN: 50 MB/s, 30 ms, heavy tail
            "wan" | "cross_region" => CommModel {
                latency_s: 3e-2,
                bandwidth_bps: 5e7,
                straggler_sigma: 0.5,
                straggler_scale_s: 1e-2,
            },
            "none" | "free" => CommModel {
                latency_s: 0.0,
                bandwidth_bps: f64::INFINITY,
                straggler_sigma: 0.0,
                straggler_scale_s: 0.0,
            },
            _ => return None,
        })
    }

    /// Ring all-reduce time for `bytes` over `n` workers.
    pub fn allreduce_time(&self, n: usize, bytes: u64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let n = n as f64;
        2.0 * (n - 1.0) * self.latency_s + 2.0 * (n - 1.0) / n * bytes as f64 / self.bandwidth_bps
    }

    /// Broadcast (one-to-all over a binomial tree).
    pub fn broadcast_time(&self, n: usize, bytes: u64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let rounds = (n as f64).log2().ceil();
        rounds * (self.latency_s + bytes as f64 / self.bandwidth_bps)
    }

    /// Flat gather (all-to-one): the server's link serializes the n-1
    /// incoming payloads, paying one latency + one transfer each. This
    /// is the worker→server half of a majority-vote round — a sign
    /// tally is not ring-reducible bit-by-bit, so the server really
    /// does ingest every rank's packed votes.
    pub fn gather_time(&self, n: usize, bytes: u64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        (n as f64 - 1.0) * (self.latency_s + bytes as f64 / self.bandwidth_bps)
    }

    /// Two-level hierarchical aggregation: n ranks in `groups` groups of
    /// m = ⌈n/groups⌉. The groups gather into their heads in parallel
    /// (`gather_time(m)`), the heads run a flat exchange among
    /// themselves (`gather_time(g) + broadcast_time(g)`), and each head
    /// broadcasts the result down its group (`broadcast_time(m)`).
    /// Degenerates to the flat gather+broadcast at `groups == 1` and
    /// moves the same `2(n-1)·bytes` total volume — only the serial
    /// critical path shrinks, from O(n) to O(√n) message times at the
    /// optimal group count ([`topology::best_group_count`]).
    pub fn hierarchical_time(&self, n: usize, groups: usize, bytes: u64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let g = groups.clamp(1, n);
        let m = crate::dist::div_up(n, g);
        self.gather_time(m, bytes)
            + self.gather_time(g, bytes)
            + self.broadcast_time(g, bytes)
            + self.broadcast_time(m, bytes)
    }

    /// Synchronization-barrier penalty: max of n log-normal delays.
    ///
    /// Consumes **no** draws when stragglers are off — pass a dedicated
    /// stream (see the module docs on stream hygiene).
    pub fn straggler_delay(&self, n: usize, rng: &mut Rng) -> f64 {
        if self.straggler_sigma == 0.0 || self.straggler_scale_s == 0.0 {
            return 0.0;
        }
        (0..n)
            .map(|_| self.straggler_scale_s * rng.lognormal(0.0, self.straggler_sigma))
            .fold(0.0, f64::max)
    }
}

/// Simulated wall clock: measured compute + modeled communication.
#[derive(Clone, Debug, Default)]
pub struct SimClock {
    pub compute_s: f64,
    pub comm_s: f64,
    pub straggler_s: f64,
    pub comm_rounds: u64,
    pub bytes_communicated: u64,
}

impl SimClock {
    pub fn total_s(&self) -> f64 {
        self.compute_s + self.comm_s + self.straggler_s
    }

    /// Charge one round exchange over `n` workers from the payload that
    /// actually crosses the wire: the billed byte count is
    /// [`WirePayload::wire_bytes`], so the accounting and the exchanged
    /// data cannot diverge — there is no caller-side byte formula left
    /// to pick by optimizer flag.
    ///
    /// Topology comes from [`Topology::select`] on the format
    /// ([`WirePayload::ring_reducible`]) and the fleet size: a dense f32
    /// mean is ring-reducible and bills
    /// [`charge_allreduce`](Self::charge_allreduce); packed sign votes,
    /// per-rank-scaled i8 payloads, and sparse top-k payloads cannot be
    /// partially aggregated in
    /// their own encoding, so they bill the flat gather+broadcast server
    /// topology ([`charge_vote_allreduce`](Self::charge_vote_allreduce))
    /// at small n and the two-level
    /// [`charge_hierarchical`](Self::charge_hierarchical) once the fleet
    /// clears [`topology::HIERARCHICAL_MIN_RANKS`].
    pub fn charge_exchange(
        &mut self,
        model: &CommModel,
        n: usize,
        payload: &WirePayload,
        rng: &mut Rng,
    ) {
        let bytes = payload.wire_bytes();
        match Topology::select(payload.ring_reducible(), n) {
            Topology::Ring => self.charge_allreduce(model, n, bytes, rng),
            Topology::FlatGatherBroadcast => self.charge_vote_allreduce(model, n, bytes, rng),
            Topology::Hierarchical { groups } => {
                self.charge_hierarchical(model, n, groups, bytes, rng)
            }
        }
    }

    /// Charge a round where only `arrived` of the `n_active` member
    /// payloads made it to the aggregation point (dropped payloads under
    /// a [`FaultPlan`]). Bills exactly what moved: `arrived − 1`
    /// messages on the up-leg (a dropped payload never reaches the
    /// server, so it is not billed), `n_active − 1` deliveries on the
    /// down-leg. With
    /// `arrived == n_active` this delegates to
    /// [`charge_exchange`](Self::charge_exchange) and is bitwise
    /// identical to the fault-free billing.
    pub fn charge_exchange_among(
        &mut self,
        model: &CommModel,
        n_active: usize,
        arrived: usize,
        payload: &WirePayload,
        rng: &mut Rng,
    ) {
        assert!(arrived <= n_active, "{arrived} payloads arrived from {n_active} active ranks");
        if arrived == n_active {
            return self.charge_exchange(model, n_active, payload, rng);
        }
        // degraded round: flat gather of what arrived, broadcast of the
        // aggregate to every active rank
        let bytes = payload.wire_bytes();
        self.comm_s += model.gather_time(arrived, bytes) + model.broadcast_time(n_active, bytes);
        self.straggler_s += model.straggler_delay(n_active, rng);
        self.comm_rounds += 1;
        let msgs = arrived.saturating_sub(1) + n_active.saturating_sub(1);
        if msgs > 0 {
            let moved = (bytes as u128) * msgs as u128;
            self.bytes_communicated = self
                .bytes_communicated
                .saturating_add(moved.min(u64::MAX as u128) as u64);
        }
    }

    /// Charge a two-level hierarchical exchange ([`CommModel::hierarchical_time`]):
    /// same `2(n-1)·bytes` volume as the flat server topology — group
    /// members send up and receive down exactly once, heads exchange
    /// among themselves — but an O(√n) serial critical path.
    pub fn charge_hierarchical(
        &mut self,
        model: &CommModel,
        n: usize,
        groups: usize,
        wire_bytes: u64,
        rng: &mut Rng,
    ) {
        self.comm_s += model.hierarchical_time(n, groups, wire_bytes);
        self.straggler_s += model.straggler_delay(n, rng);
        self.comm_rounds += 1;
        if n > 1 {
            let moved = (wire_bytes as u128) * 2 * (n as u128 - 1);
            self.bytes_communicated = self
                .bytes_communicated
                .saturating_add(moved.min(u64::MAX as u128) as u64);
        }
    }

    /// Charge a vote exchange whose per-message wire payload is
    /// `wire_bytes` — the packed data path bills the byte count of the
    /// [`crate::dist::PackedVotes`] buffers actually exchanged
    /// ([`crate::dist::PackedVotes::wire_bytes`]), so accounting and
    /// data path cannot drift apart.
    ///
    /// Topology: a majority vote is not ring-reducible bit-by-bit (a
    /// partial tally does not fit the 1-bit wire format), so unlike
    /// [`charge_allreduce`](Self::charge_allreduce) this models the
    /// practical server topology — a flat **gather** of the n-1 rank
    /// payloads ([`CommModel::gather_time`]) followed by a binomial-tree
    /// **broadcast** of the winner ([`CommModel::broadcast_time`]):
    ///
    /// ```text
    ///     T(n, b) = (n-1)(α + b/β)  +  ⌈log2 n⌉(α + b/β)
    /// ```
    ///
    /// and `2(n-1)·b` total wire bytes (n-1 payloads up, the winner to
    /// n-1 receivers). The earlier ring α-β formula was an optimistic
    /// lower bound that understated sign-vote traffic at large n
    /// (ROADMAP follow-up (d)); `comm::tests::vote_allreduce_*` pin the
    /// new formula.
    pub fn charge_vote_allreduce(
        &mut self,
        model: &CommModel,
        n: usize,
        wire_bytes: u64,
        rng: &mut Rng,
    ) {
        self.comm_s += model.gather_time(n, wire_bytes) + model.broadcast_time(n, wire_bytes);
        self.straggler_s += model.straggler_delay(n, rng);
        self.comm_rounds += 1;
        if n > 1 {
            let moved = (wire_bytes as u128) * 2 * (n as u128 - 1);
            self.bytes_communicated = self
                .bytes_communicated
                .saturating_add(moved.min(u64::MAX as u128) as u64);
        }
    }

    /// Charge one all-reduce of `bytes` over `n` workers.
    pub fn charge_allreduce(&mut self, model: &CommModel, n: usize, bytes: u64, rng: &mut Rng) {
        self.comm_s += model.allreduce_time(n, bytes);
        self.straggler_s += model.straggler_delay(n, rng);
        self.comm_rounds += 1;
        if n > 1 {
            let moved = (bytes as u128) * 2 * (n as u128 - 1) / n as u128;
            self.bytes_communicated = self
                .bytes_communicated
                .saturating_add(moved.min(u64::MAX as u128) as u64);
        }
    }

    /// Charge measured compute time.  The simulated elapsed time for one
    /// "parallel" local phase is the max over the per-worker
    /// measurements (the barrier waits for the slowest rank); the
    /// caller passes one measured duration per worker. The f64 max is
    /// order-independent, so the *aggregation* does not depend on how
    /// the fleet executed — but the measurements themselves are wall
    /// clock, and ranks running concurrently on the host pool can
    /// inflate each other's readings through cache/bandwidth/core
    /// contention. Measured time was never reproducible across hosts
    /// or loads (only the modeled comm/straggler terms are exact);
    /// runs that care about an uncontended compute axis should use
    /// `cfg.sequential_workers`, which trades wall-clock for
    /// contention-free per-rank readings while leaving the trajectory
    /// bit-identical.
    pub fn charge_parallel_compute(&mut self, per_worker_s: &[f64]) {
        self.compute_s += per_worker_s.iter().copied().fold(0.0, f64::max);
    }

    /// Number of f32 words [`SimClock::to_f32_words`] produces (five
    /// 64-bit fields × four 16-bit limbs).
    pub const F32_WORDS: usize = 20;

    /// Serialize the clock to f32 words for the checkpoint container
    /// (which stores flat f32 buffers): each 64-bit field — the three
    /// f64 accumulators via `to_bits`, then the two u64 counters —
    /// becomes four exactly-representable 16-bit limbs, the same
    /// encoding as `local_step64` and the RNG streams. With the clock
    /// checkpointed, a resumed run continues the simulated time axis
    /// instead of restarting it at zero.
    pub fn to_f32_words(&self) -> Vec<f32> {
        fn push_u64(out: &mut Vec<f32>, w: u64) {
            for k in 0..4 {
                out.push(((w >> (16 * k)) & 0xFFFF) as f32);
            }
        }
        let mut out = Vec::with_capacity(Self::F32_WORDS);
        push_u64(&mut out, self.compute_s.to_bits());
        push_u64(&mut out, self.comm_s.to_bits());
        push_u64(&mut out, self.straggler_s.to_bits());
        push_u64(&mut out, self.comm_rounds);
        push_u64(&mut out, self.bytes_communicated);
        out
    }

    /// Rebuild a clock from [`SimClock::to_f32_words`] output; `None`
    /// on a malformed buffer (wrong length or non-limb values).
    pub fn from_f32_words(words: &[f32]) -> Option<SimClock> {
        fn read_u64(words: &[f32]) -> Option<u64> {
            let mut w = 0u64;
            for (k, &x) in words.iter().enumerate() {
                if !(0.0..65536.0).contains(&x) || x.fract() != 0.0 {
                    return None;
                }
                w |= (x as u64) << (16 * k);
            }
            Some(w)
        }
        if words.len() != Self::F32_WORDS {
            return None;
        }
        Some(SimClock {
            compute_s: f64::from_bits(read_u64(&words[0..4])?),
            comm_s: f64::from_bits(read_u64(&words[4..8])?),
            straggler_s: f64::from_bits(read_u64(&words[8..12])?),
            comm_rounds: read_u64(&words[12..16])?,
            bytes_communicated: read_u64(&words[16..20])?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_exist_and_rank_by_bandwidth() {
        let nv = CommModel::preset("nvlink").unwrap();
        let ib = CommModel::preset("ib").unwrap();
        let eth = CommModel::preset("eth").unwrap();
        let wan = CommModel::preset("wan").unwrap();
        assert!(CommModel::preset("bogus").is_none());
        let bytes = 100 * 1024 * 1024;
        let t = |m: &CommModel| m.allreduce_time(8, bytes);
        assert!(t(&nv) < t(&ib) && t(&ib) < t(&eth) && t(&eth) < t(&wan));
    }

    #[test]
    fn allreduce_alpha_beta_formula() {
        let m = CommModel {
            latency_s: 1e-3,
            bandwidth_bps: 1e9,
            straggler_sigma: 0.0,
            straggler_scale_s: 0.0,
        };
        // n=2: 2*1*1ms + 2*(1/2)*1e9B/1e9 = 2ms + 1s
        let t = m.allreduce_time(2, 1_000_000_000);
        assert!((t - 1.002).abs() < 1e-9, "{t}");
        assert_eq!(m.allreduce_time(1, 123), 0.0);
    }

    #[test]
    fn allreduce_monotone_in_n_and_bytes() {
        let m = CommModel::preset("eth").unwrap();
        assert!(m.allreduce_time(4, 1 << 20) < m.allreduce_time(8, 1 << 20));
        assert!(m.allreduce_time(8, 1 << 20) < m.allreduce_time(8, 1 << 24));
    }

    #[test]
    fn bandwidth_term_saturates_with_n() {
        // 2(n-1)/n -> 2: large-n all-reduce transfers at most ~2x the data.
        let m = CommModel {
            latency_s: 0.0,
            bandwidth_bps: 1e9,
            straggler_sigma: 0.0,
            straggler_scale_s: 0.0,
        };
        let t_inf = 2.0 * 1e9 / 1e9;
        assert!(m.allreduce_time(1024, 1_000_000_000) < t_inf);
        assert!(m.allreduce_time(1024, 1_000_000_000) > 0.99 * t_inf);
    }

    #[test]
    fn straggler_max_grows_with_n() {
        let m = CommModel::preset("wan").unwrap();
        let mut rng = Rng::new(1);
        let avg = |n: usize, rng: &mut Rng| -> f64 {
            (0..2000).map(|_| m.straggler_delay(n, rng)).sum::<f64>() / 2000.0
        };
        let d2 = avg(2, &mut rng);
        let d16 = avg(16, &mut rng);
        assert!(d16 > d2, "max of more draws should be larger: {d16} vs {d2}");
    }

    #[test]
    fn clock_accumulates() {
        let m = CommModel::preset("eth").unwrap();
        let mut clock = SimClock::default();
        let mut rng = Rng::new(0);
        clock.charge_parallel_compute(&[0.1, 0.2, 0.15]);
        clock.charge_allreduce(&m, 4, 1 << 20, &mut rng);
        assert_eq!(clock.comm_rounds, 1);
        assert!(clock.compute_s == 0.2);
        assert!(clock.comm_s > 0.0);
        assert!(clock.total_s() >= clock.compute_s + clock.comm_s);
        assert!(clock.bytes_communicated > 1 << 20);
    }

    #[test]
    fn packed_sign_exchange_charges_packed_bytes() {
        use crate::dist::{codec, WireFormat};
        let m = CommModel::preset("eth").unwrap();
        let mut rng = Rng::new(2);
        let p = 1 << 20;
        let n = 4;

        let mut compressed = SimClock::default();
        let votes = WirePayload::with_len(WireFormat::PackedSigns, p);
        compressed.charge_exchange(&m, n, &votes, &mut rng);
        // payload is ~P/8 bytes plus the fixed header ...
        let payload = codec::sign_allreduce_bytes(p);
        assert_eq!(payload, (p as u64) / 8 + codec::HEADER_BYTES);
        assert_eq!(votes.wire_bytes(), payload);
        // ... and gather+broadcast moves 2(n-1) copies of it (n-1 rank
        // payloads up to the server, the winner out to n-1 receivers).
        let expected_moved = payload * 2 * (n as u64 - 1);
        assert_eq!(compressed.bytes_communicated, expected_moved);
        assert_eq!(compressed.comm_rounds, 1);

        // still far cheaper than the uncompressed f32 ring exchange:
        // the 32x payload compression dominates the topology penalty
        // (ring moves 2(n-1)/n ~= 2 payloads, gather+broadcast 2(n-1)),
        // so at n=4 the byte advantage is 32/n = 8x.
        let mut full = SimClock::default();
        full.charge_exchange(&m, n, &WirePayload::with_len(WireFormat::DenseF32, p), &mut rng);
        assert!(compressed.bytes_communicated * 7 < full.bytes_communicated);
        assert!(compressed.comm_s < full.comm_s);
    }

    #[test]
    fn charge_exchange_routes_topology_by_payload_format() {
        use crate::dist::WireFormat;
        let m = CommModel::preset("eth").unwrap();
        let p = 1 << 18;
        let n = 4;

        // dense bills exactly like the classic f32 ring all-reduce
        let mut dense = SimClock::default();
        let dense_payload = WirePayload::with_len(WireFormat::DenseF32, p);
        dense.charge_exchange(&m, n, &dense_payload, &mut Rng::new(3));
        let mut ring = SimClock::default();
        ring.charge_allreduce(&m, n, p as u64 * 4, &mut Rng::new(3));
        assert_eq!(dense.comm_s.to_bits(), ring.comm_s.to_bits());
        assert_eq!(dense.bytes_communicated, ring.bytes_communicated);

        // both quantized formats bill the gather+broadcast of their own
        // byte models (the per-tensor payload's count includes its
        // per-segment scales)
        for format in [WireFormat::QuantizedI8, WireFormat::QuantizedI8PerTensor] {
            let mut q8 = SimClock::default();
            let q8_payload = WirePayload::with_len(format, p);
            q8.charge_exchange(&m, n, &q8_payload, &mut Rng::new(3));
            let mut gather = SimClock::default();
            gather.charge_vote_allreduce(&m, n, q8_payload.wire_bytes(), &mut Rng::new(3));
            assert_eq!(q8.comm_s.to_bits(), gather.comm_s.to_bits(), "{}", format.name());
            assert_eq!(q8.bytes_communicated, gather.bytes_communicated);

            // at the default fleet size the quantized exchange undercuts
            // dense on modeled time even though its topology moves more
            // total bytes
            let (a, b) = (q8.comm_s, dense.comm_s);
            assert!(a < b, "{}: {a} vs {b}", format.name());
        }
    }

    #[test]
    fn vote_allreduce_pins_gather_broadcast_formula() {
        // deterministic model so the latency/bandwidth split is exact
        let m = CommModel {
            latency_s: 1e-3,
            bandwidth_bps: 1e6,
            straggler_sigma: 0.0,
            straggler_scale_s: 0.0,
        };
        let mut clock = SimClock::default();
        let mut rng = Rng::new(0);
        let (n, bytes) = (4usize, 10_000u64);
        clock.charge_vote_allreduce(&m, n, bytes, &mut rng);
        // gather: (n-1)(alpha + b/beta) = 3 * (1e-3 + 0.01) = 0.033
        // broadcast: ceil(log2 4)(alpha + b/beta) = 2 * 0.011 = 0.022
        let per_msg = 1e-3 + bytes as f64 / 1e6;
        let expected = 3.0 * per_msg + 2.0 * per_msg;
        assert!((clock.comm_s - expected).abs() < 1e-12, "{} vs {expected}", clock.comm_s);
        assert_eq!(clock.bytes_communicated, 2 * 3 * bytes);
        assert_eq!(clock.comm_rounds, 1);
        assert_eq!(clock.straggler_s, 0.0);

        // n = 1: nothing crosses any wire
        let mut solo = SimClock::default();
        solo.charge_vote_allreduce(&m, 1, bytes, &mut rng);
        assert_eq!(solo.comm_s, 0.0);
        assert_eq!(solo.bytes_communicated, 0);
    }

    #[test]
    fn vote_topology_grows_linearly_in_n_unlike_the_ring() {
        // the whole point of follow-up (d): at large n the server gather
        // dominates, while a ring's bandwidth term saturates at ~2 b/beta
        let m = CommModel {
            latency_s: 0.0,
            bandwidth_bps: 1e9,
            straggler_sigma: 0.0,
            straggler_scale_s: 0.0,
        };
        let b = 1u64 << 20;
        let vote = |n: usize| {
            let mut c = SimClock::default();
            let mut rng = Rng::new(1);
            c.charge_vote_allreduce(&m, n, b, &mut rng);
            c.comm_s
        };
        assert!(vote(64) > 6.0 * vote(8), "{} vs {}", vote(64), vote(8));
        assert!(vote(64) > m.allreduce_time(64, b), "vote exchange must not undercut the ring");
    }

    #[test]
    fn clock_f32_words_roundtrip_bitwise() {
        let m = CommModel::preset("wan").unwrap();
        let mut clock = SimClock::default();
        let mut rng = Rng::new(7);
        clock.charge_parallel_compute(&[0.125, 3.75]);
        clock.charge_allreduce(&m, 8, 123_456_789, &mut rng);
        clock.charge_vote_allreduce(&m, 8, 54_321, &mut rng);
        let words = clock.to_f32_words();
        assert_eq!(words.len(), SimClock::F32_WORDS);
        let back = SimClock::from_f32_words(&words).unwrap();
        assert_eq!(back.compute_s.to_bits(), clock.compute_s.to_bits());
        assert_eq!(back.comm_s.to_bits(), clock.comm_s.to_bits());
        assert_eq!(back.straggler_s.to_bits(), clock.straggler_s.to_bits());
        assert_eq!(back.comm_rounds, clock.comm_rounds);
        assert_eq!(back.bytes_communicated, clock.bytes_communicated);

        assert!(SimClock::from_f32_words(&words[1..]).is_none(), "wrong length");
        let mut bad = words;
        bad[2] = 0.5;
        assert!(SimClock::from_f32_words(&bad).is_none(), "non-limb value");
    }

    #[test]
    fn bytes_communicated_is_monotone() {
        let m = CommModel::preset("wan").unwrap();
        let mut clock = SimClock::default();
        let mut rng = Rng::new(9);
        let mut prev_bytes = 0;
        let mut prev_rounds = 0;
        for i in 0..20 {
            if i % 2 == 0 {
                clock.charge_vote_allreduce(&m, 2 + i % 5, (1000 + 100 * i) as u64, &mut rng);
            } else {
                clock.charge_allreduce(&m, 2 + i % 5, (4000 + i) as u64, &mut rng);
            }
            assert!(clock.bytes_communicated > prev_bytes, "step {i}: bytes must grow");
            assert!(clock.comm_rounds > prev_rounds, "step {i}: rounds must grow");
            prev_bytes = clock.bytes_communicated;
            prev_rounds = clock.comm_rounds;
        }
    }

    #[test]
    fn free_network_charges_nothing() {
        let m = CommModel::preset("none").unwrap();
        let mut clock = SimClock::default();
        let mut rng = Rng::new(0);
        clock.charge_allreduce(&m, 64, u64::MAX / 4, &mut rng);
        assert_eq!(clock.comm_s, 0.0);
        assert_eq!(clock.straggler_s, 0.0);
    }

    #[test]
    fn collective_times_vanish_at_n_le_1() {
        let m = CommModel::preset("wan").unwrap();
        for n in [0usize, 1] {
            assert_eq!(m.allreduce_time(n, 1 << 30), 0.0, "allreduce n={n}");
            assert_eq!(m.gather_time(n, 1 << 30), 0.0, "gather n={n}");
            assert_eq!(m.broadcast_time(n, 1 << 30), 0.0, "broadcast n={n}");
            assert_eq!(m.hierarchical_time(n, 1, 1 << 30), 0.0, "hier n={n}");
        }
    }

    #[test]
    fn broadcast_rounds_are_ceil_log2_at_non_powers_of_two() {
        let m = CommModel {
            latency_s: 1e-3,
            bandwidth_bps: 1e6,
            straggler_sigma: 0.0,
            straggler_scale_s: 0.0,
        };
        let per_msg = 1e-3 + 1000.0 / 1e6;
        // ceil(log2 3) = 2, ceil(log2 5) = 3, ceil(log2 1024) = 10
        for (n, rounds) in [(2usize, 1.0), (3, 2.0), (5, 3.0), (1024, 10.0)] {
            let t = m.broadcast_time(n, 1000);
            assert!((t - rounds * per_msg).abs() < 1e-12, "n={n}: {t}");
        }
        // and the gather stays exactly linear at the same sizes
        for n in [3usize, 1024] {
            let t = m.gather_time(n, 1000);
            assert!((t - (n as f64 - 1.0) * per_msg).abs() < 1e-9, "n={n}: {t}");
        }
    }

    #[test]
    fn large_n_crossover_flat_loses_to_ring_and_to_hierarchical() {
        // satellite pin: at n = 1024 the flat gather's (n-1) serial
        // messages lose both to the bandwidth-saturating dense ring and
        // to the two-level hierarchy; at n = 4 flat still wins the
        // small-payload race against the ring's 2(n-1) latencies
        let m = CommModel::preset("eth").unwrap();
        let b = 1u64 << 20;
        let n = 1024;
        let flat = m.gather_time(n, b) + m.broadcast_time(n, b);
        let ring = m.allreduce_time(n, b * 4); // dense carries 4x the bytes
        let g = topology::best_group_count(n);
        let hier = m.hierarchical_time(n, g, b);
        assert!(flat > ring, "flat {flat} vs dense ring {ring} at n={n}");
        assert!(hier * 8.0 < flat, "hier {hier} vs flat {flat} at n={n}");
        assert!(hier < ring, "hier {hier} must repair the loss to the ring {ring}");
    }

    #[test]
    fn hierarchical_time_degenerates_to_flat_at_one_group() {
        let m = CommModel::preset("eth").unwrap();
        for n in [2usize, 7, 64] {
            let flat = m.gather_time(n, 4096) + m.broadcast_time(n, 4096);
            let one = m.hierarchical_time(n, 1, 4096);
            assert_eq!(one.to_bits(), flat.to_bits(), "n={n}");
        }
    }

    #[test]
    fn charge_exchange_goes_hierarchical_at_scale_and_stays_flat_below() {
        use crate::dist::WireFormat;
        let m = CommModel::preset("eth").unwrap();
        let p = 1 << 20;
        let payload = WirePayload::with_len(WireFormat::QuantizedI8, p);
        let b = payload.wire_bytes();

        // below the threshold: bitwise the flat gather+broadcast
        let mut small = SimClock::default();
        small.charge_exchange(&m, 8, &payload, &mut Rng::new(3));
        let mut flat = SimClock::default();
        flat.charge_vote_allreduce(&m, 8, b, &mut Rng::new(3));
        assert_eq!(small.comm_s.to_bits(), flat.comm_s.to_bits());

        // at n = 1024: bitwise the hierarchical charge, same total bytes
        // as the flat topology would have moved
        let n = 1024;
        let mut big = SimClock::default();
        big.charge_exchange(&m, n, &payload, &mut Rng::new(3));
        let g = match Topology::select(false, n) {
            Topology::Hierarchical { groups } => groups,
            other => panic!("expected hierarchical at n={n}, got {other:?}"),
        };
        let mut hier = SimClock::default();
        hier.charge_hierarchical(&m, n, g, b, &mut Rng::new(3));
        assert_eq!(big.comm_s.to_bits(), hier.comm_s.to_bits());
        assert_eq!(big.bytes_communicated, b * 2 * (n as u64 - 1));
        // and far below what the flat topology would have billed
        let mut flat_big = SimClock::default();
        flat_big.charge_vote_allreduce(&m, n, b, &mut Rng::new(3));
        assert!(big.comm_s * 8.0 < flat_big.comm_s, "{} vs {}", big.comm_s, flat_big.comm_s);
    }

    #[test]
    fn degraded_rounds_bill_exactly_what_moved() {
        use crate::dist::WireFormat;
        let m = CommModel {
            latency_s: 1e-3,
            bandwidth_bps: 1e6,
            straggler_sigma: 0.0,
            straggler_scale_s: 0.0,
        };
        let payload = WirePayload::with_len(WireFormat::QuantizedI8, 988);
        let b = payload.wire_bytes(); // 988 + 12 = 1000
        assert_eq!(b, 1000);

        // all arrived == fault-free billing, bit for bit
        let mut full = SimClock::default();
        full.charge_exchange_among(&m, 4, 4, &payload, &mut Rng::new(5));
        let mut clean = SimClock::default();
        clean.charge_exchange(&m, 4, &payload, &mut Rng::new(5));
        assert_eq!(full.comm_s.to_bits(), clean.comm_s.to_bits());
        assert_eq!(full.bytes_communicated, clean.bytes_communicated);

        // 3 of 4 arrived: gather(3) + broadcast(4), (3-1)+(4-1) messages
        let mut degraded = SimClock::default();
        degraded.charge_exchange_among(&m, 4, 3, &payload, &mut Rng::new(5));
        let per_msg = 1e-3 + b as f64 / 1e6;
        let expected = 2.0 * per_msg + 2.0 * per_msg; // gather 2 msgs, bcast ceil(log2 4)=2 rounds
        assert!((degraded.comm_s - expected).abs() < 1e-12, "{}", degraded.comm_s);
        assert_eq!(degraded.bytes_communicated, b * (2 + 3));
        assert_eq!(degraded.comm_rounds, 1);

        // one survivor of 4: nothing gathers, the broadcast still runs
        let mut lone = SimClock::default();
        lone.charge_exchange_among(&m, 4, 1, &payload, &mut Rng::new(5));
        assert!((lone.comm_s - 2.0 * per_msg).abs() < 1e-12);
        assert_eq!(lone.bytes_communicated, b * 3);
    }
}
