//! Aggregation topology selection for a round exchange.
//!
//! Three topologies cost a round under the α-β model:
//!
//! * **Ring** all-reduce — only for payloads whose aggregation is an
//!   elementwise sum (dense f32): `2(n-1)α + 2(n-1)/n · b/β`.
//! * **Flat gather+broadcast** — every rank sends its payload to rank 0,
//!   which aggregates and broadcasts the result:
//!   `(n-1)(α + b/β) + ⌈log2 n⌉(α + b/β)`. Fine at small n, linear in n.
//! * **Hierarchical two-level** — the n ranks split into g groups of
//!   m = ⌈n/g⌉; each group gathers into its head (groups in parallel),
//!   the g heads run a flat gather+broadcast among themselves, and each
//!   head broadcasts the result down its group:
//!   `(m-1) + (g-1) + ⌈log2 g⌉ + ⌈log2 m⌉` message times. With g ≈ √n
//!   that is O(√n) instead of the flat topology's O(n), which is what
//!   keeps the compressed formats — sign votes, the quantized pair, and
//!   the sparse top-k payload — viable at thousand-rank scale.
//!
//! Every term above is `count · (α + b/β)`, so which topology is fastest
//! depends on `n` alone — never on the model constants or the payload
//! size. [`Topology::select`] is therefore a pure function of
//! (ring-reducibility, n), and the clock, the wire-format cost helper,
//! and the trainer's data path all route through it so billing and data
//! movement can never disagree.
//!
//! The byte count `b` that enters every term is a *measured* quantity,
//! not a formula: it is [`crate::dist::WirePayload::wire_bytes`], which
//! the wire layer test-asserts equal to the length of the framed
//! encoding ([`crate::dist::WirePayload::encode_into`]) for every
//! payload variant. Billing therefore tracks the bytes a real transport
//! would move, header included.

use crate::dist::div_up;

/// Fleet size at which the selector starts considering the hierarchical
/// topology. Strictly by message count it already wins at n = 4, but a
/// two-level scheme at that scale is coordination overhead for no real
/// gain (and the small-fleet cost model is pinned bitwise by tests), so
/// small fleets keep the flat topology.
pub const HIERARCHICAL_MIN_RANKS: usize = 16;

/// How a non-ring round exchange is laid out across the fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    /// Bandwidth-optimal ring all-reduce (dense payloads only).
    Ring,
    /// Single-level gather into rank 0 + tree broadcast.
    FlatGatherBroadcast,
    /// Two-level: `groups` group heads aggregate in parallel, exchange
    /// among themselves, and broadcast back down.
    Hierarchical { groups: usize },
}

/// ⌈log2 n⌉ as an integer (0 for n ≤ 1) — the binomial-tree broadcast
/// round count.
fn ceil_log2(n: usize) -> usize {
    if n <= 1 {
        0
    } else {
        (usize::BITS - (n - 1).leading_zeros()) as usize
    }
}

/// Serial message-times of the flat gather+broadcast at n ranks.
pub fn flat_message_count(n: usize) -> usize {
    if n <= 1 {
        0
    } else {
        (n - 1) + ceil_log2(n)
    }
}

/// Serial message-times of the two-level topology with g groups.
pub fn hierarchical_message_count(n: usize, groups: usize) -> usize {
    if n <= 1 {
        return 0;
    }
    let g = groups.clamp(1, n);
    let m = div_up(n, g);
    (m - 1) + ceil_log2(m) + (g - 1) + ceil_log2(g)
}

/// The group count minimizing [`hierarchical_message_count`] at n ranks
/// (smallest such g on ties, so selection is deterministic). The optimum
/// sits near √n; the scan is exact and cheap at simulated fleet sizes.
pub fn best_group_count(n: usize) -> usize {
    if n <= 1 {
        return 1;
    }
    let mut best_g = 1;
    let mut best = hierarchical_message_count(n, 1);
    for g in 2..=n {
        let c = hierarchical_message_count(n, g);
        if c < best {
            best = c;
            best_g = g;
        }
    }
    best_g
}

impl Topology {
    /// Pick the topology for one round exchange: ring iff the payload
    /// ring-reduces (dense), otherwise hierarchical once the fleet is
    /// large enough for two levels to beat the flat gather, otherwise
    /// flat. Pure in (ring_reducible, n) — see the module docs for why
    /// the model constants and byte count cannot change the answer.
    pub fn select(ring_reducible: bool, n: usize) -> Topology {
        if ring_reducible {
            return Topology::Ring;
        }
        if n >= HIERARCHICAL_MIN_RANKS {
            let g = best_group_count(n);
            if hierarchical_message_count(n, g) < flat_message_count(n) {
                return Topology::Hierarchical { groups: g };
            }
        }
        Topology::FlatGatherBroadcast
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_log2_matches_the_float_formula() {
        for n in 1..=4096usize {
            let expect = if n <= 1 { 0.0 } else { (n as f64).log2().ceil() };
            assert_eq!(ceil_log2(n) as f64, expect, "n={n}");
        }
    }

    #[test]
    fn degenerate_group_counts_reduce_to_flat() {
        for n in [2usize, 3, 16, 100, 1024] {
            // one group: the "head exchange" is a no-op
            assert_eq!(hierarchical_message_count(n, 1), flat_message_count(n));
            // n groups: every rank is a head; the group phases vanish
            assert_eq!(hierarchical_message_count(n, n), flat_message_count(n));
        }
    }

    #[test]
    fn best_group_count_is_near_sqrt_n_and_optimal() {
        for n in [16usize, 64, 100, 256, 1000, 1024, 4096] {
            let g = best_group_count(n);
            let best = hierarchical_message_count(n, g);
            for cand in 1..=n {
                assert!(
                    hierarchical_message_count(n, cand) >= best,
                    "n={n}: g={cand} beats the reported optimum g={g}"
                );
            }
            let sqrt = (n as f64).sqrt();
            assert!(
                (g as f64) >= sqrt / 4.0 && (g as f64) <= sqrt * 4.0,
                "n={n}: optimal g={g} far from sqrt(n)={sqrt:.1}"
            );
        }
    }

    #[test]
    fn hierarchical_wins_by_orders_of_magnitude_at_large_n() {
        let n = 1024;
        let g = best_group_count(n);
        let hier = hierarchical_message_count(n, g);
        let flat = flat_message_count(n);
        assert!(hier * 8 < flat, "hier {hier} vs flat {flat} at n={n}");
    }

    #[test]
    fn selector_routes_by_format_and_fleet_size() {
        // dense always rings, at any n
        for n in [1usize, 4, 1024] {
            assert_eq!(Topology::select(true, n), Topology::Ring);
        }
        // small vote fleets keep the flat topology (bitwise-pinned cost)
        for n in 1..HIERARCHICAL_MIN_RANKS {
            assert_eq!(Topology::select(false, n), Topology::FlatGatherBroadcast, "n={n}");
        }
        // large vote fleets go hierarchical
        for n in [HIERARCHICAL_MIN_RANKS, 64, 1000, 1024] {
            match Topology::select(false, n) {
                Topology::Hierarchical { groups } => {
                    assert!(groups > 1 && groups < n, "n={n}: groups={groups}")
                }
                other => panic!("n={n}: expected hierarchical, got {other:?}"),
            }
        }
    }

    #[test]
    fn selection_is_independent_of_payload_bytes_by_construction() {
        // the counts are byte-free; this pins that nobody reintroduces a
        // byte term into the comparison
        let n = 1024;
        let g = best_group_count(n);
        assert!(hierarchical_message_count(n, g) < flat_message_count(n));
    }
}
