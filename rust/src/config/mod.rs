//! Typed run configuration: TOML file + CLI overrides -> [`RunConfig`].
//!
//! A run is fully described by (model preset, worker count, τ, rounds,
//! base optimizer, outer optimizer, LR schedule, comm model, data, seed).
//! The experiment harness builds these programmatically; `repro train`
//! builds them from a TOML file and/or flags.  Everything is plain data
//! so runs are exactly reproducible from their logged config.

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use crate::comm::{Attack, CommModel, FaultPlan};
use crate::dist::{AggPolicy, WireFormat};
use crate::optim::BaseOptConfig;
use crate::outer::OuterConfig;
use crate::train::schedule::ScheduleConfig;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::toml;

/// How the distributed loop runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainMode {
    /// τ local steps per worker, then one outer round (Algorithm 1 & co.)
    LocalSteps,
    /// Per-step gradient all-reduce + ONE shared optimizer — the paper's
    /// "standalone AdamW/Sophia" upper-bound baseline.
    Standalone,
}

#[derive(Clone, Debug)]
pub struct RunConfig {
    pub preset: String,
    pub n_workers: usize,
    /// Communication interval τ (local steps per round).
    pub tau: usize,
    /// Outer rounds T.  Total local steps = T·τ per worker.
    pub rounds: usize,
    pub mode: TrainMode,
    pub base: BaseOptConfig,
    pub outer: OuterConfig,
    pub schedule: ScheduleConfig,
    pub comm: CommModel,
    pub seed: u64,
    /// Evaluate every k outer rounds (0 = only at the end).
    pub eval_every: usize,
    pub eval_batches: usize,
    pub corpus_bytes: usize,
    pub val_fraction: f64,
    /// Where to write CSV logs (None = no files).
    pub log_dir: Option<PathBuf>,
    /// Human tag for logs/tables.
    pub tag: String,
    /// Use the AOT'd Pallas kernel for Algorithm 1's global step instead
    /// of the native Rust path (equivalence/demo mode).
    pub global_step_pallas: bool,
    /// Non-IID data: each worker's shard is dominated by a different
    /// corpus source (the Theorem-2(b) heterogeneity regime).
    pub heterogeneous: bool,
    /// Round-exchange wire format override (`[outer] wire = "dense" |
    /// "packed_signs" | "q8" | "q8pt" | "topk"` / `--wire`). `None` =
    /// the outer optimizer's native format
    /// ([`OuterConfig::default_wire`]); validation rejects formats the
    /// optimizer does not speak ([`OuterConfig::supported_wires`],
    /// matched by name so tuned `topk` parameters stay valid). `q8pt`
    /// quantizes each segment of the backend's parameter layout
    /// against its own scale ([`crate::runtime::StepBackend::layout`]);
    /// `topk` transmits the k largest components per segment of a
    /// decaying residual-momentum buffer, with the keep fraction and
    /// decay tunable via `[outer] topk_frac`/`topk_decay` (or
    /// `--topk-frac`/`--topk-decay`), both parsed as plain fractions
    /// and carried as exact ppm integers.
    pub wire: Option<WireFormat>,
    /// Differential-testing / benchmarking hook: run the simulated
    /// ranks of each round serially on the coordinator thread instead
    /// of concurrently on the persistent pool. Every trajectory is
    /// bitwise-identical either way (workers own disjoint RNG
    /// substreams and optimizer state; `rust/tests/parallel_fleet.rs`
    /// proves it), which is why the flag is excluded from the
    /// experiment cache key. What does differ is measured wall-clock:
    /// concurrent ranks can inflate each other's per-step timings
    /// through host contention, so time-axis studies that want
    /// uncontended `compute_s` readings should set this (losing the
    /// round-level speedup, keeping the exact same losses).
    pub sequential_workers: bool,
    /// Benchmarking hook: pin the persistent pool's helper threads to
    /// distinct CPUs (`pin_workers = true` / `--pin-workers`), reducing
    /// scheduler migration noise in measured per-step wall-clock. Like
    /// [`RunConfig::sequential_workers`] it cannot change a trajectory
    /// — thread placement never touches data or accumulation order —
    /// so it is likewise excluded from the experiment cache key. Best
    /// effort: on non-Linux hosts the request is a no-op.
    pub pin_workers: bool,
    /// Fault injection for fleet-robustness studies (`[faults]` table /
    /// `--churn-prob` etc.): elastic membership, dropped and corrupted
    /// payloads, heavy-tailed stragglers. [`FaultPlan::none`] (the
    /// default) takes the bitwise-pinned fault-free path; an active
    /// plan draws from the trainer's dedicated checkpointed fault
    /// stream, is itself deterministic in the seed, and splits the
    /// experiment cache via [`RunConfig::describe`].
    pub faults: FaultPlan,
    /// Server-side robust-aggregation policy (`[outer] agg = "mean" |
    /// "trimmed" | "median"` / `--agg`). [`AggPolicy::Mean`] (the
    /// default) is the bitwise-historical path; the robust policies
    /// defend the dense-exchange formats against Byzantine ranks
    /// ([`FaultPlan::byzantine_frac`]). MV-sto-signSGD's majority
    /// tally ignores the knob — validation rejects a non-mean policy
    /// on the `packed_signs` wire rather than let the config imply a
    /// defense the tally never reads.
    pub agg: AggPolicy,
}

/// Peak local LR per preset, scaled-down analogue of the paper's Table 1.
pub fn default_peak_lr(preset: &str) -> f32 {
    match preset {
        "nano" => 1e-3,
        "small" => 1e-3,
        "medium" => 6e-4,
        "large" => 5e-4,
        "gpt2s" => 5e-4, // the paper's value
        _ => 6e-4,
    }
}

impl RunConfig {
    /// The paper's headline configuration at repro scale: AdamW base,
    /// Algorithm 1 outer, cosine schedule with warmup.
    pub fn paper_default(preset: &str) -> RunConfig {
        let rounds = 25;
        let tau = 12;
        let Some(comm) = CommModel::preset("ethernet") else {
            unreachable!("ethernet is a built-in comm preset")
        };
        RunConfig {
            preset: preset.to_string(),
            n_workers: 4,
            tau,
            rounds,
            mode: TrainMode::LocalSteps,
            base: BaseOptConfig::adamw_paper(),
            outer: OuterConfig::sign_momentum_paper(1.0),
            schedule: ScheduleConfig::cosine_paper(default_peak_lr(preset), (rounds * tau) as u64),
            comm,
            seed: 42,
            eval_every: 1,
            eval_batches: 8,
            corpus_bytes: 4 << 20,
            val_fraction: 0.05,
            log_dir: None,
            tag: format!("{preset}-sign_momentum"),
            global_step_pallas: false,
            heterogeneous: false,
            wire: None,
            sequential_workers: false,
            pin_workers: false,
            faults: FaultPlan::none(),
            agg: AggPolicy::Mean,
        }
    }

    /// The wire format this run's round exchange uses: the config
    /// override when present, the outer optimizer's native format
    /// otherwise.
    pub fn resolved_wire(&self) -> WireFormat {
        self.wire.unwrap_or_else(|| self.outer.default_wire())
    }

    /// Total local steps across the run (drives the LR schedule).
    pub fn total_local_steps(&self) -> u64 {
        (self.rounds * self.tau) as u64
    }

    /// Parse a TOML config file, then apply CLI overrides.
    pub fn from_toml_and_args(text: Option<&str>, args: &Args) -> Result<RunConfig> {
        let doc = match text {
            Some(t) => toml::parse(t).map_err(|e| anyhow!("{e}"))?,
            None => Json::Obj(Default::default()),
        };
        let gs = |key: &str| doc.get(key).and_then(Json::as_str).map(str::to_string);
        let gu = |key: &str| doc.get(key).and_then(Json::as_usize);
        let gf = |key: &str| doc.get(key).and_then(Json::as_f64);

        let preset = args.str_or("preset", &gs("preset").unwrap_or_else(|| "nano".into()));
        let mut cfg = RunConfig::paper_default(&preset);

        // file-level scalars
        if let Some(v) = gu("workers") {
            cfg.n_workers = v;
        }
        if let Some(v) = gu("tau") {
            cfg.tau = v;
        }
        if let Some(v) = gu("rounds") {
            cfg.rounds = v;
        }
        if let Some(v) = gu("seed") {
            cfg.seed = v as u64;
        }
        if let Some(v) = gu("eval_every") {
            cfg.eval_every = v;
        }
        if let Some(v) = gu("eval_batches") {
            cfg.eval_batches = v;
        }
        if let Some(v) = gu("corpus_bytes") {
            cfg.corpus_bytes = v;
        }
        if let Some(v) = gf("val_fraction") {
            cfg.val_fraction = v;
        }
        if let Some(mode) = gs("mode") {
            cfg.mode = parse_mode(&mode)?;
        }
        if let Some(t) = doc.get("base") {
            cfg.base = BaseOptConfig::from_json(t).map_err(|e| anyhow!(e))?;
        }
        let mut topk_frac: Option<f64> = None;
        let mut topk_decay: Option<f64> = None;
        if let Some(t) = doc.get("outer") {
            cfg.outer = OuterConfig::from_json(t).map_err(|e| anyhow!(e))?;
            if let Some(w) = t.get("wire").and_then(Json::as_str) {
                cfg.wire = Some(parse_wire(w)?);
            }
            if let Some(a) = t.get("agg").and_then(Json::as_str) {
                cfg.agg = parse_agg(a)?;
            }
            topk_frac = t.get("topk_frac").and_then(Json::as_f64);
            topk_decay = t.get("topk_decay").and_then(Json::as_f64);
        }
        if let Some(t) = doc.get("schedule") {
            cfg.schedule = ScheduleConfig::from_json(t, cfg.total_local_steps())
                .map_err(|e| anyhow!(e))?;
        }
        if let Some(t) = doc.get("comm") {
            if let Some(name) = t.get("preset").and_then(Json::as_str) {
                cfg.comm = CommModel::preset(name)
                    .ok_or_else(|| anyhow!("unknown comm preset `{name}`"))?;
            }
        }
        if let Some(t) = doc.get("faults") {
            let gff = |key: &str| t.get(key).and_then(Json::as_f64);
            if let Some(v) = gff("churn_prob") {
                cfg.faults.churn_prob = v;
            }
            if let Some(v) = gff("drop_prob") {
                cfg.faults.drop_prob = v;
            }
            if let Some(v) = gff("corrupt_prob") {
                cfg.faults.corrupt_prob = v;
            }
            if let Some(v) = gff("tail_prob") {
                cfg.faults.tail_prob = v;
            }
            if let Some(v) = gff("tail_scale_s") {
                cfg.faults.tail_scale_s = v;
            }
            if let Some(v) = gff("tail_alpha") {
                cfg.faults.tail_alpha = v;
            }
            if let Some(v) = gff("byzantine_frac") {
                cfg.faults.byzantine_frac = v;
            }
            if let Some(a) = t.get("attack").and_then(Json::as_str) {
                cfg.faults.attack = parse_attack(a)?;
            }
            if let Some(v) = t.get("retry_limit").and_then(Json::as_usize) {
                cfg.faults.retry_limit = v as u32;
            }
            if let Some(v) = t.get("quarantine").and_then(Json::as_bool) {
                cfg.faults.quarantine = v;
            }
        }

        // CLI overrides (take precedence over file)
        cfg.n_workers = args.usize_or("workers", cfg.n_workers).map_err(|e| anyhow!(e))?;
        cfg.tau = args.usize_or("tau", cfg.tau).map_err(|e| anyhow!(e))?;
        cfg.rounds = args.usize_or("rounds", cfg.rounds).map_err(|e| anyhow!(e))?;
        cfg.seed = args.u64_or("seed", cfg.seed).map_err(|e| anyhow!(e))?;
        cfg.eval_every = args.usize_or("eval-every", cfg.eval_every).map_err(|e| anyhow!(e))?;
        if let Some(m) = args.get("mode") {
            cfg.mode = parse_mode(m)?;
        }
        if let Some(name) = args.get("comm") {
            cfg.comm =
                CommModel::preset(name).ok_or_else(|| anyhow!("unknown comm preset `{name}`"))?;
        }
        if let Some(algo) = args.get("outer") {
            let eta = args.f32_or("global-lr", 1.0).map_err(|e| anyhow!(e))?;
            cfg.outer = match algo {
                "sign_momentum" => OuterConfig::sign_momentum_paper(eta),
                "slowmo" => OuterConfig::SlowMo {
                    alpha: eta,
                    beta: args.f32_or("outer-beta", 0.5).map_err(|e| anyhow!(e))?,
                },
                "local_avg" => OuterConfig::LocalAvg,
                other => {
                    // Hand from_json the object directly instead of
                    // round-tripping through the TOML parser (which
                    // would also choke on a quote in the algo name).
                    let mut algo_obj = std::collections::BTreeMap::new();
                    algo_obj.insert("algo".to_string(), Json::Str(other.to_string()));
                    OuterConfig::from_json(&Json::Obj(algo_obj)).map_err(|e| anyhow!(e))?
                }
            };
        }
        if let Some(peak) = args.get("peak-lr") {
            let peak: f32 = peak.parse().map_err(|_| anyhow!("--peak-lr: bad float"))?;
            cfg.schedule = ScheduleConfig::cosine_paper(peak, cfg.total_local_steps());
        }
        if let Some(w) = args.get("wire") {
            cfg.wire = Some(parse_wire(w)?);
        }
        if let Some(a) = args.get("agg") {
            cfg.agg = parse_agg(a)?;
        }
        if let Some(v) = args.get("topk-frac") {
            topk_frac = Some(v.parse().map_err(|_| anyhow!("--topk-frac: bad float"))?);
        }
        if let Some(v) = args.get("topk-decay") {
            topk_decay = Some(v.parse().map_err(|_| anyhow!("--topk-decay: bad float"))?);
        }
        if topk_frac.is_some() || topk_decay.is_some() {
            // the knobs parameterize the topk format itself, so handing
            // them to any other wire is a silent no-op we refuse
            let Some(WireFormat::TopK { frac_ppm, decay_ppm }) = &mut cfg.wire else {
                anyhow::bail!("topk_frac/topk_decay require `wire = \"topk\"`");
            };
            if let Some(f) = topk_frac {
                anyhow::ensure!(f > 0.0 && f <= 1.0, "topk_frac in (0, 1]");
                *frac_ppm = (f * 1e6).round() as u32;
            }
            if let Some(d) = topk_decay {
                anyhow::ensure!((0.0..=1.0).contains(&d), "topk_decay in [0, 1]");
                *decay_ppm = (d * 1e6).round() as u32;
            }
        }
        if args.has("pallas-global-step") {
            cfg.global_step_pallas = true;
        }
        if args.has("heterogeneous")
            || doc.get("heterogeneous").and_then(Json::as_bool).unwrap_or(false)
        {
            cfg.heterogeneous = true;
        }
        if args.has("sequential-workers")
            || doc.get("sequential_workers").and_then(Json::as_bool).unwrap_or(false)
        {
            cfg.sequential_workers = true;
        }
        if args.has("pin-workers")
            || doc.get("pin_workers").and_then(Json::as_bool).unwrap_or(false)
        {
            cfg.pin_workers = true;
        }
        let f = &mut cfg.faults;
        f.churn_prob = args.f64_or("churn-prob", f.churn_prob).map_err(|e| anyhow!(e))?;
        f.drop_prob = args.f64_or("drop-prob", f.drop_prob).map_err(|e| anyhow!(e))?;
        f.corrupt_prob = args.f64_or("corrupt-prob", f.corrupt_prob).map_err(|e| anyhow!(e))?;
        f.tail_prob = args.f64_or("tail-prob", f.tail_prob).map_err(|e| anyhow!(e))?;
        f.tail_scale_s = args.f64_or("tail-scale-s", f.tail_scale_s).map_err(|e| anyhow!(e))?;
        f.tail_alpha = args.f64_or("tail-alpha", f.tail_alpha).map_err(|e| anyhow!(e))?;
        f.byzantine_frac =
            args.f64_or("byzantine-frac", f.byzantine_frac).map_err(|e| anyhow!(e))?;
        if let Some(a) = args.get("attack") {
            f.attack = parse_attack(a)?;
        }
        f.retry_limit =
            args.usize_or("retry-limit", f.retry_limit as usize).map_err(|e| anyhow!(e))? as u32;
        if args.has("quarantine") {
            f.quarantine = true;
        }
        if let Some(dir) = args.get("log-dir") {
            cfg.log_dir = Some(PathBuf::from(dir));
        }
        if let Some(tag) = args.get("tag") {
            cfg.tag = tag.to_string();
        }
        // schedule total must track (possibly overridden) rounds*tau
        cfg.schedule.retarget_total(cfg.total_local_steps());
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.n_workers >= 1, "need >= 1 worker");
        anyhow::ensure!(self.tau >= 1, "tau >= 1");
        anyhow::ensure!(self.rounds >= 1, "rounds >= 1");
        anyhow::ensure!((0.0..0.9).contains(&self.val_fraction), "val_fraction in [0, 0.9)");
        anyhow::ensure!(self.corpus_bytes >= 1 << 14, "corpus too small");
        self.faults.validate()?;
        if self.mode == TrainMode::Standalone {
            anyhow::ensure!(self.tau == 1, "standalone mode communicates every step (tau=1)");
            // the fault machinery lives in the outer-round exchange;
            // the per-step all-reduce baseline has no round to degrade
            anyhow::ensure!(
                !self.faults.is_active(),
                "standalone mode has no outer rounds to inject faults into"
            );
            // standalone has no outer round exchange: a wire override
            // would label the run (and its cache key) with a format the
            // per-step dense gradient all-reduce never uses
            anyhow::ensure!(
                self.wire.is_none(),
                "standalone mode exchanges dense per-step gradients; drop the `wire` override"
            );
            // no outer aggregation step exists for a policy to govern
            anyhow::ensure!(
                self.agg == AggPolicy::Mean,
                "standalone mode has no outer aggregation; drop the `agg` override"
            );
        }
        let wire = self.resolved_wire();
        // match by name, not by value: the supported-wires menu lists
        // topk with its default frac/decay, and a tuned topk format is
        // every bit as speakable
        anyhow::ensure!(
            self.outer.supported_wires().iter().any(|w| w.name() == wire.name()),
            "outer optimizer `{}` does not speak wire format `{}` (supported: {})",
            self.outer.name(),
            wire.name(),
            self.outer
                .supported_wires()
                .iter()
                .map(|w| w.name())
                .collect::<Vec<_>>()
                .join(", ")
        );
        // the sign tally never reads the policy; a robust `agg` on the
        // 1-bit wire would label the run with a defense it doesn't run
        anyhow::ensure!(
            self.agg == AggPolicy::Mean || wire != WireFormat::PackedSigns,
            "agg = \"{}\" has no effect on the packed-signs majority tally; drop it",
            self.agg.name()
        );
        Ok(())
    }

    /// One-line summary for logs (also feeds the experiment cache key,
    /// so everything trajectory-determining belongs here — a topk wire
    /// spells out its frac/decay ppm, since those steer the trajectory
    /// as surely as the format name does).
    pub fn describe(&self) -> String {
        let wire = match self.resolved_wire() {
            WireFormat::TopK { frac_ppm, decay_ppm } => {
                format!("topk[{frac_ppm}ppm,{decay_ppm}ppm]")
            }
            w => w.name().to_string(),
        };
        // the historical describe() string is a cache key: the agg
        // segment appears only when the policy deviates from the
        // bitwise-default mean, so every pre-existing key is unchanged
        let agg = match self.agg {
            AggPolicy::Mean => String::new(),
            a => format!(" agg={}", a.name()),
        };
        format!(
            "{} n={} tau={} T={} base={} outer={} wire={wire}{agg} comm-rounds={} mode={:?}{}",
            self.preset,
            self.n_workers,
            self.tau,
            self.rounds,
            self.base.name(),
            // hyperparameter-resolved (W3): runs differing only in an
            // outer knob (eta, beta, ...) must not share a cache key
            self.outer.describe(),
            self.rounds,
            self.mode,
            self.faults.describe()
        )
    }
}

fn parse_mode(s: &str) -> Result<TrainMode> {
    match s {
        "local" | "local_steps" => Ok(TrainMode::LocalSteps),
        "standalone" => Ok(TrainMode::Standalone),
        other => Err(anyhow!("unknown mode `{other}`")),
    }
}

fn parse_wire(s: &str) -> Result<WireFormat> {
    WireFormat::parse(s).ok_or_else(|| anyhow!("unknown wire format `{s}`"))
}

fn parse_agg(s: &str) -> Result<AggPolicy> {
    AggPolicy::parse(s).ok_or_else(|| anyhow!("unknown aggregation policy `{s}`"))
}

fn parse_attack(s: &str) -> Result<Attack> {
    Attack::parse(s).ok_or_else(|| anyhow!("unknown byzantine attack `{s}`"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn paper_default_is_valid() {
        let cfg = RunConfig::paper_default("medium");
        cfg.validate().unwrap();
        assert_eq!(cfg.tau, 12);
        assert_eq!(cfg.outer.name(), "sign_momentum");
        assert_eq!(cfg.base.name(), "adamw");
    }

    #[test]
    fn toml_file_round_trip() {
        let text = r#"
preset = "small"
workers = 8
tau = 24
rounds = 10
mode = "local"

[base]
algo = "adamw"
beta2 = 0.95

[outer]
algo = "slowmo"
global_lr = 1.0
beta = 0.6

[comm]
preset = "wan"
"#;
        let cfg = RunConfig::from_toml_and_args(Some(text), &args("")).unwrap();
        assert_eq!(cfg.preset, "small");
        assert_eq!(cfg.n_workers, 8);
        assert_eq!(cfg.tau, 24);
        assert_eq!(cfg.outer, OuterConfig::SlowMo { alpha: 1.0, beta: 0.6 });
        assert_eq!(cfg.comm, CommModel::preset("wan").unwrap());
    }

    #[test]
    fn cli_overrides_file() {
        let text = "preset = \"small\"\ntau = 12\n";
        let cfg =
            RunConfig::from_toml_and_args(Some(text), &args("--tau 36 --workers 16")).unwrap();
        assert_eq!(cfg.tau, 36);
        assert_eq!(cfg.n_workers, 16);
        // schedule retargeted to new rounds*tau
        assert_eq!(cfg.schedule.total_steps(), cfg.total_local_steps());
    }

    #[test]
    fn standalone_requires_tau_1() {
        let cfg = RunConfig::from_toml_and_args(None, &args("--mode standalone --tau 12"));
        assert!(cfg.is_err());
        let ok = RunConfig::from_toml_and_args(None, &args("--mode standalone --tau 1"));
        assert!(ok.is_ok());
    }

    #[test]
    fn bad_values_error() {
        assert!(RunConfig::from_toml_and_args(Some("mode = \"bogus\""), &args("")).is_err());
        assert!(RunConfig::from_toml_and_args(None, &args("--comm warpdrive")).is_err());
        assert!(RunConfig::from_toml_and_args(None, &args("--workers 0")).is_err());
        assert!(RunConfig::from_toml_and_args(None, &args("--wire morse")).is_err());
    }

    #[test]
    fn wire_format_parses_resolves_and_validates() {
        let parse = |text: &str, cli: &str| RunConfig::from_toml_and_args(Some(text), &args(cli));

        // default: the optimizer's native format
        let cfg = RunConfig::from_toml_and_args(None, &args("")).unwrap();
        assert_eq!(cfg.wire, None);
        assert_eq!(cfg.resolved_wire(), WireFormat::DenseF32);
        let mv = parse("[outer]\nalgo = \"mv_signsgd\"\n", "").unwrap();
        assert_eq!(mv.resolved_wire(), WireFormat::PackedSigns);

        // file-level selection in the [outer] table, CLI override wins
        let toml_q8 = "[outer]\nalgo = \"slowmo\"\nwire = \"q8\"\n";
        let q8 = parse(toml_q8, "").unwrap();
        assert_eq!(q8.wire, Some(WireFormat::QuantizedI8));
        assert_eq!(q8.resolved_wire(), WireFormat::QuantizedI8);
        let cli = parse(toml_q8, "--wire dense").unwrap();
        assert_eq!(cli.resolved_wire(), WireFormat::DenseF32);

        // the layout-aware per-tensor format parses from file and CLI
        let q8pt = parse("[outer]\nalgo = \"slowmo\"\nwire = \"q8pt\"\n", "").unwrap();
        assert_eq!(q8pt.resolved_wire(), WireFormat::QuantizedI8PerTensor);
        let q8pt_cli = parse(toml_q8, "--wire q8pt").unwrap();
        assert_eq!(q8pt_cli.resolved_wire(), WireFormat::QuantizedI8PerTensor);

        // the sparse residual-momentum format parses from file and CLI
        let topk = parse("[outer]\nalgo = \"slowmo\"\nwire = \"topk\"\n", "").unwrap();
        assert_eq!(topk.resolved_wire(), WireFormat::TOPK_DEFAULT);
        let topk_cli = parse(toml_q8, "--wire topk").unwrap();
        assert_eq!(topk_cli.resolved_wire(), WireFormat::TOPK_DEFAULT);

        // unsupported pairings are rejected, not silently mis-billed
        assert!(parse("[outer]\nalgo = \"mv_signsgd\"\nwire = \"dense\"\n", "").is_err());
        assert!(parse("[outer]\nalgo = \"mv_signsgd\"\nwire = \"q8pt\"\n", "").is_err());
        assert!(parse("[outer]\nalgo = \"mv_signsgd\"\nwire = \"topk\"\n", "").is_err());
        assert!(parse("[outer]\nalgo = \"sign_momentum\"\nwire = \"1bit\"\n", "").is_err());
        // ...and so is a wire override in standalone mode, which never
        // runs the outer exchange the override would re-format
        let standalone_q8 =
            RunConfig::from_toml_and_args(None, &args("--mode standalone --tau 1 --wire q8"));
        assert!(standalone_q8.is_err());
    }

    #[test]
    fn describe_names_the_wire_format() {
        let mut cfg = RunConfig::paper_default("nano");
        assert!(cfg.describe().contains("wire=dense"));
        cfg.wire = Some(WireFormat::QuantizedI8);
        assert!(cfg.describe().contains("wire=q8"));
        cfg.wire = Some(WireFormat::QuantizedI8PerTensor);
        assert!(cfg.describe().contains("wire=q8pt"));
        // topk spells out its parameters: two runs differing only in
        // frac or decay must land in different experiment cache slots
        cfg.wire = Some(WireFormat::TOPK_DEFAULT);
        assert!(cfg.describe().contains("wire=topk[62500ppm,900000ppm]"), "{}", cfg.describe());
        cfg.wire = Some(WireFormat::TopK { frac_ppm: 125_000, decay_ppm: 900_000 });
        assert!(cfg.describe().contains("wire=topk[125000ppm,900000ppm]"), "{}", cfg.describe());
    }

    #[test]
    fn describe_splits_the_cache_key_on_outer_hyperparameters() {
        // The W3 guarantee end to end: two runs differing only in an
        // outer knob must produce different describe() strings (the
        // experiment cache key), for every knob of every optimizer.
        let base = RunConfig::paper_default("nano");
        let with_outer = |outer: OuterConfig| {
            let mut cfg = base.clone();
            cfg.outer = outer;
            cfg.describe()
        };
        let variants = [
            OuterConfig::sign_momentum_paper(1.0),
            OuterConfig::sign_momentum_paper(0.7),
            OuterConfig::SlowMo { alpha: 1.0, beta: 0.5 },
            OuterConfig::SlowMo { alpha: 1.0, beta: 0.6 },
            OuterConfig::SignedSlowMo { eta: 1.0, beta: 0.5 },
            OuterConfig::Lookahead { eta: 1.0, beta: 0.5, signed: false },
            OuterConfig::Lookahead { eta: 1.0, beta: 0.5, signed: true },
            OuterConfig::GlobalAdamW {
                eta: 1.0,
                beta1: 0.9,
                beta2: 0.95,
                eps: 1e-8,
                weight_decay: 0.0,
            },
            OuterConfig::GlobalAdamW {
                eta: 1.0,
                beta1: 0.9,
                beta2: 0.95,
                eps: 1e-8,
                weight_decay: 0.1,
            },
            OuterConfig::MvSignSgd { eta: 1.0, beta: 0.9, alpha: 0.1, bound: 1.0 },
            OuterConfig::MvSignSgd { eta: 1.0, beta: 0.9, alpha: 0.2, bound: 1.0 },
        ];
        let keys: Vec<String> = variants.into_iter().map(with_outer).collect();
        for (i, a) in keys.iter().enumerate() {
            for b in &keys[i + 1..] {
                assert_ne!(a, b, "cache keys collide");
            }
        }
    }

    #[test]
    fn topk_knobs_parse_validate_and_require_the_topk_wire() {
        let parse = |text: &str, cli: &str| RunConfig::from_toml_and_args(Some(text), &args(cli));

        // file-level knobs in the [outer] table
        let text =
            "[outer]\nalgo = \"slowmo\"\nwire = \"topk\"\ntopk_frac = 0.125\ntopk_decay = 0.5\n";
        let cfg = parse(text, "").unwrap();
        assert_eq!(
            cfg.resolved_wire(),
            WireFormat::TopK { frac_ppm: 125_000, decay_ppm: 500_000 }
        );

        // CLI beats file, and composes with --wire
        let cfg = parse(text, "--topk-frac 0.25").unwrap();
        assert_eq!(
            cfg.resolved_wire(),
            WireFormat::TopK { frac_ppm: 250_000, decay_ppm: 500_000 }
        );
        let cfg = RunConfig::from_toml_and_args(
            None,
            &args("--wire topk --topk-frac 0.03125 --topk-decay 0.999"),
        )
        .unwrap();
        assert_eq!(
            cfg.resolved_wire(),
            WireFormat::TopK { frac_ppm: 31_250, decay_ppm: 999_000 }
        );

        // the knobs without the format are a config error, not a no-op
        assert!(RunConfig::from_toml_and_args(None, &args("--topk-frac 0.1")).is_err());
        assert!(parse("[outer]\nalgo = \"slowmo\"\nwire = \"q8\"\ntopk_frac = 0.1\n", "").is_err());
        // out-of-range values are rejected
        assert!(RunConfig::from_toml_and_args(None, &args("--wire topk --topk-frac 0")).is_err());
        assert!(RunConfig::from_toml_and_args(None, &args("--wire topk --topk-frac 1.5")).is_err());
        assert!(
            RunConfig::from_toml_and_args(None, &args("--wire topk --topk-decay 1.01")).is_err()
        );
    }

    #[test]
    fn fault_plan_parses_from_file_and_cli_and_splits_the_cache_key() {
        // default: inactive, invisible in describe()
        let cfg = RunConfig::from_toml_and_args(None, &args("")).unwrap();
        assert!(!cfg.faults.is_active());
        assert!(!cfg.describe().contains("faults["));

        let text = "[faults]\nchurn_prob = 0.05\ndrop_prob = 0.1\ntail_prob = 0.01\n";
        let cfg = RunConfig::from_toml_and_args(Some(text), &args("")).unwrap();
        assert!(cfg.faults.is_active());
        assert_eq!(cfg.faults.churn_prob, 0.05);
        assert_eq!(cfg.faults.drop_prob, 0.1);
        assert!(cfg.describe().contains("faults["), "{}", cfg.describe());

        // CLI beats file
        let cfg = RunConfig::from_toml_and_args(Some(text), &args("--drop-prob 0.25")).unwrap();
        assert_eq!(cfg.faults.drop_prob, 0.25);

        // out-of-range probabilities are rejected at validation
        assert!(RunConfig::from_toml_and_args(None, &args("--drop-prob 1.5")).is_err());
        assert!(RunConfig::from_toml_and_args(None, &args("--churn-prob 1.0")).is_err());
        // standalone mode has no outer rounds to degrade
        let standalone = RunConfig::from_toml_and_args(
            None,
            &args("--mode standalone --tau 1 --drop-prob 0.1"),
        );
        assert!(standalone.is_err());
    }

    #[test]
    fn agg_policy_parses_and_splits_the_cache_key() {
        // default: mean, invisible in describe() — clean-path cache keys
        // predate the knob and must not churn
        let cfg = RunConfig::from_toml_and_args(None, &args("")).unwrap();
        assert_eq!(cfg.agg, AggPolicy::Mean);
        assert!(!cfg.describe().contains("agg="), "{}", cfg.describe());

        // file-level selection in the [outer] table, CLI override wins
        let text = "[outer]\nalgo = \"slowmo\"\nagg = \"trimmed\"\n";
        let cfg = RunConfig::from_toml_and_args(Some(text), &args("")).unwrap();
        assert_eq!(cfg.agg, AggPolicy::Trimmed);
        assert!(cfg.describe().contains(" agg=trimmed"), "{}", cfg.describe());
        let cfg = RunConfig::from_toml_and_args(Some(text), &args("--agg median")).unwrap();
        assert_eq!(cfg.agg, AggPolicy::Median);
        assert!(cfg.describe().contains(" agg=median"), "{}", cfg.describe());

        // unknown names are a config error, not a silent mean
        assert!(RunConfig::from_toml_and_args(None, &args("--agg krum")).is_err());
        // the majority tally ignores the policy: reject rather than imply
        let mv = "[outer]\nalgo = \"mv_signsgd\"\nagg = \"median\"\n";
        assert!(RunConfig::from_toml_and_args(Some(mv), &args("")).is_err());
        // standalone mode has no outer aggregation step
        let standalone =
            RunConfig::from_toml_and_args(None, &args("--mode standalone --tau 1 --agg trimmed"));
        assert!(standalone.is_err());
    }

    #[test]
    fn byzantine_knobs_parse_validate_and_split_the_cache_key() {
        let text = "[faults]\nbyzantine_frac = 0.25\nattack = \"scale_inflate\"\n";
        let cfg = RunConfig::from_toml_and_args(Some(text), &args("")).unwrap();
        assert!(cfg.faults.is_active());
        assert_eq!(cfg.faults.byzantine_frac, 0.25);
        assert_eq!(cfg.faults.attack, Attack::ScaleInflate);
        assert!(cfg.describe().contains("byz=0.25@scale_inflate"), "{}", cfg.describe());

        // CLI beats file, and the quarantine flag composes
        let cli = "--byzantine-frac 0.125 --attack collude_fixed --quarantine";
        let cfg = RunConfig::from_toml_and_args(Some(text), &args(cli)).unwrap();
        assert_eq!(cfg.faults.byzantine_frac, 0.125);
        assert_eq!(cfg.faults.attack, Attack::ColludeFixed);
        assert!(cfg.faults.quarantine);
        assert!(cfg.describe().contains("quarantine"), "{}", cfg.describe());

        // retry rides the drop stream: needs drop_prob to mean anything
        let retry = "--drop-prob 0.2 --retry-limit 3";
        let cfg = RunConfig::from_toml_and_args(None, &args(retry)).unwrap();
        assert_eq!(cfg.faults.retry_limit, 3);
        assert!(cfg.describe().contains("retry=3"), "{}", cfg.describe());
        assert!(RunConfig::from_toml_and_args(None, &args("--retry-limit 3")).is_err());

        // a full byzantine cohort (frac = 1) leaves no honest majority
        assert!(RunConfig::from_toml_and_args(None, &args("--byzantine-frac 1.0")).is_err());
        assert!(RunConfig::from_toml_and_args(None, &args("--attack nonsense")).is_err());
        // quarantine without adversaries is a config error, not a no-op
        assert!(RunConfig::from_toml_and_args(None, &args("--quarantine")).is_err());
    }

    #[test]
    fn outer_override_via_cli() {
        let cfg = RunConfig::from_toml_and_args(
            None,
            &args("--outer slowmo --global-lr 0.8 --outer-beta 0.7"),
        )
        .unwrap();
        assert_eq!(cfg.outer, OuterConfig::SlowMo { alpha: 0.8, beta: 0.7 });
    }
}
