//! Byte-pair encoding: trainer + encoder/decoder.
//!
//! A real (if compact) BPE implementation: training iteratively merges
//! the most frequent adjacent token pair (greatest count, ties broken by
//! lowest pair ids for determinism); encoding applies merges in learned
//! order, mirroring GPT-2's tokenizer semantics minus the regex
//! pre-splitting (unnecessary for our synthetic corpus).  Used by the
//! larger-vocab configurations and the `repro data` CLI; exercised
//! end-to-end in tests and benches.

use std::collections::HashMap;

use super::tokenizer::Tokenizer;

#[derive(Clone, Debug)]
pub struct Bpe {
    /// Learned merges in order: (left, right) -> new token id.
    merges: Vec<(u32, u32)>,
    /// merge lookup: (left, right) -> rank (= index into merges).
    ranks: HashMap<(u32, u32), u32>,
    /// token id -> byte expansion.
    vocab: Vec<Vec<u8>>,
}

impl Bpe {
    /// Train on `corpus` until the vocabulary reaches `vocab_size`
    /// (>= 256; ids 0-255 are the raw bytes).
    pub fn train(corpus: &[u8], vocab_size: usize) -> Bpe {
        assert!(vocab_size >= 256, "BPE vocab must include all bytes");
        let mut vocab: Vec<Vec<u8>> = (0..=255u8).map(|b| vec![b]).collect();
        let mut merges = Vec::new();
        let mut ranks = HashMap::new();

        let mut seq: Vec<u32> = corpus.iter().map(|&b| b as u32).collect();
        while vocab.len() < vocab_size {
            // count adjacent pairs
            let mut counts: HashMap<(u32, u32), u64> = HashMap::new();
            for w in seq.windows(2) {
                *counts.entry((w[0], w[1])).or_insert(0) += 1;
            }
            // most frequent pair, deterministic tie-break
            let Some((&pair, &count)) = counts
                .iter()
                .max_by_key(|(&(a, b), &c)| (c, std::cmp::Reverse((a, b))))
            else {
                break;
            };
            if count < 2 {
                break; // no compression left
            }
            let new_id = vocab.len() as u32;
            let mut bytes = vocab[pair.0 as usize].clone();
            bytes.extend_from_slice(&vocab[pair.1 as usize]);
            vocab.push(bytes);
            ranks.insert(pair, merges.len() as u32);
            merges.push(pair);

            // apply the merge to the working sequence in one pass
            let mut out = Vec::with_capacity(seq.len());
            let mut i = 0;
            while i < seq.len() {
                if i + 1 < seq.len() && (seq[i], seq[i + 1]) == pair {
                    out.push(new_id);
                    i += 2;
                } else {
                    out.push(seq[i]);
                    i += 1;
                }
            }
            seq = out;
        }
        Bpe { merges, ranks, vocab }
    }

    pub fn merges(&self) -> &[(u32, u32)] {
        &self.merges
    }

    /// Compression ratio achieved on a text (bytes per token).
    pub fn bytes_per_token(&self, text: &[u8]) -> f64 {
        let toks = self.encode(text);
        text.len() as f64 / toks.len().max(1) as f64
    }
}

impl Tokenizer for Bpe {
    fn vocab_size(&self) -> usize {
        self.vocab.len()
    }

    fn encode(&self, text: &[u8]) -> Vec<u32> {
        let mut seq: Vec<u32> = text.iter().map(|&b| b as u32).collect();
        // repeatedly apply the lowest-rank applicable merge (GPT-2 style)
        loop {
            let mut best: Option<(u32, usize)> = None; // (rank, position)
            for (i, w) in seq.windows(2).enumerate() {
                if let Some(&r) = self.ranks.get(&(w[0], w[1])) {
                    if best.map(|(br, _)| r < br).unwrap_or(true) {
                        best = Some((r, i));
                    }
                }
            }
            let Some((rank, _)) = best else { break };
            let pair = self.merges[rank as usize];
            let new_id = 256 + rank;
            // merge ALL occurrences of this pair in one pass
            let mut out = Vec::with_capacity(seq.len());
            let mut i = 0;
            while i < seq.len() {
                if i + 1 < seq.len() && (seq[i], seq[i + 1]) == pair {
                    out.push(new_id);
                    i += 2;
                } else {
                    out.push(seq[i]);
                    i += 1;
                }
            }
            seq = out;
        }
        seq
    }

    fn decode(&self, tokens: &[u32]) -> Vec<u8> {
        let mut out = Vec::new();
        for &t in tokens {
            out.extend_from_slice(&self.vocab[t as usize]);
        }
        out
    }

    fn name(&self) -> &'static str {
        "bpe"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{generate, CorpusConfig};

    fn sample_corpus() -> Vec<u8> {
        generate(&CorpusConfig { bytes: 60_000, ..Default::default() })
    }

    #[test]
    fn roundtrip_on_training_text() {
        let corpus = sample_corpus();
        let bpe = Bpe::train(&corpus, 512);
        let enc = bpe.encode(&corpus[..5000]);
        assert_eq!(bpe.decode(&enc), &corpus[..5000]);
    }

    #[test]
    fn roundtrip_on_unseen_text() {
        let bpe = Bpe::train(&sample_corpus(), 384);
        let unseen = b"completely novel zz@@qq bytes 42+58=100.".to_vec();
        assert_eq!(bpe.decode(&bpe.encode(&unseen)), unseen);
    }

    #[test]
    fn reaches_requested_vocab_and_compresses() {
        let corpus = sample_corpus();
        let bpe = Bpe::train(&corpus, 512);
        assert_eq!(bpe.vocab_size(), 512);
        let bpt = bpe.bytes_per_token(&corpus);
        assert!(bpt > 1.5, "expected >1.5 bytes/token on Zipfian text, got {bpt}");
    }

    #[test]
    fn merges_frequent_pairs_first() {
        // "the" dominates the corpus -> 't','h' or 'h','e' or ' t' among
        // the earliest merges.
        let bpe = Bpe::train(&sample_corpus(), 300);
        let early: Vec<Vec<u8>> = bpe.merges()[..8]
            .iter()
            .map(|&(a, b)| {
                let mut v = bpe.decode(&[a]);
                v.extend(bpe.decode(&[b]));
                v
            })
            .collect();
        assert!(
            early.iter().any(|m| m == b"th" || m == b"he" || m == b" t" || m == b"e "),
            "early merges: {:?}",
            early.iter().map(|m| String::from_utf8_lossy(m).into_owned()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn training_is_deterministic() {
        let corpus = sample_corpus();
        let a = Bpe::train(&corpus, 320);
        let b = Bpe::train(&corpus, 320);
        assert_eq!(a.merges(), b.merges());
    }

    #[test]
    fn encode_uses_merge_priority() {
        // train on text where "ab" is merged before "bc"; encoding "abc"
        // must then produce [ab, c] not [a, bc].
        let text = b"ababababab bc".repeat(50);
        let bpe = Bpe::train(&text, 258);
        let enc = bpe.encode(b"abc");
        assert_eq!(bpe.decode(&[enc[0]]), b"ab");
    }
}
