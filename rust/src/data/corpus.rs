//! Deterministic synthetic pre-training corpus (OpenWebText substitute).
//!
//! Design requirements (DESIGN.md §5.1): the corpus must make LM loss a
//! *meaningful* objective so optimizer rankings transfer — i.e. it needs
//! (a) heavy-tailed unigram statistics (Zipf), (b) local syntactic
//! structure a small model learns quickly, (c) longer-range dependencies
//! that keep the loss curve moving at the horizon we train, and (d) a
//! validation split from the same distribution.  Four interleaved
//! generators provide this:
//!
//!   1. **Zipf word soup** — sentences of dictionary words drawn Zipf(1.1),
//!      with function-word glue, capitalization and punctuation rules.
//!   2. **Bracket grammar** — well-nested (), [], {} sequences with
//!      bounded depth: classic context the model must track.
//!   3. **Arithmetic facts** — "7+15=22." with correct sums: predictable
//!      given prefix, rewards digit-level reasoning.
//!   4. **Template news** — "the NOUN of NOUN VERB the NOUN ." motifs
//!      introducing mid-range co-occurrence structure.
//!
//! Everything is generated from a seeded [`Rng`], so corpora are
//! bit-reproducible across runs and machines.

use crate::util::rng::{Rng, Zipf};

#[derive(Clone, Debug)]
pub struct CorpusConfig {
    pub bytes: usize,
    pub seed: u64,
    /// Mixture weights (normalized internally).
    pub w_zipf: f64,
    pub w_brackets: f64,
    pub w_arithmetic: f64,
    pub w_template: f64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            bytes: 4 << 20,
            seed: 1234,
            w_zipf: 0.55,
            w_brackets: 0.1,
            w_arithmetic: 0.15,
            w_template: 0.2,
        }
    }
}

/// Base vocabulary: 128 frequent English stems — enough for Zipfian
/// statistics without inflating the byte-level entropy floor.
const WORDS: &[&str] = &[
    "the", "of", "and", "to", "in", "that", "it", "was", "for", "on", "with", "as", "his",
    "they", "be", "at", "one", "have", "this", "from", "or", "had", "by", "hot", "word",
    "but", "what", "some", "we", "can", "out", "other", "were", "all", "there", "when",
    "up", "use", "your", "how", "said", "an", "each", "she", "which", "do", "their",
    "time", "if", "will", "way", "about", "many", "then", "them", "write", "would",
    "like", "so", "these", "her", "long", "make", "thing", "see", "him", "two", "has",
    "look", "more", "day", "could", "go", "come", "did", "number", "sound", "no", "most",
    "people", "my", "over", "know", "water", "than", "call", "first", "who", "may",
    "down", "side", "been", "now", "find", "any", "new", "work", "part", "take", "get",
    "place", "made", "live", "where", "after", "back", "little", "only", "round", "man",
    "year", "came", "show", "every", "good", "me", "give", "our", "under", "name",
    "very", "through", "just", "form", "sentence", "great", "think", "say", "help",
];

const NOUNS: &[&str] = &[
    "model", "worker", "gradient", "momentum", "server", "cluster", "token", "layer",
    "matrix", "signal", "network", "system", "update", "buffer", "batch", "epoch",
];
const VERBS: &[&str] = &[
    "computes", "averages", "sends", "receives", "updates", "scales", "clips", "signs",
    "reduces", "shards", "syncs", "trains",
];

pub fn generate(cfg: &CorpusConfig) -> Vec<u8> {
    let mut rng = Rng::new(cfg.seed).substream("corpus", 0);
    let zipf = Zipf::new(WORDS.len(), 1.1);
    let mut out = Vec::with_capacity(cfg.bytes + 256);
    let total = cfg.w_zipf + cfg.w_brackets + cfg.w_arithmetic + cfg.w_template;
    let thresholds = [
        cfg.w_zipf / total,
        (cfg.w_zipf + cfg.w_brackets) / total,
        (cfg.w_zipf + cfg.w_brackets + cfg.w_arithmetic) / total,
    ];
    while out.len() < cfg.bytes {
        let u = rng.f64();
        if u < thresholds[0] {
            zipf_sentence(&mut out, &mut rng, &zipf);
        } else if u < thresholds[1] {
            bracket_sequence(&mut out, &mut rng);
        } else if u < thresholds[2] {
            arithmetic_fact(&mut out, &mut rng);
        } else {
            template_sentence(&mut out, &mut rng);
        }
    }
    out.truncate(cfg.bytes);
    out
}

fn zipf_sentence(out: &mut Vec<u8>, rng: &mut Rng, zipf: &Zipf) {
    let n_words = 4 + rng.below(12) as usize;
    for i in 0..n_words {
        let w = WORDS[zipf.sample(rng)];
        if i == 0 {
            // capitalize first word
            let mut cs = w.chars();
            if let Some(c) = cs.next() {
                out.extend(c.to_ascii_uppercase().to_string().bytes());
                out.extend(cs.as_str().bytes());
            }
        } else {
            out.push(b' ');
            out.extend(w.bytes());
        }
    }
    out.extend(if rng.bernoulli(0.8) { b". ".iter() } else { b"? ".iter() });
}

fn bracket_sequence(out: &mut Vec<u8>, rng: &mut Rng) {
    const PAIRS: [(u8, u8); 3] = [(b'(', b')'), (b'[', b']'), (b'{', b'}')];
    fn rec(out: &mut Vec<u8>, rng: &mut Rng, depth: usize) {
        let n = 1 + rng.below(3);
        for _ in 0..n {
            let (open, close) = *rng.choose(&PAIRS);
            out.push(open);
            if depth < 4 && rng.bernoulli(0.55) {
                rec(out, rng, depth + 1);
            } else if rng.bernoulli(0.5) {
                out.push(b'a' + rng.below(26) as u8);
            }
            out.push(close);
        }
    }
    rec(out, rng, 0);
    out.push(b' ');
}

fn arithmetic_fact(out: &mut Vec<u8>, rng: &mut Rng) {
    let a = rng.below(100);
    let b = rng.below(100);
    if rng.bernoulli(0.5) {
        out.extend(format!("{a}+{b}={}. ", a + b).bytes());
    } else {
        let (hi, lo) = (a.max(b), a.min(b));
        out.extend(format!("{hi}-{lo}={}. ", hi - lo).bytes());
    }
}

fn template_sentence(out: &mut Vec<u8>, rng: &mut Rng) {
    let n1 = rng.choose(NOUNS);
    let n2 = rng.choose(NOUNS);
    let n3 = rng.choose(NOUNS);
    let v = rng.choose(VERBS);
    out.extend(format!("the {n1} of the {n2} {v} the {n3}. ").bytes());
}

/// Non-IID corpus for heterogeneous-worker experiments: `segments`
/// contiguous blocks, each generated with a different mixture (segment i
/// over-weights source i mod 4).  Combined with `TokenDataset`'s
/// contiguous sharding, worker i's shard is dominated by one source —
/// the controlled analogue of Assumption (b)'s gradient heterogeneity δ
/// in Theorem 2 (federated-style non-IID data).
pub fn generate_heterogeneous(bytes: usize, seed: u64, segments: usize) -> Vec<u8> {
    assert!(segments >= 1);
    let per = bytes / segments;
    let mut out = Vec::with_capacity(bytes + 64);
    for s in 0..segments {
        // one dominant source per segment, others at 5%
        let mut w = [0.05f64; 4];
        w[s % 4] = 0.85;
        let cfg = CorpusConfig {
            bytes: if s + 1 == segments { bytes - out.len() } else { per },
            seed: seed ^ (s as u64).wrapping_mul(0x9E3779B97F4A7C15),
            w_zipf: w[0],
            w_brackets: w[1],
            w_arithmetic: w[2],
            w_template: w[3],
        };
        out.extend(generate(&cfg));
    }
    out.truncate(bytes);
    out
}

/// Unigram byte entropy in bits — used by tests and the data CLI to show
/// the corpus is neither degenerate nor uniform noise.
pub fn byte_entropy_bits(data: &[u8]) -> f64 {
    let mut counts = [0u64; 256];
    for &b in data {
        counts[b as usize] += 1;
    }
    let n = data.len() as f64;
    counts
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / n;
            -p * p.log2()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Vec<u8> {
        generate(&CorpusConfig { bytes: 200_000, ..Default::default() })
    }

    #[test]
    fn deterministic_and_exact_size() {
        let a = small();
        let b = small();
        assert_eq!(a.len(), 200_000);
        assert_eq!(a, b);
        let c = generate(&CorpusConfig { bytes: 200_000, seed: 999, ..Default::default() });
        assert_ne!(a, c);
    }

    #[test]
    fn is_printable_ascii() {
        for &b in small().iter() {
            assert!((0x20..0x7f).contains(&b), "byte {b:#x}");
        }
    }

    #[test]
    fn entropy_in_natural_text_range() {
        // English-like text sits around 4.1-4.6 bits/byte unigram entropy;
        // uniform noise would be ~6.6 over printable ASCII, degenerate ~0.
        let h = byte_entropy_bits(&small());
        assert!((3.5..5.5).contains(&h), "entropy {h}");
    }

    #[test]
    fn brackets_are_balanced() {
        let data = generate(&CorpusConfig {
            bytes: 100_000,
            w_zipf: 0.0,
            w_brackets: 1.0,
            w_arithmetic: 0.0,
            w_template: 0.0,
            ..Default::default()
        });
        // Drop a possibly-truncated tail (generation cuts at byte budget).
        let last_space = data.iter().rposition(|&b| b == b' ').unwrap();
        let mut stack = Vec::new();
        for &b in &data[..last_space] {
            match b {
                b'(' | b'[' | b'{' => stack.push(b),
                b')' => assert_eq!(stack.pop(), Some(b'(')),
                b']' => assert_eq!(stack.pop(), Some(b'[')),
                b'}' => assert_eq!(stack.pop(), Some(b'{')),
                _ => {}
            }
        }
        assert!(stack.is_empty());
    }

    #[test]
    fn arithmetic_facts_are_correct() {
        let data = generate(&CorpusConfig {
            bytes: 50_000,
            w_zipf: 0.0,
            w_brackets: 0.0,
            w_arithmetic: 1.0,
            w_template: 0.0,
            ..Default::default()
        });
        let text = String::from_utf8(data).unwrap();
        let mut checked = 0;
        for fact in text.split(". ").take(200) {
            let Some((lhs, rhs)) = fact.split_once('=') else { continue };
            let Ok(r) = rhs.trim_end_matches('.').parse::<i64>() else { continue };
            if let Some((a, b)) = lhs.split_once('+') {
                if let (Ok(a), Ok(b)) = (a.parse::<i64>(), b.parse::<i64>()) {
                    assert_eq!(a + b, r, "{fact}");
                    checked += 1;
                }
            } else if let Some((a, b)) = lhs.split_once('-') {
                if let (Ok(a), Ok(b)) = (a.parse::<i64>(), b.parse::<i64>()) {
                    assert_eq!(a - b, r, "{fact}");
                    checked += 1;
                }
            }
        }
        assert!(checked > 50, "only {checked} facts parsed");
    }

    #[test]
    fn zipf_head_words_dominate() {
        let data = generate(&CorpusConfig {
            bytes: 300_000,
            w_zipf: 1.0,
            w_brackets: 0.0,
            w_arithmetic: 0.0,
            w_template: 0.0,
            ..Default::default()
        });
        let text = String::from_utf8(data).unwrap().to_lowercase();
        let count = |w: &str| text.matches(&format!(" {w} ")).count();
        assert!(count("the") > count("help") * 3);
    }
}
