//! Tokenized dataset with worker sharding and train/val split.
//!
//! Mirrors the paper's data-parallel setup: the token stream is split
//! into a validation tail and a training head; the training head is
//! partitioned into n *disjoint contiguous shards*, one per worker
//! (distribution D_i in problem (1)); each worker samples (B, S) windows
//! uniformly from its shard with its own RNG substream.  Batches are
//! (tokens, targets) with targets = tokens shifted by one.

use super::tokenizer::Tokenizer;
use crate::util::rng::Rng;

#[derive(Clone)]
pub struct TokenDataset {
    tokens: Vec<u32>,
    val_start: usize,
}

/// One (tokens, targets) batch in the i32 layout the AOT'd model expects.
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

impl TokenDataset {
    pub fn from_text(tok: &dyn Tokenizer, text: &[u8], val_fraction: f64) -> Self {
        let tokens = tok.encode(text);
        Self::from_tokens(tokens, val_fraction)
    }

    pub fn from_tokens(tokens: Vec<u32>, val_fraction: f64) -> Self {
        assert!(tokens.len() >= 64, "dataset too small");
        assert!((0.0..0.9).contains(&val_fraction));
        let val_start = ((tokens.len() as f64) * (1.0 - val_fraction)) as usize;
        TokenDataset { tokens, val_start }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    pub fn train_len(&self) -> usize {
        self.val_start
    }

    pub fn val_len(&self) -> usize {
        self.tokens.len() - self.val_start
    }

    /// The contiguous training shard `[lo, hi)` for worker `i` of `n`.
    pub fn shard_range(&self, worker: usize, n_workers: usize) -> (usize, usize) {
        assert!(worker < n_workers);
        let per = self.val_start / n_workers;
        let lo = worker * per;
        let hi = if worker + 1 == n_workers { self.val_start } else { lo + per };
        (lo, hi)
    }

    fn window(&self, start: usize, batch_i: usize, seq: usize, out: &mut Batch) {
        for j in 0..seq {
            out.tokens[batch_i * seq + j] = self.tokens[start + j] as i32;
            out.targets[batch_i * seq + j] = self.tokens[start + j + 1] as i32;
        }
    }

    /// Sample a training batch from worker `i`'s shard.
    pub fn sample_train(
        &self,
        worker: usize,
        n_workers: usize,
        batch: usize,
        seq: usize,
        rng: &mut Rng,
    ) -> Batch {
        let (lo, hi) = self.shard_range(worker, n_workers);
        assert!(hi - lo > seq + 1, "shard smaller than one window");
        let mut out = Batch {
            tokens: vec![0; batch * seq],
            targets: vec![0; batch * seq],
            batch,
            seq,
        };
        for b in 0..batch {
            let start = lo + rng.below((hi - lo - seq - 1) as u64) as usize;
            self.window(start, b, seq, &mut out);
        }
        out
    }

    /// Deterministic validation batches: fixed strided windows over the
    /// validation tail, so every algorithm is evaluated on identical data.
    pub fn val_batches(&self, batch: usize, seq: usize, max_batches: usize) -> Vec<Batch> {
        let lo = self.val_start;
        let hi = self.tokens.len();
        let n_windows = (hi - lo - 1) / seq;
        let n_batches = (n_windows / batch).min(max_batches);
        let mut out = Vec::with_capacity(n_batches);
        for bi in 0..n_batches {
            let mut b = Batch {
                tokens: vec![0; batch * seq],
                targets: vec![0; batch * seq],
                batch,
                seq,
            };
            for j in 0..batch {
                let start = lo + (bi * batch + j) * seq;
                self.window(start, j, seq, &mut b);
            }
            out.push(b);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{generate, CorpusConfig};
    use crate::data::tokenizer::ByteTokenizer;

    fn ds() -> TokenDataset {
        let text = generate(&CorpusConfig { bytes: 100_000, ..Default::default() });
        TokenDataset::from_text(&ByteTokenizer, &text, 0.1)
    }

    #[test]
    fn split_sizes() {
        let d = ds();
        assert_eq!(d.len(), 100_000);
        assert_eq!(d.train_len(), 90_000);
        assert_eq!(d.val_len(), 10_000);
    }

    #[test]
    fn shards_are_disjoint_and_cover_train() {
        let d = ds();
        let n = 7;
        let mut last_hi = 0;
        for w in 0..n {
            let (lo, hi) = d.shard_range(w, n);
            assert_eq!(lo, last_hi);
            assert!(hi > lo);
            last_hi = hi;
        }
        assert_eq!(last_hi, d.train_len());
    }

    #[test]
    fn targets_are_next_token() {
        let d = ds();
        let mut rng = Rng::new(0);
        let b = d.sample_train(0, 4, 3, 32, &mut rng);
        for i in 0..3 {
            for j in 0..31 {
                assert_eq!(b.tokens[i * 32 + j + 1], b.targets[i * 32 + j]);
            }
        }
    }

    #[test]
    fn train_samples_stay_inside_worker_shard() {
        // Construct a dataset where shard membership is detectable from
        // the token values themselves.
        let tokens: Vec<u32> = (0..10_000u32).map(|i| i / 2500).collect(); // 4 blocks
        let d = TokenDataset::from_tokens(tokens, 0.0_f64.max(0.0) + 0.2);
        let mut rng = Rng::new(1);
        for w in 0..4 {
            // 8000 train tokens -> 4 shards of 2000: worker w sees values
            // from blocks floor(w*2000/2500)..; worker 0 only value 0.
            let b = d.sample_train(w, 4, 4, 16, &mut rng);
            let (lo, hi) = d.shard_range(w, 4);
            for &t in &b.tokens {
                assert!(
                    (t as u32) >= (lo as u32 / 2500) && (t as u32) <= ((hi + 16) as u32 / 2500),
                    "worker {w} saw token {t} outside shard [{lo},{hi})"
                );
            }
        }
    }

    #[test]
    fn val_batches_are_deterministic_and_distinct() {
        let d = ds();
        let a = d.val_batches(4, 32, 8);
        let b = d.val_batches(4, 32, 8);
        assert_eq!(a.len(), 8);
        assert_eq!(a[0].tokens, b[0].tokens);
        assert_ne!(a[0].tokens, a[1].tokens);
    }

    #[test]
    fn val_batches_use_only_validation_tail() {
        let tokens: Vec<u32> = (0..1000u32).map(|i| if i < 800 { 1 } else { 2 }).collect();
        let d = TokenDataset::from_tokens(tokens, 0.2);
        for b in d.val_batches(2, 16, 4) {
            assert!(b.tokens.iter().all(|&t| t == 2));
        }
    }

    #[test]
    fn different_rng_streams_give_different_batches() {
        let d = ds();
        let mut r1 = Rng::new(5).substream("worker", 0);
        let mut r2 = Rng::new(5).substream("worker", 1);
        let b1 = d.sample_train(0, 2, 2, 32, &mut r1);
        let b2 = d.sample_train(0, 2, 2, 32, &mut r2);
        assert_ne!(b1.tokens, b2.tokens);
    }
}
