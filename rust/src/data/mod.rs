//! Data pipeline: synthetic corpus, tokenizers, sharded token datasets.
//!
//! Substitutes the paper's OpenWebText (38 GB, unavailable offline) with
//! a deterministic synthetic corpus that keeps the statistical properties
//! LM-loss dynamics depend on — see corpus.rs.  A byte-level tokenizer is
//! the default at repro scale (vocab 256); a real trainable BPE tokenizer
//! is provided and exercised for fidelity at larger vocabularies.

pub mod bpe;
pub mod corpus;
pub mod dataset;
pub mod tokenizer;

pub use bpe::Bpe;
pub use corpus::CorpusConfig;
pub use dataset::TokenDataset;
pub use tokenizer::{ByteTokenizer, Tokenizer};
