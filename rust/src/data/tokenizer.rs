//! Tokenizer trait + byte-level tokenizer.
//!
//! Repro-scale presets use byte-level tokens (vocab 256) so the embedding
//! table stays small on the 1-core testbed; the BPE implementation in
//! bpe.rs serves larger vocabularies (and the `gpt2s` preset's 50257-ish
//! regime) and demonstrates the full pipeline the paper's setup uses.

pub trait Tokenizer: Send + Sync {
    fn vocab_size(&self) -> usize;
    fn encode(&self, text: &[u8]) -> Vec<u32>;
    fn decode(&self, tokens: &[u32]) -> Vec<u8>;
    fn name(&self) -> &'static str;
}

/// Identity byte tokenizer: token id == byte value.
#[derive(Clone, Copy, Debug, Default)]
pub struct ByteTokenizer;

impl Tokenizer for ByteTokenizer {
    fn vocab_size(&self) -> usize {
        256
    }

    fn encode(&self, text: &[u8]) -> Vec<u32> {
        text.iter().map(|&b| b as u32).collect()
    }

    fn decode(&self, tokens: &[u32]) -> Vec<u8> {
        tokens.iter().map(|&t| (t & 0xff) as u8).collect()
    }

    fn name(&self) -> &'static str {
        "byte"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_roundtrip() {
        let t = ByteTokenizer;
        let text = b"Hello, world! 123".to_vec();
        let enc = t.encode(&text);
        assert_eq!(enc.len(), text.len());
        assert_eq!(t.decode(&enc), text);
        assert_eq!(t.vocab_size(), 256);
    }

    #[test]
    fn all_bytes_covered() {
        let t = ByteTokenizer;
        let all: Vec<u8> = (0..=255).collect();
        assert_eq!(t.decode(&t.encode(&all)), all);
    }
}
