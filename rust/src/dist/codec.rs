//! Compressed wire codecs: the byte formats behind the typed round
//! exchange ([`super::wire::WirePayload`]).
//!
//! Three compressed formats live here. signSGD-style methods (majority
//! vote, MV-sto-signSGD) only move the *sign* of each coordinate, which
//! packs to 1 bit instead of an f32's 32 — the 32× communication
//! reduction that motivates them (Bernstein et al. 2018);
//! [`pack_signs`]/[`unpack_signs`] implement that payload. The 8-bit
//! quantized format ([`quantize_diff_into`]/[`dequantize_i8`]) trades a
//! 4× payload reduction for a bounded rounding error on dense
//! pseudo-gradient exchanges; its per-tensor refinement
//! ([`quantize_diff_slice`] run once per [`crate::runtime::ParamLayout`]
//! segment) spends 4 extra bytes per segment to give every parameter
//! block its own scale, cutting the rounding error wherever blocks
//! have very different difference magnitudes. The sparse top-k format
//! ([`topk_select_segment`], DeMo-style: Peng et al. 2024) transmits
//! only the [`topk_budget`] largest-magnitude components per layout
//! segment as (u32 index, f32 value) pairs; the untransmitted mass
//! stays in a decaying worker-side residual buffer owned by the
//! payload. [`sign_allreduce_bytes`], [`q8_bytes`], [`q8pt_bytes`],
//! and [`topk_bytes`] are the byte models the simulated clock bills
//! through [`crate::comm::SimClock::charge_exchange`].
//!
//! # Wire format
//!
//! Little-endian bit order: element `i` lives in bit `i % 8` of byte
//! `i / 8`. A **set** bit encodes a non-negative sign (decodes to
//! `+1.0`), a **clear** bit a negative sign (`-1.0`). Zeros carry their
//! IEEE sign bit (`+0.0 → +1`, `-0.0 → -1`): one bit has no zero
//! symbol, and decoding to ±1 matches how sign steps consume the value
//! (a ±1 multiplied into the learning rate). Consequently
//! `unpack_signs(pack_signs(v))[i] == copysign(1.0, v[i])`, and any
//! vector already in {-1, +1} round-trips exactly.
//!
//! # Tally protocol
//!
//! The majority-vote exchange built on this format ([`super::votes`])
//! is worker→server: each rank sends one packed payload, the server
//! tallies set bits per coordinate directly on the packed words
//! (never unpacking to f32) and decodes coordinate `i` to `+1` iff at
//! least half the ranks set bit `i` — a tie has no zero symbol to fall
//! back to, so it resolves to `+1`. Sign-vote outer optimizers (the
//! `packed_signs` wire format, [`super::wire::WireFormat`]) therefore
//! use wire-tie semantics *everywhere*, including their in-memory
//! reference paths.

/// Fixed per-message framing overhead (element count as a u64), charged
/// on top of the packed payload by [`sign_allreduce_bytes`].
pub const HEADER_BYTES: u64 = 8;

/// Packed payload size for `n` sign coordinates: ⌈n / 8⌉ bytes.
pub fn packed_len(n: usize) -> usize {
    super::div_up(n, 8)
}

/// Total bytes one sign message of `n_params` coordinates puts on the
/// wire: packed payload plus the fixed header.
pub fn sign_allreduce_bytes(n_params: usize) -> u64 {
    packed_len(n_params) as u64 + HEADER_BYTES
}

/// Pack the sign bit of every coordinate (1 bit each, 32× smaller than
/// the f32 payload). See the module docs for the exact bit layout.
pub fn pack_signs(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::new();
    pack_signs_into(v, &mut out);
    out
}

/// [`pack_signs`] into a caller-owned buffer, reusing its capacity —
/// the allocation-free path for persistent per-rank vote buffers
/// ([`super::votes::PackedVotes::pack_into`]). The buffer is resized
/// to exactly [`packed_len`] bytes.
pub fn pack_signs_into(v: &[f32], out: &mut Vec<u8>) {
    out.clear();
    out.resize(packed_len(v.len()), 0);
    for (i, &x) in v.iter().enumerate() {
        if !x.is_sign_negative() {
            out[i / 8] |= 1 << (i % 8);
        }
    }
}

/// Framing overhead of one [`quantize_diff_into`] message on top of the
/// 1-byte-per-coordinate payload: the element count as a u64 plus the
/// f32 quantization scale.
pub const Q8_OVERHEAD_BYTES: u64 = HEADER_BYTES + 4;

/// Total bytes one 8-bit quantized message of `n_params` coordinates
/// puts on the wire: 1 byte per coordinate plus the fixed framing.
pub fn q8_bytes(n_params: usize) -> u64 {
    n_params as u64 + Q8_OVERHEAD_BYTES
}

/// Total bytes one **per-tensor** 8-bit quantized message puts on the
/// wire: 1 byte per coordinate, the u64 length header, and one f32
/// scale per layout segment. With one segment this is exactly
/// [`q8_bytes`] — the per-tensor format is a strict generalization of
/// the per-message one.
pub fn q8pt_bytes(n_params: usize, n_segments: usize) -> u64 {
    n_params as u64 + HEADER_BYTES + 4 * n_segments as u64
}

/// Total bytes one sparse top-k message of `k_total` kept components
/// puts on the wire: a u32 index + f32 value pair per component plus
/// the u64 length header. `k_total` is the sum of [`topk_budget`] over
/// the layout's segments, so the count — and therefore the bill — is a
/// pure function of (layout, keep fraction), never of packed contents.
pub fn topk_bytes(k_total: usize) -> u64 {
    8 * k_total as u64 + HEADER_BYTES
}

/// Per-segment keep budget of the top-k wire: `frac_ppm` parts per
/// million of the segment's coordinates, rounded down but never below
/// one component for a non-empty segment (every parameter block stays
/// represented on the wire; an empty segment keeps zero). Content-free
/// by construction — the clock can bill a round before any rank packs.
pub fn topk_budget(numel: usize, frac_ppm: u32) -> usize {
    if numel == 0 {
        return 0;
    }
    let k = (numel as u64 * frac_ppm as u64) / 1_000_000;
    (k.max(1) as usize).min(numel)
}

/// Top-k selection + residual hand-off for one layout segment of the
/// sparse wire: pick the `k` largest-|residual| coordinates (ties
/// broken toward the lower index — a total order, so the kept set is
/// deterministic), write their **global** indices (`base + local`) and
/// values sorted by index (canonical payload bytes), and zero the
/// transmitted entries — the kept mass leaves the buffer, the
/// untransmitted mass stays behind for the caller's decay. NaN
/// magnitudes rank largest under `total_cmp`, so a poisoned residual
/// transmits its NaN instead of hiding it from the divergence check.
pub fn topk_select_segment(
    residual: &mut [f32],
    base: usize,
    idx_out: &mut [u32],
    val_out: &mut [f32],
    scratch: &mut Vec<u32>,
) {
    let k = idx_out.len();
    assert_eq!(k, val_out.len(), "top-k outputs disagree: {k} indices, {} values", val_out.len());
    assert!(
        k <= residual.len(),
        "top-k keeps {k} of a segment holding {} coordinates",
        residual.len()
    );
    if k == 0 {
        return;
    }
    // packed-key partition kernel — identical kept set to the old
    // comparator (see `kernels::topk_partition` for the order proof)
    super::kernels::topk_partition(residual, k, scratch);
    for ((&local, i), v) in scratch[..k].iter().zip(idx_out.iter_mut()).zip(val_out.iter_mut()) {
        *i = (base + local as usize) as u32;
        *v = residual[local as usize];
        residual[local as usize] = 0.0;
    }
}

/// Quantize the local difference `start - end` to symmetric i8 with a
/// per-message scale, writing the two's-complement bytes into `out`
/// (capacity reused — the allocation-free path for persistent payload
/// buffers) and returning the scale.
///
/// Encoding: `scale = max_i |start_i - end_i| / 127` and
/// `byte_i = round((start_i - end_i) / scale)` clamped to ±127, so the
/// extreme coordinate is exact and every coordinate decodes within
/// `scale / 2` of its true value ([`dequantize_i8`]). An all-zero
/// difference encodes `scale = 0` with an all-zero payload and decodes
/// exactly. Any non-finite difference poisons the message: the scale is
/// encoded as NaN, every byte decodes to NaN (rather than silently
/// saturating to a finite value), and the trainer's divergence check
/// fires exactly as it would on the dense wire.
pub fn quantize_diff_into(start: &[f32], end: &[f32], out: &mut Vec<u8>) -> f32 {
    assert_eq!(
        start.len(),
        end.len(),
        "quantize: start has {} coordinates, end {}",
        start.len(),
        end.len()
    );
    // no clear(): in steady state the persistent buffer already has the
    // right length, so this resize is a no-op instead of a full memset
    // (quantize_diff_slice overwrites every byte either way)
    out.resize(start.len(), 0);
    quantize_diff_slice(start, end, out)
}

/// [`quantize_diff_into`] over a caller-sized byte slice — the
/// per-segment core the layout-aware `q8pt` payload calls once per
/// [`crate::runtime::ParamLayout`] segment (each segment quantizes
/// against its own scale). Arithmetic is identical to the per-message
/// path, so a one-segment layout produces bitwise-identical bytes and
/// scale.
pub fn quantize_diff_slice(start: &[f32], end: &[f32], out: &mut [u8]) -> f32 {
    assert_eq!(
        start.len(),
        end.len(),
        "quantize: start has {} coordinates, end {}",
        start.len(),
        end.len()
    );
    assert_eq!(
        out.len(),
        start.len(),
        "quantize: output holds {} bytes, need {}",
        out.len(),
        start.len()
    );
    // f32::max skips NaN operands, so finiteness is tracked explicitly —
    // a diverged worker must not encode as an innocuous finite payload.
    // Both passes run on the lane-widened kernels; the scale and every
    // byte are bitwise-identical to the serial scan (order-free max,
    // elementwise second pass — differential-tested in `kernels`).
    let (max, finite) = super::kernels::abs_max_diff(start, end);
    let scale = if finite { max / 127.0 } else { f32::NAN };
    if scale == 0.0 {
        out.fill(0);
        return 0.0;
    }
    super::kernels::quantize_scaled(start, end, 1.0 / scale, out);
    scale
}

/// Quantize a raw value vector (not a start−end difference) to symmetric
/// i8 with one scale, same arithmetic as [`quantize_diff_slice`] — used
/// by the hierarchical exchange's group heads to re-quantize a decoded
/// group-mean difference before it travels up a level. Same non-finite
/// poisoning contract: any non-finite value encodes a NaN scale.
pub fn quantize_slice(vals: &[f32], out: &mut [u8]) -> f32 {
    assert_eq!(
        out.len(),
        vals.len(),
        "quantize: output holds {} bytes, need {}",
        out.len(),
        vals.len()
    );
    let (max, finite) = super::kernels::abs_max(vals);
    let scale = if finite { max / 127.0 } else { f32::NAN };
    if scale == 0.0 {
        out.fill(0);
        return 0.0;
    }
    super::kernels::quantize_vals_scaled(vals, 1.0 / scale, out);
    scale
}

/// Decode one byte produced by [`quantize_diff_into`] back to f32.
pub fn dequantize_i8(byte: u8, scale: f32) -> f32 {
    (byte as i8) as f32 * scale
}

/// Decode `len` coordinates packed by [`pack_signs`] back to ±1.0.
pub fn unpack_signs(packed: &[u8], len: usize) -> Vec<f32> {
    assert_eq!(
        packed.len(),
        packed_len(len),
        "packed buffer is {} bytes, {} coordinates need {}",
        packed.len(),
        len,
        packed_len(len)
    );
    (0..len)
        .map(|i| if (packed[i / 8] >> (i % 8)) & 1 == 1 { 1.0 } else { -1.0 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packed_len_rounds_up() {
        assert_eq!(packed_len(0), 0);
        assert_eq!(packed_len(1), 1);
        assert_eq!(packed_len(8), 1);
        assert_eq!(packed_len(9), 2);
        assert_eq!(packed_len(1 << 20), 1 << 17);
    }

    #[test]
    fn sign_message_is_32x_smaller_than_f32_plus_header() {
        let p = 1 << 20;
        assert_eq!(sign_allreduce_bytes(p), (p as u64) / 8 + HEADER_BYTES);
        assert!(sign_allreduce_bytes(p) * 30 < (p as u64) * 4);
    }

    #[test]
    fn pm_one_patterns_roundtrip_exactly() {
        let v: Vec<f32> = (0..67).map(|i| if i % 3 == 0 { 1.0 } else { -1.0 }).collect();
        assert_eq!(unpack_signs(&pack_signs(&v), v.len()), v);
    }

    #[test]
    fn arbitrary_floats_decode_to_their_copysign() {
        let v = vec![3.5f32, -0.25, 0.0, -0.0, 1e-30, -1e30, f32::MAX, f32::MIN];
        let decoded = unpack_signs(&pack_signs(&v), v.len());
        for (&x, &d) in v.iter().zip(&decoded) {
            assert_eq!(d, 1.0f32.copysign(x), "input {x}");
        }
    }

    #[test]
    fn bit_layout_is_little_endian_within_bytes() {
        // element 0 -> bit 0 of byte 0; element 8 -> bit 0 of byte 1
        let mut v = vec![-1.0f32; 9];
        v[0] = 1.0;
        v[8] = 1.0;
        assert_eq!(pack_signs(&v), vec![0b0000_0001, 0b0000_0001]);
    }

    #[test]
    fn empty_input_packs_to_empty() {
        assert_eq!(pack_signs(&[]), Vec::<u8>::new());
        assert_eq!(unpack_signs(&[], 0), Vec::<f32>::new());
    }

    #[test]
    #[should_panic(expected = "packed buffer")]
    fn wrong_packed_length_panics() {
        unpack_signs(&[0u8; 2], 32);
    }

    #[test]
    fn q8_message_is_4x_smaller_than_f32_plus_framing() {
        let p = 1 << 20;
        assert_eq!(q8_bytes(p), p as u64 + Q8_OVERHEAD_BYTES);
        assert!(q8_bytes(p) * 3 < (p as u64) * 4);
    }

    #[test]
    fn q8_extreme_coordinate_is_exact_and_error_is_bounded() {
        let start = vec![1.0f32, 0.5, -0.25, 0.0, 2.0];
        let end = vec![0.0f32, 0.75, -0.25, 0.254, 2.001];
        let mut bytes = Vec::new();
        let scale = quantize_diff_into(&start, &end, &mut bytes);
        assert_eq!(bytes.len(), 5);
        assert_eq!(scale, 1.0 / 127.0); // max |diff| = 1.0
        for ((&s, &e), &b) in start.iter().zip(&end).zip(&bytes) {
            let err = (dequantize_i8(b, scale) - (s - e)).abs();
            assert!(err <= scale / 2.0 + 1e-6, "diff {} decoded with err {err}", s - e);
        }
        // the max-magnitude coordinate round-trips exactly (q = ±127)
        assert_eq!(dequantize_i8(bytes[0], scale), 1.0);
    }

    #[test]
    fn q8_zero_difference_encodes_scale_zero_and_decodes_exactly() {
        let x = vec![3.0f32, -1.0, 0.0];
        let mut bytes = vec![0xFFu8; 1]; // stale content must be overwritten
        let scale = quantize_diff_into(&x, &x, &mut bytes);
        assert_eq!(scale, 0.0);
        assert_eq!(bytes, vec![0u8; 3]);
        for &b in &bytes {
            assert_eq!(dequantize_i8(b, scale), 0.0);
        }
    }

    #[test]
    fn q8_non_finite_differences_poison_the_message() {
        // a diverged worker must decode non-finite everywhere so the
        // trainer's all_finite check fires, exactly like the dense wire
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let start = vec![1.0f32, 2.0, 3.0];
            let end = vec![0.5f32, bad, 3.25];
            let mut bytes = Vec::new();
            let scale = quantize_diff_into(&start, &end, &mut bytes);
            assert!(scale.is_nan(), "scale for bad={bad}");
            assert_eq!(bytes.len(), 3);
            for &b in &bytes {
                assert!(!dequantize_i8(b, scale).is_finite(), "bad={bad}");
            }
        }
    }

    #[test]
    fn q8pt_bytes_generalizes_q8_bytes() {
        let p = 1 << 20;
        assert_eq!(q8pt_bytes(p, 1), q8_bytes(p));
        // each extra segment costs exactly one f32 scale
        assert_eq!(q8pt_bytes(p, 12), q8_bytes(p) + 4 * 11);
    }

    #[test]
    fn topk_budget_floors_scales_and_never_drops_a_live_segment() {
        assert_eq!(topk_budget(0, 62_500), 0);
        assert_eq!(topk_budget(1, 62_500), 1); // floor, not round-to-zero
        assert_eq!(topk_budget(16, 62_500), 1); // 1/16 of 16
        assert_eq!(topk_budget(1 << 20, 62_500), 1 << 16);
        assert_eq!(topk_budget(5, 1_000_000), 5); // frac 1.0 keeps everything
        assert_eq!(topk_budget(5, 2_000_000), 5); // and clamps above it
        // the byte model pairs each kept component with a u32 index
        assert_eq!(topk_bytes(0), HEADER_BYTES);
        assert_eq!(topk_bytes(100), 800 + HEADER_BYTES);
        // at the default 1/16 keep fraction each kept component costs 8
        // bytes, so the sparse message lands near P/2 — comfortably
        // under the q8pt message's ~P bytes on the same layout
        let p = 1 << 20;
        let k: usize = (0..15).map(|_| topk_budget(p / 15, 62_500)).sum();
        assert!(
            topk_bytes(k) * 3 < q8pt_bytes(p, 15) * 2,
            "{} vs {}",
            topk_bytes(k),
            q8pt_bytes(p, 15)
        );
    }

    #[test]
    fn topk_select_keeps_the_largest_magnitudes_sorted_by_index() {
        let mut residual = vec![0.1f32, -5.0, 0.0, 3.0, -0.2, 4.0];
        let mut idx = vec![0u32; 3];
        let mut val = vec![0.0f32; 3];
        let mut scratch = Vec::new();
        topk_select_segment(&mut residual, 10, &mut idx, &mut val, &mut scratch);
        // |−5| > |4| > |3|: coordinates 1, 5, 3 — emitted index-sorted,
        // offset by the segment base, values untouched by the selection
        assert_eq!(idx, vec![11, 13, 15]);
        assert_eq!(val, vec![-5.0, 3.0, 4.0]);
        // transmitted mass left the buffer; the rest stayed behind
        assert_eq!(residual, vec![0.1, 0.0, 0.0, 0.0, -0.2, 0.0]);
    }

    #[test]
    fn topk_select_ties_break_toward_the_lower_index() {
        let mut residual = vec![1.0f32, -1.0, 1.0, 1.0];
        let mut idx = vec![0u32; 2];
        let mut val = vec![0.0f32; 2];
        topk_select_segment(&mut residual, 0, &mut idx, &mut val, &mut Vec::new());
        assert_eq!(idx, vec![0, 1]);
        assert_eq!(val, vec![1.0, -1.0]);
    }

    #[test]
    fn topk_select_transmits_nan_instead_of_hiding_it() {
        // a poisoned residual must reach the wire so check_finite fires
        let mut residual = vec![9.0f32, f32::NAN, -2.0];
        let mut idx = vec![0u32; 1];
        let mut val = vec![0.0f32; 1];
        topk_select_segment(&mut residual, 0, &mut idx, &mut val, &mut Vec::new());
        assert_eq!(idx, vec![1]);
        assert!(val[0].is_nan());
    }

    #[test]
    fn topk_select_with_k_equal_len_moves_everything() {
        let mut residual = vec![0.5f32, -0.25];
        let mut idx = vec![0u32; 2];
        let mut val = vec![0.0f32; 2];
        topk_select_segment(&mut residual, 4, &mut idx, &mut val, &mut Vec::new());
        assert_eq!(idx, vec![4, 5]);
        assert_eq!(val, vec![0.5, -0.25]);
        assert_eq!(residual, vec![0.0, 0.0]);
    }

    #[test]
    fn quantize_slice_matches_quantize_into_bitwise() {
        let start: Vec<f32> = (0..100).map(|i| (i as f32 * 0.37).sin()).collect();
        let end: Vec<f32> = (0..100).map(|i| (i as f32 * 0.53).cos() * 0.1).collect();
        let mut via_vec = Vec::new();
        let scale_vec = quantize_diff_into(&start, &end, &mut via_vec);
        let mut via_slice = vec![0xAAu8; 100]; // stale content must be overwritten
        let scale_slice = quantize_diff_slice(&start, &end, &mut via_slice);
        assert_eq!(scale_vec.to_bits(), scale_slice.to_bits());
        assert_eq!(via_vec, via_slice);
    }

    #[test]
    #[should_panic(expected = "output holds")]
    fn quantize_slice_wrong_output_size_panics() {
        quantize_diff_slice(&[1.0, 2.0], &[0.0, 0.0], &mut [0u8; 3]);
    }

    #[test]
    fn quantize_slice_of_raw_values_matches_diff_against_zero() {
        // quantize_slice(v) must equal quantize_diff_slice(v, 0) bit for
        // bit — it is the same encoder with the subtraction folded away
        let vals: Vec<f32> = (0..64).map(|i| (i as f32 * 0.29).sin() * 0.01).collect();
        let zeros = vec![0.0f32; vals.len()];
        let mut a = vec![0u8; vals.len()];
        let mut b = vec![0u8; vals.len()];
        let sa = quantize_slice(&vals, &mut a);
        let sb = quantize_diff_slice(&vals, &zeros, &mut b);
        assert_eq!(sa.to_bits(), sb.to_bits());
        assert_eq!(a, b);
        // zero vector encodes scale 0, and non-finite input poisons
        let mut out = vec![0xFFu8; 2];
        assert_eq!(quantize_slice(&[0.0, -0.0], &mut out), 0.0);
        assert_eq!(out, vec![0, 0]);
        assert!(quantize_slice(&[1.0, f32::INFINITY], &mut out).is_nan());
    }

    #[test]
    fn quantize_diff_slice_matches_scalar_reference_bitwise() {
        // the public encoder runs on the lane-widened kernels; the
        // serial pre-kernel pass is kept in `kernels` as the oracle
        let start: Vec<f32> =
            (0..257).map(|i| (i as f32 * 0.13).sin() * (i % 7) as f32).collect();
        let end: Vec<f32> = (0..257).map(|i| (i as f32 * 0.29).cos()).collect();
        let mut a = vec![0u8; 257];
        let mut b = vec![0u8; 257];
        let sa = quantize_diff_slice(&start, &end, &mut a);
        let sb = crate::dist::kernels::quantize_diff_ref(&start, &end, &mut b);
        assert_eq!(sa.to_bits(), sb.to_bits());
        assert_eq!(a, b);
    }

    #[test]
    fn q8_buffer_is_reused_across_repacks() {
        let start = vec![1.0f32; 512];
        let end = vec![0.25f32; 512];
        let mut bytes = Vec::new();
        quantize_diff_into(&start, &end, &mut bytes);
        let cap = bytes.capacity();
        for _ in 0..8 {
            quantize_diff_into(&start, &end, &mut bytes);
        }
        assert_eq!(bytes.capacity(), cap);
        assert_eq!(bytes.len(), 512);
    }

    #[test]
    fn q8_negative_differences_round_trip_with_sign() {
        let start = vec![0.0f32; 4];
        let end = vec![1.0f32, -1.0, 0.5, -0.5];
        let mut bytes = Vec::new();
        let scale = quantize_diff_into(&start, &end, &mut bytes);
        let decoded: Vec<f32> = bytes.iter().map(|&b| dequantize_i8(b, scale)).collect();
        // both extremes are exact; interior values keep their sign and
        // land within half a quantization step
        assert_eq!(decoded[0], -1.0);
        assert_eq!(decoded[1], 1.0);
        for (d, expect) in decoded.iter().zip([-1.0f32, 1.0, -0.5, 0.5]) {
            assert_eq!(d.signum(), expect.signum());
            assert!((d - expect).abs() <= scale / 2.0 + 1e-6);
        }
    }
}
