//! 1-bit sign codec: the wire format for sign-exchange collectives.
//!
//! signSGD-style methods (majority vote, MV-sto-signSGD) only move the
//! *sign* of each coordinate, which packs to 1 bit instead of an f32's
//! 32 — the 32× communication reduction that motivates them (Bernstein
//! et al. 2018). [`pack_signs`]/[`unpack_signs`] implement the payload;
//! [`sign_allreduce_bytes`] is the byte model the simulated clock
//! charges ([`crate::comm::SimClock::charge_sign_allreduce`]).
//!
//! # Wire format
//!
//! Little-endian bit order: element `i` lives in bit `i % 8` of byte
//! `i / 8`. A **set** bit encodes a non-negative sign (decodes to
//! `+1.0`), a **clear** bit a negative sign (`-1.0`). Zeros carry their
//! IEEE sign bit (`+0.0 → +1`, `-0.0 → -1`): one bit has no zero
//! symbol, and decoding to ±1 matches how sign steps consume the value
//! (a ±1 multiplied into the learning rate). Consequently
//! `unpack_signs(pack_signs(v))[i] == copysign(1.0, v[i])`, and any
//! vector already in {-1, +1} round-trips exactly.
//!
//! # Tally protocol
//!
//! The majority-vote exchange built on this format ([`super::votes`])
//! is worker→server: each rank sends one packed payload, the server
//! tallies set bits per coordinate directly on the packed words
//! (never unpacking to f32) and decodes coordinate `i` to `+1` iff at
//! least half the ranks set bit `i` — a tie has no zero symbol to fall
//! back to, so it resolves to `+1`. Sign-compressed outer optimizers
//! (`OuterOptimizer::sign_compressed_comm`) therefore use wire-tie
//! semantics *everywhere*, including their in-memory reference paths.

/// Fixed per-message framing overhead (element count as a u64), charged
/// on top of the packed payload by [`sign_allreduce_bytes`].
pub const HEADER_BYTES: u64 = 8;

/// Packed payload size for `n` sign coordinates: ⌈n / 8⌉ bytes.
pub fn packed_len(n: usize) -> usize {
    super::div_up(n, 8)
}

/// Total bytes one sign message of `n_params` coordinates puts on the
/// wire: packed payload plus the fixed header.
pub fn sign_allreduce_bytes(n_params: usize) -> u64 {
    packed_len(n_params) as u64 + HEADER_BYTES
}

/// Pack the sign bit of every coordinate (1 bit each, 32× smaller than
/// the f32 payload). See the module docs for the exact bit layout.
pub fn pack_signs(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::new();
    pack_signs_into(v, &mut out);
    out
}

/// [`pack_signs`] into a caller-owned buffer, reusing its capacity —
/// the allocation-free path for persistent per-rank vote buffers
/// ([`super::votes::PackedVotes::pack_into`]). The buffer is resized
/// to exactly [`packed_len`] bytes.
pub fn pack_signs_into(v: &[f32], out: &mut Vec<u8>) {
    out.clear();
    out.resize(packed_len(v.len()), 0);
    for (i, &x) in v.iter().enumerate() {
        if !x.is_sign_negative() {
            out[i / 8] |= 1 << (i % 8);
        }
    }
}

/// Decode `len` coordinates packed by [`pack_signs`] back to ±1.0.
pub fn unpack_signs(packed: &[u8], len: usize) -> Vec<f32> {
    assert_eq!(
        packed.len(),
        packed_len(len),
        "packed buffer is {} bytes, {} coordinates need {}",
        packed.len(),
        len,
        packed_len(len)
    );
    (0..len)
        .map(|i| if (packed[i / 8] >> (i % 8)) & 1 == 1 { 1.0 } else { -1.0 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packed_len_rounds_up() {
        assert_eq!(packed_len(0), 0);
        assert_eq!(packed_len(1), 1);
        assert_eq!(packed_len(8), 1);
        assert_eq!(packed_len(9), 2);
        assert_eq!(packed_len(1 << 20), 1 << 17);
    }

    #[test]
    fn sign_message_is_32x_smaller_than_f32_plus_header() {
        let p = 1 << 20;
        assert_eq!(sign_allreduce_bytes(p), (p as u64) / 8 + HEADER_BYTES);
        assert!(sign_allreduce_bytes(p) * 30 < (p as u64) * 4);
    }

    #[test]
    fn pm_one_patterns_roundtrip_exactly() {
        let v: Vec<f32> = (0..67).map(|i| if i % 3 == 0 { 1.0 } else { -1.0 }).collect();
        assert_eq!(unpack_signs(&pack_signs(&v), v.len()), v);
    }

    #[test]
    fn arbitrary_floats_decode_to_their_copysign() {
        let v = vec![3.5f32, -0.25, 0.0, -0.0, 1e-30, -1e30, f32::MAX, f32::MIN];
        let decoded = unpack_signs(&pack_signs(&v), v.len());
        for (&x, &d) in v.iter().zip(&decoded) {
            assert_eq!(d, 1.0f32.copysign(x), "input {x}");
        }
    }

    #[test]
    fn bit_layout_is_little_endian_within_bytes() {
        // element 0 -> bit 0 of byte 0; element 8 -> bit 0 of byte 1
        let mut v = vec![-1.0f32; 9];
        v[0] = 1.0;
        v[8] = 1.0;
        assert_eq!(pack_signs(&v), vec![0b0000_0001, 0b0000_0001]);
    }

    #[test]
    fn empty_input_packs_to_empty() {
        assert_eq!(pack_signs(&[]), Vec::<u8>::new());
        assert_eq!(unpack_signs(&[], 0), Vec::<f32>::new());
    }

    #[test]
    #[should_panic(expected = "packed buffer")]
    fn wrong_packed_length_panics() {
        unpack_signs(&[0u8; 2], 32);
    }
}
