//! Collective arithmetic over worker state: exact-mean all-reduce and
//! sign majority vote, with a sequential reference backend and a
//! chunked multi-threaded backend that is bitwise identical to it.
//!
//! The network *cost* of these collectives is modeled separately by
//! [`crate::comm`]; here we do the actual math the simulated cluster
//! would perform.
//!
//! # Backend determinism
//!
//! Every element `out[j]` is computed by the same expression in both
//! backends — accumulate `slices[0][j], slices[1][j], ...` in f64 in
//! worker order, then scale — and the threaded backend only partitions
//! the *output index range* across the persistent worker pool
//! ([`super::pool`]; the chunk→thread mapping is irrelevant to the
//! result). No reduction-tree reassociation happens, so `Sequential`
//! and `Threaded { .. }` agree bit-for-bit for any thread count
//! (property-tested in `rust/tests/collectives.rs`), and runs stay
//! reproducible regardless of the host's core count.

use super::pool;
use crate::tensor::sign_f32;

/// How a collective executes on the host.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Single-threaded reference implementation.
    Sequential,
    /// Split the output across up to `threads` pool workers.
    Threaded { threads: usize },
}

/// Below this output length the dispatch overhead dominates any speedup.
const PARALLEL_THRESHOLD: usize = 1 << 16;

impl Backend {
    /// Pick a backend for an output of length `len`: threaded on
    /// multi-core hosts for large vectors, sequential otherwise.
    pub fn auto(len: usize) -> Backend {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        if len >= PARALLEL_THRESHOLD && cores > 1 {
            Backend::Threaded { threads: cores.min(pool::MAX_THREADS) }
        } else {
            Backend::Sequential
        }
    }
}

/// Run `body(base_index, chunk)` over `out`, either whole (sequential)
/// or split into contiguous chunks executed on the persistent pool.
fn run_chunked<F>(backend: Backend, out: &mut [f32], body: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let threads = match backend {
        Backend::Sequential => 1,
        Backend::Threaded { threads } => threads,
    };
    pool::run_chunked_mut(threads, 1, out, body);
}

fn check_shapes(slices: &[&[f32]], out: &[f32]) {
    assert!(!slices.is_empty(), "collective over zero workers");
    for (i, s) in slices.iter().enumerate() {
        assert_eq!(s.len(), out.len(), "worker {i}: length {} != output {}", s.len(), out.len());
    }
}

/// Exact mean of one slice per item into `out`, auto-picking a backend.
///
/// `get` projects each item to its f32 slice (e.g. `|w| w.params
/// .as_slice()` over a `&[Worker]` fleet, or `|g| g.as_slice()` over
/// raw gradient vectors).
pub fn allreduce_mean<T, F>(items: &[T], get: F, out: &mut [f32])
where
    F: Fn(&T) -> &[f32],
{
    allreduce_mean_with(Backend::auto(out.len()), items, get, out)
}

/// [`allreduce_mean`] with an explicit [`Backend`].
pub fn allreduce_mean_with<T, F>(backend: Backend, items: &[T], get: F, out: &mut [f32])
where
    F: Fn(&T) -> &[f32],
{
    let slices: Vec<&[f32]> = items.iter().map(get).collect();
    allreduce_mean_slices(backend, &slices, out);
}

/// Core mean reduction over pre-projected slices.
pub fn allreduce_mean_slices(backend: Backend, slices: &[&[f32]], out: &mut [f32]) {
    check_shapes(slices, out);
    let inv_n = 1.0f64 / slices.len() as f64;
    run_chunked(backend, out, |base, chunk| {
        for (j, o) in chunk.iter_mut().enumerate() {
            let idx = base + j;
            let mut acc = 0.0f64;
            for s in slices {
                acc += s[idx] as f64;
            }
            *o = (acc * inv_n) as f32;
        }
    });
}

/// Element-wise sign majority vote over per-worker vote vectors,
/// auto-picking a backend.
///
/// Each vote contributes `sign(v) ∈ {-1, 0, +1}` to the tally; the
/// output is **always ±1** — a tied (or all-zero) coordinate resolves
/// to **+1**, because the 1-bit wire format ([`super::codec`]) has no
/// zero symbol. Sign-compressed methods use these wire-tie semantics
/// everywhere — Algorithm 6's in-memory reference path routes through
/// the same packed tally ([`super::votes`]), so it never sits still on
/// a zero tally either.
pub fn majority_vote<V: AsRef<[f32]>>(votes: &[V], out: &mut [f32]) {
    majority_vote_with(Backend::auto(out.len()), votes, out)
}

/// [`majority_vote`] with an explicit [`Backend`].
pub fn majority_vote_with<V: AsRef<[f32]>>(backend: Backend, votes: &[V], out: &mut [f32]) {
    let slices: Vec<&[f32]> = votes.iter().map(|v| v.as_ref()).collect();
    check_shapes(&slices, out);
    let slices = &slices;
    run_chunked(backend, out, |base, chunk| {
        for (j, o) in chunk.iter_mut().enumerate() {
            let idx = base + j;
            let mut tally = 0i64;
            for s in slices {
                tally += sign_f32(s[idx]) as i64;
            }
            *o = if tally >= 0 { 1.0 } else { -1.0 };
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_of_constant_vectors_is_exact() {
        let workers = vec![vec![2.0f32; 5], vec![4.0f32; 5]];
        let mut out = vec![0.0f32; 5];
        allreduce_mean(&workers, |w| w.as_slice(), &mut out);
        assert_eq!(out, vec![3.0f32; 5]);
    }

    #[test]
    fn single_worker_mean_is_identity() {
        let workers = vec![vec![1.0f32, -2.5, 3.25]];
        let mut out = vec![0.0f32; 3];
        allreduce_mean_with(Backend::Sequential, &workers, |w| w.as_slice(), &mut out);
        assert_eq!(out, workers[0]);
    }

    #[test]
    fn threaded_equals_sequential_on_small_input() {
        let workers = vec![vec![1.0f32, 2.0, 3.0], vec![-1.0, 0.5, 9.0], vec![0.0, 0.0, 1.0]];
        let mut seq = vec![0.0f32; 3];
        let mut thr = vec![0.0f32; 3];
        allreduce_mean_with(Backend::Sequential, &workers, |w| w.as_slice(), &mut seq);
        allreduce_mean_with(Backend::Threaded { threads: 7 }, &workers, |w| w.as_slice(), &mut thr);
        assert_eq!(seq, thr);
    }

    #[test]
    fn majority_vote_is_plus_minus_one_with_positive_ties() {
        let votes = vec![
            vec![1.0f32, -1.0, 1.0, 0.0],
            vec![1.0f32, -1.0, -1.0, 0.0],
            vec![-1.0f32, -1.0, 0.0, 0.0],
        ];
        let mut out = vec![0.0f32; 4];
        majority_vote(&votes, &mut out);
        // 2-1 positive; 0-3 negative; 1-1 tie -> +1; all-zero tie -> +1
        assert_eq!(out, vec![1.0, -1.0, 1.0, 1.0]);
    }

    #[test]
    fn auto_backend_picks_sequential_for_tiny_outputs() {
        assert_eq!(Backend::auto(8), Backend::Sequential);
    }

    #[test]
    #[should_panic(expected = "length")]
    fn shape_mismatch_panics() {
        let workers = vec![vec![1.0f32; 3], vec![1.0f32; 4]];
        let mut out = vec![0.0f32; 3];
        allreduce_mean(&workers, |w| w.as_slice(), &mut out);
    }
}
