//! Shared hot-path kernels: wide word-level tally strips and exact-lane,
//! fixed-reduction-order byte/float loops.
//!
//! Every simulated exchange funnels through a handful of inner loops —
//! the bit-sliced popcount tally ([`super::votes`]), the q8/q8pt
//! quantize/dequantize passes and the top-k select ([`super::codec`]),
//! and the mean-decode paths in [`super::wire`]. This module holds the
//! widened versions of those loops plus the scalar references they are
//! measured and differential-tested against (`benches/kernels.rs`
//! records the before/after trajectory in `BENCH_kernels.json`).
//!
//! # The fixed-reduction-order contract
//!
//! The standing invariants — parallel ≡ sequential bit-identity,
//! checkpoint/resume bit-identity, golden per-optimizer trajectories —
//! survive these kernels because no kernel is allowed to reassociate a
//! floating-point reduction:
//!
//! - **Sums stay serial.** Any f32/f64 accumulation (a dot product, a
//!   mean) keeps its original index order per output element. Kernels
//!   widen *across independent output elements* (elementwise maps,
//!   rank-1 `axpy` updates), never across the terms of one sum.
//! - **Order-free ops may go wide.** `max` over non-negative values,
//!   boolean AND-reduction, and integer/bit arithmetic are independent
//!   of evaluation order, so those loops split into fixed lanes
//!   ([`LANES`]) that autovectorize. `f32::max` skips a NaN operand the
//!   same way in every order, and the separately tracked finiteness bit
//!   makes the max irrelevant whenever a NaN was present at all.
//! - **Integer/bit kernels are bitwise-identical by construction** —
//!   carry-save addition is exact per lane-bit — and pinned by the
//!   differential tests below against the scalar `_ref` ports of the
//!   pre-kernel code.
//!
//! Anything that cannot be expressed under this contract (e.g. a
//! reduction-tree sum) does not belong here.

use super::codec;

/// Fixed lane count for the widened float/byte loops. Eight f32 lanes
/// fill one AVX2 register / two NEON registers; the loops are written
/// over `chunks_exact(LANES)` so the compiler can vectorize them
/// without a reassociation license.
pub const LANES: usize = 8;

/// Number of 64-lane vote words processed per tally strip: four
/// independent carry chains give the ripple-carry adder instruction-level
/// parallelism the single-word version cannot have (each level's
/// XOR/AND depends on the previous level's output).
pub const STRIP_WORDS: usize = 4;

// ---------------------------------------------------------------------------
// Packed-vote tally
// ---------------------------------------------------------------------------

/// Load 64 packed sign lanes (word `wi`) from a raw packed-vote byte
/// buffer, zero-padding past the end — byte-for-byte the semantics of
/// `PackedVotes::word`, but on the borrowed byte slice so a tally over
/// many payloads touches no per-word bounds-checked copies.
#[inline]
pub fn packed_word(bytes: &[u8], wi: usize) -> u64 {
    let lo = wi * 8;
    if lo >= bytes.len() {
        return 0;
    }
    let mut b = [0u8; 8];
    if let Some(full) = bytes.get(lo..lo + 8) {
        b.copy_from_slice(full);
    } else {
        let tail = &bytes[lo..];
        b[..tail.len()].copy_from_slice(tail);
    }
    u64::from_le_bytes(b)
}

/// Carry-save add one vote word per strip slot into the bit-sliced
/// counters (`counts[lvl * STRIP_WORDS + k]` is level `lvl` of slot
/// `k`). Returns the OR of the carry-out words: non-zero means some
/// lane overflowed the counter width.
#[inline]
fn add_strip(counts: &mut [u64], words: &[u64; STRIP_WORDS]) -> u64 {
    let mut carry = *words;
    for row in counts.chunks_exact_mut(STRIP_WORDS) {
        if carry == [0u64; STRIP_WORDS] {
            return 0;
        }
        for (c, w) in row.iter_mut().zip(carry.iter_mut()) {
            let t = *c;
            *c = t ^ *w;
            *w = t & *w;
        }
    }
    carry[0] | carry[1] | carry[2] | carry[3]
}

/// Per-lane `count >= threshold` over the bit-sliced counters of one
/// strip slot, MSB-down — the strip-layout port of the single-word
/// comparator in the scalar reference.
#[inline]
fn strip_lanes_ge(counts: &[u64], slot: usize, threshold: u64) -> u64 {
    let levels = counts.len() / STRIP_WORDS;
    let mut ge = 0u64;
    let mut eq = !0u64;
    for lvl in (0..levels).rev() {
        let c = counts[lvl * STRIP_WORDS + slot];
        let tk = if (threshold >> lvl) & 1 == 1 { !0u64 } else { 0u64 };
        ge |= eq & c & !tk;
        eq &= !(c ^ tk);
    }
    ge | eq
}

/// Majority-tally `n_words` (1..=[`STRIP_WORDS`]) consecutive 64-lane
/// vote words starting at `base_word` across every payload byte slice,
/// writing one winner mask (`1` bit = majority non-negative) per word
/// into `winners[..n_words]`.
///
/// Bitwise-identical to tallying each word with [`tally_word_ref`]:
/// carry-save addition is exact per lane-bit, and the comparator reads
/// the same counter bits MSB-down. The overflow check fires under the
/// same condition as the scalar path (some lane's count exceeded the
/// counter width), with the same message.
///
/// # Panics
/// If a lane count overflows `levels` bits — the caller must size
/// `levels` to cover the payload count, exactly as before.
pub fn tally_strip(
    slices: &[&[u8]],
    base_word: usize,
    n_words: usize,
    levels: usize,
    threshold: u64,
    winners: &mut [u64; STRIP_WORDS],
) {
    debug_assert!((1..=STRIP_WORDS).contains(&n_words), "strip width {n_words}");
    debug_assert!(levels <= 64, "counter deeper than a u64 rank count");
    let mut counts = [0u64; STRIP_WORDS * 64];
    let counts = &mut counts[..levels * STRIP_WORDS];
    let mut overflow = 0u64;
    for s in slices {
        let mut words = [0u64; STRIP_WORDS];
        for (k, w) in words.iter_mut().enumerate().take(n_words) {
            *w = packed_word(s, base_word + k);
        }
        overflow |= add_strip(counts, &words);
    }
    assert_eq!(overflow, 0, "counter width must cover the rank count");
    for (k, w) in winners.iter_mut().enumerate().take(n_words) {
        *w = strip_lanes_ge(counts, k, threshold);
    }
}

/// Scalar reference: tally a single 64-lane word the way the
/// pre-kernel `dist/votes.rs` inner loop did — one ripple-carry chain,
/// early exit when the carry clears. Kept public for the differential
/// tests and as the `tally/scalar` bench baseline.
pub fn tally_word_ref(slices: &[&[u8]], wi: usize, levels: usize, threshold: u64) -> u64 {
    let mut counts = [0u64; 64];
    let counts = &mut counts[..levels];
    let mut overflow = 0u64;
    for s in slices {
        let mut carry = packed_word(s, wi);
        for c in counts.iter_mut() {
            if carry == 0 {
                break;
            }
            let t = *c;
            *c = t ^ carry;
            carry = t & carry;
        }
        overflow |= carry;
    }
    assert_eq!(overflow, 0, "counter width must cover the rank count");
    let mut ge = 0u64;
    let mut eq = !0u64;
    for lvl in (0..levels).rev() {
        let c = counts[lvl];
        let tk = if (threshold >> lvl) & 1 == 1 { !0u64 } else { 0u64 };
        ge |= eq & c & !tk;
        eq &= !(c ^ tk);
    }
    ge | eq
}

// ---------------------------------------------------------------------------
// q8 quantize / dequantize
// ---------------------------------------------------------------------------

/// `(max |start - end|, every diff finite)` in [`LANES`] independent
/// max chains. Bitwise-identical to the serial scan: max over
/// non-negative values is order-free, `f32::max` drops a NaN operand in
/// any order, and when some diff was non-finite the caller's scale is
/// NaN regardless of the max.
pub fn abs_max_diff(start: &[f32], end: &[f32]) -> (f32, bool) {
    debug_assert_eq!(start.len(), end.len());
    let mut lane_max = [0.0f32; LANES];
    let mut finite = true;
    let mut sc = start.chunks_exact(LANES);
    let mut ec = end.chunks_exact(LANES);
    for (s8, e8) in (&mut sc).zip(&mut ec) {
        for (k, m) in lane_max.iter_mut().enumerate() {
            let d = s8[k] - e8[k];
            finite &= d.is_finite();
            *m = m.max(d.abs());
        }
    }
    for (s, e) in sc.remainder().iter().zip(ec.remainder()) {
        let d = s - e;
        finite &= d.is_finite();
        lane_max[0] = lane_max[0].max(d.abs());
    }
    let mut max = 0.0f32;
    for m in lane_max {
        max = max.max(m);
    }
    (max, finite)
}

/// [`abs_max_diff`] over raw values (diff against zero).
pub fn abs_max(vals: &[f32]) -> (f32, bool) {
    let mut lane_max = [0.0f32; LANES];
    let mut finite = true;
    let mut vc = vals.chunks_exact(LANES);
    for v8 in &mut vc {
        for (k, m) in lane_max.iter_mut().enumerate() {
            let v = v8[k];
            finite &= v.is_finite();
            *m = m.max(v.abs());
        }
    }
    for v in vc.remainder() {
        finite &= v.is_finite();
        lane_max[0] = lane_max[0].max(v.abs());
    }
    let mut max = 0.0f32;
    for m in lane_max {
        max = max.max(m);
    }
    (max, finite)
}

/// Scalar reference for [`abs_max_diff`] — the pre-kernel first pass of
/// `codec::quantize_diff_slice`, verbatim.
pub fn abs_max_diff_ref(start: &[f32], end: &[f32]) -> (f32, bool) {
    let mut finite = true;
    let mut max = 0.0f32;
    for (s, e) in start.iter().zip(end) {
        let d = s - e;
        finite &= d.is_finite();
        max = max.max(d.abs());
    }
    (max, finite)
}

/// Quantize `start - end` at a fixed `inv = 127 / max` scale into i8
/// bytes. Pure elementwise map (round, clamp, narrow) — identical in
/// any chunking; written over exact lanes so it vectorizes.
pub fn quantize_scaled(start: &[f32], end: &[f32], inv: f32, out: &mut [u8]) {
    debug_assert_eq!(start.len(), end.len());
    debug_assert_eq!(start.len(), out.len());
    let mut oc = out.chunks_exact_mut(LANES);
    let mut sc = start.chunks_exact(LANES);
    let mut ec = end.chunks_exact(LANES);
    for ((o8, s8), e8) in (&mut oc).zip(&mut sc).zip(&mut ec) {
        for (k, o) in o8.iter_mut().enumerate() {
            let q = ((s8[k] - e8[k]) * inv).round().clamp(-127.0, 127.0);
            *o = q as i8 as u8;
        }
    }
    for ((o, s), e) in
        oc.into_remainder().iter_mut().zip(sc.remainder()).zip(ec.remainder())
    {
        let q = ((s - e) * inv).round().clamp(-127.0, 127.0);
        *o = q as i8 as u8;
    }
}

/// [`quantize_scaled`] over raw values.
pub fn quantize_vals_scaled(vals: &[f32], inv: f32, out: &mut [u8]) {
    debug_assert_eq!(vals.len(), out.len());
    let mut oc = out.chunks_exact_mut(LANES);
    let mut vc = vals.chunks_exact(LANES);
    for (o8, v8) in (&mut oc).zip(&mut vc) {
        for (k, o) in o8.iter_mut().enumerate() {
            let q = (v8[k] * inv).round().clamp(-127.0, 127.0);
            *o = q as i8 as u8;
        }
    }
    for (o, v) in oc.into_remainder().iter_mut().zip(vc.remainder()) {
        let q = (v * inv).round().clamp(-127.0, 127.0);
        *o = q as i8 as u8;
    }
}

/// Scalar reference for the full diff-quantize pass (both passes,
/// serial) — the pre-kernel body of `codec::quantize_diff_slice`,
/// kept as the `q8_quantize/scalar` bench baseline and differential
/// oracle. Returns the scale.
pub fn quantize_diff_ref(start: &[f32], end: &[f32], out: &mut [u8]) -> f32 {
    let (max, finite) = abs_max_diff_ref(start, end);
    let scale = if finite { max / 127.0 } else { f32::NAN };
    if scale == 0.0 {
        out.fill(0);
        return 0.0;
    }
    let inv = 1.0 / scale;
    for ((s, e), o) in start.iter().zip(end).zip(out.iter_mut()) {
        let q = ((s - e) * inv).round().clamp(-127.0, 127.0);
        *o = q as i8 as u8;
    }
    scale
}

/// Accumulate one payload's dequantized bytes into an f64 accumulator:
/// `acc[j] += dequantize(bytes[j], scale)`. Elementwise over
/// independent outputs; the caller iterates payloads in rank order, so
/// every `acc[j]` receives its terms in exactly the order the old
/// per-element loop produced — bitwise-identical means.
pub fn dequant_accumulate(bytes: &[u8], scale: f32, acc: &mut [f64]) {
    debug_assert_eq!(bytes.len(), acc.len());
    let mut ac = acc.chunks_exact_mut(LANES);
    let mut bc = bytes.chunks_exact(LANES);
    for (a8, b8) in (&mut ac).zip(&mut bc) {
        for (k, a) in a8.iter_mut().enumerate() {
            *a += codec::dequantize_i8(b8[k], scale) as f64;
        }
    }
    for (a, b) in ac.into_remainder().iter_mut().zip(bc.remainder()) {
        *a += codec::dequantize_i8(*b, scale) as f64;
    }
}

// ---------------------------------------------------------------------------
// top-k select
// ---------------------------------------------------------------------------

/// Fill `scratch` with the local indices `0..residual.len()` of one
/// segment, partitioned so `scratch[..k]` holds the kept set (largest
/// `|value|`, ties → lowest index) sorted ascending — the packed-key
/// form of [`topk_partition_ref`].
///
/// The key `(!abs_bits << 32) | index` is a strict total order: for
/// sign-cleared f32 bit patterns `total_cmp` *is* unsigned bit
/// comparison (NaN above infinity included), so descending magnitude is
/// ascending `!abs_bits`, and the unique index tiebreak means the k
/// smallest keys are one well-defined set no matter how the partition
/// algorithm pivots. Kept set and output are therefore identical to the
/// comparator-based reference.
pub fn topk_partition(residual: &[f32], k: usize, scratch: &mut Vec<u32>) {
    debug_assert!(k >= 1 && k <= residual.len());
    scratch.clear();
    scratch.extend(0..residual.len() as u32);
    if k < scratch.len() {
        scratch.select_nth_unstable_by_key(k - 1, |i| {
            let bits = residual[*i as usize].abs().to_bits();
            ((!bits as u64) << 32) | *i as u64
        });
    }
    scratch[..k].sort_unstable();
}

/// Comparator-based reference — the pre-kernel selection from
/// `codec::topk_select_segment`, verbatim.
pub fn topk_partition_ref(residual: &[f32], k: usize, scratch: &mut Vec<u32>) {
    debug_assert!(k >= 1 && k <= residual.len());
    scratch.clear();
    scratch.extend(0..residual.len() as u32);
    if k < scratch.len() {
        scratch.select_nth_unstable_by(k - 1, |&a, &b| {
            let (ra, rb) = (residual[a as usize].abs(), residual[b as usize].abs());
            rb.total_cmp(&ra).then(a.cmp(&b))
        });
    }
    scratch[..k].sort_unstable();
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny deterministic xorshift so the differential tests need no
    /// harness plumbing (and stay miri-cheap at small sizes).
    fn xs(state: &mut u64) -> u64 {
        let mut x = *state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        *state = x;
        x
    }

    fn random_f32(state: &mut u64) -> f32 {
        // mix magnitudes, signs, zeros, and the odd special value
        match xs(state) % 16 {
            0 => 0.0,
            1 => -0.0,
            2 => f32::NAN,
            3 => f32::INFINITY,
            4 => f32::NEG_INFINITY,
            5 => 1.0e-40, // subnormal
            _ => {
                let m = (xs(state) % 2_000_000) as f32 / 1000.0 - 1000.0;
                m * 1.5
            }
        }
    }

    fn random_bytes(state: &mut u64, n: usize) -> Vec<u8> {
        (0..n).map(|_| (xs(state) & 0xFF) as u8).collect()
    }

    #[test]
    fn packed_word_matches_byte_shifts_and_zero_pads() {
        let bytes: Vec<u8> = (1..=11).collect(); // 11 bytes: one full word + 3-byte tail
        let mut w0 = 0u64;
        for (i, b) in bytes[..8].iter().enumerate() {
            w0 |= (*b as u64) << (8 * i);
        }
        assert_eq!(packed_word(&bytes, 0), w0);
        let mut w1 = 0u64;
        for (i, b) in bytes[8..].iter().enumerate() {
            w1 |= (*b as u64) << (8 * i);
        }
        assert_eq!(packed_word(&bytes, 1), w1);
        assert_eq!(packed_word(&bytes, 2), 0);
        assert_eq!(packed_word(&[], 0), 0);
    }

    #[test]
    fn tally_strip_matches_single_word_reference() {
        let mut st = 0x1234_5678_9abc_def0u64;
        for &(n_votes, n_bytes) in &[(1usize, 3usize), (5, 33), (12, 40)] {
            let votes: Vec<Vec<u8>> = (0..n_votes).map(|_| random_bytes(&mut st, n_bytes)).collect();
            let slices: Vec<&[u8]> = votes.iter().map(|v| v.as_slice()).collect();
            let levels = (64 - (n_votes as u64).leading_zeros()) as usize;
            let threshold = (n_votes / 2 + n_votes % 2) as u64;
            let n_words = n_bytes / 8 + usize::from(n_bytes % 8 != 0);
            let mut wi = 0;
            while wi < n_words {
                let strip = (n_words - wi).min(STRIP_WORDS);
                let mut winners = [0u64; STRIP_WORDS];
                tally_strip(&slices, wi, strip, levels, threshold, &mut winners);
                for (k, w) in winners.iter().enumerate().take(strip) {
                    assert_eq!(*w, tally_word_ref(&slices, wi + k, levels, threshold));
                }
                wi += strip;
            }
        }
    }

    #[test]
    #[should_panic(expected = "counter width must cover the rank count")]
    fn tally_strip_overflow_is_loud() {
        // 3 all-ones votes into a 1-level counter: lane count reaches 2.
        let v = vec![0xFFu8; 8];
        let slices: Vec<&[u8]> = vec![&v, &v, &v];
        let mut winners = [0u64; STRIP_WORDS];
        tally_strip(&slices, 0, 1, 1, 1, &mut winners);
    }

    #[test]
    fn abs_max_matches_reference_bitwise() {
        let mut st = 0xdead_beef_cafe_f00du64;
        for len in [0usize, 1, 7, 8, 9, 31, 64, 100] {
            let a: Vec<f32> = (0..len).map(|_| random_f32(&mut st)).collect();
            let b: Vec<f32> = (0..len).map(|_| random_f32(&mut st)).collect();
            let (m, f) = abs_max_diff(&a, &b);
            let (mr, fr) = abs_max_diff_ref(&a, &b);
            assert_eq!(m.to_bits(), mr.to_bits(), "len {len}");
            assert_eq!(f, fr, "len {len}");
            let zeros = vec![0.0f32; len];
            let (mv, fv) = abs_max(&a);
            let (mvr, fvr) = abs_max_diff_ref(&a, &zeros);
            assert_eq!(mv.to_bits(), mvr.to_bits(), "vals len {len}");
            assert_eq!(fv, fvr, "vals len {len}");
        }
    }

    #[test]
    fn quantize_kernels_match_reference_bitwise() {
        let mut st = 0x0bad_5eed_0bad_5eedu64;
        for len in [0usize, 1, 7, 8, 9, 31, 100] {
            let a: Vec<f32> = (0..len).map(|_| random_f32(&mut st)).collect();
            let b: Vec<f32> = (0..len).map(|_| random_f32(&mut st)).collect();
            let mut want = vec![0u8; len];
            let scale = quantize_diff_ref(&a, &b, &mut want);
            let mut got = vec![0u8; len];
            let (max, finite) = abs_max_diff(&a, &b);
            let kscale = if finite { max / 127.0 } else { f32::NAN };
            if kscale == 0.0 {
                got.fill(0);
            } else {
                quantize_scaled(&a, &b, 1.0 / kscale, &mut got);
            }
            if finite {
                assert_eq!(scale.to_bits(), kscale.to_bits(), "len {len}");
                assert_eq!(want, got, "len {len}");
            } else {
                assert!(scale.is_nan() && kscale.is_nan(), "len {len}");
                assert_eq!(want, got, "poisoned bytes, len {len}");
            }
        }
    }

    #[test]
    fn dequant_accumulate_matches_per_element_loop() {
        let mut st = 0x5151_5151_5151_5151u64;
        for len in [0usize, 1, 8, 13, 100] {
            let bytes = random_bytes(&mut st, len);
            let scale = 0.037f32;
            let mut acc = vec![1.25f64; len];
            let mut want = acc.clone();
            for (a, b) in want.iter_mut().zip(&bytes) {
                *a += codec::dequantize_i8(*b, scale) as f64;
            }
            dequant_accumulate(&bytes, scale, &mut acc);
            assert_eq!(
                want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                acc.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "len {len}"
            );
        }
    }

    #[test]
    fn topk_partition_matches_comparator_reference() {
        let mut st = 0x7777_1234_7777_1234u64;
        for len in [1usize, 5, 17, 64] {
            let residual: Vec<f32> = (0..len).map(|_| random_f32(&mut st)).collect();
            for k in [1usize, len / 2 + 1, len] {
                let mut a = Vec::new();
                let mut b = Vec::new();
                topk_partition(&residual, k, &mut a);
                topk_partition_ref(&residual, k, &mut b);
                assert_eq!(a[..k], b[..k], "len {len} k {k}");
            }
        }
    }

    #[test]
    fn topk_partition_breaks_ties_toward_low_index() {
        // all-equal magnitudes: kept set must be the k lowest indices
        let residual = vec![2.0f32, -2.0, 2.0, -2.0, 2.0];
        let mut s = Vec::new();
        topk_partition(&residual, 3, &mut s);
        assert_eq!(&s[..3], &[0, 1, 2]);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // large differential sweep; covered small above
    fn kernels_match_reference_at_scale() {
        let mut st = 0x2468_ace0_1357_9bdfu64;
        let n_votes = 129; // 8 counter levels
        let n_bytes = 4099;
        let votes: Vec<Vec<u8>> = (0..n_votes).map(|_| random_bytes(&mut st, n_bytes)).collect();
        let slices: Vec<&[u8]> = votes.iter().map(|v| v.as_slice()).collect();
        let levels = (64 - (n_votes as u64).leading_zeros()) as usize;
        let threshold = (n_votes / 2 + n_votes % 2) as u64;
        let n_words = n_bytes / 8 + usize::from(n_bytes % 8 != 0);
        let mut wi = 0;
        while wi < n_words {
            let strip = (n_words - wi).min(STRIP_WORDS);
            let mut winners = [0u64; STRIP_WORDS];
            tally_strip(&slices, wi, strip, levels, threshold, &mut winners);
            for (k, w) in winners.iter().enumerate().take(strip) {
                assert_eq!(*w, tally_word_ref(&slices, wi + k, levels, threshold));
            }
            wi += strip;
        }

        let len = 100_003;
        let a: Vec<f32> = (0..len).map(|_| random_f32(&mut st)).collect();
        let b: Vec<f32> = (0..len).map(|_| random_f32(&mut st)).collect();
        let (m, f) = abs_max_diff(&a, &b);
        let (mr, fr) = abs_max_diff_ref(&a, &b);
        assert_eq!(m.to_bits(), mr.to_bits());
        assert_eq!(f, fr);
        let finite: Vec<f32> = (0..len).map(|i| ((i * 37) % 255) as f32 - 127.0).collect();
        let zeros = vec![0.0f32; len];
        let mut want = vec![0u8; len];
        let s1 = quantize_diff_ref(&finite, &zeros, &mut want);
        let (max, ok) = abs_max_diff(&finite, &zeros);
        assert!(ok);
        let mut got = vec![0u8; len];
        quantize_scaled(&finite, &zeros, 1.0 / (max / 127.0), &mut got);
        assert_eq!(s1.to_bits(), (max / 127.0).to_bits());
        assert_eq!(want, got);

        let mut ka = Vec::new();
        let mut kb = Vec::new();
        topk_partition(&a, len / 16, &mut ka);
        topk_partition_ref(&a, len / 16, &mut kb);
        assert_eq!(ka[..len / 16], kb[..len / 16]);
    }
}
