//! The distributed-worker subsystem: simulated workers, collective
//! operations over their state, and the 1-bit sign codec that makes
//! sign-exchange methods cheap on the wire.
//!
//! # Worker lifecycle
//!
//! One [`Worker`] models one data-parallel rank of Algorithm 1. The
//! trainer drives all of them through each outer round:
//!
//! ```text
//!            Trainer::local_round  (Algorithm 1, lines 3-11, round t)
//!   ┌───────────────────────────────────────────────────────────────┐
//!   │ for each Worker i = 0..n:                                     │
//!   │     params ← x_{t,0}                 (outer.local_start)      │
//!   │     τ × { rng → sample batch                                  │
//!   │           bundle.train_step          (PJRT fwd+bwd)           │
//!   │           observe(loss, grads)       (loss acc + last_grad)   │
//!   │           opt.step(params, grads)  } (base optimizer, γ_t,k)  │
//!   │                                                               │
//!   │ collectives::allreduce_mean(workers) → x̄_{t,τ}               │
//!   │ SimClock charge: f32 payload, or packed-sign payload when the │
//!   │     outer optimizer exchanges 1-bit votes (dist::codec)       │
//!   │ outer.round(global, Δ_t)             (global sign-momentum)   │
//!   │ take_mean_loss() per worker          (round's train loss)     │
//!   └───────────────────────────────────────────────────────────────┘
//! ```
//!
//! Each worker's RNG is an independent substream of the run's root seed
//! (`root.substream("worker", i)`), so fleets rebuilt from the same root
//! are bit-identical and workers never share a stream — the
//! seed-determinism property in `rust/tests/properties.rs` guards this.
//!
//! # Collective backends
//!
//! [`collectives`] reduces worker state with a [`collectives::Backend`]:
//! `Sequential` is the bitwise reference; `Threaded` splits the *output*
//! vector into contiguous chunks across scoped OS threads, computing
//! every element with the identical worker-order arithmetic — so the two
//! backends are bitwise identical by construction (property-tested in
//! `rust/tests/collectives.rs`). `Backend::auto` picks threads only when
//! the vector is large enough to amortize spawning.
//!
//! # Compression semantics
//!
//! [`codec`] packs sign vectors at 1 bit/coordinate (32× vs f32):
//! the IEEE sign bit is kept (`+0 → +1`, `-0 → -1`), decoding always
//! yields ±1. `codec::sign_allreduce_bytes` is the wire-cost model the
//! [`crate::comm::SimClock`] charges for majority-vote exchanges.

pub mod codec;
pub mod collectives;
mod worker;

pub use collectives::Backend;
pub use worker::Worker;
