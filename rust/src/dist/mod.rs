//! The distributed-worker subsystem: simulated workers, collective
//! operations over their state, and the 1-bit sign codec that makes
//! sign-exchange methods cheap on the wire.
//!
//! # Worker lifecycle
//!
//! One [`Worker`] models one data-parallel rank of Algorithm 1. The
//! trainer drives all of them through each outer round:
//!
//! ```text
//!            Trainer::local_round  (Algorithm 1, lines 3-11, round t)
//!   ┌───────────────────────────────────────────────────────────────┐
//!   │ all Workers i = 0..n, CONCURRENTLY on the persistent pool     │
//!   │ (pool::run_indexed_mut; each job owns a disjoint &mut Worker):│
//!   │     params ← x_{t,0}                 (outer.local_start)      │
//!   │     τ × { rng → sample batch                                  │
//!   │           backend.train_step         (PJRT / native fwd+bwd)  │
//!   │           observe(loss, grads)       (loss acc + last_grad)   │
//!   │           opt.step(params, grads)  } (base optimizer, γ_t,k)  │
//!   │ join, per-rank results gathered by rank index                 │
//!   │                                                               │
//!   │ SimClock.charge_exchange(payload)    (bills wire::WirePayload │
//!   │     bytes — ring for dense f32, gather+broadcast otherwise)   │
//!   │ outer.contribute(w, view) per rank   (pack into the payload)  │
//!   │ outer.apply(global, payloads)        (global sign-momentum)   │
//!   │ take_mean_loss() per worker          (round's train loss)     │
//!   └───────────────────────────────────────────────────────────────┘
//! ```
//!
//! The fan-out is bitwise-identical to a serial loop (workers own
//! disjoint RNG substreams and optimizer state; the trainer RNG is
//! only consumed after the join) — `cfg.sequential_workers` keeps the
//! serial reference path and `rust/tests/parallel_fleet.rs` proves the
//! equivalence.
//!
//! Each worker's RNG is an independent substream of the run's root seed
//! (`root.substream("worker", i)`), so fleets rebuilt from the same root
//! are bit-identical and workers never share a stream — the
//! seed-determinism property in `rust/tests/properties.rs` guards this.
//!
//! # Collective backends
//!
//! [`collectives`] reduces worker state with a [`collectives::Backend`]:
//! `Sequential` is the bitwise reference; `Threaded` splits the *output*
//! vector into contiguous chunks executed on the persistent worker pool
//! ([`pool`], created lazily, reused for every collective), computing
//! every element with the identical worker-order arithmetic — so the two
//! backends are bitwise identical by construction (property-tested in
//! `rust/tests/collectives.rs`). `Backend::auto` picks threads only when
//! the vector is large enough to amortize the dispatch.
//!
//! # The typed wire
//!
//! [`wire`] defines the round-exchange contract: every worker→server
//! message is a [`WirePayload`] (dense f32 parameters, packed 1-bit
//! sign votes, 8-bit quantized differences, layout-aware 8-bit
//! differences with one scale per parameter segment, or DeMo-style
//! top-k sparse components of a decaying residual-momentum buffer),
//! billed by its
//! own [`WirePayload::wire_bytes`] so accounting and data path cannot
//! drift. [`codec`] holds the byte formats: sign vectors pack at
//! 1 bit/coordinate (32× vs f32), the IEEE sign bit is kept
//! (`+0 → +1`, `-0 → -1`), and decoding always yields ±1 — the wire has
//! no zero symbol, so a tied majority tally resolves to +1 everywhere;
//! the i8 formats quantize each rank's local difference against a
//! per-message scale (`q8`) or against one scale per segment of the
//! backend's validated [`crate::runtime::ParamLayout`] (`q8pt`, 4 extra
//! bytes per segment — the fix for parameter blocks whose diff
//! magnitudes differ by orders of magnitude); the top-k format
//! transmits the k largest-magnitude residual components per segment
//! as (u32 index, f32 value) pairs and banks the untransmitted mass in
//! a decaying worker-side buffer ([`codec::topk_select_segment`]).
//! [`Worker`] carries that
//! same layout, so per-segment slice views come straight off a rank
//! ([`Worker::param_segments`]). [`votes`] is the *data path* over the
//! 1-bit format: workers produce [`PackedVotes`] and the server runs
//! [`votes::majority_vote_packed`], a word-level popcount tally that
//! never unpacks to f32 and is bitwise-identical to
//! [`collectives::majority_vote`] over the decoded votes
//! (property-tested in `rust/tests/packed_vote.rs`).
//!
//! # Hot-path kernels
//!
//! [`kernels`] holds the widened inner loops behind the codec, the
//! tally, and the mean-decode paths — word-strip carry-save tallies,
//! exact-lane quantize/dequantize, packed-key top-k selection — under a
//! fixed-reduction-order contract that keeps every kernel
//! bitwise-identical to its scalar reference (differential-tested
//! there; before/after timings recorded by `benches/kernels.rs`).

pub mod codec;
pub mod collectives;
pub mod kernels;
pub mod pool;
pub mod votes;
pub mod wire;
mod worker;

pub use collectives::Backend;
pub use votes::PackedVotes;
pub use wire::{AggPolicy, WireError, WireFormat, WirePayload};
pub use worker::Worker;

/// Ceiling division shared by the wire codec and the pool chunking
/// (spelled out to stay lint- and MSRV-friendly).
pub(crate) fn div_up(a: usize, b: usize) -> usize {
    a / b + usize::from(a % b != 0)
}
