//! Persistent worker pool backing the threaded collective backend.
//!
//! `Backend::Threaded` originally spawned fresh OS threads through
//! `std::thread::scope` on every collective call; at P = 2^20+ with
//! several collectives per outer round the per-call spawn cost is a
//! measurable fraction of the reduction itself (ROADMAP follow-up (c)).
//! This module keeps a process-wide set of parked helper threads,
//! created lazily on the first threaded collective and reused for every
//! subsequent call: [`run_chunked_mut`] splits the output slice into
//! contiguous chunks and executes them on the pool, with the calling
//! thread participating — a `threads = k` request uses up to `k - 1`
//! helpers plus the caller.
//!
//! Beyond the chunked-output API, [`run_indexed_mut`] is a scoped
//! fan-out over a fleet of items: each job receives a disjoint
//! `&mut T` and its results are collected per index with a panic-safe
//! join. The trainer runs the n simulated ranks of one outer round
//! concurrently through it.
//!
//! # Determinism
//!
//! The pool decides only *which OS thread* executes a chunk. Chunk
//! boundaries are a pure function of `(len, threads, align)` and every
//! chunk's arithmetic is fixed by the caller, so results are bitwise
//! independent of scheduling — the same contract the spawn-per-call
//! implementation (kept as [`run_chunked_mut_spawn`], the benchmark
//! baseline) provided.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock, PoisonError};

use super::div_up;

/// Hard cap on pool parallelism: the collectives are memory-bound and
/// show no win past this many threads. `Backend::auto` references this
/// same constant so the auto backend never requests more threads than
/// the pool can serve.
pub const MAX_THREADS: usize = 8;

/// Opt-in rank→core pinning for the pool's helper threads (the
/// `pin_workers` config knob). When set before the pool first spawns,
/// helper `i` pins itself to CPU `i + 1` (CPU 0 is left to the calling
/// thread), which keeps each helper's chunk of the output resident in
/// one core's private cache across the many collectives of a round.
///
/// Placement only — the chunk→thread mapping was never part of any
/// result ([`run_chunked_mut`]'s determinism contract), so this cannot
/// change a trajectory, and the experiment cache key excludes it like
/// `sequential_workers`. The pool spawns lazily on first use: set this
/// before the first threaded collective (the trainer does, while
/// building a run); already-running helpers are not migrated. Best
/// effort — a refused syscall or a non-Linux host leaves threads
/// unpinned. Default off.
pub fn set_pin_workers(enabled: bool) {
    PIN_WORKERS.store(enabled, Ordering::Relaxed);
}

static PIN_WORKERS: AtomicBool = AtomicBool::new(false);

fn maybe_pin(cpu: usize) {
    if PIN_WORKERS.load(Ordering::Relaxed) {
        pin_current_thread(cpu);
    }
}

/// Best-effort affinity pin of the current thread to `cpu` via a raw
/// `sched_setaffinity` syscall — the crate vendors no libc, and the
/// two supported Linux ISAs cover every host this opt-in perf knob
/// targets. An out-of-range CPU fails the call and the thread simply
/// stays unpinned.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
fn pin_current_thread(cpu: usize) {
    let mut mask = [0u64; 16]; // 1024-bit CPU set, the kernel's default sizing
    mask[(cpu / 64) % 16] |= 1 << (cpu % 64);
    // SAFETY: sched_setaffinity(pid = 0 → current thread, cpusetsize,
    // *mask) only reads `mask` (valid for the whole call — it lives on
    // this frame) and has no other memory effects; on failure the
    // kernel leaves the thread's affinity unchanged and we ignore the
    // returned errno. Registers follow the Linux syscall ABI exactly.
    unsafe {
        #[cfg(target_arch = "x86_64")]
        core::arch::asm!(
            "syscall",
            inlateout("rax") 203usize => _, // __NR_sched_setaffinity
            in("rdi") 0usize,
            in("rsi") core::mem::size_of_val(&mask),
            in("rdx") mask.as_ptr(),
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack)
        );
        #[cfg(target_arch = "aarch64")]
        core::arch::asm!(
            "svc 0",
            in("x8") 122usize, // __NR_sched_setaffinity
            inlateout("x0") 0usize => _,
            in("x1") core::mem::size_of_val(&mask),
            in("x2") mask.as_ptr(),
            options(nostack)
        );
    }
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
fn pin_current_thread(_cpu: usize) {}

/// One in-flight pool job. Every helper that pops a copy pulls chunk
/// indices from `next` until exhausted, then reports through `pending`.
struct Shared {
    /// Chunk runner with the caller's borrow lifetime erased.
    /// [`ThreadPool::run`] blocks until `pending` reaches zero, which
    /// keeps the underlying closure alive for as long as any helper
    /// can still dereference this.
    run: &'static (dyn Fn(usize) + Sync),
    /// Next chunk index to claim (work-stealing dispenser).
    next: AtomicUsize,
    n_chunks: usize,
    /// Helpers that were handed a copy and have not finished yet.
    pending: Mutex<usize>,
    done: Condvar,
    /// A helper's chunk panicked; the caller re-raises after the join.
    panicked: AtomicBool,
}

/// Blocks until every helper signed off — also during a panic unwind,
/// because the lifetime-erased closure must outlive all helpers (the
/// same join-on-unwind contract `std::thread::scope` provides).
struct WaitGuard<'a>(&'a Shared);

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        // Tolerate a poisoned lock instead of panicking: this drop also
        // runs during an unwind (a second panic would abort), and a
        // helper that panicked mid-chunk already reports through
        // `panicked`. The guarded state is a plain countdown counter.
        let mut pending = self.0.pending.lock().unwrap_or_else(PoisonError::into_inner);
        while *pending > 0 {
            pending = self.0.done.wait(pending).unwrap_or_else(PoisonError::into_inner);
        }
    }
}

struct Injector {
    jobs: Mutex<Vec<Arc<Shared>>>,
    available: Condvar,
}

/// The process-wide pool: parked helpers plus a job queue.
pub struct ThreadPool {
    queue: Arc<Injector>,
    helpers: usize,
}

thread_local! {
    /// Set inside pool helpers: nested `run` calls execute inline
    /// instead of re-entering the queue.
    static IS_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();

/// The process-wide pool, created lazily on first use.
pub fn global() -> &'static ThreadPool {
    GLOBAL.get_or_init(ThreadPool::with_default_size)
}

impl ThreadPool {
    fn with_default_size() -> ThreadPool {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(cores.min(MAX_THREADS).saturating_sub(1))
    }

    /// Pool with `helpers` parked worker threads (callers participate in
    /// their own jobs, so peak parallelism is `helpers + 1`).
    fn new(helpers: usize) -> ThreadPool {
        let queue =
            Arc::new(Injector { jobs: Mutex::new(Vec::new()), available: Condvar::new() });
        // Count the helpers that actually came up: if the OS refuses a
        // thread (resource exhaustion) the pool degrades to fewer
        // helpers — with zero, `run` executes everything inline.
        let mut spawned = 0;
        for i in 0..helpers {
            let q = Arc::clone(&queue);
            let helper = std::thread::Builder::new()
                .name("dsm-collective".into())
                .spawn(move || {
                    // CPU 0 stays with the calling thread, which always
                    // participates in its own jobs.
                    maybe_pin(i + 1);
                    helper_loop(&q)
                });
            if helper.is_ok() {
                spawned += 1;
            }
        }
        ThreadPool { queue, helpers: spawned }
    }

    /// Parked helper threads (0 on single-core hosts: [`ThreadPool::run`]
    /// then executes inline).
    pub fn helpers(&self) -> usize {
        self.helpers
    }

    /// Execute `job(chunk_index)` for every index in `0..n_chunks`,
    /// blocking until all chunks complete. Chunks run concurrently on up
    /// to `helpers + 1` threads; the chunk→thread mapping is
    /// unspecified, so chunks must be mutually independent.
    pub fn run<F: Fn(usize) + Sync>(&self, n_chunks: usize, job: F) {
        let inline =
            self.helpers == 0 || n_chunks <= 1 || IS_POOL_WORKER.with(|w| w.get());
        if inline {
            for i in 0..n_chunks {
                job(i);
            }
            return;
        }
        let run_ref: &(dyn Fn(usize) + Sync) = &job;
        // SAFETY: lifetime erasure only. The wait on `pending` below
        // does not return until every helper that received a copy of
        // this job has finished running it, so the borrow of `job`
        // outlives every dereference.
        let run_static: &'static (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute(run_ref) };
        let copies = self.helpers.min(n_chunks - 1);
        let shared = Arc::new(Shared {
            run: run_static,
            next: AtomicUsize::new(0),
            n_chunks,
            pending: Mutex::new(copies),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        {
            let mut jobs = self.queue.jobs.lock().unwrap_or_else(PoisonError::into_inner);
            for _ in 0..copies {
                jobs.push(Arc::clone(&shared));
            }
        }
        self.queue.available.notify_all();
        // The caller works too: by the time the helpers wake it may
        // already have drained everything — they then just sign off.
        let guard = WaitGuard(&shared);
        drain(&shared);
        drop(guard);
        if shared.panicked.load(Ordering::Relaxed) {
            panic!("a collective pool chunk panicked on a helper thread");
        }
    }
}

/// Claim and run chunks until the dispenser is exhausted.
fn drain(shared: &Shared) {
    loop {
        let i = shared.next.fetch_add(1, Ordering::Relaxed);
        if i >= shared.n_chunks {
            return;
        }
        (shared.run)(i);
    }
}

fn helper_loop(queue: &Injector) {
    IS_POOL_WORKER.with(|w| w.set(true));
    loop {
        let job = {
            let mut jobs = queue.jobs.lock().unwrap_or_else(PoisonError::into_inner);
            loop {
                if let Some(job) = jobs.pop() {
                    break job;
                }
                jobs = queue.available.wait(jobs).unwrap_or_else(PoisonError::into_inner);
            }
        };
        let result =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| drain(&job)));
        if result.is_err() {
            job.panicked.store(true, Ordering::Relaxed);
        }
        // The unlock ordering makes the chunk writes (and the panic
        // flag) visible to the caller before its wait observes zero.
        let mut pending = job.pending.lock().unwrap_or_else(PoisonError::into_inner);
        *pending -= 1;
        if *pending == 0 {
            job.done.notify_all();
        }
    }
}

/// Raw output pointer crossing the closure boundary; sound because each
/// chunk index owns exactly one disjoint sub-slice.
#[derive(Clone, Copy)]
struct OutPtr(*mut f32);
// SAFETY: the pointer is only dereferenced through per-chunk disjoint
// sub-slices (one chunk index per thread), so moving it across threads
// cannot create aliasing writes.
unsafe impl Send for OutPtr {}
// SAFETY: shared access copies the pointer value; all writes go through
// the disjoint chunk windows described on `Send`.
unsafe impl Sync for OutPtr {}

/// A chunking decision: `n_chunks` contiguous windows of `chunk`
/// elements each (the last one shorter). Pure in `(len, threads,
/// align)`, so chunk boundaries — and therefore every result — are
/// independent of the host's scheduling.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct Plan {
    pub chunk: usize,
    pub n_chunks: usize,
}

impl Plan {
    /// Everything fits in one window — the runners execute inline.
    fn is_inline(&self) -> bool {
        self.n_chunks <= 1
    }
}

/// Deterministic chunk sizing with the tiny-input clamp made explicit:
/// the worker count never exceeds the element count (`len <
/// threads` collapses to `len` single-element chunks, `len == 0` to
/// one inline empty window), so **no plan ever contains an empty
/// chunk** — pinned by `plan_never_emits_empty_chunks`.
pub(crate) fn plan(len: usize, threads: usize, align: usize) -> Plan {
    let threads = threads.clamp(1, len.max(1));
    let mut chunk = div_up(len, threads);
    if align > 1 {
        chunk = div_up(chunk, align) * align;
    }
    if chunk == 0 || chunk >= len {
        return Plan { chunk: len, n_chunks: 1 };
    }
    Plan { chunk, n_chunks: div_up(len, chunk) }
}

/// Split `out` into contiguous chunks — one per requested thread,
/// lengths rounded up to a multiple of `align` — and run
/// `body(base_index, chunk)` over them on the global pool. `align = 1`
/// reproduces the historical chunking of the f32 collectives; the
/// packed vote tally passes 64 so no u64 tally word straddles chunks.
pub fn run_chunked_mut<F>(threads: usize, align: usize, out: &mut [f32], body: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let len = out.len();
    let p = plan(len, threads, align);
    if threads <= 1 || p.is_inline() {
        body(0, out);
        return;
    }
    let chunk = p.chunk;
    let ptr = OutPtr(out.as_mut_ptr());
    let body = &body;
    global().run(p.n_chunks, move |ci| {
        let start = ci * chunk;
        let end = (start + chunk).min(len);
        // SAFETY: chunk ranges [start, end) are disjoint across `ci`
        // and stay within `out`'s bounds.
        let window =
            unsafe { std::slice::from_raw_parts_mut(ptr.0.add(start), end - start) };
        body(start, window);
    });
}

/// [`run_chunked_mut`] with chunk boundaries snapped to *layout
/// segment ends* instead of a flat element count: `bounds` holds the
/// cumulative end offset of each segment (ascending, last one equal to
/// `out.len()`), and every chunk covers a whole number of segments —
/// so a per-segment decode (one scale per `q8pt` segment, say) never
/// straddles two threads and each segment's bytes stream through one
/// core's cache. Boundaries are a pure function of `(bounds, threads)`;
/// determinism is exactly [`run_chunked_mut`]'s.
pub fn run_segmented_mut<F>(threads: usize, bounds: &[usize], out: &mut [f32], body: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let len = out.len();
    debug_assert_eq!(bounds.last().copied().unwrap_or(0), len, "segment bounds cover the output");
    let threads = threads.clamp(1, len.max(1));
    // greedy: close a chunk at the first segment end at or past the
    // even-split target — tiny segments coalesce, huge segments become
    // one chunk each, and no chunk is ever empty
    let target = div_up(len, threads);
    let mut cuts: Vec<usize> = Vec::with_capacity(threads + 1);
    cuts.push(0);
    let mut start = 0usize;
    for &b in bounds {
        if b < len && b.saturating_sub(start) >= target {
            cuts.push(b);
            start = b;
        }
    }
    cuts.push(len);
    if threads <= 1 || cuts.len() <= 2 {
        body(0, out);
        return;
    }
    let ptr = OutPtr(out.as_mut_ptr());
    let body = &body;
    let cuts = &cuts;
    global().run(cuts.len() - 1, move |ci| {
        let (start, end) = (cuts[ci], cuts[ci + 1]);
        // SAFETY: the cut list is strictly ascending and ends at `len`,
        // so [start, end) windows are disjoint across `ci` and in
        // bounds.
        let window =
            unsafe { std::slice::from_raw_parts_mut(ptr.0.add(start), end - start) };
        body(start, window);
    });
}

/// Raw item/result-slot pointer crossing the closure boundary; sound
/// because the pool's dispenser hands each index to exactly one thread,
/// so every slot is touched by at most one job.
struct SlotPtr<T>(*mut T);
// SAFETY: each index is dispensed to exactly one thread, so the slot at
// any offset is touched by at most one job; T itself must be Send for
// the value to land on another thread.
unsafe impl<T: Send> Send for SlotPtr<T> {}
// SAFETY: shared access copies the pointer value; all writes go through
// the per-index disjoint slots described on `Send`.
unsafe impl<T: Send> Sync for SlotPtr<T> {}

/// Scoped fan-out over a fleet of worker-like items: execute
/// `job(i, &mut items[i])` for every index concurrently on the global
/// pool (the caller participates) and return the results in index
/// order. This is the API the trainer uses to run all n simulated
/// ranks of one outer round in parallel — each job owns a disjoint
/// `&mut T`, so no locking is involved and the per-item arithmetic is
/// exactly what a sequential loop would compute.
///
/// # Panic safety
///
/// If a job panics on a helper thread the remaining jobs still run,
/// every helper signs off (the same join-on-unwind contract as
/// [`run_chunked_mut`]), and the panic is re-raised on the calling
/// thread; the pool itself is not poisoned and stays usable.
pub fn run_indexed_mut<T, R, F>(items: &mut [T], job: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut T) -> R + Sync,
{
    let n = items.len();
    let mut results: Vec<Option<R>> = Vec::with_capacity(n);
    results.resize_with(n, || None);
    let item_ptr = SlotPtr(items.as_mut_ptr());
    let slot_ptr = SlotPtr(results.as_mut_ptr());
    global().run(n, move |i| {
        // SAFETY: the dispenser yields each index exactly once, so the
        // item and result slot at `i` are accessed by one thread only,
        // and both stay in bounds (i < n). The caller's `run` blocks
        // until every helper finished, keeping both borrows alive.
        let item = unsafe { &mut *item_ptr.0.add(i) };
        let out = job(i, item);
        unsafe { *slot_ptr.0.add(i) = Some(out) };
    });
    results
        .into_iter()
        .map(|r| match r {
            Some(v) => v,
            None => unreachable!("the pool dispenser yields every job index exactly once"),
        })
        .collect()
}

/// Read-only sibling of [`run_indexed_mut`]: execute `job(i, &items[i])`
/// for every index concurrently on the global pool (the caller
/// participates) and return the results in index order. The trainer
/// fans the validation batches of one eval pass across the pool with
/// this — each job only reads shared state (backend, params, batch), so
/// no `&mut` fleet is needed.
///
/// Determinism and panic safety match [`run_indexed_mut`]: each index
/// runs exactly once on some thread, results are gathered by index (so
/// any order-sensitive reduction the caller does afterwards sees the
/// sequential order), and a panicking job re-raises on the caller after
/// a full join.
pub fn run_indexed<T, R, F>(items: &[T], job: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    let mut results: Vec<Option<R>> = Vec::with_capacity(n);
    results.resize_with(n, || None);
    let slot_ptr = SlotPtr(results.as_mut_ptr());
    global().run(n, move |i| {
        // SAFETY: the dispenser yields each index exactly once, so the
        // result slot at `i` is written by one thread only and stays in
        // bounds (i < n); the caller's `run` blocks until every helper
        // finished, keeping the borrow alive.
        let out = job(i, &items[i]);
        unsafe { *slot_ptr.0.add(i) = Some(out) };
    });
    results
        .into_iter()
        .map(|r| match r {
            Some(v) => v,
            None => unreachable!("the pool dispenser yields every job index exactly once"),
        })
        .collect()
}

/// The pre-pool implementation — scoped threads spawned on every call —
/// kept only as the benchmark baseline so `benches/collectives.rs` can
/// quantify the pool's win.
pub fn run_chunked_mut_spawn<F>(threads: usize, align: usize, out: &mut [f32], body: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let len = out.len();
    let p = plan(len, threads, align);
    if threads <= 1 || p.is_inline() {
        body(0, out);
        return;
    }
    let chunk = p.chunk;
    let body = &body;
    std::thread::scope(|scope| {
        for (ci, window) in out.chunks_mut(chunk).enumerate() {
            scope.spawn(move || body(ci * chunk, window));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_covers_every_chunk_exactly_once() {
        let hits: Vec<AtomicUsize> = (0..23).map(|_| AtomicUsize::new(0)).collect();
        global().run(hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "chunk {i}");
        }
    }

    #[test]
    fn chunked_writes_match_inline_execution() {
        for (len, threads, align) in
            [(1usize, 4usize, 1usize), (100, 3, 1), (1000, 7, 64), (64, 2, 64), (130, 16, 64)]
        {
            let fill = |base: usize, chunk: &mut [f32]| {
                for (j, o) in chunk.iter_mut().enumerate() {
                    *o = (base + j) as f32 * 0.5;
                }
            };
            let mut pooled = vec![0.0f32; len];
            run_chunked_mut(threads, align, &mut pooled, fill);
            let mut spawned = vec![0.0f32; len];
            run_chunked_mut_spawn(threads, align, &mut spawned, fill);
            let mut inline = vec![0.0f32; len];
            fill(0, &mut inline);
            assert_eq!(pooled, inline, "pool: len={len} threads={threads} align={align}");
            assert_eq!(spawned, inline, "spawn: len={len} threads={threads} align={align}");
        }
    }

    #[test]
    fn plan_never_emits_empty_chunks() {
        // tiny-input regression: len < threads must collapse to fewer
        // chunks, never to empty jobs, for both flat and aligned sizing
        for len in [0usize, 1, 3, 7, 64, 65, 1000] {
            for threads in [1usize, 2, 4, 8, 16] {
                for align in [1usize, 64] {
                    let p = plan(len, threads, align);
                    assert!(p.n_chunks >= 1, "len={len} threads={threads} align={align}");
                    if len == 0 {
                        assert!(p.is_inline());
                        continue;
                    }
                    // every chunk window is non-empty...
                    assert!(
                        p.chunk * (p.n_chunks - 1) < len,
                        "empty tail chunk: len={len} threads={threads} align={align} {p:?}"
                    );
                    // ...and the windows tile the whole output
                    assert!(
                        p.chunk * p.n_chunks >= len,
                        "uncovered tail: len={len} threads={threads} align={align} {p:?}"
                    );
                    if align > 1 && !p.is_inline() {
                        assert_eq!(p.chunk % align, 0);
                    }
                }
            }
        }
    }

    #[test]
    fn tiny_inputs_match_inline_execution() {
        // len in {0, 1, threads - 1}: the historical trouble spots for
        // per-thread chunk sizing
        let threads = 4;
        for len in [0usize, 1, threads - 1] {
            let fill = |base: usize, chunk: &mut [f32]| {
                for (j, o) in chunk.iter_mut().enumerate() {
                    *o = (base + j) as f32 + 1.0;
                }
            };
            let mut pooled = vec![0.0f32; len];
            run_chunked_mut(threads, 1, &mut pooled, fill);
            let mut inline = vec![0.0f32; len];
            fill(0, &mut inline);
            assert_eq!(pooled, inline, "len={len}");
        }
    }

    #[test]
    fn segmented_writes_match_inline_and_respect_bounds() {
        let bounds = [3usize, 10, 11, 300, 1000];
        let len = *bounds.last().unwrap();
        let fill = |base: usize, chunk: &mut [f32]| {
            for (j, o) in chunk.iter_mut().enumerate() {
                *o = (base + j) as f32 * 0.25;
            }
        };
        for threads in [1usize, 2, 4, 8] {
            let mut pooled = vec![0.0f32; len];
            run_segmented_mut(threads, &bounds, &mut pooled, fill);
            let mut inline = vec![0.0f32; len];
            fill(0, &mut inline);
            assert_eq!(pooled, inline, "threads={threads}");
        }
        // every chunk must start on a segment boundary (or 0)
        let bases = Mutex::new(Vec::new());
        let mut out = vec![0.0f32; len];
        run_segmented_mut(4, &bounds, &mut out, |base, _| bases.lock().unwrap().push(base));
        for base in bases.into_inner().unwrap() {
            assert!(
                base == 0 || bounds.contains(&base),
                "chunk base {base} is not a segment boundary"
            );
        }
    }

    #[test]
    fn segmented_handles_degenerate_inputs() {
        let fill = |base: usize, chunk: &mut [f32]| {
            for (j, o) in chunk.iter_mut().enumerate() {
                *o = (base + j) as f32 + 2.0;
            }
        };
        // empty output with no segments
        let mut none: Vec<f32> = Vec::new();
        run_segmented_mut(4, &[], &mut none, fill);
        // one giant segment: must run inline as a single window
        let mut one = vec![0.0f32; 257];
        run_segmented_mut(4, &[257], &mut one, fill);
        let mut inline = vec![0.0f32; 257];
        fill(0, &mut inline);
        assert_eq!(one, inline);
    }

    #[cfg(all(
        not(miri),
        target_os = "linux",
        any(target_arch = "x86_64", target_arch = "aarch64")
    ))]
    #[test]
    fn pin_current_thread_is_best_effort_safe() {
        // smoke test for the raw syscall wrapper: pinning the test
        // thread to CPU 0 (always present) and to an absurd CPU id must
        // both return without fault — the latter is simply refused by
        // the kernel.
        pin_current_thread(0);
        pin_current_thread(10_000);
    }
        let mut out = vec![0.0f32; 1000];
        let bases = Mutex::new(Vec::new());
        run_chunked_mut(7, 64, &mut out, |base, _| bases.lock().unwrap().push(base));
        for base in bases.into_inner().unwrap() {
            assert_eq!(base % 64, 0, "chunk base {base} not 64-aligned");
        }
    }

    #[test]
    fn nested_run_does_not_deadlock() {
        let total = AtomicUsize::new(0);
        global().run(4, |_| {
            global().run(3, |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 12);
    }

    #[test]
    fn run_indexed_collects_results_in_index_order() {
        let mut items: Vec<u64> = (0..37).collect();
        let doubled = run_indexed_mut(&mut items, |i, x| {
            *x += 1;
            (i as u64, *x * 2)
        });
        for (i, (idx, d)) in doubled.iter().enumerate() {
            assert_eq!(*idx, i as u64, "result {i} out of order");
            assert_eq!(*d, (i as u64 + 1) * 2);
        }
        assert_eq!(items[0], 1);
        assert_eq!(items[36], 37);
    }

    #[test]
    fn run_indexed_matches_sequential_loop() {
        let job = |i: usize, x: &mut f64| {
            *x = (*x + i as f64).sqrt();
            *x * 3.0
        };
        let mut par: Vec<f64> = (0..23).map(|i| i as f64 * 0.7).collect();
        let mut seq = par.clone();
        let rp = run_indexed_mut(&mut par, job);
        let rs: Vec<f64> = seq.iter_mut().enumerate().map(|(i, x)| job(i, x)).collect();
        assert_eq!(par, seq);
        for (a, b) in rp.iter().zip(&rs) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn run_indexed_readonly_matches_sequential_map() {
        let items: Vec<f64> = (0..29).map(|i| i as f64 * 1.3).collect();
        let job = |i: usize, x: &f64| (x + i as f64).sqrt();
        let pooled = run_indexed(&items, job);
        let serial: Vec<f64> = items.iter().enumerate().map(|(i, x)| job(i, x)).collect();
        assert_eq!(pooled.len(), serial.len());
        for (a, b) in pooled.iter().zip(&serial) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(run_indexed(&Vec::<u8>::new(), |_, _| 0).is_empty());
    }

    #[test]
    fn run_indexed_handles_empty_and_single() {
        let mut none: Vec<u8> = Vec::new();
        assert!(run_indexed_mut(&mut none, |_, _| 1).is_empty());
        let mut one = vec![5u8];
        assert_eq!(run_indexed_mut(&mut one, |_, x| *x as usize + 1), vec![6]);
    }

    #[test]
    fn run_indexed_panic_does_not_deadlock_or_poison_the_pool() {
        // mirror of the run_chunked_mut panic-safety contract: one rank's
        // job panicking must re-raise on the caller after a full join...
        let mut items = vec![0u32; 16];
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_indexed_mut(&mut items, |i, x| {
                if i == 7 {
                    panic!("rank 7 exploded");
                }
                *x = i as u32;
                i
            });
        }));
        assert!(caught.is_err(), "the job panic must surface to the caller");
        // ...and the pool must stay fully usable afterwards.
        let mut again = vec![0u32; 16];
        let results = run_indexed_mut(&mut again, |i, x| {
            *x = i as u32 + 1;
            i + 1
        });
        assert_eq!(results, (1..=16).collect::<Vec<_>>());
        assert_eq!(again[15], 16);
        let mut out = vec![0.0f32; 512];
        run_chunked_mut(4, 1, &mut out, |base, chunk| {
            for (j, o) in chunk.iter_mut().enumerate() {
                *o = (base + j) as f32;
            }
        });
        assert_eq!(out[511], 511.0);
    }

    #[test]
    fn pool_is_reused_across_many_calls() {
        // regression guard for the spawn-per-call behavior: hammering
        // the pool must not accumulate threads or leak jobs
        let mut out = vec![0.0f32; 4096];
        for round in 0..200 {
            let r = round as f32;
            run_chunked_mut(4, 1, &mut out, |base, chunk| {
                for (j, o) in chunk.iter_mut().enumerate() {
                    *o = r + (base + j) as f32;
                }
            });
        }
        assert_eq!(out[0], 199.0);
        assert_eq!(out[4095], 199.0 + 4095.0);
    }
}
