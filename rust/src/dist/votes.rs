//! Packed 1-bit vote buffers and the word-level majority tally — the
//! *data path* of sign-compressed collectives (the codec in
//! [`super::codec`] defines the wire format; this module actually moves
//! and tallies the packed bytes).
//!
//! # Wire protocol
//!
//! Each worker packs its randomized-sign vote vector with
//! [`codec::pack_signs`] (1 bit per coordinate, little-endian bit
//! order, plus the fixed [`codec::HEADER_BYTES`] frame) and ships the
//! resulting [`PackedVotes`] to the server. The server never unpacks:
//! [`majority_vote_packed`] tallies per-coordinate set-bit counts
//! across ranks directly on the `u64` words of the payload with a
//! bit-sliced carry-save adder, and a coordinate decodes to `+1` iff
//! at least half the ranks set its bit (`2·count ≥ n`). Ties — possible
//! only for even worker counts — decode to `+1`, exactly like
//! [`super::collectives::majority_vote`] over the unpacked ±1 votes:
//! the two tallies are bitwise-identical by construction, which
//! `rust/tests/packed_vote.rs` property-tests across backends.

use super::codec;
use super::collectives::Backend;
use super::kernels;
use super::pool;

/// One worker's sign votes, packed at 1 bit/coordinate — exactly the
/// bytes that cross the simulated wire (plus the fixed length header
/// accounted by [`PackedVotes::wire_bytes`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedVotes {
    bytes: Vec<u8>,
    len: usize,
}

impl PackedVotes {
    /// Pack the sign bit of every coordinate ([`codec::pack_signs`]).
    /// Note the 1-bit wire has no zero symbol: ±0.0 votes encode their
    /// IEEE sign and decode to ±1.
    pub fn pack(votes: &[f32]) -> PackedVotes {
        PackedVotes { bytes: codec::pack_signs(votes), len: votes.len() }
    }

    /// A zero-coordinate placeholder — the initial state of persistent
    /// per-rank vote buffers before their first [`pack_into`](Self::pack_into).
    pub fn empty() -> PackedVotes {
        PackedVotes { bytes: Vec::new(), len: 0 }
    }

    /// A sized all-clear buffer of `len` coordinates (every vote −1):
    /// the initial state of the trainer's persistent payload buffers.
    /// Its [`wire_bytes`](Self::wire_bytes) is already the final round
    /// cost — the byte count depends only on `len`, so the clock can
    /// bill an exchange before the ranks re-pack the buffer.
    pub fn with_len(len: usize) -> PackedVotes {
        PackedVotes { bytes: vec![0; codec::packed_len(len)], len }
    }

    /// Re-pack in place, reusing this buffer's allocation
    /// ([`codec::pack_signs_into`]). Persistent per-rank buffers call
    /// this every round, so the steady-state packed data path allocates
    /// nothing.
    pub fn pack_into(&mut self, votes: &[f32]) {
        codec::pack_signs_into(votes, &mut self.bytes);
        self.len = votes.len();
    }

    /// Adopt an already-packed payload of `len` coordinates.
    pub fn from_bytes(bytes: Vec<u8>, len: usize) -> PackedVotes {
        assert_eq!(
            bytes.len(),
            codec::packed_len(len),
            "payload is {} bytes, {} coordinates need {}",
            bytes.len(),
            len,
            codec::packed_len(len)
        );
        PackedVotes { bytes, len }
    }

    /// Number of vote coordinates.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The packed payload (⌈len/8⌉ bytes).
    pub fn as_bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// Total bytes this vote message puts on the wire: payload plus the
    /// fixed header ([`codec::sign_allreduce_bytes`]).
    pub fn wire_bytes(&self) -> u64 {
        codec::sign_allreduce_bytes(self.len)
    }

    /// Decode back to ±1.0 f32 votes (reference/debug path only — the
    /// tally itself never unpacks).
    pub fn unpack(&self) -> Vec<f32> {
        codec::unpack_signs(&self.bytes, self.len)
    }

    /// Flip one coordinate's vote bit in place — the fault injector's
    /// model of a corrupted sign word in transit. Every bit pattern is
    /// a valid vote payload, so a flipped bit is *survived* (one wrong
    /// vote entering the majority) rather than rejected.
    pub fn flip_bit(&mut self, coord: usize) {
        assert!(coord < self.len, "flip_bit: coordinate {coord} of {}", self.len);
        self.bytes[coord / 8] ^= 1 << (coord % 8);
    }

    /// Flip every vote in place — the `sign_flip` Byzantine attack on
    /// the 1-bit wire. Tail bits past `len` in the last byte stay
    /// clear, so a double flip restores the exact byte payload.
    pub fn flip_all(&mut self) {
        for b in &mut self.bytes {
            *b = !*b;
        }
        self.mask_tail();
    }

    /// Overwrite every vote with `+1` (`positive`) or `-1` — the
    /// `collude_fixed` Byzantine attack: colluding ranks all push the
    /// identical direction on every coordinate.
    pub fn set_all(&mut self, positive: bool) {
        let fill = if positive { 0xFFu8 } else { 0x00 };
        self.bytes.fill(fill);
        self.mask_tail();
    }

    /// Fraction of coordinates whose vote sign matches the IEEE sign of
    /// `reference` (a set bit is `+1`; `reference[i] = +0.0` counts as
    /// positive, matching the codec's no-zero-symbol convention). The
    /// reputation supervisor scores each rank's votes against the
    /// direction the round actually applied.
    pub fn agreement(&self, reference: &[f32]) -> f64 {
        assert_eq!(reference.len(), self.len, "agreement: reference length");
        if self.len == 0 {
            return 1.0;
        }
        let mut matches = 0usize;
        for (i, r) in reference.iter().enumerate() {
            let vote_positive = (self.bytes[i / 8] >> (i % 8)) & 1 == 1;
            if vote_positive == r.is_sign_positive() {
                matches += 1;
            }
        }
        matches as f64 / self.len as f64
    }

    /// Clear the unused bits of the last byte so whole-payload edits
    /// keep the `pack`-produced invariant (tail bits are zero).
    fn mask_tail(&mut self) {
        let tail = self.len % 8;
        if tail != 0 {
            if let Some(last) = self.bytes.last_mut() {
                *last &= (1u8 << tail) - 1;
            }
        }
    }

    /// The 64 coordinates starting at `w * 64` as one little-endian
    /// word (bit `b` = coordinate `w*64 + b`), zero-padded past the
    /// end of the payload. The live tally reads words straight off
    /// [`Self::as_bytes`] via `kernels::packed_word` (same semantics,
    /// no per-word copy); this stays as the tests' reference accessor.
    #[cfg(test)]
    fn word(&self, w: usize) -> u64 {
        let start = w * 8;
        if start >= self.bytes.len() {
            return 0;
        }
        let end = (start + 8).min(self.bytes.len());
        let mut buf = [0u8; 8];
        buf[..end - start].copy_from_slice(&self.bytes[start..end]);
        u64::from_le_bytes(buf)
    }
}

/// Add one rank's vote word into the bit-sliced per-lane counters:
/// `counts[k]` holds bit `k` of every lane's running set-bit count, so
/// adding a word is a 64-lane ripple-carry increment in a handful of
/// bitwise ops instead of 64 scalar adds.
///
/// Returns the carry out of the top counter bit: nonzero iff some
/// lane's count overflowed the counter width, in which lanes the
/// counters now hold a silently wrapped count. Callers must treat a
/// nonzero return as a sizing bug — the tally ORs the carries across
/// ranks and asserts zero in release builds too, because a wrapped
/// lane would flip majorities without any other symptom.
///
/// The live tally now runs the four-word strip form of this adder
/// ([`kernels::tally_strip`], bitwise-identical per word); this
/// single-word original stays as the tests' reference.
#[cfg(test)]
#[must_use]
fn add_word(counts: &mut [u64], word: u64) -> u64 {
    let mut carry = word;
    for c in counts.iter_mut() {
        if carry == 0 {
            return 0;
        }
        let t = *c & carry;
        *c ^= carry;
        carry = t;
    }
    carry
}

/// Per-lane `count >= t` over the bit-sliced counters: bit `b` of the
/// result is set iff lane `b`'s count is at least `t` (MSB-down
/// comparison against the broadcast constant). Reference twin of the
/// strip-layout comparator inside [`kernels::tally_strip`].
#[cfg(test)]
fn lanes_ge(counts: &[u64], t: u64) -> u64 {
    let mut ge = 0u64;
    let mut eq = !0u64;
    for (k, &c) in counts.iter().enumerate().rev() {
        let tk = if (t >> k) & 1 == 1 { !0u64 } else { 0 };
        ge |= eq & c & !tk;
        eq &= !(c ^ tk);
    }
    ge | eq
}

/// Element-wise sign majority over packed vote payloads, auto-picking a
/// backend. The output is always ±1 with ties decoding to +1 — see the
/// module docs; bitwise-identical to running
/// [`super::collectives::majority_vote`] on the unpacked votes.
///
/// Generic over owned buffers and references (`&[PackedVotes]` or
/// `&[&PackedVotes]`): the server-side tally borrows the trainer's
/// persistent [`super::wire::WirePayload`] buffers without copying.
pub fn majority_vote_packed<V: std::borrow::Borrow<PackedVotes> + Sync>(
    votes: &[V],
    out: &mut [f32],
) {
    majority_vote_packed_with(Backend::auto(out.len()), votes, out)
}

/// [`majority_vote_packed`] with an explicit [`Backend`].
pub fn majority_vote_packed_with<V: std::borrow::Borrow<PackedVotes> + Sync>(
    backend: Backend,
    votes: &[V],
    out: &mut [f32],
) {
    assert!(!votes.is_empty(), "majority vote over zero workers");
    for (i, v) in votes.iter().enumerate() {
        assert_eq!(
            v.borrow().len(),
            out.len(),
            "worker {i}: vote length {} != output {}",
            v.borrow().len(),
            out.len()
        );
    }
    let n = votes.len();
    // bits needed to hold a set-bit count in 0..=n
    let levels = (64 - (n as u64).leading_zeros()) as usize;
    // 2·count ≥ n  ⇔  count ≥ ⌈n/2⌉ (ties, even n only, decode +1)
    let threshold = (n / 2 + n % 2) as u64;
    let threads = match backend {
        Backend::Sequential => 1,
        Backend::Threaded { threads } => threads,
    };
    // Hoist every payload's byte slice once: the strip kernel loads
    // tally words straight off these borrows (no per-word
    // bounds-checked copy through `PackedVotes`), four words — 256
    // lanes — per pass, with one independent carry chain per word.
    // Bitwise-identical to the single-word reference tally
    // (differential-tested in `kernels`), and the kernel asserts the
    // same counter-overflow condition in release builds too: a silent
    // wrap would flip majorities without any other symptom.
    let slices: Vec<&[u8]> = votes.iter().map(|v| v.borrow().as_bytes()).collect();
    let slices = &slices;
    // align 64 so every u64 tally word lives in exactly one chunk
    pool::run_chunked_mut(threads, 64, out, |base, chunk| {
        debug_assert_eq!(base % 64, 0);
        let mut winners = [0u64; kernels::STRIP_WORDS];
        let mut done = 0;
        while done < chunk.len() {
            let strip = super::div_up(chunk.len() - done, 64).min(kernels::STRIP_WORDS);
            kernels::tally_strip(slices, (base + done) / 64, strip, levels, threshold, &mut winners);
            for w in winners.iter().take(strip) {
                let lanes = (chunk.len() - done).min(64);
                for (b, o) in chunk[done..done + lanes].iter_mut().enumerate() {
                    *o = if (*w >> b) & 1 == 1 { 1.0 } else { -1.0 };
                }
                done += lanes;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::super::collectives;
    use super::*;

    fn reference(votes: &[PackedVotes]) -> Vec<f32> {
        let unpacked: Vec<Vec<f32>> = votes.iter().map(|v| v.unpack()).collect();
        let mut out = vec![0.0f32; votes[0].len()];
        collectives::majority_vote_with(Backend::Sequential, &unpacked, &mut out);
        out
    }

    #[test]
    fn pack_roundtrips_through_unpack() {
        let v = vec![3.5f32, -0.25, 0.0, -0.0, 1e-30, -1e30];
        let p = PackedVotes::pack(&v);
        assert_eq!(p.len(), 6);
        assert_eq!(p.unpack(), vec![1.0, -1.0, 1.0, -1.0, 1.0, -1.0]);
        assert_eq!(p.as_bytes().len(), codec::packed_len(6));
        assert_eq!(p.wire_bytes(), codec::sign_allreduce_bytes(6));
    }

    #[test]
    fn pack_into_reuses_the_buffer_and_matches_pack() {
        let mut buf = PackedVotes::empty();
        assert!(buf.is_empty());
        for len in [5usize, 130, 64, 7] {
            let v: Vec<f32> =
                (0..len).map(|i| if i % 3 == 0 { 1.0 } else { -1.0 }).collect();
            buf.pack_into(&v);
            assert_eq!(buf, PackedVotes::pack(&v), "len={len}");
        }
        // steady state at a fixed length: capacity is reused, so
        // repacking must not grow the allocation
        let v = vec![-1.0f32; 1024];
        buf.pack_into(&v);
        let cap = buf.bytes.capacity();
        for _ in 0..10 {
            buf.pack_into(&v);
        }
        assert_eq!(buf.bytes.capacity(), cap);
    }

    #[test]
    fn word_layout_is_little_endian_across_bytes() {
        let mut v = vec![-1.0f32; 130];
        v[0] = 1.0;
        v[63] = 1.0;
        v[64] = 1.0;
        v[129] = 1.0;
        let p = PackedVotes::pack(&v);
        assert_eq!(p.word(0), (1u64 << 63) | 1);
        assert_eq!(p.word(1), 1);
        assert_eq!(p.word(2), 1 << 1); // coordinate 129 = word 2, bit 1
        assert_eq!(p.word(3), 0); // past the payload: zero padding
    }

    #[test]
    fn tally_matches_f32_reference_on_small_patterns() {
        // 257 and 300 straddle the 4-word strip boundary (256 lanes)
        for p in [1usize, 7, 8, 9, 63, 64, 65, 127, 130, 257, 300] {
            for n in [1usize, 2, 3, 4, 5, 8] {
                let votes: Vec<PackedVotes> = (0..n)
                    .map(|w| {
                        let v: Vec<f32> = (0..p)
                            .map(|j| if (w * 31 + j * 7) % 3 == 0 { 1.0 } else { -1.0 })
                            .collect();
                        PackedVotes::pack(&v)
                    })
                    .collect();
                let mut out = vec![0.0f32; p];
                majority_vote_packed_with(Backend::Sequential, &votes, &mut out);
                assert_eq!(out, reference(&votes), "n={n} P={p}");
            }
        }
    }

    #[test]
    fn exact_tie_decodes_to_plus_one() {
        let votes =
            vec![PackedVotes::pack(&[1.0, -1.0]), PackedVotes::pack(&[-1.0, 1.0])];
        let mut out = vec![0.0f32; 2];
        majority_vote_packed(&votes, &mut out);
        assert_eq!(out, vec![1.0, 1.0]);
    }

    #[test]
    fn single_worker_vote_is_identity_on_signs() {
        let v = vec![1.0f32, -1.0, -1.0, 1.0, 1.0];
        let votes = vec![PackedVotes::pack(&v)];
        let mut out = vec![0.0f32; 5];
        majority_vote_packed(&votes, &mut out);
        assert_eq!(out, v);
    }

    #[test]
    fn bit_sliced_comparator_is_exact() {
        // lane b of word w has count = number of ranks whose bit is set;
        // cross-check lanes_ge against scalar counting for all thresholds
        let words = [0b1011u64, 0b1110, 0b0101, 0b1111, 0b0000];
        for t in 0..=5u64 {
            let mut counts = vec![0u64; 3];
            for &w in &words {
                assert_eq!(add_word(&mut counts, w), 0, "3 bits hold counts up to 5");
            }
            let mask = lanes_ge(&counts, t);
            for lane in 0..4 {
                let count = words.iter().filter(|&&w| (w >> lane) & 1 == 1).count() as u64;
                assert_eq!(
                    (mask >> lane) & 1 == 1,
                    count >= t,
                    "lane {lane}: count {count}, threshold {t}"
                );
            }
        }
    }

    #[test]
    fn add_word_reports_counter_overflow_as_carry_out() {
        // two counter bits hold counts 0..=3; the fourth increment of a
        // lane must surface as a nonzero carry instead of wrapping the
        // lane back to zero — the load-bearing form of what used to be
        // a debug_assert inside add_word
        let mut counts = vec![0u64; 2];
        for i in 0..3 {
            assert_eq!(add_word(&mut counts, 1), 0, "increment {i} fits in 2 bits");
        }
        assert_ne!(add_word(&mut counts, 1), 0, "overflow must be loud, not a wrap");
    }

    #[test]
    fn thousand_rank_tally_is_exact_in_release_builds() {
        // n = 1024 needs 11 counter bits and exercises lanes whose
        // counts straddle the threshold (512) as well as the extremes;
        // before the carry became load-bearing, an undersized counter
        // would have flipped these majorities silently in release
        // builds, where the old debug_assert compiled away
        let n = 1024usize;
        let p = 70usize;
        let count_for = |j: usize| -> usize {
            match j {
                0 => 0,
                1 => 511, // one short of the threshold: decodes -1
                2 => 512, // exactly the threshold: decodes +1
                3 => 513,
                4 => n,
                _ => (j * 389) % (n + 1),
            }
        };
        let votes: Vec<PackedVotes> = (0..n)
            .map(|w| {
                let v: Vec<f32> =
                    (0..p).map(|j| if w < count_for(j) { 1.0 } else { -1.0 }).collect();
                PackedVotes::pack(&v)
            })
            .collect();
        for backend in [Backend::Sequential, Backend::auto(p)] {
            let mut out = vec![0.0f32; p];
            majority_vote_packed_with(backend, &votes, &mut out);
            for (j, &o) in out.iter().enumerate() {
                let expect = if count_for(j) >= n / 2 { 1.0 } else { -1.0 };
                assert_eq!(o, expect, "coordinate {j}: {} set bits of {n}", count_for(j));
            }
        }
    }

    #[test]
    #[should_panic(expected = "vote length")]
    fn mismatched_vote_lengths_panic() {
        let votes = vec![PackedVotes::pack(&[1.0; 4]), PackedVotes::pack(&[1.0; 5])];
        let mut out = vec![0.0f32; 4];
        majority_vote_packed(&votes, &mut out);
    }

    #[test]
    #[should_panic(expected = "payload")]
    fn from_bytes_validates_length() {
        PackedVotes::from_bytes(vec![0u8; 2], 32);
    }

    #[test]
    fn with_len_is_sized_all_minus_one_and_costs_like_a_packed_round() {
        let v = PackedVotes::with_len(70);
        assert_eq!(v.len(), 70);
        assert_eq!(v.as_bytes().len(), codec::packed_len(70));
        assert_eq!(v.wire_bytes(), codec::sign_allreduce_bytes(70));
        assert_eq!(v.unpack(), vec![-1.0f32; 70]);
        assert!(PackedVotes::with_len(0).is_empty());
    }

    #[test]
    fn flip_bit_toggles_exactly_one_vote() {
        let v: Vec<f32> = (0..70).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let mut p = PackedVotes::pack(&v);
        p.flip_bit(65);
        let decoded = p.unpack();
        for (i, (&orig, &got)) in v.iter().zip(&decoded).enumerate() {
            if i == 65 {
                assert_eq!(got, -orig, "flipped coordinate");
            } else {
                assert_eq!(got, orig, "coordinate {i} must be untouched");
            }
        }
        p.flip_bit(65); // flipping twice restores the payload
        assert_eq!(p.unpack(), v);
    }

    #[test]
    #[should_panic(expected = "flip_bit")]
    fn flip_bit_past_the_end_panics() {
        PackedVotes::pack(&[1.0; 8]).flip_bit(8);
    }

    #[test]
    fn flip_all_negates_every_vote_and_roundtrips_bytes() {
        let v: Vec<f32> = (0..70).map(|i| if i % 3 == 0 { 1.0 } else { -1.0 }).collect();
        let mut p = PackedVotes::pack(&v);
        let original_bytes = p.as_bytes().to_vec();
        p.flip_all();
        let flipped: Vec<f32> = v.iter().map(|&x| -x).collect();
        assert_eq!(p.unpack(), flipped);
        // tail bits stay clear: a second flip restores the exact bytes
        p.flip_all();
        assert_eq!(p.as_bytes(), &original_bytes[..]);
    }

    #[test]
    fn set_all_is_a_unanimous_vote() {
        let mut p = PackedVotes::pack(
            &(0..37).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect::<Vec<f32>>(),
        );
        p.set_all(true);
        assert_eq!(p.unpack(), vec![1.0f32; 37]);
        assert_eq!(p, PackedVotes::pack(&vec![1.0f32; 37]), "tail bits masked");
        p.set_all(false);
        assert_eq!(p.unpack(), vec![-1.0f32; 37]);
    }

    #[test]
    fn agreement_counts_matching_signs() {
        let p = PackedVotes::pack(&[1.0, -1.0, 1.0, -1.0]);
        assert_eq!(p.agreement(&[2.0, -3.0, 0.5, -0.1]), 1.0);
        assert_eq!(p.agreement(&[-2.0, 3.0, -0.5, 0.1]), 0.0);
        assert_eq!(p.agreement(&[2.0, 3.0, 0.5, 0.1]), 0.5);
        // +0.0 is positive on the zero-symbol-free wire
        assert_eq!(p.agreement(&[0.0, -1.0, 1.0, -1.0]), 1.0);
        assert_eq!(PackedVotes::empty().agreement(&[]), 1.0);
    }

    #[test]
    fn tally_accepts_references_and_matches_owned_buffers() {
        let owned: Vec<PackedVotes> = (0..3)
            .map(|w| {
                let v: Vec<f32> =
                    (0..100).map(|j| if (w + j) % 2 == 0 { 1.0 } else { -1.0 }).collect();
                PackedVotes::pack(&v)
            })
            .collect();
        let refs: Vec<&PackedVotes> = owned.iter().collect();
        let mut from_owned = vec![0.0f32; 100];
        majority_vote_packed(&owned, &mut from_owned);
        let mut from_refs = vec![0.0f32; 100];
        majority_vote_packed(&refs, &mut from_refs);
        assert_eq!(from_owned, from_refs);
    }
}
