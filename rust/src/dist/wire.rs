//! The typed round-exchange payload: what one rank actually puts on the
//! simulated wire at a communication round.
//!
//! Every outer optimizer's worker→server exchange is a [`WirePayload`]
//! — full-precision parameters, packed 1-bit sign votes, 8-bit
//! quantized differences, or **layout-aware** 8-bit differences with
//! one scale per parameter segment — and the clock bills the payload's
//! own [`WirePayload::wire_bytes`]
//! ([`crate::comm::SimClock::charge_exchange`]). Because the billed
//! object IS the exchanged object, the accounting and the data path
//! cannot diverge: there is no per-optimizer flag left to choose a byte
//! formula from, and adding a format means adding a variant here (its
//! byte cost and topology come with it) rather than a new `if` in the
//! trainer.
//!
//! # Formats
//!
//! | format | payload | bytes/message | topology |
//! |---|---|---|---|
//! | [`WireFormat::DenseF32`] | rank's end parameters `x_{t,τ}^{(i)}` | `4P` | ring all-reduce |
//! | [`WireFormat::PackedSigns`] | 1-bit randomized sign votes | `⌈P/8⌉ + 8` | gather + broadcast |
//! | [`WireFormat::QuantizedI8`] | i8-quantized local difference, one scale | `P + 12` | gather + broadcast |
//! | [`WireFormat::QuantizedI8PerTensor`] | i8-quantized difference, one scale per layout segment | `P + 8 + 4S` | gather + broadcast |
//!
//! A mean over dense payloads is ring-reducible, so `DenseF32` keeps
//! the classic α-β ring model. Neither a majority tally nor a
//! per-rank-scaled i8 sum fits its own wire format mid-reduction (a
//! partial tally has no 1-bit encoding; summing i8 payloads with
//! different scales requires dequantizing first), so the compressed
//! formats bill the practical server topology — a flat gather of the
//! n−1 rank payloads plus a binomial-tree broadcast of the result. At
//! the default n = 4 the quantized exchanges beat dense on both the
//! latency and bandwidth terms; at large n the linear gather overtakes
//! the saturating ring — an honest tradeoff the comm-tradeoff example
//! tabulates.
//!
//! # The layout contract (`q8pt`)
//!
//! The per-message `q8` format pays one quantization scale for the
//! whole vector, so the segment with the largest difference magnitude
//! sets everyone's resolution — GPT-2 blocks (embeddings, attention,
//! MLP, layernorm) differ by orders of magnitude, and the small-moving
//! blocks round to garbage. `QuantizedI8PerTensor` carries the
//! backend's validated [`ParamLayout`]
//! ([`crate::runtime::StepBackend::layout`]) and quantizes each named
//! segment against its own scale ([`super::codec::quantize_diff_slice`])
//! for 4 extra wire bytes per segment. Under a one-segment layout it is
//! **bitwise-identical** to `q8` (same arithmetic, same bytes modulo
//! the identical 4-byte scale frame) — the golden tests in
//! `rust/tests/layout_wire.rs` pin both that identity and the error
//! reduction on hetero-magnitude layouts.

use std::sync::Arc;

use super::codec;
use super::collectives;
use super::votes::PackedVotes;
use crate::comm::CommModel;
use crate::runtime::ParamLayout;

/// Construction-time name of a [`WirePayload`] variant: what a config
/// file selects (`wire = "dense" | "packed_signs" | "q8" | "q8pt"`) and
/// what the trainer sizes its persistent per-rank buffers with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireFormat {
    /// Full-precision f32 parameters (the classic exchange).
    DenseF32,
    /// 1-bit sign votes ([`codec::pack_signs`], Algorithm 6's wire).
    PackedSigns,
    /// 8-bit symmetric-quantized local differences, one per-message
    /// scale ([`codec::quantize_diff_into`]).
    QuantizedI8,
    /// 8-bit symmetric-quantized local differences with one scale per
    /// [`ParamLayout`] segment ([`codec::quantize_diff_slice`]).
    QuantizedI8PerTensor,
}

impl WireFormat {
    /// Parse a config-file / CLI name.
    pub fn parse(s: &str) -> Option<WireFormat> {
        match s {
            "dense" | "f32" => Some(WireFormat::DenseF32),
            "packed_signs" | "signs" | "1bit" => Some(WireFormat::PackedSigns),
            "q8" | "i8" | "quantized_i8" => Some(WireFormat::QuantizedI8),
            "q8pt" | "q8_per_tensor" | "i8pt" => Some(WireFormat::QuantizedI8PerTensor),
            _ => None,
        }
    }

    /// Stable config-facing name (inverse of [`WireFormat::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            WireFormat::DenseF32 => "dense",
            WireFormat::PackedSigns => "packed_signs",
            WireFormat::QuantizedI8 => "q8",
            WireFormat::QuantizedI8PerTensor => "q8pt",
        }
    }

    /// Bytes one message of `len` coordinates in this format puts on
    /// the wire (what a sized [`WirePayload`] will report). `segments`
    /// is the parameter-layout segment count — it only affects the
    /// per-tensor format (one extra f32 scale each); pass 1 for
    /// layout-less analysis.
    pub fn wire_bytes(&self, len: usize, segments: usize) -> u64 {
        match self {
            WireFormat::DenseF32 => len as u64 * 4,
            WireFormat::PackedSigns => codec::sign_allreduce_bytes(len),
            WireFormat::QuantizedI8 => codec::q8_bytes(len),
            WireFormat::QuantizedI8PerTensor => codec::q8pt_bytes(len, segments),
        }
    }

    /// Whether a partial aggregate of this format fits back into the
    /// format itself — true only for dense f32, which therefore bills
    /// the ring all-reduce; compressed formats bill gather+broadcast
    /// (see the module docs).
    pub fn ring_reducible(&self) -> bool {
        matches!(self, WireFormat::DenseF32)
    }

    /// Modeled seconds of one round exchange of `len` coordinates over
    /// a `segments`-segment layout under `m` — the ONE place the
    /// byte-count × topology rule lives for analytical re-costing.
    /// [`crate::comm::SimClock::charge_exchange`] makes the identical
    /// choice off the payload (ring for the ring-reducible dense
    /// format, gather+broadcast otherwise), so tables re-costed through
    /// this helper cannot drift from what the clock actually billed
    /// (pinned by `exchange_time_matches_the_clock_topology`).
    pub fn exchange_time(&self, m: &CommModel, n: usize, len: usize, segments: usize) -> f64 {
        let bytes = self.wire_bytes(len, segments);
        if self.ring_reducible() {
            m.allreduce_time(n, bytes)
        } else {
            m.gather_time(n, bytes) + m.broadcast_time(n, bytes)
        }
    }
}

/// One rank's round contribution, in exactly the bytes that cross the
/// simulated wire. Trainer-owned and persistent: the same buffers are
/// re-packed in place every round, so the steady-state exchange
/// allocates nothing in any format.
#[derive(Clone, Debug, PartialEq)]
pub enum WirePayload {
    /// The rank's end-of-round parameters, full precision.
    DenseF32(Vec<f32>),
    /// The rank's packed 1-bit sign votes.
    PackedSigns(PackedVotes),
    /// The rank's local difference `start - end`, quantized to i8 with
    /// a per-message scale ([`codec::quantize_diff_into`]).
    QuantizedI8 {
        /// Symmetric quantization step (`max |diff| / 127`).
        scale: f32,
        /// One two's-complement i8 per coordinate.
        bytes: Vec<u8>,
    },
    /// The rank's local difference `start - end`, quantized to i8 with
    /// one scale per segment of the parameter layout
    /// ([`codec::quantize_diff_slice`] per segment). The layout rides
    /// in the payload (shared, not serialized: the byte cost counts the
    /// scales, the segment boundaries are part of the static
    /// backend↔trainer contract both ends already hold).
    QuantizedI8PerTensor {
        /// The validated segment layout the scales follow.
        layout: Arc<ParamLayout>,
        /// Symmetric quantization step per segment
        /// (`max |diff over segment| / 127` each).
        scales: Vec<f32>,
        /// One two's-complement i8 per coordinate.
        bytes: Vec<u8>,
    },
}

impl WirePayload {
    /// A zeroed payload of `len` coordinates in `format` — the initial
    /// state of the trainer's persistent buffers. Its
    /// [`wire_bytes`](Self::wire_bytes) is already final: the byte cost
    /// is a function of (format, len, layout) only, never of the packed
    /// contents, which is what lets the clock bill a round before the
    /// ranks pack into it. The per-tensor format gets the one-segment
    /// fallback layout here; use [`WirePayload::with_layout`] to size
    /// it from a real backend layout.
    pub fn with_len(format: WireFormat, len: usize) -> WirePayload {
        match format {
            WireFormat::DenseF32 => WirePayload::DenseF32(vec![0.0; len]),
            WireFormat::PackedSigns => WirePayload::PackedSigns(PackedVotes::with_len(len)),
            WireFormat::QuantizedI8 => {
                WirePayload::QuantizedI8 { scale: 0.0, bytes: vec![0; len] }
            }
            WireFormat::QuantizedI8PerTensor => {
                WirePayload::with_layout(format, &Arc::new(ParamLayout::single(len)))
            }
        }
    }

    /// A zeroed payload sized from a parameter layout — how the trainer
    /// builds its persistent buffers
    /// ([`crate::runtime::StepBackend::layout`]). Only the per-tensor
    /// format actually stores the layout (one scale slot per segment);
    /// every other format just takes its coordinate count.
    pub fn with_layout(format: WireFormat, layout: &Arc<ParamLayout>) -> WirePayload {
        match format {
            WireFormat::QuantizedI8PerTensor => WirePayload::QuantizedI8PerTensor {
                scales: vec![0.0; layout.len()],
                bytes: vec![0; layout.param_count()],
                layout: Arc::clone(layout),
            },
            other => WirePayload::with_len(other, layout.param_count()),
        }
    }

    pub fn format(&self) -> WireFormat {
        match self {
            WirePayload::DenseF32(_) => WireFormat::DenseF32,
            WirePayload::PackedSigns(_) => WireFormat::PackedSigns,
            WirePayload::QuantizedI8 { .. } => WireFormat::QuantizedI8,
            WirePayload::QuantizedI8PerTensor { .. } => WireFormat::QuantizedI8PerTensor,
        }
    }

    /// Number of coordinates this payload carries.
    pub fn len(&self) -> usize {
        match self {
            WirePayload::DenseF32(v) => v.len(),
            WirePayload::PackedSigns(p) => p.len(),
            WirePayload::QuantizedI8 { bytes, .. } => bytes.len(),
            WirePayload::QuantizedI8PerTensor { bytes, .. } => bytes.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total bytes this message puts on the wire — the number the clock
    /// bills. By construction equal to
    /// `self.format().wire_bytes(self.len(), segments)` with `segments`
    /// the payload's own scale count.
    pub fn wire_bytes(&self) -> u64 {
        match self {
            WirePayload::DenseF32(v) => v.len() as u64 * 4,
            WirePayload::PackedSigns(p) => p.wire_bytes(),
            WirePayload::QuantizedI8 { bytes, .. } => codec::q8_bytes(bytes.len()),
            WirePayload::QuantizedI8PerTensor { scales, bytes, .. } => {
                codec::q8pt_bytes(bytes.len(), scales.len())
            }
        }
    }

    /// See [`WireFormat::ring_reducible`].
    pub fn ring_reducible(&self) -> bool {
        self.format().ring_reducible()
    }

    /// The dense f32 view, when this is a [`WirePayload::DenseF32`].
    pub fn as_dense(&self) -> Option<&[f32]> {
        match self {
            WirePayload::DenseF32(v) => Some(v),
            _ => None,
        }
    }

    /// The packed-vote view, when this is a [`WirePayload::PackedSigns`].
    pub fn as_packed_signs(&self) -> Option<&PackedVotes> {
        match self {
            WirePayload::PackedSigns(p) => Some(p),
            _ => None,
        }
    }

    /// The parameter layout a per-tensor payload was sized with.
    pub fn layout(&self) -> Option<&Arc<ParamLayout>> {
        match self {
            WirePayload::QuantizedI8PerTensor { layout, .. } => Some(layout),
            _ => None,
        }
    }

    /// The per-segment scales of a per-tensor payload (or the single
    /// per-message scale of a `q8` payload).
    pub fn scales(&self) -> Option<&[f32]> {
        match self {
            WirePayload::QuantizedI8 { scale, .. } => Some(std::slice::from_ref(scale)),
            WirePayload::QuantizedI8PerTensor { scales, .. } => Some(scales),
            _ => None,
        }
    }

    /// Worker-side packing shared by every dense-exchange outer
    /// optimizer: fill this payload with rank's end-of-round state in
    /// the payload's own format — the parameters themselves for
    /// `DenseF32`, the quantized difference `start - end` for the
    /// quantized formats (one scale per message for `QuantizedI8`, one
    /// per layout segment for `QuantizedI8PerTensor`). Buffer capacity
    /// is reused; no allocation in steady state.
    ///
    /// # Panics
    ///
    /// On a `PackedSigns` buffer: a dense parameter exchange has no
    /// 1-bit encoding (config validation keeps this combination from
    /// ever being built — [`crate::config::RunConfig::validate`]). On a
    /// per-tensor buffer whose layout does not tile `start.len()`.
    pub fn pack_end(&mut self, start: &[f32], end: &[f32]) {
        match self {
            WirePayload::DenseF32(buf) => {
                buf.clear();
                buf.extend_from_slice(end);
            }
            WirePayload::QuantizedI8 { scale, bytes } => {
                *scale = codec::quantize_diff_into(start, end, bytes);
            }
            WirePayload::QuantizedI8PerTensor { layout, scales, bytes } => {
                assert_eq!(
                    start.len(),
                    layout.param_count(),
                    "pack_end: {} coordinates vs a layout tiling {}",
                    start.len(),
                    layout.param_count()
                );
                for (e, s) in layout.entries().iter().zip(scales.iter_mut()) {
                    let r = e.offset..e.offset + e.numel();
                    *s = codec::quantize_diff_slice(
                        &start[r.clone()],
                        &end[r.clone()],
                        &mut bytes[r],
                    );
                }
            }
            WirePayload::PackedSigns(_) => {
                panic!("a dense parameter exchange cannot pack into a packed_signs payload")
            }
        }
    }

    /// Worker-side packing for sign-vote optimizers: pack the ±1 vote
    /// vector at 1 bit/coordinate ([`PackedVotes::pack_into`]).
    ///
    /// # Panics
    ///
    /// On a dense or quantized buffer — sign votes only have the 1-bit
    /// encoding (again unreachable under a validated config).
    pub fn pack_sign_votes(&mut self, votes: &[f32]) {
        match self {
            WirePayload::PackedSigns(p) => p.pack_into(votes),
            other => panic!(
                "sign votes need a packed_signs payload, got {}",
                other.format().name()
            ),
        }
    }

    /// Server-side reconstruction of the round's average end point
    /// `x̄_{t,τ}` from the gathered payloads, into `out`:
    ///
    /// * `DenseF32` — the exact mean of the rank parameters, computed
    ///   by the same [`collectives::allreduce_mean`] arithmetic (f64
    ///   accumulation in rank order) the trainer historically used, so
    ///   the dense path is bitwise-identical to the pre-payload
    ///   semantics by construction.
    /// * `QuantizedI8` — `start - mean_i(dequantize(payload_i))`: each
    ///   rank's difference decodes with its own scale, is averaged in
    ///   f64 in rank order, and re-anchors at the round start.
    /// * `QuantizedI8PerTensor` — same arithmetic, but each coordinate
    ///   decodes with its **segment's** scale. Iteration is segment-
    ///   major in layout (= coordinate) order, so with a one-segment
    ///   layout the accumulation order — and hence the result — is
    ///   bitwise-identical to `QuantizedI8`.
    ///
    /// # Panics
    ///
    /// On `PackedSigns` payloads (a majority tally has no mean end
    /// point — tally them with
    /// [`crate::dist::votes::majority_vote_packed`]), on mixed formats
    /// or mixed layouts, or on length mismatches.
    pub fn mean_end_into(payloads: &[WirePayload], start: &[f32], out: &mut [f32]) {
        assert!(!payloads.is_empty(), "exchange over zero workers");
        for (i, p) in payloads.iter().enumerate() {
            assert_eq!(p.format(), payloads[0].format(), "worker {i}: mixed wire formats");
            assert_eq!(
                p.len(),
                out.len(),
                "worker {i}: payload length {} != output {}",
                p.len(),
                out.len()
            );
        }
        match payloads[0] {
            WirePayload::DenseF32(_) => {
                collectives::allreduce_mean(
                    payloads,
                    |p| p.as_dense().expect("format checked above"),
                    out,
                );
            }
            WirePayload::QuantizedI8 { .. } => {
                assert_eq!(start.len(), out.len(), "start length {} != output", start.len());
                let inv_n = 1.0f64 / payloads.len() as f64;
                for (i, o) in out.iter_mut().enumerate() {
                    let mut acc = 0.0f64;
                    for p in payloads {
                        let WirePayload::QuantizedI8 { scale, bytes } = p else {
                            unreachable!("format checked above")
                        };
                        acc += codec::dequantize_i8(bytes[i], *scale) as f64;
                    }
                    *o = start[i] - (acc * inv_n) as f32;
                }
            }
            WirePayload::QuantizedI8PerTensor { .. } => {
                assert_eq!(start.len(), out.len(), "start length {} != output", start.len());
                let WirePayload::QuantizedI8PerTensor { layout, .. } = &payloads[0] else {
                    unreachable!("format checked above")
                };
                // a layout tiling fewer coordinates than the payload
                // carries would leave out's tail stale below — reject
                // inconsistent hand-built payloads loudly instead
                assert_eq!(
                    layout.param_count(),
                    out.len(),
                    "payload layout tiles {} of {} coordinates",
                    layout.param_count(),
                    out.len()
                );
                for (i, p) in payloads.iter().enumerate() {
                    assert_eq!(p.layout(), Some(layout), "worker {i}: mixed parameter layouts");
                }
                let inv_n = 1.0f64 / payloads.len() as f64;
                for (si, e) in layout.entries().iter().enumerate() {
                    for i in e.offset..e.offset + e.numel() {
                        let mut acc = 0.0f64;
                        for p in payloads {
                            let WirePayload::QuantizedI8PerTensor { scales, bytes, .. } = p else {
                                unreachable!("format checked above")
                            };
                            acc += codec::dequantize_i8(bytes[i], scales[si]) as f64;
                        }
                        out[i] = start[i] - (acc * inv_n) as f32;
                    }
                }
            }
            WirePayload::PackedSigns(_) => {
                panic!("packed sign votes have no mean end point; run the majority tally")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL_FORMATS: [WireFormat; 4] = [
        WireFormat::DenseF32,
        WireFormat::PackedSigns,
        WireFormat::QuantizedI8,
        WireFormat::QuantizedI8PerTensor,
    ];

    fn two_segment_layout(a: usize, b: usize) -> Arc<ParamLayout> {
        use crate::runtime::ParamEntry;
        let entries = vec![
            ParamEntry { name: "lo".into(), offset: 0, shape: vec![a] },
            ParamEntry { name: "hi".into(), offset: a, shape: vec![b] },
        ];
        Arc::new(ParamLayout::from_entries(entries, a + b).unwrap())
    }

    #[test]
    fn with_len_builds_sized_zeroed_payloads_in_every_format() {
        for format in ALL_FORMATS {
            let p = WirePayload::with_len(format, 37);
            assert_eq!(p.format(), format);
            assert_eq!(p.len(), 37);
            assert!(!p.is_empty());
            assert_eq!(p.wire_bytes(), format.wire_bytes(37, 1), "{}", format.name());
            assert!(WirePayload::with_len(format, 0).is_empty());
        }
    }

    #[test]
    fn with_layout_sizes_per_tensor_payloads_from_the_layout() {
        let layout = two_segment_layout(5, 11);
        for format in ALL_FORMATS {
            let p = WirePayload::with_layout(format, &layout);
            assert_eq!(p.format(), format);
            assert_eq!(p.len(), 16, "{}", format.name());
        }
        let pt = WirePayload::with_layout(WireFormat::QuantizedI8PerTensor, &layout);
        assert_eq!(pt.scales().unwrap().len(), 2);
        assert_eq!(pt.layout(), Some(&layout));
        assert_eq!(pt.wire_bytes(), WireFormat::QuantizedI8PerTensor.wire_bytes(16, 2));
        // one scale more than the per-message format
        assert_eq!(pt.wire_bytes(), WireFormat::QuantizedI8.wire_bytes(16, 1) + 4);
    }

    #[test]
    fn wire_bytes_match_the_codec_models() {
        let p = 1 << 20;
        assert_eq!(WireFormat::DenseF32.wire_bytes(p, 1), p as u64 * 4);
        assert_eq!(WireFormat::PackedSigns.wire_bytes(p, 1), codec::sign_allreduce_bytes(p));
        assert_eq!(WireFormat::QuantizedI8.wire_bytes(p, 1), codec::q8_bytes(p));
        assert_eq!(WireFormat::QuantizedI8PerTensor.wire_bytes(p, 7), codec::q8pt_bytes(p, 7));
    }

    #[test]
    fn parse_and_name_round_trip() {
        for format in ALL_FORMATS {
            assert_eq!(WireFormat::parse(format.name()), Some(format));
        }
        assert_eq!(WireFormat::parse("q8"), Some(WireFormat::QuantizedI8));
        assert_eq!(WireFormat::parse("q8pt"), Some(WireFormat::QuantizedI8PerTensor));
        assert_eq!(WireFormat::parse("1bit"), Some(WireFormat::PackedSigns));
        assert_eq!(WireFormat::parse("warpdrive"), None);
    }

    #[test]
    fn only_dense_is_ring_reducible() {
        assert!(WireFormat::DenseF32.ring_reducible());
        assert!(!WireFormat::PackedSigns.ring_reducible());
        assert!(!WireFormat::QuantizedI8.ring_reducible());
        assert!(!WireFormat::QuantizedI8PerTensor.ring_reducible());
    }

    #[test]
    fn exchange_time_matches_the_clock_topology() {
        // the analytical re-costing helper and the clock's payload
        // billing must agree exactly, format by format
        use crate::comm::SimClock;
        use crate::util::rng::Rng;
        let m = CommModel {
            latency_s: 1e-3,
            bandwidth_bps: 1e6,
            straggler_sigma: 0.0,
            straggler_scale_s: 0.0,
        };
        for format in ALL_FORMATS {
            let payload = WirePayload::with_len(format, 1000);
            let mut clock = SimClock::default();
            clock.charge_exchange(&m, 4, &payload, &mut Rng::new(1));
            let t = format.exchange_time(&m, 4, 1000, 1);
            assert!((clock.comm_s - t).abs() < 1e-15, "{}", format.name());
        }
    }

    #[test]
    fn dense_mean_matches_allreduce_mean_bitwise() {
        let ends = [vec![1.0f32, 2.0, -3.0], vec![0.5f32, -2.0, 9.0], vec![0.25f32, 0.1, 1.0]];
        let payloads: Vec<WirePayload> = ends
            .iter()
            .map(|e| {
                let mut p = WirePayload::with_len(WireFormat::DenseF32, 3);
                p.pack_end(&[0.0; 3], e);
                p
            })
            .collect();
        let mut from_payloads = vec![0.0f32; 3];
        WirePayload::mean_end_into(&payloads, &[0.0; 3], &mut from_payloads);
        let mut reference = vec![0.0f32; 3];
        collectives::allreduce_mean(&ends, |e| e.as_slice(), &mut reference);
        for (a, b) in from_payloads.iter().zip(&reference) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn q8_mean_reconstructs_the_average_end_within_quantization_error() {
        let start = vec![1.0f32, -0.5, 0.25, 2.0];
        let ends = [vec![0.9f32, -0.45, 0.30, 1.90], vec![0.8f32, -0.55, 0.20, 2.05]];
        let payloads: Vec<WirePayload> = ends
            .iter()
            .map(|e| {
                let mut p = WirePayload::with_len(WireFormat::QuantizedI8, 4);
                p.pack_end(&start, e);
                p
            })
            .collect();
        let mut avg = vec![0.0f32; 4];
        WirePayload::mean_end_into(&payloads, &start, &mut avg);
        let mut exact = vec![0.0f32; 4];
        collectives::allreduce_mean(&ends, |e| e.as_slice(), &mut exact);
        // per-rank quantization step: scale = max|diff|/127; the mean's
        // error is at most the mean of the per-rank half-steps
        for (j, (a, e)) in avg.iter().zip(&exact).enumerate() {
            assert!((a - e).abs() < 2e-3, "coord {j}: {a} vs {e}");
        }
    }

    #[test]
    fn q8pt_per_segment_scales_resolve_hetero_magnitudes() {
        // segment "lo" moves by ~1e-3, segment "hi" by ~1.0: one shared
        // scale (q8) rounds the small segment to nothing, per-tensor
        // scales keep it. This is the format's reason to exist; the
        // pinned numeric version lives in rust/tests/layout_wire.rs.
        let layout = two_segment_layout(4, 4);
        let start = vec![0.0f32; 8];
        #[rustfmt::skip]
        let end = vec![
            -1e-3f32, -5e-4, 1e-3, -7.5e-4, // lo: tiny diffs
            -1.0, 0.5, -0.25, 1.0,          // hi: large diffs
        ];
        let mut pt = WirePayload::with_layout(WireFormat::QuantizedI8PerTensor, &layout);
        pt.pack_end(&start, &end);
        let scales = pt.scales().unwrap().to_vec();
        assert!(scales[0] < scales[1] / 100.0, "{scales:?}");
        let mut avg = vec![0.0f32; 8];
        WirePayload::mean_end_into(std::slice::from_ref(&pt), &start, &mut avg);
        // every coordinate decodes within half its segment's step
        for (j, (a, e)) in avg.iter().zip(&end).enumerate() {
            let step = scales[j / 4];
            assert!((a - e).abs() <= step / 2.0 + 1e-7, "coord {j}: {a} vs {e}");
        }
        // and the tiny segment survived (q8 would have zeroed it)
        assert!(avg[0] != 0.0 && avg[2] != 0.0, "{avg:?}");
    }

    #[test]
    fn q8_exchange_with_zero_difference_is_exact() {
        let start = vec![0.5f32, -3.0, 7.0];
        for format in [WireFormat::QuantizedI8, WireFormat::QuantizedI8PerTensor] {
            let mut p = WirePayload::with_len(format, 3);
            p.pack_end(&start, &start);
            let mut avg = vec![9.0f32; 3];
            WirePayload::mean_end_into(std::slice::from_ref(&p), &start, &mut avg);
            assert_eq!(avg, start, "{}", format.name());
        }
    }

    #[test]
    fn pack_end_reuses_buffers_across_rounds() {
        let start = vec![1.0f32; 256];
        let end = vec![0.75f32; 256];
        for format in ALL_FORMATS {
            if format == WireFormat::PackedSigns {
                continue; // votes pack through pack_sign_votes instead
            }
            let mut p = WirePayload::with_len(format, 256);
            p.pack_end(&start, &end);
            let bytes_before = p.wire_bytes();
            for _ in 0..5 {
                p.pack_end(&start, &end);
            }
            assert_eq!(p.len(), 256, "{}", format.name());
            assert_eq!(p.wire_bytes(), bytes_before);
        }
    }

    #[test]
    #[should_panic(expected = "packed_signs")]
    fn dense_pack_into_sign_buffer_panics() {
        let mut p = WirePayload::with_len(WireFormat::PackedSigns, 8);
        p.pack_end(&[0.0; 8], &[1.0; 8]);
    }

    #[test]
    #[should_panic(expected = "sign votes")]
    fn sign_votes_into_dense_buffer_panic() {
        let mut p = WirePayload::with_len(WireFormat::DenseF32, 8);
        p.pack_sign_votes(&[1.0; 8]);
    }

    #[test]
    #[should_panic(expected = "layout tiling")]
    fn per_tensor_pack_with_wrong_dimension_panics() {
        let layout = two_segment_layout(4, 4);
        let mut p = WirePayload::with_layout(WireFormat::QuantizedI8PerTensor, &layout);
        p.pack_end(&[0.0; 6], &[1.0; 6]);
    }

    #[test]
    #[should_panic(expected = "majority tally")]
    fn mean_over_sign_votes_panics() {
        let payloads = vec![WirePayload::with_len(WireFormat::PackedSigns, 8)];
        let mut out = vec![0.0f32; 8];
        WirePayload::mean_end_into(&payloads, &[0.0; 8], &mut out);
    }

    #[test]
    #[should_panic(expected = "mixed wire formats")]
    fn mixed_formats_panic() {
        let payloads = vec![
            WirePayload::with_len(WireFormat::DenseF32, 4),
            WirePayload::with_len(WireFormat::QuantizedI8, 4),
        ];
        let mut out = vec![0.0f32; 4];
        WirePayload::mean_end_into(&payloads, &[0.0; 4], &mut out);
    }

    #[test]
    #[should_panic(expected = "mixed parameter layouts")]
    fn mixed_layouts_panic() {
        let pt = WireFormat::QuantizedI8PerTensor;
        let payloads = vec![
            WirePayload::with_layout(pt, &two_segment_layout(4, 4)),
            WirePayload::with_layout(pt, &two_segment_layout(2, 6)),
        ];
        let mut out = vec![0.0f32; 8];
        WirePayload::mean_end_into(&payloads, &[0.0; 8], &mut out);
    }
}
