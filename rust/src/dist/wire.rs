//! The typed round-exchange payload: what one rank actually puts on the
//! simulated wire at a communication round.
//!
//! Every outer optimizer's worker→server exchange is a [`WirePayload`]
//! — full-precision parameters, packed 1-bit sign votes, 8-bit
//! quantized differences, **layout-aware** 8-bit differences with one
//! scale per parameter segment, or the sparse **top-k** of a decaying
//! residual-momentum buffer — and the clock bills the payload's own
//! [`WirePayload::wire_bytes`]
//! ([`crate::comm::SimClock::charge_exchange`]). Because the billed
//! object IS the exchanged object, the accounting and the data path
//! cannot diverge: there is no per-optimizer flag left to choose a byte
//! formula from, and adding a format means adding a variant here (its
//! byte cost and topology come with it) rather than a new `if` in the
//! trainer.
//!
//! # Formats and topologies
//!
//! `P` = parameter count, `S` = layout segment count, `K` = total kept
//! top-k components (Σ over segments of [`super::codec::topk_budget`]).
//!
//! | format | payload | bytes/message | topology (n < 16 / n ≥ 16) |
//! |---|---|---|---|
//! | [`WireFormat::DenseF32`] | rank's end parameters `x_{t,τ}^{(i)}` | `4P` | ring all-reduce (any n) |
//! | [`WireFormat::PackedSigns`] | 1-bit randomized sign votes | `⌈P/8⌉ + 8` | flat gather+broadcast / hierarchical |
//! | [`WireFormat::QuantizedI8`] | i8-quantized local difference, one scale | `P + 12` | flat gather+broadcast / hierarchical |
//! | [`WireFormat::QuantizedI8PerTensor`] | i8-quantized difference, one scale per layout segment | `P + 8 + 4S` | flat gather+broadcast / hierarchical |
//! | [`WireFormat::TopK`] | top-k of the decaying residual, one (u32 index, f32 value) pair per kept component | `8K + 8` | flat gather+broadcast / hierarchical |
//!
//! A mean over dense payloads is ring-reducible, so `DenseF32` keeps
//! the classic α-β ring model at every fleet size. Neither a majority
//! tally nor a per-rank-scaled i8 sum fits its own wire format
//! mid-reduction (a partial tally has no 1-bit encoding; summing i8
//! payloads with different scales requires dequantizing first, and a
//! sparse index-union outgrows its k-budget mid-reduction), so the
//! compressed formats bill a server topology. Which one is
//! [`Topology::select`]'s call, shared with the clock: the flat gather
//! of n−1 rank payloads plus a binomial-tree broadcast at small n, and
//! the two-level **hierarchical** scheme — ranks gather into ≈√n
//! groups, each group head partially aggregates
//! ([`WirePayload::aggregate_group_heads`]: decode-mean-requantize for
//! the i8 formats, a partial majority tally repacked as votes for
//! signs, an index-union mean re-truncated to the per-segment budget
//! for top-k), the heads exchange flat, and the result broadcasts back down
//! — once n reaches [`crate::comm::topology::HIERARCHICAL_MIN_RANKS`].
//! That fixes the compressed formats' large-n loss to the dense ring by
//! construction: the flat gather's (n−1) serial messages become O(√n),
//! while the per-format byte advantage is untouched (the hierarchy
//! moves the same `2(n−1)·b` total bytes).
//!
//! # The framed byte encoding
//!
//! Every payload serializes to one length-prefixed byte frame
//! ([`WirePayload::encode_into`]) and parses back as a zero-copy
//! borrowed view ([`WirePayload::decode`] → [`WirePayloadView`], which
//! documents the per-format byte layout table). The frame length is
//! *exactly* [`WirePayload::wire_bytes`] for every format — the billed
//! number IS the framed length, so the byte accounting is pinned to a
//! real encoding rather than a formula that could drift from it.
//! Truncated frames, trailing bytes, and length-prefix drift are typed
//! [`WireError`]s, never silent short reads.
//!
//! # Faults and `n_effective`
//!
//! Under an active [`crate::comm::FaultPlan`] a round's gather may see
//! fewer payloads than the fleet has ranks: members sit rounds out
//! (churn), payloads drop in transit, and corrupted payloads that fail
//! [`WirePayload::check_finite`] are rejected before aggregation. The
//! aggregate is then taken over the `n_effective` surviving payloads —
//! [`WirePayload::mean_end_into`] divides by `payloads.len()`, the
//! majority tally thresholds at half its vote count, so both paths are
//! well defined for any non-empty survivor set (an empty one skips the
//! round). Corruption is never silently averaged in: a NaN-poisoned
//! scale is a typed [`WireError`] at pack *and* decode time, while a
//! bit-flipped i8 byte or sign word is a valid encoding and is
//! *survived* with bounded error — exactly the distinction between
//! detectable and undetectable damage on a real wire.
//!
//! # Byzantine ranks and robust aggregation (`agg`)
//!
//! An adversarial rank ([`crate::comm::faults::Attack`]) sends
//! payloads that are *finite but wrong* — every byte a valid encoding,
//! so no [`WirePayload::check_finite`] gate can reject them; the
//! aggregation itself must defend. The defense is a pluggable
//! [`AggPolicy`] (`[outer] agg = "mean" | "trimmed" | "median"`),
//! applied by every dense-exchange outer optimizer through
//! [`WirePayload::aggregate_end_into`] and inside
//! [`WirePayload::aggregate_group_heads`] so hierarchical group heads
//! defend locally before the top-level exchange. Attack × defense
//! breakdown points, for `n` surviving payloads of which `f` are
//! adversarial and trim depth `k` = [`AggPolicy::trim_depth`]:
//!
//! | attack | on the wire | `mean` | `trimmed` | `median` | MV tally |
//! |---|---|---|---|---|---|
//! | `sign_flip` | local diff negated: dense end reflected around the round start, q8/q8pt scales negated, top-k values negated, sign votes flipped | poisoned by f = 1 | f ≤ k | f < n/2 | f < n/2 on unanimous honest coordinates |
//! | `scale_inflate` | diff ×64: dense end stretched from the start, scales / sparse values inflated | poisoned by f = 1 | f ≤ k | f < n/2 | immune — no magnitude on the 1-bit wire |
//! | `collude_fixed` | diff ≡ +1 in every transmitted coordinate, identical across colluders | poisoned by f = 1 | f ≤ k | f < n/2 | f < n/2 on unanimous honest coordinates |
//! | `flaky` | honest or `sign_flip`, fair coin per adversary per round | poisoned by f = 1 | f ≤ k | f < n/2 | f < n/2 |
//!
//! "Poisoned by f = 1" is literal: a single ×64-inflated payload
//! shifts the mean by ~64/n of a full local step per coordinate, every
//! round, which is the breakdown the robust-aggregation experiment
//! (`examples/robust_agg.rs`) pins. The packed sign tally ignores the
//! policy knob — the majority vote IS the robust aggregator, which is
//! the source paper's case for MV-sto-signSGD under unreliable
//! workers. The per-rank reputation/quarantine supervisor layered on
//! top of these policies lives in the trainer; its lifecycle is
//! documented at [`crate::comm::faults`].
//!
//! # The layout contract (`q8pt`)
//!
//! The per-message `q8` format pays one quantization scale for the
//! whole vector, so the segment with the largest difference magnitude
//! sets everyone's resolution — GPT-2 blocks (embeddings, attention,
//! MLP, layernorm) differ by orders of magnitude, and the small-moving
//! blocks round to garbage. `QuantizedI8PerTensor` carries the
//! backend's validated [`ParamLayout`]
//! ([`crate::runtime::StepBackend::layout`]) and quantizes each named
//! segment against its own scale ([`super::codec::quantize_diff_slice`])
//! for 4 extra wire bytes per segment. Under a one-segment layout it is
//! **bitwise-identical** to `q8` (same arithmetic, same bytes modulo
//! the identical 4-byte scale frame) — the golden tests in
//! `rust/tests/layout_wire.rs` pin both that identity and the error
//! reduction on hetero-magnitude layouts.
//!
//! # The top-k residual contract (`topk`)
//!
//! The DeMo-style sparse format (PAPERS.md: Peng et al. 2024)
//! transmits only the K = Σ_s k_s largest-magnitude components of a
//! worker-side **residual-momentum** buffer, with k_s chosen per
//! layout segment from the keep fraction
//! ([`super::codec::topk_budget`]: ⌊numel_s · frac⌋, never below one
//! component for a non-empty segment). [`WirePayload::pack_end`] first
//! accumulates this round's local difference `start − end` into the
//! residual, then moves the top k_s of each segment onto the wire
//! ([`super::codec::topk_select_segment`]) and decays what stays
//! behind by the configured rate: untransmitted mass is neither
//! discarded (it re-competes next round) nor kept forever (the decay
//! bounds its age). K is a pure function of (layout, keep fraction) —
//! never of the packed contents — so the `8K + 8` byte bill is fixed
//! at construction exactly like every other format's. The residual is
//! *worker state*: the trainer checkpoints it
//! ([`WirePayload::residual`]) alongside the optimizer state so a
//! resumed run replays the same sparse selections bit for bit.

use std::fmt;
use std::sync::Arc;

use super::codec;
use super::collectives;
use super::kernels;
use super::pool;
use super::votes::{self, PackedVotes};
use crate::comm::faults::Attack;
use crate::comm::{CommModel, Topology};
use crate::runtime::ParamLayout;
use crate::util::rng::Rng;

/// Typed rejection of damaged wire data — the loud path for corruption
/// that IS detectable (non-finite quantization scales or dense
/// coordinates). Misuse of the API (mixed formats, length drift, a mean
/// over sign votes) stays a panic: that is a bug in the caller, not bad
/// data on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireError {
    /// A quantized payload carries a non-finite scale (NaN poison from a
    /// non-finite difference at pack time, or corruption in transit).
    NonFiniteScale {
        /// Index of the offending payload in the round's gather.
        worker: usize,
        /// Layout segment of the offending scale (0 for per-message q8).
        segment: usize,
    },
    /// A dense payload carries a non-finite coordinate.
    NonFiniteCoord {
        /// Index of the offending payload in the round's gather.
        worker: usize,
        /// Offending coordinate.
        index: usize,
    },
    /// A sparse top-k component names a coordinate outside the
    /// exchanged parameter vector (a corrupted index in transit — the
    /// detectable half of index damage; an in-range flip is a valid
    /// encoding and is survived like a flipped i8 byte).
    SparseIndexOutOfRange {
        /// Index of the offending payload in the round's gather.
        worker: usize,
        /// The out-of-range coordinate index carried on the wire.
        index: u32,
    },
    /// A framed byte message ([`WirePayload::encode_into`]) is shorter
    /// than its layout requires — truncated in transit.
    TruncatedFrame {
        /// Bytes the frame layout requires.
        needed: usize,
        /// Bytes actually received.
        got: usize,
    },
    /// A framed byte message carries bytes past its layout's end — a
    /// frame boundary was lost in transit.
    TrailingBytes {
        /// Bytes past the end of the decoded frame.
        extra: usize,
    },
    /// A frame's length-prefix header disagrees with the coordinate
    /// count both ends agreed on at construction (the static sizing
    /// contract — see [`WirePayload::decode`]).
    FrameHeaderMismatch {
        /// The agreed coordinate count.
        expected: u64,
        /// The count the frame header claims.
        got: u64,
    },
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::NonFiniteScale { worker, segment } => write!(
                f,
                "worker {worker}: non-finite quantization scale in segment {segment} \
                 (diverged rank or corrupted payload)"
            ),
            WireError::NonFiniteCoord { worker, index } => write!(
                f,
                "worker {worker}: non-finite coordinate {index} in dense payload \
                 (diverged rank or corrupted payload)"
            ),
            WireError::SparseIndexOutOfRange { worker, index } => write!(
                f,
                "worker {worker}: sparse component index {index} outside the \
                 parameter vector (corrupted payload)"
            ),
            WireError::TruncatedFrame { needed, got } => write!(
                f,
                "framed message truncated: layout requires {needed} bytes, got {got}"
            ),
            WireError::TrailingBytes { extra } => write!(
                f,
                "framed message carries {extra} bytes past the end of its layout"
            ),
            WireError::FrameHeaderMismatch { expected, got } => write!(
                f,
                "frame header claims {got} coordinates, the sizing contract says {expected}"
            ),
        }
    }
}

impl std::error::Error for WireError {}

/// Server-side aggregation policy over a round's surviving payloads
/// (`[outer] agg = "mean" | "trimmed" | "median"`).
///
/// `Mean` is the historical path: [`WirePayload::aggregate_end_into`]
/// delegates to [`WirePayload::mean_end_into`] so clean-path
/// trajectories stay bitwise unchanged. The robust policies defend the
/// aggregate against finite-but-wrong payloads from Byzantine ranks
/// (see the module docs for the attack × defense breakdown table):
/// both decode every survivor to an f64 end vector and combine
/// coordinate-wise over the sorted per-coordinate values, so the
/// result is permutation-invariant in the survivor order. Packed sign
/// votes ignore the policy — the majority tally IS the robust
/// aggregator.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AggPolicy {
    /// Plain mean over survivors — maximum statistical efficiency, zero
    /// breakdown point (one adversary owns the aggregate).
    #[default]
    Mean,
    /// Coordinate-wise trimmed mean: drop the [`AggPolicy::trim_depth`]
    /// smallest and largest values, mean the rest in f64. Tolerates up
    /// to `trim_depth(n)` arbitrary payloads per coordinate.
    Trimmed,
    /// Coordinate-wise median (even counts average the two middles in
    /// f64). Breakdown point ⌈n/2⌉ − 1, the best any
    /// permutation-invariant aggregator can do.
    Median,
}

impl AggPolicy {
    /// Parse a config-file / CLI name.
    pub fn parse(s: &str) -> Option<AggPolicy> {
        match s {
            "mean" => Some(AggPolicy::Mean),
            "trimmed" | "trimmed_mean" => Some(AggPolicy::Trimmed),
            "median" => Some(AggPolicy::Median),
            _ => None,
        }
    }

    /// Canonical config-file name (round-trips through
    /// [`AggPolicy::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            AggPolicy::Mean => "mean",
            AggPolicy::Trimmed => "trimmed",
            AggPolicy::Median => "median",
        }
    }

    /// Trim depth `k` of the trimmed mean over `n` survivors:
    /// `max(1, n/4)`, clamped so the kept slice stays non-empty
    /// (`2k < n`), and zero for `n ≤ 2` — with two payloads there is no
    /// third vote to out an outlier with, so trimming would just throw
    /// information away.
    pub fn trim_depth(n: usize) -> usize {
        if n <= 2 {
            return 0;
        }
        let mut k = (n / 4).max(1);
        while 2 * k >= n {
            k -= 1;
        }
        k
    }

    /// Combine one coordinate's decoded values across survivors.
    /// Sorts `vals` in place (f64 total order); the result depends only
    /// on the multiset, never the survivor order.
    fn combine(self, vals: &mut [f64]) -> f64 {
        vals.sort_by(|a, b| a.total_cmp(b));
        let n = vals.len();
        match self {
            AggPolicy::Mean => vals.iter().sum::<f64>() / n as f64,
            AggPolicy::Trimmed => {
                let k = Self::trim_depth(n);
                let kept = &vals[k..n - k];
                kept.iter().sum::<f64>() / kept.len() as f64
            }
            AggPolicy::Median => {
                if n % 2 == 1 {
                    vals[n / 2]
                } else {
                    0.5 * (vals[n / 2 - 1] + vals[n / 2])
                }
            }
        }
    }
}

/// Construction-time name of a [`WirePayload`] variant: what a config
/// file selects (`wire = "dense" | "packed_signs" | "q8" | "q8pt" |
/// "topk"`) and what the trainer sizes its persistent per-rank buffers
/// with. The top-k variant carries its keep fraction and residual
/// decay as parts-per-million integers so the format stays `Copy + Eq`
/// (the trainer's buffer-drift check compares formats exactly, and the
/// outer optimizers' supported-wire menus are `const` tables).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireFormat {
    /// Full-precision f32 parameters (the classic exchange).
    DenseF32,
    /// 1-bit sign votes ([`codec::pack_signs`], Algorithm 6's wire).
    PackedSigns,
    /// 8-bit symmetric-quantized local differences, one per-message
    /// scale ([`codec::quantize_diff_into`]).
    QuantizedI8,
    /// 8-bit symmetric-quantized local differences with one scale per
    /// [`ParamLayout`] segment ([`codec::quantize_diff_slice`]).
    QuantizedI8PerTensor,
    /// Sparse top-k of a decaying worker-side residual-momentum buffer,
    /// k per layout segment ([`codec::topk_budget`], DeMo-style — see
    /// the module docs).
    TopK {
        /// Keep fraction in parts per million of each segment's
        /// coordinates (62 500 = 1/16).
        frac_ppm: u32,
        /// Per-round residual decay in parts per million
        /// (900 000 = ×0.9 after every pack).
        decay_ppm: u32,
    },
}

impl WireFormat {
    /// Default top-k keep fraction: 1/16 of each segment's coordinates,
    /// putting the sparse message near `P/2` bytes — under the `~P` of
    /// [`WireFormat::QuantizedI8PerTensor`] on any layout.
    pub const TOPK_DEFAULT_FRAC_PPM: u32 = 62_500;

    /// Default residual decay: ×0.9 per round — carried mass re-competes
    /// for a few rounds, then fades instead of accumulating staleness.
    pub const TOPK_DEFAULT_DECAY_PPM: u32 = 900_000;

    /// The `topk` format at its default keep fraction and decay — what
    /// `wire = "topk"` parses to and what the supported-wire menus list.
    pub const TOPK_DEFAULT: WireFormat = WireFormat::TopK {
        frac_ppm: Self::TOPK_DEFAULT_FRAC_PPM,
        decay_ppm: Self::TOPK_DEFAULT_DECAY_PPM,
    };

    /// Parse a config-file / CLI name. `topk` parses to the default
    /// keep fraction and decay; config applies `topk_frac`/`topk_decay`
    /// overrides on top.
    pub fn parse(s: &str) -> Option<WireFormat> {
        match s {
            "dense" | "f32" => Some(WireFormat::DenseF32),
            "packed_signs" | "signs" | "1bit" => Some(WireFormat::PackedSigns),
            "q8" | "i8" | "quantized_i8" => Some(WireFormat::QuantizedI8),
            "q8pt" | "q8_per_tensor" | "i8pt" => Some(WireFormat::QuantizedI8PerTensor),
            "topk" | "top_k" | "demo" => Some(WireFormat::TOPK_DEFAULT),
            _ => None,
        }
    }

    /// Stable config-facing name (inverse of [`WireFormat::parse`] up
    /// to the top-k parameters, which parse to their defaults).
    pub fn name(&self) -> &'static str {
        match self {
            WireFormat::DenseF32 => "dense",
            WireFormat::PackedSigns => "packed_signs",
            WireFormat::QuantizedI8 => "q8",
            WireFormat::QuantizedI8PerTensor => "q8pt",
            WireFormat::TopK { .. } => "topk",
        }
    }

    /// Bytes one message of `len` coordinates in this format puts on
    /// the wire (what a sized [`WirePayload`] will report). `segments`
    /// is the parameter-layout segment count — it only affects the
    /// per-tensor format (one extra f32 scale each) and the top-k
    /// format (whose keep budget is per segment; this layout-free
    /// helper splits `len` into near-equal segments, exact at
    /// `segments == 1`, while a sized payload's own
    /// [`WirePayload::wire_bytes`] uses the true layout); pass 1 for
    /// layout-less analysis.
    pub fn wire_bytes(&self, len: usize, segments: usize) -> u64 {
        match self {
            WireFormat::DenseF32 => len as u64 * 4,
            WireFormat::PackedSigns => codec::sign_allreduce_bytes(len),
            WireFormat::QuantizedI8 => codec::q8_bytes(len),
            WireFormat::QuantizedI8PerTensor => codec::q8pt_bytes(len, segments),
            WireFormat::TopK { frac_ppm, .. } => {
                let s = segments.max(1);
                let (base, rem) = (len / s, len % s);
                let k: usize = (0..s)
                    .map(|i| codec::topk_budget(base + usize::from(i < rem), *frac_ppm))
                    .sum();
                codec::topk_bytes(k)
            }
        }
    }

    /// Whether a partial aggregate of this format fits back into the
    /// format itself — true only for dense f32, which therefore bills
    /// the ring all-reduce; compressed formats bill gather+broadcast
    /// (see the module docs).
    pub fn ring_reducible(&self) -> bool {
        matches!(self, WireFormat::DenseF32)
    }

    /// Modeled seconds of one round exchange of `len` coordinates over
    /// a `segments`-segment layout under `m` — the analytical
    /// re-costing twin of [`crate::comm::SimClock::charge_exchange`].
    /// Both route through [`Topology::select`] on (format, n): ring for
    /// the ring-reducible dense format, flat gather+broadcast for small
    /// compressed fleets, hierarchical at scale — so tables re-costed
    /// through this helper cannot drift from what the clock actually
    /// billed (pinned by `exchange_time_matches_the_clock_topology`).
    pub fn exchange_time(&self, m: &CommModel, n: usize, len: usize, segments: usize) -> f64 {
        let bytes = self.wire_bytes(len, segments);
        match Topology::select(self.ring_reducible(), n) {
            Topology::Ring => m.allreduce_time(n, bytes),
            Topology::FlatGatherBroadcast => {
                m.gather_time(n, bytes) + m.broadcast_time(n, bytes)
            }
            Topology::Hierarchical { groups } => m.hierarchical_time(n, groups, bytes),
        }
    }
}

/// One rank's round contribution, in exactly the bytes that cross the
/// simulated wire. Trainer-owned and persistent: the same buffers are
/// re-packed in place every round, so the steady-state exchange
/// allocates nothing in any format.
#[derive(Clone, Debug, PartialEq)]
pub enum WirePayload {
    /// The rank's end-of-round parameters, full precision.
    DenseF32(Vec<f32>),
    /// The rank's packed 1-bit sign votes.
    PackedSigns(PackedVotes),
    /// The rank's local difference `start - end`, quantized to i8 with
    /// a per-message scale ([`codec::quantize_diff_into`]).
    QuantizedI8 {
        /// Symmetric quantization step (`max |diff| / 127`).
        scale: f32,
        /// One two's-complement i8 per coordinate.
        bytes: Vec<u8>,
    },
    /// The rank's local difference `start - end`, quantized to i8 with
    /// one scale per segment of the parameter layout
    /// ([`codec::quantize_diff_slice`] per segment). The layout rides
    /// in the payload (shared, not serialized: the byte cost counts the
    /// scales, the segment boundaries are part of the static
    /// backend↔trainer contract both ends already hold).
    QuantizedI8PerTensor {
        /// The validated segment layout the scales follow.
        layout: Arc<ParamLayout>,
        /// Symmetric quantization step per segment
        /// (`max |diff over segment| / 127` each).
        scales: Vec<f32>,
        /// One two's-complement i8 per coordinate.
        bytes: Vec<u8>,
    },
    /// The top-k components of the rank's decaying residual-momentum
    /// buffer, selected per layout segment (see the module docs). The
    /// wire carries `indices` + `values`; the residual is worker state
    /// riding in the trainer's persistent buffer (checkpointed, never
    /// billed), and the layout/ppm parameters are part of the static
    /// config contract both ends already hold.
    TopK {
        /// The validated segment layout the keep budgets follow.
        layout: Arc<ParamLayout>,
        /// Keep fraction in parts per million ([`WireFormat::TopK`]).
        frac_ppm: u32,
        /// Per-round residual decay in parts per million.
        decay_ppm: u32,
        /// Global coordinate index of each kept component — exactly
        /// Σ_s [`codec::topk_budget`] entries, segment-major.
        indices: Vec<u32>,
        /// The transmitted residual value of each kept component.
        values: Vec<f32>,
        /// The untransmitted mass, one slot per coordinate: grows by
        /// `start − end` at each pack, loses what the wire takes,
        /// decays by `decay_ppm`.
        residual: Vec<f32>,
    },
}

/// Thread count for the mean-decode paths: the same auto policy the
/// f32 collectives use (threaded only past the dispatch-amortizing
/// threshold, capped at the pool size), so a small decode never pays
/// pool dispatch.
fn mean_decode_threads(len: usize) -> usize {
    match collectives::Backend::auto(len) {
        collectives::Backend::Sequential => 1,
        collectives::Backend::Threaded { threads } => threads,
    }
}

/// Zero-copy view of one framed byte message
/// ([`WirePayload::encode_into`] / [`WirePayload::decode`]): every
/// field is a borrowed sub-slice of the frame, so decoding allocates
/// nothing and copies nothing. Multi-byte fields stay byte slices
/// (little-endian) rather than `&[f32]`/`&[u32]` borrows because a
/// frame buffer carries no alignment guarantee; read them through
/// [`WirePayloadView::read_f32`] / [`WirePayloadView::read_u32`].
///
/// # Frame layouts (all integers little-endian)
///
/// | format | frame bytes, in order | total |
/// |---|---|---|
/// | `dense` | `P × f32` coordinates | `4P` |
/// | `packed_signs` | `u64` coordinate count, `⌈P/8⌉` vote bytes | `⌈P/8⌉ + 8` |
/// | `q8` | `u64` coordinate count, `f32` scale, `P × i8` | `P + 12` |
/// | `q8pt` | `u64` coordinate count, `S × f32` scales, `P × i8` | `P + 4S + 8` |
/// | `topk` | `u64` kept count `K`, `K × u32` indices, `K × f32` values | `8K + 8` |
///
/// Each layout's total is exactly the payload's
/// [`WirePayload::wire_bytes`] — the billed number IS the framed
/// length, asserted at encode time and test-pinned for every format.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WirePayloadView<'a> {
    /// `P` little-endian f32 coordinates.
    DenseF32 {
        /// The `4P`-byte coordinate body.
        body: &'a [u8],
    },
    /// Packed 1-bit sign votes.
    PackedSigns {
        /// Coordinate count from the frame header.
        len: usize,
        /// The `⌈len/8⌉` vote bytes (LSB-first, like
        /// [`PackedVotes::as_bytes`]).
        bits: &'a [u8],
    },
    /// Per-message-scale quantized differences.
    QuantizedI8 {
        /// The symmetric quantization step.
        scale: f32,
        /// One two's-complement i8 per coordinate.
        bytes: &'a [u8],
    },
    /// Per-segment-scale quantized differences.
    QuantizedI8PerTensor {
        /// `S` little-endian f32 scales.
        scales: &'a [u8],
        /// One two's-complement i8 per coordinate.
        bytes: &'a [u8],
    },
    /// Sparse top-k components.
    TopK {
        /// `K` little-endian u32 global coordinate indices.
        indices: &'a [u8],
        /// `K` little-endian f32 transmitted values.
        values: &'a [u8],
    },
}

impl WirePayloadView<'_> {
    /// The `i`-th little-endian f32 of a byte-packed field.
    pub fn read_f32(bytes: &[u8], i: usize) -> f32 {
        let b: [u8; 4] = bytes[i * 4..i * 4 + 4].try_into().expect("4-byte window");
        f32::from_le_bytes(b)
    }

    /// The `i`-th little-endian u32 of a byte-packed field.
    pub fn read_u32(bytes: &[u8], i: usize) -> u32 {
        let b: [u8; 4] = bytes[i * 4..i * 4 + 4].try_into().expect("4-byte window");
        u32::from_le_bytes(b)
    }

    /// Coordinates this frame speaks for (for `topk`: the kept
    /// component count `K`, the frame's own length prefix — the tiled
    /// coordinate count is the static contract's, not the frame's).
    pub fn frame_items(&self) -> usize {
        match self {
            WirePayloadView::DenseF32 { body } => body.len() / 4,
            WirePayloadView::PackedSigns { len, .. } => *len,
            WirePayloadView::QuantizedI8 { bytes, .. } => bytes.len(),
            WirePayloadView::QuantizedI8PerTensor { bytes, .. } => bytes.len(),
            WirePayloadView::TopK { indices, .. } => indices.len() / 4,
        }
    }
}

/// Check `frame` against its layout's exact byte length: truncation
/// and trailing garbage are both typed rejections, never a silent
/// short read.
fn check_frame_len(frame: &[u8], needed: usize) -> Result<(), WireError> {
    if frame.len() < needed {
        return Err(WireError::TruncatedFrame { needed, got: frame.len() });
    }
    if frame.len() > needed {
        return Err(WireError::TrailingBytes { extra: frame.len() - needed });
    }
    Ok(())
}

/// Read the little-endian u64 length prefix off the front of `frame`
/// (the caller has already length-checked the whole frame).
fn frame_header(frame: &[u8]) -> u64 {
    let h: [u8; 8] = frame[..8].try_into().expect("8-byte window");
    u64::from_le_bytes(h)
}

impl WirePayload {
    /// A zeroed payload of `len` coordinates in `format` — the initial
    /// state of the trainer's persistent buffers. Its
    /// [`wire_bytes`](Self::wire_bytes) is already final: the byte cost
    /// is a function of (format, len, layout) only, never of the packed
    /// contents, which is what lets the clock bill a round before the
    /// ranks pack into it. The per-tensor and top-k formats get the
    /// one-segment fallback layout here; use
    /// [`WirePayload::with_layout`] to size them from a real backend
    /// layout.
    pub fn with_len(format: WireFormat, len: usize) -> WirePayload {
        match format {
            WireFormat::DenseF32 => WirePayload::DenseF32(vec![0.0; len]),
            WireFormat::PackedSigns => WirePayload::PackedSigns(PackedVotes::with_len(len)),
            WireFormat::QuantizedI8 => {
                WirePayload::QuantizedI8 { scale: 0.0, bytes: vec![0; len] }
            }
            WireFormat::QuantizedI8PerTensor | WireFormat::TopK { .. } => {
                WirePayload::with_layout(format, &Arc::new(ParamLayout::single(len)))
            }
        }
    }

    /// A zeroed payload sized from a parameter layout — how the trainer
    /// builds its persistent buffers
    /// ([`crate::runtime::StepBackend::layout`]). Only the per-tensor
    /// format (one scale slot per segment) and the top-k format (one
    /// keep budget per segment, plus the coordinate-sized residual)
    /// actually store the layout; every other format just takes its
    /// coordinate count.
    pub fn with_layout(format: WireFormat, layout: &Arc<ParamLayout>) -> WirePayload {
        match format {
            WireFormat::QuantizedI8PerTensor => WirePayload::QuantizedI8PerTensor {
                scales: vec![0.0; layout.len()],
                bytes: vec![0; layout.param_count()],
                layout: Arc::clone(layout),
            },
            WireFormat::TopK { frac_ppm, decay_ppm } => {
                let k_total: usize = layout
                    .entries()
                    .iter()
                    .map(|e| codec::topk_budget(e.numel(), frac_ppm))
                    .sum();
                WirePayload::TopK {
                    layout: Arc::clone(layout),
                    frac_ppm,
                    decay_ppm,
                    indices: vec![0; k_total],
                    values: vec![0.0; k_total],
                    residual: vec![0.0; layout.param_count()],
                }
            }
            WireFormat::DenseF32 | WireFormat::PackedSigns | WireFormat::QuantizedI8 => {
                WirePayload::with_len(format, layout.param_count())
            }
        }
    }

    pub fn format(&self) -> WireFormat {
        match self {
            WirePayload::DenseF32(_) => WireFormat::DenseF32,
            WirePayload::PackedSigns(_) => WireFormat::PackedSigns,
            WirePayload::QuantizedI8 { .. } => WireFormat::QuantizedI8,
            WirePayload::QuantizedI8PerTensor { .. } => WireFormat::QuantizedI8PerTensor,
            WirePayload::TopK { frac_ppm, decay_ppm, .. } => {
                WireFormat::TopK { frac_ppm: *frac_ppm, decay_ppm: *decay_ppm }
            }
        }
    }

    /// Number of coordinates this payload carries (for the sparse
    /// top-k format: the coordinates of the parameter vector it tiles,
    /// not the kept-component count).
    pub fn len(&self) -> usize {
        match self {
            WirePayload::DenseF32(v) => v.len(),
            WirePayload::PackedSigns(p) => p.len(),
            WirePayload::QuantizedI8 { bytes, .. } => bytes.len(),
            WirePayload::QuantizedI8PerTensor { bytes, .. } => bytes.len(),
            WirePayload::TopK { residual, .. } => residual.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total bytes this message puts on the wire — the number the clock
    /// bills. By construction equal to
    /// `self.format().wire_bytes(self.len(), segments)` with `segments`
    /// the payload's own scale count.
    pub fn wire_bytes(&self) -> u64 {
        match self {
            WirePayload::DenseF32(v) => v.len() as u64 * 4,
            WirePayload::PackedSigns(p) => p.wire_bytes(),
            WirePayload::QuantizedI8 { bytes, .. } => codec::q8_bytes(bytes.len()),
            WirePayload::QuantizedI8PerTensor { scales, bytes, .. } => {
                codec::q8pt_bytes(bytes.len(), scales.len())
            }
            WirePayload::TopK { indices, .. } => codec::topk_bytes(indices.len()),
        }
    }

    /// Serialize this payload as one framed byte message (the layouts
    /// on [`WirePayloadView`]) into `out`, reusing its capacity: the
    /// steady-state encode allocates nothing once the buffer has grown
    /// to frame size. The encoded length is exactly
    /// [`WirePayload::wire_bytes`] — the billed number IS the framed
    /// length, debug-asserted here and test-pinned per format.
    ///
    /// What frames carry is the *wire data only*: the top-k residual is
    /// worker state and the `q8pt`/`topk` layouts are the static
    /// contract both ends already hold ([`WirePayload::decode`] takes
    /// them back as parameters), exactly like the byte accounting.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.clear();
        out.reserve(self.wire_bytes() as usize);
        match self {
            WirePayload::DenseF32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            WirePayload::PackedSigns(p) => {
                out.extend_from_slice(&(p.len() as u64).to_le_bytes());
                out.extend_from_slice(p.as_bytes());
            }
            WirePayload::QuantizedI8 { scale, bytes } => {
                out.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
                out.extend_from_slice(&scale.to_le_bytes());
                out.extend_from_slice(bytes);
            }
            WirePayload::QuantizedI8PerTensor { scales, bytes, .. } => {
                out.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
                for s in scales {
                    out.extend_from_slice(&s.to_le_bytes());
                }
                out.extend_from_slice(bytes);
            }
            WirePayload::TopK { indices, values, .. } => {
                out.extend_from_slice(&(indices.len() as u64).to_le_bytes());
                for ix in indices {
                    out.extend_from_slice(&ix.to_le_bytes());
                }
                for v in values {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
        debug_assert_eq!(
            out.len() as u64,
            self.wire_bytes(),
            "framed length must equal the billed wire bytes"
        );
    }

    /// Parse one framed byte message into a zero-copy
    /// [`WirePayloadView`] — every field borrows from `frame`, no
    /// intermediate `Vec` per field. `format` and `layout` are the
    /// static sizing contract both ends hold (what
    /// [`WirePayload::with_layout`] builds from; pass
    /// `ParamLayout::single(len)` for the layout-free formats), so the
    /// expected frame length is known exactly up front.
    ///
    /// # Errors
    ///
    /// [`WireError::TruncatedFrame`] when the frame is shorter than its
    /// layout requires, [`WireError::TrailingBytes`] when it runs past
    /// it, and [`WireError::FrameHeaderMismatch`] when the length
    /// prefix disagrees with the contract. Structural validation only:
    /// finiteness and sparse index ranges stay with
    /// [`WirePayload::check_finite`], on the decoded payload level.
    pub fn decode<'a>(
        format: WireFormat,
        layout: &ParamLayout,
        frame: &'a [u8],
    ) -> Result<WirePayloadView<'a>, WireError> {
        let p = layout.param_count();
        match format {
            WireFormat::DenseF32 => {
                check_frame_len(frame, p * 4)?;
                Ok(WirePayloadView::DenseF32 { body: frame })
            }
            WireFormat::PackedSigns => {
                check_frame_len(frame, codec::sign_allreduce_bytes(p) as usize)?;
                let got = frame_header(frame);
                if got != p as u64 {
                    return Err(WireError::FrameHeaderMismatch { expected: p as u64, got });
                }
                Ok(WirePayloadView::PackedSigns { len: p, bits: &frame[8..] })
            }
            WireFormat::QuantizedI8 => {
                check_frame_len(frame, codec::q8_bytes(p) as usize)?;
                let got = frame_header(frame);
                if got != p as u64 {
                    return Err(WireError::FrameHeaderMismatch { expected: p as u64, got });
                }
                let scale = WirePayloadView::read_f32(&frame[8..12], 0);
                Ok(WirePayloadView::QuantizedI8 { scale, bytes: &frame[12..] })
            }
            WireFormat::QuantizedI8PerTensor => {
                let s = layout.len();
                check_frame_len(frame, codec::q8pt_bytes(p, s) as usize)?;
                let got = frame_header(frame);
                if got != p as u64 {
                    return Err(WireError::FrameHeaderMismatch { expected: p as u64, got });
                }
                Ok(WirePayloadView::QuantizedI8PerTensor {
                    scales: &frame[8..8 + 4 * s],
                    bytes: &frame[8 + 4 * s..],
                })
            }
            WireFormat::TopK { frac_ppm, .. } => {
                let k: usize = layout
                    .entries()
                    .iter()
                    .map(|e| codec::topk_budget(e.numel(), frac_ppm))
                    .sum();
                check_frame_len(frame, codec::topk_bytes(k) as usize)?;
                let got = frame_header(frame);
                if got != k as u64 {
                    return Err(WireError::FrameHeaderMismatch { expected: k as u64, got });
                }
                Ok(WirePayloadView::TopK {
                    indices: &frame[8..8 + 4 * k],
                    values: &frame[8 + 4 * k..],
                })
            }
        }
    }

    /// See [`WireFormat::ring_reducible`].
    pub fn ring_reducible(&self) -> bool {
        self.format().ring_reducible()
    }

    /// The dense f32 view, when this is a [`WirePayload::DenseF32`].
    pub fn as_dense(&self) -> Option<&[f32]> {
        match self {
            WirePayload::DenseF32(v) => Some(v),
            WirePayload::PackedSigns(_)
            | WirePayload::QuantizedI8 { .. }
            | WirePayload::QuantizedI8PerTensor { .. }
            | WirePayload::TopK { .. } => None,
        }
    }

    /// The packed-vote view, when this is a [`WirePayload::PackedSigns`].
    pub fn as_packed_signs(&self) -> Option<&PackedVotes> {
        match self {
            WirePayload::PackedSigns(p) => Some(p),
            WirePayload::DenseF32(_)
            | WirePayload::QuantizedI8 { .. }
            | WirePayload::QuantizedI8PerTensor { .. }
            | WirePayload::TopK { .. } => None,
        }
    }

    /// The parameter layout a per-tensor or top-k payload was sized
    /// with.
    pub fn layout(&self) -> Option<&Arc<ParamLayout>> {
        match self {
            WirePayload::QuantizedI8PerTensor { layout, .. } => Some(layout),
            WirePayload::TopK { layout, .. } => Some(layout),
            WirePayload::DenseF32(_)
            | WirePayload::PackedSigns(_)
            | WirePayload::QuantizedI8 { .. } => None,
        }
    }

    /// The per-segment scales of a per-tensor payload (or the single
    /// per-message scale of a `q8` payload).
    pub fn scales(&self) -> Option<&[f32]> {
        match self {
            WirePayload::QuantizedI8 { scale, .. } => Some(std::slice::from_ref(scale)),
            WirePayload::QuantizedI8PerTensor { scales, .. } => Some(scales),
            WirePayload::DenseF32(_)
            | WirePayload::PackedSigns(_)
            | WirePayload::TopK { .. } => None,
        }
    }

    /// The worker-side residual-momentum buffer of a top-k payload:
    /// the untransmitted mass [`WirePayload::pack_end`] accumulates
    /// and decays. Worker state, not wire data — the trainer
    /// checkpoints it through this accessor so a resumed run replays
    /// the same sparse selections bit for bit.
    pub fn residual(&self) -> Option<&[f32]> {
        match self {
            WirePayload::TopK { residual, .. } => Some(residual),
            WirePayload::DenseF32(_)
            | WirePayload::PackedSigns(_)
            | WirePayload::QuantizedI8 { .. }
            | WirePayload::QuantizedI8PerTensor { .. } => None,
        }
    }

    /// Mutable view of the top-k residual buffer
    /// ([`WirePayload::residual`]) — the checkpoint-restore path.
    pub fn residual_mut(&mut self) -> Option<&mut [f32]> {
        match self {
            WirePayload::TopK { residual, .. } => Some(residual),
            WirePayload::DenseF32(_)
            | WirePayload::PackedSigns(_)
            | WirePayload::QuantizedI8 { .. }
            | WirePayload::QuantizedI8PerTensor { .. } => None,
        }
    }

    /// Worker-side packing shared by every dense-exchange outer
    /// optimizer: fill this payload with rank's end-of-round state in
    /// the payload's own format — the parameters themselves for
    /// `DenseF32`, the quantized difference `start - end` for the
    /// quantized formats (one scale per message for `QuantizedI8`, one
    /// per layout segment for `QuantizedI8PerTensor`), and for `TopK`
    /// the per-segment top-k of the residual buffer after adding
    /// `start - end` into it (what stays behind then decays — the
    /// module docs spell out the contract). Buffer capacity is reused;
    /// no allocation in steady state beyond the top-k selection's small
    /// per-call index scratch.
    ///
    /// # Panics
    ///
    /// On a `PackedSigns` buffer: a dense parameter exchange has no
    /// 1-bit encoding (config validation keeps this combination from
    /// ever being built — [`crate::config::RunConfig::validate`]). On a
    /// per-tensor or top-k buffer whose layout does not tile
    /// `start.len()`, or a dense buffer whose length differs from
    /// `end.len()` — the persistent buffer's size is the byte count the
    /// round was billed with, so silently resizing it here would defeat
    /// the trainer's pack-time drift check.
    pub fn pack_end(&mut self, start: &[f32], end: &[f32]) {
        match self {
            WirePayload::DenseF32(buf) => {
                assert_eq!(
                    buf.len(),
                    end.len(),
                    "pack_end: {} coordinates into a dense payload sized {}",
                    end.len(),
                    buf.len()
                );
                buf.copy_from_slice(end);
            }
            WirePayload::QuantizedI8 { scale, bytes } => {
                // the persistent buffer is already sized; the slice
                // variant keeps the hot path allocation-free (the
                // resizing `quantize_diff_into` is the cold-path /
                // test convenience — invlint W8 keeps it out of the
                // training loop)
                assert_eq!(
                    bytes.len(),
                    end.len(),
                    "pack_end: {} coordinates into a q8 payload sized {}",
                    end.len(),
                    bytes.len()
                );
                *scale = codec::quantize_diff_slice(start, end, bytes);
            }
            WirePayload::QuantizedI8PerTensor { layout, scales, bytes } => {
                assert_eq!(
                    start.len(),
                    layout.param_count(),
                    "pack_end: {} coordinates vs a layout tiling {}",
                    start.len(),
                    layout.param_count()
                );
                for (e, s) in layout.entries().iter().zip(scales.iter_mut()) {
                    let r = e.offset..e.offset + e.numel();
                    *s = codec::quantize_diff_slice(
                        &start[r.clone()],
                        &end[r.clone()],
                        &mut bytes[r],
                    );
                }
            }
            WirePayload::TopK { layout, frac_ppm, decay_ppm, indices, values, residual } => {
                assert_eq!(
                    start.len(),
                    layout.param_count(),
                    "pack_end: {} coordinates vs a layout tiling {}",
                    start.len(),
                    layout.param_count()
                );
                assert_eq!(
                    start.len(),
                    end.len(),
                    "pack_end: start has {} coordinates, end {}",
                    start.len(),
                    end.len()
                );
                for ((r, &s), &e) in residual.iter_mut().zip(start).zip(end) {
                    *r += s - e;
                }
                let mut scratch = Vec::new();
                let mut off = 0usize;
                for ent in layout.entries() {
                    let k = codec::topk_budget(ent.numel(), *frac_ppm);
                    let seg = ent.offset..ent.offset + ent.numel();
                    codec::topk_select_segment(
                        &mut residual[seg],
                        ent.offset,
                        &mut indices[off..off + k],
                        &mut values[off..off + k],
                        &mut scratch,
                    );
                    off += k;
                }
                debug_assert_eq!(off, indices.len(), "segment budgets must tile the payload");
                let decay = *decay_ppm as f32 / 1e6;
                for r in residual.iter_mut() {
                    *r *= decay;
                }
            }
            WirePayload::PackedSigns(_) => {
                panic!("a dense parameter exchange cannot pack into a packed_signs payload")
            }
        }
    }

    /// Worker-side packing for sign-vote optimizers: pack the ±1 vote
    /// vector at 1 bit/coordinate ([`PackedVotes::pack_into`]).
    ///
    /// # Panics
    ///
    /// On a dense or quantized buffer — sign votes only have the 1-bit
    /// encoding (again unreachable under a validated config).
    pub fn pack_sign_votes(&mut self, votes: &[f32]) {
        let format = self.format();
        match self {
            WirePayload::PackedSigns(p) => p.pack_into(votes),
            WirePayload::DenseF32(_)
            | WirePayload::QuantizedI8 { .. }
            | WirePayload::QuantizedI8PerTensor { .. }
            | WirePayload::TopK { .. } => {
                panic!("sign votes need a packed_signs payload, got {}", format.name())
            }
        }
    }

    /// Server-side reconstruction of the round's average end point
    /// `x̄_{t,τ}` from the gathered payloads, into `out`:
    ///
    /// * `DenseF32` — the exact mean of the rank parameters, computed
    ///   by the same [`collectives::allreduce_mean`] arithmetic (f64
    ///   accumulation in rank order) the trainer historically used, so
    ///   the dense path is bitwise-identical to the pre-payload
    ///   semantics by construction.
    /// * `QuantizedI8` — `start - mean_i(dequantize(payload_i))`: each
    ///   rank's difference decodes with its own scale, is averaged in
    ///   f64 in rank order, and re-anchors at the round start.
    /// * `QuantizedI8PerTensor` — same arithmetic, but each coordinate
    ///   decodes with its **segment's** scale. Iteration is segment-
    ///   major in layout (= coordinate) order, so with a one-segment
    ///   layout the accumulation order — and hence the result — is
    ///   bitwise-identical to `QuantizedI8`.
    /// * `TopK` — `start - mean_i(scatter(payload_i))`: each rank's
    ///   sparse components accumulate into a dense f64 vector by index
    ///   in rank order (untransmitted coordinates contribute zero — the
    ///   mass they are missing is still in the ranks' residual buffers
    ///   and re-competes next round), divided by `n_effective` like the
    ///   other dense-exchange formats.
    ///
    /// The divisor is `payloads.len()` — the round's `n_effective` —
    /// so the mean is well defined for any non-empty survivor set under
    /// dropped/rejected payloads.
    ///
    /// # Errors
    ///
    /// [`WireError::NonFiniteScale`] if any quantized payload carries a
    /// non-finite scale (NaN poison from a diverged rank, or corruption
    /// in transit): bad data must never be silently averaged in.
    /// [`WireError::NonFiniteCoord`] / [`WireError::SparseIndexOutOfRange`]
    /// if a top-k payload carries a non-finite value or an index
    /// outside the parameter vector. Every check runs before any
    /// accumulation — `out` is untouched on error. Dense payloads carry
    /// no scale; a non-finite dense coordinate propagates into the
    /// mean, where the trainer's finiteness check catches it (reject
    /// dense payloads up front with [`WirePayload::check_finite`] when
    /// faults are in play).
    ///
    /// # Panics
    ///
    /// On `PackedSigns` payloads (a majority tally has no mean end
    /// point — tally them with
    /// [`crate::dist::votes::majority_vote_packed`]), on mixed formats
    /// or mixed layouts, or on length mismatches — API misuse, not wire
    /// damage.
    pub fn mean_end_into(
        payloads: &[WirePayload],
        start: &[f32],
        out: &mut [f32],
    ) -> Result<(), WireError> {
        assert!(!payloads.is_empty(), "exchange over zero workers");
        for (i, p) in payloads.iter().enumerate() {
            assert_eq!(p.format(), payloads[0].format(), "worker {i}: mixed wire formats");
            assert_eq!(
                p.len(),
                out.len(),
                "worker {i}: payload length {} != output {}",
                p.len(),
                out.len()
            );
        }
        // reject non-finite scales before touching `out`: O(S) per
        // payload, and the poison never reaches the accumulator
        for (i, p) in payloads.iter().enumerate() {
            if let Some(scales) = p.scales() {
                for (si, s) in scales.iter().enumerate() {
                    if !s.is_finite() {
                        return Err(WireError::NonFiniteScale { worker: i, segment: si });
                    }
                }
            }
        }
        match payloads[0] {
            WirePayload::DenseF32(_) => {
                collectives::allreduce_mean(
                    payloads,
                    |p| match p.as_dense() {
                        Some(v) => v,
                        None => unreachable!("format checked above"),
                    },
                    out,
                );
            }
            WirePayload::QuantizedI8 { .. } => {
                assert_eq!(start.len(), out.len(), "start length {} != output", start.len());
                let inv_n = 1.0f64 / payloads.len() as f64;
                // Payload-major decode: each payload's byte vector
                // streams once through `kernels::dequant_accumulate`
                // instead of being random-accessed per coordinate.
                // Every coordinate still sums its dequantized values in
                // payload order into an f64 slot, so the result is
                // bitwise-identical to the historical coordinate-major
                // loop — and independent of the chunking, which lets
                // large decodes split across the pool.
                let threads = mean_decode_threads(out.len());
                pool::run_chunked_mut(threads, 1, out, |base, chunk| {
                    let mut acc = vec![0.0f64; chunk.len()];
                    for p in payloads {
                        let WirePayload::QuantizedI8 { scale, bytes } = p else {
                            unreachable!("format checked above")
                        };
                        kernels::dequant_accumulate(
                            &bytes[base..base + chunk.len()],
                            *scale,
                            &mut acc,
                        );
                    }
                    for (j, o) in chunk.iter_mut().enumerate() {
                        *o = start[base + j] - (acc[j] * inv_n) as f32;
                    }
                });
            }
            WirePayload::QuantizedI8PerTensor { .. } => {
                assert_eq!(start.len(), out.len(), "start length {} != output", start.len());
                let WirePayload::QuantizedI8PerTensor { layout, .. } = &payloads[0] else {
                    unreachable!("format checked above")
                };
                // a layout tiling fewer coordinates than the payload
                // carries would leave out's tail stale below — reject
                // inconsistent hand-built payloads loudly instead
                assert_eq!(
                    layout.param_count(),
                    out.len(),
                    "payload layout tiles {} of {} coordinates",
                    layout.param_count(),
                    out.len()
                );
                for (i, p) in payloads.iter().enumerate() {
                    assert_eq!(p.layout(), Some(layout), "worker {i}: mixed parameter layouts");
                }
                let inv_n = 1.0f64 / payloads.len() as f64;
                // Same payload-major restructure as the q8 arm, with
                // chunk boundaries snapped to segment ends so every
                // (segment, scale) pair decodes on one thread. Each
                // coordinate's f64 sum still runs in payload order, so
                // the chunking cannot change a bit — and a one-segment
                // layout still reproduces the q8 arm exactly.
                let entries = layout.entries();
                let bounds: Vec<usize> = entries.iter().map(|e| e.offset + e.numel()).collect();
                let threads = mean_decode_threads(out.len());
                pool::run_segmented_mut(threads, &bounds, out, |base, chunk| {
                    let mut acc = vec![0.0f64; chunk.len()];
                    for p in payloads {
                        let WirePayload::QuantizedI8PerTensor { scales, bytes, .. } = p else {
                            unreachable!("format checked above")
                        };
                        for (si, e) in entries.iter().enumerate() {
                            if e.offset < base || e.offset >= base + chunk.len() {
                                continue;
                            }
                            let r = e.offset..e.offset + e.numel();
                            kernels::dequant_accumulate(
                                &bytes[r],
                                scales[si],
                                &mut acc[e.offset - base..e.offset - base + e.numel()],
                            );
                        }
                    }
                    for (j, o) in chunk.iter_mut().enumerate() {
                        *o = start[base + j] - (acc[j] * inv_n) as f32;
                    }
                });
            }
            WirePayload::TopK { .. } => {
                assert_eq!(start.len(), out.len(), "start length {} != output", start.len());
                let WirePayload::TopK { layout, .. } = &payloads[0] else {
                    unreachable!("format checked above")
                };
                assert_eq!(
                    layout.param_count(),
                    out.len(),
                    "payload layout tiles {} of {} coordinates",
                    layout.param_count(),
                    out.len()
                );
                for (i, p) in payloads.iter().enumerate() {
                    assert_eq!(p.layout(), Some(layout), "worker {i}: mixed parameter layouts");
                }
                // sparse components are fully validated before any
                // accumulation: a NaN value or out-of-range index must
                // never touch `out`
                for (i, p) in payloads.iter().enumerate() {
                    p.check_finite(i)?;
                }
                let inv_n = 1.0f64 / payloads.len() as f64;
                let mut acc = vec![0.0f64; out.len()];
                for p in payloads {
                    let WirePayload::TopK { indices, values, .. } = p else {
                        unreachable!("format checked above")
                    };
                    for (&ix, &v) in indices.iter().zip(values) {
                        acc[ix as usize] += v as f64;
                    }
                }
                for (i, o) in out.iter_mut().enumerate() {
                    *o = start[i] - (acc[i] * inv_n) as f32;
                }
            }
            WirePayload::PackedSigns(_) => {
                panic!("packed sign votes have no mean end point; run the majority tally")
            }
        }
        Ok(())
    }

    /// Policy-selected reconstruction of the round's aggregate end
    /// point from the gathered payloads, into `out`.
    ///
    /// [`AggPolicy::Mean`] delegates to [`WirePayload::mean_end_into`]
    /// — same function, same arithmetic, bitwise-identical results —
    /// so a `agg = "mean"` run cannot drift from the historical
    /// trajectories. The robust policies decode every survivor to a
    /// dense f64 end vector first (`start − diff` for the compressed
    /// formats; untransmitted top-k coordinates decode to the round
    /// start, i.e. an implicit zero diff, which is exactly the trimmed
    /// index-union merge — an adversary cannot hide an outlier by
    /// *omitting* coordinates) and then combine coordinate-wise over
    /// the sorted values ([`AggPolicy::combine`]).
    ///
    /// # Errors / panics
    ///
    /// Exactly [`WirePayload::mean_end_into`]'s: non-finite scales,
    /// values, or out-of-range sparse indices are typed errors checked
    /// before any accumulation (`out` is untouched on error); packed
    /// sign votes, mixed formats/layouts, and length drift panic.
    pub fn aggregate_end_into(
        agg: AggPolicy,
        payloads: &[WirePayload],
        start: &[f32],
        out: &mut [f32],
    ) -> Result<(), WireError> {
        if agg == AggPolicy::Mean {
            return Self::mean_end_into(payloads, start, out);
        }
        assert!(!payloads.is_empty(), "exchange over zero workers");
        for (i, p) in payloads.iter().enumerate() {
            assert_eq!(p.format(), payloads[0].format(), "worker {i}: mixed wire formats");
            assert_eq!(
                p.len(),
                out.len(),
                "worker {i}: payload length {} != output {}",
                p.len(),
                out.len()
            );
        }
        let ends = Self::decode_ends_f64(payloads, start, out.len())?;
        let mut col = vec![0.0f64; ends.len()];
        for (i, o) in out.iter_mut().enumerate() {
            for (c, e) in col.iter_mut().zip(&ends) {
                *c = e[i];
            }
            *o = agg.combine(&mut col) as f32;
        }
        Ok(())
    }

    /// Decode every payload to a dense f64 end vector for the robust
    /// aggregation policies, running the same validation the mean path
    /// runs (scale finiteness, layout consistency, sparse bounds)
    /// before any value is produced.
    fn decode_ends_f64(
        payloads: &[WirePayload],
        start: &[f32],
        len: usize,
    ) -> Result<Vec<Vec<f64>>, WireError> {
        for (i, p) in payloads.iter().enumerate() {
            if let Some(scales) = p.scales() {
                for (si, s) in scales.iter().enumerate() {
                    if !s.is_finite() {
                        return Err(WireError::NonFiniteScale { worker: i, segment: si });
                    }
                }
            }
        }
        if !matches!(payloads[0], WirePayload::DenseF32(_)) {
            assert_eq!(start.len(), len, "start length {} != output", start.len());
        }
        if let Some(layout) = payloads[0].layout() {
            assert_eq!(
                layout.param_count(),
                len,
                "payload layout tiles {} of {} coordinates",
                layout.param_count(),
                len
            );
            for (i, p) in payloads.iter().enumerate() {
                assert_eq!(p.layout(), Some(layout), "worker {i}: mixed parameter layouts");
            }
        }
        let mut ends = Vec::with_capacity(payloads.len());
        for (i, p) in payloads.iter().enumerate() {
            let end: Vec<f64> = match p {
                WirePayload::DenseF32(v) => v.iter().map(|&e| e as f64).collect(),
                WirePayload::QuantizedI8 { scale, bytes } => bytes
                    .iter()
                    .zip(start)
                    .map(|(&b, &s)| s as f64 - codec::dequantize_i8(b, *scale) as f64)
                    .collect(),
                WirePayload::QuantizedI8PerTensor { layout, scales, bytes } => {
                    let mut end = vec![0.0f64; len];
                    for (si, e) in layout.entries().iter().enumerate() {
                        for j in e.offset..e.offset + e.numel() {
                            end[j] = start[j] as f64
                                - codec::dequantize_i8(bytes[j], scales[si]) as f64;
                        }
                    }
                    end
                }
                WirePayload::TopK { indices, values, .. } => {
                    p.check_finite(i)?;
                    let mut end: Vec<f64> = start.iter().map(|&s| s as f64).collect();
                    for (&ix, &v) in indices.iter().zip(values) {
                        end[ix as usize] -= v as f64;
                    }
                    end
                }
                WirePayload::PackedSigns(_) => {
                    panic!("packed sign votes have no robust end point; run the majority tally")
                }
            };
            ends.push(end);
        }
        Ok(ends)
    }

    /// Validate that this payload carries no detectably damaged data:
    /// scales for the quantized formats (O(S)), every coordinate for
    /// dense (O(P) — only worth paying when faults are in play), values
    /// **and index ranges** for the sparse top-k format (O(K)), and
    /// nothing for packed signs (every bit pattern is a valid vote).
    /// `worker` is the payload's index in the round's gather, reported
    /// in the error. This is the pack-time half of the corruption
    /// contract; [`WirePayload::mean_end_into`] re-checks at decode
    /// time.
    pub fn check_finite(&self, worker: usize) -> Result<(), WireError> {
        match self {
            WirePayload::DenseF32(v) => {
                if let Some(index) = v.iter().position(|x| !x.is_finite()) {
                    return Err(WireError::NonFiniteCoord { worker, index });
                }
            }
            WirePayload::PackedSigns(_) => {}
            WirePayload::QuantizedI8 { scale, .. } => {
                if !scale.is_finite() {
                    return Err(WireError::NonFiniteScale { worker, segment: 0 });
                }
            }
            WirePayload::QuantizedI8PerTensor { scales, .. } => {
                if let Some(segment) = scales.iter().position(|s| !s.is_finite()) {
                    return Err(WireError::NonFiniteScale { worker, segment });
                }
            }
            WirePayload::TopK { indices, values, residual, .. } => {
                let n = residual.len();
                if let Some(&index) = indices.iter().find(|&&ix| ix as usize >= n) {
                    return Err(WireError::SparseIndexOutOfRange { worker, index });
                }
                if let Some(pos) = values.iter().position(|v| !v.is_finite()) {
                    return Err(WireError::NonFiniteCoord {
                        worker,
                        index: indices[pos] as usize,
                    });
                }
            }
        }
        Ok(())
    }

    /// Inject one transit corruption into this payload, fault-plan
    /// style: a NaN-poisoned scale, coordinate, or sparse value
    /// (detectable — fails [`WirePayload::check_finite`]) or a flipped
    /// quantized byte / sign bit / sparse index bit (a valid encoding
    /// wherever it lands in range, survived with bounded error; a
    /// flipped index that leaves the parameter vector is detected).
    /// Formats with both failure modes pick one with a fair draw.
    ///
    /// Returns whether damage actually landed: the fault accounting
    /// must count injections that happened, not attempts — a payload
    /// with nothing to damage (zero coordinates, or a per-tensor
    /// payload with no scale slots on the poison branch) reports
    /// `false` and stays untouched. The RNG draw sequence is fixed per
    /// format — every arm makes the same draws whatever the payload
    /// shape or branch taken — so fault-stream positions (and with
    /// them resumed trajectories) cannot depend on payload contents.
    #[must_use = "count only injections that landed"]
    pub fn corrupt(&mut self, rng: &mut Rng) -> bool {
        match self {
            WirePayload::DenseF32(v) => {
                let i = rng.below(v.len().max(1) as u64) as usize;
                if v.is_empty() {
                    return false;
                }
                v[i] = f32::NAN;
                true
            }
            WirePayload::PackedSigns(p) => {
                let coord = rng.below(p.len().max(1) as u64) as usize;
                if p.is_empty() {
                    return false;
                }
                p.flip_bit(coord);
                true
            }
            WirePayload::QuantizedI8 { scale, bytes } => {
                let poison = rng.bernoulli(0.5);
                let i = rng.below(bytes.len().max(1) as u64) as usize;
                let bit = rng.below(8);
                if poison || bytes.is_empty() {
                    *scale = f32::NAN;
                } else {
                    bytes[i] ^= 1 << bit;
                }
                true
            }
            WirePayload::QuantizedI8PerTensor { scales, bytes, .. } => {
                let poison = rng.bernoulli(0.5);
                let si = rng.below(scales.len().max(1) as u64) as usize;
                let i = rng.below(bytes.len().max(1) as u64) as usize;
                let bit = rng.below(8);
                if poison || bytes.is_empty() {
                    // the poison needs a scale slot to land in; with
                    // none this is honestly a no-op, not an injection
                    match scales.get_mut(si) {
                        Some(s) => {
                            *s = f32::NAN;
                            true
                        }
                        None => false,
                    }
                } else {
                    bytes[i] ^= 1 << bit;
                    true
                }
            }
            WirePayload::TopK { indices, values, .. } => {
                let poison = rng.bernoulli(0.5);
                let i = rng.below(values.len().max(1) as u64) as usize;
                let bit = rng.below(32);
                if values.is_empty() {
                    return false;
                }
                if poison {
                    values[i] = f32::NAN;
                } else {
                    indices[i] ^= 1 << bit;
                }
                true
            }
        }
    }

    /// Rewrite this payload as a Byzantine adversary would, in place —
    /// the wire half of the adversary model
    /// ([`crate::comm::faults::FaultPlan::byzantine_frac`]). Unlike
    /// [`WirePayload::corrupt`], the result is always a *finite, valid*
    /// encoding: it passes [`WirePayload::check_finite`] by
    /// construction, so only a robust [`AggPolicy`] (or the sign
    /// tally's built-in majority) stands between it and the aggregate.
    /// Deterministic — no RNG; the one randomized attack
    /// ([`Attack::Flaky`]) resolves its per-round coin on the trainer's
    /// fault stream *before* this call, to honest (no call) or
    /// [`Attack::SignFlip`].
    ///
    /// Per attack (`diff` is the transmitted local difference
    /// `start − end`):
    ///
    /// * `SignFlip` — negate the diff: dense ends reflect around
    ///   `start` (`e ↦ 2·start − e`), q8/q8pt negate their scale(s),
    ///   top-k negates its transmitted values, sign votes flip every
    ///   bit.
    /// * `ScaleInflate` — inflate the diff ×64: dense ends stretch from
    ///   `start`, scales and sparse values multiply. A no-op on packed
    ///   signs — the 1-bit wire carries no magnitude to inflate, which
    ///   is exactly the tally's immunity.
    /// * `ColludeFixed` — every colluder claims the identical
    ///   `diff ≡ +1`: dense `e = start − 1`, q8/q8pt bytes 127 at scale
    ///   1/127, top-k values pinned to +1 (at the rank's own indices),
    ///   sign votes unanimously +1.
    ///
    /// # Panics
    ///
    /// On [`Attack::Flaky`] (resolve the coin first) and on a
    /// dense-payload length drifting from `start` — API misuse.
    pub fn byzantine(&mut self, attack: Attack, start: &[f32]) {
        const INFLATE: f32 = 64.0;
        if let WirePayload::DenseF32(v) = self {
            assert_eq!(v.len(), start.len(), "dense payload length {} != start", v.len());
        }
        match attack {
            Attack::SignFlip => match self {
                WirePayload::DenseF32(v) => {
                    for (e, &s) in v.iter_mut().zip(start) {
                        *e = 2.0 * s - *e;
                    }
                }
                WirePayload::PackedSigns(p) => p.flip_all(),
                WirePayload::QuantizedI8 { scale, .. } => *scale = -*scale,
                WirePayload::QuantizedI8PerTensor { scales, .. } => {
                    for s in scales {
                        *s = -*s;
                    }
                }
                WirePayload::TopK { values, .. } => {
                    for v in values {
                        *v = -*v;
                    }
                }
            },
            Attack::ScaleInflate => match self {
                WirePayload::DenseF32(v) => {
                    for (e, &s) in v.iter_mut().zip(start) {
                        *e = s + INFLATE * (*e - s);
                    }
                }
                WirePayload::PackedSigns(_) => {}
                WirePayload::QuantizedI8 { scale, .. } => *scale *= INFLATE,
                WirePayload::QuantizedI8PerTensor { scales, .. } => {
                    for s in scales {
                        *s *= INFLATE;
                    }
                }
                WirePayload::TopK { values, .. } => {
                    for v in values {
                        *v *= INFLATE;
                    }
                }
            },
            Attack::ColludeFixed => match self {
                WirePayload::DenseF32(v) => {
                    for (e, &s) in v.iter_mut().zip(start) {
                        *e = s - 1.0;
                    }
                }
                WirePayload::PackedSigns(p) => p.set_all(true),
                WirePayload::QuantizedI8 { scale, bytes } => {
                    *scale = 1.0 / 127.0;
                    bytes.fill(127);
                }
                WirePayload::QuantizedI8PerTensor { scales, bytes, .. } => {
                    scales.fill(1.0 / 127.0);
                    bytes.fill(127);
                }
                WirePayload::TopK { values, .. } => values.fill(1.0),
            },
            Attack::Flaky => {
                panic!("flaky resolves on the fault stream to honest or sign_flip before the wire")
            }
        }
    }

    /// The hierarchical exchange's data path: split the round's
    /// payloads into `groups` contiguous groups of ⌈len/groups⌉ (the
    /// same split [`crate::comm::CommModel::hierarchical_time`] bills),
    /// aggregate each group at its head in the payload's own format,
    /// and return one payload per *input slot* holding its group head's
    /// aggregate. Feeding that replicated vector to the ordinary
    /// n-effective aggregation (mean or tally) weights each group by
    /// its member count — majority-of-weighted-majorities for votes,
    /// group-size-weighted mean of group means for the i8 formats — so
    /// outer optimizers consume a hierarchical round through their
    /// unchanged `apply(payloads)` interface.
    ///
    /// Per-format head aggregation:
    ///
    /// * `QuantizedI8` / `QuantizedI8PerTensor` — decode each member's
    ///   difference with its own scale(s), mean in f64 in member order,
    ///   re-quantize against a fresh head scale
    ///   ([`codec::quantize_slice`], per segment for `q8pt`). One extra
    ///   bounded quantization error per level — the price of a partial
    ///   aggregate that fits back into the wire format.
    /// * `PackedSigns` — partial majority tally over the group
    ///   ([`votes::majority_vote_packed`]), repacked as a ±1 vote
    ///   payload (wire-tie semantics: group ties decode +1).
    /// * `TopK` — index-union mean in member order, re-truncated to
    ///   each segment's k-budget by |value| (ties broken by index), so
    ///   the head transmits exactly the bytes one member would. A
    ///   segment whose union come up short of its budget pads with
    ///   zero-valued components at the segment base — the component
    ///   count, and with it `wire_bytes()`, is a function of the layout
    ///   alone. Mass the re-truncation drops is lost for the round
    ///   (the head has no residual buffer of its own); that is the
    ///   hierarchy's bounded approximation for sparse payloads.
    ///
    /// Under a robust `agg` policy ([`AggPolicy::Trimmed`] /
    /// [`AggPolicy::Median`]) each head replaces its member-order mean
    /// with the coordinate-wise robust combine over its own members
    /// (implicit zeros for top-k coordinates a member did not
    /// transmit), then re-encodes as before — so a Byzantine member is
    /// voted out *inside its group*, before its damage can reach the
    /// top-level exchange. [`AggPolicy::Mean`] keeps the historical
    /// arithmetic bitwise. Sign-vote heads tally under every policy.
    ///
    /// # Panics
    ///
    /// On dense payloads (ring-reducible — the hierarchy is never
    /// selected for them), on empty/mixed inputs, and on
    /// `groups == 0`: misuse, not wire damage. Callers must
    /// [`check_finite`](Self::check_finite) survivors first; a NaN
    /// scale here would poison the head's re-quantization.
    pub fn aggregate_group_heads(
        payloads: &[WirePayload],
        groups: usize,
        agg: AggPolicy,
    ) -> Vec<WirePayload> {
        assert!(!payloads.is_empty(), "hierarchical aggregation over zero payloads");
        assert!(groups > 0, "hierarchical aggregation needs at least one group");
        let format = payloads[0].format();
        let len = payloads[0].len();
        for (i, p) in payloads.iter().enumerate() {
            assert_eq!(p.format(), format, "worker {i}: mixed wire formats");
            assert_eq!(p.len(), len, "worker {i}: payload length {} != {len}", p.len());
        }
        assert!(
            !format.ring_reducible(),
            "dense exchanges ring-reduce; the hierarchy is never selected for them"
        );
        let m = super::div_up(payloads.len(), groups.min(payloads.len()));
        let mut out = Vec::with_capacity(payloads.len());
        for chunk in payloads.chunks(m) {
            let head = Self::aggregate_head(chunk, len, agg);
            for _ in 0..chunk.len() - 1 {
                out.push(head.clone());
            }
            out.push(head);
        }
        out
    }

    /// One group head's partial aggregate over its members' payloads.
    fn aggregate_head(chunk: &[WirePayload], len: usize, agg: AggPolicy) -> WirePayload {
        let inv = 1.0f64 / chunk.len() as f64;
        match &chunk[0] {
            WirePayload::QuantizedI8 { .. } => {
                let q8_at = |p: &WirePayload, i: usize| {
                    let WirePayload::QuantizedI8 { scale, bytes } = p else {
                        unreachable!("format checked by the caller")
                    };
                    codec::dequantize_i8(bytes[i], *scale) as f64
                };
                let mut mean = vec![0.0f32; len];
                if agg == AggPolicy::Mean {
                    let mut acc = vec![0.0f64; len];
                    for p in chunk {
                        for (i, a) in acc.iter_mut().enumerate() {
                            *a += q8_at(p, i);
                        }
                    }
                    for (m, a) in mean.iter_mut().zip(&acc) {
                        *m = (a * inv) as f32;
                    }
                } else {
                    let mut col = vec![0.0f64; chunk.len()];
                    for (i, m) in mean.iter_mut().enumerate() {
                        for (c, p) in col.iter_mut().zip(chunk) {
                            *c = q8_at(p, i);
                        }
                        *m = agg.combine(&mut col) as f32;
                    }
                }
                let mut bytes = vec![0u8; len];
                let scale = codec::quantize_slice(&mean, &mut bytes);
                WirePayload::QuantizedI8 { scale, bytes }
            }
            WirePayload::QuantizedI8PerTensor { layout, .. } => {
                let layout = Arc::clone(layout);
                for (i, p) in chunk.iter().enumerate() {
                    assert_eq!(
                        p.layout(),
                        Some(&layout),
                        "worker {i}: mixed parameter layouts"
                    );
                }
                let q8pt_at = |p: &WirePayload, si: usize, i: usize| {
                    let WirePayload::QuantizedI8PerTensor { scales, bytes, .. } = p else {
                        unreachable!("format checked by the caller")
                    };
                    codec::dequantize_i8(bytes[i], scales[si]) as f64
                };
                let mut mean = vec![0.0f32; len];
                if agg == AggPolicy::Mean {
                    let mut acc = vec![0.0f64; len];
                    for p in chunk {
                        for (si, e) in layout.entries().iter().enumerate() {
                            for i in e.offset..e.offset + e.numel() {
                                acc[i] += q8pt_at(p, si, i);
                            }
                        }
                    }
                    for (m, a) in mean.iter_mut().zip(&acc) {
                        *m = (a * inv) as f32;
                    }
                } else {
                    let mut col = vec![0.0f64; chunk.len()];
                    for (si, e) in layout.entries().iter().enumerate() {
                        for i in e.offset..e.offset + e.numel() {
                            for (c, p) in col.iter_mut().zip(chunk) {
                                *c = q8pt_at(p, si, i);
                            }
                            mean[i] = agg.combine(&mut col) as f32;
                        }
                    }
                }
                let mut bytes = vec![0u8; len];
                let mut scales = vec![0.0f32; layout.len()];
                for (e, s) in layout.entries().iter().zip(scales.iter_mut()) {
                    let r = e.offset..e.offset + e.numel();
                    *s = codec::quantize_slice(&mean[r.clone()], &mut bytes[r]);
                }
                WirePayload::QuantizedI8PerTensor { layout, scales, bytes }
            }
            WirePayload::PackedSigns(_) => {
                let members: Vec<&PackedVotes> = chunk
                    .iter()
                    .map(|p| match p.as_packed_signs() {
                        Some(v) => v,
                        None => unreachable!("format checked by the caller"),
                    })
                    .collect();
                let mut tally = vec![0.0f32; len];
                votes::majority_vote_packed(&members, &mut tally);
                WirePayload::PackedSigns(PackedVotes::pack(&tally))
            }
            WirePayload::TopK { layout, frac_ppm, decay_ppm, .. } => {
                let layout = Arc::clone(layout);
                let (frac_ppm, decay_ppm) = (*frac_ppm, *decay_ppm);
                for (i, p) in chunk.iter().enumerate() {
                    assert_eq!(
                        p.layout(),
                        Some(&layout),
                        "worker {i}: mixed parameter layouts"
                    );
                }
                // Index-union accumulate in member order: f64 keeps the
                // mean deterministic and exact enough that re-truncation
                // order can't flip on rounding noise. Robust policies
                // keep one column per member instead (implicit zero for
                // coordinates a member did not transmit) and combine
                // per union index.
                let mut combined = std::collections::BTreeMap::<u32, f64>::new();
                if agg == AggPolicy::Mean {
                    for p in chunk {
                        let WirePayload::TopK { indices, values, .. } = p else {
                            unreachable!("format checked by the caller")
                        };
                        for (&ix, &v) in indices.iter().zip(values) {
                            *combined.entry(ix).or_insert(0.0) += v as f64;
                        }
                    }
                    for a in combined.values_mut() {
                        *a *= inv;
                    }
                } else {
                    let mut cols = std::collections::BTreeMap::<u32, Vec<f64>>::new();
                    for (mi, p) in chunk.iter().enumerate() {
                        let WirePayload::TopK { indices, values, .. } = p else {
                            unreachable!("format checked by the caller")
                        };
                        for (&ix, &v) in indices.iter().zip(values) {
                            cols.entry(ix).or_insert_with(|| vec![0.0; chunk.len()])[mi] +=
                                v as f64;
                        }
                    }
                    for (ix, mut vals) in cols {
                        combined.insert(ix, agg.combine(&mut vals));
                    }
                }
                let format = WireFormat::TopK { frac_ppm, decay_ppm };
                let mut head = WirePayload::with_layout(format, &layout);
                let WirePayload::TopK { indices, values, .. } = &mut head else {
                    unreachable!("with_layout builds the requested format")
                };
                let mut off = 0usize;
                for ent in layout.entries() {
                    let k = codec::topk_budget(ent.numel(), frac_ppm);
                    let (lo, hi) = (ent.offset as u32, (ent.offset + ent.numel()) as u32);
                    let mut seg: Vec<(u32, f64)> =
                        combined.range(lo..hi).map(|(&ix, &a)| (ix, a)).collect();
                    seg.sort_by(|a, b| b.1.abs().total_cmp(&a.1.abs()).then(a.0.cmp(&b.0)));
                    seg.truncate(k);
                    seg.sort_unstable_by_key(|&(ix, _)| ix);
                    for j in 0..k {
                        let (ix, v) = seg.get(j).copied().unwrap_or((lo, 0.0));
                        indices[off + j] = ix;
                        values[off + j] = v as f32;
                    }
                    off += k;
                }
                debug_assert_eq!(off, indices.len(), "segment budgets must tile the payload");
                head
            }
            WirePayload::DenseF32(_) => unreachable!("rejected by the caller"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL_FORMATS: [WireFormat; 5] = [
        WireFormat::DenseF32,
        WireFormat::PackedSigns,
        WireFormat::QuantizedI8,
        WireFormat::QuantizedI8PerTensor,
        WireFormat::TOPK_DEFAULT,
    ];

    /// A top-k format whose budgets stay hand-checkable: keep 1 of
    /// every 4-wide segment, halve the residual each round.
    const TOPK_TEST: WireFormat = WireFormat::TopK { frac_ppm: 250_000, decay_ppm: 500_000 };

    fn two_segment_layout(a: usize, b: usize) -> Arc<ParamLayout> {
        use crate::runtime::ParamEntry;
        let entries = vec![
            ParamEntry { name: "lo".into(), offset: 0, shape: vec![a] },
            ParamEntry { name: "hi".into(), offset: a, shape: vec![b] },
        ];
        Arc::new(ParamLayout::from_entries(entries, a + b).unwrap())
    }

    #[test]
    fn with_len_builds_sized_zeroed_payloads_in_every_format() {
        for format in ALL_FORMATS {
            let p = WirePayload::with_len(format, 37);
            assert_eq!(p.format(), format);
            assert_eq!(p.len(), 37);
            assert!(!p.is_empty());
            assert_eq!(p.wire_bytes(), format.wire_bytes(37, 1), "{}", format.name());
            assert!(WirePayload::with_len(format, 0).is_empty());
        }
    }

    #[test]
    fn with_layout_sizes_per_tensor_payloads_from_the_layout() {
        let layout = two_segment_layout(5, 11);
        for format in ALL_FORMATS {
            let p = WirePayload::with_layout(format, &layout);
            assert_eq!(p.format(), format);
            assert_eq!(p.len(), 16, "{}", format.name());
        }
        let pt = WirePayload::with_layout(WireFormat::QuantizedI8PerTensor, &layout);
        assert_eq!(pt.scales().unwrap().len(), 2);
        assert_eq!(pt.layout(), Some(&layout));
        assert_eq!(pt.wire_bytes(), WireFormat::QuantizedI8PerTensor.wire_bytes(16, 2));
        // one scale more than the per-message format
        assert_eq!(pt.wire_bytes(), WireFormat::QuantizedI8.wire_bytes(16, 1) + 4);
    }

    #[test]
    fn accessors_pin_the_per_variant_contract() {
        // Pins what the W1 wildcard expansion made explicit: which
        // accessor answers for which format (scales() covers both
        // quantized encodings; layout() the layout-carrying ones), so a
        // new wire format must decide every accessor on purpose rather
        // than inherit a silent None from a `_ =>` arm.
        let layout = two_segment_layout(5, 11);
        for format in ALL_FORMATS {
            let mut p = WirePayload::with_layout(format, &layout);
            assert_eq!(p.as_dense().is_some(), format == WireFormat::DenseF32);
            assert_eq!(p.as_packed_signs().is_some(), format == WireFormat::PackedSigns);
            assert_eq!(
                p.scales().is_some(),
                matches!(format, WireFormat::QuantizedI8 | WireFormat::QuantizedI8PerTensor),
                "{}",
                format.name()
            );
            assert_eq!(
                p.layout().is_some(),
                matches!(format, WireFormat::QuantizedI8PerTensor | WireFormat::TopK { .. }),
                "{}",
                format.name()
            );
            assert_eq!(p.residual().is_some(), matches!(format, WireFormat::TopK { .. }));
            assert_eq!(p.residual_mut().is_some(), matches!(format, WireFormat::TopK { .. }));
        }
    }

    #[test]
    fn wire_bytes_match_the_codec_models() {
        let p = 1 << 20;
        assert_eq!(WireFormat::DenseF32.wire_bytes(p, 1), p as u64 * 4);
        assert_eq!(WireFormat::PackedSigns.wire_bytes(p, 1), codec::sign_allreduce_bytes(p));
        assert_eq!(WireFormat::QuantizedI8.wire_bytes(p, 1), codec::q8_bytes(p));
        assert_eq!(WireFormat::QuantizedI8PerTensor.wire_bytes(p, 7), codec::q8pt_bytes(p, 7));
        let k = codec::topk_budget(p, WireFormat::TOPK_DEFAULT_FRAC_PPM);
        assert_eq!(WireFormat::TOPK_DEFAULT.wire_bytes(p, 1), codec::topk_bytes(k));
        // the default keep fraction undercuts q8pt's ~P bytes by ~2x
        assert!(WireFormat::TOPK_DEFAULT.wire_bytes(p, 7) * 3 < codec::q8pt_bytes(p, 7) * 2);
    }

    #[test]
    fn parse_and_name_round_trip() {
        for format in ALL_FORMATS {
            assert_eq!(WireFormat::parse(format.name()), Some(format));
        }
        assert_eq!(WireFormat::parse("q8"), Some(WireFormat::QuantizedI8));
        assert_eq!(WireFormat::parse("q8pt"), Some(WireFormat::QuantizedI8PerTensor));
        assert_eq!(WireFormat::parse("1bit"), Some(WireFormat::PackedSigns));
        assert_eq!(WireFormat::parse("demo"), Some(WireFormat::TOPK_DEFAULT));
        assert_eq!(WireFormat::parse("warpdrive"), None);
    }

    #[test]
    fn only_dense_is_ring_reducible() {
        assert!(WireFormat::DenseF32.ring_reducible());
        assert!(!WireFormat::PackedSigns.ring_reducible());
        assert!(!WireFormat::QuantizedI8.ring_reducible());
        assert!(!WireFormat::QuantizedI8PerTensor.ring_reducible());
        assert!(!WireFormat::TOPK_DEFAULT.ring_reducible());
    }

    #[test]
    fn exchange_time_matches_the_clock_topology() {
        // the analytical re-costing helper and the clock's payload
        // billing must agree exactly, format by format
        use crate::comm::SimClock;
        use crate::util::rng::Rng;
        let m = CommModel {
            latency_s: 1e-3,
            bandwidth_bps: 1e6,
            straggler_sigma: 0.0,
            straggler_scale_s: 0.0,
        };
        for n in [4usize, 1024] {
            for format in ALL_FORMATS {
                let payload = WirePayload::with_len(format, 1000);
                let mut clock = SimClock::default();
                clock.charge_exchange(&m, n, &payload, &mut Rng::new(1));
                let t = format.exchange_time(&m, n, 1000, 1);
                assert!((clock.comm_s - t).abs() < 1e-15, "{} n={n}", format.name());
            }
        }
    }

    #[test]
    fn hierarchical_topology_beats_flat_for_compressed_formats_at_scale() {
        // the acceptance pin: at n = 1024 the selector picks the
        // hierarchical topology for q8/q8pt/signs and the modeled round
        // time beats the flat gather+broadcast by a wide margin
        let m = CommModel::preset("ethernet").unwrap();
        let n = 1024;
        let p = 1 << 20;
        for format in [
            WireFormat::PackedSigns,
            WireFormat::QuantizedI8,
            WireFormat::QuantizedI8PerTensor,
            WireFormat::TOPK_DEFAULT,
        ] {
            let topo = Topology::select(format.ring_reducible(), n);
            assert!(
                matches!(topo, Topology::Hierarchical { .. }),
                "{}: {topo:?}",
                format.name()
            );
            let bytes = format.wire_bytes(p, 4);
            let hier = format.exchange_time(&m, n, p, 4);
            let flat = m.gather_time(n, bytes) + m.broadcast_time(n, bytes);
            assert!(hier * 8.0 < flat, "{}: {hier} vs flat {flat}", format.name());
        }
        // dense still rings, at every n
        assert_eq!(Topology::select(true, n), Topology::Ring);
    }

    #[test]
    fn dense_mean_matches_allreduce_mean_bitwise() {
        let ends = [vec![1.0f32, 2.0, -3.0], vec![0.5f32, -2.0, 9.0], vec![0.25f32, 0.1, 1.0]];
        let payloads: Vec<WirePayload> = ends
            .iter()
            .map(|e| {
                let mut p = WirePayload::with_len(WireFormat::DenseF32, 3);
                p.pack_end(&[0.0; 3], e);
                p
            })
            .collect();
        let mut from_payloads = vec![0.0f32; 3];
        WirePayload::mean_end_into(&payloads, &[0.0; 3], &mut from_payloads).unwrap();
        let mut reference = vec![0.0f32; 3];
        collectives::allreduce_mean(&ends, |e| e.as_slice(), &mut reference);
        for (a, b) in from_payloads.iter().zip(&reference) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn q8_mean_reconstructs_the_average_end_within_quantization_error() {
        let start = vec![1.0f32, -0.5, 0.25, 2.0];
        let ends = [vec![0.9f32, -0.45, 0.30, 1.90], vec![0.8f32, -0.55, 0.20, 2.05]];
        let payloads: Vec<WirePayload> = ends
            .iter()
            .map(|e| {
                let mut p = WirePayload::with_len(WireFormat::QuantizedI8, 4);
                p.pack_end(&start, e);
                p
            })
            .collect();
        let mut avg = vec![0.0f32; 4];
        WirePayload::mean_end_into(&payloads, &start, &mut avg).unwrap();
        let mut exact = vec![0.0f32; 4];
        collectives::allreduce_mean(&ends, |e| e.as_slice(), &mut exact);
        // per-rank quantization step: scale = max|diff|/127; the mean's
        // error is at most the mean of the per-rank half-steps
        for (j, (a, e)) in avg.iter().zip(&exact).enumerate() {
            assert!((a - e).abs() < 2e-3, "coord {j}: {a} vs {e}");
        }
    }

    #[test]
    fn q8pt_per_segment_scales_resolve_hetero_magnitudes() {
        // segment "lo" moves by ~1e-3, segment "hi" by ~1.0: one shared
        // scale (q8) rounds the small segment to nothing, per-tensor
        // scales keep it. This is the format's reason to exist; the
        // pinned numeric version lives in rust/tests/layout_wire.rs.
        let layout = two_segment_layout(4, 4);
        let start = vec![0.0f32; 8];
        #[rustfmt::skip]
        let end = vec![
            -1e-3f32, -5e-4, 1e-3, -7.5e-4, // lo: tiny diffs
            -1.0, 0.5, -0.25, 1.0,          // hi: large diffs
        ];
        let mut pt = WirePayload::with_layout(WireFormat::QuantizedI8PerTensor, &layout);
        pt.pack_end(&start, &end);
        let scales = pt.scales().unwrap().to_vec();
        assert!(scales[0] < scales[1] / 100.0, "{scales:?}");
        let mut avg = vec![0.0f32; 8];
        WirePayload::mean_end_into(std::slice::from_ref(&pt), &start, &mut avg).unwrap();
        // every coordinate decodes within half its segment's step
        for (j, (a, e)) in avg.iter().zip(&end).enumerate() {
            let step = scales[j / 4];
            assert!((a - e).abs() <= step / 2.0 + 1e-7, "coord {j}: {a} vs {e}");
        }
        // and the tiny segment survived (q8 would have zeroed it)
        assert!(avg[0] != 0.0 && avg[2] != 0.0, "{avg:?}");
    }

    #[test]
    fn q8_exchange_with_zero_difference_is_exact() {
        let start = vec![0.5f32, -3.0, 7.0];
        for format in [WireFormat::QuantizedI8, WireFormat::QuantizedI8PerTensor] {
            let mut p = WirePayload::with_len(format, 3);
            p.pack_end(&start, &start);
            let mut avg = vec![9.0f32; 3];
            WirePayload::mean_end_into(std::slice::from_ref(&p), &start, &mut avg).unwrap();
            assert_eq!(avg, start, "{}", format.name());
        }
    }

    #[test]
    fn topk_pack_transmits_the_largest_residual_and_decays_the_rest() {
        // keep 1 of each 4-wide segment, halve what stays behind
        let layout = two_segment_layout(4, 4);
        let start = vec![0.0f32; 8];
        #[rustfmt::skip]
        let end = vec![
            -1.0f32, 0.5, -0.25, 0.5, // lo: biggest diff at coord 0
            -4.0, 3.0, -2.0, 1.0,     // hi: biggest diff at coord 4
        ];
        let mut p = WirePayload::with_layout(TOPK_TEST, &layout);
        p.pack_end(&start, &end);
        let WirePayload::TopK { indices, values, residual, .. } = &p else { unreachable!() };
        assert_eq!(indices, &[0, 4]);
        assert_eq!(values, &[1.0, 4.0]);
        // transmitted mass removed, the rest halved by the decay
        assert_eq!(residual, &[0.0, -0.25, 0.125, -0.25, 0.0, -1.5, 1.0, -0.5]);
        // the mean over one worker reconstructs exactly the kept coords
        let mut out = vec![9.0f32; 8];
        WirePayload::mean_end_into(std::slice::from_ref(&p), &start, &mut out).unwrap();
        assert_eq!(out, vec![-1.0, 0.0, 0.0, 0.0, -4.0, 0.0, 0.0, 0.0]);
        // a zero-difference second round transmits leftover momentum:
        // the residual re-competes (ties in |value| break low-index)
        p.pack_end(&start, &start);
        let WirePayload::TopK { indices, values, residual, .. } = &p else { unreachable!() };
        assert_eq!(indices, &[1, 5]);
        assert_eq!(values, &[-0.25, -1.5]);
        assert_eq!(residual, &[0.0, 0.0, 0.0625, -0.125, 0.0, 0.0, 0.5, -0.25]);
    }

    #[test]
    fn topk_with_full_budget_reconstructs_the_mean_exactly() {
        // frac = 1.0 keeps every coordinate: the sparse path degrades
        // to a dense exchange and the f64 mean is exact on dyadics
        let full = WireFormat::TopK { frac_ppm: 1_000_000, decay_ppm: 0 };
        let start = vec![1.0f32, 2.0, -3.0, 0.5];
        let ends = [vec![0.5f32, 2.25, -4.0, 2.5], vec![1.5f32, 1.25, -1.0, 0.25]];
        let payloads: Vec<WirePayload> = ends
            .iter()
            .map(|e| {
                let mut p = WirePayload::with_len(full, 4);
                p.pack_end(&start, e);
                p
            })
            .collect();
        assert_eq!(payloads[0].wire_bytes(), codec::topk_bytes(4));
        let mut avg = vec![0.0f32; 4];
        WirePayload::mean_end_into(&payloads, &start, &mut avg).unwrap();
        assert_eq!(avg, vec![1.0, 1.75, -2.5, 1.375]);
    }

    #[test]
    fn topk_check_finite_flags_nan_values_and_stray_indices() {
        let layout = two_segment_layout(4, 4);
        let mut p = WirePayload::with_layout(TOPK_TEST, &layout);
        p.pack_end(&[0.0; 8], &[1.0, 0.0, 0.0, 0.0, 0.0, -2.0, 0.0, 0.0]);
        assert_eq!(p.check_finite(0), Ok(()));
        let clean = p.clone();
        {
            let WirePayload::TopK { values, .. } = &mut p else { unreachable!() };
            values[1] = f32::NAN;
        }
        assert_eq!(p.check_finite(2), Err(WireError::NonFiniteCoord { worker: 2, index: 5 }));
        let mut p = clean.clone();
        {
            let WirePayload::TopK { indices, .. } = &mut p else { unreachable!() };
            indices[0] = 64; // past the 8-coordinate vector
        }
        assert_eq!(
            p.check_finite(4),
            Err(WireError::SparseIndexOutOfRange { worker: 4, index: 64 })
        );
        // decode refuses the damaged payload and leaves `out` untouched
        let mut out = vec![7.0f32; 8];
        let got = WirePayload::mean_end_into(&[clean, p], &[0.0; 8], &mut out);
        assert!(matches!(got, Err(WireError::SparseIndexOutOfRange { worker: 1, index: 64 })));
        assert_eq!(out, vec![7.0f32; 8]);
    }

    #[test]
    fn pack_end_reuses_buffers_across_rounds() {
        let start = vec![1.0f32; 256];
        let end = vec![0.75f32; 256];
        for format in ALL_FORMATS {
            if format == WireFormat::PackedSigns {
                continue; // votes pack through pack_sign_votes instead
            }
            let mut p = WirePayload::with_len(format, 256);
            p.pack_end(&start, &end);
            let bytes_before = p.wire_bytes();
            for _ in 0..5 {
                p.pack_end(&start, &end);
            }
            assert_eq!(p.len(), 256, "{}", format.name());
            assert_eq!(p.wire_bytes(), bytes_before);
        }
    }

    #[test]
    #[should_panic(expected = "packed_signs")]
    fn dense_pack_into_sign_buffer_panics() {
        let mut p = WirePayload::with_len(WireFormat::PackedSigns, 8);
        p.pack_end(&[0.0; 8], &[1.0; 8]);
    }

    #[test]
    #[should_panic(expected = "sign votes")]
    fn sign_votes_into_dense_buffer_panic() {
        let mut p = WirePayload::with_len(WireFormat::DenseF32, 8);
        p.pack_sign_votes(&[1.0; 8]);
    }

    #[test]
    #[should_panic(expected = "layout tiling")]
    fn per_tensor_pack_with_wrong_dimension_panics() {
        let layout = two_segment_layout(4, 4);
        let mut p = WirePayload::with_layout(WireFormat::QuantizedI8PerTensor, &layout);
        p.pack_end(&[0.0; 6], &[1.0; 6]);
    }

    #[test]
    #[should_panic(expected = "majority tally")]
    fn mean_over_sign_votes_panics() {
        let payloads = vec![WirePayload::with_len(WireFormat::PackedSigns, 8)];
        let mut out = vec![0.0f32; 8];
        let _ = WirePayload::mean_end_into(&payloads, &[0.0; 8], &mut out);
    }

    #[test]
    #[should_panic(expected = "mixed wire formats")]
    fn mixed_formats_panic() {
        let payloads = vec![
            WirePayload::with_len(WireFormat::DenseF32, 4),
            WirePayload::with_len(WireFormat::QuantizedI8, 4),
        ];
        let mut out = vec![0.0f32; 4];
        let _ = WirePayload::mean_end_into(&payloads, &[0.0; 4], &mut out);
    }

    #[test]
    #[should_panic(expected = "mixed parameter layouts")]
    fn mixed_layouts_panic() {
        let pt = WireFormat::QuantizedI8PerTensor;
        let payloads = vec![
            WirePayload::with_layout(pt, &two_segment_layout(4, 4)),
            WirePayload::with_layout(pt, &two_segment_layout(2, 6)),
        ];
        let mut out = vec![0.0f32; 8];
        let _ = WirePayload::mean_end_into(&payloads, &[0.0; 8], &mut out);
    }

    #[test]
    #[should_panic(expected = "pack_end")]
    fn dense_pack_with_wrong_dimension_panics() {
        // regression: this used to silently resize the persistent
        // buffer, defeating the trainer's pack-time drift check
        let mut p = WirePayload::with_len(WireFormat::DenseF32, 8);
        p.pack_end(&[0.0; 6], &[1.0; 6]);
    }

    #[test]
    fn non_finite_differences_are_rejected_not_averaged() {
        // NaN and inf coordinates poison the quantization scale at pack
        // time; both check_finite and the decode-time mean report the
        // offending worker instead of folding the poison into the mean
        let layout = two_segment_layout(2, 2);
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let start = vec![0.0f32; 4];
            let end = vec![0.1f32, bad, -0.1, 0.2];
            for format in [WireFormat::QuantizedI8, WireFormat::QuantizedI8PerTensor] {
                let mut good = WirePayload::with_layout(format, &layout);
                good.pack_end(&start, &[0.1, 0.0, -0.1, 0.2]);
                let mut p = WirePayload::with_layout(format, &layout);
                p.pack_end(&start, &end);
                assert!(
                    p.scales().unwrap().iter().any(|s| !s.is_finite()),
                    "{}: {bad} must poison a scale",
                    format.name()
                );
                assert_eq!(good.check_finite(0), Ok(()));
                let err = p.check_finite(3).unwrap_err();
                let WireError::NonFiniteScale { worker, segment } = err else {
                    panic!("{}: unexpected {err:?}", format.name())
                };
                assert_eq!(worker, 3);
                // q8 poisons its only scale; q8pt isolates the poison
                // to the segment holding the bad coordinate (coord 1
                // lives in segment "lo") — both report segment 0 here
                assert_eq!(segment, 0);
                let mut out = vec![7.0f32; 4];
                let payloads = vec![good.clone(), p.clone()];
                let got = WirePayload::mean_end_into(&payloads, &start, &mut out);
                assert!(
                    matches!(got, Err(WireError::NonFiniteScale { worker: 1, .. })),
                    "{}: {got:?}",
                    format.name()
                );
                // error path must not touch the output
                assert_eq!(out, vec![7.0f32; 4], "{}", format.name());
            }
        }
    }

    #[test]
    fn check_finite_flags_dense_coordinates_and_passes_votes() {
        let mut p = WirePayload::with_len(WireFormat::DenseF32, 4);
        p.pack_end(&[0.0; 4], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(p.check_finite(0), Ok(()));
        p.pack_end(&[0.0; 4], &[1.0, 2.0, f32::NAN, 4.0]);
        assert_eq!(p.check_finite(5), Err(WireError::NonFiniteCoord { worker: 5, index: 2 }));
        let votes = WirePayload::with_len(WireFormat::PackedSigns, 64);
        assert_eq!(votes.check_finite(0), Ok(()));
    }

    #[test]
    fn corrupt_damages_exactly_one_thing_per_format() {
        let mut rng = Rng::new(77);
        for format in ALL_FORMATS {
            for trial in 0..20 {
                let mut p = WirePayload::with_len(format, 33);
                if format == WireFormat::PackedSigns {
                    p.pack_sign_votes(&[1.0; 33]);
                } else {
                    p.pack_end(&[0.5; 33], &[0.25; 33]);
                }
                let clean = p.clone();
                assert!(
                    p.corrupt(&mut rng),
                    "{} trial {trial}: a populated payload always takes damage",
                    format.name()
                );
                assert_ne!(p, clean, "{} trial {trial}: corruption must show", format.name());
                // wire size is untouched — corruption is in-place damage
                assert_eq!(p.wire_bytes(), clean.wire_bytes());
                match format {
                    // every sign-word bit pattern is valid: survived
                    WireFormat::PackedSigns => assert_eq!(p.check_finite(0), Ok(())),
                    // dense / scale poison is detectable, byte flips are
                    // not — either way the payload stays structurally valid
                    _ => {
                        let _ = p.check_finite(0);
                    }
                }
            }
        }
    }

    #[test]
    fn corrupt_reports_exactly_whether_damage_landed() {
        // regression: the q8pt poison branch used to no-op silently on
        // an empty scale vector while FaultStats still counted an
        // injection. The return value is now the single source of
        // truth: true iff the payload actually changed.
        let mut rng = Rng::new(31);
        for format in ALL_FORMATS {
            for trial in 0..20 {
                let mut p = WirePayload::with_len(format, 19);
                let clean = p.clone();
                let landed = p.corrupt(&mut rng);
                assert!(landed, "{} trial {trial}", format.name());
                assert_ne!(p, clean, "{} trial {trial}", format.name());
                // empty payloads: the report and the diff must agree,
                // whichever way the format resolves it (q8 can still
                // poison its scalar scale; dense has nothing to hit)
                let mut e = WirePayload::with_len(format, 0);
                let e_clean = e.clone();
                let e_landed = e.corrupt(&mut rng);
                assert_eq!(e_landed, e != e_clean, "{} trial {trial} empty", format.name());
            }
        }
        // the exact degenerate shape from the bug report: a per-tensor
        // payload whose poison branch has no scale slot to land in
        let mut hollow = WirePayload::QuantizedI8PerTensor {
            layout: Arc::new(ParamLayout::single(0)),
            scales: vec![],
            bytes: vec![],
        };
        let hollow_clean = hollow.clone();
        for _ in 0..8 {
            assert!(!hollow.corrupt(&mut rng), "no scale slot, no injection");
        }
        assert_eq!(hollow, hollow_clean);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 4096-element payloads x 12 rounds x 5 formats: minutes under miri
    fn corrupt_draw_count_is_shape_independent_per_format() {
        // the fault stream must advance the same number of RNG draws
        // whatever the payload's shape or which branch lands — else a
        // resumed run's later faults shift position with model size
        for format in ALL_FORMATS {
            let mut small_rng = Rng::new(404);
            let mut large_rng = Rng::new(404);
            let mut small = WirePayload::with_len(format, 7);
            let mut large = WirePayload::with_len(format, 4096);
            for _ in 0..12 {
                let _ = small.corrupt(&mut small_rng);
                let _ = large.corrupt(&mut large_rng);
            }
            assert_eq!(
                small_rng.below(u64::MAX),
                large_rng.below(u64::MAX),
                "{}: draw counts diverged",
                format.name()
            );
        }
    }

    #[test]
    fn group_heads_replicate_one_aggregate_per_member() {
        // 7 payloads in 3 groups -> chunks of 3/3/1; each slot holds its
        // group head's aggregate, so adjacent members are identical
        let payloads: Vec<WirePayload> = (0..7)
            .map(|w| {
                let mut p = WirePayload::with_len(WireFormat::QuantizedI8, 5);
                p.pack_end(&[0.0; 5], &[0.1 * (w as f32 + 1.0); 5]);
                p
            })
            .collect();
        let heads = WirePayload::aggregate_group_heads(&payloads, 3, AggPolicy::Mean);
        assert_eq!(heads.len(), 7);
        assert_eq!(heads[0], heads[1]);
        assert_eq!(heads[1], heads[2]);
        assert_eq!(heads[3], heads[5]);
        assert_ne!(heads[0], heads[3]);
        assert_ne!(heads[5], heads[6]);
    }

    #[test]
    fn hierarchical_mean_matches_flat_mean_within_quantization_error() {
        // equal group sizes: the mean of replicated group means equals
        // the flat mean up to one extra quantization level
        let start = vec![1.0f32, -0.5, 0.25, 2.0];
        let ends: Vec<Vec<f32>> = (0..8)
            .map(|w| start.iter().map(|s| s - 0.01 * (w as f32 - 3.5)).collect())
            .collect();
        for format in [WireFormat::QuantizedI8, WireFormat::QuantizedI8PerTensor] {
            let payloads: Vec<WirePayload> = ends
                .iter()
                .map(|e| {
                    let mut p = WirePayload::with_len(format, 4);
                    p.pack_end(&start, e);
                    p
                })
                .collect();
            let mut flat = vec![0.0f32; 4];
            WirePayload::mean_end_into(&payloads, &start, &mut flat).unwrap();
            let heads = WirePayload::aggregate_group_heads(&payloads, 4, AggPolicy::Mean);
            let mut hier = vec![0.0f32; 4];
            WirePayload::mean_end_into(&heads, &start, &mut hier).unwrap();
            for (j, (h, f)) in hier.iter().zip(&flat).enumerate() {
                assert!((h - f).abs() < 2e-3, "{} coord {j}: {h} vs {f}", format.name());
            }
        }
    }

    #[test]
    fn topk_group_heads_union_mean_and_retruncate_to_budget() {
        // two members of one group disagree on which lo-coordinate
        // matters; the head means the index union and keeps the larger
        let layout = two_segment_layout(4, 4);
        let ends = [
            vec![-1.0f32, 0.0, 0.0, 0.0, -2.0, 0.0, 0.0, 0.0], // lo idx0, hi idx4
            vec![0.0f32, 3.0, 0.0, 0.0, -2.0, 0.0, 0.0, 0.0],  // lo idx1, hi idx4
        ];
        let payloads: Vec<WirePayload> = ends
            .iter()
            .map(|e| {
                let mut p = WirePayload::with_layout(TOPK_TEST, &layout);
                p.pack_end(&[0.0; 8], e);
                p
            })
            .collect();
        let heads = WirePayload::aggregate_group_heads(&payloads, 1, AggPolicy::Mean);
        assert_eq!(heads.len(), 2);
        assert_eq!(heads[0], heads[1]);
        // billing contract: the head costs exactly what a member does
        assert_eq!(heads[0].wire_bytes(), payloads[0].wire_bytes());
        let WirePayload::TopK { indices, values, .. } = &heads[0] else { unreachable!() };
        // lo union {0: 1.0, 1: -3.0} means to {0: 0.5, 1: -1.5}; the
        // k=1 re-truncation keeps idx 1. hi agrees: mean 2.0 at idx 4.
        assert_eq!(indices, &[1, 4]);
        assert_eq!(values, &[-1.5, 2.0]);
    }

    #[test]
    fn topk_group_heads_pad_short_segments_to_the_budget() {
        // a well-formed member transmits k distinct indices per
        // segment, but a survived in-range index flip (corrupt()) can
        // collide two slots — then the union comes up short of the
        // budget and the head pads with zero-valued components so the
        // component count, and with it wire_bytes, stays layout-pure
        let fmt = WireFormat::TopK { frac_ppm: 500_000, decay_ppm: 500_000 };
        let layout = two_segment_layout(4, 4);
        let mut p = WirePayload::with_layout(fmt, &layout);
        {
            let WirePayload::TopK { indices, values, .. } = &mut p else { unreachable!() };
            // hi segment's two slots collided onto index 4
            indices.copy_from_slice(&[0, 1, 4, 4]);
            values.copy_from_slice(&[1.0, -2.0, 3.0, 3.0]);
        }
        let heads =
            WirePayload::aggregate_group_heads(std::slice::from_ref(&p), 1, AggPolicy::Mean);
        assert_eq!(heads[0].wire_bytes(), p.wire_bytes());
        let WirePayload::TopK { indices, values, .. } = &heads[0] else { unreachable!() };
        // the duplicates sum in the union; the missing slot pads with a
        // zero at the segment base, inert under the decode-time mean
        assert_eq!(indices, &[0, 1, 4, 4]);
        assert_eq!(values, &[1.0, -2.0, 6.0, 0.0]);
        assert_eq!(heads[0].check_finite(0), Ok(()));
    }

    #[test]
    fn topk_hierarchical_mean_with_full_budget_matches_flat_mean() {
        // with frac = 1.0 nothing is ever truncated, so the two-level
        // mean of group means (equal groups) agrees with the flat mean
        // up to one f32 rounding at the head
        let full = WireFormat::TopK { frac_ppm: 1_000_000, decay_ppm: 0 };
        let start = vec![1.0f32, -0.5, 0.25, 2.0];
        let ends: Vec<Vec<f32>> = (0..8)
            .map(|w| start.iter().map(|s| s - 0.01 * (w as f32 - 3.5)).collect())
            .collect();
        let payloads: Vec<WirePayload> = ends
            .iter()
            .map(|e| {
                let mut p = WirePayload::with_len(full, 4);
                p.pack_end(&start, e);
                p
            })
            .collect();
        let mut flat = vec![0.0f32; 4];
        WirePayload::mean_end_into(&payloads, &start, &mut flat).unwrap();
        let heads = WirePayload::aggregate_group_heads(&payloads, 4, AggPolicy::Mean);
        let mut hier = vec![0.0f32; 4];
        WirePayload::mean_end_into(&heads, &start, &mut hier).unwrap();
        for (j, (h, f)) in hier.iter().zip(&flat).enumerate() {
            assert!((h - f).abs() < 1e-6, "coord {j}: {h} vs {f}");
        }
    }

    #[test]
    fn group_heads_tally_signs_as_majority_of_majorities() {
        // 6 voters in 2 groups of 3. Coordinate 0: group A votes
        // (+,+,-) -> +, group B votes (-,-,+) -> -; the weighted final
        // tally ties 3:3 and decodes the wire-tie convention (+1).
        // Coordinate 1: unanimous per group, final -1.
        let votes: [[f32; 2]; 6] = [
            [1.0, -1.0],
            [1.0, -1.0],
            [-1.0, -1.0],
            [-1.0, -1.0],
            [-1.0, -1.0],
            [1.0, -1.0],
        ];
        let payloads: Vec<WirePayload> = votes
            .iter()
            .map(|v| {
                let mut p = WirePayload::with_len(WireFormat::PackedSigns, 2);
                p.pack_sign_votes(v);
                p
            })
            .collect();
        let heads = WirePayload::aggregate_group_heads(&payloads, 2, AggPolicy::Mean);
        assert_eq!(heads.len(), 6);
        let mut tally = vec![0.0f32; 2];
        let packed: Vec<&PackedVotes> =
            heads.iter().map(|p| p.as_packed_signs().unwrap()).collect();
        votes::majority_vote_packed(&packed, &mut tally);
        assert_eq!(tally, vec![1.0, -1.0]);
    }

    /// The flat tally and the weighted hierarchical tally over the
    /// same payloads, for the satellite pins below.
    fn flat_and_hier_tallies(votes: &[Vec<f32>], groups: usize) -> (Vec<f32>, Vec<f32>) {
        let len = votes[0].len();
        let payloads: Vec<WirePayload> = votes
            .iter()
            .map(|v| {
                let mut p = WirePayload::with_len(WireFormat::PackedSigns, len);
                p.pack_sign_votes(v);
                p
            })
            .collect();
        let tally_of = |ps: &[WirePayload]| {
            let packed: Vec<&PackedVotes> =
                ps.iter().map(|p| p.as_packed_signs().unwrap()).collect();
            let mut t = vec![0.0f32; len];
            votes::majority_vote_packed(&packed, &mut t);
            t
        };
        let flat = tally_of(&payloads);
        let hier =
            tally_of(&WirePayload::aggregate_group_heads(&payloads, groups, AggPolicy::Mean));
        (flat, hier)
    }

    #[test]
    fn hierarchical_tally_diverges_from_flat_on_split_groups() {
        // The documented approximation, pinned: majority-of-weighted-
        // majorities is NOT the flat tally. Six voters, two groups of
        // three. Flat count: 2 votes +1, 4 votes -1 -> -1 decisively.
        // Hierarchical: group A (+,+,-) -> head +1 replicated x3,
        // group B (-,-,-) -> head -1 replicated x3; the weighted final
        // round ties 3:3 and the wire-tie convention decodes +1.
        let votes: Vec<Vec<f32>> = vec![
            vec![1.0],
            vec![1.0],
            vec![-1.0],
            vec![-1.0],
            vec![-1.0],
            vec![-1.0],
        ];
        let (flat, hier) = flat_and_hier_tallies(&votes, 2);
        assert_eq!(flat, vec![-1.0]);
        assert_eq!(hier, vec![1.0]);
    }

    #[test]
    fn degenerate_groupings_reproduce_the_flat_tally_exactly() {
        // groups = 1 (one head tallies everyone) and groups = n (every
        // head is its own member) are exact: the approximation only
        // lives strictly between the extremes
        let mut rng = Rng::new(2024);
        let n = 5;
        let votes: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..64).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect())
            .collect();
        let (flat, hier_one) = flat_and_hier_tallies(&votes, 1);
        assert_eq!(flat, hier_one);
        let (_, hier_n) = flat_and_hier_tallies(&votes, n);
        assert_eq!(flat, hier_n);
    }

    #[test]
    #[should_panic(expected = "ring-reduce")]
    fn dense_payloads_refuse_hierarchical_aggregation() {
        let payloads = vec![WirePayload::with_len(WireFormat::DenseF32, 4); 4];
        let _ = WirePayload::aggregate_group_heads(&payloads, 2, AggPolicy::Mean);
    }

    #[test]
    fn agg_policy_parse_name_and_trim_depth() {
        for agg in [AggPolicy::Mean, AggPolicy::Trimmed, AggPolicy::Median] {
            assert_eq!(AggPolicy::parse(agg.name()), Some(agg));
        }
        assert_eq!(AggPolicy::parse("trimmed_mean"), Some(AggPolicy::Trimmed));
        assert_eq!(AggPolicy::parse("krum"), None);
        assert_eq!(AggPolicy::default(), AggPolicy::Mean);
        // n ≤ 2 never trims; above that k = max(1, n/4) with 2k < n
        assert_eq!(AggPolicy::trim_depth(1), 0);
        assert_eq!(AggPolicy::trim_depth(2), 0);
        assert_eq!(AggPolicy::trim_depth(3), 1);
        assert_eq!(AggPolicy::trim_depth(4), 1);
        assert_eq!(AggPolicy::trim_depth(8), 2);
        assert_eq!(AggPolicy::trim_depth(16), 4);
        for n in 3..64 {
            let k = AggPolicy::trim_depth(n);
            assert!(k >= 1 && 2 * k < n, "n={n} k={k}");
        }
    }

    /// Round-packed payloads for every dense-exchange format, one per
    /// `end` vector, plus the layout the per-tensor formats carry.
    fn packed_fleet(format: WireFormat, start: &[f32], ends: &[Vec<f32>]) -> Vec<WirePayload> {
        let layout = two_segment_layout(start.len() / 2, start.len() - start.len() / 2);
        ends.iter()
            .map(|e| {
                let mut p = WirePayload::with_layout(format, &layout);
                p.pack_end(start, e);
                p
            })
            .collect()
    }

    #[test]
    fn mean_policy_is_the_mean_path_bitwise() {
        let start = vec![1.0f32, -0.5, 0.25, 2.0, 0.0, -1.0];
        let ends: Vec<Vec<f32>> = (0..5)
            .map(|w| start.iter().map(|s| s - 0.01 * (w as f32 - 2.0)).collect())
            .collect();
        let full = WireFormat::TopK { frac_ppm: 1_000_000, decay_ppm: 0 };
        for format in
            [WireFormat::DenseF32, WireFormat::QuantizedI8, WireFormat::QuantizedI8PerTensor, full]
        {
            let payloads = packed_fleet(format, &start, &ends);
            let mut mean = vec![0.0f32; 6];
            WirePayload::mean_end_into(&payloads, &start, &mut mean).unwrap();
            let mut agg = vec![0.0f32; 6];
            WirePayload::aggregate_end_into(AggPolicy::Mean, &payloads, &start, &mut agg)
                .unwrap();
            for (a, m) in agg.iter().zip(&mean) {
                assert_eq!(a.to_bits(), m.to_bits(), "{}", format.name());
            }
        }
    }

    #[test]
    fn byzantine_payloads_stay_finite_in_every_format() {
        // the adversary model's defining property: nothing it sends is
        // rejectable by the finiteness gate — only robust aggregation
        // (or the tally) stands between the attack and the aggregate
        let start = vec![1.0f32, -0.5, 0.25, 2.0];
        let end = vec![0.9f32, -0.4, 0.35, 1.9];
        for format in ALL_FORMATS {
            for attack in [Attack::SignFlip, Attack::ScaleInflate, Attack::ColludeFixed] {
                let mut p = WirePayload::with_len(format, 4);
                if format == WireFormat::PackedSigns {
                    p.pack_sign_votes(&[1.0, -1.0, 1.0, -1.0]);
                } else {
                    p.pack_end(&start, &end);
                }
                let bytes = p.wire_bytes();
                p.byzantine(attack, &start);
                assert_eq!(p.check_finite(0), Ok(()), "{} {}", format.name(), attack.name());
                assert_eq!(p.wire_bytes(), bytes, "{} {}", format.name(), attack.name());
            }
        }
    }

    #[test]
    fn sign_flip_negates_and_collude_pins_the_decoded_diff() {
        let start = vec![1.0f32, -0.5, 0.25, 2.0];
        let end = vec![0.9f32, -0.4, 0.35, 1.9]; // diff = ±0.1 exactly
        let full = WireFormat::TopK { frac_ppm: 1_000_000, decay_ppm: 0 };
        for format in
            [WireFormat::DenseF32, WireFormat::QuantizedI8, WireFormat::QuantizedI8PerTensor, full]
        {
            let mut p = packed_fleet(format, &start, std::slice::from_ref(&end)).remove(0);
            p.byzantine(Attack::SignFlip, &start);
            let mut out = vec![0.0f32; 4];
            WirePayload::mean_end_into(std::slice::from_ref(&p), &start, &mut out).unwrap();
            // end reflects around start: decoded diff is the negation
            for (j, (o, (&s, &e))) in out.iter().zip(start.iter().zip(&end)).enumerate() {
                assert!((o - (2.0 * s - e)).abs() < 2e-3, "{} coord {j}", format.name());
            }
            let mut p = packed_fleet(format, &start, std::slice::from_ref(&end)).remove(0);
            p.byzantine(Attack::ColludeFixed, &start);
            WirePayload::mean_end_into(std::slice::from_ref(&p), &start, &mut out).unwrap();
            // diff ≡ +1 where transmitted (full-budget topk covers all)
            for (j, (o, &s)) in out.iter().zip(&start).enumerate() {
                assert!((o - (s - 1.0)).abs() < 2e-2, "{} coord {j}: {o}", format.name());
            }
        }
    }

    #[test]
    fn trimmed_mean_recovers_where_plain_mean_is_poisoned() {
        // satellite pin: n = 8, trim depth 2; f = 2 ×64 scale-inflators
        // sit inside the trim and the trimmed mean lands on the honest
        // mean, while the plain mean is pulled ≥ 2x the honest diff
        let n = 8;
        let start = vec![1.0f32, -0.5, 0.25, 2.0, 0.0, -1.0];
        let ends: Vec<Vec<f32>> = (0..n)
            .map(|w| start.iter().map(|s| s - 0.01 * (w as f32 + 1.0)).collect())
            .collect();
        let full = WireFormat::TopK { frac_ppm: 1_000_000, decay_ppm: 0 };
        for format in
            [WireFormat::DenseF32, WireFormat::QuantizedI8, WireFormat::QuantizedI8PerTensor, full]
        {
            let mut payloads = packed_fleet(format, &start, &ends);
            let mut honest = vec![0.0f32; 6];
            WirePayload::mean_end_into(&payloads, &start, &mut honest).unwrap();
            payloads[1].byzantine(Attack::ScaleInflate, &start);
            payloads[5].byzantine(Attack::ScaleInflate, &start);
            let mut poisoned = vec![0.0f32; 6];
            WirePayload::mean_end_into(&payloads, &start, &mut poisoned).unwrap();
            let mut trimmed = vec![0.0f32; 6];
            WirePayload::aggregate_end_into(AggPolicy::Trimmed, &payloads, &start, &mut trimmed)
                .unwrap();
            let mut median = vec![0.0f32; 6];
            WirePayload::aggregate_end_into(AggPolicy::Median, &payloads, &start, &mut median)
                .unwrap();
            for j in 0..6 {
                let honest_diff = (start[j] - honest[j]).abs();
                let poisoned_diff = (start[j] - poisoned[j]).abs();
                assert!(
                    poisoned_diff > 2.0 * honest_diff,
                    "{} coord {j}: mean must be poisoned ({poisoned_diff} vs {honest_diff})",
                    format.name()
                );
                // one-sided contamination biases a trimmed mean within
                // the honest spread (the trim clips the clean tail
                // too); both robust aggregates land well inside it
                assert!(
                    (trimmed[j] - honest[j]).abs() < 0.5 * honest_diff + 2e-3,
                    "{} coord {j}: trimmed {} vs honest {}",
                    format.name(),
                    trimmed[j],
                    honest[j]
                );
                assert!(
                    (median[j] - honest[j]).abs() < 0.5 * honest_diff + 2e-3,
                    "{} coord {j}: median {} vs honest {}",
                    format.name(),
                    median[j],
                    honest[j]
                );
            }
        }
    }

    #[test]
    fn majority_tally_is_bitwise_unchanged_under_minority_sign_flippers() {
        // satellite pin: f < n/2 flipped copies of a unanimous honest
        // vote leave every tally coordinate exactly where it was
        let n = 9;
        let mut rng = Rng::new(88);
        let honest: Vec<f32> =
            (0..67).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        let mut payloads: Vec<WirePayload> = (0..n)
            .map(|_| {
                let mut p = WirePayload::with_len(WireFormat::PackedSigns, honest.len());
                p.pack_sign_votes(&honest);
                p
            })
            .collect();
        let tally_of = |ps: &[WirePayload]| {
            let packed: Vec<&PackedVotes> =
                ps.iter().map(|p| p.as_packed_signs().unwrap()).collect();
            let mut t = vec![0.0f32; honest.len()];
            votes::majority_vote_packed(&packed, &mut t);
            t
        };
        let clean = tally_of(&payloads);
        assert_eq!(clean, honest);
        for f in 1..=4 {
            payloads[f - 1].byzantine(Attack::SignFlip, &[]);
            let attacked = tally_of(&payloads);
            for (j, (a, c)) in attacked.iter().zip(&clean).enumerate() {
                assert_eq!(a.to_bits(), c.to_bits(), "f={f} coord {j}");
            }
        }
        // and the breakdown is sharp: the 5th flipper owns the tally
        payloads[4].byzantine(Attack::SignFlip, &[]);
        let broken = tally_of(&payloads);
        assert!(broken.iter().zip(&clean).any(|(b, c)| b != c));
    }

    #[test]
    fn robust_group_heads_defend_inside_the_group() {
        // one ×64 inflator among 4 group members: the trimmed head
        // re-encodes something near the honest mean while the mean head
        // is dragged an order of magnitude away
        let start = vec![1.0f32, -0.5, 0.25, 2.0];
        let ends: Vec<Vec<f32>> =
            (0..4).map(|w| start.iter().map(|s| s - 0.01 * (w as f32 + 1.0)).collect()).collect();
        let honest_mean_diff = 0.025f32;
        for format in [WireFormat::QuantizedI8, WireFormat::QuantizedI8PerTensor] {
            let mut payloads = packed_fleet(format, &start, &ends);
            payloads[2].byzantine(Attack::ScaleInflate, &start);
            for (agg, close) in [(AggPolicy::Mean, false), (AggPolicy::Trimmed, true)] {
                let heads = WirePayload::aggregate_group_heads(&payloads, 1, agg);
                let mut out = vec![0.0f32; 4];
                WirePayload::mean_end_into(&heads[..1], &start, &mut out).unwrap();
                let diff = (start[0] - out[0]).abs();
                assert_eq!(
                    diff < 2.0 * honest_mean_diff,
                    close,
                    "{} {}: head diff {diff}",
                    format.name(),
                    agg.name()
                );
            }
        }
        // trimmed top-k heads: the union merge sees the inflated values
        // voted out against the implicit zeros and honest members
        let full = WireFormat::TopK { frac_ppm: 1_000_000, decay_ppm: 0 };
        let mut payloads = packed_fleet(full, &start, &ends);
        payloads[2].byzantine(Attack::ScaleInflate, &start);
        let heads = WirePayload::aggregate_group_heads(&payloads, 1, AggPolicy::Trimmed);
        let mut out = vec![0.0f32; 4];
        WirePayload::mean_end_into(&heads[..1], &start, &mut out).unwrap();
        assert!((start[0] - out[0]).abs() < 2.0 * honest_mean_diff, "{}", out[0]);
    }

    /// One packed payload per format over the shared two-segment
    /// layout, plus the layout itself — the frame-codec fixtures.
    fn framed_fixture(format: WireFormat) -> (WirePayload, Arc<ParamLayout>) {
        let layout = two_segment_layout(5, 11);
        let mut p = WirePayload::with_layout(format, &layout);
        let start: Vec<f32> = (0..16).map(|i| i as f32 * 0.25).collect();
        let end: Vec<f32> = start.iter().map(|s| s - 0.125).collect();
        if format == WireFormat::PackedSigns {
            let votes: Vec<f32> =
                (0..16).map(|i| if i % 3 == 0 { 1.0 } else { -1.0 }).collect();
            p.pack_sign_votes(&votes);
        } else {
            p.pack_end(&start, &end);
        }
        (p, layout)
    }

    #[test]
    fn encoded_frame_length_equals_wire_bytes_for_every_format() {
        // the tentpole pin: the billed number IS the framed length
        for format in ALL_FORMATS {
            let (p, _) = framed_fixture(format);
            let mut frame = Vec::new();
            p.encode_into(&mut frame);
            assert_eq!(frame.len() as u64, p.wire_bytes(), "{}", format.name());
            // encode reuses the buffer without growing past frame size
            let cap = frame.capacity();
            p.encode_into(&mut frame);
            assert_eq!(frame.capacity(), cap, "{}", format.name());
        }
    }

    #[test]
    fn frame_round_trip_preserves_every_field() {
        for format in ALL_FORMATS {
            let (p, layout) = framed_fixture(format);
            let mut frame = Vec::new();
            p.encode_into(&mut frame);
            let view = WirePayload::decode(format, &layout, &frame).unwrap();
            match (&p, view) {
                (WirePayload::DenseF32(v), WirePayloadView::DenseF32 { body }) => {
                    assert_eq!(body.len(), v.len() * 4);
                    for (i, x) in v.iter().enumerate() {
                        assert_eq!(
                            WirePayloadView::read_f32(body, i).to_bits(),
                            x.to_bits()
                        );
                    }
                }
                (WirePayload::PackedSigns(pv), WirePayloadView::PackedSigns { len, bits }) => {
                    assert_eq!(len, pv.len());
                    assert_eq!(bits, pv.as_bytes());
                }
                (
                    WirePayload::QuantizedI8 { scale, bytes },
                    WirePayloadView::QuantizedI8 { scale: vscale, bytes: vbytes },
                ) => {
                    assert_eq!(vscale.to_bits(), scale.to_bits());
                    assert_eq!(vbytes, bytes.as_slice());
                }
                (
                    WirePayload::QuantizedI8PerTensor { scales, bytes, .. },
                    WirePayloadView::QuantizedI8PerTensor { scales: vscales, bytes: vbytes },
                ) => {
                    assert_eq!(vscales.len(), scales.len() * 4);
                    for (i, s) in scales.iter().enumerate() {
                        assert_eq!(
                            WirePayloadView::read_f32(vscales, i).to_bits(),
                            s.to_bits()
                        );
                    }
                    assert_eq!(vbytes, bytes.as_slice());
                }
                (
                    WirePayload::TopK { indices, values, .. },
                    WirePayloadView::TopK { indices: vidx, values: vvals },
                ) => {
                    assert_eq!(vidx.len(), indices.len() * 4);
                    for (i, ix) in indices.iter().enumerate() {
                        assert_eq!(WirePayloadView::read_u32(vidx, i), *ix);
                    }
                    for (i, v) in values.iter().enumerate() {
                        assert_eq!(
                            WirePayloadView::read_f32(vvals, i).to_bits(),
                            v.to_bits()
                        );
                    }
                }
                (payload, view) => {
                    panic!("{}: view {view:?} mismatches payload {payload:?}", format.name())
                }
            }
            assert_eq!(
                WirePayload::decode(format, &layout, &frame).unwrap().frame_items(),
                match format {
                    WireFormat::TopK { .. } => p
                        .layout()
                        .unwrap()
                        .entries()
                        .iter()
                        .map(|e| codec::topk_budget(e.numel(), 62_500))
                        .sum::<usize>(),
                    WireFormat::DenseF32
                    | WireFormat::PackedSigns
                    | WireFormat::QuantizedI8
                    | WireFormat::QuantizedI8PerTensor => 16,
                },
                "{}",
                format.name()
            );
        }
    }

    #[test]
    fn frame_decode_rejects_truncation_trailing_and_header_drift() {
        for format in ALL_FORMATS {
            let (p, layout) = framed_fixture(format);
            let mut frame = Vec::new();
            p.encode_into(&mut frame);
            let needed = frame.len();
            // every strict prefix is a typed truncation
            for cut in [0, 1, needed.saturating_sub(1)] {
                let got = WirePayload::decode(format, &layout, &frame[..cut]);
                assert_eq!(
                    got,
                    Err(WireError::TruncatedFrame { needed, got: cut }),
                    "{} cut={cut}",
                    format.name()
                );
            }
            // bytes past the layout's end are a typed rejection too
            let mut long = frame.clone();
            long.extend_from_slice(&[0xAB; 3]);
            assert_eq!(
                WirePayload::decode(format, &layout, &long),
                Err(WireError::TrailingBytes { extra: 3 }),
                "{}",
                format.name()
            );
            // a corrupted length prefix is caught against the contract
            // (dense frames carry no prefix — their length check IS the
            // contract)
            if format != WireFormat::DenseF32 {
                let mut drifted = frame.clone();
                drifted[0] ^= 0x01;
                let got = WirePayload::decode(format, &layout, &drifted);
                let expected = frame_header(&frame);
                assert_eq!(
                    got,
                    Err(WireError::FrameHeaderMismatch {
                        expected,
                        got: frame_header(&drifted),
                    }),
                    "{}",
                    format.name()
                );
            }
        }
    }

    #[test]
    fn frame_errors_display_their_numbers() {
        // W1 companion: the new typed rejections render per-variant
        let e = WireError::TruncatedFrame { needed: 20, got: 12 };
        assert!(e.to_string().contains("20") && e.to_string().contains("12"));
        let e = WireError::TrailingBytes { extra: 3 };
        assert!(e.to_string().contains('3'));
        let e = WireError::FrameHeaderMismatch { expected: 16, got: 17 };
        assert!(e.to_string().contains("16") && e.to_string().contains("17"));
    }

    #[test]
    fn mean_decode_is_bitwise_identical_to_the_scalar_reference() {
        // the q8/q8pt mean paths now stream payload-major through
        // kernels::dequant_accumulate and may split across the pool;
        // both restructures must keep every output bit. The reference
        // below is the historical coordinate-major loop, verbatim.
        let mut rng = Rng::new(515);
        let layout = two_segment_layout(37, 91);
        let p = layout.param_count();
        let start: Vec<f32> = (0..p).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let n = 5;
        for format in [WireFormat::QuantizedI8, WireFormat::QuantizedI8PerTensor] {
            let payloads: Vec<WirePayload> = (0..n)
                .map(|_| {
                    let end: Vec<f32> =
                        start.iter().map(|s| s - 0.01 * rng.normal_f32(0.0, 1.0)).collect();
                    let mut pl = WirePayload::with_layout(format, &layout);
                    pl.pack_end(&start, &end);
                    pl
                })
                .collect();
            let mut fast = vec![0.0f32; p];
            WirePayload::mean_end_into(&payloads, &start, &mut fast).unwrap();
            let inv_n = 1.0f64 / n as f64;
            let mut reference = vec![0.0f32; p];
            for (i, o) in reference.iter_mut().enumerate() {
                let mut acc = 0.0f64;
                for pl in &payloads {
                    let (scale, byte) = match pl {
                        WirePayload::QuantizedI8 { scale, bytes } => (*scale, bytes[i]),
                        WirePayload::QuantizedI8PerTensor { scales, bytes, .. } => {
                            let si = usize::from(i >= 37);
                            (scales[si], bytes[i])
                        }
                        WirePayload::DenseF32(_)
                        | WirePayload::PackedSigns(_)
                        | WirePayload::TopK { .. } => unreachable!("q8/q8pt only"),
                    };
                    acc += codec::dequantize_i8(byte, scale) as f64;
                }
                *o = start[i] - (acc * inv_n) as f32;
            }
            for (j, (a, b)) in fast.iter().zip(&reference).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{} coord {j}", format.name());
            }
        }
    }

    #[test]
    fn robust_policies_reject_damaged_payloads_like_the_mean_path() {
        // the typed-error contract carries over: poisoned scales and
        // stray sparse indices error out before `out` is touched
        let start = vec![0.0f32; 4];
        let mut q8 = WirePayload::with_len(WireFormat::QuantizedI8, 4);
        q8.pack_end(&start, &[0.1, -0.1, 0.2, -0.2]);
        let mut bad = q8.clone();
        let WirePayload::QuantizedI8 { scale, .. } = &mut bad else { unreachable!() };
        *scale = f32::NAN;
        let mut out = vec![7.0f32; 4];
        let got =
            WirePayload::aggregate_end_into(AggPolicy::Median, &[q8, bad], &start, &mut out);
        assert!(matches!(got, Err(WireError::NonFiniteScale { worker: 1, .. })));
        assert_eq!(out, vec![7.0f32; 4]);
    }
}
