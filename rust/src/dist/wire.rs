//! The typed round-exchange payload: what one rank actually puts on the
//! simulated wire at a communication round.
//!
//! Every outer optimizer's worker→server exchange is a [`WirePayload`]
//! — full-precision parameters, packed 1-bit sign votes, 8-bit
//! quantized differences, or **layout-aware** 8-bit differences with
//! one scale per parameter segment — and the clock bills the payload's
//! own [`WirePayload::wire_bytes`]
//! ([`crate::comm::SimClock::charge_exchange`]). Because the billed
//! object IS the exchanged object, the accounting and the data path
//! cannot diverge: there is no per-optimizer flag left to choose a byte
//! formula from, and adding a format means adding a variant here (its
//! byte cost and topology come with it) rather than a new `if` in the
//! trainer.
//!
//! # Formats and topologies
//!
//! | format | payload | bytes/message | topology (n < 16 / n ≥ 16) |
//! |---|---|---|---|
//! | [`WireFormat::DenseF32`] | rank's end parameters `x_{t,τ}^{(i)}` | `4P` | ring all-reduce (any n) |
//! | [`WireFormat::PackedSigns`] | 1-bit randomized sign votes | `⌈P/8⌉ + 8` | flat gather+broadcast / hierarchical |
//! | [`WireFormat::QuantizedI8`] | i8-quantized local difference, one scale | `P + 12` | flat gather+broadcast / hierarchical |
//! | [`WireFormat::QuantizedI8PerTensor`] | i8-quantized difference, one scale per layout segment | `P + 8 + 4S` | flat gather+broadcast / hierarchical |
//!
//! A mean over dense payloads is ring-reducible, so `DenseF32` keeps
//! the classic α-β ring model at every fleet size. Neither a majority
//! tally nor a per-rank-scaled i8 sum fits its own wire format
//! mid-reduction (a partial tally has no 1-bit encoding; summing i8
//! payloads with different scales requires dequantizing first), so the
//! compressed formats bill a server topology. Which one is
//! [`Topology::select`]'s call, shared with the clock: the flat gather
//! of n−1 rank payloads plus a binomial-tree broadcast at small n, and
//! the two-level **hierarchical** scheme — ranks gather into ≈√n
//! groups, each group head partially aggregates
//! ([`WirePayload::aggregate_group_heads`]: decode-mean-requantize for
//! the i8 formats, a partial majority tally repacked as votes for
//! signs), the heads exchange flat, and the result broadcasts back down
//! — once n reaches [`crate::comm::topology::HIERARCHICAL_MIN_RANKS`].
//! That fixes the compressed formats' large-n loss to the dense ring by
//! construction: the flat gather's (n−1) serial messages become O(√n),
//! while the per-format byte advantage is untouched (the hierarchy
//! moves the same `2(n−1)·b` total bytes).
//!
//! # Faults and `n_effective`
//!
//! Under an active [`crate::comm::FaultPlan`] a round's gather may see
//! fewer payloads than the fleet has ranks: members sit rounds out
//! (churn), payloads drop in transit, and corrupted payloads that fail
//! [`WirePayload::check_finite`] are rejected before aggregation. The
//! aggregate is then taken over the `n_effective` surviving payloads —
//! [`WirePayload::mean_end_into`] divides by `payloads.len()`, the
//! majority tally thresholds at half its vote count, so both paths are
//! well defined for any non-empty survivor set (an empty one skips the
//! round). Corruption is never silently averaged in: a NaN-poisoned
//! scale is a typed [`WireError`] at pack *and* decode time, while a
//! bit-flipped i8 byte or sign word is a valid encoding and is
//! *survived* with bounded error — exactly the distinction between
//! detectable and undetectable damage on a real wire.
//!
//! # The layout contract (`q8pt`)
//!
//! The per-message `q8` format pays one quantization scale for the
//! whole vector, so the segment with the largest difference magnitude
//! sets everyone's resolution — GPT-2 blocks (embeddings, attention,
//! MLP, layernorm) differ by orders of magnitude, and the small-moving
//! blocks round to garbage. `QuantizedI8PerTensor` carries the
//! backend's validated [`ParamLayout`]
//! ([`crate::runtime::StepBackend::layout`]) and quantizes each named
//! segment against its own scale ([`super::codec::quantize_diff_slice`])
//! for 4 extra wire bytes per segment. Under a one-segment layout it is
//! **bitwise-identical** to `q8` (same arithmetic, same bytes modulo
//! the identical 4-byte scale frame) — the golden tests in
//! `rust/tests/layout_wire.rs` pin both that identity and the error
//! reduction on hetero-magnitude layouts.

use std::fmt;
use std::sync::Arc;

use super::codec;
use super::collectives;
use super::votes::{self, PackedVotes};
use crate::comm::{CommModel, Topology};
use crate::runtime::ParamLayout;
use crate::util::rng::Rng;

/// Typed rejection of damaged wire data — the loud path for corruption
/// that IS detectable (non-finite quantization scales or dense
/// coordinates). Misuse of the API (mixed formats, length drift, a mean
/// over sign votes) stays a panic: that is a bug in the caller, not bad
/// data on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireError {
    /// A quantized payload carries a non-finite scale (NaN poison from a
    /// non-finite difference at pack time, or corruption in transit).
    NonFiniteScale {
        /// Index of the offending payload in the round's gather.
        worker: usize,
        /// Layout segment of the offending scale (0 for per-message q8).
        segment: usize,
    },
    /// A dense payload carries a non-finite coordinate.
    NonFiniteCoord {
        /// Index of the offending payload in the round's gather.
        worker: usize,
        /// Offending coordinate.
        index: usize,
    },
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::NonFiniteScale { worker, segment } => write!(
                f,
                "worker {worker}: non-finite quantization scale in segment {segment} \
                 (diverged rank or corrupted payload)"
            ),
            WireError::NonFiniteCoord { worker, index } => write!(
                f,
                "worker {worker}: non-finite coordinate {index} in dense payload \
                 (diverged rank or corrupted payload)"
            ),
        }
    }
}

impl std::error::Error for WireError {}

/// Construction-time name of a [`WirePayload`] variant: what a config
/// file selects (`wire = "dense" | "packed_signs" | "q8" | "q8pt"`) and
/// what the trainer sizes its persistent per-rank buffers with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireFormat {
    /// Full-precision f32 parameters (the classic exchange).
    DenseF32,
    /// 1-bit sign votes ([`codec::pack_signs`], Algorithm 6's wire).
    PackedSigns,
    /// 8-bit symmetric-quantized local differences, one per-message
    /// scale ([`codec::quantize_diff_into`]).
    QuantizedI8,
    /// 8-bit symmetric-quantized local differences with one scale per
    /// [`ParamLayout`] segment ([`codec::quantize_diff_slice`]).
    QuantizedI8PerTensor,
}

impl WireFormat {
    /// Parse a config-file / CLI name.
    pub fn parse(s: &str) -> Option<WireFormat> {
        match s {
            "dense" | "f32" => Some(WireFormat::DenseF32),
            "packed_signs" | "signs" | "1bit" => Some(WireFormat::PackedSigns),
            "q8" | "i8" | "quantized_i8" => Some(WireFormat::QuantizedI8),
            "q8pt" | "q8_per_tensor" | "i8pt" => Some(WireFormat::QuantizedI8PerTensor),
            _ => None,
        }
    }

    /// Stable config-facing name (inverse of [`WireFormat::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            WireFormat::DenseF32 => "dense",
            WireFormat::PackedSigns => "packed_signs",
            WireFormat::QuantizedI8 => "q8",
            WireFormat::QuantizedI8PerTensor => "q8pt",
        }
    }

    /// Bytes one message of `len` coordinates in this format puts on
    /// the wire (what a sized [`WirePayload`] will report). `segments`
    /// is the parameter-layout segment count — it only affects the
    /// per-tensor format (one extra f32 scale each); pass 1 for
    /// layout-less analysis.
    pub fn wire_bytes(&self, len: usize, segments: usize) -> u64 {
        match self {
            WireFormat::DenseF32 => len as u64 * 4,
            WireFormat::PackedSigns => codec::sign_allreduce_bytes(len),
            WireFormat::QuantizedI8 => codec::q8_bytes(len),
            WireFormat::QuantizedI8PerTensor => codec::q8pt_bytes(len, segments),
        }
    }

    /// Whether a partial aggregate of this format fits back into the
    /// format itself — true only for dense f32, which therefore bills
    /// the ring all-reduce; compressed formats bill gather+broadcast
    /// (see the module docs).
    pub fn ring_reducible(&self) -> bool {
        matches!(self, WireFormat::DenseF32)
    }

    /// Modeled seconds of one round exchange of `len` coordinates over
    /// a `segments`-segment layout under `m` — the analytical
    /// re-costing twin of [`crate::comm::SimClock::charge_exchange`].
    /// Both route through [`Topology::select`] on (format, n): ring for
    /// the ring-reducible dense format, flat gather+broadcast for small
    /// compressed fleets, hierarchical at scale — so tables re-costed
    /// through this helper cannot drift from what the clock actually
    /// billed (pinned by `exchange_time_matches_the_clock_topology`).
    pub fn exchange_time(&self, m: &CommModel, n: usize, len: usize, segments: usize) -> f64 {
        let bytes = self.wire_bytes(len, segments);
        match Topology::select(self.ring_reducible(), n) {
            Topology::Ring => m.allreduce_time(n, bytes),
            Topology::FlatGatherBroadcast => {
                m.gather_time(n, bytes) + m.broadcast_time(n, bytes)
            }
            Topology::Hierarchical { groups } => m.hierarchical_time(n, groups, bytes),
        }
    }
}

/// One rank's round contribution, in exactly the bytes that cross the
/// simulated wire. Trainer-owned and persistent: the same buffers are
/// re-packed in place every round, so the steady-state exchange
/// allocates nothing in any format.
#[derive(Clone, Debug, PartialEq)]
pub enum WirePayload {
    /// The rank's end-of-round parameters, full precision.
    DenseF32(Vec<f32>),
    /// The rank's packed 1-bit sign votes.
    PackedSigns(PackedVotes),
    /// The rank's local difference `start - end`, quantized to i8 with
    /// a per-message scale ([`codec::quantize_diff_into`]).
    QuantizedI8 {
        /// Symmetric quantization step (`max |diff| / 127`).
        scale: f32,
        /// One two's-complement i8 per coordinate.
        bytes: Vec<u8>,
    },
    /// The rank's local difference `start - end`, quantized to i8 with
    /// one scale per segment of the parameter layout
    /// ([`codec::quantize_diff_slice`] per segment). The layout rides
    /// in the payload (shared, not serialized: the byte cost counts the
    /// scales, the segment boundaries are part of the static
    /// backend↔trainer contract both ends already hold).
    QuantizedI8PerTensor {
        /// The validated segment layout the scales follow.
        layout: Arc<ParamLayout>,
        /// Symmetric quantization step per segment
        /// (`max |diff over segment| / 127` each).
        scales: Vec<f32>,
        /// One two's-complement i8 per coordinate.
        bytes: Vec<u8>,
    },
}

impl WirePayload {
    /// A zeroed payload of `len` coordinates in `format` — the initial
    /// state of the trainer's persistent buffers. Its
    /// [`wire_bytes`](Self::wire_bytes) is already final: the byte cost
    /// is a function of (format, len, layout) only, never of the packed
    /// contents, which is what lets the clock bill a round before the
    /// ranks pack into it. The per-tensor format gets the one-segment
    /// fallback layout here; use [`WirePayload::with_layout`] to size
    /// it from a real backend layout.
    pub fn with_len(format: WireFormat, len: usize) -> WirePayload {
        match format {
            WireFormat::DenseF32 => WirePayload::DenseF32(vec![0.0; len]),
            WireFormat::PackedSigns => WirePayload::PackedSigns(PackedVotes::with_len(len)),
            WireFormat::QuantizedI8 => {
                WirePayload::QuantizedI8 { scale: 0.0, bytes: vec![0; len] }
            }
            WireFormat::QuantizedI8PerTensor => {
                WirePayload::with_layout(format, &Arc::new(ParamLayout::single(len)))
            }
        }
    }

    /// A zeroed payload sized from a parameter layout — how the trainer
    /// builds its persistent buffers
    /// ([`crate::runtime::StepBackend::layout`]). Only the per-tensor
    /// format actually stores the layout (one scale slot per segment);
    /// every other format just takes its coordinate count.
    pub fn with_layout(format: WireFormat, layout: &Arc<ParamLayout>) -> WirePayload {
        match format {
            WireFormat::QuantizedI8PerTensor => WirePayload::QuantizedI8PerTensor {
                scales: vec![0.0; layout.len()],
                bytes: vec![0; layout.param_count()],
                layout: Arc::clone(layout),
            },
            other => WirePayload::with_len(other, layout.param_count()),
        }
    }

    pub fn format(&self) -> WireFormat {
        match self {
            WirePayload::DenseF32(_) => WireFormat::DenseF32,
            WirePayload::PackedSigns(_) => WireFormat::PackedSigns,
            WirePayload::QuantizedI8 { .. } => WireFormat::QuantizedI8,
            WirePayload::QuantizedI8PerTensor { .. } => WireFormat::QuantizedI8PerTensor,
        }
    }

    /// Number of coordinates this payload carries.
    pub fn len(&self) -> usize {
        match self {
            WirePayload::DenseF32(v) => v.len(),
            WirePayload::PackedSigns(p) => p.len(),
            WirePayload::QuantizedI8 { bytes, .. } => bytes.len(),
            WirePayload::QuantizedI8PerTensor { bytes, .. } => bytes.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total bytes this message puts on the wire — the number the clock
    /// bills. By construction equal to
    /// `self.format().wire_bytes(self.len(), segments)` with `segments`
    /// the payload's own scale count.
    pub fn wire_bytes(&self) -> u64 {
        match self {
            WirePayload::DenseF32(v) => v.len() as u64 * 4,
            WirePayload::PackedSigns(p) => p.wire_bytes(),
            WirePayload::QuantizedI8 { bytes, .. } => codec::q8_bytes(bytes.len()),
            WirePayload::QuantizedI8PerTensor { scales, bytes, .. } => {
                codec::q8pt_bytes(bytes.len(), scales.len())
            }
        }
    }

    /// See [`WireFormat::ring_reducible`].
    pub fn ring_reducible(&self) -> bool {
        self.format().ring_reducible()
    }

    /// The dense f32 view, when this is a [`WirePayload::DenseF32`].
    pub fn as_dense(&self) -> Option<&[f32]> {
        match self {
            WirePayload::DenseF32(v) => Some(v),
            _ => None,
        }
    }

    /// The packed-vote view, when this is a [`WirePayload::PackedSigns`].
    pub fn as_packed_signs(&self) -> Option<&PackedVotes> {
        match self {
            WirePayload::PackedSigns(p) => Some(p),
            _ => None,
        }
    }

    /// The parameter layout a per-tensor payload was sized with.
    pub fn layout(&self) -> Option<&Arc<ParamLayout>> {
        match self {
            WirePayload::QuantizedI8PerTensor { layout, .. } => Some(layout),
            _ => None,
        }
    }

    /// The per-segment scales of a per-tensor payload (or the single
    /// per-message scale of a `q8` payload).
    pub fn scales(&self) -> Option<&[f32]> {
        match self {
            WirePayload::QuantizedI8 { scale, .. } => Some(std::slice::from_ref(scale)),
            WirePayload::QuantizedI8PerTensor { scales, .. } => Some(scales),
            _ => None,
        }
    }

    /// Worker-side packing shared by every dense-exchange outer
    /// optimizer: fill this payload with rank's end-of-round state in
    /// the payload's own format — the parameters themselves for
    /// `DenseF32`, the quantized difference `start - end` for the
    /// quantized formats (one scale per message for `QuantizedI8`, one
    /// per layout segment for `QuantizedI8PerTensor`). Buffer capacity
    /// is reused; no allocation in steady state.
    ///
    /// # Panics
    ///
    /// On a `PackedSigns` buffer: a dense parameter exchange has no
    /// 1-bit encoding (config validation keeps this combination from
    /// ever being built — [`crate::config::RunConfig::validate`]). On a
    /// per-tensor buffer whose layout does not tile `start.len()`, or a
    /// dense buffer whose length differs from `end.len()` — the
    /// persistent buffer's size is the byte count the round was billed
    /// with, so silently resizing it here would defeat the trainer's
    /// pack-time drift check.
    pub fn pack_end(&mut self, start: &[f32], end: &[f32]) {
        match self {
            WirePayload::DenseF32(buf) => {
                assert_eq!(
                    buf.len(),
                    end.len(),
                    "pack_end: {} coordinates into a dense payload sized {}",
                    end.len(),
                    buf.len()
                );
                buf.copy_from_slice(end);
            }
            WirePayload::QuantizedI8 { scale, bytes } => {
                *scale = codec::quantize_diff_into(start, end, bytes);
            }
            WirePayload::QuantizedI8PerTensor { layout, scales, bytes } => {
                assert_eq!(
                    start.len(),
                    layout.param_count(),
                    "pack_end: {} coordinates vs a layout tiling {}",
                    start.len(),
                    layout.param_count()
                );
                for (e, s) in layout.entries().iter().zip(scales.iter_mut()) {
                    let r = e.offset..e.offset + e.numel();
                    *s = codec::quantize_diff_slice(
                        &start[r.clone()],
                        &end[r.clone()],
                        &mut bytes[r],
                    );
                }
            }
            WirePayload::PackedSigns(_) => {
                panic!("a dense parameter exchange cannot pack into a packed_signs payload")
            }
        }
    }

    /// Worker-side packing for sign-vote optimizers: pack the ±1 vote
    /// vector at 1 bit/coordinate ([`PackedVotes::pack_into`]).
    ///
    /// # Panics
    ///
    /// On a dense or quantized buffer — sign votes only have the 1-bit
    /// encoding (again unreachable under a validated config).
    pub fn pack_sign_votes(&mut self, votes: &[f32]) {
        match self {
            WirePayload::PackedSigns(p) => p.pack_into(votes),
            other => panic!(
                "sign votes need a packed_signs payload, got {}",
                other.format().name()
            ),
        }
    }

    /// Server-side reconstruction of the round's average end point
    /// `x̄_{t,τ}` from the gathered payloads, into `out`:
    ///
    /// * `DenseF32` — the exact mean of the rank parameters, computed
    ///   by the same [`collectives::allreduce_mean`] arithmetic (f64
    ///   accumulation in rank order) the trainer historically used, so
    ///   the dense path is bitwise-identical to the pre-payload
    ///   semantics by construction.
    /// * `QuantizedI8` — `start - mean_i(dequantize(payload_i))`: each
    ///   rank's difference decodes with its own scale, is averaged in
    ///   f64 in rank order, and re-anchors at the round start.
    /// * `QuantizedI8PerTensor` — same arithmetic, but each coordinate
    ///   decodes with its **segment's** scale. Iteration is segment-
    ///   major in layout (= coordinate) order, so with a one-segment
    ///   layout the accumulation order — and hence the result — is
    ///   bitwise-identical to `QuantizedI8`.
    ///
    /// The divisor is `payloads.len()` — the round's `n_effective` —
    /// so the mean is well defined for any non-empty survivor set under
    /// dropped/rejected payloads.
    ///
    /// # Errors
    ///
    /// [`WireError::NonFiniteScale`] if any quantized payload carries a
    /// non-finite scale (NaN poison from a diverged rank, or corruption
    /// in transit): bad data must never be silently averaged in. The
    /// check runs before any accumulation — `out` is untouched on
    /// error. Dense payloads carry no scale; a non-finite dense
    /// coordinate propagates into the mean, where the trainer's
    /// finiteness check catches it (reject dense payloads up front with
    /// [`WirePayload::check_finite`] when faults are in play).
    ///
    /// # Panics
    ///
    /// On `PackedSigns` payloads (a majority tally has no mean end
    /// point — tally them with
    /// [`crate::dist::votes::majority_vote_packed`]), on mixed formats
    /// or mixed layouts, or on length mismatches — API misuse, not wire
    /// damage.
    pub fn mean_end_into(
        payloads: &[WirePayload],
        start: &[f32],
        out: &mut [f32],
    ) -> Result<(), WireError> {
        assert!(!payloads.is_empty(), "exchange over zero workers");
        for (i, p) in payloads.iter().enumerate() {
            assert_eq!(p.format(), payloads[0].format(), "worker {i}: mixed wire formats");
            assert_eq!(
                p.len(),
                out.len(),
                "worker {i}: payload length {} != output {}",
                p.len(),
                out.len()
            );
        }
        // reject non-finite scales before touching `out`: O(S) per
        // payload, and the poison never reaches the accumulator
        for (i, p) in payloads.iter().enumerate() {
            if let Some(scales) = p.scales() {
                for (si, s) in scales.iter().enumerate() {
                    if !s.is_finite() {
                        return Err(WireError::NonFiniteScale { worker: i, segment: si });
                    }
                }
            }
        }
        match payloads[0] {
            WirePayload::DenseF32(_) => {
                collectives::allreduce_mean(
                    payloads,
                    |p| p.as_dense().expect("format checked above"),
                    out,
                );
            }
            WirePayload::QuantizedI8 { .. } => {
                assert_eq!(start.len(), out.len(), "start length {} != output", start.len());
                let inv_n = 1.0f64 / payloads.len() as f64;
                for (i, o) in out.iter_mut().enumerate() {
                    let mut acc = 0.0f64;
                    for p in payloads {
                        let WirePayload::QuantizedI8 { scale, bytes } = p else {
                            unreachable!("format checked above")
                        };
                        acc += codec::dequantize_i8(bytes[i], *scale) as f64;
                    }
                    *o = start[i] - (acc * inv_n) as f32;
                }
            }
            WirePayload::QuantizedI8PerTensor { .. } => {
                assert_eq!(start.len(), out.len(), "start length {} != output", start.len());
                let WirePayload::QuantizedI8PerTensor { layout, .. } = &payloads[0] else {
                    unreachable!("format checked above")
                };
                // a layout tiling fewer coordinates than the payload
                // carries would leave out's tail stale below — reject
                // inconsistent hand-built payloads loudly instead
                assert_eq!(
                    layout.param_count(),
                    out.len(),
                    "payload layout tiles {} of {} coordinates",
                    layout.param_count(),
                    out.len()
                );
                for (i, p) in payloads.iter().enumerate() {
                    assert_eq!(p.layout(), Some(layout), "worker {i}: mixed parameter layouts");
                }
                let inv_n = 1.0f64 / payloads.len() as f64;
                for (si, e) in layout.entries().iter().enumerate() {
                    for i in e.offset..e.offset + e.numel() {
                        let mut acc = 0.0f64;
                        for p in payloads {
                            let WirePayload::QuantizedI8PerTensor { scales, bytes, .. } = p else {
                                unreachable!("format checked above")
                            };
                            acc += codec::dequantize_i8(bytes[i], scales[si]) as f64;
                        }
                        out[i] = start[i] - (acc * inv_n) as f32;
                    }
                }
            }
            WirePayload::PackedSigns(_) => {
                panic!("packed sign votes have no mean end point; run the majority tally")
            }
        }
        Ok(())
    }

    /// Validate that this payload carries no non-finite data: scales
    /// for the quantized formats (O(S)), every coordinate for dense
    /// (O(P) — only worth paying when faults are in play), and nothing
    /// for packed signs (every bit pattern is a valid vote). `worker`
    /// is the payload's index in the round's gather, reported in the
    /// error. This is the pack-time half of the corruption contract;
    /// [`WirePayload::mean_end_into`] re-checks scales at decode time.
    pub fn check_finite(&self, worker: usize) -> Result<(), WireError> {
        match self {
            WirePayload::DenseF32(v) => {
                if let Some(index) = v.iter().position(|x| !x.is_finite()) {
                    return Err(WireError::NonFiniteCoord { worker, index });
                }
            }
            WirePayload::PackedSigns(_) => {}
            WirePayload::QuantizedI8 { scale, .. } => {
                if !scale.is_finite() {
                    return Err(WireError::NonFiniteScale { worker, segment: 0 });
                }
            }
            WirePayload::QuantizedI8PerTensor { scales, .. } => {
                if let Some(segment) = scales.iter().position(|s| !s.is_finite()) {
                    return Err(WireError::NonFiniteScale { worker, segment });
                }
            }
        }
        Ok(())
    }

    /// Inject one transit corruption into this payload, fault-plan
    /// style: a NaN-poisoned scale or coordinate (detectable — fails
    /// [`WirePayload::check_finite`]) or a flipped quantized byte /
    /// sign bit (undetectable by construction — every bit pattern is a
    /// valid encoding — and survived with bounded error). Formats with
    /// both failure modes pick one with a fair draw.
    pub fn corrupt(&mut self, rng: &mut Rng) {
        match self {
            WirePayload::DenseF32(v) => {
                if !v.is_empty() {
                    let i = rng.below(v.len() as u64) as usize;
                    v[i] = f32::NAN;
                }
            }
            WirePayload::PackedSigns(p) => {
                if !p.is_empty() {
                    let coord = rng.below(p.len() as u64) as usize;
                    p.flip_bit(coord);
                }
            }
            WirePayload::QuantizedI8 { scale, bytes } => {
                if bytes.is_empty() || rng.bernoulli(0.5) {
                    *scale = f32::NAN;
                } else {
                    let i = rng.below(bytes.len() as u64) as usize;
                    bytes[i] ^= 1 << rng.below(8);
                }
            }
            WirePayload::QuantizedI8PerTensor { scales, bytes, .. } => {
                if bytes.is_empty() || rng.bernoulli(0.5) {
                    let si = rng.below(scales.len().max(1) as u64) as usize;
                    if let Some(s) = scales.get_mut(si) {
                        *s = f32::NAN;
                    }
                } else {
                    let i = rng.below(bytes.len() as u64) as usize;
                    bytes[i] ^= 1 << rng.below(8);
                }
            }
        }
    }

    /// The hierarchical exchange's data path: split the round's
    /// payloads into `groups` contiguous groups of ⌈len/groups⌉ (the
    /// same split [`crate::comm::CommModel::hierarchical_time`] bills),
    /// aggregate each group at its head in the payload's own format,
    /// and return one payload per *input slot* holding its group head's
    /// aggregate. Feeding that replicated vector to the ordinary
    /// n-effective aggregation (mean or tally) weights each group by
    /// its member count — majority-of-weighted-majorities for votes,
    /// group-size-weighted mean of group means for the i8 formats — so
    /// outer optimizers consume a hierarchical round through their
    /// unchanged `apply(payloads)` interface.
    ///
    /// Per-format head aggregation:
    ///
    /// * `QuantizedI8` / `QuantizedI8PerTensor` — decode each member's
    ///   difference with its own scale(s), mean in f64 in member order,
    ///   re-quantize against a fresh head scale
    ///   ([`codec::quantize_slice`], per segment for `q8pt`). One extra
    ///   bounded quantization error per level — the price of a partial
    ///   aggregate that fits back into the wire format.
    /// * `PackedSigns` — partial majority tally over the group
    ///   ([`votes::majority_vote_packed`]), repacked as a ±1 vote
    ///   payload (wire-tie semantics: group ties decode +1).
    ///
    /// # Panics
    ///
    /// On dense payloads (ring-reducible — the hierarchy is never
    /// selected for them), on empty/mixed inputs, and on
    /// `groups == 0`: misuse, not wire damage. Callers must
    /// [`check_finite`](Self::check_finite) survivors first; a NaN
    /// scale here would poison the head's re-quantization.
    pub fn aggregate_group_heads(payloads: &[WirePayload], groups: usize) -> Vec<WirePayload> {
        assert!(!payloads.is_empty(), "hierarchical aggregation over zero payloads");
        assert!(groups > 0, "hierarchical aggregation needs at least one group");
        let format = payloads[0].format();
        let len = payloads[0].len();
        for (i, p) in payloads.iter().enumerate() {
            assert_eq!(p.format(), format, "worker {i}: mixed wire formats");
            assert_eq!(p.len(), len, "worker {i}: payload length {} != {len}", p.len());
        }
        assert!(
            !format.ring_reducible(),
            "dense exchanges ring-reduce; the hierarchy is never selected for them"
        );
        let m = super::div_up(payloads.len(), groups.min(payloads.len()));
        let mut out = Vec::with_capacity(payloads.len());
        for chunk in payloads.chunks(m) {
            let head = Self::aggregate_head(chunk, len);
            for _ in 0..chunk.len() - 1 {
                out.push(head.clone());
            }
            out.push(head);
        }
        out
    }

    /// One group head's partial aggregate over its members' payloads.
    fn aggregate_head(chunk: &[WirePayload], len: usize) -> WirePayload {
        let inv = 1.0f64 / chunk.len() as f64;
        match &chunk[0] {
            WirePayload::QuantizedI8 { .. } => {
                let mut acc = vec![0.0f64; len];
                for p in chunk {
                    let WirePayload::QuantizedI8 { scale, bytes } = p else {
                        unreachable!("format checked by the caller")
                    };
                    for (a, &b) in acc.iter_mut().zip(bytes) {
                        *a += codec::dequantize_i8(b, *scale) as f64;
                    }
                }
                let mean: Vec<f32> = acc.iter().map(|a| (a * inv) as f32).collect();
                let mut bytes = vec![0u8; len];
                let scale = codec::quantize_slice(&mean, &mut bytes);
                WirePayload::QuantizedI8 { scale, bytes }
            }
            WirePayload::QuantizedI8PerTensor { layout, .. } => {
                let layout = Arc::clone(layout);
                for (i, p) in chunk.iter().enumerate() {
                    assert_eq!(
                        p.layout(),
                        Some(&layout),
                        "worker {i}: mixed parameter layouts"
                    );
                }
                let mut acc = vec![0.0f64; len];
                for p in chunk {
                    let WirePayload::QuantizedI8PerTensor { scales, bytes, .. } = p else {
                        unreachable!("format checked by the caller")
                    };
                    for (si, e) in layout.entries().iter().enumerate() {
                        for i in e.offset..e.offset + e.numel() {
                            acc[i] += codec::dequantize_i8(bytes[i], scales[si]) as f64;
                        }
                    }
                }
                let mean: Vec<f32> = acc.iter().map(|a| (a * inv) as f32).collect();
                let mut bytes = vec![0u8; len];
                let mut scales = vec![0.0f32; layout.len()];
                for (e, s) in layout.entries().iter().zip(scales.iter_mut()) {
                    let r = e.offset..e.offset + e.numel();
                    *s = codec::quantize_slice(&mean[r.clone()], &mut bytes[r]);
                }
                WirePayload::QuantizedI8PerTensor { layout, scales, bytes }
            }
            WirePayload::PackedSigns(_) => {
                let members: Vec<&PackedVotes> = chunk
                    .iter()
                    .map(|p| p.as_packed_signs().expect("format checked by the caller"))
                    .collect();
                let mut tally = vec![0.0f32; len];
                votes::majority_vote_packed(&members, &mut tally);
                WirePayload::PackedSigns(PackedVotes::pack(&tally))
            }
            WirePayload::DenseF32(_) => unreachable!("rejected by the caller"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL_FORMATS: [WireFormat; 4] = [
        WireFormat::DenseF32,
        WireFormat::PackedSigns,
        WireFormat::QuantizedI8,
        WireFormat::QuantizedI8PerTensor,
    ];

    fn two_segment_layout(a: usize, b: usize) -> Arc<ParamLayout> {
        use crate::runtime::ParamEntry;
        let entries = vec![
            ParamEntry { name: "lo".into(), offset: 0, shape: vec![a] },
            ParamEntry { name: "hi".into(), offset: a, shape: vec![b] },
        ];
        Arc::new(ParamLayout::from_entries(entries, a + b).unwrap())
    }

    #[test]
    fn with_len_builds_sized_zeroed_payloads_in_every_format() {
        for format in ALL_FORMATS {
            let p = WirePayload::with_len(format, 37);
            assert_eq!(p.format(), format);
            assert_eq!(p.len(), 37);
            assert!(!p.is_empty());
            assert_eq!(p.wire_bytes(), format.wire_bytes(37, 1), "{}", format.name());
            assert!(WirePayload::with_len(format, 0).is_empty());
        }
    }

    #[test]
    fn with_layout_sizes_per_tensor_payloads_from_the_layout() {
        let layout = two_segment_layout(5, 11);
        for format in ALL_FORMATS {
            let p = WirePayload::with_layout(format, &layout);
            assert_eq!(p.format(), format);
            assert_eq!(p.len(), 16, "{}", format.name());
        }
        let pt = WirePayload::with_layout(WireFormat::QuantizedI8PerTensor, &layout);
        assert_eq!(pt.scales().unwrap().len(), 2);
        assert_eq!(pt.layout(), Some(&layout));
        assert_eq!(pt.wire_bytes(), WireFormat::QuantizedI8PerTensor.wire_bytes(16, 2));
        // one scale more than the per-message format
        assert_eq!(pt.wire_bytes(), WireFormat::QuantizedI8.wire_bytes(16, 1) + 4);
    }

    #[test]
    fn wire_bytes_match_the_codec_models() {
        let p = 1 << 20;
        assert_eq!(WireFormat::DenseF32.wire_bytes(p, 1), p as u64 * 4);
        assert_eq!(WireFormat::PackedSigns.wire_bytes(p, 1), codec::sign_allreduce_bytes(p));
        assert_eq!(WireFormat::QuantizedI8.wire_bytes(p, 1), codec::q8_bytes(p));
        assert_eq!(WireFormat::QuantizedI8PerTensor.wire_bytes(p, 7), codec::q8pt_bytes(p, 7));
    }

    #[test]
    fn parse_and_name_round_trip() {
        for format in ALL_FORMATS {
            assert_eq!(WireFormat::parse(format.name()), Some(format));
        }
        assert_eq!(WireFormat::parse("q8"), Some(WireFormat::QuantizedI8));
        assert_eq!(WireFormat::parse("q8pt"), Some(WireFormat::QuantizedI8PerTensor));
        assert_eq!(WireFormat::parse("1bit"), Some(WireFormat::PackedSigns));
        assert_eq!(WireFormat::parse("warpdrive"), None);
    }

    #[test]
    fn only_dense_is_ring_reducible() {
        assert!(WireFormat::DenseF32.ring_reducible());
        assert!(!WireFormat::PackedSigns.ring_reducible());
        assert!(!WireFormat::QuantizedI8.ring_reducible());
        assert!(!WireFormat::QuantizedI8PerTensor.ring_reducible());
    }

    #[test]
    fn exchange_time_matches_the_clock_topology() {
        // the analytical re-costing helper and the clock's payload
        // billing must agree exactly, format by format
        use crate::comm::SimClock;
        use crate::util::rng::Rng;
        let m = CommModel {
            latency_s: 1e-3,
            bandwidth_bps: 1e6,
            straggler_sigma: 0.0,
            straggler_scale_s: 0.0,
        };
        for n in [4usize, 1024] {
            for format in ALL_FORMATS {
                let payload = WirePayload::with_len(format, 1000);
                let mut clock = SimClock::default();
                clock.charge_exchange(&m, n, &payload, &mut Rng::new(1));
                let t = format.exchange_time(&m, n, 1000, 1);
                assert!((clock.comm_s - t).abs() < 1e-15, "{} n={n}", format.name());
            }
        }
    }

    #[test]
    fn hierarchical_topology_beats_flat_for_compressed_formats_at_scale() {
        // the acceptance pin: at n = 1024 the selector picks the
        // hierarchical topology for q8/q8pt/signs and the modeled round
        // time beats the flat gather+broadcast by a wide margin
        let m = CommModel::preset("ethernet").unwrap();
        let n = 1024;
        let p = 1 << 20;
        for format in [
            WireFormat::PackedSigns,
            WireFormat::QuantizedI8,
            WireFormat::QuantizedI8PerTensor,
        ] {
            let topo = Topology::select(format.ring_reducible(), n);
            assert!(
                matches!(topo, Topology::Hierarchical { .. }),
                "{}: {topo:?}",
                format.name()
            );
            let bytes = format.wire_bytes(p, 4);
            let hier = format.exchange_time(&m, n, p, 4);
            let flat = m.gather_time(n, bytes) + m.broadcast_time(n, bytes);
            assert!(hier * 8.0 < flat, "{}: {hier} vs flat {flat}", format.name());
        }
        // dense still rings, at every n
        assert_eq!(Topology::select(true, n), Topology::Ring);
    }

    #[test]
    fn dense_mean_matches_allreduce_mean_bitwise() {
        let ends = [vec![1.0f32, 2.0, -3.0], vec![0.5f32, -2.0, 9.0], vec![0.25f32, 0.1, 1.0]];
        let payloads: Vec<WirePayload> = ends
            .iter()
            .map(|e| {
                let mut p = WirePayload::with_len(WireFormat::DenseF32, 3);
                p.pack_end(&[0.0; 3], e);
                p
            })
            .collect();
        let mut from_payloads = vec![0.0f32; 3];
        WirePayload::mean_end_into(&payloads, &[0.0; 3], &mut from_payloads).unwrap();
        let mut reference = vec![0.0f32; 3];
        collectives::allreduce_mean(&ends, |e| e.as_slice(), &mut reference);
        for (a, b) in from_payloads.iter().zip(&reference) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn q8_mean_reconstructs_the_average_end_within_quantization_error() {
        let start = vec![1.0f32, -0.5, 0.25, 2.0];
        let ends = [vec![0.9f32, -0.45, 0.30, 1.90], vec![0.8f32, -0.55, 0.20, 2.05]];
        let payloads: Vec<WirePayload> = ends
            .iter()
            .map(|e| {
                let mut p = WirePayload::with_len(WireFormat::QuantizedI8, 4);
                p.pack_end(&start, e);
                p
            })
            .collect();
        let mut avg = vec![0.0f32; 4];
        WirePayload::mean_end_into(&payloads, &start, &mut avg).unwrap();
        let mut exact = vec![0.0f32; 4];
        collectives::allreduce_mean(&ends, |e| e.as_slice(), &mut exact);
        // per-rank quantization step: scale = max|diff|/127; the mean's
        // error is at most the mean of the per-rank half-steps
        for (j, (a, e)) in avg.iter().zip(&exact).enumerate() {
            assert!((a - e).abs() < 2e-3, "coord {j}: {a} vs {e}");
        }
    }

    #[test]
    fn q8pt_per_segment_scales_resolve_hetero_magnitudes() {
        // segment "lo" moves by ~1e-3, segment "hi" by ~1.0: one shared
        // scale (q8) rounds the small segment to nothing, per-tensor
        // scales keep it. This is the format's reason to exist; the
        // pinned numeric version lives in rust/tests/layout_wire.rs.
        let layout = two_segment_layout(4, 4);
        let start = vec![0.0f32; 8];
        #[rustfmt::skip]
        let end = vec![
            -1e-3f32, -5e-4, 1e-3, -7.5e-4, // lo: tiny diffs
            -1.0, 0.5, -0.25, 1.0,          // hi: large diffs
        ];
        let mut pt = WirePayload::with_layout(WireFormat::QuantizedI8PerTensor, &layout);
        pt.pack_end(&start, &end);
        let scales = pt.scales().unwrap().to_vec();
        assert!(scales[0] < scales[1] / 100.0, "{scales:?}");
        let mut avg = vec![0.0f32; 8];
        WirePayload::mean_end_into(std::slice::from_ref(&pt), &start, &mut avg).unwrap();
        // every coordinate decodes within half its segment's step
        for (j, (a, e)) in avg.iter().zip(&end).enumerate() {
            let step = scales[j / 4];
            assert!((a - e).abs() <= step / 2.0 + 1e-7, "coord {j}: {a} vs {e}");
        }
        // and the tiny segment survived (q8 would have zeroed it)
        assert!(avg[0] != 0.0 && avg[2] != 0.0, "{avg:?}");
    }

    #[test]
    fn q8_exchange_with_zero_difference_is_exact() {
        let start = vec![0.5f32, -3.0, 7.0];
        for format in [WireFormat::QuantizedI8, WireFormat::QuantizedI8PerTensor] {
            let mut p = WirePayload::with_len(format, 3);
            p.pack_end(&start, &start);
            let mut avg = vec![9.0f32; 3];
            WirePayload::mean_end_into(std::slice::from_ref(&p), &start, &mut avg).unwrap();
            assert_eq!(avg, start, "{}", format.name());
        }
    }

    #[test]
    fn pack_end_reuses_buffers_across_rounds() {
        let start = vec![1.0f32; 256];
        let end = vec![0.75f32; 256];
        for format in ALL_FORMATS {
            if format == WireFormat::PackedSigns {
                continue; // votes pack through pack_sign_votes instead
            }
            let mut p = WirePayload::with_len(format, 256);
            p.pack_end(&start, &end);
            let bytes_before = p.wire_bytes();
            for _ in 0..5 {
                p.pack_end(&start, &end);
            }
            assert_eq!(p.len(), 256, "{}", format.name());
            assert_eq!(p.wire_bytes(), bytes_before);
        }
    }

    #[test]
    #[should_panic(expected = "packed_signs")]
    fn dense_pack_into_sign_buffer_panics() {
        let mut p = WirePayload::with_len(WireFormat::PackedSigns, 8);
        p.pack_end(&[0.0; 8], &[1.0; 8]);
    }

    #[test]
    #[should_panic(expected = "sign votes")]
    fn sign_votes_into_dense_buffer_panic() {
        let mut p = WirePayload::with_len(WireFormat::DenseF32, 8);
        p.pack_sign_votes(&[1.0; 8]);
    }

    #[test]
    #[should_panic(expected = "layout tiling")]
    fn per_tensor_pack_with_wrong_dimension_panics() {
        let layout = two_segment_layout(4, 4);
        let mut p = WirePayload::with_layout(WireFormat::QuantizedI8PerTensor, &layout);
        p.pack_end(&[0.0; 6], &[1.0; 6]);
    }

    #[test]
    #[should_panic(expected = "majority tally")]
    fn mean_over_sign_votes_panics() {
        let payloads = vec![WirePayload::with_len(WireFormat::PackedSigns, 8)];
        let mut out = vec![0.0f32; 8];
        let _ = WirePayload::mean_end_into(&payloads, &[0.0; 8], &mut out);
    }

    #[test]
    #[should_panic(expected = "mixed wire formats")]
    fn mixed_formats_panic() {
        let payloads = vec![
            WirePayload::with_len(WireFormat::DenseF32, 4),
            WirePayload::with_len(WireFormat::QuantizedI8, 4),
        ];
        let mut out = vec![0.0f32; 4];
        let _ = WirePayload::mean_end_into(&payloads, &[0.0; 4], &mut out);
    }

    #[test]
    #[should_panic(expected = "mixed parameter layouts")]
    fn mixed_layouts_panic() {
        let pt = WireFormat::QuantizedI8PerTensor;
        let payloads = vec![
            WirePayload::with_layout(pt, &two_segment_layout(4, 4)),
            WirePayload::with_layout(pt, &two_segment_layout(2, 6)),
        ];
        let mut out = vec![0.0f32; 8];
        let _ = WirePayload::mean_end_into(&payloads, &[0.0; 8], &mut out);
    }

    #[test]
    #[should_panic(expected = "pack_end")]
    fn dense_pack_with_wrong_dimension_panics() {
        // regression: this used to silently resize the persistent
        // buffer, defeating the trainer's pack-time drift check
        let mut p = WirePayload::with_len(WireFormat::DenseF32, 8);
        p.pack_end(&[0.0; 6], &[1.0; 6]);
    }

    #[test]
    fn non_finite_differences_are_rejected_not_averaged() {
        // NaN and inf coordinates poison the quantization scale at pack
        // time; both check_finite and the decode-time mean report the
        // offending worker instead of folding the poison into the mean
        let layout = two_segment_layout(2, 2);
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let start = vec![0.0f32; 4];
            let end = vec![0.1f32, bad, -0.1, 0.2];
            for format in [WireFormat::QuantizedI8, WireFormat::QuantizedI8PerTensor] {
                let mut good = WirePayload::with_layout(format, &layout);
                good.pack_end(&start, &[0.1, 0.0, -0.1, 0.2]);
                let mut p = WirePayload::with_layout(format, &layout);
                p.pack_end(&start, &end);
                assert!(
                    p.scales().unwrap().iter().any(|s| !s.is_finite()),
                    "{}: {bad} must poison a scale",
                    format.name()
                );
                assert_eq!(good.check_finite(0), Ok(()));
                let err = p.check_finite(3).unwrap_err();
                let WireError::NonFiniteScale { worker, segment } = err else {
                    panic!("{}: unexpected {err:?}", format.name())
                };
                assert_eq!(worker, 3);
                // q8 poisons its only scale; q8pt isolates the poison
                // to the segment holding the bad coordinate (coord 1
                // lives in segment "lo") — both report segment 0 here
                assert_eq!(segment, 0);
                let mut out = vec![7.0f32; 4];
                let payloads = vec![good.clone(), p.clone()];
                let got = WirePayload::mean_end_into(&payloads, &start, &mut out);
                assert!(
                    matches!(got, Err(WireError::NonFiniteScale { worker: 1, .. })),
                    "{}: {got:?}",
                    format.name()
                );
                // error path must not touch the output
                assert_eq!(out, vec![7.0f32; 4], "{}", format.name());
            }
        }
    }

    #[test]
    fn check_finite_flags_dense_coordinates_and_passes_votes() {
        let mut p = WirePayload::with_len(WireFormat::DenseF32, 4);
        p.pack_end(&[0.0; 4], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(p.check_finite(0), Ok(()));
        p.pack_end(&[0.0; 4], &[1.0, 2.0, f32::NAN, 4.0]);
        assert_eq!(p.check_finite(5), Err(WireError::NonFiniteCoord { worker: 5, index: 2 }));
        let votes = WirePayload::with_len(WireFormat::PackedSigns, 64);
        assert_eq!(votes.check_finite(0), Ok(()));
    }

    #[test]
    fn corrupt_damages_exactly_one_thing_per_format() {
        let mut rng = Rng::new(77);
        for format in ALL_FORMATS {
            for trial in 0..20 {
                let mut p = WirePayload::with_len(format, 33);
                if format == WireFormat::PackedSigns {
                    p.pack_sign_votes(&[1.0; 33]);
                } else {
                    p.pack_end(&[0.5; 33], &[0.25; 33]);
                }
                let clean = p.clone();
                p.corrupt(&mut rng);
                assert_ne!(p, clean, "{} trial {trial}: corruption must show", format.name());
                // wire size is untouched — corruption is in-place damage
                assert_eq!(p.wire_bytes(), clean.wire_bytes());
                match format {
                    // every sign-word bit pattern is valid: survived
                    WireFormat::PackedSigns => assert_eq!(p.check_finite(0), Ok(())),
                    // dense / scale poison is detectable, byte flips are
                    // not — either way the payload stays structurally valid
                    _ => {
                        let _ = p.check_finite(0);
                    }
                }
            }
        }
    }

    #[test]
    fn group_heads_replicate_one_aggregate_per_member() {
        // 7 payloads in 3 groups -> chunks of 3/3/1; each slot holds its
        // group head's aggregate, so adjacent members are identical
        let payloads: Vec<WirePayload> = (0..7)
            .map(|w| {
                let mut p = WirePayload::with_len(WireFormat::QuantizedI8, 5);
                p.pack_end(&[0.0; 5], &[0.1 * (w as f32 + 1.0); 5]);
                p
            })
            .collect();
        let heads = WirePayload::aggregate_group_heads(&payloads, 3);
        assert_eq!(heads.len(), 7);
        assert_eq!(heads[0], heads[1]);
        assert_eq!(heads[1], heads[2]);
        assert_eq!(heads[3], heads[5]);
        assert_ne!(heads[0], heads[3]);
        assert_ne!(heads[5], heads[6]);
    }

    #[test]
    fn hierarchical_mean_matches_flat_mean_within_quantization_error() {
        // equal group sizes: the mean of replicated group means equals
        // the flat mean up to one extra quantization level
        let start = vec![1.0f32, -0.5, 0.25, 2.0];
        let ends: Vec<Vec<f32>> = (0..8)
            .map(|w| start.iter().map(|s| s - 0.01 * (w as f32 - 3.5)).collect())
            .collect();
        for format in [WireFormat::QuantizedI8, WireFormat::QuantizedI8PerTensor] {
            let payloads: Vec<WirePayload> = ends
                .iter()
                .map(|e| {
                    let mut p = WirePayload::with_len(format, 4);
                    p.pack_end(&start, e);
                    p
                })
                .collect();
            let mut flat = vec![0.0f32; 4];
            WirePayload::mean_end_into(&payloads, &start, &mut flat).unwrap();
            let heads = WirePayload::aggregate_group_heads(&payloads, 4);
            let mut hier = vec![0.0f32; 4];
            WirePayload::mean_end_into(&heads, &start, &mut hier).unwrap();
            for (j, (h, f)) in hier.iter().zip(&flat).enumerate() {
                assert!((h - f).abs() < 2e-3, "{} coord {j}: {h} vs {f}", format.name());
            }
        }
    }

    #[test]
    fn group_heads_tally_signs_as_majority_of_majorities() {
        // 6 voters in 2 groups of 3. Coordinate 0: group A votes
        // (+,+,-) -> +, group B votes (-,-,+) -> -; the weighted final
        // tally ties 3:3 and decodes the wire-tie convention (+1).
        // Coordinate 1: unanimous per group, final -1.
        let votes: [[f32; 2]; 6] = [
            [1.0, -1.0],
            [1.0, -1.0],
            [-1.0, -1.0],
            [-1.0, -1.0],
            [-1.0, -1.0],
            [1.0, -1.0],
        ];
        let payloads: Vec<WirePayload> = votes
            .iter()
            .map(|v| {
                let mut p = WirePayload::with_len(WireFormat::PackedSigns, 2);
                p.pack_sign_votes(v);
                p
            })
            .collect();
        let heads = WirePayload::aggregate_group_heads(&payloads, 2);
        assert_eq!(heads.len(), 6);
        let mut tally = vec![0.0f32; 2];
        let packed: Vec<&PackedVotes> =
            heads.iter().map(|p| p.as_packed_signs().unwrap()).collect();
        votes::majority_vote_packed(&packed, &mut tally);
        assert_eq!(tally, vec![1.0, -1.0]);
    }

    #[test]
    #[should_panic(expected = "ring-reduce")]
    fn dense_payloads_refuse_hierarchical_aggregation() {
        let payloads = vec![WirePayload::with_len(WireFormat::DenseF32, 4); 4];
        let _ = WirePayload::aggregate_group_heads(&payloads, 2);
    }
}
