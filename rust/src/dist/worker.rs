//! One simulated data-parallel worker: local iterate, base-optimizer
//! state, private RNG substream, per-round loss bookkeeping — and the
//! parameter layout its flat vector follows, so per-segment views come
//! straight off the rank.

use std::sync::Arc;

use crate::optim::{BaseOptConfig, BaseOptimizer};
use crate::runtime::ParamLayout;
use crate::util::rng::Rng;

/// The state of rank `i` in the simulated fleet. Fields are public:
/// the trainer *is* the coordinator and manipulates workers directly
/// (copying the round's start point in, stepping the base optimizer,
/// borrowing `params` for the all-reduce).
pub struct Worker {
    /// Worker index i in 0..n (stable across the run; keys checkpoints).
    pub id: usize,
    /// Local iterate x^{(i)} as the flat f32[P] vector.
    pub params: Vec<f32>,
    /// Most recent local stochastic gradient — consumed by outer
    /// optimizers that build momentum from per-worker gradients
    /// (MV-sto-signSGD, Algorithm 6).
    pub last_grad: Vec<f32>,
    /// Private RNG substream for this worker's batch sampling.
    pub rng: Rng,
    /// Local base optimizer (AdamW / SGD / Lion / Sophia).
    pub opt: Box<dyn BaseOptimizer>,
    /// The backend's validated parameter layout
    /// ([`crate::runtime::StepBackend::layout`]): how `params` and
    /// `last_grad` tile into named segments. Shared across the fleet —
    /// every rank of a run follows the same layout.
    pub layout: Arc<ParamLayout>,
    loss_acc: f64,
    loss_n: u64,
}

impl Worker {
    /// Build rank `id` over the parameter vector `layout` tiles. The
    /// RNG is derived as `root.substream("worker", id)`, so a fleet
    /// rebuilt from the same root seed is bit-identical and distinct
    /// ranks get disjoint streams.
    pub fn new(id: usize, layout: Arc<ParamLayout>, base: &BaseOptConfig, root: &Rng) -> Worker {
        let p = layout.param_count();
        Worker {
            id,
            params: vec![0.0; p],
            last_grad: vec![0.0; p],
            rng: root.substream("worker", id as u64),
            opt: base.build(p),
            layout,
            loss_acc: 0.0,
            loss_n: 0,
        }
    }

    /// Parameter-vector dimension P.
    pub fn dim(&self) -> usize {
        self.params.len()
    }

    /// `(name, slice)` views of this rank's iterate, one per layout
    /// segment, in offset order.
    pub fn param_segments(&self) -> Vec<(&str, &[f32])> {
        self.layout.segments_of(&self.params)
    }

    /// `(name, slice)` views of this rank's last local gradient.
    pub fn grad_segments(&self) -> Vec<(&str, &[f32])> {
        self.layout.segments_of(&self.last_grad)
    }

    /// Record one local step: accumulate the loss for this round's
    /// report and stash the gradient for gradient-consuming outer
    /// optimizers.
    pub fn observe(&mut self, loss: f32, grads: &[f32]) {
        self.loss_acc += loss as f64;
        self.loss_n += 1;
        self.last_grad.copy_from_slice(grads);
    }

    /// Mean loss over the steps observed since the previous call; NaN
    /// when no step ran (e.g. a round this worker sat out). Resets the
    /// accumulator.
    pub fn take_mean_loss(&mut self) -> f64 {
        if self.loss_n == 0 {
            return f64::NAN;
        }
        let mean = self.loss_acc / self.loss_n as f64;
        self.loss_acc = 0.0;
        self.loss_n = 0;
        mean
    }

    /// Clear optimizer state and loss bookkeeping (parameters are left
    /// as-is; the trainer overwrites them at the next round start).
    pub fn reset(&mut self) {
        self.opt.reset();
        self.loss_acc = 0.0;
        self.loss_n = 0;
        self.last_grad.fill(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn worker(p: usize) -> Worker {
        Worker::new(0, Arc::new(ParamLayout::single(p)), &BaseOptConfig::sgd_plain(), &Rng::new(7))
    }

    #[test]
    fn new_worker_is_zeroed_with_right_dims() {
        let w = worker(16);
        assert_eq!(w.dim(), 16);
        assert_eq!(w.params, vec![0.0; 16]);
        assert_eq!(w.last_grad, vec![0.0; 16]);
        assert_eq!(w.id, 0);
        assert_eq!(w.layout.param_count(), 16);
    }

    #[test]
    fn segment_views_follow_the_layout() {
        use crate::runtime::ParamEntry;
        let layout = Arc::new(
            ParamLayout::from_entries(
                vec![
                    ParamEntry { name: "embed".into(), offset: 0, shape: vec![2, 3] },
                    ParamEntry { name: "out".into(), offset: 6, shape: vec![2] },
                ],
                8,
            )
            .unwrap(),
        );
        let mut w = Worker::new(1, layout, &BaseOptConfig::sgd_plain(), &Rng::new(7));
        for (i, p) in w.params.iter_mut().enumerate() {
            *p = i as f32;
        }
        let segs = w.param_segments();
        assert_eq!(segs.len(), 2);
        assert_eq!(segs[0].0, "embed");
        assert_eq!(segs[0].1, &w.params[0..6]);
        assert_eq!(segs[1].0, "out");
        assert_eq!(segs[1].1, &[6.0f32, 7.0][..]);
        w.observe(1.0, &[0.5; 8]);
        assert_eq!(w.grad_segments()[1].1, &[0.5f32, 0.5][..]);
    }

    #[test]
    fn mean_loss_accumulates_and_resets() {
        let mut w = worker(4);
        assert!(w.take_mean_loss().is_nan());
        let g = vec![1.0f32; 4];
        w.observe(2.0, &g);
        w.observe(4.0, &g);
        assert_eq!(w.take_mean_loss(), 3.0);
        assert!(w.take_mean_loss().is_nan(), "second take must see a reset accumulator");
    }

    #[test]
    fn observe_stashes_last_grad() {
        let mut w = worker(3);
        w.observe(1.0, &[1.0, -2.0, 3.0]);
        w.observe(1.0, &[4.0, 5.0, 6.0]);
        assert_eq!(w.last_grad, vec![4.0, 5.0, 6.0]);
    }

    #[test]
    fn workers_get_disjoint_deterministic_rng_substreams() {
        let root = Rng::new(42);
        let base = BaseOptConfig::sgd_plain();
        let layout = Arc::new(ParamLayout::single(4));
        let mut a0 = Worker::new(0, layout.clone(), &base, &root);
        let mut a0b = Worker::new(0, layout.clone(), &base, &root);
        let mut a1 = Worker::new(1, layout, &base, &root);
        let draw = |w: &mut Worker| -> Vec<u64> { (0..4).map(|_| w.rng.next_u64()).collect() };
        let s0 = draw(&mut a0);
        assert_eq!(s0, draw(&mut a0b), "same (root, id) must give the same stream");
        assert_ne!(s0, draw(&mut a1), "different ids must give different streams");
    }

    #[test]
    fn reset_clears_state_but_not_params() {
        let mut w = worker(2);
        w.params.copy_from_slice(&[5.0, 6.0]);
        w.observe(1.0, &[1.0, 1.0]);
        w.reset();
        assert_eq!(w.params, vec![5.0, 6.0]);
        assert_eq!(w.last_grad, vec![0.0, 0.0]);
        assert!(w.take_mean_loss().is_nan());
    }
}
