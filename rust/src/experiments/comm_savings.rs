//! Communication-savings experiment — the paper's motivating claim (§1):
//! with τ local steps, simulated wall-clock time to a target validation
//! loss collapses on slow interconnects, because per-step all-reduce
//! dominates.  Reports, per interconnect preset, the modeled time
//! breakdown and time-to-target for per-step AdamW vs Algorithm 1 at
//! τ ∈ {12, 24, 36} (the paper's 12×/24×/36× communication reductions).

use anyhow::Result;

use super::gpt::{cell, Algo};
use super::runner::{save_summary, Harness, Table};
use crate::comm::CommModel;
use crate::dist::WireFormat;
use crate::optim::BaseOptConfig;

/// Modeled seconds for one round exchange of `p` coordinates in `wire`
/// format — the same topology choice [`crate::comm::SimClock::charge_exchange`]
/// makes: ring for dense f32, gather+broadcast for compressed formats.
fn exchange_time(model: &CommModel, n: usize, wire: WireFormat, p: usize) -> f64 {
    let bytes = wire.wire_bytes(p);
    if wire.ring_reducible() {
        model.allreduce_time(n, bytes)
    } else {
        model.gather_time(n, bytes) + model.broadcast_time(n, bytes)
    }
}

pub fn run(h: &Harness) -> Result<()> {
    let budget = h.step_budget(120);
    let (label, preset) = h.sizes()[0];
    let mut text = format!(
        "Communication savings (GPT-2 {label} repro scale, n = 4 workers)\n\
         compute time measured on this host; comm time re-costed per wire\n\
         format (ring alpha-beta for dense f32, gather+broadcast for the\n\
         8-bit quantized exchange — comm/mod.rs + dist/wire.rs).\n\n"
    );

    // Run each algorithm ONCE on the neutral (free) network to get the
    // loss trajectory + measured compute; then re-cost communication
    // under each interconnect preset analytically (same trajectory —
    // the algorithms' updates don't depend on link speed). The q8 row
    // is a genuinely different trajectory (the exchange quantizes), so
    // it is its own run, not a re-costing.
    let mut runs = Vec::new();
    for (name, algo, tau, wire) in [
        ("AdamW (per-step)", Algo::StandaloneAdamW, 1usize, None),
        ("Algorithm 1, tau=12", Algo::Alg1 { eta: 12.0 }, 12, None),
        ("Algorithm 1, tau=24", Algo::Alg1 { eta: 12.0 }, 24, None),
        ("Algorithm 1, tau=36", Algo::Alg1 { eta: 12.0 }, 36, None),
        ("Algorithm 1, tau=12, q8", Algo::Alg1 { eta: 12.0 }, 12, Some(WireFormat::QuantizedI8)),
    ] {
        let mut cfg = cell(h, preset, algo, tau, budget, 4, BaseOptConfig::adamw_paper());
        cfg.wire = wire;
        if wire.is_some() {
            cfg.tag.push_str("-q8");
        }
        let resolved = cfg.resolved_wire();
        let summary = h.run(cfg)?;
        runs.push((name, resolved, summary));
    }

    let info = h.arts.preset(preset)?;
    let p = info.param_count;
    for net in ["nvlink", "infiniband", "ethernet", "wan"] {
        let model = CommModel::preset(net).unwrap();
        let mut t = Table::new(&[
            "Alg.",
            "wire",
            "comm rounds",
            "compute s",
            "comm s (model)",
            "total s",
            "final val",
        ]);
        for (name, wire, s) in &runs {
            let last = s.log.rows.last().unwrap();
            let comm_rounds = last.comm_rounds;
            // compute seconds: measured; comm: re-costed under this net
            let compute_s = last.sim_time_s; // free-net run: time == compute
            let comm_s = comm_rounds as f64 * exchange_time(&model, 4, *wire, p);
            t.row(vec![
                name.to_string(),
                wire.name().to_string(),
                format!("{comm_rounds}"),
                format!("{compute_s:.1}"),
                format!("{comm_s:.2}"),
                format!("{:.1}", compute_s + comm_s),
                format!("{:.4}", s.final_val),
            ]);
        }
        text.push_str(&format!("interconnect = {net}\n{}\n", t.render()));
    }
    text.push_str(
        "Reading: on fast links (nvlink) per-step AdamW is fine; on slow links\n\
         the tau-fold reduction in comm rounds dominates total time — the\n\
         regime the paper targets. The q8 row additionally shrinks each\n\
         round's payload 4x (at n = 4 its gather+broadcast undercuts the\n\
         dense ring on both latency and bandwidth terms) at the cost of a\n\
         bounded quantization error in the exchanged differences.\n",
    );
    println!("{text}");
    save_summary(h, "comm", &text)
}
