//! Communication-savings experiment — the paper's motivating claim (§1):
//! with τ local steps, simulated wall-clock time to a target validation
//! loss collapses on slow interconnects, because per-step all-reduce
//! dominates.  Reports, per interconnect preset, the modeled time
//! breakdown and time-to-target for per-step AdamW vs Algorithm 1 at
//! τ ∈ {12, 24, 36} (the paper's 12×/24×/36× communication reductions),
//! plus the payload-level axis: the 8-bit quantized exchange with one
//! scale per message (`q8`), with one scale per parameter-layout
//! segment (`q8pt`), and the DeMo-style sparse top-k residual-momentum
//! wire (`topk`) — and a per-segment breakdown of where the bits and
//! the update magnitude actually go.

use anyhow::Result;

use super::gpt::{cell, Algo};
use super::runner::{save_summary, Harness, Table};
use crate::comm::CommModel;
use crate::dist::WireFormat;
use crate::optim::BaseOptConfig;
use crate::train::metrics::render_segment_norms;

pub fn run(h: &Harness) -> Result<()> {
    let budget = h.step_budget(120);
    let (label, preset) = h.sizes()[0];
    let mut text = format!(
        "Communication savings (GPT-2 {label} repro scale, n = 4 workers)\n\
         compute time measured on this host; comm time re-costed per wire\n\
         format (ring alpha-beta for dense f32, gather+broadcast for the\n\
         compressed exchanges — comm/mod.rs + dist/wire.rs; q8pt\n\
         quantizes each parameter-layout segment against its own scale;\n\
         topk sends each segment's k largest residual-momentum\n\
         components as sparse index/value pairs).\n\n"
    );

    // Run each algorithm ONCE on the neutral (free) network to get the
    // loss trajectory + measured compute; then re-cost communication
    // under each interconnect preset analytically (same trajectory —
    // the algorithms' updates don't depend on link speed). The q8/q8pt
    // rows are genuinely different trajectories (the exchange
    // quantizes), so each is its own run, not a re-costing.
    let mut runs = Vec::new();
    for (name, algo, tau, wire) in [
        ("AdamW (per-step)", Algo::StandaloneAdamW, 1usize, None),
        ("Algorithm 1, tau=12", Algo::Alg1 { eta: 12.0 }, 12, None),
        ("Algorithm 1, tau=24", Algo::Alg1 { eta: 12.0 }, 24, None),
        ("Algorithm 1, tau=36", Algo::Alg1 { eta: 12.0 }, 36, None),
        ("Algorithm 1, tau=12, q8", Algo::Alg1 { eta: 12.0 }, 12, Some(WireFormat::QuantizedI8)),
        (
            "Algorithm 1, tau=12, q8pt",
            Algo::Alg1 { eta: 12.0 },
            12,
            Some(WireFormat::QuantizedI8PerTensor),
        ),
        ("Algorithm 1, tau=12, topk", Algo::Alg1 { eta: 12.0 }, 12, Some(WireFormat::TOPK_DEFAULT)),
    ] {
        let mut cfg = cell(h, preset, algo, tau, budget, 4, BaseOptConfig::adamw_paper());
        cfg.wire = wire;
        if let Some(w) = wire {
            cfg.tag.push('-');
            cfg.tag.push_str(w.name());
        }
        let resolved = cfg.resolved_wire();
        let summary = h.run(cfg)?;
        runs.push((name, resolved, summary));
    }

    let info = h.arts.preset(preset)?;
    let p = info.param_count;
    let segments = info.layout.len();
    for net in ["nvlink", "infiniband", "ethernet", "wan"] {
        let Some(model) = CommModel::preset(net) else {
            unreachable!("`{net}` is a built-in comm preset")
        };
        let mut t = Table::new(&[
            "Alg.",
            "wire",
            "comm rounds",
            "compute s",
            "comm s (model)",
            "total s",
            "final val",
        ]);
        for (name, wire, s) in &runs {
            let Some(last) = s.log.rows.last() else {
                anyhow::bail!("run `{name}` logged no eval rows")
            };
            let comm_rounds = last.comm_rounds;
            // compute seconds: measured; comm: re-costed under this net
            let compute_s = last.sim_time_s; // free-net run: time == compute
            // re-cost through WireFormat::exchange_time — the one place
            // the byte × topology rule lives (same choice the clock made)
            let comm_s = comm_rounds as f64 * wire.exchange_time(&model, 4, p, segments);
            t.row(vec![
                name.to_string(),
                wire.name().to_string(),
                format!("{comm_rounds}"),
                format!("{compute_s:.1}"),
                format!("{comm_s:.2}"),
                format!("{:.1}", compute_s + comm_s),
                format!("{:.4}", s.final_val),
            ]);
        }
        text.push_str(&format!("interconnect = {net}\n{}\n", t.render()));
    }

    // Where the bits go: per-segment payload share of one q8pt message
    // (numel + 4 scale bytes each), next to the last-round update norms
    // of the q8pt run — hetero per-segment magnitudes are exactly why
    // per-tensor scales beat the single per-message scale.
    let q8pt_summary =
        runs.iter().find(|(_, w, _)| *w == WireFormat::QuantizedI8PerTensor).map(|(_, _, s)| s);
    let total_bytes = WireFormat::QuantizedI8PerTensor.wire_bytes(p, segments) as f64;
    let mut seg = Table::new(&["segment", "numel", "q8pt bytes", "share %"]);
    for e in info.layout.iter() {
        let bytes = e.numel() as u64 + 4;
        seg.row(vec![
            e.name.clone(),
            format!("{}", e.numel()),
            format!("{bytes}"),
            format!("{:.2}", bytes as f64 / total_bytes * 100.0),
        ]);
    }
    text.push_str(&format!(
        "per-segment payload breakdown ({segments} segments, one q8pt message = {} bytes):\n{}\n",
        total_bytes as u64,
        seg.render()
    ));
    match q8pt_summary {
        Some(s) if !s.segment_norms.is_empty() => {
            text.push_str(&format!(
                "last-round global update, per segment (q8pt run):\n{}\n",
                render_segment_norms(&s.segment_norms)
            ));
        }
        _ => text.push_str(
            "last-round per-segment update norms: (cached run — re-run with\n\
             --no-cache to recompute them)\n\n",
        ),
    }

    text.push_str(
        "Reading: on fast links (nvlink) per-step AdamW is fine; on slow links\n\
         the tau-fold reduction in comm rounds dominates total time — the\n\
         regime the paper targets. The q8 rows additionally shrink each\n\
         round's payload 4x (at n = 4 their gather+broadcast undercuts the\n\
         dense ring on both latency and bandwidth terms) at the cost of a\n\
         bounded quantization error in the exchanged differences; q8pt\n\
         spends 4 bytes per segment to give every parameter block its own\n\
         scale, cutting that error exactly where the per-segment norms\n\
         above are smallest relative to the largest block. The topk row\n\
         drops the payload further still — 8 bytes per kept component at\n\
         the default 1/16 keep fraction — and banks everything it does\n\
         not send in a decaying per-rank residual, so withheld mass\n\
         re-competes on later rounds instead of being lost.\n",
    );
    println!("{text}");
    save_summary(h, "comm", &text)
}
