//! Communication-savings experiment — the paper's motivating claim (§1):
//! with τ local steps, simulated wall-clock time to a target validation
//! loss collapses on slow interconnects, because per-step all-reduce
//! dominates.  Reports, per interconnect preset, the modeled time
//! breakdown and time-to-target for per-step AdamW vs Algorithm 1 at
//! τ ∈ {12, 24, 36} (the paper's 12×/24×/36× communication reductions).

use anyhow::Result;

use super::gpt::{cell, Algo};
use super::runner::{save_summary, Harness, Table};
use crate::comm::CommModel;
use crate::optim::BaseOptConfig;

pub fn run(h: &Harness) -> Result<()> {
    let budget = h.step_budget(120);
    let (label, preset) = h.sizes()[0];
    let mut text = format!(
        "Communication savings (GPT-2 {label} repro scale, n = 4 workers)\n\
         compute time measured on this host; comm time from the alpha-beta\n\
         ring-all-reduce model (comm/mod.rs presets).\n\n"
    );

    // Run each algorithm ONCE on the neutral (free) network to get the
    // loss trajectory + measured compute; then re-cost communication
    // under each interconnect preset analytically (same trajectory —
    // the algorithms' updates don't depend on link speed).
    let mut runs = Vec::new();
    for (name, algo, tau) in [
        ("AdamW (per-step)", Algo::StandaloneAdamW, 1usize),
        ("Algorithm 1, tau=12", Algo::Alg1 { eta: 12.0 }, 12),
        ("Algorithm 1, tau=24", Algo::Alg1 { eta: 12.0 }, 24),
        ("Algorithm 1, tau=36", Algo::Alg1 { eta: 12.0 }, 36),
    ] {
        let cfg = cell(h, preset, algo, tau, budget, 4, BaseOptConfig::adamw_paper());
        let summary = h.run(cfg)?;
        runs.push((name, tau, summary));
    }

    let info = h.arts.preset(preset)?;
    let bytes = info.param_count as u64 * 4;
    for net in ["nvlink", "infiniband", "ethernet", "wan"] {
        let model = CommModel::preset(net).unwrap();
        let mut t = Table::new(&[
            "Alg.",
            "comm rounds",
            "compute s",
            "comm s (model)",
            "total s",
            "final val",
        ]);
        for (name, _tau, s) in &runs {
            let last = s.log.rows.last().unwrap();
            let comm_rounds = last.comm_rounds;
            // compute seconds: measured; comm: re-costed under this net
            let compute_s = last.sim_time_s; // free-net run: time == compute
            let comm_s = comm_rounds as f64 * model.allreduce_time(4, bytes);
            t.row(vec![
                name.to_string(),
                format!("{comm_rounds}"),
                format!("{compute_s:.1}"),
                format!("{comm_s:.2}"),
                format!("{:.1}", compute_s + comm_s),
                format!("{:.4}", s.final_val),
            ]);
        }
        text.push_str(&format!("interconnect = {net}\n{}\n", t.render()));
    }
    text.push_str(
        "Reading: on fast links (nvlink) per-step AdamW is fine; on slow links\n\
         the tau-fold reduction in comm rounds dominates total time — the\n\
         regime the paper targets.\n",
    );
    println!("{text}");
    save_summary(h, "comm", &text)
}
