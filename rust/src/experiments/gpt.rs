//! GPT-2 pre-training experiments: Figures 1-5 and Tables 2-6 of §4.
//!
//! Every sweep fixes the same local-step budget across algorithms (the
//! paper fixes 100k steps / identical token counts), uses the paper's
//! hyper-parameters where it states them (AdamW β=(0.9,0.95) λ=0.1;
//! Lion-style global step β=(0.95,0.98) λ=0.1; cosine LR, 2% warmup,
//! final = 5% peak), and evaluates all methods on identical validation
//! batches.

use anyhow::Result;

use super::runner::{ppl_improvement, save_summary, Harness, RunSummary, Table};
use crate::config::{default_peak_lr, RunConfig, TrainMode};
use crate::optim::BaseOptConfig;
use crate::outer::OuterConfig;
use crate::train::metrics::{ascii_chart, Axis};
use crate::train::schedule::ScheduleConfig;

/// Main-sweep local-step budget before `--scale` (the 100k analogue).
const BUDGET_MAIN: usize = 120;
/// n=1 ablation budget (Tables 4-5 use longer τ, so more steps).
const BUDGET_N1: usize = 240;
const WORKERS: usize = 4;
const SEED: u64 = 42;
/// Tuned global LRs at repro scale (the paper tunes these per setup, §4
/// "Parameter tuning").  Sign-style outer steps move a FIXED magnitude
/// per round (eta*gamma for Alg.1, ~eta for signed SlowMo / MV-style
/// votes, eta for global AdamW), so their LR must scale with the round
/// budget: at T ~ 10-20 rounds the tuned values are much larger than the
/// paper's 100k-step values. Swept in runs/cache (eta in {1,3,6,12,24}).
const ETA_ALG1: f32 = 12.0;
const ETA_SIGNED_SLOWMO: f32 = 0.01;
const ETA_GLOBAL_ADAMW: f32 = 0.01;

#[derive(Clone, Copy, PartialEq)]
pub enum Algo {
    StandaloneAdamW,
    StandaloneSophia,
    SlowMo { alpha: f32, beta: f32 },
    Alg1 { eta: f32 },
    SignedSlowMo { eta: f32, beta: f32 },
    Lookahead { eta: f32, beta: f32, signed: bool },
    GlobalAdamW { eta: f32 },
    LocalAvg,
}

impl Algo {
    pub fn label(&self) -> String {
        match self {
            Algo::StandaloneAdamW => "AdamW".into(),
            Algo::StandaloneSophia => "Sophia".into(),
            Algo::SlowMo { .. } => "SlowMo".into(),
            Algo::Alg1 { .. } => "Algorithm 1".into(),
            Algo::SignedSlowMo { beta, .. } => format!("Signed SlowMo b={beta}"),
            Algo::Lookahead { beta, signed: false, .. } => format!("Lookahead b={beta}"),
            Algo::Lookahead { beta, signed: true, .. } => format!("Signed Lookahead b={beta}"),
            Algo::GlobalAdamW { .. } => "Global AdamW".into(),
            Algo::LocalAvg => "Local AdamW".into(),
        }
    }
}

/// Build the run config for one cell of a sweep.
pub fn cell(
    _h: &Harness,
    preset: &str,
    algo: Algo,
    tau: usize,
    budget: usize,
    n_workers: usize,
    base: BaseOptConfig,
) -> RunConfig {
    let (mode, tau, outer) = match algo {
        Algo::StandaloneAdamW | Algo::StandaloneSophia => {
            (TrainMode::Standalone, 1, OuterConfig::LocalAvg)
        }
        Algo::SlowMo { alpha, beta } => {
            (TrainMode::LocalSteps, tau, OuterConfig::SlowMo { alpha, beta })
        }
        Algo::Alg1 { eta } => {
            (TrainMode::LocalSteps, tau, OuterConfig::sign_momentum_paper(eta))
        }
        Algo::SignedSlowMo { eta, beta } => {
            (TrainMode::LocalSteps, tau, OuterConfig::SignedSlowMo { eta, beta })
        }
        Algo::Lookahead { eta, beta, signed } => {
            (TrainMode::LocalSteps, tau, OuterConfig::Lookahead { eta, beta, signed })
        }
        Algo::GlobalAdamW { eta } => (
            TrainMode::LocalSteps,
            tau,
            OuterConfig::GlobalAdamW { eta, beta1: 0.9, beta2: 0.95, eps: 1e-8, weight_decay: 0.1 },
        ),
        Algo::LocalAvg => (TrainMode::LocalSteps, tau, OuterConfig::LocalAvg),
    };
    let rounds = (budget / tau).max(1);
    let total = (rounds * tau) as u64;
    let mut cfg = RunConfig::paper_default(preset);
    cfg.mode = mode;
    cfg.tau = tau;
    cfg.rounds = rounds;
    cfg.n_workers = n_workers;
    cfg.base = base;
    cfg.outer = outer;
    cfg.schedule = ScheduleConfig::cosine_paper(default_peak_lr(preset), total);
    cfg.seed = SEED;
    // experiments run on the "free" network: trajectories are identical on
    // any link, and comm_savings re-costs communication analytically.
    let Some(free_net) = crate::comm::CommModel::preset("none") else {
        unreachable!("`none` is a built-in comm preset")
    };
    cfg.comm = free_net;
    cfg.eval_every = (rounds / 10).max(1);
    cfg.eval_batches = 4;
    cfg.corpus_bytes = 2 << 20;
    cfg.tag = format!(
        "{preset}-{}-tau{tau}-n{n_workers}-b{budget}",
        algo.label().replace(' ', "_").to_lowercase()
    );
    cfg
}

fn adamw() -> BaseOptConfig {
    BaseOptConfig::adamw_paper()
}

/// The τ=12 main sweep shared by Figures 1, 2, 4 (cache makes reuse free).
fn main_sweep(h: &Harness) -> Result<Vec<(String, Vec<(String, RunSummary)>)>> {
    let budget = h.step_budget(BUDGET_MAIN);
    let mut out = Vec::new();
    for (label, preset) in h.sizes() {
        let mut rows = Vec::new();
        for algo in [
            Algo::StandaloneAdamW,
            Algo::SlowMo { alpha: 1.0, beta: 0.5 },
            Algo::Alg1 { eta: ETA_ALG1 },
        ] {
            let cfg = cell(h, preset, algo, 12, budget, WORKERS, adamw());
            rows.push((algo.label(), h.run(cfg)?));
        }
        out.push((label.to_string(), rows));
    }
    Ok(out)
}

pub fn fig1(h: &Harness) -> Result<()> {
    let sweep = main_sweep(h)?;
    let mut text = String::from(
        "Figure 1: validation loss vs COMMUNICATION rounds (tau = 12)\n\
         AdamW communicates every step; SlowMo / Algorithm 1 every 12 steps.\n\n",
    );
    for (size, rows) in &sweep {
        let curves: Vec<(&str, Vec<(f64, f64)>)> = rows
            .iter()
            .map(|(name, s)| (name.as_str(), s.log.val_curve(Axis::CommRounds)))
            .collect();
        text.push_str(&ascii_chart(&format!("GPT-2 {size} (repro scale)"), &curves, 64, 12));
        text.push('\n');
    }
    println!("{text}");
    save_summary(h, "fig1", &text)
}

pub fn fig2(h: &Harness) -> Result<()> {
    let sweep = main_sweep(h)?;
    let mut text = String::from(
        "Figure 2: validation loss vs COMPUTATION rounds (tau = 12)\n\
         Same runs as Figure 1, re-keyed by local steps: with multiple local\n\
         steps the gap to per-step AdamW at equal compute is the 'cost' of\n\
         communicating 12x less.\n\n",
    );
    for (size, rows) in &sweep {
        let curves: Vec<(&str, Vec<(f64, f64)>)> = rows
            .iter()
            .map(|(name, s)| (name.as_str(), s.log.val_curve(Axis::LocalSteps)))
            .collect();
        text.push_str(&ascii_chart(&format!("GPT-2 {size} (repro scale)"), &curves, 64, 12));
        text.push('\n');
    }
    println!("{text}");
    save_summary(h, "fig2", &text)
}

pub fn fig4(h: &Harness) -> Result<()> {
    let sweep = main_sweep(h)?;
    let mut text = String::from(
        "Figure 4: TRAINING loss curves (tau = 12) — optimization error view.\n\n",
    );
    for (size, rows) in &sweep {
        let curves: Vec<(&str, Vec<(f64, f64)>)> = rows
            .iter()
            .map(|(name, s)| (name.as_str(), s.log.train_curve(Axis::LocalSteps)))
            .collect();
        text.push_str(&ascii_chart(&format!("GPT-2 {size} (repro scale)"), &curves, 64, 12));
        text.push('\n');
    }
    println!("{text}");
    save_summary(h, "fig4", &text)
}

pub fn table2(h: &Harness) -> Result<()> {
    let budget = h.step_budget(BUDGET_MAIN);
    let mut table = Table::new(&["Alg.", "Com. red.", "Size", "Val.", "Improv. vs SlowMo"]);
    let mut text = String::from("Table 2: final validation loss under tau = 12, 24, 36\n\n");
    for (label, preset) in h.sizes() {
        let adamw_run =
            h.run(cell(h, preset, Algo::StandaloneAdamW, 1, budget, WORKERS, adamw()))?;
        table.row(vec![
            "AdamW".into(),
            "N.A.".into(),
            label.to_string(),
            format!("{:.4}", adamw_run.final_val),
            String::new(),
        ]);
        for tau in [12usize, 24, 36] {
            let slowmo = h.run(cell(
                h,
                preset,
                Algo::SlowMo { alpha: 1.0, beta: 0.5 },
                tau,
                budget,
                WORKERS,
                adamw(),
            ))?;
            let alg1 = h.run(cell(
                h,
                preset,
                Algo::Alg1 { eta: ETA_ALG1 },
                tau,
                budget,
                WORKERS,
                adamw(),
            ))?;
            table.row(vec![
                "SlowMo".into(),
                format!("{tau}x"),
                label.to_string(),
                format!("{:.4}", slowmo.final_val),
                String::new(),
            ]);
            table.row(vec![
                "Algorithm 1".into(),
                format!("{tau}x"),
                label.to_string(),
                format!("{:.4}", alg1.final_val),
                format!("{:+.2}%", ppl_improvement(slowmo.final_val, alg1.final_val)),
            ]);
        }
    }
    text.push_str(&table.render());
    println!("{text}");
    save_summary(h, "tab2", &text)
}

pub fn fig5(h: &Harness) -> Result<()> {
    // τ=24 runs are a subset of Table 2's grid (cache shared).
    let budget = h.step_budget(BUDGET_MAIN);
    let mut text = String::from("Figure 5: validation loss curves, tau = 24\n\n");
    for (label, preset) in h.sizes() {
        let adamw_run =
            h.run(cell(h, preset, Algo::StandaloneAdamW, 1, budget, WORKERS, adamw()))?;
        let slowmo = h.run(cell(
            h,
            preset,
            Algo::SlowMo { alpha: 1.0, beta: 0.5 },
            24,
            budget,
            WORKERS,
            adamw(),
        ))?;
        let alg1 =
            h.run(cell(h, preset, Algo::Alg1 { eta: ETA_ALG1 }, 24, budget, WORKERS, adamw()))?;
        let curves = vec![
            ("AdamW", adamw_run.log.val_curve(Axis::LocalSteps)),
            ("SlowMo", slowmo.log.val_curve(Axis::LocalSteps)),
            ("Algorithm 1", alg1.log.val_curve(Axis::LocalSteps)),
        ];
        text.push_str(&ascii_chart(&format!("GPT-2 {label} (repro scale)"), &curves, 64, 12));
        text.push('\n');
    }
    println!("{text}");
    save_summary(h, "fig5", &text)
}

pub fn fig3(h: &Harness) -> Result<()> {
    let budget = h.step_budget(BUDGET_MAIN);
    let (label, preset) = h.sizes()[0];
    let mut text = String::from(
        "Figure 3: Local AdamW (periodic parameter averaging) vs SlowMo vs\n\
         Algorithm 1 — Local AdamW is significantly slower (paper App. C.2).\n\n",
    );
    for tau in [12usize, 24] {
        let local = h.run(cell(h, preset, Algo::LocalAvg, tau, budget, WORKERS, adamw()))?;
        let slowmo = h.run(cell(
            h,
            preset,
            Algo::SlowMo { alpha: 1.0, beta: 0.5 },
            tau,
            budget,
            WORKERS,
            adamw(),
        ))?;
        let alg1 =
            h.run(cell(h, preset, Algo::Alg1 { eta: ETA_ALG1 }, tau, budget, WORKERS, adamw()))?;
        let curves = vec![
            ("Local AdamW", local.log.val_curve(Axis::LocalSteps)),
            ("SlowMo", slowmo.log.val_curve(Axis::LocalSteps)),
            ("Algorithm 1", alg1.log.val_curve(Axis::LocalSteps)),
        ];
        text.push_str(&ascii_chart(
            &format!("GPT-2 {label} (repro scale), tau = {tau}"),
            &curves,
            64,
            12,
        ));
        text.push_str(&format!(
            "final: Local AdamW {:.4} | SlowMo {:.4} | Algorithm 1 {:.4}\n\n",
            local.final_val, slowmo.final_val, alg1.final_val
        ));
    }
    println!("{text}");
    save_summary(h, "fig3", &text)
}

pub fn table3(h: &Harness) -> Result<()> {
    // Paper: GPT-2 small over 4 workers, Sophia base, τ = 12.
    let budget = h.step_budget(BUDGET_MAIN);
    let (_, preset) = h.sizes()[0];
    let sophia = BaseOptConfig::sophia_paper();
    let standalone =
        h.run(cell(h, preset, Algo::StandaloneSophia, 1, budget, WORKERS, sophia.clone()))?;
    let slowmo = h.run(cell(
        h,
        preset,
        Algo::SlowMo { alpha: 1.0, beta: 0.5 },
        12,
        budget,
        WORKERS,
        sophia.clone(),
    ))?;
    let alg1 = h.run(cell(h, preset, Algo::Alg1 { eta: ETA_ALG1 }, 12, budget, WORKERS, sophia))?;

    let mut t = Table::new(&["Alg.", "Com. red.", "Val.", "Improv."]);
    t.row(vec!["Sophia".into(), "N.A.".into(), format!("{:.4}", standalone.final_val), "".into()]);
    t.row(vec!["SlowMo".into(), "12x".into(), format!("{:.4}", slowmo.final_val), "".into()]);
    t.row(vec![
        "Algorithm 1".into(),
        "12x".into(),
        format!("{:.4}", alg1.final_val),
        format!("{:+.2}%", ppl_improvement(slowmo.final_val, alg1.final_val)),
    ]);
    let text = format!(
        "Table 3: Sophia(-lite) as base optimizer (tau = 12)\n\n{}",
        t.render()
    );
    println!("{text}");
    save_summary(h, "tab3", &text)
}

pub fn table4(h: &Harness) -> Result<()> {
    // Paper: Lookahead on GPT-2 medium, n = 1, τ = 48, global LR = 1.
    let budget = h.step_budget(BUDGET_N1);
    let (label, preset) = h.sizes()[1];
    let baseline = h.run(cell(h, preset, Algo::StandaloneAdamW, 1, budget, 1, adamw()))?;
    let mut t = Table::new(&["Alg.", "beta", "Val.", "Improv."]);
    t.row(vec!["AdamW".into(), "N.A.".into(), format!("{:.4}", baseline.final_val), "".into()]);
    let mut text = format!("Table 4: Lookahead with AdamW base, n = 1, tau = 48 ({label})\n\n");
    for beta in [0.1f32, 0.2] {
        let la = h.run(cell(
            h,
            preset,
            Algo::Lookahead { eta: 1.0, beta, signed: false },
            48,
            budget,
            1,
            adamw(),
        ))?;
        t.row(vec![
            "Lookahead".into(),
            format!("{beta}"),
            format!("{:.4}", la.final_val),
            format!("{:+.2}%", ppl_improvement(baseline.final_val, la.final_val)),
        ]);
    }
    text.push_str(&t.render());
    println!("{text}");
    save_summary(h, "tab4", &text)
}

pub fn table5(h: &Harness) -> Result<()> {
    // Paper: signed Lookahead on GPT-2 small, n = 1, τ = 24, global LR = 6.
    let budget = h.step_budget(BUDGET_N1);
    let (label, preset) = h.sizes()[0];
    let baseline = h.run(cell(h, preset, Algo::StandaloneAdamW, 1, budget, 1, adamw()))?;
    let mut t = Table::new(&["Alg.", "beta", "Val.", "Improv."]);
    t.row(vec!["AdamW".into(), "N.A.".into(), format!("{:.4}", baseline.final_val), "".into()]);
    let mut text =
        format!("Table 5: signed Lookahead with AdamW base, n = 1, tau = 24 ({label})\n\n");
    for beta in [0.6f32, 0.8] {
        let la = h.run(cell(
            h,
            preset,
            Algo::Lookahead { eta: 6.0, beta, signed: true },
            24,
            budget,
            1,
            adamw(),
        ))?;
        t.row(vec![
            "Signed Lookahead".into(),
            format!("{beta}"),
            format!("{:.4}", la.final_val),
            format!("{:+.2}%", ppl_improvement(baseline.final_val, la.final_val)),
        ]);
    }
    text.push_str(&t.render());
    println!("{text}");
    save_summary(h, "tab5", &text)
}

pub fn table6(h: &Harness) -> Result<()> {
    // Paper: GPT-2 small, n > 1, τ = 12: signed SlowMo and Global AdamW.
    let budget = h.step_budget(BUDGET_MAIN);
    let (label, preset) = h.sizes()[0];
    let adamw_run = h.run(cell(h, preset, Algo::StandaloneAdamW, 1, budget, WORKERS, adamw()))?;
    let slowmo = h.run(cell(
        h,
        preset,
        Algo::SlowMo { alpha: 1.0, beta: 0.5 },
        12,
        budget,
        WORKERS,
        adamw(),
    ))?;
    let mut t = Table::new(&["Alg.", "beta", "Val.", "Improv. vs SlowMo"]);
    t.row(vec!["AdamW".into(), "N.A.".into(), format!("{:.4}", adamw_run.final_val), "".into()]);
    t.row(vec!["SlowMo".into(), "0.5".into(), format!("{:.4}", slowmo.final_val), "".into()]);
    let mut text =
        format!("Table 6: signed SlowMo and Global AdamW ablations ({label}, tau=12)\n\n");
    for beta in [0.5f32, 0.8] {
        let ss = h.run(cell(
            h,
            preset,
            Algo::SignedSlowMo { eta: ETA_SIGNED_SLOWMO, beta },
            12,
            budget,
            WORKERS,
            adamw(),
        ))?;
        t.row(vec![
            "Signed SlowMo".into(),
            format!("{beta}"),
            format!("{:.4}", ss.final_val),
            format!("{:+.2}%", ppl_improvement(slowmo.final_val, ss.final_val)),
        ]);
    }
    let ga = h.run(cell(
        h,
        preset,
        Algo::GlobalAdamW { eta: ETA_GLOBAL_ADAMW },
        12,
        budget,
        WORKERS,
        adamw(),
    ))?;
    t.row(vec![
        "Global AdamW".into(),
        "N.A.".into(),
        format!("{:.4}", ga.final_val),
        format!("{:+.2}%", ppl_improvement(slowmo.final_val, ga.final_val)),
    ]);
    // reference: Algorithm 1's number on the same cell (paper quotes 2.942)
    let alg1 = h.run(cell(h, preset, Algo::Alg1 { eta: ETA_ALG1 }, 12, budget, WORKERS, adamw()))?;
    t.row(vec![
        "Algorithm 1 (ref)".into(),
        "0.95/0.98".into(),
        format!("{:.4}", alg1.final_val),
        format!("{:+.2}%", ppl_improvement(slowmo.final_val, alg1.final_val)),
    ]);
    text.push_str(&t.render());
    println!("{text}");
    save_summary(h, "tab6", &text)
}
