//! Supplementary experiments beyond the paper's figures:
//!
//! * `hetero` — the Theorem-2(b) non-IID regime: every worker's shard is
//!   dominated by a different corpus source (data/corpus.rs
//!   `generate_heterogeneous`).  Compares Algorithm 1 / SlowMo / local
//!   averaging under IID vs non-IID sharding: heterogeneity is the
//!   δ²-term of the theory and the regime where naive local averaging
//!   degrades hardest.
//! * `remark1` — the Remark 1/2 comparison: Algorithm 1 (full-precision
//!   aggregation, sign AFTER averaging) vs Federated MV-sto-signSGD-SIM
//!   (randomized 1-bit signs + majority vote), which the paper proves
//!   only converges to an O(dR/√n) neighborhood.

use anyhow::Result;

use super::gpt::{cell, Algo};
use super::runner::{save_summary, Harness, Table};
use crate::optim::BaseOptConfig;
use crate::outer::OuterConfig;

pub fn hetero(h: &Harness) -> Result<()> {
    let budget = h.step_budget(120);
    let (label, preset) = h.sizes()[0];
    let mut t = Table::new(&["Alg.", "IID val", "non-IID val", "degradation"]);
    let mut text = format!(
        "Heterogeneous-data supplement ({label}, tau=12, n=4): Theorem 2(b)'s\n\
         delta^2 regime — each worker's shard is dominated by one corpus source.\n\n"
    );
    for algo in [
        Algo::Alg1 { eta: 12.0 },
        Algo::SlowMo { alpha: 1.0, beta: 0.5 },
        Algo::LocalAvg,
    ] {
        let iid = h.run(cell(h, preset, algo, 12, budget, 4, BaseOptConfig::adamw_paper()))?;
        let mut cfg = cell(h, preset, algo, 12, budget, 4, BaseOptConfig::adamw_paper());
        cfg.heterogeneous = true;
        cfg.tag = format!("{}-hetero", cfg.tag);
        let noniid = h.run(cfg)?;
        t.row(vec![
            algo.label(),
            format!("{:.4}", iid.final_val),
            format!("{:.4}", noniid.final_val),
            format!("{:+.4}", noniid.final_val - iid.final_val),
        ]);
    }
    text.push_str(&t.render());
    println!("{text}");
    save_summary(h, "hetero", &text)
}

pub fn remark1(h: &Harness) -> Result<()> {
    let budget = h.step_budget(120);
    let (label, preset) = h.sizes()[0];
    let mut t = Table::new(&["Alg.", "communication", "Val."]);
    let mut text = format!(
        "Remark 1/2 supplement ({label}, tau=12, n=4): Algorithm 1's\n\
         full-precision aggregation vs MV-sto-signSGD's 1-bit majority vote\n\
         (converges only to an O(dR/sqrt(n)) neighborhood).\n\n"
    );
    let alg1 = h.run(cell(h, preset, Algo::Alg1 { eta: 12.0 }, 12, budget, 4,
        BaseOptConfig::adamw_paper()))?;
    t.row(vec!["Algorithm 1".into(), "full-precision".into(), format!("{:.4}", alg1.final_val)]);
    // MV-signSGD per Alg. 6: SGD local steps, per-round movement = eta.
    let mut cfg = cell(h, preset, Algo::Alg1 { eta: 1.0 }, 12, budget, 4,
        BaseOptConfig::sgd_plain());
    cfg.outer = OuterConfig::MvSignSgd { eta: 12e-3, beta: 0.9, alpha: 0.1, bound: 5.0 };
    cfg.tag = format!("{preset}-mv_signsgd-tau12-n4-b{budget}");
    let mv = h.run(cfg)?;
    t.row(vec!["MV-sto-signSGD-SIM".into(), "1-bit majority vote".into(),
        format!("{:.4}", mv.final_val)]);
    text.push_str(&t.render());
    text.push_str(
        "\nExpected shape: MV's randomized-sign votes decorrelate when |m| << B,\n\
         stalling in a neighborhood — Algorithm 1 reaches lower loss on the\n\
         same budget (Remark 2).\n",
    );
    println!("{text}");
    save_summary(h, "remark1", &text)
}
