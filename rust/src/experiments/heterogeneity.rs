//! Supplementary experiments beyond the paper's figures:
//!
//! * `hetero` — the Theorem-2(b) non-IID regime: every worker's shard is
//!   dominated by a different corpus source (data/corpus.rs
//!   `generate_heterogeneous`).  Compares Algorithm 1 / SlowMo / local
//!   averaging under IID vs non-IID sharding: heterogeneity is the
//!   δ²-term of the theory and the regime where naive local averaging
//!   degrades hardest.
//! * `remark1` — the Remark 1/2 comparison: Algorithm 1 (full-precision
//!   aggregation, sign AFTER averaging) vs Federated MV-sto-signSGD-SIM
//!   (randomized 1-bit signs + majority vote), which the paper proves
//!   only converges to an O(dR/√n) neighborhood.
//! * `fleet` — fault tolerance: the same two methods trained through
//!   the fault plan (payload drops, membership churn, heavy-tailed
//!   stragglers, corruption). The majority vote thresholds at half of
//!   whatever arrived and Algorithm 1 averages the finite survivors, so
//!   both should hold their loss near the clean run — the table makes
//!   the degradation a number.

use anyhow::Result;

use super::gpt::{cell, Algo};
use super::runner::{save_summary, Harness, Table};
use crate::optim::BaseOptConfig;
use crate::outer::OuterConfig;

pub fn hetero(h: &Harness) -> Result<()> {
    let budget = h.step_budget(120);
    let (label, preset) = h.sizes()[0];
    let mut t = Table::new(&["Alg.", "IID val", "non-IID val", "degradation"]);
    let mut text = format!(
        "Heterogeneous-data supplement ({label}, tau=12, n=4): Theorem 2(b)'s\n\
         delta^2 regime — each worker's shard is dominated by one corpus source.\n\n"
    );
    for algo in [
        Algo::Alg1 { eta: 12.0 },
        Algo::SlowMo { alpha: 1.0, beta: 0.5 },
        Algo::LocalAvg,
    ] {
        let iid = h.run(cell(h, preset, algo, 12, budget, 4, BaseOptConfig::adamw_paper()))?;
        let mut cfg = cell(h, preset, algo, 12, budget, 4, BaseOptConfig::adamw_paper());
        cfg.heterogeneous = true;
        cfg.tag = format!("{}-hetero", cfg.tag);
        let noniid = h.run(cfg)?;
        t.row(vec![
            algo.label(),
            format!("{:.4}", iid.final_val),
            format!("{:.4}", noniid.final_val),
            format!("{:+.4}", noniid.final_val - iid.final_val),
        ]);
    }
    text.push_str(&t.render());
    println!("{text}");
    save_summary(h, "hetero", &text)
}

pub fn remark1(h: &Harness) -> Result<()> {
    let budget = h.step_budget(120);
    let (label, preset) = h.sizes()[0];
    let mut t = Table::new(&["Alg.", "communication", "Val."]);
    let mut text = format!(
        "Remark 1/2 supplement ({label}, tau=12, n=4): Algorithm 1's\n\
         full-precision aggregation vs MV-sto-signSGD's 1-bit majority vote\n\
         (converges only to an O(dR/sqrt(n)) neighborhood).\n\n"
    );
    let alg1 = h.run(cell(h, preset, Algo::Alg1 { eta: 12.0 }, 12, budget, 4,
        BaseOptConfig::adamw_paper()))?;
    t.row(vec!["Algorithm 1".into(), "full-precision".into(), format!("{:.4}", alg1.final_val)]);
    // MV-signSGD per Alg. 6: SGD local steps, per-round movement = eta.
    let mut cfg = cell(h, preset, Algo::Alg1 { eta: 1.0 }, 12, budget, 4,
        BaseOptConfig::sgd_plain());
    cfg.outer = OuterConfig::MvSignSgd { eta: 12e-3, beta: 0.9, alpha: 0.1, bound: 5.0 };
    cfg.tag = format!("{preset}-mv_signsgd-tau12-n4-b{budget}");
    let mv = h.run(cfg)?;
    t.row(vec!["MV-sto-signSGD-SIM".into(), "1-bit majority vote".into(),
        format!("{:.4}", mv.final_val)]);
    text.push_str(&t.render());
    text.push_str(
        "\nExpected shape: MV's randomized-sign votes decorrelate when |m| << B,\n\
         stalling in a neighborhood — Algorithm 1 reaches lower loss on the\n\
         same budget (Remark 2).\n",
    );
    println!("{text}");
    save_summary(h, "remark1", &text)
}

pub fn fleet(h: &Harness) -> Result<()> {
    let budget = h.step_budget(120);
    let (label, preset) = h.sizes()[0];
    let mut t = Table::new(&["Alg.", "fault plan", "Val.", "vs clean"]);
    let mut text = format!(
        "Fleet-under-faults supplement ({label}, tau=12, n=4): each method\n\
         trained through the fault plan. Dropped payloads shrink the round to\n\
         whatever arrived, absent ranks sit the round out, corrupted dense/q8\n\
         payloads with non-finite scales are rejected before aggregation, and\n\
         heavy-tailed stragglers stretch simulated time without touching the\n\
         trajectory (their draws live on the dedicated fault stream).\n\n"
    );
    // (label, configure) pairs; `none` is the baseline row
    let plans: &[(&str, fn(&mut crate::comm::FaultPlan))] = &[
        ("none", |_| {}),
        ("drop 10%", |f| f.drop_prob = 0.10),
        ("churn 25%", |f| f.churn_prob = 0.25),
        ("storm (drop+churn+tail)", |f| {
            f.drop_prob = 0.10;
            f.churn_prob = 0.20;
            f.tail_prob = 0.3;
            f.tail_scale_s = 2.0;
        }),
    ];
    for mv in [false, true] {
        let mut clean_val = f64::NAN;
        for (plan_label, configure) in plans {
            // MV per Alg. 6 rides SGD local steps (remark1's setup);
            // Algorithm 1 keeps the paper's AdamW base
            let (eta, base_opt) = if mv {
                (1.0, BaseOptConfig::sgd_plain())
            } else {
                (12.0, BaseOptConfig::adamw_paper())
            };
            let mut cfg = cell(h, preset, Algo::Alg1 { eta }, 12, budget, 4, base_opt);
            if mv {
                cfg.outer =
                    OuterConfig::MvSignSgd { eta: 12e-3, beta: 0.9, alpha: 0.1, bound: 5.0 };
                cfg.tag = format!("{preset}-mv_signsgd-tau12-n4-b{budget}");
            }
            configure(&mut cfg.faults);
            // the fault plan rides in describe() and therefore in the
            // cache key; the tag only disambiguates the runs/ directory
            cfg.tag = format!("{}-{}", cfg.tag, plan_label.replace(' ', "_"));
            let res = h.run(cfg)?;
            if *plan_label == "none" {
                clean_val = res.final_val;
            }
            t.row(vec![
                if mv { "MV-sto-signSGD-SIM" } else { "Algorithm 1" }.into(),
                (*plan_label).into(),
                format!("{:.4}", res.final_val),
                format!("{:+.4}", res.final_val - clean_val),
            ]);
        }
    }
    text.push_str(&t.render());
    text.push_str(
        "\nExpected shape: small positive deltas — a 10% thinner quorum is a\n\
         noisier aggregate, not a divergence. Per-fault counters (dropped /\n\
         rejected / absent / no-quorum) are surfaced by the fleet_faults\n\
         example, which CI runs as a smoke job.\n",
    );
    println!("{text}");
    save_summary(h, "fleet", &text)
}
