//! Experiment harness: one entry per table/figure of the paper's
//! evaluation (§4) plus the theory-validation experiments (§3).
//!
//! Every experiment prints the paper-shaped table or an ASCII rendition
//! of the figure, and persists raw curves as CSV under `runs/<id>/`.
//! Completed runs are content-addressed-cached (runs/cache/) so figures
//! that share runs (Fig. 1/2/4 = the τ=12 sweep; Table 2 ⊃ Fig. 5's τ=24
//! runs) never recompute them.
//!
//! Scale note (DESIGN.md §5.3): the default "Small/Medium/Large" trio
//! maps to the nano/small/medium presets with a 120-local-step budget so
//! the full suite fits a single CPU core; `--scale` multiplies the step
//! budget and `--big` shifts the trio to small/medium/large.  The paper's
//! qualitative claims (method ranking, τ sensitivity, gap sizes) are what
//! the harness reproduces — not absolute GPT-2/OpenWebText losses.

pub mod comm_savings;
pub mod gpt;
pub mod heterogeneity;
pub mod robustness;
pub mod runner;
pub mod theory;

use anyhow::{bail, Result};
use runner::Harness;

pub const ALL: &[(&str, &str)] = &[
    ("fig1", "validation loss vs COMMUNICATION rounds, τ=12, 3 sizes (AdamW/SlowMo/Alg.1)"),
    ("fig2", "validation loss vs COMPUTATION rounds (same runs as fig1)"),
    ("tab2", "final val loss @ τ∈{12,24,36} × 3 sizes, SlowMo vs Algorithm 1 (+AdamW)"),
    ("tab3", "Sophia as base optimizer, τ=12 (standalone/SlowMo/Alg.1)"),
    ("tab4", "Lookahead ablation, n=1 (β∈{0.1,0.2}) vs AdamW"),
    ("tab5", "signed Lookahead ablation, n=1 (β∈{0.6,0.8}) vs AdamW"),
    ("tab6", "signed SlowMo (β∈{0.5,0.8}) + Global AdamW vs SlowMo"),
    ("fig3", "Local AdamW (periodic averaging) vs SlowMo vs Alg.1, τ∈{12,24}"),
    ("fig4", "TRAINING loss curves, τ=12 (same runs as fig1)"),
    ("fig5", "validation loss curves, τ=24 (subset of tab2 runs)"),
    ("theory", "Theorems 1-3: empirical rate exponents + linear speedup (pure-Rust sim)"),
    ("comm", "communication-savings: simulated time-to-loss across interconnects"),
    ("hetero", "supplement: IID vs non-IID worker shards (Theorem 2(b) regime)"),
    ("remark1", "supplement: Algorithm 1 vs MV-sto-signSGD majority vote (Remarks 1-2)"),
    ("fleet", "supplement: fault tolerance — drops/churn/stragglers vs the clean fleet"),
    ("robust", "supplement: Byzantine ranks — attack × defense grid (agg/MV/quarantine)"),
];

pub fn run(id: &str, h: &Harness) -> Result<()> {
    match id {
        "fig1" => gpt::fig1(h),
        "fig2" => gpt::fig2(h),
        "tab2" | "table2" => gpt::table2(h),
        "tab3" | "table3" => gpt::table3(h),
        "tab4" | "table4" => gpt::table4(h),
        "tab5" | "table5" => gpt::table5(h),
        "tab6" | "table6" => gpt::table6(h),
        "fig3" => gpt::fig3(h),
        "fig4" => gpt::fig4(h),
        "fig5" => gpt::fig5(h),
        "theory" => theory::run(h),
        "comm" | "comm_savings" => comm_savings::run(h),
        "hetero" => heterogeneity::hetero(h),
        "remark1" => heterogeneity::remark1(h),
        "fleet" => heterogeneity::fleet(h),
        "robust" => robustness::robust(h),
        "all" => {
            for (id, _) in ALL {
                println!("\n================ {id} ================");
                run(id, h)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment `{other}`; available: {:?}", ALL),
    }
}
