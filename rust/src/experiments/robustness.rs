//! Byzantine-robustness supplement: attack × defense grid.
//!
//! Every cell trains the same fleet with ⌊byzantine_frac·n⌋ adversarial
//! ranks mutating their own round contributions at the source
//! ([`crate::comm::Attack`]) and one defense on the server side:
//!
//! * **mean** — the undefended baseline ([`crate::dist::AggPolicy::Mean`]);
//!   a single inflated or colluding rank poisons every coordinate.
//! * **trimmed / median** — the robust policies
//!   ([`crate::dist::WirePayload::aggregate_end_into`]), on the dense
//!   wire and on the compressed `q8pt` wire (the defense composes with
//!   quantization: decode first, trim in f64).
//! * **MV tally** — MV-sto-signSGD's majority vote on the 1-bit wire,
//!   robust by construction (breakdown point f < n/2 per coordinate).
//! * **mean + quarantine** — no robust combine at all; the reputation
//!   supervisor ([`crate::comm::FaultPlan::quarantine`]) has to find
//!   the liars and freeze them out.
//!
//! The expected shape is the table's whole point: the undefended mean
//! diverges (or degrades severely) under scale inflation and fixed-point
//! collusion at 1-in-8 adversaries, while every defended row stays near
//! its clean baseline. Minority sign-flipping barely moves the mean
//! (the flipped terms damp the average, they don't redirect it), so the
//! interesting columns there are the tally and the supervisor.

use anyhow::Result;

use super::gpt::{cell, Algo};
use super::runner::{save_summary, Harness, Table};
use crate::comm::Attack;
use crate::dist::{AggPolicy, WireFormat};
use crate::optim::BaseOptConfig;
use crate::outer::OuterConfig;

/// One server-side defense: a wire format, an aggregation policy, and
/// optionally the reputation supervisor.
struct Defense {
    label: &'static str,
    tag: &'static str,
    wire: Option<WireFormat>,
    agg: AggPolicy,
    mv: bool,
    quarantine: bool,
}

const DEFENSES: &[Defense] = &[
    Defense {
        label: "mean (undefended)",
        tag: "mean",
        wire: None,
        agg: AggPolicy::Mean,
        mv: false,
        quarantine: false,
    },
    Defense {
        label: "trimmed mean",
        tag: "trimmed",
        wire: None,
        agg: AggPolicy::Trimmed,
        mv: false,
        quarantine: false,
    },
    Defense {
        label: "median",
        tag: "median",
        wire: None,
        agg: AggPolicy::Median,
        mv: false,
        quarantine: false,
    },
    Defense {
        label: "q8pt + trimmed",
        tag: "q8pt-trimmed",
        wire: Some(WireFormat::QuantizedI8PerTensor),
        agg: AggPolicy::Trimmed,
        mv: false,
        quarantine: false,
    },
    Defense {
        label: "MV majority tally",
        tag: "mv",
        wire: None,
        agg: AggPolicy::Mean,
        mv: true,
        quarantine: false,
    },
    Defense {
        label: "mean + quarantine",
        tag: "quarantine",
        wire: None,
        agg: AggPolicy::Mean,
        mv: false,
        quarantine: true,
    },
];

const ATTACKS: &[Attack] =
    &[Attack::SignFlip, Attack::ScaleInflate, Attack::ColludeFixed, Attack::Flaky];

pub fn robust(h: &Harness) -> Result<()> {
    let budget = h.step_budget(120);
    let (label, preset) = h.sizes()[0];
    let n = 8;
    let frac = 0.125; // one adversary in the fleet of 8
    let mut t = Table::new(&["defense", "attack", "Val.", "vs clean", "note"]);
    let mut text = format!(
        "Byzantine-robustness supplement ({label}, tau=12, n={n}, one\n\
         adversarial rank): each row trains through an attack with one\n\
         server-side defense. `diverged` rows hit the finiteness guard\n\
         mid-run; everything else reports final validation loss.\n\n"
    );
    for d in DEFENSES {
        let mut clean_val = f64::NAN;
        for byz in std::iter::once(None).chain(ATTACKS.iter().map(Some)) {
            // MV per Alg. 6 rides SGD local steps (remark1's setup);
            // the dense-exchange defenses average local AdamW fleets
            let (algo, base_opt) = if d.mv {
                (Algo::Alg1 { eta: 1.0 }, BaseOptConfig::sgd_plain())
            } else {
                (Algo::LocalAvg, BaseOptConfig::adamw_paper())
            };
            let mut cfg = cell(h, preset, algo, 12, budget, n, base_opt);
            if d.mv {
                cfg.outer =
                    OuterConfig::MvSignSgd { eta: 12e-3, beta: 0.9, alpha: 0.1, bound: 5.0 };
            }
            cfg.wire = d.wire;
            cfg.agg = d.agg;
            let attack_tag = match byz {
                Some(a) => {
                    cfg.faults.byzantine_frac = frac;
                    cfg.faults.attack = *a;
                    cfg.faults.quarantine = d.quarantine;
                    a.name()
                }
                None => "clean",
            };
            // the byz/agg knobs ride in describe() and therefore in the
            // cache key; the tag only disambiguates the runs/ directory
            cfg.tag = format!("robust-{}-{}-n{n}-b{budget}", d.tag, attack_tag);
            // a poisoned mean can trip the finiteness guard mid-run —
            // that IS the result, not an experiment failure
            let (val, note) = match h.run(cfg) {
                Ok(res) => (res.final_val, String::new()),
                Err(e) => {
                    let msg: String = e.to_string().chars().take(48).collect();
                    (f64::NAN, format!("diverged ({msg})"))
                }
            };
            if byz.is_none() {
                clean_val = val;
            }
            t.row(vec![
                d.label.into(),
                attack_tag.into(),
                if val.is_nan() { "-".into() } else { format!("{val:.4}") },
                if val.is_nan() || clean_val.is_nan() {
                    "-".into()
                } else {
                    format!("{:+.4}", val - clean_val)
                },
                note,
            ]);
        }
    }
    text.push_str(&t.render());
    text.push_str(
        "\nExpected shape: scale_inflate and collude_fixed wreck the undefended\n\
         mean and leave every defended row near its clean baseline; sign_flip\n\
         at 1-in-8 only damps the mean (the tally and the supervisor columns\n\
         are where it shows); flaky lands between sign_flip and clean. The\n\
         full fraction sweep (0, 1/16, 1/8, 1/4 at n=16) lives in the\n\
         robust_agg example, which CI runs as a smoke job.\n",
    );
    println!("{text}");
    save_summary(h, "robust", &text)
}
