//! Shared experiment infrastructure: harness construction, run caching,
//! table formatting.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

use crate::config::RunConfig;
use crate::runtime::{Artifacts, ModelBundle, Runtime};
use crate::train::metrics::RunLog;
use crate::train::Trainer;

pub struct Harness {
    pub rt: Runtime,
    pub arts: Artifacts,
    pub runs_dir: PathBuf,
    /// Multiplies every experiment's local-step budget.
    pub scale: f64,
    /// Shift the size trio up one preset (nano/small/medium -> small/medium/large).
    pub big: bool,
    pub use_cache: bool,
    pub quiet: bool,
    /// Compiled-executable cache: one ModelBundle per preset, shared by
    /// every run in a sweep (XLA compilation is ~15 s per preset). The
    /// `Arc` is what the trainer's parallel worker fleet clones across
    /// pool threads.
    bundles: RefCell<HashMap<String, Arc<ModelBundle>>>,
}

impl Harness {
    pub fn new(scale: f64, big: bool, use_cache: bool) -> Result<Harness> {
        Ok(Harness {
            rt: Runtime::cpu()?,
            arts: Artifacts::load(&Artifacts::default_dir())?,
            runs_dir: PathBuf::from("runs"),
            scale,
            big,
            use_cache,
            quiet: false,
            bundles: RefCell::new(HashMap::new()),
        })
    }

    pub fn bundle(&self, preset: &str) -> Result<Arc<ModelBundle>> {
        if let Some(b) = self.bundles.borrow().get(preset) {
            return Ok(b.clone());
        }
        let info = self.arts.preset(preset)?;
        let b = Arc::new(ModelBundle::load(&self.rt, info)?);
        self.bundles.borrow_mut().insert(preset.to_string(), b.clone());
        Ok(b)
    }

    /// The "Small / Medium / Large" trio at the current scale.
    pub fn sizes(&self) -> [(&'static str, &'static str); 3] {
        if self.big {
            [("Small", "small"), ("Medium", "medium"), ("Large", "large")]
        } else {
            [("Small", "nano"), ("Medium", "small"), ("Large", "medium")]
        }
    }

    /// Local-step budget shared by all algorithms in a sweep (the paper
    /// fixes 100k steps for every method; we fix `base·scale`).
    pub fn step_budget(&self, base: usize) -> usize {
        ((base as f64) * self.scale).round().max(2.0) as usize
    }

    /// Run (or load from cache) one configuration.
    pub fn run(&self, mut cfg: RunConfig) -> Result<RunSummary> {
        let key = cache_key(&cfg);
        let cache_csv = self.runs_dir.join("cache").join(format!("{key}.csv"));
        if self.use_cache && cache_csv.exists() {
            let log = RunLog::read_csv(&cache_csv)?;
            if let Some(final_val) = log.final_val_loss() {
                if !self.quiet {
                    println!("  [cached] {:<40} val {:.4}", cfg.tag, final_val);
                }
                return Ok(RunSummary {
                    tag: cfg.tag.clone(),
                    final_val,
                    best_val: log.best_val_loss().unwrap_or(final_val),
                    log,
                    // per-segment norms are not serialized into the
                    // cache CSV; cached summaries report none
                    segment_norms: Vec::new(),
                });
            }
        }

        cfg.log_dir = None;
        if !self.quiet {
            println!("  [run] {}", cfg.describe());
        }
        let t0 = std::time::Instant::now();
        let bundle = self.bundle(&cfg.preset)?;
        let mut trainer = Trainer::with_bundle(cfg.clone(), bundle, &self.rt, &self.arts)?;
        let res = trainer.run()?;
        if !self.quiet {
            println!(
                "        -> val {:.4} (best {:.4})  [{:.1}s wall, {:.1}s sim, {} comm rounds]",
                res.final_val,
                res.best_val,
                t0.elapsed().as_secs_f64(),
                res.clock.total_s(),
                res.clock.comm_rounds
            );
        }
        res.log.write_csv(&cache_csv)?;
        Ok(RunSummary {
            tag: cfg.tag,
            final_val: res.final_val,
            best_val: res.best_val,
            log: res.log,
            segment_norms: res.segment_norms,
        })
    }
}

#[derive(Clone, Debug)]
pub struct RunSummary {
    pub tag: String,
    pub final_val: f64,
    pub best_val: f64,
    pub log: RunLog,
    /// Per-segment norms of the run's last-round global update
    /// ([`crate::train::Trainer::segment_norms`]); empty when the
    /// summary came from the CSV cache.
    pub segment_norms: Vec<crate::train::metrics::SegmentNorm>,
}

/// Bump whenever the *models* behind a run change (comm topology,
/// clock accounting, data path) so stale cache CSVs computed under the
/// old formulas are not mixed into new tables. v2: sign-vote rounds
/// moved from the ring α-β formula to gather+broadcast (PR 3). v3: the
/// typed WirePayload exchange landed (wire format now in the key via
/// `describe()`) and MV-sto-signSGD's update anchors at x_t per the
/// literal Algorithm 6 recursion (ROADMAP (g)) — pre-fix MV CSVs are
/// stale. v4: the parameter layout became load-bearing (validated
/// `ParamLayout`, layout-sized payload buffers, the per-tensor `q8pt`
/// wire) — pre-layout CSVs must never be mixed into comm-savings
/// tables that now carry per-segment rows. v5: straggler/jitter draws
/// moved off the trainer RNG onto the dedicated checkpointed fault
/// stream (and large compressed fleets route the hierarchical
/// topology), so any cached clock columns computed under a jittery
/// preset are stale. v6: `corrupt()` draws a fixed per-format RNG
/// pattern and only tallies injections that landed (q8pt scale
/// poisoning was a silent no-op), shifting every faulty trajectory,
/// and the sparse `topk` wire joined the format menu. v7: Byzantine
/// ranks and robust aggregation (`byz=`/`agg=` in the key via
/// `describe()`), the no-quorum hold is pinned early — a total-drop
/// round no longer consumes trainer-RNG contribution draws — and
/// dropped payloads can retry, so faulty trajectories shift again;
/// clean-path keys and trajectories are untouched.
const CACHE_MODEL_VERSION: &str = "v7";

/// Content hash of everything that determines a run's trajectory.
/// `cfg.sequential_workers` and `cfg.pin_workers` are deliberately
/// excluded: the parallel, sequential, and core-pinned fleets produce
/// bit-identical trajectories (only measured wall-clock differs, and
/// measured time was never part of the key).
fn cache_key(cfg: &RunConfig) -> String {
    let desc = format!(
        "{CACHE_MODEL_VERSION}|{}|{:?}|{:?}|{:?}|{}|{}|{}|{}|{}|{}|{}|{}",
        cfg.describe(),
        cfg.base,
        cfg.outer,
        cfg.schedule,
        cfg.seed,
        cfg.eval_every,
        cfg.eval_batches,
        cfg.corpus_bytes,
        cfg.val_fraction,
        cfg.comm.latency_s,
        cfg.comm.bandwidth_bps,
        cfg.global_step_pallas,
    ) + if cfg.heterogeneous { "|hetero" } else { "" };
    // FNV-1a 64
    let mut h: u64 = 0xcbf29ce484222325;
    for b in desc.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    format!("{}-{h:016x}", cfg.tag.replace(['/', ' '], "_"))
}

/// Perplexity improvement of `ours` over `baseline` in % — the paper's
/// "Improv." column: e^(val_base - val_ours) - 1.
pub fn ppl_improvement(baseline_val: f64, ours_val: f64) -> f64 {
    ((baseline_val - ours_val).exp() - 1.0) * 100.0
}

/// Fixed-width table printer.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths = vec![0usize; ncol];
        for row in std::iter::once(&self.header).chain(self.rows.iter()) {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Persist an experiment's rendered output under runs/<id>/summary.txt.
pub fn save_summary(h: &Harness, id: &str, text: &str) -> Result<()> {
    let dir = h.runs_dir.join(id);
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join("summary.txt"), text)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ppl_improvement_matches_paper_arithmetic() {
        // Table 2 medium τ=12: SlowMo 2.810, Alg1 2.709 -> 10.63%
        let imp = ppl_improvement(2.810, 2.709);
        assert!((imp - 10.63).abs() < 0.05, "{imp}");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Alg.", "Val."]);
        t.row(vec!["AdamW".into(), "2.917".into()]);
        t.row(vec!["Algorithm 1".into(), "2.942".into()]);
        let s = t.render();
        assert!(s.contains("Alg."));
        assert!(s.lines().count() == 4);
    }

    #[test]
    fn cache_key_distinguishes_configs() {
        let a = RunConfig::paper_default("nano");
        let mut b = a.clone();
        b.seed += 1;
        assert_ne!(cache_key(&a), cache_key(&b));
        let mut c = a.clone();
        c.tau = 24;
        assert_ne!(cache_key(&a), cache_key(&c));
        assert_eq!(cache_key(&a), cache_key(&a.clone()));
        // the wire format determines the trajectory (q8 quantizes the
        // exchange), so it must split the cache
        let mut d = a.clone();
        d.wire = Some(crate::dist::WireFormat::QuantizedI8);
        assert_ne!(cache_key(&a), cache_key(&d));
        // topk's tuning knobs shape the trajectory too — describe()
        // carries the ppm values, so two topk runs with different keep
        // fractions never share a cache row
        let mut e = a.clone();
        e.wire = Some(crate::dist::WireFormat::TOPK_DEFAULT);
        let mut f = a.clone();
        f.wire = Some(crate::dist::WireFormat::TopK { frac_ppm: 125_000, decay_ppm: 900_000 });
        assert_ne!(cache_key(&a), cache_key(&e));
        assert_ne!(cache_key(&e), cache_key(&f));
        // the robust-aggregation policy steers the server-side combine
        let mut g = a.clone();
        g.agg = crate::dist::AggPolicy::Trimmed;
        assert_ne!(cache_key(&a), cache_key(&g));
        let mut h = g.clone();
        h.agg = crate::dist::AggPolicy::Median;
        assert_ne!(cache_key(&g), cache_key(&h));
        // byzantine knobs shift the faulty trajectory (and the retry
        // limit shifts the fault stream), so each splits the key
        let mut i = a.clone();
        i.faults.byzantine_frac = 0.125;
        assert_ne!(cache_key(&a), cache_key(&i));
        let mut j = i.clone();
        j.faults.attack = crate::comm::Attack::ColludeFixed;
        assert_ne!(cache_key(&i), cache_key(&j));
        let mut k = i.clone();
        k.faults.quarantine = true;
        assert_ne!(cache_key(&i), cache_key(&k));
        let mut l = a.clone();
        l.faults.drop_prob = 0.1;
        let mut m = l.clone();
        m.faults.retry_limit = 2;
        assert_ne!(cache_key(&l), cache_key(&m));
    }
}
