//! Theory validation (§3): empirical convergence-rate exponents for the
//! instances analyzed in Theorems 1-3, on the analytic problems of
//! `sim::problems` where every assumption holds by construction.
//!
//! * Thm 2 check — randomized sign (eq. 9), SGD base, parameters as in
//!   Thm 1 (γ ∝ √(nτ/T)): the running mean of ‖∇f‖² should decay like
//!   O(1/√T)  ⇒ log-log slope ≈ -0.5 in T.
//! * Thm 3 check — exact sign, η = 1/(L T^{3/4}), 1-β = 1/√T: mean ℓ1
//!   gradient norm decays like O(1/T^{1/4}) ⇒ slope ≈ -0.25.
//! * Speedup check — the σ-term of Thm 3 is σ√(d/τn)/T^{1/4}: in the
//!   noise-dominated regime the achieved ℓ1 norm should improve as n and
//!   τ grow.

use anyhow::Result;

use super::runner::{save_summary, Harness, Table};
use crate::sign::SignOp;
use crate::sim::{loglog_slope, run_sign_momentum, HeterogeneousQuadratic, RastriginLike, SimSpec};

pub fn run(h: &Harness) -> Result<()> {
    let mut text = String::new();

    // ---- Theorem 1/2: randomized sign, averaged squared norm ----------
    {
        let dim = 32;
        let problem = HeterogeneousQuadratic::new(dim, 8, 0.4, 0.4, 9);
        let (n, tau) = (8usize, 4usize);
        let r_bound = 8.0f32; // generous Assumption-3 bound on this problem
        let mut pts = Vec::new();
        let mut t = Table::new(&["T (rounds)", "gamma (thm)", "mean ||grad||^2"]);
        for rounds in [64usize, 256, 1024, 4096] {
            // Theorem 1 step size: γ = (R/η)·√(nτ/T) with η = τR ⇒ α = √(n/τT).
            let eta = tau as f32 * r_bound;
            let gamma = (r_bound / eta) * ((n * tau) as f32 / rounds as f32).sqrt();
            let spec = SimSpec {
                n_workers: n,
                tau,
                rounds,
                gamma,
                eta,
                beta1: 0.9,
                beta2: 0.9,
                sign_op: SignOp::RandPm,
                sign_bound: tau as f32 * r_bound,
                seed: 5,
            };
            let res = run_sign_momentum(&problem, &spec);
            t.row(vec![
                format!("{rounds}"),
                format!("{gamma:.4}"),
                format!("{:.4e}", res.mean_sq_grad_norm),
            ]);
            pts.push((rounds as f64, res.mean_sq_grad_norm));
        }
        let slope = loglog_slope(&pts);
        text.push_str(&format!(
            "Theorem 1/2 instance (randomized sign S_r, SGD base, quadratic, n={n}, tau={tau}):\n{}\
             empirical rate: mean||grad||^2 ~ T^{slope:.3}   (theory: <= O(T^-0.5))\n\n",
            t.render()
        ));
    }

    // ---- Theorem 3: exact sign, l1 norms -------------------------------
    {
        let dim = 32;
        let problem = RastriginLike::new(dim, 8, 0.5, 1.5, 0.3, 3);
        let l = 2.5f32; // smoothness of the problem (1 + c)
        let (n, tau) = (8usize, 4usize);
        let mut pts = Vec::new();
        let mut t = Table::new(&["T (rounds)", "eta (thm)", "1-beta", "mean ||grad||_1"]);
        for rounds in [256usize, 1024, 4096, 16384] {
            let eta = 1.0 / (l * (rounds as f32).powf(0.75));
            let beta = 1.0 - 1.0 / (rounds as f32).sqrt();
            let spec = SimSpec {
                n_workers: n,
                tau,
                rounds,
                gamma: 0.02,
                // in Thm 3's parameterization the applied step is η·sign(m);
                // our update applies η·γ·sign(m), so fold γ into η here.
                eta: eta / 0.02,
                beta1: beta,
                beta2: beta,
                sign_op: SignOp::Exact,
                sign_bound: 1.0,
                seed: 7,
            };
            let res = run_sign_momentum(&problem, &spec);
            t.row(vec![
                format!("{rounds}"),
                format!("{eta:.5}"),
                format!("{:.4}", 1.0 - beta),
                format!("{:.4}", res.mean_l1_grad_norm),
            ]);
            pts.push((rounds as f64, res.mean_l1_grad_norm));
        }
        let slope = loglog_slope(&pts);
        text.push_str(&format!(
            "Theorem 3 instance (exact sign, eta=1/(L T^0.75), 1-beta=1/sqrt(T), nonconvex):\n{}\
             empirical rate: mean||grad||_1 ~ T^{slope:.3}   (theory: <= O(T^-0.25))\n\n",
            t.render()
        ));
    }

    // ---- Linear speedup in n and tau (Thm 3's sigma-term) --------------
    {
        let dim = 32;
        let rounds = 64;
        let seeds = [13u64, 14, 15, 16, 17];
        let mut t = Table::new(&["n", "tau", "final loss (5-seed mean)"]);
        for (n, tau) in [(1usize, 4usize), (4, 4), (16, 4), (4, 1), (4, 16)] {
            let mut acc = 0.0;
            for &seed in &seeds {
                let problem = HeterogeneousQuadratic::new(dim, n, 6.0, 0.0, 21);
                let spec = SimSpec {
                    n_workers: n,
                    tau,
                    rounds,
                    gamma: 0.05,
                    eta: 1.0,
                    beta1: 0.9,
                    beta2: 0.9,
                    sign_op: SignOp::Exact,
                    sign_bound: 1.0,
                    seed,
                };
                acc += run_sign_momentum(&problem, &spec).final_loss;
            }
            t.row(vec![
                format!("{n}"),
                format!("{tau}"),
                format!("{:.3}", acc / seeds.len() as f64),
            ]);
        }
        text.push_str(&format!(
            "Speedup check (sigma = 6 noise-dominated quadratic, T = {rounds}, gamma = 0.05):\n\
             Thm 3's sigma-term sigma*sqrt(d/(tau*n)) predicts progress improves in BOTH n \
             and tau.\n{}\n",
            t.render()
        ));
    }

    println!("{text}");
    save_summary(h, "theory", &text)
}
