//! # dist-sign-momentum
//!
//! Production-grade reproduction of *"Distributed Sign Momentum with
//! Local Steps for Training Transformers"* (Yu et al., 2024) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the distributed-training coordinator: local
//!   steps with pluggable base optimizers ([`optim`]), periodic exact
//!   averaging with a modeled communication cost ([`dist`], [`comm`]),
//!   and the paper's global sign-momentum step plus every baseline /
//!   ablation outer optimizer ([`outer`]).
//! * **L2/L1 (python/compile/)** — GPT-2 fwd/bwd in JAX calling Pallas
//!   kernels, AOT-lowered to HLO text loaded by [`runtime`] via PJRT.
//!   Python never runs at training time.
//!
//! Entry points: the `repro` binary (train / experiment / data / inspect),
//! the [`train::Trainer`] API, and `examples/`.

pub mod comm;
pub mod config;
pub mod data;
pub mod dist;
pub mod optim;
pub mod outer;
pub mod runtime;
pub mod sign;
pub mod sim;
pub mod tensor;
pub mod train;
pub mod util;

pub mod experiments;
