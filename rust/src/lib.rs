//! # dist-sign-momentum
//!
//! Production-grade reproduction of *"Distributed Sign Momentum with
//! Local Steps for Training Transformers"* (Yu et al., 2024) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the distributed-training coordinator: local
//!   steps with pluggable base optimizers ([`optim`]), periodic exact
//!   averaging with a modeled communication cost ([`dist`], [`comm`]),
//!   and the paper's global sign-momentum step plus every baseline /
//!   ablation outer optimizer ([`outer`]).
//! * **L2/L1 (python/compile/)** — GPT-2 fwd/bwd in JAX calling Pallas
//!   kernels, AOT-lowered to HLO text loaded by [`runtime`] via PJRT.
//!   Python never runs at training time.
//!
//! Entry points: the `repro` binary (train / experiment / data / inspect),
//! the [`train::Trainer`] API, and `examples/`.
//!
//! ## Standing invariants and how they are enforced
//!
//! The fleet simulator's correctness rests on a handful of cross-file
//! contracts that the type system cannot see. They are enforced
//! mechanically by the in-tree linter `tools/invlint` (a zero-dependency
//! workspace member: `cargo run -p invlint`), which also runs as the
//! tier-1 test `tests/invariants.rs`, so `cargo test -q` fails on any
//! violation. One rule per guarantee:
//!
//! * **W1 — wire exhaustiveness.** No catch-all (`_ =>` or binding)
//!   arms in `match`es over `WirePayload` / `WireFormat` variants in
//!   `dist/wire.rs`. Adding a wire format must force every accessor,
//!   size rule, and codec path to be revisited, not silently fall into
//!   a default.
//! * **W2 — checkpoint key parity.** Every key written by
//!   `train/checkpoint.rs` save paths is read by a load path and vice
//!   versa (including `format!`-templated and `with_prefix` keys).
//!   A checkpoint that round-trips is the resume guarantee.
//! * **W3 — cache-key completeness.** Every field of `OuterConfig` and
//!   `FaultPlan` appears in its `describe()`: two runs differing in any
//!   knob must not share an experiment-cache entry.
//! * **W4 — billing discipline.** No numeric byte arithmetic at
//!   `SimClock::charge_*` call sites; all sizes flow through
//!   `WireFormat::wire_bytes`, the one place the byte rule lives.
//! * **W5 — RNG-stream hygiene.** Fault injection (`comm/faults.rs`)
//!   and supervisor scoring stay off the training RNG streams, so
//!   enabling faults cannot perturb a seeded run's trajectory.
//! * **W6 — no `unwrap`/`expect` outside tests.** Library code
//!   propagates errors (`?` / `bail!`) or documents impossibility with
//!   `unreachable!`; a worker thread must not abort the fleet.
//! * **W7 — `SAFETY:` comments.** Every `unsafe` block or impl carries
//!   an adjacent `// SAFETY:` justification, and
//!   `#![deny(unsafe_op_in_unsafe_fn)]` keeps unsafe scopes explicit.
//!
//! A site that must break a rule carries an inline waiver comment,
//! `// invlint: allow(WN) -- reason`, which the linter honors and a
//! reviewer can grep.

#![deny(unsafe_op_in_unsafe_fn)]

pub mod comm;
pub mod config;
pub mod data;
pub mod dist;
pub mod optim;
pub mod outer;
pub mod runtime;
pub mod sign;
pub mod sim;
pub mod tensor;
pub mod train;
pub mod util;

pub mod experiments;
