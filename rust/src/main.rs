//! `repro` — launcher for the dist-sign-momentum training system.
//!
//! Subcommands:
//!   train        run one training configuration (TOML file + flag overrides)
//!   experiment   regenerate a paper table/figure (or `all`)
//!   data         synthesize/inspect the corpus, train a BPE tokenizer
//!   inspect      show manifest / artifact / checkpoint contents
//!   sim          run the pure-Rust theory testbed once
//!   list         list experiments and model presets

use std::path::PathBuf;

use anyhow::{anyhow, bail, Context, Result};

use dsm::config::RunConfig;
use dsm::data::corpus::{byte_entropy_bits, generate, CorpusConfig};
use dsm::data::{Bpe, Tokenizer};
use dsm::experiments::{self, runner::Harness};
use dsm::runtime::{Artifacts, Runtime};
use dsm::sign::SignOp;
use dsm::sim::{run_sign_momentum, HeterogeneousQuadratic, SimSpec};
use dsm::train::checkpoint::Checkpoint;
use dsm::train::Trainer;
use dsm::util::cli::Args;

const BOOL_FLAGS: &[&str] = &[
    "verbose",
    "no-cache",
    "big",
    "pallas-global-step",
    "quiet",
    "nesterov",
    "signed",
    "heterogeneous",
    "sequential-workers",
    "quarantine",
];

const USAGE: &str = "\
repro — Distributed Sign Momentum (Yu et al. 2024) training system

USAGE:
  repro train   [--config run.toml] [--preset P] [--workers N] [--tau K]
                [--rounds T] [--outer ALGO] [--global-lr F] [--peak-lr F]
                [--wire dense|packed_signs|q8|q8pt|topk] [--agg mean|trimmed|median]
                [--mode local|standalone] [--comm PRESET] [--seed S]
                [--churn-prob F] [--drop-prob F] [--corrupt-prob F] [--retry-limit N]
                [--byzantine-frac F] [--attack sign_flip|scale_inflate|collude_fixed|flaky]
                [--quarantine] [--pallas-global-step] [--sequential-workers]
                [--log-dir DIR] [--checkpoint F] [--resume F]
  repro experiment <id|all> [--scale F] [--big] [--no-cache]
  repro data    [--bytes N] [--seed S] [--bpe-vocab V] [--out FILE]
  repro inspect manifest|checkpoint [PATH]
  repro sim     [--workers N] [--tau K] [--rounds T] [--sign exact|rand_pm|rand_zero]
  repro list
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: Vec<String>) -> Result<()> {
    let args = Args::parse_with_bools(argv, BOOL_FLAGS).map_err(|e| anyhow!(e))?;
    let Some(cmd) = args.positional.first().map(String::as_str) else {
        print!("{USAGE}");
        return Ok(());
    };
    match cmd {
        "train" => cmd_train(&args),
        "experiment" | "exp" => cmd_experiment(&args),
        "data" => cmd_data(&args),
        "inspect" => cmd_inspect(&args),
        "sim" => cmd_sim(&args),
        "list" => cmd_list(),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command `{other}`\n{USAGE}"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let toml_text = match args.get("config") {
        Some(path) => {
            Some(std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?)
        }
        None => None,
    };
    let cfg = RunConfig::from_toml_and_args(toml_text.as_deref(), args)?;
    warn_unknown(args);

    let rt = Runtime::cpu()?;
    let arts = Artifacts::load(&Artifacts::default_dir())?;
    println!("platform: {}", rt.platform());
    println!("run: {}", cfg.describe());

    let log_dir = cfg.log_dir.clone();
    let tag = cfg.tag.clone();
    let ckpt_out = args.get("checkpoint").map(PathBuf::from);
    let resume = args.get("resume").map(PathBuf::from);

    let mut trainer = Trainer::new(cfg, &rt, &arts)?;
    if let Some(path) = resume {
        trainer.load_checkpoint(&path)?;
        println!("resumed from {path:?}");
    }
    let t0 = std::time::Instant::now();
    let res = trainer.run_with_progress(|row| {
        println!(
            "round {:>4}  steps {:>6}  train {:.4}  val {}  lr {:.2e}  sim {:.1}s",
            row.round,
            row.local_steps,
            row.train_loss,
            if row.val_loss.is_nan() {
                "  --  ".to_string()
            } else {
                format!("{:.4}", row.val_loss)
            },
            row.lr,
            row.sim_time_s,
        );
    })?;
    println!(
        "done: final val {:.4} (best {:.4}) | wall {:.1}s | sim {:.1}s \
         ({:.1}s compute + {:.2}s comm + {:.2}s stragglers) | {} comm rounds, {:.1} MB moved",
        res.final_val,
        res.best_val,
        t0.elapsed().as_secs_f64(),
        res.clock.total_s(),
        res.clock.compute_s,
        res.clock.comm_s,
        res.clock.straggler_s,
        res.clock.comm_rounds,
        res.clock.bytes_communicated as f64 / 1e6,
    );
    if let Some(dir) = log_dir {
        let path = dir.join(format!("{tag}.csv"));
        res.log.write_csv(&path)?;
        println!("log: {path:?}");
    }
    if let Some(path) = ckpt_out {
        trainer.save_checkpoint(&path)?;
        println!("checkpoint: {path:?}");
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("usage: repro experiment <id|all>; see `repro list`"))?
        .clone();
    let scale = args.f64_or("scale", 1.0).map_err(|e| anyhow!(e))?;
    let h = Harness::new(scale, args.has("big"), !args.has("no-cache"))?;
    warn_unknown(args);
    experiments::run(&id, &h)
}

fn cmd_data(args: &Args) -> Result<()> {
    let bytes = args.usize_or("bytes", 1 << 20).map_err(|e| anyhow!(e))?;
    let seed = args.u64_or("seed", 1234).map_err(|e| anyhow!(e))?;
    let corpus = generate(&CorpusConfig { bytes, seed, ..Default::default() });
    println!(
        "corpus: {} bytes, unigram entropy {:.3} bits/byte",
        corpus.len(),
        byte_entropy_bits(&corpus)
    );
    println!("sample: {}", String::from_utf8_lossy(&corpus[..200.min(corpus.len())]));
    if let Some(v) = args.get("bpe-vocab") {
        let vocab: usize = v.parse().map_err(|_| anyhow!("--bpe-vocab: bad integer"))?;
        let t0 = std::time::Instant::now();
        let bpe = Bpe::train(&corpus[..corpus.len().min(256 << 10)], vocab);
        println!(
            "bpe: trained vocab {} in {:.1}s, {:.2} bytes/token on held-out text",
            bpe.vocab_size(),
            t0.elapsed().as_secs_f64(),
            bpe.bytes_per_token(&corpus[corpus.len() / 2..corpus.len() / 2 + 65536])
        );
    }
    if let Some(out) = args.get("out") {
        std::fs::write(out, &corpus)?;
        println!("wrote {out}");
    }
    warn_unknown(args);
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    match args.positional.get(1).map(String::as_str) {
        Some("manifest") => {
            let arts = Artifacts::load(&Artifacts::default_dir())?;
            arts.validate()?;
            println!("artifacts dir: {:?}", arts.dir);
            println!(
                "sign_update kernel: {:?} (chunk {})",
                arts.sign_update_file.file_name().unwrap_or(arts.sign_update_file.as_os_str()),
                arts.sign_update_chunk
            );
            for (name, p) in &arts.presets {
                println!(
                    "preset {name:>8}: {:>10} params | d={} L={} H={} S={} B={} vocab={} | {} tensors",
                    p.param_count,
                    p.d_model,
                    p.n_layer,
                    p.n_head,
                    p.seq,
                    p.batch,
                    p.vocab,
                    p.layout.len()
                );
            }
            Ok(())
        }
        Some("checkpoint") => {
            let path = args
                .positional
                .get(2)
                .ok_or_else(|| anyhow!("usage: repro inspect checkpoint <path>"))?;
            let ck = Checkpoint::load(&PathBuf::from(path))?;
            println!("checkpoint `{}` @ round {}", ck.tag, ck.round);
            for (name, buf) in &ck.buffers {
                println!("  {name:<24} {:>10} f32", buf.len());
            }
            Ok(())
        }
        _ => bail!("usage: repro inspect manifest|checkpoint [PATH]"),
    }
}

fn cmd_sim(args: &Args) -> Result<()> {
    let spec = SimSpec {
        n_workers: args.usize_or("workers", 8).map_err(|e| anyhow!(e))?,
        tau: args.usize_or("tau", 4).map_err(|e| anyhow!(e))?,
        rounds: args.usize_or("rounds", 1000).map_err(|e| anyhow!(e))?,
        gamma: args.f32_or("gamma", 0.01).map_err(|e| anyhow!(e))?,
        eta: args.f32_or("eta", 1.0).map_err(|e| anyhow!(e))?,
        beta1: args.f32_or("beta1", 0.95).map_err(|e| anyhow!(e))?,
        beta2: args.f32_or("beta2", 0.98).map_err(|e| anyhow!(e))?,
        sign_op: SignOp::parse(&args.str_or("sign", "exact"))
            .ok_or_else(|| anyhow!("--sign: exact|rand_pm|rand_zero"))?,
        sign_bound: args.f32_or("bound", 50.0).map_err(|e| anyhow!(e))?,
        seed: args.u64_or("seed", 1).map_err(|e| anyhow!(e))?,
    };
    let problem = HeterogeneousQuadratic::new(
        args.usize_or("dim", 64).map_err(|e| anyhow!(e))?,
        spec.n_workers,
        args.f32_or("sigma", 0.5).map_err(|e| anyhow!(e))?,
        args.f32_or("delta", 0.5).map_err(|e| anyhow!(e))?,
        spec.seed,
    );
    warn_unknown(args);
    let res = run_sign_momentum(&problem, &spec);
    println!(
        "sim: mean||grad||^2 {:.4e} | mean||grad||_1 {:.4} | final loss {:.4} | final ||grad|| {:.4e}",
        res.mean_sq_grad_norm, res.mean_l1_grad_norm, res.final_loss, res.final_grad_norm
    );
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("experiments:");
    for (id, desc) in experiments::ALL {
        println!("  {id:<8} {desc}");
    }
    println!("\nmodel presets (run `repro inspect manifest` for details):");
    println!("  nano small medium large  — repro-scale GPT-2 analogues");
    println!("  gpt2s                    — the paper's GPT-2 Small (AOT proof)");
    Ok(())
}

fn warn_unknown(args: &Args) {
    for flag in args.unknown_flags() {
        eprintln!("warning: unused flag --{flag}");
    }
}
