//! AdamW (Loshchilov & Hutter) — the paper's main base optimizer (§4),
//! with bias correction and decoupled weight decay exactly as in the
//! paper's Algorithm 2.

use super::BaseOptimizer;

pub struct AdamW {
    beta1: f32,
    beta2: f32,
    eps: f32,
    weight_decay: f32,
    t: u64,
    m: Vec<f32>,
    v: Vec<f32>,
    /// Step counter mirrored as an f32 buffer so it rides along in
    /// [`BaseOptimizer::state`] (bias correction depends on t).
    t_buf: Vec<f32>,
}

impl AdamW {
    pub fn new(dim: usize, beta1: f32, beta2: f32, eps: f32, weight_decay: f32) -> Self {
        AdamW {
            beta1,
            beta2,
            eps,
            weight_decay,
            t: 0,
            m: vec![0.0; dim],
            v: vec![0.0; dim],
            t_buf: vec![0.0],
        }
    }
}

impl BaseOptimizer for AdamW {
    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len());
        self.t += 1;
        self.t_buf[0] = self.t as f32;
        let b1 = self.beta1;
        let b2 = self.beta2;
        // bias corrections folded into a single scalar per step
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        let inv_bc1 = 1.0 / bc1;
        let inv_sqrt_bc2 = 1.0 / bc2.sqrt();
        let wd = self.weight_decay;
        for (((p, &g), m), v) in params
            .iter_mut()
            .zip(grads)
            .zip(self.m.iter_mut())
            .zip(self.v.iter_mut())
        {
            *m = b1 * *m + (1.0 - b1) * g;
            *v = b2 * *v + (1.0 - b2) * g * g;
            let mhat = *m * inv_bc1;
            let denom = (*v).sqrt() * inv_sqrt_bc2 + self.eps;
            *p -= lr * (mhat / denom + wd * *p);
        }
    }

    fn reset(&mut self) {
        self.t = 0;
        self.t_buf[0] = 0.0;
        self.m.fill(0.0);
        self.v.fill(0.0);
    }

    fn name(&self) -> &'static str {
        "adamw"
    }

    fn state(&self) -> Vec<&[f32]> {
        vec![&self.m, &self.v, &self.t_buf]
    }

    fn load_state(&mut self, bufs: &[Vec<f32>]) {
        self.m.copy_from_slice(&bufs[0]);
        self.v.copy_from_slice(&bufs[1]);
        self.t = bufs[2][0] as u64;
        self.t_buf[0] = bufs[2][0];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// First step must be p -= lr * sign-ish(g): with bias correction the
    /// very first update is exactly lr * g/|g| (+wd) for scalar g.
    #[test]
    fn first_step_is_unit_scaled() {
        let mut opt = AdamW::new(1, 0.9, 0.999, 0.0, 0.0);
        let mut p = vec![0.0f32];
        opt.step(&mut p, &[0.123], 0.01);
        assert!((p[0] + 0.01).abs() < 1e-6, "{}", p[0]);
        let mut p2 = vec![0.0f32];
        let mut opt2 = AdamW::new(1, 0.9, 0.999, 0.0, 0.0);
        opt2.step(&mut p2, &[-7.0], 0.01);
        assert!((p2[0] - 0.01).abs() < 1e-6);
    }

    /// Reference values computed with the canonical PyTorch AdamW recipe.
    #[test]
    fn matches_reference_trajectory() {
        let mut opt = AdamW::new(2, 0.9, 0.95, 1e-8, 0.1);
        let mut p = vec![1.0f32, -2.0];
        let grads = [[0.5f32, 1.0], [-0.25, 0.75], [0.1, -0.3]];
        for g in grads {
            opt.step(&mut p, &g, 0.1);
        }
        // Checked against a NumPy implementation of Algorithm 2.
        let expect = [0.81359192f32, -2.195994];
        for (a, e) in p.iter().zip(expect) {
            assert!((a - e).abs() < 2e-4, "{a} vs {e}");
        }
    }

    #[test]
    fn weight_decay_is_decoupled() {
        // zero gradients: p' = p (1 - lr*wd); Adam part contributes 0/eps = 0.
        let mut opt = AdamW::new(1, 0.9, 0.95, 1e-8, 0.5);
        let mut p = vec![2.0f32];
        opt.step(&mut p, &[0.0], 0.1);
        assert!((p[0] - 2.0 * (1.0 - 0.05)).abs() < 1e-6);
    }

    #[test]
    fn converges_on_quadratic() {
        let mut opt = AdamW::new(1, 0.9, 0.999, 1e-8, 0.0);
        let mut p = vec![5.0f32];
        for _ in 0..2000 {
            let g = vec![p[0]];
            opt.step(&mut p, &g, 0.05);
        }
        assert!(p[0].abs() < 1e-2, "{}", p[0]);
    }

    #[test]
    fn update_magnitude_bounded_by_lr() {
        // |adam update| <= lr / (1-beta1) style bound; with bc, ~lr per coord.
        let mut opt = AdamW::new(4, 0.9, 0.95, 1e-8, 0.0);
        let mut p = vec![0.0f32; 4];
        let mut rngstate = 123u64;
        for _ in 0..50 {
            rngstate = rngstate.wrapping_mul(6364136223846793005).wrapping_add(1);
            let g: Vec<f32> =
                (0..4).map(|i| ((rngstate >> (i * 8)) & 0xff) as f32 - 127.0).collect();
            let before = p.clone();
            opt.step(&mut p, &g, 0.01);
            for (a, b) in p.iter().zip(&before) {
                assert!((a - b).abs() <= 0.011 * 3.0);
            }
        }
    }
}
