//! Lion (Evolved Sign Momentum, Chen et al. 2024) — paper's Algorithm 4.
//!
//! Algorithm 1's global step is exactly a Lion step over pseudo-gradients
//! (aggregated local differences); having the centralized optimizer here
//! lets tests pin that correspondence: Algorithm 1 with n=1, τ=1, SGD
//! base reduces to Lion on the same gradient stream
//! (rust/tests/equivalence.rs).

use super::BaseOptimizer;
use crate::tensor::sign_f32;

pub struct Lion {
    beta1: f32,
    beta2: f32,
    weight_decay: f32,
    m: Vec<f32>,
}

impl Lion {
    pub fn new(dim: usize, beta1: f32, beta2: f32, weight_decay: f32) -> Self {
        Lion { beta1, beta2, weight_decay, m: vec![0.0; dim] }
    }
}

impl BaseOptimizer for Lion {
    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        assert_eq!(params.len(), grads.len());
        let (b1, b2, wd) = (self.beta1, self.beta2, self.weight_decay);
        for ((p, &g), m) in params.iter_mut().zip(grads).zip(self.m.iter_mut()) {
            let u = b1 * *m + (1.0 - b1) * g;
            *p -= lr * (sign_f32(u) + wd * *p);
            *m = b2 * *m + (1.0 - b2) * g;
        }
    }

    fn reset(&mut self) {
        self.m.fill(0.0);
    }

    fn name(&self) -> &'static str {
        "lion"
    }

    fn state(&self) -> Vec<&[f32]> {
        vec![&self.m]
    }

    fn load_state(&mut self, bufs: &[Vec<f32>]) {
        self.m.copy_from_slice(&bufs[0]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn updates_have_unit_magnitude() {
        let mut opt = Lion::new(3, 0.9, 0.99, 0.0);
        let mut p = vec![0.0f32; 3];
        opt.step(&mut p, &[5.0, -0.001, 100.0], 0.1);
        assert_eq!(p, vec![-0.1, 0.1, -0.1]);
    }

    #[test]
    fn interpolation_uses_beta1_update_uses_beta2() {
        let mut opt = Lion::new(1, 0.5, 0.9, 0.0);
        let mut p = vec![0.0f32];
        opt.step(&mut p, &[1.0], 1.0); // u = 0.5*0 + 0.5*1 > 0 -> p=-1; m=0.1
        assert_eq!(p[0], -1.0);
        // strong negative gradient: u = 0.5*0.1 - 0.5*0.3 < 0 -> +1 step
        opt.step(&mut p, &[-0.3], 1.0);
        assert_eq!(p[0], 0.0);
        // m now = 0.9*0.1 + 0.1*(-0.3) = 0.06
        opt.step(&mut p, &[0.0], 1.0); // u = 0.5*0.06 > 0 -> p -= 1
        assert_eq!(p[0], -1.0);
    }

    #[test]
    fn weight_decay_is_decoupled_and_signless() {
        let mut opt = Lion::new(1, 0.9, 0.99, 0.1);
        let mut p = vec![10.0f32];
        opt.step(&mut p, &[0.0], 0.5);
        // sign(u)=0, so the move is purely decay: 10 - 0.5*0.1*10 = 9.5
        assert_eq!(p[0], 9.5);
    }

    #[test]
    fn converges_on_quadratic_with_decaying_lr() {
        let mut opt = Lion::new(1, 0.9, 0.99, 0.0);
        let mut p = vec![4.0f32];
        for t in 0..400 {
            let g = vec![p[0]];
            let lr = 0.5 / (1.0 + t as f32).sqrt();
            opt.step(&mut p, &g, lr);
        }
        assert!(p[0].abs() < 0.1, "{}", p[0]);
    }
}
