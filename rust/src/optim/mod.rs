//! Base optimizers — the inner-loop "local step" engines of Algorithm 1.
//!
//! The paper's framework is agnostic to the base optimizer (§2): workers
//! run τ local steps of *any* of these, and only the resulting parameter
//! difference feeds the global sign-momentum step.  We provide the ones
//! the paper evaluates or references: SGD (±momentum) for the theory
//! instances (Theorems 2-3), AdamW for the main experiments (§4), Lion
//! because Algorithm 1's global step mimics it, and a Sophia variant for
//! Table 3.
//!
//! All optimizers operate on the flat `f32[P]` parameter vector produced
//! by the AOT'd model; `step()` consumes the gradient for one minibatch
//! and the current LR from the schedule.

mod adamw;
mod lion;
mod sgd;
mod sophia;

pub use adamw::AdamW;
pub use lion::Lion;
pub use sgd::Sgd;
pub use sophia::SophiaLite;

use crate::util::json::Json;

/// A local (per-worker) optimizer over the flat parameter vector.
pub trait BaseOptimizer: Send {
    /// Apply one update in place: `params <- params - lr * d(grads)`.
    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32);

    /// Reset internal state (momentum buffers etc.) to zero.
    fn reset(&mut self);

    /// Stable name, used in logs and checkpoints.
    fn name(&self) -> &'static str;

    /// Internal state as flat buffers for checkpointing, in a fixed order.
    fn state(&self) -> Vec<&[f32]>;

    /// Restore state saved by [`BaseOptimizer::state`].
    fn load_state(&mut self, bufs: &[Vec<f32>]);
}

/// Configuration for constructing a base optimizer (paper §4 defaults).
#[derive(Clone, Debug, PartialEq)]
pub enum BaseOptConfig {
    Sgd { momentum: f32, nesterov: bool, weight_decay: f32 },
    AdamW { beta1: f32, beta2: f32, eps: f32, weight_decay: f32 },
    Lion { beta1: f32, beta2: f32, weight_decay: f32 },
    Sophia { beta1: f32, beta2: f32, rho: f32, eps: f32, weight_decay: f32 },
}

impl BaseOptConfig {
    /// AdamW with the paper's pre-training hyper-parameters
    /// (β1=0.9, β2=0.95, λ=0.1 — §4 "Implementations").
    pub fn adamw_paper() -> Self {
        BaseOptConfig::AdamW { beta1: 0.9, beta2: 0.95, eps: 1e-8, weight_decay: 0.1 }
    }

    pub fn sgd_plain() -> Self {
        BaseOptConfig::Sgd { momentum: 0.0, nesterov: false, weight_decay: 0.0 }
    }

    pub fn sophia_paper() -> Self {
        BaseOptConfig::Sophia { beta1: 0.96, beta2: 0.99, rho: 0.05, eps: 1e-12, weight_decay: 0.1 }
    }

    pub fn lion_paper() -> Self {
        BaseOptConfig::Lion { beta1: 0.95, beta2: 0.98, weight_decay: 0.1 }
    }

    pub fn build(&self, dim: usize) -> Box<dyn BaseOptimizer> {
        match *self {
            BaseOptConfig::Sgd { momentum, nesterov, weight_decay } => {
                Box::new(Sgd::new(dim, momentum, nesterov, weight_decay))
            }
            BaseOptConfig::AdamW { beta1, beta2, eps, weight_decay } => {
                Box::new(AdamW::new(dim, beta1, beta2, eps, weight_decay))
            }
            BaseOptConfig::Lion { beta1, beta2, weight_decay } => {
                Box::new(Lion::new(dim, beta1, beta2, weight_decay))
            }
            BaseOptConfig::Sophia { beta1, beta2, rho, eps, weight_decay } => {
                Box::new(SophiaLite::new(dim, beta1, beta2, rho, eps, weight_decay))
            }
        }
    }

    /// Parse from a config table like `{algo = "adamw", beta1 = 0.9, ...}`.
    /// Unknown keys are ignored; missing keys take paper defaults.
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let algo = v
            .get("algo")
            .and_then(Json::as_str)
            .ok_or("base optimizer table needs `algo`")?;
        let f = |key: &str, default: f32| -> f32 {
            v.get(key).and_then(Json::as_f64).map(|x| x as f32).unwrap_or(default)
        };
        Ok(match algo {
            "sgd" => BaseOptConfig::Sgd {
                momentum: f("momentum", 0.0),
                nesterov: v.get("nesterov").and_then(Json::as_bool).unwrap_or(false),
                weight_decay: f("weight_decay", 0.0),
            },
            "adamw" => BaseOptConfig::AdamW {
                beta1: f("beta1", 0.9),
                beta2: f("beta2", 0.95),
                eps: f("eps", 1e-8),
                weight_decay: f("weight_decay", 0.1),
            },
            "lion" => BaseOptConfig::Lion {
                beta1: f("beta1", 0.95),
                beta2: f("beta2", 0.98),
                weight_decay: f("weight_decay", 0.1),
            },
            "sophia" => BaseOptConfig::Sophia {
                beta1: f("beta1", 0.96),
                beta2: f("beta2", 0.99),
                rho: f("rho", 0.05),
                eps: f("eps", 1e-12),
                weight_decay: f("weight_decay", 0.1),
            },
            other => return Err(format!("unknown base optimizer `{other}`")),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BaseOptConfig::Sgd { .. } => "sgd",
            BaseOptConfig::AdamW { .. } => "adamw",
            BaseOptConfig::Lion { .. } => "lion",
            BaseOptConfig::Sophia { .. } => "sophia",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::toml;

    #[test]
    fn build_all_kinds() {
        for cfg in [
            BaseOptConfig::sgd_plain(),
            BaseOptConfig::adamw_paper(),
            BaseOptConfig::lion_paper(),
            BaseOptConfig::sophia_paper(),
        ] {
            let mut opt = cfg.build(8);
            let mut p = vec![1.0f32; 8];
            let g = vec![0.5f32; 8];
            opt.step(&mut p, &g, 0.1);
            assert!(p.iter().all(|&x| x < 1.0), "{} did not descend", opt.name());
        }
    }

    #[test]
    fn from_json_parses_and_defaults() {
        let t = toml::parse("algo = \"adamw\"\nbeta2 = 0.999\n").unwrap();
        let cfg = BaseOptConfig::from_json(&t).unwrap();
        assert_eq!(
            cfg,
            BaseOptConfig::AdamW { beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.1 }
        );
        assert!(BaseOptConfig::from_json(&toml::parse("algo = \"nope\"").unwrap()).is_err());
        assert!(BaseOptConfig::from_json(&toml::parse("x = 1").unwrap()).is_err());
    }

    #[test]
    fn state_roundtrip_every_kind() {
        for cfg in [
            BaseOptConfig::Sgd { momentum: 0.9, nesterov: true, weight_decay: 0.0 },
            BaseOptConfig::adamw_paper(),
            BaseOptConfig::lion_paper(),
            BaseOptConfig::sophia_paper(),
        ] {
            let mut a = cfg.build(16);
            let mut b = cfg.build(16);
            let mut pa = vec![0.3f32; 16];
            let mut pb = pa.clone();
            let g: Vec<f32> = (0..16).map(|i| (i as f32 - 8.0) / 4.0).collect();
            for _ in 0..5 {
                a.step(&mut pa, &g, 0.01);
            }
            // transplant state a -> b, then both must evolve identically
            let saved: Vec<Vec<f32>> = a.state().iter().map(|s| s.to_vec()).collect();
            b.load_state(&saved);
            pb.copy_from_slice(&pa);
            a.step(&mut pa, &g, 0.01);
            b.step(&mut pb, &g, 0.01);
            assert_eq!(pa, pb, "{}", a.name());
        }
    }
}
