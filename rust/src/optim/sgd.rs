//! SGD with optional (Polyak / Nesterov) momentum and decoupled weight
//! decay — the base optimizer of the paper's theory (Theorems 2-3).

use super::BaseOptimizer;

pub struct Sgd {
    momentum: f32,
    nesterov: bool,
    weight_decay: f32,
    /// Velocity buffer; empty when momentum == 0 (saves P floats).
    v: Vec<f32>,
}

impl Sgd {
    pub fn new(dim: usize, momentum: f32, nesterov: bool, weight_decay: f32) -> Self {
        assert!((0.0..1.0).contains(&momentum) || momentum == 0.0);
        let v = if momentum != 0.0 { vec![0.0; dim] } else { Vec::new() };
        Sgd { momentum, nesterov, weight_decay, v }
    }
}

impl BaseOptimizer for Sgd {
    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        assert_eq!(params.len(), grads.len());
        if self.momentum == 0.0 {
            for (p, &g) in params.iter_mut().zip(grads) {
                *p -= lr * (g + self.weight_decay * *p);
            }
            return;
        }
        assert_eq!(self.v.len(), params.len());
        let beta = self.momentum;
        for ((p, &g), v) in params.iter_mut().zip(grads).zip(self.v.iter_mut()) {
            // Polyak: v <- beta v + g (paper Alg. 3 convention).
            *v = beta * *v + g;
            let d = if self.nesterov { g + beta * *v } else { *v };
            *p -= lr * (d + self.weight_decay * *p);
        }
    }

    fn reset(&mut self) {
        self.v.fill(0.0);
    }

    fn name(&self) -> &'static str {
        "sgd"
    }

    fn state(&self) -> Vec<&[f32]> {
        if self.v.is_empty() {
            vec![]
        } else {
            vec![&self.v]
        }
    }

    fn load_state(&mut self, bufs: &[Vec<f32>]) {
        if !self.v.is_empty() {
            self.v.copy_from_slice(&bufs[0]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_is_exact() {
        let mut opt = Sgd::new(3, 0.0, false, 0.0);
        let mut p = vec![1.0, 2.0, 3.0];
        opt.step(&mut p, &[1.0, 0.5, -1.0], 0.1);
        assert_eq!(p, vec![0.9, 1.95, 3.1]);
    }

    #[test]
    fn momentum_accumulates_polyak() {
        // constant gradient g: after k steps, v_k = g * (1-beta^k)/(1-beta)
        let beta = 0.5f32;
        let mut opt = Sgd::new(1, beta, false, 0.0);
        let mut p = vec![0.0f32];
        let lr = 1.0;
        opt.step(&mut p, &[1.0], lr); // v=1, p=-1
        opt.step(&mut p, &[1.0], lr); // v=1.5, p=-2.5
        opt.step(&mut p, &[1.0], lr); // v=1.75, p=-4.25
        assert!((p[0] + 4.25).abs() < 1e-6, "{}", p[0]);
    }

    #[test]
    fn nesterov_differs_from_polyak() {
        let mut a = Sgd::new(1, 0.9, false, 0.0);
        let mut b = Sgd::new(1, 0.9, true, 0.0);
        let (mut pa, mut pb) = (vec![0.0f32], vec![0.0f32]);
        for _ in 0..3 {
            a.step(&mut pa, &[1.0], 0.1);
            b.step(&mut pb, &[1.0], 0.1);
        }
        assert!(pb[0] < pa[0], "nesterov should look ahead: {} vs {}", pb[0], pa[0]);
    }

    #[test]
    fn decoupled_weight_decay_shrinks_without_gradient() {
        let mut opt = Sgd::new(2, 0.0, false, 0.1);
        let mut p = vec![1.0, -1.0];
        opt.step(&mut p, &[0.0, 0.0], 0.5);
        assert_eq!(p, vec![0.95, -0.95]);
    }

    #[test]
    fn reset_zeroes_velocity() {
        let mut opt = Sgd::new(1, 0.9, false, 0.0);
        let mut p = vec![0.0f32];
        opt.step(&mut p, &[1.0], 0.1);
        opt.reset();
        let mut q = vec![0.0f32];
        opt.step(&mut q, &[1.0], 0.1);
        assert_eq!(q[0], -0.1);
    }

    #[test]
    fn converges_on_quadratic() {
        // f(x) = 0.5 * x^2, grad = x
        let mut opt = Sgd::new(1, 0.9, false, 0.0);
        let mut p = vec![10.0f32];
        for _ in 0..200 {
            let g = vec![p[0]];
            opt.step(&mut p, &g, 0.05);
        }
        assert!(p[0].abs() < 1e-3, "{}", p[0]);
    }
}
