//! Sophia-lite: clipped second-order-ish optimizer for the Table 3
//! comparison.
//!
//! **Substitution note (DESIGN.md §5.4):** the paper's Table 3 uses
//! Sophia-G, whose Hessian-diagonal estimator (Gauss-Newton-Bartlett)
//! needs an extra forward pass with *sampled* labels every k steps — an
//! additional AOT entry point that buys nothing on this CPU testbed.  We
//! keep Sophia's defining structure — EMA momentum divided by an EMA
//! Hessian-diagonal proxy with per-coordinate clipping
//! `clip(m / max(rho*bs*h, eps), 1)` — but estimate the diagonal with an
//! EMA of squared gradients (the AdaHessian/GGN-proxy used by several
//! Sophia reimplementations).  What Table 3 measures (a second-order-ish
//! base optimizer under SlowMo vs Algorithm 1) is preserved.

use super::BaseOptimizer;

pub struct SophiaLite {
    beta1: f32,
    beta2: f32,
    /// Clipping scale rho (paper suggests 0.03-0.05 for GPT-2).
    rho: f32,
    eps: f32,
    weight_decay: f32,
    /// Hessian EMA refresh interval (Sophia updates h every k=10 steps).
    pub hess_interval: u64,
    t: u64,
    m: Vec<f32>,
    h: Vec<f32>,
}

impl SophiaLite {
    pub fn new(dim: usize, beta1: f32, beta2: f32, rho: f32, eps: f32, weight_decay: f32) -> Self {
        SophiaLite {
            beta1,
            beta2,
            rho,
            eps,
            weight_decay,
            hess_interval: 10,
            t: 0,
            m: vec![0.0; dim],
            h: vec![0.0; dim],
        }
    }
}

impl BaseOptimizer for SophiaLite {
    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        assert_eq!(params.len(), grads.len());
        let (b1, b2, wd) = (self.beta1, self.beta2, self.weight_decay);
        let refresh = self.t % self.hess_interval == 0;
        self.t += 1;
        for (((p, &g), m), h) in params
            .iter_mut()
            .zip(grads)
            .zip(self.m.iter_mut())
            .zip(self.h.iter_mut())
        {
            *m = b1 * *m + (1.0 - b1) * g;
            if refresh {
                // squared-gradient proxy for the GNB Hessian diagonal
                *h = b2 * *h + (1.0 - b2) * g * g;
            }
            let ratio = (*m / (self.rho * *h + self.eps)).clamp(-1.0, 1.0);
            *p -= lr * (ratio + wd * *p);
        }
    }

    fn reset(&mut self) {
        self.t = 0;
        self.m.fill(0.0);
        self.h.fill(0.0);
    }

    fn name(&self) -> &'static str {
        "sophia"
    }

    fn state(&self) -> Vec<&[f32]> {
        vec![&self.m, &self.h]
    }

    fn load_state(&mut self, bufs: &[Vec<f32>]) {
        self.m.copy_from_slice(&bufs[0]);
        self.h.copy_from_slice(&bufs[1]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn update_is_clipped_to_unit() {
        let mut opt = SophiaLite::new(2, 0.9, 0.99, 0.05, 1e-12, 0.0);
        let mut p = vec![0.0f32; 2];
        // tiny h (first step) -> ratio saturates at ±1 -> sign-like step
        opt.step(&mut p, &[3.0, -0.2], 0.1);
        assert_eq!(p, vec![-0.1, 0.1]);
    }

    #[test]
    fn flat_coordinates_move_less_when_h_large() {
        let mut opt = SophiaLite::new(1, 0.0, 0.0, 1.0, 1e-12, 0.0);
        // with beta's zero: m = g, h = g^2 (refresh at every interval step)
        opt.hess_interval = 1;
        let mut p = vec![0.0f32];
        opt.step(&mut p, &[10.0], 0.1);
        // ratio = 10 / (1*100) = 0.1 -> step = -0.01
        assert!((p[0] + 0.01).abs() < 1e-6, "{}", p[0]);
    }

    #[test]
    fn hessian_refresh_interval_respected() {
        let mut opt = SophiaLite::new(1, 0.0, 0.5, 1.0, 1e-12, 0.0);
        opt.hess_interval = 2;
        let mut p = vec![0.0f32];
        opt.step(&mut p, &[2.0], 0.0); // t=0: refresh, h = 0.5*0 + 0.5*4 = 2
        let h_after_first = opt.h[0];
        opt.step(&mut p, &[100.0], 0.0); // t=1: no refresh
        assert_eq!(opt.h[0], h_after_first);
        opt.step(&mut p, &[2.0], 0.0); // t=2: refresh again
        assert!(opt.h[0] != h_after_first);
    }

    #[test]
    fn converges_on_quadratic() {
        let mut opt = SophiaLite::new(1, 0.9, 0.99, 0.05, 1e-12, 0.0);
        let mut p = vec![3.0f32];
        for t in 0..500 {
            let g = vec![p[0]];
            let lr = 0.3 / (1.0 + t as f32 / 50.0);
            opt.step(&mut p, &g, lr);
        }
        assert!(p[0].abs() < 0.05, "{}", p[0]);
    }
}
