//! Global AdamW with local steps — the paper's Algorithm 7 (§4.1
//! "Adaptive global update" ablation, Table 6 row "Global AdamW").
//!
//! Treats g_t = (x_{t,0} - x̄_{t,τ})/γ_t as a pseudo-gradient (the
//! average end point reconstructed from the dense payloads) and applies
//! one bias-corrected AdamW step with decoupled weight decay.  Balles &
//! Hennig's reading of Adam as variance-adapted sign momentum makes this
//! the natural adaptive comparator for Algorithm 1's pure sign step; the
//! paper finds the adaptivity buys little here.

use anyhow::Result;

use super::{OuterOptimizer, RoundCtx, WireFormat, WirePayload, WorkerView};
use crate::util::rng::Rng;

pub struct GlobalAdamW {
    eta: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    weight_decay: f32,
    t: u64,
    t_buf: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    /// round scratch: reconstructed average end point (not checkpointed)
    avg: Vec<f32>,
}

impl GlobalAdamW {
    pub fn new(dim: usize, eta: f32, beta1: f32, beta2: f32, eps: f32, weight_decay: f32) -> Self {
        GlobalAdamW {
            eta,
            beta1,
            beta2,
            eps,
            weight_decay,
            t: 0,
            t_buf: vec![0.0],
            m: vec![0.0; dim],
            v: vec![0.0; dim],
            avg: vec![0.0; dim],
        }
    }
}

impl OuterOptimizer for GlobalAdamW {
    fn wire(&self) -> WireFormat {
        WireFormat::DenseF32
    }

    fn contribute(
        &mut self,
        _worker: usize,
        _n_workers: usize,
        view: &WorkerView,
        _rng: &mut Rng,
        out: &mut WirePayload,
    ) {
        out.pack_end(view.start, view.end);
    }

    fn apply(
        &mut self,
        global: &mut [f32],
        ctx: &RoundCtx,
        payloads: &[WirePayload],
        _rng: &mut Rng,
    ) -> Result<()> {
        WirePayload::aggregate_end_into(ctx.agg, payloads, ctx.start, &mut self.avg)?;
        self.t += 1;
        self.t_buf[0] = self.t as f32;
        let inv_gamma = 1.0 / ctx.gamma;
        let (b1, b2) = (self.beta1, self.beta2);
        let inv_bc1 = 1.0 / (1.0 - b1.powi(self.t as i32));
        let inv_sqrt_bc2 = 1.0 / (1.0 - b2.powi(self.t as i32)).sqrt();
        for i in 0..global.len() {
            let g = (ctx.start[i] - self.avg[i]) * inv_gamma;
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g;
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g * g;
            let mhat = self.m[i] * inv_bc1;
            let denom = self.v[i].sqrt() * inv_sqrt_bc2 + self.eps;
            global[i] =
                ctx.start[i] - self.eta * (mhat / denom + self.weight_decay * ctx.start[i]);
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "global_adamw"
    }

    fn state(&self) -> Vec<&[f32]> {
        vec![&self.m, &self.v, &self.t_buf]
    }

    fn load_state(&mut self, bufs: &[Vec<f32>]) {
        self.m.copy_from_slice(&bufs[0]);
        self.v.copy_from_slice(&bufs[1]);
        self.t = bufs[2][0] as u64;
        self.t_buf[0] = bufs[2][0];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::outer::run_synthetic_round;

    #[test]
    fn first_round_moves_by_eta_in_pseudograd_sign() {
        let mut opt = GlobalAdamW::new(2, 0.5, 0.9, 0.999, 0.0, 0.0);
        let mut global = vec![0.0f32; 2];
        run_synthetic_round(&mut opt, &mut global, &[0.03, -0.9], 0.1, 0);
        // bias-corrected first Adam step has magnitude eta regardless of g
        assert!((global[0] + 0.5).abs() < 1e-4, "{global:?}");
        assert!((global[1] - 0.5).abs() < 1e-4, "{global:?}");
    }

    #[test]
    fn agrees_with_base_adamw_on_same_pseudogradients() {
        use crate::optim::{AdamW, BaseOptimizer};
        let mut outer = GlobalAdamW::new(2, 0.1, 0.9, 0.95, 1e-8, 0.1);
        let mut inner = AdamW::new(2, 0.9, 0.95, 1e-8, 0.1);
        let mut ga = vec![1.0f32, -2.0];
        let mut gb = ga.clone();
        let gamma = 0.2;
        for r in 0..5 {
            let pg = [0.1 * (r as f32 + 1.0), -0.05];
            let diff: Vec<f32> = pg.iter().map(|&g| g * gamma).collect();
            run_synthetic_round(&mut outer, &mut ga, &diff, gamma, r as u64);
            inner.step(&mut gb, &pg, 0.1);
        }
        for (a, b) in ga.iter().zip(&gb) {
            assert!((a - b).abs() < 1e-5, "{ga:?} vs {gb:?}");
        }
    }

    #[test]
    fn adaptivity_normalizes_coordinate_scales() {
        // pseudo-gradient 100x larger in coord 0 -> after a few rounds the
        // applied steps should be within ~2x of each other (unlike SlowMo).
        let mut opt = GlobalAdamW::new(2, 0.1, 0.9, 0.95, 1e-8, 0.0);
        let mut global = vec![0.0f32; 2];
        let mut prev = global.clone();
        let mut last_steps = [0.0f32; 2];
        for r in 0..10 {
            run_synthetic_round(&mut opt, &mut global, &[1.0, 0.01], 0.1, r);
            last_steps = [global[0] - prev[0], global[1] - prev[1]];
            prev = global.clone();
        }
        let ratio = (last_steps[0] / last_steps[1]).abs();
        assert!(ratio < 2.0, "adaptive steps should be scale-free: {ratio}");
    }
}
