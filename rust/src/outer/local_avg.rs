//! Plain periodic parameter averaging — "Local AdamW" in the paper's
//! Figure 3 (local SGD / FedAvg-style): the global step IS the exchange
//! mean, reconstructed straight into the iterate from the payloads.

use anyhow::Result;

use super::{OuterOptimizer, RoundCtx, WireFormat, WirePayload, WorkerView};
use crate::util::rng::Rng;

pub struct LocalAvg;

impl LocalAvg {
    pub fn new() -> Self {
        LocalAvg
    }
}

impl Default for LocalAvg {
    fn default() -> Self {
        Self::new()
    }
}

impl OuterOptimizer for LocalAvg {
    fn wire(&self) -> WireFormat {
        WireFormat::DenseF32
    }

    fn contribute(
        &mut self,
        _worker: usize,
        _n_workers: usize,
        view: &WorkerView,
        _rng: &mut Rng,
        out: &mut WirePayload,
    ) {
        out.pack_end(view.start, view.end);
    }

    fn apply(
        &mut self,
        global: &mut [f32],
        ctx: &RoundCtx,
        payloads: &[WirePayload],
        _rng: &mut Rng,
    ) -> Result<()> {
        WirePayload::aggregate_end_into(ctx.agg, payloads, ctx.start, global)?;
        Ok(())
    }

    fn name(&self) -> &'static str {
        "local_avg"
    }

    fn state(&self) -> Vec<&[f32]> {
        vec![]
    }

    fn load_state(&mut self, _bufs: &[Vec<f32>]) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::outer::run_synthetic_round;

    #[test]
    fn sets_global_to_average() {
        let mut opt = LocalAvg::new();
        let mut global = vec![1.0f32, 2.0, 3.0];
        run_synthetic_round(&mut opt, &mut global, &[0.5, -0.5, 0.0], 0.1, 0);
        assert_eq!(global, vec![0.5, 2.5, 3.0]);
    }

    #[test]
    fn is_stateless() {
        let opt = LocalAvg::new();
        assert!(opt.state().is_empty());
    }
}
