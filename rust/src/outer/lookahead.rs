//! Lookahead / signed Lookahead — the paper's n=1 ablations (Tables 4-5).
//!
//! Per §4.1, both are instances of Algorithm 1 with n=1, β1=β2=β, λ=0:
//!
//!   u_{t+1} = β m_t + (1-β)/γ_t (x_{t,0} - x_{t,τ})
//!   x_{t+1} = x_{t,0} - η γ_t u_{t+1}           (Lookahead, Table 4)
//!   x_{t+1} = x_{t,0} - η γ_t sign(u_{t+1})     (signed Lookahead, Table 5)
//!   m_{t+1} = β m_t + (1-β)/γ_t (x_{t,0} - x_{t,τ})
//!
//! (The unsigned variant with β momentum generalizes Zhang et al. 2019's
//! "k steps forward, 1 step back".)
//!
//! Dense-exchange method: `contribute` ships the rank's end parameters,
//! `apply` reconstructs the average end point from the payloads.

use anyhow::Result;

use super::{OuterOptimizer, RoundCtx, WireFormat, WirePayload, WorkerView};
use crate::tensor::sign_f32;
use crate::util::rng::Rng;

pub struct Lookahead {
    eta: f32,
    beta: f32,
    signed: bool,
    m: Vec<f32>,
    /// round scratch: reconstructed average end point (not checkpointed)
    avg: Vec<f32>,
}

impl Lookahead {
    pub fn new(dim: usize, eta: f32, beta: f32, signed: bool) -> Self {
        Lookahead { eta, beta, signed, m: vec![0.0; dim], avg: vec![0.0; dim] }
    }
}

impl OuterOptimizer for Lookahead {
    fn wire(&self) -> WireFormat {
        WireFormat::DenseF32
    }

    fn contribute(
        &mut self,
        _worker: usize,
        _n_workers: usize,
        view: &WorkerView,
        _rng: &mut Rng,
        out: &mut WirePayload,
    ) {
        out.pack_end(view.start, view.end);
    }

    fn apply(
        &mut self,
        global: &mut [f32],
        ctx: &RoundCtx,
        payloads: &[WirePayload],
        _rng: &mut Rng,
    ) -> Result<()> {
        WirePayload::aggregate_end_into(ctx.agg, payloads, ctx.start, &mut self.avg)?;
        let inv_gamma = 1.0 / ctx.gamma;
        for i in 0..global.len() {
            let pg = (ctx.start[i] - self.avg[i]) * inv_gamma;
            let u = self.beta * self.m[i] + (1.0 - self.beta) * pg;
            let step = if self.signed { sign_f32(u) } else { u };
            global[i] = ctx.start[i] - self.eta * ctx.gamma * step;
            self.m[i] = u; // β1 == β2 means m_{t+1} == u_{t+1}
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        if self.signed {
            "signed_lookahead"
        } else {
            "lookahead"
        }
    }

    fn state(&self) -> Vec<&[f32]> {
        vec![&self.m]
    }

    fn load_state(&mut self, bufs: &[Vec<f32>]) {
        self.m.copy_from_slice(&bufs[0]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::outer::{run_synthetic_round, OuterConfig, SignMomentum};
    use crate::sign::SignOp;

    #[test]
    fn unsigned_beta0_eta1_recovers_local_end() {
        // β=0, η=1: x' = x - γ·(diff/γ) = x - diff = x_{t,τ}.
        let mut opt = Lookahead::new(2, 1.0, 0.0, false);
        let mut global = vec![1.0f32, -1.0];
        run_synthetic_round(&mut opt, &mut global, &[0.3, -0.4], 0.1, 0);
        assert!((global[0] - 0.7).abs() < 1e-6);
        assert!((global[1] + 0.6).abs() < 1e-6);
    }

    #[test]
    fn signed_lookahead_equals_sign_momentum_with_equal_betas() {
        // §4.1: signed Lookahead == Algorithm 1 with β1=β2, λ=0.
        let beta = 0.6f32;
        let mut la = Lookahead::new(3, 6.0, beta, true);
        let mut sm = SignMomentum::new(3, 6.0, beta, beta, 0.0, SignOp::Exact, 1.0);
        let mut ga = vec![0.2f32, -0.1, 0.5];
        let mut gb = ga.clone();
        for r in 0..6 {
            let diff = vec![0.01 * (r as f32 - 2.0), 0.02, -0.015];
            run_synthetic_round(&mut la, &mut ga, &diff, 0.1, r as u64);
            run_synthetic_round(&mut sm, &mut gb, &diff, 0.1, r as u64);
        }
        for (a, b) in ga.iter().zip(&gb) {
            assert!((a - b).abs() < 1e-6, "{ga:?} vs {gb:?}");
        }
    }

    #[test]
    fn momentum_converges_to_steady_pseudogradient() {
        // constant progress d: m_t = (1 - β^t)·(d/γ) -> d/γ geometrically.
        let beta = 0.5f32;
        let (d, gamma) = (0.05f32, 0.1f32);
        let mut opt = Lookahead::new(1, 1.0, beta, false);
        let mut x = vec![1.0f32];
        for r in 1..=10u32 {
            run_synthetic_round(&mut opt, &mut x, &[d], gamma, r as u64);
            let expect = (1.0 - beta.powi(r as i32)) * d / gamma;
            assert!((opt.m[0] - expect).abs() < 1e-5, "round {r}: {} vs {expect}", opt.m[0]);
        }
        // and x decreased monotonically under constant positive progress
        assert!(x[0] < 1.0);
    }

    #[test]
    fn config_names() {
        let name = |signed: bool| {
            OuterConfig::Lookahead { eta: 1.0, beta: 0.1, signed }.build(1).name()
        };
        assert_eq!(name(false), "lookahead");
        assert_eq!(name(true), "signed_lookahead");
    }
}
