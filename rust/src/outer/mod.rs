//! Outer (global, per-communication-round) optimizers.
//!
//! This module is the paper's system contribution.  After each worker
//! runs τ local steps of its base optimizer, the trainer drives the
//! outer optimizer through one **typed round exchange** — a symmetric
//! two-phase contract over [`WirePayload`]:
//!
//! 1. **Worker side** — [`OuterOptimizer::contribute`] runs once per
//!    rank, in rank order, packing that rank's contribution (its
//!    end-of-round view, [`WorkerView`]) into a trainer-owned
//!    persistent payload buffer: full-precision parameters, 1-bit sign
//!    votes, 8-bit quantized differences, or top-k sparse components
//!    of the rank's decaying residual momentum.
//! 2. **Server side** — [`OuterOptimizer::apply`] consumes the gathered
//!    payloads and applies the global step to the iterate.
//!
//! The payloads are the *only* worker→server channel, and the clock
//! bills their own byte count
//! ([`crate::comm::SimClock::charge_exchange`]), so the simulated wire
//! cost and the exchanged data agree by construction — there is no
//! per-optimizer billing flag and no parallel method family per format.
//!
//! # Optimizers and their wire formats
//!
//! | optimizer | paper algorithm | wire formats | bytes / rank message | agg policies |
//! |---|---|---|---|---|
//! | [`SignMomentum`] | Algorithm 1 (eqs. 6-8) | `dense` (default), `q8`, `q8pt`, `topk` | `4P` / `P + 12` / `P + 8 + 4S` / `8K + 8` | `mean`, `trimmed`, `median` |
//! | [`SlowMo`] | Algorithm 5 (Wang et al. 2019) | `dense` (default), `q8`, `q8pt`, `topk` | `4P` / `P + 12` / `P + 8 + 4S` / `8K + 8` | `mean`, `trimmed`, `median` |
//! | [`SignedSlowMo`] | §4.1 ablation | `dense` (default), `q8`, `q8pt`, `topk` | `4P` / `P + 12` / `P + 8 + 4S` / `8K + 8` | `mean`, `trimmed`, `median` |
//! | [`Lookahead`] (± signed) | Tables 4-5 (n = 1) | `dense` (default), `q8`, `q8pt`, `topk` | `4P` / `P + 12` / `P + 8 + 4S` / `8K + 8` | `mean`, `trimmed`, `median` |
//! | [`GlobalAdamW`] | Algorithm 7 | `dense` (default), `q8`, `q8pt`, `topk` | `4P` / `P + 12` / `P + 8 + 4S` / `8K + 8` | `mean`, `trimmed`, `median` |
//! | [`LocalAvg`] | "Local AdamW" (Fig. 3) | `dense` (default), `q8`, `q8pt`, `topk` | `4P` / `P + 12` / `P + 8 + 4S` / `8K + 8` | `mean`, `trimmed`, `median` |
//! | [`MvSignSgd`] | Algorithm 6 (Sun et al. 2023) | `packed_signs` only | `⌈P/8⌉ + 8` | majority tally (robust by construction — ignores `agg`) |
//!
//! (`S` = segment count of the backend's parameter layout,
//! [`crate::runtime::StepBackend::layout`]; `K` = Σ per-segment top-k
//! budgets, ⌊`numel · topk_frac`⌋ clamped to `1..=numel` per segment.)
//!
//! The dense-exchange methods all reconstruct the round's average end
//! point from the payloads ([`WirePayload::mean_end_into`]) and then
//! run their own elementwise update, which is why every one of them
//! supports the compressed formats for free: selecting `wire = "q8"`,
//! the layout-aware `wire = "q8pt"` (one quantization scale per
//! parameter segment), or the DeMo-style `wire = "topk"` (per-segment
//! top-k of a decaying residual-momentum buffer — what a rank does not
//! transmit this round decays by `topk_decay` and re-competes next
//! round) in the `[outer]` config table swaps the payload variant,
//! nothing else. MV-sto-signSGD's exchange *is* the 1-bit
//! majority vote, so it pins `packed_signs`
//! ([`crate::config::RunConfig::validate`] rejects the rest).
//!
//! The same sharing carries the robust-aggregation policy: every
//! dense-exchange method reconstructs through
//! [`WirePayload::aggregate_end_into`] with [`RoundCtx::agg`]
//! (`[outer] agg = "mean" | "trimmed" | "median"`; `mean` is the
//! bitwise-historical path), so a Byzantine-tolerant aggregate is one
//! config knob, never a per-optimizer reimplementation. MV-sto-signSGD
//! ignores the knob — its majority tally is already the robust
//! aggregator, the property the robustness suite pins
//! (`examples/robust_agg.rs`).
//!
//! All operate on the flat `f32[P]` vector; every implementation is
//! cross-checked against the jnp/Pallas references where one exists
//! (rust/tests/equivalence.rs, python kernels/ref.py), and the payload
//! contract is pinned to the historical semantics by the hand-computed
//! unit tests below plus the golden differential suites in
//! rust/tests/parallel_fleet.rs.

mod global_adamw;
mod local_avg;
mod lookahead;
mod mv_signsgd;
mod sign_momentum;
mod slowmo;

pub use global_adamw::GlobalAdamW;
pub use local_avg::LocalAvg;
pub use lookahead::Lookahead;
pub use mv_signsgd::MvSignSgd;
pub use sign_momentum::SignMomentum;
pub use slowmo::{SignedSlowMo, SlowMo};

use anyhow::Result;

pub use crate::dist::{AggPolicy, WireFormat, WirePayload};
use crate::sign::SignOp;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// What one rank exposes to [`OuterOptimizer::contribute`] at a round
/// boundary. Everything here is rank-local: nothing crosses the
/// simulated wire except what `contribute` packs into the payload.
pub struct WorkerView<'a> {
    /// The round's start point — what [`OuterOptimizer::local_start`]
    /// handed every rank (the global iterate, or e.g. MV-sto-signSGD's
    /// extrapolated y_t).
    pub start: &'a [f32],
    /// x_{t,τ}^{(i)}: this rank's parameters after its τ local steps.
    pub end: &'a [f32],
    /// This rank's last local stochastic gradient (Algorithm 6's
    /// momentum input).
    pub last_grad: &'a [f32],
    /// The backend's validated parameter layout
    /// ([`crate::runtime::StepBackend::layout`]): how `start`/`end`
    /// tile into named segments. Layout-aware payloads (`q8pt`,
    /// `topk`) carry it themselves, so `contribute` rarely touches
    /// this — it exists so segment-resolved consumers (metrics,
    /// diagnostics) see the same contract the wire does.
    pub layout: &'a crate::runtime::ParamLayout,
}

impl<'a> WorkerView<'a> {
    /// Segment `i` of the round's start point.
    pub fn segment_start(&self, i: usize) -> &'a [f32] {
        self.layout.slice_of(i, self.start)
    }

    /// Segment `i` of this rank's end-of-round parameters.
    pub fn segment_end(&self, i: usize) -> &'a [f32] {
        self.layout.slice_of(i, self.end)
    }
}

/// Server-side context for [`OuterOptimizer::apply`]. Deliberately
/// slim: per-rank state only reaches the server through the payloads.
pub struct RoundCtx<'a> {
    /// x_{t,0}: the round's start point (== `global` on entry to
    /// `apply`); what [`OuterOptimizer::local_start`] returned.
    pub start: &'a [f32],
    /// γ_t: local learning rate in effect this round (schedules vary it).
    pub gamma: f32,
    /// Outer round index t.
    pub round: u64,
    /// Server-side aggregation policy over the gathered payloads
    /// ([`AggPolicy::Mean`] is the bitwise-historical path; the robust
    /// policies defend against Byzantine ranks). The sign tally
    /// ignores it — see the module docs' agg-policies column.
    pub agg: AggPolicy,
}

/// The round-exchange contract every outer optimizer implements — one
/// symmetric two-phase API for all wire formats (see the module docs).
///
/// # Execution order and determinism
///
/// Per round the trainer calls [`local_start`](Self::local_start), runs
/// the local phases, then `contribute` for ranks `0..n` in order
/// (sharing the trainer RNG — randomized-sign draws consume it in rank
/// order), then [`apply`](Self::apply) once. `global == ctx.start` on
/// entry to `apply`. Both halves must be deterministic given their RNG
/// stream: the differential suites pin loss curves, checkpoints, and
/// RNG streams across execution modes.
pub trait OuterOptimizer: Send {
    /// This optimizer's *native* wire format — what it exchanges when
    /// the config does not override the format
    /// ([`crate::config::RunConfig::resolved_wire`]). The set of
    /// formats an optimizer accepts is a config-level property
    /// ([`OuterConfig::supported_wires`]); `contribute`/`apply`
    /// dispatch on the payload variant the trainer sized the buffers
    /// with.
    fn wire(&self) -> WireFormat;

    /// Worker-side half: pack rank `worker`'s round contribution into
    /// `out`, a persistent trainer-owned buffer re-passed every round
    /// (the steady-state exchange allocates nothing). Must not change
    /// the payload's format or coordinate count — the round's wire cost
    /// was already billed from them, and the trainer errors on drift.
    fn contribute(
        &mut self,
        worker: usize,
        n_workers: usize,
        view: &WorkerView,
        rng: &mut Rng,
        out: &mut WirePayload,
    );

    /// Server-side half: consume the gathered payloads and apply the
    /// global step to `global` (== `ctx.start` on entry).
    fn apply(
        &mut self,
        global: &mut [f32],
        ctx: &RoundCtx,
        payloads: &[WirePayload],
        rng: &mut Rng,
    ) -> Result<()>;

    /// Starting point handed to workers for the *next* local phase.
    /// Default: the global iterate itself.  MV-sto-signSGD overrides this
    /// with its extrapolated y_t = x_t + α (x_t - x_{t-1}).
    fn local_start(&mut self, global: &[f32]) -> Vec<f32> {
        global.to_vec()
    }

    fn name(&self) -> &'static str;

    /// Flat state buffers for checkpointing.
    fn state(&self) -> Vec<&[f32]>;
    fn load_state(&mut self, bufs: &[Vec<f32>]);
}

/// Construction-time description of an outer optimizer (config file /
/// CLI / experiment harness).
#[derive(Clone, Debug, PartialEq)]
pub enum OuterConfig {
    /// Algorithm 1 with Lion-recommended defaults (§4: β1=0.95, β2=0.98, λ=0.1).
    SignMomentum {
        eta: f32,
        beta1: f32,
        beta2: f32,
        weight_decay: f32,
        sign_op: SignOp,
        sign_bound: f32,
    },
    SlowMo { alpha: f32, beta: f32 },
    SignedSlowMo { eta: f32, beta: f32 },
    /// β1=β2=β, λ=0, unsigned update (Table 4) or signed (Table 5).
    Lookahead { eta: f32, beta: f32, signed: bool },
    GlobalAdamW { eta: f32, beta1: f32, beta2: f32, eps: f32, weight_decay: f32 },
    LocalAvg,
    MvSignSgd { eta: f32, beta: f32, alpha: f32, bound: f32 },
}

impl OuterConfig {
    pub fn sign_momentum_paper(eta: f32) -> Self {
        OuterConfig::SignMomentum {
            eta,
            beta1: 0.95,
            beta2: 0.98,
            weight_decay: 0.1,
            sign_op: SignOp::Exact,
            sign_bound: 1.0,
        }
    }

    pub fn slowmo_paper(alpha: f32, beta: f32) -> Self {
        OuterConfig::SlowMo { alpha, beta }
    }

    /// The format this optimizer exchanges when the config does not
    /// select one (`wire = ...` absent).
    pub fn default_wire(&self) -> WireFormat {
        match self {
            OuterConfig::MvSignSgd { .. } => WireFormat::PackedSigns,
            _ => WireFormat::DenseF32,
        }
    }

    /// The wire formats this optimizer can exchange. Every
    /// dense-exchange method also speaks `q8`, the layout-aware
    /// `q8pt`, and the sparse `topk` (the payload mean reconstructs
    /// the average end point whatever the compression);
    /// MV-sto-signSGD's exchange is definitionally the 1-bit vote.
    /// The `topk` entry is the default-parameter format; config
    /// validation matches by name, so tuned `topk_frac`/`topk_decay`
    /// values stay on the menu.
    pub fn supported_wires(&self) -> &'static [WireFormat] {
        match self {
            OuterConfig::MvSignSgd { .. } => &[WireFormat::PackedSigns],
            _ => &[
                WireFormat::DenseF32,
                WireFormat::QuantizedI8,
                WireFormat::QuantizedI8PerTensor,
                WireFormat::TOPK_DEFAULT,
            ],
        }
    }

    /// The concrete [`SignMomentum`] this config describes, when it is
    /// Algorithm 1 — the trainer uses this to install the Pallas-kernel
    /// `apply` specialization ([`SignMomentum::with_kernel`]).
    pub fn build_sign_momentum(&self, dim: usize) -> Option<SignMomentum> {
        match *self {
            OuterConfig::SignMomentum { eta, beta1, beta2, weight_decay, sign_op, sign_bound } => {
                Some(SignMomentum::new(dim, eta, beta1, beta2, weight_decay, sign_op, sign_bound))
            }
            _ => None,
        }
    }

    pub fn build(&self, dim: usize) -> Box<dyn OuterOptimizer> {
        match *self {
            OuterConfig::SignMomentum { eta, beta1, beta2, weight_decay, sign_op, sign_bound } => {
                Box::new(SignMomentum::new(
                    dim,
                    eta,
                    beta1,
                    beta2,
                    weight_decay,
                    sign_op,
                    sign_bound,
                ))
            }
            OuterConfig::SlowMo { alpha, beta } => Box::new(SlowMo::new(dim, alpha, beta)),
            OuterConfig::SignedSlowMo { eta, beta } => Box::new(SignedSlowMo::new(dim, eta, beta)),
            OuterConfig::Lookahead { eta, beta, signed } => {
                Box::new(Lookahead::new(dim, eta, beta, signed))
            }
            OuterConfig::GlobalAdamW { eta, beta1, beta2, eps, weight_decay } => {
                Box::new(GlobalAdamW::new(dim, eta, beta1, beta2, eps, weight_decay))
            }
            OuterConfig::LocalAvg => Box::new(LocalAvg::new()),
            OuterConfig::MvSignSgd { eta, beta, alpha, bound } => {
                Box::new(MvSignSgd::new(dim, eta, beta, alpha, bound))
            }
        }
    }

    /// Parse from a `[outer]` config table.
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let algo = v.get("algo").and_then(Json::as_str).ok_or("outer table needs `algo`")?;
        let f = |key: &str, default: f32| -> f32 {
            v.get(key).and_then(Json::as_f64).map(|x| x as f32).unwrap_or(default)
        };
        Ok(match algo {
            "sign_momentum" | "algorithm1" => OuterConfig::SignMomentum {
                eta: f("global_lr", 1.0),
                beta1: f("beta1", 0.95),
                beta2: f("beta2", 0.98),
                weight_decay: f("weight_decay", 0.1),
                sign_op: v
                    .get("sign_op")
                    .and_then(Json::as_str)
                    .and_then(SignOp::parse)
                    .unwrap_or(SignOp::Exact),
                sign_bound: f("sign_bound", 1.0),
            },
            "slowmo" => OuterConfig::SlowMo { alpha: f("global_lr", 1.0), beta: f("beta", 0.5) },
            "signed_slowmo" => {
                OuterConfig::SignedSlowMo { eta: f("global_lr", 1.0), beta: f("beta", 0.5) }
            }
            "lookahead" => OuterConfig::Lookahead {
                eta: f("global_lr", 1.0),
                beta: f("beta", 0.2),
                signed: v.get("signed").and_then(Json::as_bool).unwrap_or(false),
            },
            "global_adamw" => OuterConfig::GlobalAdamW {
                eta: f("global_lr", 1.0),
                beta1: f("beta1", 0.9),
                beta2: f("beta2", 0.95),
                eps: f("eps", 1e-8),
                weight_decay: f("weight_decay", 0.1),
            },
            "local_avg" => OuterConfig::LocalAvg,
            "mv_signsgd" => OuterConfig::MvSignSgd {
                eta: f("global_lr", 1e-3),
                beta: f("beta", 0.9),
                alpha: f("alpha", 0.1),
                bound: f("bound", 10.0),
            },
            other => return Err(format!("unknown outer optimizer `{other}`")),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            OuterConfig::SignMomentum { .. } => "sign_momentum",
            OuterConfig::SlowMo { .. } => "slowmo",
            OuterConfig::SignedSlowMo { .. } => "signed_slowmo",
            OuterConfig::Lookahead { signed: false, .. } => "lookahead",
            OuterConfig::Lookahead { signed: true, .. } => "signed_lookahead",
            OuterConfig::GlobalAdamW { .. } => "global_adamw",
            OuterConfig::LocalAvg => "local_avg",
            OuterConfig::MvSignSgd { .. } => "mv_signsgd",
        }
    }

    /// Hyperparameter-resolved form of [`OuterConfig::name`] for run
    /// descriptions and the experiment cache key: every parsed field
    /// appears here, so two runs differing in any outer knob never
    /// collide in [`crate::config::RunConfig::describe`]. The invariant
    /// linter (rule W3) checks this list against the declared fields
    /// mechanically.
    pub fn describe(&self) -> String {
        match *self {
            OuterConfig::SignMomentum { eta, beta1, beta2, weight_decay, sign_op, sign_bound } => {
                format!(
                    "sign_momentum[eta={eta},b1={beta1},b2={beta2},wd={weight_decay},\
                     sign={},bound={sign_bound}]",
                    sign_op.name()
                )
            }
            OuterConfig::SlowMo { alpha, beta } => format!("slowmo[alpha={alpha},beta={beta}]"),
            OuterConfig::SignedSlowMo { eta, beta } => {
                format!("signed_slowmo[eta={eta},beta={beta}]")
            }
            OuterConfig::Lookahead { eta, beta, signed: _ } => {
                format!("{}[eta={eta},beta={beta}]", self.name())
            }
            OuterConfig::GlobalAdamW { eta, beta1, beta2, eps, weight_decay } => {
                format!(
                    "global_adamw[eta={eta},b1={beta1},b2={beta2},eps={eps},\
                     wd={weight_decay}]"
                )
            }
            OuterConfig::LocalAvg => "local_avg".to_string(),
            OuterConfig::MvSignSgd { eta, beta, alpha, bound } => {
                format!("mv_signsgd[eta={eta},beta={beta},alpha={alpha},bound={bound}]")
            }
        }
    }
}

/// Drive one outer round on a synthetic single-worker context where the
/// averaged local difference is `diff` (the worker ended at
/// start − diff), through the full two-phase payload contract in the
/// optimizer's native wire format.  Shared by unit tests here and the
/// cross-implementation equivalence suite.
///
/// The RNG stream is consumed exactly as the historical one-call API
/// did: `contribute` draws first (randomized sign votes), `apply` draws
/// after (randomized sign operators) — so the hand-computed expected
/// values pinned by the unit tests carry over unchanged.
pub fn run_synthetic_round(
    opt: &mut dyn OuterOptimizer,
    global: &mut Vec<f32>,
    diff: &[f32],
    gamma: f32,
    round: u64,
) {
    let start = global.clone();
    let end: Vec<f32> = start.iter().zip(diff).map(|(&s, &d)| s - d).collect();
    // expose the applied difference as the "last local gradient" so
    // gradient-momentum methods (Alg. 6) also see a consistent signal
    let layout = crate::runtime::ParamLayout::single(start.len());
    let view = WorkerView { start: &start, end: &end, last_grad: diff, layout: &layout };
    let mut rng = Rng::new(round ^ 0xABCD);
    let mut payload = WirePayload::with_len(opt.wire(), start.len());
    opt.contribute(0, 1, &view, &mut rng, &mut payload);
    let ctx = RoundCtx { start: &start, gamma, round, agg: AggPolicy::Mean };
    global.copy_from_slice(&start);
    if let Err(e) = opt.apply(global, &ctx, std::slice::from_ref(&payload), &mut rng) {
        panic!("synthetic round failed: {e}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::toml;

    #[test]
    fn build_all_kinds_and_descend() {
        let configs = [
            OuterConfig::sign_momentum_paper(1.0),
            OuterConfig::SlowMo { alpha: 1.0, beta: 0.5 },
            OuterConfig::SignedSlowMo { eta: 1.0, beta: 0.5 },
            OuterConfig::Lookahead { eta: 1.0, beta: 0.2, signed: false },
            OuterConfig::Lookahead { eta: 1.0, beta: 0.2, signed: true },
            OuterConfig::GlobalAdamW {
                eta: 1.0,
                beta1: 0.9,
                beta2: 0.95,
                eps: 1e-8,
                weight_decay: 0.0,
            },
            OuterConfig::LocalAvg,
            // bound == |pseudo-grad| makes the randomized vote deterministic
            // here (a single synthetic worker would otherwise coin-flip —
            // exactly the Remark-2 neighborhood effect).
            OuterConfig::MvSignSgd { eta: 0.1, beta: 0.9, alpha: 0.1, bound: 0.0101 },
        ];
        for cfg in configs {
            let mut opt = cfg.build(4);
            let mut global = vec![1.0f32; 4];
            // positive accumulated difference = descent direction
            run_synthetic_round(opt.as_mut(), &mut global, &[0.1, 0.1, 0.1, 0.1], 0.1, 0);
            assert!(
                global.iter().all(|&x| x < 1.0),
                "{}: {global:?} did not move down",
                opt.name()
            );
        }
    }

    #[test]
    fn from_json_roundtrip() {
        let t = toml::parse(
            "algo = \"sign_momentum\"\nglobal_lr = 1.2\nbeta1 = 0.9\nsign_op = \"rand_pm\"\n",
        )
        .unwrap();
        let cfg = OuterConfig::from_json(&t).unwrap();
        match cfg {
            OuterConfig::SignMomentum { eta, beta1, beta2, sign_op, .. } => {
                assert_eq!(eta, 1.2);
                assert_eq!(beta1, 0.9);
                assert_eq!(beta2, 0.98); // default
                assert_eq!(sign_op, SignOp::RandPm);
            }
            other => panic!("{other:?}"),
        }
        assert!(OuterConfig::from_json(&toml::parse("algo = \"zzz\"").unwrap()).is_err());
    }

    #[test]
    fn worker_view_exposes_segment_slices() {
        use crate::runtime::{ParamEntry, ParamLayout};
        let entries = vec![
            ParamEntry { name: "a".into(), offset: 0, shape: vec![3] },
            ParamEntry { name: "b".into(), offset: 3, shape: vec![1] },
        ];
        let layout = ParamLayout::from_entries(entries, 4).unwrap();
        let start = [1.0f32, 2.0, 3.0, 4.0];
        let end = [0.5f32, 1.5, 2.5, 3.5];
        let view = WorkerView { start: &start, end: &end, last_grad: &end, layout: &layout };
        assert_eq!(view.segment_start(0), &start[..3]);
        assert_eq!(view.segment_end(1), &end[3..]);
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(OuterConfig::LocalAvg.name(), "local_avg");
        assert_eq!(
            OuterConfig::Lookahead { eta: 1.0, beta: 0.1, signed: true }.name(),
            "signed_lookahead"
        );
    }

    #[test]
    fn wire_menus_match_the_contract() {
        let mv = OuterConfig::MvSignSgd { eta: 0.1, beta: 0.9, alpha: 0.1, bound: 10.0 };
        assert_eq!(mv.default_wire(), WireFormat::PackedSigns);
        assert_eq!(mv.supported_wires(), &[WireFormat::PackedSigns]);
        assert_eq!(mv.build(4).wire(), WireFormat::PackedSigns);
        // the concrete-SignMomentum accessor backs the Pallas fast path
        assert!(mv.build_sign_momentum(4).is_none());
        assert!(OuterConfig::sign_momentum_paper(1.0).build_sign_momentum(4).is_some());
        for cfg in [
            OuterConfig::sign_momentum_paper(1.0),
            OuterConfig::SlowMo { alpha: 1.0, beta: 0.5 },
            OuterConfig::LocalAvg,
        ] {
            assert_eq!(cfg.default_wire(), WireFormat::DenseF32, "{}", cfg.name());
            assert!(cfg.supported_wires().contains(&WireFormat::QuantizedI8), "{}", cfg.name());
            assert!(
                cfg.supported_wires().contains(&WireFormat::QuantizedI8PerTensor),
                "{}",
                cfg.name()
            );
            assert!(
                cfg.supported_wires().contains(&WireFormat::TOPK_DEFAULT),
                "{}",
                cfg.name()
            );
            assert_eq!(cfg.build(4).wire(), WireFormat::DenseF32, "{}", cfg.name());
        }
    }

    #[test]
    fn state_roundtrip_all_kinds() {
        for cfg in [
            OuterConfig::sign_momentum_paper(1.0),
            OuterConfig::SlowMo { alpha: 1.0, beta: 0.5 },
            OuterConfig::SignedSlowMo { eta: 1.0, beta: 0.5 },
            OuterConfig::GlobalAdamW {
                eta: 1.0,
                beta1: 0.9,
                beta2: 0.95,
                eps: 1e-8,
                weight_decay: 0.0,
            },
        ] {
            let mut a = cfg.build(8);
            let mut b = cfg.build(8);
            let mut ga = vec![0.5f32; 8];
            let diff = vec![0.01f32; 8];
            for r in 0..4 {
                run_synthetic_round(a.as_mut(), &mut ga, &diff, 0.1, r);
            }
            let saved: Vec<Vec<f32>> = a.state().iter().map(|s| s.to_vec()).collect();
            b.load_state(&saved);
            let mut gb = ga.clone();
            run_synthetic_round(a.as_mut(), &mut ga, &diff, 0.1, 4);
            run_synthetic_round(b.as_mut(), &mut gb, &diff, 0.1, 4);
            assert_eq!(ga, gb, "{}", a.name());
        }
    }

    /// Golden differential for the averaging plumbing every dense
    /// method shares: applying n payloads must equal applying ONE
    /// payload that holds their exact mean — i.e. the payload path
    /// reconstructs the same `x̄_{t,τ}` the trainer's historical
    /// `allreduce_mean` handed the old one-call API.
    #[test]
    fn dense_apply_equals_single_worker_at_the_mean() {
        use crate::dist::collectives;
        let d = 16;
        for cfg in [
            OuterConfig::sign_momentum_paper(2.0),
            OuterConfig::SlowMo { alpha: 1.0, beta: 0.5 },
            OuterConfig::SignedSlowMo { eta: 1.0, beta: 0.5 },
            OuterConfig::Lookahead { eta: 1.0, beta: 0.2, signed: false },
            OuterConfig::Lookahead { eta: 1.0, beta: 0.2, signed: true },
            OuterConfig::GlobalAdamW {
                eta: 0.1,
                beta1: 0.9,
                beta2: 0.95,
                eps: 1e-8,
                weight_decay: 0.1,
            },
            OuterConfig::LocalAvg,
        ] {
            let start: Vec<f32> = (0..d).map(|i| (i as f32 * 0.7).sin()).collect();
            let ends: Vec<Vec<f32>> = (0..3)
                .map(|w| (0..d).map(|i| start[i] - 0.01 * ((w + i) as f32).cos()).collect())
                .collect();
            let layout = crate::runtime::ParamLayout::single(d);
            let mut rng = crate::util::rng::Rng::new(5);

            // path A: n = 3 payloads through the contract
            let mut a = cfg.build(d);
            let mut payloads: Vec<WirePayload> =
                (0..3).map(|_| WirePayload::with_len(WireFormat::DenseF32, d)).collect();
            for (w, end) in ends.iter().enumerate() {
                let view = WorkerView { start: &start, end, last_grad: end, layout: &layout };
                a.contribute(w, 3, &view, &mut rng, &mut payloads[w]);
            }
            let ctx = RoundCtx { start: &start, gamma: 0.1, round: 0, agg: AggPolicy::Mean };
            let mut ga = start.clone();
            a.apply(&mut ga, &ctx, &payloads, &mut rng).unwrap();

            // path B: one payload holding the exact mean of the ends
            let mut mean = vec![0.0f32; d];
            collectives::allreduce_mean(&ends, |e| e.as_slice(), &mut mean);
            let mut b = cfg.build(d);
            let mut single = WirePayload::with_len(WireFormat::DenseF32, d);
            let view = WorkerView { start: &start, end: &mean, last_grad: &mean, layout: &layout };
            b.contribute(0, 1, &view, &mut rng, &mut single);
            let mut gb = start.clone();
            b.apply(&mut gb, &ctx, std::slice::from_ref(&single), &mut rng).unwrap();

            for (x, y) in ga.iter().zip(&gb) {
                assert_eq!(x.to_bits(), y.to_bits(), "{}", cfg.name());
            }
        }
    }

    /// The quantized payload paths run the same update off a slightly
    /// quantized average: the result must track the dense path within
    /// the quantization error, not bit-for-bit — for both the
    /// per-message and the per-tensor scale granularity.
    #[test]
    fn quantized_apply_tracks_dense_apply_for_dense_methods() {
        let d = 32;
        for cfg in [OuterConfig::SlowMo { alpha: 1.0, beta: 0.5 }, OuterConfig::LocalAvg] {
            let start: Vec<f32> = (0..d).map(|i| (i as f32 * 0.3).cos()).collect();
            let ends: Vec<Vec<f32>> = (0..4)
                .map(|w| (0..d).map(|i| start[i] - 0.05 * ((w + i) as f32).sin()).collect())
                .collect();
            let layout = crate::runtime::ParamLayout::single(d);
            let run = |format: WireFormat| -> Vec<f32> {
                let mut opt = cfg.build(d);
                let mut rng = crate::util::rng::Rng::new(11);
                let mut payloads: Vec<WirePayload> =
                    (0..4).map(|_| WirePayload::with_len(format, d)).collect();
                for (w, end) in ends.iter().enumerate() {
                    let view =
                        WorkerView { start: &start, end, last_grad: end, layout: &layout };
                    opt.contribute(w, 4, &view, &mut rng, &mut payloads[w]);
                }
                let ctx = RoundCtx { start: &start, gamma: 0.1, round: 0, agg: AggPolicy::Mean };
                let mut g = start.clone();
                opt.apply(&mut g, &ctx, &payloads, &mut rng).unwrap();
                g
            };
            let dense = run(WireFormat::DenseF32);
            // max quantization error per rank: scale/2 = max|diff|/254
            // ≈ 2e-4 here; SlowMo amplifies by alpha = 1. A full-budget
            // topk payload transmits every coordinate exactly, so its
            // only deviation is the f64 mean's final f32 rounding.
            let full_topk = WireFormat::TopK { frac_ppm: 1_000_000, decay_ppm: 0 };
            for format in [WireFormat::QuantizedI8, WireFormat::QuantizedI8PerTensor, full_topk] {
                let quant = run(format);
                for (j, (a, b)) in dense.iter().zip(&quant).enumerate() {
                    assert!(
                        (a - b).abs() < 5e-3,
                        "{} over {}: coord {j}: {a} vs {b}",
                        cfg.name(),
                        format.name()
                    );
                }
            }
        }
    }

    /// A budget-limited topk exchange transmits the largest residual
    /// components and still descends: the untransmitted mass is not an
    /// error term that compounds silently, it waits (decayed) in the
    /// worker's residual buffer for a later round.
    #[test]
    fn topk_apply_descends_with_a_partial_budget() {
        // keep 1 in 4 coordinates per round
        let topk = WireFormat::TopK { frac_ppm: 250_000, decay_ppm: 900_000 };
        let d = 16;
        let cfg = OuterConfig::LocalAvg;
        let mut opt = cfg.build(d);
        let mut rng = crate::util::rng::Rng::new(3);
        let layout = crate::runtime::ParamLayout::single(d);
        let mut global = vec![1.0f32; d];
        let mut payloads: Vec<WirePayload> =
            (0..2).map(|_| WirePayload::with_len(topk, d)).collect();
        for round in 0..6 {
            let start = global.clone();
            // both workers keep descending every coordinate by 0.05
            let end: Vec<f32> = start.iter().map(|s| s - 0.05).collect();
            for (w, p) in payloads.iter_mut().enumerate() {
                let view =
                    WorkerView { start: &start, end: &end, last_grad: &end, layout: &layout };
                opt.contribute(w, 2, &view, &mut rng, p);
            }
            let ctx = RoundCtx { start: &start, gamma: 0.1, round, agg: AggPolicy::Mean };
            opt.apply(&mut global, &ctx, &payloads, &mut rng).unwrap();
        }
        // six rounds of k = 4-of-16 cover every coordinate; all moved
        assert!(global.iter().all(|&x| x < 1.0), "{global:?}");
    }
}
