//! Outer (global, per-communication-round) optimizers.
//!
//! This module is the paper's system contribution.  After each worker
//! runs τ local steps of its base optimizer, the trainer aggregates and
//! hands this module the round context; the outer optimizer transforms
//! the accumulated local differences into a global update:
//!
//! * [`SignMomentum`] — **Algorithm 1**, the paper's method: a Lion-style
//!   sign-momentum step over pseudo-gradients (eqs. 6-8).
//! * [`SlowMo`] — Wang et al. 2019 (paper's Algorithm 5), the main baseline.
//! * [`SignedSlowMo`] — §4.1 ablation: sign *inside* the momentum.
//! * [`Lookahead`] / signed Lookahead — n=1 ablations (Tables 4-5).
//! * [`GlobalAdamW`] — Algorithm 7 ablation (adaptive global step).
//! * [`LocalAvg`] — plain periodic parameter averaging ("Local AdamW").
//! * [`MvSignSgd`] — Federated MV-sto-signSGD-SIM (Algorithm 6), the
//!   related method of Sun et al. 2023 discussed in Remarks 1-2.
//!
//! All operate on the flat `f32[P]` vector; every implementation is
//! cross-checked against the jnp/Pallas references where one exists
//! (rust/tests/equivalence.rs, python kernels/ref.py).

mod global_adamw;
mod local_avg;
mod lookahead;
mod mv_signsgd;
mod sign_momentum;
mod slowmo;

pub use global_adamw::GlobalAdamW;
pub use local_avg::LocalAvg;
pub use lookahead::Lookahead;
pub use mv_signsgd::MvSignSgd;
pub use sign_momentum::SignMomentum;
pub use slowmo::{SignedSlowMo, SlowMo};

use crate::dist::votes::PackedVotes;
use crate::sign::SignOp;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Everything an outer optimizer may consume at a communication round.
pub struct RoundCtx<'a> {
    /// x_{t,0}: global parameters at the start of the round.
    pub start: &'a [f32],
    /// x_{t,τ} = (1/n) Σ_i x_{t,τ}^{(i)}: exact average of worker ends.
    pub avg_end: &'a [f32],
    /// Per-worker end parameters x_{t,τ}^{(i)} (majority-vote methods).
    pub worker_end: &'a [&'a [f32]],
    /// Per-worker last local stochastic gradient (Algorithm 6's momentum).
    pub worker_last_grad: &'a [&'a [f32]],
    /// γ_t: local learning rate in effect this round (schedules vary it).
    pub gamma: f32,
    /// Outer round index t.
    pub round: u64,
}

/// Context for the packed 1-bit exchange
/// ([`OuterOptimizer::round_packed`]). Unlike [`RoundCtx`] there is no
/// f32 aggregate: the round's only worker→server payload is the packed
/// votes themselves, so nothing else exists server-side to hand over.
pub struct PackedRoundCtx<'a> {
    /// The round's start point — what [`OuterOptimizer::local_start`]
    /// returned (the global iterate itself, or e.g. MV-sto-signSGD's
    /// extrapolated y_t).
    pub start: &'a [f32],
    /// γ_t: local learning rate in effect this round.
    pub gamma: f32,
    /// Outer round index t.
    pub round: u64,
}

pub trait OuterOptimizer: Send {
    /// Apply the global step, updating `global` (== ctx.start on entry).
    fn round(&mut self, global: &mut [f32], ctx: &RoundCtx, rng: &mut Rng);

    /// Starting point handed to workers for the *next* local phase.
    /// Default: the global iterate itself.  MV-sto-signSGD overrides this
    /// with its extrapolated y_t = x_t + α (x_t - x_{t-1}).
    fn local_start(&mut self, global: &[f32]) -> Vec<f32> {
        global.to_vec()
    }

    fn name(&self) -> &'static str;

    /// True when this optimizer's round exchange is 1-bit sign traffic
    /// (worker→server majority-vote votes, Algorithm 6) rather than
    /// full-precision parameters. The trainer then routes the round
    /// through the packed data path — [`make_votes`](Self::make_votes)
    /// per rank, then [`round_packed`](Self::round_packed) — and
    /// charges the packed wire cost
    /// ([`crate::comm::SimClock::charge_sign_allreduce`], backed by
    /// [`crate::dist::codec`]) instead of 4 bytes per f32.
    ///
    /// Returning `true` **obligates** implementing
    /// [`make_votes`](Self::make_votes) and
    /// [`round_packed`](Self::round_packed): billing 1-bit traffic
    /// while exchanging f32 votes is exactly the accounting/data-path
    /// divergence the packed path exists to close, so the defaults
    /// fail fast (panic naming the optimizer) rather than silently
    /// falling back to the f32 wire.
    fn sign_compressed_comm(&self) -> bool {
        false
    }

    /// Worker-side half of the packed 1-bit exchange (only called when
    /// [`sign_compressed_comm`](Self::sign_compressed_comm) is true):
    /// fold rank `worker`'s last local stochastic gradient into its
    /// local state and pack the randomized-sign vote that crosses the
    /// simulated wire into `out` — a persistent per-rank buffer the
    /// trainer owns and re-passes every round, so the steady-state
    /// packed path allocates nothing
    /// ([`PackedVotes::pack_into`](crate::dist::PackedVotes::pack_into)).
    /// The trainer calls this once per rank, in rank order, before
    /// [`round_packed`](Self::round_packed).
    fn make_votes(
        &mut self,
        worker: usize,
        n_workers: usize,
        last_grad: &[f32],
        rng: &mut Rng,
        out: &mut PackedVotes,
    ) {
        let _ = (worker, n_workers, last_grad, rng, out);
        unimplemented!("{}: no packed-vote data path", self.name())
    }

    /// Server-side half of the packed exchange: majority-tally `votes`
    /// word-level ([`crate::dist::votes::majority_vote_packed`]) and
    /// apply the global step to `global` (== ctx.start on entry).
    /// Must be bitwise-identical to routing the same votes through
    /// [`round`](Self::round)'s f32 reference path.
    fn round_packed(
        &mut self,
        global: &mut [f32],
        ctx: &PackedRoundCtx,
        votes: &[PackedVotes],
        rng: &mut Rng,
    ) {
        let _ = (global, ctx, votes, rng);
        unimplemented!("{}: no packed-vote data path", self.name())
    }

    /// Flat state buffers for checkpointing.
    fn state(&self) -> Vec<&[f32]>;
    fn load_state(&mut self, bufs: &[Vec<f32>]);
}

/// Construction-time description of an outer optimizer (config file /
/// CLI / experiment harness).
#[derive(Clone, Debug, PartialEq)]
pub enum OuterConfig {
    /// Algorithm 1 with Lion-recommended defaults (§4: β1=0.95, β2=0.98, λ=0.1).
    SignMomentum {
        eta: f32,
        beta1: f32,
        beta2: f32,
        weight_decay: f32,
        sign_op: SignOp,
        sign_bound: f32,
    },
    SlowMo { alpha: f32, beta: f32 },
    SignedSlowMo { eta: f32, beta: f32 },
    /// β1=β2=β, λ=0, unsigned update (Table 4) or signed (Table 5).
    Lookahead { eta: f32, beta: f32, signed: bool },
    GlobalAdamW { eta: f32, beta1: f32, beta2: f32, eps: f32, weight_decay: f32 },
    LocalAvg,
    MvSignSgd { eta: f32, beta: f32, alpha: f32, bound: f32 },
}

impl OuterConfig {
    pub fn sign_momentum_paper(eta: f32) -> Self {
        OuterConfig::SignMomentum {
            eta,
            beta1: 0.95,
            beta2: 0.98,
            weight_decay: 0.1,
            sign_op: SignOp::Exact,
            sign_bound: 1.0,
        }
    }

    pub fn slowmo_paper(alpha: f32, beta: f32) -> Self {
        OuterConfig::SlowMo { alpha, beta }
    }

    pub fn build(&self, dim: usize) -> Box<dyn OuterOptimizer> {
        match *self {
            OuterConfig::SignMomentum { eta, beta1, beta2, weight_decay, sign_op, sign_bound } => {
                Box::new(SignMomentum::new(
                    dim,
                    eta,
                    beta1,
                    beta2,
                    weight_decay,
                    sign_op,
                    sign_bound,
                ))
            }
            OuterConfig::SlowMo { alpha, beta } => Box::new(SlowMo::new(dim, alpha, beta)),
            OuterConfig::SignedSlowMo { eta, beta } => Box::new(SignedSlowMo::new(dim, eta, beta)),
            OuterConfig::Lookahead { eta, beta, signed } => {
                Box::new(Lookahead::new(dim, eta, beta, signed))
            }
            OuterConfig::GlobalAdamW { eta, beta1, beta2, eps, weight_decay } => {
                Box::new(GlobalAdamW::new(dim, eta, beta1, beta2, eps, weight_decay))
            }
            OuterConfig::LocalAvg => Box::new(LocalAvg::new()),
            OuterConfig::MvSignSgd { eta, beta, alpha, bound } => {
                Box::new(MvSignSgd::new(dim, eta, beta, alpha, bound))
            }
        }
    }

    /// Parse from a `[outer]` config table.
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let algo = v.get("algo").and_then(Json::as_str).ok_or("outer table needs `algo`")?;
        let f = |key: &str, default: f32| -> f32 {
            v.get(key).and_then(Json::as_f64).map(|x| x as f32).unwrap_or(default)
        };
        Ok(match algo {
            "sign_momentum" | "algorithm1" => OuterConfig::SignMomentum {
                eta: f("global_lr", 1.0),
                beta1: f("beta1", 0.95),
                beta2: f("beta2", 0.98),
                weight_decay: f("weight_decay", 0.1),
                sign_op: v
                    .get("sign_op")
                    .and_then(Json::as_str)
                    .and_then(SignOp::parse)
                    .unwrap_or(SignOp::Exact),
                sign_bound: f("sign_bound", 1.0),
            },
            "slowmo" => OuterConfig::SlowMo { alpha: f("global_lr", 1.0), beta: f("beta", 0.5) },
            "signed_slowmo" => {
                OuterConfig::SignedSlowMo { eta: f("global_lr", 1.0), beta: f("beta", 0.5) }
            }
            "lookahead" => OuterConfig::Lookahead {
                eta: f("global_lr", 1.0),
                beta: f("beta", 0.2),
                signed: v.get("signed").and_then(Json::as_bool).unwrap_or(false),
            },
            "global_adamw" => OuterConfig::GlobalAdamW {
                eta: f("global_lr", 1.0),
                beta1: f("beta1", 0.9),
                beta2: f("beta2", 0.95),
                eps: f("eps", 1e-8),
                weight_decay: f("weight_decay", 0.1),
            },
            "local_avg" => OuterConfig::LocalAvg,
            "mv_signsgd" => OuterConfig::MvSignSgd {
                eta: f("global_lr", 1e-3),
                beta: f("beta", 0.9),
                alpha: f("alpha", 0.1),
                bound: f("bound", 10.0),
            },
            other => return Err(format!("unknown outer optimizer `{other}`")),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            OuterConfig::SignMomentum { .. } => "sign_momentum",
            OuterConfig::SlowMo { .. } => "slowmo",
            OuterConfig::SignedSlowMo { .. } => "signed_slowmo",
            OuterConfig::Lookahead { signed: false, .. } => "lookahead",
            OuterConfig::Lookahead { signed: true, .. } => "signed_lookahead",
            OuterConfig::GlobalAdamW { .. } => "global_adamw",
            OuterConfig::LocalAvg => "local_avg",
            OuterConfig::MvSignSgd { .. } => "mv_signsgd",
        }
    }
}

/// Drive one outer round on a synthetic context where the averaged local
/// difference is `diff` (workers ended at start - diff).  Shared by unit
/// tests here and the cross-implementation equivalence suite.
pub fn run_synthetic_round(
    opt: &mut dyn OuterOptimizer,
    global: &mut Vec<f32>,
    diff: &[f32],
    gamma: f32,
    round: u64,
) {
    let start = global.clone();
    let avg_end: Vec<f32> = start.iter().zip(diff).map(|(&s, &d)| s - d).collect();
    let worker_end: Vec<&[f32]> = vec![&avg_end];
    // expose the applied difference as the "last local gradient" so
    // gradient-momentum methods (Alg. 6) also see a consistent signal
    let worker_last_grad: Vec<&[f32]> = vec![diff];
    let ctx = RoundCtx {
        start: &start,
        avg_end: &avg_end,
        worker_end: &worker_end,
        worker_last_grad: &worker_last_grad,
        gamma,
        round,
    };
    let mut rng = Rng::new(round ^ 0xABCD);
    opt.round(global, &ctx, &mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::toml;

    #[test]
    fn build_all_kinds_and_descend() {
        let configs = [
            OuterConfig::sign_momentum_paper(1.0),
            OuterConfig::SlowMo { alpha: 1.0, beta: 0.5 },
            OuterConfig::SignedSlowMo { eta: 1.0, beta: 0.5 },
            OuterConfig::Lookahead { eta: 1.0, beta: 0.2, signed: false },
            OuterConfig::Lookahead { eta: 1.0, beta: 0.2, signed: true },
            OuterConfig::GlobalAdamW {
                eta: 1.0,
                beta1: 0.9,
                beta2: 0.95,
                eps: 1e-8,
                weight_decay: 0.0,
            },
            OuterConfig::LocalAvg,
            // bound == |pseudo-grad| makes the randomized vote deterministic
            // here (a single synthetic worker would otherwise coin-flip —
            // exactly the Remark-2 neighborhood effect).
            OuterConfig::MvSignSgd { eta: 0.1, beta: 0.9, alpha: 0.1, bound: 0.0101 },
        ];
        for cfg in configs {
            let mut opt = cfg.build(4);
            let mut global = vec![1.0f32; 4];
            // positive accumulated difference = descent direction
            run_synthetic_round(opt.as_mut(), &mut global, &[0.1, 0.1, 0.1, 0.1], 0.1, 0);
            assert!(
                global.iter().all(|&x| x < 1.0),
                "{}: {global:?} did not move down",
                opt.name()
            );
        }
    }

    #[test]
    fn from_json_roundtrip() {
        let t = toml::parse(
            "algo = \"sign_momentum\"\nglobal_lr = 1.2\nbeta1 = 0.9\nsign_op = \"rand_pm\"\n",
        )
        .unwrap();
        let cfg = OuterConfig::from_json(&t).unwrap();
        match cfg {
            OuterConfig::SignMomentum { eta, beta1, beta2, sign_op, .. } => {
                assert_eq!(eta, 1.2);
                assert_eq!(beta1, 0.9);
                assert_eq!(beta2, 0.98); // default
                assert_eq!(sign_op, SignOp::RandPm);
            }
            other => panic!("{other:?}"),
        }
        assert!(OuterConfig::from_json(&toml::parse("algo = \"zzz\"").unwrap()).is_err());
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(OuterConfig::LocalAvg.name(), "local_avg");
        assert_eq!(
            OuterConfig::Lookahead { eta: 1.0, beta: 0.1, signed: true }.name(),
            "signed_lookahead"
        );
    }

    #[test]
    fn state_roundtrip_all_kinds() {
        for cfg in [
            OuterConfig::sign_momentum_paper(1.0),
            OuterConfig::SlowMo { alpha: 1.0, beta: 0.5 },
            OuterConfig::SignedSlowMo { eta: 1.0, beta: 0.5 },
            OuterConfig::GlobalAdamW {
                eta: 1.0,
                beta1: 0.9,
                beta2: 0.95,
                eps: 1e-8,
                weight_decay: 0.0,
            },
        ] {
            let mut a = cfg.build(8);
            let mut b = cfg.build(8);
            let mut ga = vec![0.5f32; 8];
            let diff = vec![0.01f32; 8];
            for r in 0..4 {
                run_synthetic_round(a.as_mut(), &mut ga, &diff, 0.1, r);
            }
            let saved: Vec<Vec<f32>> = a.state().iter().map(|s| s.to_vec()).collect();
            b.load_state(&saved);
            let mut gb = ga.clone();
            run_synthetic_round(a.as_mut(), &mut ga, &diff, 0.1, 4);
            run_synthetic_round(b.as_mut(), &mut gb, &diff, 0.1, 4);
            assert_eq!(ga, gb, "{}", a.name());
        }
    }
}
