//! Federated MV-sto-signSGD-SIM — the paper's Algorithm 6 (Sun et al.
//! 2023), the closest prior method (Remarks 1-2).
//!
//! Structure per outer round t:
//!   y_t          = x_t + α (x_t - x_{t-1})          (outer extrapolation)
//!   workers run τ SGD steps from y_t, ending at y_t^{(i)}
//!   m_{t+1}^{(i)} = β m_t^{(i)} + (1-β) ∇f_i(y_t^{(i)}, ξ)   (LOCAL grad momentum)
//!   x_{t+1}      = x_t - η MV( S_r(m_{t+1}^{(i)}) )          (majority vote)
//!
//! The contrasts with Algorithm 1 that Remark 1 highlights are all here:
//! momentum is built from local stochastic *gradients* (not aggregated
//! local differences), and worker→server communication is 1-bit via the
//! randomized sign S_r (eq. 9) + majority vote, which is why it only
//! converges to an O(dR/√n) neighborhood (Remark 2).
//!
//! # Wire semantics
//!
//! Votes really are 1-bit here: [`MvSignSgd::make_votes`] packs each
//! rank's randomized signs ([`PackedVotes`]) and
//! [`MvSignSgd::round_packed`] tallies the packed words without ever
//! unpacking ([`votes::majority_vote_packed`]). Two consequences of the
//! wire having no zero symbol: `S_r(0)` keeps the IEEE sign of its ±0
//! output — a fair ±1 coin, exactly eq. (9) at v = 0 — and a tied
//! majority decodes to +1, so the iterate always moves by η per
//! coordinate. The f32 reference path ([`MvSignSgd::round`]) shares
//! this code and is bitwise-identical by construction.

use super::{OuterOptimizer, PackedRoundCtx, RoundCtx};
use crate::dist::votes::{self, PackedVotes};
use crate::sign::SignOp;
use crate::util::rng::Rng;

pub struct MvSignSgd {
    eta: f32,
    beta: f32,
    alpha: f32,
    /// Norm bound B for the randomized sign operator (Alg. 6 requires the
    /// uniform stochastic-gradient bound).
    bound: f32,
    /// Per-worker momentum buffers m^{(i)}, created lazily at first round
    /// (worker count is only known then).
    m: Vec<Vec<f32>>,
    x_prev: Vec<f32>,
    /// Dim-sized scratch reused across ranks and rounds: the
    /// randomized-sign output in `fold_and_sign`, the decoded winner in
    /// `apply_packed` (not checkpointed — overwritten before every use).
    scratch: Vec<f32>,
    /// Persistent per-rank packed vote buffers for the f32 reference
    /// path (`round`): reused every round via [`PackedVotes::pack_into`],
    /// so the steady state allocates nothing. Not checkpointed — fully
    /// overwritten before every tally. (On the packed wire path the
    /// trainer owns the equivalent persistent buffers.)
    packed: Vec<PackedVotes>,
    dim: usize,
}

impl MvSignSgd {
    pub fn new(dim: usize, eta: f32, beta: f32, alpha: f32, bound: f32) -> Self {
        MvSignSgd {
            eta,
            beta,
            alpha,
            bound,
            m: Vec::new(),
            x_prev: vec![0.0; dim],
            scratch: vec![0.0; dim],
            packed: Vec::new(),
            dim,
        }
    }

    /// Lazily size the per-worker momentum buffers.
    fn ensure_workers(&mut self, n: usize) {
        assert!(n > 0);
        if self.m.is_empty() {
            self.m = vec![vec![0.0; self.dim]; n];
        }
        assert_eq!(self.m.len(), n, "worker count changed mid-run");
    }

    /// Worker-side half of vote production: fold the rank's last
    /// stochastic gradient into its momentum and apply the randomized
    /// sign S_r into `self.scratch` (packing is the caller's step, so
    /// the destination buffer can be caller-owned and persistent).
    fn fold_and_sign(&mut self, worker: usize, grad: &[f32], rng: &mut Rng) {
        assert_eq!(grad.len(), self.dim, "worker {worker}: gradient length");
        let m = &mut self.m[worker];
        for (mi, &g) in m.iter_mut().zip(grad) {
            *mi = self.beta * *mi + (1.0 - self.beta) * g;
        }
        SignOp::RandPm.apply_into(&mut self.scratch, m, self.bound, rng);
    }
}

/// Server-side step: word-level majority tally over the packed votes
/// into `winner`, then a step of -η · winner from the round's start
/// point. A free function over the individual buffers so both the f32
/// reference path (tallying `self.packed`) and the trainer's packed
/// wire path (tallying external votes) can borrow `MvSignSgd`'s fields
/// disjointly.
/// NOTE: `start` is what `local_start` produced — y_t when α > 0 —
/// so with extrapolation the update and the stored x_prev anchor at
/// y_t rather than x_t. This preserves the seed's semantics
/// bit-for-bit; auditing it against Algorithm 6's exact recursion
/// is ROADMAP follow-up (g).
fn apply_packed(
    global: &mut [f32],
    start: &[f32],
    packed: &[PackedVotes],
    winner: &mut [f32],
    x_prev: &mut [f32],
    eta: f32,
) {
    votes::majority_vote_packed(packed, winner);
    x_prev.copy_from_slice(start);
    for ((g, &x), &w) in global.iter_mut().zip(start).zip(winner.iter()) {
        *g = x - eta * w;
    }
}

impl OuterOptimizer for MvSignSgd {
    /// f32 reference path: produce every rank's vote locally, then run
    /// the identical packed tally — `round` and the trainer's
    /// `make_votes`/`round_packed` split execute the same code in the
    /// same order, so the two paths are bitwise-identical.
    fn round(&mut self, global: &mut [f32], ctx: &RoundCtx, rng: &mut Rng) {
        let n = ctx.worker_last_grad.len();
        self.ensure_workers(n);
        if self.packed.len() != n {
            self.packed = vec![PackedVotes::empty(); n];
        }
        for (w, grad) in ctx.worker_last_grad.iter().enumerate() {
            self.fold_and_sign(w, grad, rng);
            self.packed[w].pack_into(&self.scratch);
        }
        apply_packed(
            global,
            ctx.start,
            &self.packed,
            &mut self.scratch,
            &mut self.x_prev,
            self.eta,
        );
    }

    fn make_votes(
        &mut self,
        worker: usize,
        n_workers: usize,
        last_grad: &[f32],
        rng: &mut Rng,
        out: &mut PackedVotes,
    ) {
        self.ensure_workers(n_workers);
        self.fold_and_sign(worker, last_grad, rng);
        out.pack_into(&self.scratch);
    }

    fn round_packed(
        &mut self,
        global: &mut [f32],
        ctx: &PackedRoundCtx,
        votes: &[PackedVotes],
        _rng: &mut Rng,
    ) {
        self.ensure_workers(votes.len());
        apply_packed(global, ctx.start, votes, &mut self.scratch, &mut self.x_prev, self.eta);
    }

    fn local_start(&mut self, global: &[f32]) -> Vec<f32> {
        if self.m.is_empty() {
            // round 0: x_{-1} = x_0 ⇒ y_0 = x_0
            return global.to_vec();
        }
        global
            .iter()
            .zip(&self.x_prev)
            .map(|(&x, &xp)| x + self.alpha * (x - xp))
            .collect()
    }

    fn name(&self) -> &'static str {
        "mv_signsgd"
    }

    /// Algorithm 6's worker→server traffic is the randomized sign votes
    /// — 1 bit per coordinate on the wire (Remark 1). The trainer
    /// routes rounds through `make_votes`/`round_packed` and charges
    /// the packed payload instead of f32 parameters.
    fn sign_compressed_comm(&self) -> bool {
        true
    }

    fn state(&self) -> Vec<&[f32]> {
        let mut out: Vec<&[f32]> = vec![&self.x_prev];
        for m in &self.m {
            out.push(m);
        }
        out
    }

    fn load_state(&mut self, bufs: &[Vec<f32>]) {
        self.x_prev.copy_from_slice(&bufs[0]);
        self.m = bufs[1..].to_vec();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx_with_grads<'a>(
        start: &'a [f32],
        grads: &'a [&'a [f32]],
        ends: &'a [&'a [f32]],
        avg: &'a [f32],
        round: u64,
    ) -> RoundCtx<'a> {
        RoundCtx {
            start,
            avg_end: avg,
            worker_end: ends,
            worker_last_grad: grads,
            gamma: 0.1,
            round,
        }
    }

    #[test]
    fn unanimous_vote_moves_by_eta() {
        let mut opt = MvSignSgd::new(3, 0.5, 0.0, 0.0, 10.0);
        let mut global = vec![0.0f32; 3];
        let start = global.clone();
        // all workers see strong positive gradients on coord 0, negative on 1,
        // zero on 2 (bound >> |g| keeps the randomized flip probability low
        // but with 8 workers the vote is still decisively correct).
        let grads_own = vec![vec![9.9f32, -9.9, 0.0]; 8];
        let grads: Vec<&[f32]> = grads_own.iter().map(|g| g.as_slice()).collect();
        let ends: Vec<&[f32]> = (0..8).map(|_| start.as_slice()).collect();
        let mut rng = Rng::new(3);
        opt.round(&mut global, &ctx_with_grads(&start, &grads, &ends, &start, 0), &mut rng);
        assert_eq!(global[0], -0.5);
        assert_eq!(global[1], 0.5);
        // coord 2: m = 0 -> S_r(0) is a fair ±1 coin on the wire (the
        // 1-bit format has no zero symbol), so the iterate moves by a
        // full ±η — it can never sit still under wire semantics.
        assert_eq!(global[2].abs(), 0.5);
    }

    #[test]
    fn tie_decodes_to_plus_one_on_both_paths() {
        // |m| == bound makes S_r deterministic: two workers with exactly
        // opposite momenta produce an exact 1-1 tie on every coordinate.
        // The wire has no zero symbol, so the tally decodes +1 and the
        // iterate moves by -η (the old f32 path would have sat still).
        let eta = 0.25f32;
        let grads_own = vec![vec![1.0f32, 1.0], vec![-1.0f32, -1.0]];
        let grads: Vec<&[f32]> = grads_own.iter().map(|g| g.as_slice()).collect();
        let start = vec![1.0f32, -1.0];
        let ends: Vec<&[f32]> = (0..2).map(|_| start.as_slice()).collect();

        // path 1: the f32 reference round
        let mut a = MvSignSgd::new(2, eta, 0.0, 0.0, 1.0);
        let mut ga = start.clone();
        let mut rng_a = Rng::new(11);
        a.round(&mut ga, &ctx_with_grads(&start, &grads, &ends, &start, 0), &mut rng_a);
        assert_eq!(ga, vec![1.0 - eta, -1.0 - eta]);

        // path 2: the packed make_votes/round_packed split
        let mut b = MvSignSgd::new(2, eta, 0.0, 0.0, 1.0);
        let mut gb = start.clone();
        let mut rng_b = Rng::new(11);
        let mut votes = vec![PackedVotes::empty(); 2];
        for w in 0..2 {
            b.make_votes(w, 2, &grads_own[w], &mut rng_b, &mut votes[w]);
        }
        let ctx = PackedRoundCtx { start: &start, gamma: 0.1, round: 0 };
        b.round_packed(&mut gb, &ctx, &votes, &mut rng_b);
        assert_eq!(gb, ga);
    }

    #[test]
    fn round_and_packed_split_agree_bitwise() {
        // dim deliberately not a multiple of 8 or 64
        let dim = 37;
        let n = 3;
        let start: Vec<f32> = (0..dim).map(|i| (i as f32).sin()).collect();
        let grads_own: Vec<Vec<f32>> = (0..n)
            .map(|w| (0..dim).map(|i| ((w * dim + i) as f32).cos() * 3.0).collect())
            .collect();
        let grads: Vec<&[f32]> = grads_own.iter().map(|g| g.as_slice()).collect();
        let ends: Vec<&[f32]> = (0..n).map(|_| start.as_slice()).collect();

        let mut a = MvSignSgd::new(dim, 0.3, 0.5, 0.0, 4.0);
        let mut ga = start.clone();
        let mut rng_a = Rng::new(99);
        a.round(&mut ga, &ctx_with_grads(&start, &grads, &ends, &start, 0), &mut rng_a);

        let mut b = MvSignSgd::new(dim, 0.3, 0.5, 0.0, 4.0);
        let mut gb = start.clone();
        let mut rng_b = Rng::new(99);
        let mut votes = vec![PackedVotes::empty(); n];
        for w in 0..n {
            b.make_votes(w, n, &grads_own[w], &mut rng_b, &mut votes[w]);
        }
        let ctx = PackedRoundCtx { start: &start, gamma: 0.1, round: 0 };
        b.round_packed(&mut gb, &ctx, &votes, &mut rng_b);

        assert_eq!(ga, gb);
        // and the two optimizers carry identical state forward
        assert_eq!(a.x_prev, b.x_prev);
        assert_eq!(a.m, b.m);
    }

    #[test]
    fn extrapolation_kicks_in_after_first_round() {
        let mut opt = MvSignSgd::new(1, 1.0, 0.0, 0.5, 10.0);
        let mut global = vec![4.0f32];
        let start = global.clone();
        assert_eq!(opt.local_start(&global), vec![4.0]); // y_0 = x_0
        let grads_own = vec![vec![9.9f32]; 4];
        let grads: Vec<&[f32]> = grads_own.iter().map(|g| g.as_slice()).collect();
        let ends: Vec<&[f32]> = (0..4).map(|_| start.as_slice()).collect();
        let mut rng = Rng::new(1);
        opt.round(&mut global, &ctx_with_grads(&start, &grads, &ends, &start, 0), &mut rng);
        assert_eq!(global, vec![3.0]); // 4 - 1
        // y_1 = x_1 + 0.5 (x_1 - x_0) = 3 + 0.5*(-1) = 2.5
        assert_eq!(opt.local_start(&global), vec![2.5]);
    }

    #[test]
    fn majority_vote_suppresses_minority_noise() {
        // 7 workers say +, 1 worker says - strongly: update must follow +.
        let mut opt = MvSignSgd::new(1, 0.1, 0.0, 0.0, 10.0);
        let mut global = vec![0.0f32];
        let start = global.clone();
        let mut grads_own = vec![vec![9.5f32]; 7];
        grads_own.push(vec![-9.5f32]);
        let grads: Vec<&[f32]> = grads_own.iter().map(|g| g.as_slice()).collect();
        let ends: Vec<&[f32]> = (0..8).map(|_| start.as_slice()).collect();
        let mut rng = Rng::new(7);
        opt.round(&mut global, &ctx_with_grads(&start, &grads, &ends, &start, 0), &mut rng);
        assert_eq!(global[0], -0.1);
    }

    #[test]
    fn reports_sign_compressed_communication() {
        let opt = MvSignSgd::new(4, 0.1, 0.9, 0.1, 10.0);
        assert!(opt.sign_compressed_comm());
        // the default for every other outer optimizer is full-precision
        let sm = crate::outer::OuterConfig::sign_momentum_paper(1.0).build(4);
        assert!(!sm.sign_compressed_comm());
    }

    #[test]
    fn momentum_buffers_are_per_worker() {
        let mut opt = MvSignSgd::new(1, 0.1, 0.9, 0.0, 10.0);
        let mut global = vec![0.0f32];
        let start = global.clone();
        let grads_own = vec![vec![1.0f32], vec![-1.0f32]];
        let grads: Vec<&[f32]> = grads_own.iter().map(|g| g.as_slice()).collect();
        let ends: Vec<&[f32]> = (0..2).map(|_| start.as_slice()).collect();
        let mut rng = Rng::new(0);
        opt.round(&mut global, &ctx_with_grads(&start, &grads, &ends, &start, 0), &mut rng);
        assert!((opt.m[0][0] - 0.1).abs() < 1e-6);
        assert!((opt.m[1][0] + 0.1).abs() < 1e-6);
    }
}
