//! Federated MV-sto-signSGD-SIM — the paper's Algorithm 6 (Sun et al.
//! 2023), the closest prior method (Remarks 1-2).
//!
//! Structure per outer round t:
//!   y_t          = x_t + α (x_t - x_{t-1})          (outer extrapolation)
//!   workers run τ SGD steps from y_t, ending at y_t^{(i)}
//!   m_{t+1}^{(i)} = β m_t^{(i)} + (1-β) ∇f_i(y_t^{(i)}, ξ)   (LOCAL grad momentum)
//!   x_{t+1}      = x_t - η sign( Σ_i S_r(m_{t+1}^{(i)}) )    (majority vote)
//!
//! The contrasts with Algorithm 1 that Remark 1 highlights are all here:
//! momentum is built from local stochastic *gradients* (not aggregated
//! local differences), and worker→server communication is 1-bit via the
//! randomized sign S_r (eq. 9) + majority vote, which is why it only
//! converges to an O(dR/√n) neighborhood (Remark 2).

use super::{OuterOptimizer, RoundCtx};
use crate::sign::SignOp;
use crate::tensor::sign_f32;
use crate::util::rng::Rng;

pub struct MvSignSgd {
    eta: f32,
    beta: f32,
    alpha: f32,
    /// Norm bound B for the randomized sign operator (Alg. 6 requires the
    /// uniform stochastic-gradient bound).
    bound: f32,
    /// Per-worker momentum buffers m^{(i)}, created lazily at first round
    /// (worker count is only known then).
    m: Vec<Vec<f32>>,
    x_prev: Vec<f32>,
    dim: usize,
}

impl MvSignSgd {
    pub fn new(dim: usize, eta: f32, beta: f32, alpha: f32, bound: f32) -> Self {
        MvSignSgd { eta, beta, alpha, bound, m: Vec::new(), x_prev: vec![0.0; dim], dim }
    }
}

impl OuterOptimizer for MvSignSgd {
    fn round(&mut self, global: &mut [f32], ctx: &RoundCtx, rng: &mut Rng) {
        let n = ctx.worker_last_grad.len();
        assert!(n > 0);
        if self.m.is_empty() {
            self.m = vec![vec![0.0; self.dim]; n];
            self.x_prev = ctx.start.to_vec();
        }
        assert_eq!(self.m.len(), n, "worker count changed mid-run");

        // local momentum update + randomized-sign vote accumulation
        let mut vote = vec![0.0f32; self.dim];
        let mut signs = vec![0.0f32; self.dim];
        for (w, grad) in ctx.worker_last_grad.iter().enumerate() {
            let m = &mut self.m[w];
            for i in 0..self.dim {
                m[i] = self.beta * m[i] + (1.0 - self.beta) * grad[i];
            }
            SignOp::RandPm.apply_into(&mut signs, m, self.bound, rng);
            for i in 0..self.dim {
                vote[i] += signs[i];
            }
        }

        // x_{t+1} = x_t - η sign(vote); note x_t here is the un-extrapolated
        // iterate: `global` holds x_t (local_start produced y_t separately).
        let x_t = ctx.start; // == x_t by construction of the trainer loop
        for i in 0..self.dim {
            let x_new = x_t[i] - self.eta * sign_f32(vote[i]);
            self.x_prev[i] = x_t[i];
            global[i] = x_new;
        }
    }

    fn local_start(&mut self, global: &[f32]) -> Vec<f32> {
        if self.m.is_empty() {
            // round 0: x_{-1} = x_0 ⇒ y_0 = x_0
            return global.to_vec();
        }
        global
            .iter()
            .zip(&self.x_prev)
            .map(|(&x, &xp)| x + self.alpha * (x - xp))
            .collect()
    }

    fn name(&self) -> &'static str {
        "mv_signsgd"
    }

    /// Algorithm 6's worker→server traffic is the randomized sign votes
    /// — 1 bit per coordinate on the wire (Remark 1), so the simulated
    /// clock charges the packed payload instead of f32 parameters.
    fn sign_compressed_comm(&self) -> bool {
        true
    }

    fn state(&self) -> Vec<&[f32]> {
        let mut out: Vec<&[f32]> = vec![&self.x_prev];
        for m in &self.m {
            out.push(m);
        }
        out
    }

    fn load_state(&mut self, bufs: &[Vec<f32>]) {
        self.x_prev.copy_from_slice(&bufs[0]);
        self.m = bufs[1..].to_vec();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx_with_grads<'a>(
        start: &'a [f32],
        grads: &'a [&'a [f32]],
        ends: &'a [&'a [f32]],
        avg: &'a [f32],
        round: u64,
    ) -> RoundCtx<'a> {
        RoundCtx {
            start,
            avg_end: avg,
            worker_end: ends,
            worker_last_grad: grads,
            gamma: 0.1,
            round,
        }
    }

    #[test]
    fn unanimous_vote_moves_by_eta() {
        let mut opt = MvSignSgd::new(3, 0.5, 0.0, 0.0, 10.0);
        let mut global = vec![0.0f32; 3];
        let start = global.clone();
        // all workers see strong positive gradients on coord 0, negative on 1,
        // zero on 2 (bound >> |g| keeps the randomized flip probability low
        // but with 8 workers the vote is still decisively correct).
        let grads_own = vec![vec![9.9f32, -9.9, 0.0]; 8];
        let grads: Vec<&[f32]> = grads_own.iter().map(|g| g.as_slice()).collect();
        let ends: Vec<&[f32]> = (0..8).map(|_| start.as_slice()).collect();
        let mut rng = Rng::new(3);
        opt.round(&mut global, &ctx_with_grads(&start, &grads, &ends, &start, 0), &mut rng);
        assert_eq!(global[0], -0.5);
        assert_eq!(global[1], 0.5);
        // coord 2: m = 0 -> S_r(0) = ±0 ... sign(0 votes) = 0
        assert_eq!(global[2], 0.0);
    }

    #[test]
    fn extrapolation_kicks_in_after_first_round() {
        let mut opt = MvSignSgd::new(1, 1.0, 0.0, 0.5, 10.0);
        let mut global = vec![4.0f32];
        let start = global.clone();
        assert_eq!(opt.local_start(&global), vec![4.0]); // y_0 = x_0
        let grads_own = vec![vec![9.9f32]; 4];
        let grads: Vec<&[f32]> = grads_own.iter().map(|g| g.as_slice()).collect();
        let ends: Vec<&[f32]> = (0..4).map(|_| start.as_slice()).collect();
        let mut rng = Rng::new(1);
        opt.round(&mut global, &ctx_with_grads(&start, &grads, &ends, &start, 0), &mut rng);
        assert_eq!(global, vec![3.0]); // 4 - 1
        // y_1 = x_1 + 0.5 (x_1 - x_0) = 3 + 0.5*(-1) = 2.5
        assert_eq!(opt.local_start(&global), vec![2.5]);
    }

    #[test]
    fn majority_vote_suppresses_minority_noise() {
        // 7 workers say +, 1 worker says - strongly: update must follow +.
        let mut opt = MvSignSgd::new(1, 0.1, 0.0, 0.0, 10.0);
        let mut global = vec![0.0f32];
        let start = global.clone();
        let mut grads_own = vec![vec![9.5f32]; 7];
        grads_own.push(vec![-9.5f32]);
        let grads: Vec<&[f32]> = grads_own.iter().map(|g| g.as_slice()).collect();
        let ends: Vec<&[f32]> = (0..8).map(|_| start.as_slice()).collect();
        let mut rng = Rng::new(7);
        opt.round(&mut global, &ctx_with_grads(&start, &grads, &ends, &start, 0), &mut rng);
        assert_eq!(global[0], -0.1);
    }

    #[test]
    fn reports_sign_compressed_communication() {
        let opt = MvSignSgd::new(4, 0.1, 0.9, 0.1, 10.0);
        assert!(opt.sign_compressed_comm());
        // the default for every other outer optimizer is full-precision
        let sm = crate::outer::OuterConfig::sign_momentum_paper(1.0).build(4);
        assert!(!sm.sign_compressed_comm());
    }

    #[test]
    fn momentum_buffers_are_per_worker() {
        let mut opt = MvSignSgd::new(1, 0.1, 0.9, 0.0, 10.0);
        let mut global = vec![0.0f32];
        let start = global.clone();
        let grads_own = vec![vec![1.0f32], vec![-1.0f32]];
        let grads: Vec<&[f32]> = grads_own.iter().map(|g| g.as_slice()).collect();
        let ends: Vec<&[f32]> = (0..2).map(|_| start.as_slice()).collect();
        let mut rng = Rng::new(0);
        opt.round(&mut global, &ctx_with_grads(&start, &grads, &ends, &start, 0), &mut rng);
        assert!((opt.m[0][0] - 0.1).abs() < 1e-6);
        assert!((opt.m[1][0] + 0.1).abs() < 1e-6);
    }
}
