//! Federated MV-sto-signSGD-SIM — the paper's Algorithm 6 (Sun et al.
//! 2023), the closest prior method (Remarks 1-2).
//!
//! Structure per outer round t:
//!   y_t          = x_t + α (x_t - x_{t-1})          (outer extrapolation)
//!   workers run τ SGD steps from y_t, ending at y_t^{(i)}
//!   m_{t+1}^{(i)} = β m_t^{(i)} + (1-β) ∇f_i(y_t^{(i)}, ξ)   (LOCAL grad momentum)
//!   x_{t+1}      = x_t - η MV( S_r(m_{t+1}^{(i)}) )          (majority vote)
//!
//! The contrasts with Algorithm 1 that Remark 1 highlights are all here:
//! momentum is built from local stochastic *gradients* (not aggregated
//! local differences), and worker→server communication is 1-bit via the
//! randomized sign S_r (eq. 9) + majority vote, which is why it only
//! converges to an O(dR/√n) neighborhood (Remark 2).
//!
//! # Anchoring (ROADMAP follow-up (g), resolved)
//!
//! Algorithm 6's recursion updates **x_t**, not the extrapolated y_t:
//! the seed implementation anchored both the update and the stored
//! x_prev at the round's start point (y_t whenever α > 0), a
//! transcription slip against the recursion above. [`MvSignSgd`] now
//! captures x_t when [`local_start`](OuterOptimizer::local_start)
//! derives y_t from it, and [`apply`](OuterOptimizer::apply) steps
//! x_{t+1} = x_t − η·MV(...) from that capture (x_prev ← x_t likewise).
//! With α = 0 the two readings coincide, so every α = 0 pinned value is
//! unchanged; the α > 0 divergence is pinned by
//! `literal_alg6_anchors_update_at_x_t` below. When `apply` runs
//! without a prior `local_start` (synthetic unit rounds), it falls back
//! to `ctx.start` — identical whenever α = 0.
//!
//! # Wire semantics
//!
//! Votes really are 1-bit here: [`OuterOptimizer::contribute`] folds
//! the rank's last gradient into its momentum and packs the randomized
//! signs into the round's [`WirePayload::PackedSigns`] buffer, and
//! [`OuterOptimizer::apply`] tallies the packed words without ever
//! unpacking ([`votes::majority_vote_packed`]). Two consequences of the
//! wire having no zero symbol: `S_r(0)` keeps the IEEE sign of its ±0
//! output — a fair ±1 coin, exactly eq. (9) at v = 0 — and a tied
//! majority decodes to +1, so the iterate always moves by η per
//! coordinate.

use anyhow::Result;

use super::{OuterOptimizer, RoundCtx, WireFormat, WirePayload, WorkerView};
use crate::dist::votes::{self, PackedVotes};
use crate::sign::SignOp;
use crate::util::rng::Rng;

pub struct MvSignSgd {
    eta: f32,
    beta: f32,
    alpha: f32,
    /// Norm bound B for the randomized sign operator (Alg. 6 requires the
    /// uniform stochastic-gradient bound).
    bound: f32,
    /// Per-worker momentum buffers m^{(i)}, created lazily at first round
    /// (worker count is only known then).
    m: Vec<Vec<f32>>,
    /// x_{t-1}: the previous global iterate (drives the extrapolation;
    /// checkpointed).
    x_prev: Vec<f32>,
    /// x_t captured by `local_start` before it derives y_t — the anchor
    /// of Algorithm 6's update. Not checkpointed: the trainer calls
    /// `local_start` at every round (including the first after a
    /// resume) before any `apply`. Empty until the first `local_start`;
    /// `apply` then anchors at `ctx.start` (α = 0 semantics).
    x_curr: Vec<f32>,
    /// Dim-sized scratch reused across ranks and rounds: the
    /// randomized-sign output in `fold_and_sign`, the decoded winner in
    /// `apply` (not checkpointed — overwritten before every use).
    scratch: Vec<f32>,
    dim: usize,
}

impl MvSignSgd {
    pub fn new(dim: usize, eta: f32, beta: f32, alpha: f32, bound: f32) -> Self {
        MvSignSgd {
            eta,
            beta,
            alpha,
            bound,
            m: Vec::new(),
            x_prev: vec![0.0; dim],
            x_curr: Vec::new(),
            scratch: vec![0.0; dim],
            dim,
        }
    }

    /// Lazily size the per-worker momentum buffers.
    fn ensure_workers(&mut self, n: usize) {
        assert!(n > 0);
        if self.m.is_empty() {
            self.m = vec![vec![0.0; self.dim]; n];
        }
        assert_eq!(self.m.len(), n, "worker count changed mid-run");
    }

    /// Worker-side half of vote production: fold the rank's last
    /// stochastic gradient into its momentum and apply the randomized
    /// sign S_r into `self.scratch` (packing is the caller's step, so
    /// the destination buffer can be caller-owned and persistent).
    fn fold_and_sign(&mut self, worker: usize, grad: &[f32], rng: &mut Rng) {
        assert_eq!(grad.len(), self.dim, "worker {worker}: gradient length");
        let m = &mut self.m[worker];
        for (mi, &g) in m.iter_mut().zip(grad) {
            *mi = self.beta * *mi + (1.0 - self.beta) * g;
        }
        SignOp::RandPm.apply_into(&mut self.scratch, m, self.bound, rng);
    }
}

impl OuterOptimizer for MvSignSgd {
    /// Algorithm 6's worker→server traffic is the randomized sign votes
    /// — 1 bit per coordinate on the wire (Remark 1); this is the only
    /// format the method speaks
    /// ([`super::OuterConfig::supported_wires`]).
    fn wire(&self) -> WireFormat {
        WireFormat::PackedSigns
    }

    fn contribute(
        &mut self,
        worker: usize,
        n_workers: usize,
        view: &WorkerView,
        rng: &mut Rng,
        out: &mut WirePayload,
    ) {
        self.ensure_workers(n_workers);
        self.fold_and_sign(worker, view.last_grad, rng);
        out.pack_sign_votes(&self.scratch);
    }

    fn apply(
        &mut self,
        global: &mut [f32],
        ctx: &RoundCtx,
        payloads: &[WirePayload],
        _rng: &mut Rng,
    ) -> Result<()> {
        // the tally accepts any non-empty survivor subset of the fleet
        // (dropped/rejected payloads under faults shrink n_effective);
        // contribute already sized `m` from the full worker count.
        // `ctx.agg` is deliberately ignored: the majority tally IS the
        // robust aggregator (breakdown point f < n/2 on unanimous
        // honest coordinates — pinned by the wire tests), there is no
        // mean to trim
        assert!(
            !self.m.is_empty() && payloads.len() <= self.m.len(),
            "{} payloads for a {}-worker fleet",
            payloads.len(),
            self.m.len()
        );
        let packed: Vec<&PackedVotes> = payloads
            .iter()
            .map(|p| match p.as_packed_signs() {
                Some(v) => v,
                None => unreachable!("mv_signsgd exchanges packed sign votes (validated config)"),
            })
            .collect();
        // word-level majority tally over the packed votes, never
        // unpacking to f32 (the decoded winner lands in scratch)
        votes::majority_vote_packed(&packed, &mut self.scratch);
        // literal Algorithm 6: step from x_t (captured by local_start),
        // not from the extrapolated y_t the workers trained from; fall
        // back to ctx.start when no local_start preceded (α = 0 rounds
        // and synthetic tests, where the two coincide)
        let anchor: &[f32] = if self.x_curr.len() == global.len() {
            &self.x_curr
        } else {
            ctx.start
        };
        for ((g, &x), &w) in global.iter_mut().zip(anchor).zip(self.scratch.iter()) {
            *g = x - self.eta * w;
        }
        self.x_prev.copy_from_slice(anchor);
        Ok(())
    }

    fn local_start(&mut self, global: &[f32]) -> Vec<f32> {
        // capture x_t: the anchor for this round's update
        self.x_curr.clear();
        self.x_curr.extend_from_slice(global);
        if self.m.is_empty() {
            // round 0: x_{-1} = x_0 ⇒ y_0 = x_0
            return global.to_vec();
        }
        global
            .iter()
            .zip(&self.x_prev)
            .map(|(&x, &xp)| x + self.alpha * (x - xp))
            .collect()
    }

    fn name(&self) -> &'static str {
        "mv_signsgd"
    }

    fn state(&self) -> Vec<&[f32]> {
        let mut out: Vec<&[f32]> = vec![&self.x_prev];
        for m in &self.m {
            out.push(m);
        }
        out
    }

    fn load_state(&mut self, bufs: &[Vec<f32>]) {
        self.x_prev.copy_from_slice(&bufs[0]);
        self.m = bufs[1..].to_vec();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::collectives;

    /// Drive one full round through the two-phase contract: one
    /// contribute per rank (rank order, shared rng), then apply.
    fn run_round(
        opt: &mut MvSignSgd,
        global: &mut [f32],
        start: &[f32],
        grads: &[Vec<f32>],
        rng: &mut Rng,
        round: u64,
    ) {
        let n = grads.len();
        let buf = WirePayload::with_len(WireFormat::PackedSigns, start.len());
        let mut payloads: Vec<WirePayload> = vec![buf; n];
        let layout = crate::runtime::ParamLayout::single(start.len());
        for (w, grad) in grads.iter().enumerate() {
            let view = WorkerView { start, end: start, last_grad: grad, layout: &layout };
            opt.contribute(w, n, &view, rng, &mut payloads[w]);
        }
        let ctx = RoundCtx { start, gamma: 0.1, round, agg: crate::dist::AggPolicy::Mean };
        global.copy_from_slice(start);
        opt.apply(global, &ctx, &payloads, rng).unwrap();
    }

    #[test]
    fn unanimous_vote_moves_by_eta() {
        let mut opt = MvSignSgd::new(3, 0.5, 0.0, 0.0, 10.0);
        let mut global = vec![0.0f32; 3];
        let start = global.clone();
        // all workers see strong positive gradients on coord 0, negative on 1,
        // zero on 2 (bound >> |g| keeps the randomized flip probability low
        // but with 8 workers the vote is still decisively correct).
        let grads = vec![vec![9.9f32, -9.9, 0.0]; 8];
        let mut rng = Rng::new(3);
        run_round(&mut opt, &mut global, &start, &grads, &mut rng, 0);
        assert_eq!(global[0], -0.5);
        assert_eq!(global[1], 0.5);
        // coord 2: m = 0 -> S_r(0) is a fair ±1 coin on the wire (the
        // 1-bit format has no zero symbol), so the iterate moves by a
        // full ±η — it can never sit still under wire semantics.
        assert_eq!(global[2].abs(), 0.5);
    }

    #[test]
    fn packed_apply_matches_f32_reference_tally_bitwise() {
        // the same votes, tallied two ways: the packed word-level path
        // through the contract vs an f32 majority_vote over votes
        // produced by identical arithmetic on an identically-seeded rng.
        // dim deliberately not a multiple of 8 or 64.
        let dim = 37;
        let n = 3;
        let (eta, beta, bound) = (0.3f32, 0.5f32, 4.0f32);
        let start: Vec<f32> = (0..dim).map(|i| (i as f32).sin()).collect();
        let grads: Vec<Vec<f32>> = (0..n)
            .map(|w| (0..dim).map(|i| ((w * dim + i) as f32).cos() * 3.0).collect())
            .collect();

        // path A: the payload contract
        let mut opt = MvSignSgd::new(dim, eta, beta, 0.0, bound);
        let mut ga = start.clone();
        let mut rng_a = Rng::new(99);
        run_round(&mut opt, &mut ga, &start, &grads, &mut rng_a, 0);

        // path B: f32 reference — same momentum fold, same S_r draws,
        // f32 majority vote, manual step
        let mut rng_b = Rng::new(99);
        let mut m = vec![vec![0.0f32; dim]; n];
        let mut votes_f32: Vec<Vec<f32>> = Vec::new();
        for (w, grad) in grads.iter().enumerate() {
            for (mi, &g) in m[w].iter_mut().zip(grad) {
                *mi = beta * *mi + (1.0 - beta) * g;
            }
            votes_f32.push(SignOp::RandPm.apply(&m[w], bound, &mut rng_b));
        }
        let mut winner = vec![0.0f32; dim];
        collectives::majority_vote(&votes_f32, &mut winner);
        let gb: Vec<f32> =
            start.iter().zip(&winner).map(|(&x, &w)| x - eta * w).collect();

        assert_eq!(ga, gb);
        assert_eq!(opt.m, m);
        assert_eq!(opt.x_prev, start);
    }

    #[test]
    fn tie_decodes_to_plus_one_on_the_wire() {
        // |m| == bound makes S_r deterministic: two workers with exactly
        // opposite momenta produce an exact 1-1 tie on every coordinate.
        // The wire has no zero symbol, so the tally decodes +1 and the
        // iterate moves by -η (an f32 tally with a zero symbol would
        // have sat still).
        let eta = 0.25f32;
        let grads = vec![vec![1.0f32, 1.0], vec![-1.0f32, -1.0]];
        let start = vec![1.0f32, -1.0];
        let mut opt = MvSignSgd::new(2, eta, 0.0, 0.0, 1.0);
        let mut global = start.clone();
        let mut rng = Rng::new(11);
        run_round(&mut opt, &mut global, &start, &grads, &mut rng, 0);
        assert_eq!(global, vec![1.0 - eta, -1.0 - eta]);
    }

    #[test]
    fn extrapolation_kicks_in_after_first_round() {
        let mut opt = MvSignSgd::new(1, 1.0, 0.0, 0.5, 10.0);
        let mut global = vec![4.0f32];
        let start = opt.local_start(&global);
        assert_eq!(start, vec![4.0]); // y_0 = x_0
        let grads = vec![vec![9.9f32]; 4];
        let mut rng = Rng::new(1);
        run_round(&mut opt, &mut global, &start, &grads, &mut rng, 0);
        assert_eq!(global, vec![3.0]); // x_1 = x_0 - 1
        // y_1 = x_1 + 0.5 (x_1 - x_0) = 3 + 0.5*(-1) = 2.5
        assert_eq!(opt.local_start(&global), vec![2.5]);
    }

    /// Pins the (g) fix: with α > 0 the update anchors at x_t, not at
    /// the extrapolated y_t the workers trained from.
    #[test]
    fn literal_alg6_anchors_update_at_x_t() {
        // bound == |m| makes every vote deterministic (+1), so each
        // round steps exactly -η on the single coordinate.
        let mut opt = MvSignSgd::new(1, 1.0, 0.0, 0.5, 1.0);
        let mut global = vec![4.0f32];
        let grads = vec![vec![1.0f32]; 4];
        let mut rng = Rng::new(7);

        // round 0: y_0 = x_0 = 4, x_1 = x_0 - η = 3
        let start = opt.local_start(&global);
        run_round(&mut opt, &mut global, &start, &grads, &mut rng, 0);
        assert_eq!(global, vec![3.0]);

        // round 1: y_1 = 3 + 0.5*(3-4) = 2.5, but the update anchors at
        // x_1 = 3: x_2 = x_1 - η = 2 (the seed's y-anchored recursion
        // would have produced y_1 - η = 1.5)
        let start = opt.local_start(&global);
        assert_eq!(start, vec![2.5]);
        run_round(&mut opt, &mut global, &start, &grads, &mut rng, 1);
        assert_eq!(global, vec![2.0]);

        // and the extrapolation continues from the x-sequence:
        // y_2 = x_2 + 0.5*(x_2 - x_1) = 2 - 0.5 = 1.5
        assert_eq!(opt.local_start(&global), vec![1.5]);
    }

    #[test]
    fn majority_vote_suppresses_minority_noise() {
        // 7 workers say +, 1 worker says - strongly: update must follow +.
        let mut opt = MvSignSgd::new(1, 0.1, 0.0, 0.0, 10.0);
        let mut global = vec![0.0f32];
        let start = global.clone();
        let mut grads = vec![vec![9.5f32]; 7];
        grads.push(vec![-9.5f32]);
        let mut rng = Rng::new(7);
        run_round(&mut opt, &mut global, &start, &grads, &mut rng, 0);
        assert_eq!(global[0], -0.1);
    }

    #[test]
    fn speaks_packed_signs_only() {
        let opt = MvSignSgd::new(4, 0.1, 0.9, 0.1, 10.0);
        assert_eq!(opt.wire(), WireFormat::PackedSigns);
        // every dense method defaults to the full-precision wire
        let sm = crate::outer::OuterConfig::sign_momentum_paper(1.0).build(4);
        assert_eq!(sm.wire(), WireFormat::DenseF32);
    }

    #[test]
    fn momentum_buffers_are_per_worker() {
        let mut opt = MvSignSgd::new(1, 0.1, 0.9, 0.0, 10.0);
        let mut global = vec![0.0f32];
        let start = global.clone();
        let grads = vec![vec![1.0f32], vec![-1.0f32]];
        let mut rng = Rng::new(0);
        run_round(&mut opt, &mut global, &start, &grads, &mut rng, 0);
        assert!((opt.m[0][0] - 0.1).abs() < 1e-6);
        assert!((opt.m[1][0] + 0.1).abs() < 1e-6);
    }
}
