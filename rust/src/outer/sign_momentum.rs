//! **Algorithm 1** — the paper's distributed sign-momentum global step.
//!
//! After τ local steps, `apply` reconstructs the exact average end
//! point x̄_{t,τ} from the dense payloads and, with
//! diff = x_{t,0} - x̄_{t,τ} (the aggregated local progress scaled into a
//! pseudo-gradient by 1/γ_t):
//!
//! ```text
//!     u_{t+1} = β1 m_t + (1-β1)/γ_t · diff            (eq. 6)
//!     x_{t+1} = x_t - η γ_t (sign(u_{t+1}) + λ x_t)   (eq. 7)
//!     m_{t+1} = β2 m_t + (1-β2)/γ_t · diff            (eq. 8)
//! ```
//!
//! This mimics Lion over pseudo-gradients; β2 > β1 weights the fresh
//! difference more in the applied direction than in the stored momentum,
//! the acceleration the paper credits for beating signed SlowMo (§4.1).
//! The 1/γ_t scaling keeps the momentum buffer LR-schedule-invariant.
//!
//! `sign_op` selects the deterministic operator (deployment, default) or
//! the randomized analogs of §3.1 used by the theory experiments.
//!
//! # The Pallas fast path
//!
//! [`SignMomentum::with_kernel`] installs the AOT'd fused Pallas
//! sign-update kernel ([`SignUpdateKernel`]); `apply` then runs
//! eqs. (6)-(8) as one fused kernel call instead of the native loop —
//! an *apply specialization*, not a trainer special case, so the kernel
//! path shares this optimizer's momentum buffer and therefore
//! checkpoints exactly like the native path (the pre-redesign trainer
//! kept a separate, un-checkpointed kernel momentum). Only the exact
//! sign operator was AOT'd; the trainer's config gate keeps randomized
//! operators off this path.

use anyhow::Result;

use super::{OuterOptimizer, RoundCtx, WireFormat, WirePayload, WorkerView};
use crate::runtime::{SignUpdateKernel, SignUpdateScalars};
use crate::sign::SignOp;
use crate::tensor::sign_f32;
use crate::util::rng::Rng;

pub struct SignMomentum {
    eta: f32,
    beta1: f32,
    beta2: f32,
    weight_decay: f32,
    sign_op: SignOp,
    /// B for the randomized operators (Theorem 1 uses B = τR). Unused by
    /// SignOp::Exact.
    sign_bound: f32,
    m: Vec<f32>,
    /// scratch for the randomized-sign input / the kernel's diff vector
    /// (avoids per-round allocation)
    scratch: Vec<f32>,
    /// scratch holding the round's reconstructed average end point
    /// (not checkpointed — overwritten every `apply`)
    avg: Vec<f32>,
    /// Optional AOT'd fused kernel for the exact-sign global step.
    kernel: Option<SignUpdateKernel>,
}

impl SignMomentum {
    pub fn new(
        dim: usize,
        eta: f32,
        beta1: f32,
        beta2: f32,
        weight_decay: f32,
        sign_op: SignOp,
        sign_bound: f32,
    ) -> Self {
        assert!((0.0..=1.0).contains(&beta1) && (0.0..=1.0).contains(&beta2));
        SignMomentum {
            eta,
            beta1,
            beta2,
            weight_decay,
            sign_op,
            sign_bound,
            m: vec![0.0; dim],
            scratch: vec![0.0; dim],
            avg: vec![0.0; dim],
            kernel: None,
        }
    }

    /// Route `apply` through the AOT'd fused Pallas kernel (requires
    /// [`SignOp::Exact`] — the trainer validates before installing).
    pub fn with_kernel(mut self, kernel: SignUpdateKernel) -> Self {
        self.kernel = Some(kernel);
        self
    }

    pub fn momentum(&self) -> &[f32] {
        &self.m
    }
}

impl OuterOptimizer for SignMomentum {
    fn wire(&self) -> WireFormat {
        WireFormat::DenseF32
    }

    fn contribute(
        &mut self,
        _worker: usize,
        _n_workers: usize,
        view: &WorkerView,
        _rng: &mut Rng,
        out: &mut WirePayload,
    ) {
        out.pack_end(view.start, view.end);
    }

    fn apply(
        &mut self,
        global: &mut [f32],
        ctx: &RoundCtx,
        payloads: &[WirePayload],
        rng: &mut Rng,
    ) -> Result<()> {
        let p = global.len();
        assert_eq!(ctx.start.len(), p);
        assert_eq!(self.m.len(), p);
        WirePayload::aggregate_end_into(ctx.agg, payloads, ctx.start, &mut self.avg)?;

        if let Some(kernel) = &self.kernel {
            anyhow::ensure!(
                self.sign_op == SignOp::Exact,
                "the Pallas sign-update kernel implements the exact sign operator only"
            );
            for i in 0..p {
                self.scratch[i] = ctx.start[i] - self.avg[i];
            }
            kernel.apply(
                global,
                &mut self.m,
                &self.scratch,
                SignUpdateScalars {
                    gamma: ctx.gamma,
                    eta: self.eta,
                    weight_decay: self.weight_decay,
                    beta1: self.beta1,
                    beta2: self.beta2,
                },
            )?;
            return Ok(());
        }

        let inv_gamma = 1.0 / ctx.gamma;
        let (b1, b2, eta, lam, g) =
            (self.beta1, self.beta2, self.eta, self.weight_decay, ctx.gamma);
        match self.sign_op {
            SignOp::Exact => {
                // fused single pass: u, sign, x-update, m-update per element
                for i in 0..p {
                    let diff = (ctx.start[i] - self.avg[i]) * inv_gamma;
                    let u = b1 * self.m[i] + (1.0 - b1) * diff;
                    global[i] = ctx.start[i] - eta * g * (sign_f32(u) + lam * ctx.start[i]);
                    self.m[i] = b2 * self.m[i] + (1.0 - b2) * diff;
                }
            }
            op => {
                // two-pass: build u in scratch, apply randomized sign, update
                for i in 0..p {
                    let diff = (ctx.start[i] - self.avg[i]) * inv_gamma;
                    self.scratch[i] = b1 * self.m[i] + (1.0 - b1) * diff;
                    self.m[i] = b2 * self.m[i] + (1.0 - b2) * diff;
                }
                let u = std::mem::take(&mut self.scratch);
                let mut signs = vec![0.0f32; p];
                op.apply_into(&mut signs, &u, self.sign_bound, rng);
                self.scratch = u;
                for i in 0..p {
                    global[i] = ctx.start[i] - eta * g * (signs[i] + lam * ctx.start[i]);
                }
            }
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "sign_momentum"
    }

    fn state(&self) -> Vec<&[f32]> {
        vec![&self.m]
    }

    fn load_state(&mut self, bufs: &[Vec<f32>]) {
        self.m.copy_from_slice(&bufs[0]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::outer::run_synthetic_round;

    fn new_default(dim: usize, eta: f32, b1: f32, b2: f32, wd: f32) -> SignMomentum {
        SignMomentum::new(dim, eta, b1, b2, wd, SignOp::Exact, 1.0)
    }

    /// Hand-checked single round against the paper's eqs. (6)-(8).
    #[test]
    fn matches_hand_computed_round() {
        let mut opt = new_default(2, 2.0, 0.5, 0.8, 0.1);
        // preload momentum
        opt.m = vec![1.0, -3.0];
        let mut global = vec![1.0f32, 2.0];
        let gamma = 0.5;
        // diff(applied) = [0.2, -0.4] -> pseudo-grad = diff/gamma = [0.4, -0.8]
        run_synthetic_round(&mut opt, &mut global, &[0.2, -0.4], gamma, 0);
        // u = 0.5*m + 0.5*pg = [0.5+0.2, -1.5-0.4] = [0.7, -1.9]
        // x = x - eta*gamma*(sign(u) + 0.1 x) = [1 - 1*(1+0.1), 2 - 1*(-1+0.2)]
        assert!((global[0] - (1.0 - 1.0 * 1.1)).abs() < 1e-6, "{global:?}");
        assert!((global[1] - (2.0 - 1.0 * (-0.8))).abs() < 1e-6, "{global:?}");
        // m = 0.8*m + 0.2*pg = [0.8+0.08, -2.4-0.16]
        assert!((opt.m[0] - 0.88).abs() < 1e-6);
        assert!((opt.m[1] + 2.56).abs() < 1e-6);
    }

    /// Matches the jnp oracle sign_update_ref (same numbers as the Pallas
    /// kernel test test_sign_update_zero_momentum_is_pure_sign_step).
    #[test]
    fn matches_pallas_oracle_case() {
        let mut opt = new_default(4096, 1.5, 0.0, 0.0, 0.0);
        let mut global = vec![0.0f32; 4096];
        let gamma = 0.5;
        // applied diff = gamma * pseudo-grad; oracle used diff(pg) 2.0 / -3.0
        let mut diff = vec![2.0f32 * gamma; 2048];
        diff.extend(vec![-3.0f32 * gamma; 2048]);
        run_synthetic_round(&mut opt, &mut global, &diff, gamma, 0);
        assert!((global[0] - (-1.5 * 0.5)).abs() < 1e-6);
        assert!((global[4095] - (1.5 * 0.5)).abs() < 1e-6);
        assert!((opt.m[0] - 2.0 / 0.5 * 0.5).abs() < 1e-5); // pg=4? no: see below
    }

    /// Momentum buffer is invariant to the LR schedule: halving gamma with
    /// the same *pseudo-gradient* leaves m identical (paper's rationale
    /// for the 1/γ_t scaling).
    #[test]
    fn momentum_is_lr_schedule_invariant() {
        let pg = [0.3f32, -0.7, 0.1];
        let mut results = Vec::new();
        for gamma in [0.5f32, 0.05] {
            let mut opt = new_default(3, 1.0, 0.95, 0.98, 0.0);
            let mut global = vec![0.0f32; 3];
            let diff: Vec<f32> = pg.iter().map(|&d| d * gamma).collect();
            run_synthetic_round(&mut opt, &mut global, &diff, gamma, 0);
            results.push(opt.m.clone());
        }
        for (a, b) in results[0].iter().zip(&results[1]) {
            assert!((a - b).abs() < 1e-6, "{results:?}");
        }
    }

    /// With β1=β2=β, λ=0, n=1, τ=1, SGD base: one Algorithm-1 round equals
    /// one signSGD-with-momentum step (eq. 3 of the paper).
    #[test]
    fn reduces_to_signsgd_momentum() {
        let beta = 0.9f32;
        let mut opt = new_default(2, 1.0, beta, beta, 0.0);
        let mut global = vec![0.5f32, -0.5];
        let mut m_ref = vec![0.0f32; 2];
        let mut x_ref = global.clone();
        let gamma = 0.1;
        let grads = [[1.0f32, -2.0], [-0.5, 0.3], [0.2, 0.2]];
        for (t, gr) in grads.iter().enumerate() {
            // reference eq. (3)
            for i in 0..2 {
                m_ref[i] = beta * m_ref[i] + (1.0 - beta) * gr[i];
                x_ref[i] -= 1.0 * gamma * sign_f32(m_ref[i]);
            }
            // Algorithm 1 round: τ=1 SGD local step means diff = γ g.
            let diff: Vec<f32> = gr.iter().map(|&g| g * gamma).collect();
            run_synthetic_round(&mut opt, &mut global, &diff, gamma, t as u64);
        }
        for (a, e) in global.iter().zip(&x_ref) {
            assert!((a - e).abs() < 1e-6, "{global:?} vs {x_ref:?}");
        }
    }

    #[test]
    fn weight_decay_pulls_toward_zero() {
        let mut opt = new_default(1, 1.0, 0.9, 0.99, 0.5);
        let mut global = vec![2.0f32];
        // zero progress: sign(u)=0, so pure decoupled decay
        run_synthetic_round(&mut opt, &mut global, &[0.0], 0.1, 0);
        assert!((global[0] - (2.0 - 1.0 * 0.1 * 0.5 * 2.0)).abs() < 1e-6);
    }

    #[test]
    fn randomized_ops_agree_with_exact_in_expectation() {
        let dim = 2048;
        let gamma = 0.1f32;
        let diff: Vec<f32> = (0..dim).map(|i| if i % 2 == 0 { 0.05 } else { -0.05 }).collect();
        // exact
        let mut ex = new_default(dim, 1.0, 0.0, 0.0, 0.0);
        let mut gx = vec![0.0f32; dim];
        run_synthetic_round(&mut ex, &mut gx, &diff, gamma, 0);
        // randomized, averaged over repeats (B=1 so E[S_r] = u with |u|=0.5)
        let mut acc = vec![0.0f64; dim];
        let reps = 400;
        for r in 0..reps {
            let mut op = SignMomentum::new(dim, 1.0, 0.0, 0.0, 0.0, SignOp::RandPm, 1.0);
            let mut g = vec![0.0f32; dim];
            run_synthetic_round(&mut op, &mut g, &diff, gamma, r);
            for (a, &v) in acc.iter_mut().zip(&g) {
                *a += v as f64;
            }
        }
        // E[x_rand] = -eta*gamma*u/B = 0.5 * x_exact here (|u|=0.5, B=1)
        let mean0 = acc[0] / reps as f64;
        assert!((mean0 - 0.5 * gx[0] as f64).abs() < 0.02, "{mean0} vs {}", gx[0]);
    }
}
