//! SlowMo (paper's Algorithm 5, Wang et al. 2019) and the signed-SlowMo
//! ablation of §4.1.
//!
//! SlowMo:        u_{t+1} = β u_t + (1/γ_t)(x_{t,0} - x_{t,τ})
//! ```text
//!                x_{t+1} = x_{t,0} - α γ_t u_{t+1}
//! ```
//!
//! Signed SlowMo: u_{t+1} = β u_t + (1-β)/γ_t · sign(x_{t,0} - x_{t,τ})
//! ```text
//!                x_{t+1} = x_{t,0} - η γ_t u_{t+1}
//! ```
//!
//! Note the asymmetry the paper inherits: SlowMo's momentum uses weight 1
//! on the fresh difference (classical momentum), signed SlowMo uses
//! (1-β) (EMA), exactly as §4.1 defines them.
//!
//! Both are dense-exchange methods: `contribute` ships each rank's end
//! parameters ([`WirePayload::pack_end`]) and `apply` reconstructs the
//! exact average end point from the payloads before the update.

use anyhow::Result;

use super::{OuterOptimizer, RoundCtx, WireFormat, WirePayload, WorkerView};
use crate::tensor::sign_f32;
use crate::util::rng::Rng;

pub struct SlowMo {
    alpha: f32,
    beta: f32,
    u: Vec<f32>,
    /// round scratch: reconstructed average end point (not checkpointed)
    avg: Vec<f32>,
}

impl SlowMo {
    pub fn new(dim: usize, alpha: f32, beta: f32) -> Self {
        SlowMo { alpha, beta, u: vec![0.0; dim], avg: vec![0.0; dim] }
    }

    pub fn momentum(&self) -> &[f32] {
        &self.u
    }
}

impl OuterOptimizer for SlowMo {
    fn wire(&self) -> WireFormat {
        WireFormat::DenseF32
    }

    fn contribute(
        &mut self,
        _worker: usize,
        _n_workers: usize,
        view: &WorkerView,
        _rng: &mut Rng,
        out: &mut WirePayload,
    ) {
        out.pack_end(view.start, view.end);
    }

    fn apply(
        &mut self,
        global: &mut [f32],
        ctx: &RoundCtx,
        payloads: &[WirePayload],
        _rng: &mut Rng,
    ) -> Result<()> {
        WirePayload::aggregate_end_into(ctx.agg, payloads, ctx.start, &mut self.avg)?;
        let inv_gamma = 1.0 / ctx.gamma;
        for i in 0..global.len() {
            let diff = (ctx.start[i] - self.avg[i]) * inv_gamma;
            self.u[i] = self.beta * self.u[i] + diff;
            global[i] = ctx.start[i] - self.alpha * ctx.gamma * self.u[i];
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "slowmo"
    }

    fn state(&self) -> Vec<&[f32]> {
        vec![&self.u]
    }

    fn load_state(&mut self, bufs: &[Vec<f32>]) {
        self.u.copy_from_slice(&bufs[0]);
    }
}

pub struct SignedSlowMo {
    eta: f32,
    beta: f32,
    u: Vec<f32>,
    /// round scratch: reconstructed average end point (not checkpointed)
    avg: Vec<f32>,
}

impl SignedSlowMo {
    pub fn new(dim: usize, eta: f32, beta: f32) -> Self {
        SignedSlowMo { eta, beta, u: vec![0.0; dim], avg: vec![0.0; dim] }
    }
}

impl OuterOptimizer for SignedSlowMo {
    fn wire(&self) -> WireFormat {
        WireFormat::DenseF32
    }

    fn contribute(
        &mut self,
        _worker: usize,
        _n_workers: usize,
        view: &WorkerView,
        _rng: &mut Rng,
        out: &mut WirePayload,
    ) {
        out.pack_end(view.start, view.end);
    }

    fn apply(
        &mut self,
        global: &mut [f32],
        ctx: &RoundCtx,
        payloads: &[WirePayload],
        _rng: &mut Rng,
    ) -> Result<()> {
        WirePayload::aggregate_end_into(ctx.agg, payloads, ctx.start, &mut self.avg)?;
        let inv_gamma = 1.0 / ctx.gamma;
        for i in 0..global.len() {
            let s = sign_f32(ctx.start[i] - self.avg[i]);
            self.u[i] = self.beta * self.u[i] + (1.0 - self.beta) * s * inv_gamma;
            global[i] = ctx.start[i] - self.eta * ctx.gamma * self.u[i];
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "signed_slowmo"
    }

    fn state(&self) -> Vec<&[f32]> {
        vec![&self.u]
    }

    fn load_state(&mut self, bufs: &[Vec<f32>]) {
        self.u.copy_from_slice(&bufs[0]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::outer::run_synthetic_round;

    #[test]
    fn slowmo_hand_computed_round() {
        let mut opt = SlowMo::new(2, 0.5, 0.8);
        opt.u = vec![2.0, -1.0];
        let mut global = vec![1.0f32, 1.0];
        let gamma = 0.25;
        // applied diff [0.5, -0.25] -> pg = [2.0, -1.0]
        run_synthetic_round(&mut opt, &mut global, &[0.5, -0.25], gamma, 0);
        // u = 0.8*u + pg = [3.6, -1.8]; x = 1 - 0.5*0.25*u
        assert!((opt.u[0] - 3.6).abs() < 1e-6 && (opt.u[1] + 1.8).abs() < 1e-6);
        assert!((global[0] - (1.0 - 0.125 * 3.6)).abs() < 1e-6);
        assert!((global[1] - (1.0 + 0.125 * 1.8)).abs() < 1e-6);
    }

    #[test]
    fn slowmo_beta_zero_is_plain_averaging_with_alpha_one() {
        // β=0, α=1: x_{t+1} = x_t - (x_t - avg) = avg.
        let mut opt = SlowMo::new(3, 1.0, 0.0);
        let mut global = vec![1.0f32, 2.0, 3.0];
        run_synthetic_round(&mut opt, &mut global, &[0.1, -0.2, 0.3], 0.5, 0);
        let expect = [0.9f32, 2.2, 2.7];
        for (a, e) in global.iter().zip(expect) {
            assert!((a - e).abs() < 1e-6);
        }
    }

    #[test]
    fn signed_slowmo_momentum_bounded() {
        // |u| <= (1-β) Σ β^k / γ = 1/γ: the signed pseudo-grad is ±1/γ.
        let mut opt = SignedSlowMo::new(1, 1.0, 0.5);
        let mut global = vec![0.0f32];
        for r in 0..50 {
            run_synthetic_round(&mut opt, &mut global, &[1.0], 0.1, r);
            assert!(opt.u[0].abs() <= 10.0 + 1e-4);
        }
        assert!((opt.u[0] - 10.0).abs() < 1e-3, "{}", opt.u[0]);
    }

    #[test]
    fn signed_slowmo_ignores_diff_magnitude() {
        let mut a = SignedSlowMo::new(2, 1.0, 0.5);
        let mut b = SignedSlowMo::new(2, 1.0, 0.5);
        let mut ga = vec![0.0f32; 2];
        let mut gb = vec![0.0f32; 2];
        run_synthetic_round(&mut a, &mut ga, &[0.001, -5.0], 0.1, 0);
        run_synthetic_round(&mut b, &mut gb, &[7.0, -0.002], 0.1, 0);
        assert_eq!(ga, gb);
    }

    #[test]
    fn slowmo_accelerates_vs_plain_averaging_on_quadratic() {
        // local step = gradient step on f(x)=0.5x²; SlowMo's momentum
        // should reach the optimum faster than plain local averaging.
        let run = |beta: f32| -> f32 {
            let mut opt = SlowMo::new(1, 1.0, beta);
            let mut x = vec![8.0f32];
            let gamma = 0.05;
            for r in 0..40 {
                // one local step of SGD from x: end = x - γ x
                let diff = vec![gamma * x[0]];
                run_synthetic_round(&mut opt, &mut x, &diff, gamma, r);
            }
            x[0].abs()
        };
        assert!(run(0.5) < run(0.0), "momentum should help: {} vs {}", run(0.5), run(0.0));
    }
}
