//! Manifest loader: the contract between `make artifacts` (python) and
//! the Rust runtime.
//!
//! The parameter layout is validated **here, at load time** — a
//! malformed `param_layout` (gaps, overlaps, wrong total, duplicate
//! names, unparsable entries) is a real error instead of a silently
//! empty layout, and a manifest that omits the layout degrades to the
//! documented single-segment fallback ([`ParamLayout::single`]).
//! Everything above the runtime therefore receives a [`ParamLayout`]
//! whose invariants already hold.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::layout::{ParamEntry, ParamLayout};
use crate::util::json::Json;

/// Static description of one AOT'd model preset.
#[derive(Clone, Debug)]
pub struct PresetInfo {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub n_layer: usize,
    pub seq: usize,
    pub batch: usize,
    pub param_count: usize,
    pub init_file: PathBuf,
    pub train_file: PathBuf,
    pub eval_file: PathBuf,
    /// Validated segment layout of the flat parameter vector
    /// (manifest `param_layout`, or the single-segment fallback).
    pub layout: ParamLayout,
}

impl PresetInfo {
    /// Tokens consumed per train step (for tokens/sec reporting).
    pub fn tokens_per_step(&self) -> usize {
        self.batch * self.seq
    }

    pub fn param_bytes(&self) -> u64 {
        self.param_count as u64 * 4
    }
}

/// Parsed `artifacts/manifest.json`.
pub struct Artifacts {
    pub dir: PathBuf,
    pub presets: BTreeMap<String, PresetInfo>,
    pub sign_update_file: PathBuf,
    pub sign_update_chunk: usize,
}

impl Artifacts {
    pub fn load(dir: &Path) -> Result<Artifacts> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?}; run `make artifacts` first"))?;
        let root = Json::parse(&text).map_err(|e| anyhow!("{manifest_path:?}: {e}"))?;

        let version = root.get("version").and_then(Json::as_usize).unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version} (expected 1)");
        }

        let mut presets = BTreeMap::new();
        let preset_obj = root
            .get("presets")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing `presets`"))?;
        for (name, entry) in preset_obj {
            let cfg = entry.get("config").ok_or_else(|| anyhow!("{name}: no config"))?;
            let u = |key: &str| -> Result<usize> {
                cfg.get(key)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("{name}: config.{key} missing"))
            };
            let file = |kind: &str| -> Result<PathBuf> {
                let f = entry
                    .get("artifacts")
                    .and_then(|a| a.get(kind))
                    .and_then(|k| k.get("file"))
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("{name}: artifacts.{kind}.file missing"))?;
                let path = dir.join(f);
                if !path.exists() {
                    bail!("{name}: artifact file {path:?} missing; re-run `make artifacts`");
                }
                Ok(path)
            };
            let param_count = entry
                .get("param_count")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("{name}: param_count missing"))?;
            let layout = parse_layout(name, entry, param_count)?;
            presets.insert(
                name.clone(),
                PresetInfo {
                    name: name.clone(),
                    vocab: u("vocab")?,
                    d_model: u("d_model")?,
                    n_head: u("n_head")?,
                    n_layer: u("n_layer")?,
                    seq: u("seq")?,
                    batch: u("batch")?,
                    param_count,
                    init_file: file("init")?,
                    train_file: file("train")?,
                    eval_file: file("eval")?,
                    layout,
                },
            );
        }

        let su = root
            .get("sign_update")
            .ok_or_else(|| anyhow!("manifest missing `sign_update`"))?;
        let sign_update_file = dir.join(
            su.get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("sign_update.file missing"))?,
        );
        let sign_update_chunk = su
            .get("chunk")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("sign_update.chunk missing"))?;

        Ok(Artifacts { dir: dir.to_path_buf(), presets, sign_update_file, sign_update_chunk })
    }

    /// Default artifacts dir: `$REPO/artifacts` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        let cand = PathBuf::from("artifacts");
        if cand.exists() {
            cand
        } else {
            PathBuf::from("../artifacts")
        }
    }

    pub fn preset(&self, name: &str) -> Result<&PresetInfo> {
        self.presets.get(name).ok_or_else(|| {
            anyhow!(
                "preset `{name}` not in manifest (have: {:?}); re-run `make artifacts`",
                self.presets.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Post-load consistency sweep (`repro inspect manifest`). The
    /// layout invariants are proven at construction —
    /// [`ParamLayout::from_entries`] runs during [`Artifacts::load`],
    /// so `layout.param_count() == param_count` always holds by the
    /// time an `Artifacts` exists. What CAN still go stale afterwards
    /// is the filesystem: re-check that every referenced artifact file
    /// is still present.
    pub fn validate(&self) -> Result<()> {
        let check = |kind: &str, path: &Path| -> Result<()> {
            anyhow::ensure!(path.exists(), "{kind} artifact {path:?} is missing");
            Ok(())
        };
        for (name, p) in &self.presets {
            check(&format!("{name}: init"), &p.init_file)?;
            check(&format!("{name}: train"), &p.train_file)?;
            check(&format!("{name}: eval"), &p.eval_file)?;
        }
        check("sign_update", &self.sign_update_file)?;
        Ok(())
    }
}

/// Parse one preset's `param_layout` into a validated [`ParamLayout`].
///
/// Absent key → the single-segment fallback. Present key → every entry
/// must parse (an unparsable entry is an error, not a silently dropped
/// one) and the whole list must tile `[0, param_count)`.
fn parse_layout(name: &str, entry: &Json, param_count: usize) -> Result<ParamLayout> {
    // only an ABSENT key gets the fallback; a declared layout — even
    // `[]` or a wrong-typed value — must validate (an explicitly empty
    // list of a non-empty vector errors in `from_entries`, by design)
    let Some(raw) = entry.get("param_layout") else {
        return Ok(ParamLayout::single(param_count));
    };
    let arr = raw
        .as_arr()
        .ok_or_else(|| anyhow!("{name}: param_layout must be an array of entries"))?;
    let mut entries = Vec::with_capacity(arr.len());
    for (i, e) in arr.iter().enumerate() {
        let parsed = parse_entry(e).ok_or_else(|| {
            anyhow!("{name}: param_layout[{i}] malformed (needs name, offset, shape)")
        })?;
        entries.push(parsed);
    }
    ParamLayout::from_entries(entries, param_count)
        .with_context(|| format!("{name}: invalid param_layout"))
}

/// One `param_layout` element; `None` when any field is missing or of
/// the wrong type (the caller turns that into a named error).
fn parse_entry(e: &Json) -> Option<ParamEntry> {
    let raw = e.get("shape")?.as_arr()?;
    let shape: Vec<usize> = raw.iter().filter_map(Json::as_usize).collect();
    if shape.len() != raw.len() {
        return None;
    }
    Some(ParamEntry {
        name: e.get("name")?.as_str()?.to_string(),
        offset: e.get("offset")?.as_usize()?,
        shape,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = Artifacts::default_dir();
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn loads_real_manifest_and_validates() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let arts = Artifacts::load(&dir).unwrap();
        arts.validate().unwrap();
        let nano = arts.preset("nano").unwrap();
        assert_eq!(nano.vocab, 256);
        assert_eq!(nano.seq, 64);
        assert!(nano.param_count > 100_000);
        assert!(nano.layout.iter().any(|e| e.name == "wte"));
        assert_eq!(nano.layout.param_count(), nano.param_count);
        assert!(arts.sign_update_chunk >= 4096);
        assert!(arts.preset("nonexistent").is_err());
    }

    // ---- synthetic-manifest tests: load-time layout validation ----

    /// Write a minimal one-preset manifest (plus the dummy artifact
    /// files its loader checks for) whose `param_layout` value is
    /// spliced in verbatim; `""` omits the key entirely.
    fn write_manifest(dir: &Path, param_count: usize, layout_json: &str) {
        std::fs::create_dir_all(dir).unwrap();
        for f in ["a.hlo", "sign.hlo"] {
            std::fs::write(dir.join(f), "dummy").unwrap();
        }
        let layout_field = if layout_json.is_empty() {
            String::new()
        } else {
            format!(", \"param_layout\": {layout_json}")
        };
        let manifest = format!(
            "{{\"version\": 1, \
              \"sign_update\": {{\"file\": \"sign.hlo\", \"chunk\": 8192}}, \
              \"presets\": {{\"t\": {{\
                \"config\": {{\"vocab\": 256, \"d_model\": 4, \"n_head\": 1, \
                             \"n_layer\": 1, \"seq\": 8, \"batch\": 2}}, \
                \"param_count\": {param_count}, \
                \"artifacts\": {{\"init\": {{\"file\": \"a.hlo\"}}, \
                                \"train\": {{\"file\": \"a.hlo\"}}, \
                                \"eval\": {{\"file\": \"a.hlo\"}}}}\
                {layout_field}}}}}}}"
        );
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    }

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("dsm_artifacts_{tag}"))
    }

    #[test]
    fn missing_layout_falls_back_to_single_segment() {
        let dir = tmp("fallback");
        write_manifest(&dir, 12, "");
        let arts = Artifacts::load(&dir).unwrap();
        let p = arts.preset("t").unwrap();
        assert_eq!(p.layout, ParamLayout::single(12));
        assert_eq!(p.layout.len(), 1);
        arts.validate().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn valid_layout_loads_and_is_offset_sorted() {
        let dir = tmp("valid");
        // entries out of order on purpose: the loader sorts by offset
        write_manifest(
            &dir,
            12,
            "[{\"name\": \"out\", \"offset\": 8, \"shape\": [4]}, \
              {\"name\": \"embed\", \"offset\": 0, \"shape\": [2, 4]}]",
        );
        let arts = Artifacts::load(&dir).unwrap();
        let p = arts.preset("t").unwrap();
        assert_eq!(p.layout.len(), 2);
        assert_eq!(p.layout.entries()[0].name, "embed");
        assert_eq!(p.layout.range(1), 8..12);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn malformed_layouts_fail_at_load_time() {
        // gap between segments
        let dir = tmp("gap");
        write_manifest(
            &dir,
            12,
            "[{\"name\": \"a\", \"offset\": 0, \"shape\": [4]}, \
              {\"name\": \"b\", \"offset\": 6, \"shape\": [6]}]",
        );
        let err = Artifacts::load(&dir).err().expect("gap layout must fail").to_string();
        assert!(err.contains("param_layout"), "{err}");
        std::fs::remove_dir_all(&dir).ok();

        // total does not cover param_count
        let dir = tmp("total");
        write_manifest(&dir, 12, "[{\"name\": \"a\", \"offset\": 0, \"shape\": [4]}]");
        assert!(Artifacts::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();

        // unparsable entry (offset missing) is an error, not dropped
        let dir = tmp("unparsable");
        write_manifest(&dir, 12, "[{\"name\": \"a\", \"shape\": [12]}]");
        let err = Artifacts::load(&dir).err().expect("bad entry must fail").to_string();
        assert!(err.contains("malformed"), "{err}");
        std::fs::remove_dir_all(&dir).ok();

        // a DECLARED-but-empty layout is an error (only an absent key
        // gets the single-segment fallback)
        let dir = tmp("declared_empty");
        write_manifest(&dir, 12, "[]");
        let err = Artifacts::load(&dir).err().expect("empty layout must fail").to_string();
        assert!(err.contains("param_layout"), "{err}");
        std::fs::remove_dir_all(&dir).ok();

        // ...and so is a declared layout of the wrong type
        let dir = tmp("wrong_type");
        write_manifest(&dir, 12, "{\"wte\": 1}");
        let err = Artifacts::load(&dir).err().expect("non-array layout must fail").to_string();
        assert!(err.contains("must be an array"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn validate_catches_artifact_files_vanishing_after_load() {
        let dir = tmp("vanish");
        write_manifest(&dir, 12, "");
        let arts = Artifacts::load(&dir).unwrap();
        arts.validate().unwrap();
        std::fs::remove_file(dir.join("a.hlo")).unwrap();
        assert!(arts.validate().is_err(), "missing artifact file must fail validate()");
        std::fs::remove_dir_all(&dir).ok();
    }
}
