//! Manifest loader: the contract between `make artifacts` (python) and
//! the Rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// One named tensor inside the flat parameter vector.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub offset: usize,
    pub shape: Vec<usize>,
}

impl ParamEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Static description of one AOT'd model preset.
#[derive(Clone, Debug)]
pub struct PresetInfo {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub n_layer: usize,
    pub seq: usize,
    pub batch: usize,
    pub param_count: usize,
    pub init_file: PathBuf,
    pub train_file: PathBuf,
    pub eval_file: PathBuf,
    pub layout: Vec<ParamEntry>,
}

impl PresetInfo {
    /// Tokens consumed per train step (for tokens/sec reporting).
    pub fn tokens_per_step(&self) -> usize {
        self.batch * self.seq
    }

    pub fn param_bytes(&self) -> u64 {
        self.param_count as u64 * 4
    }
}

/// Parsed `artifacts/manifest.json`.
pub struct Artifacts {
    pub dir: PathBuf,
    pub presets: BTreeMap<String, PresetInfo>,
    pub sign_update_file: PathBuf,
    pub sign_update_chunk: usize,
}

impl Artifacts {
    pub fn load(dir: &Path) -> Result<Artifacts> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?}; run `make artifacts` first"))?;
        let root = Json::parse(&text).map_err(|e| anyhow!("{manifest_path:?}: {e}"))?;

        let version = root.get("version").and_then(Json::as_usize).unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version} (expected 1)");
        }

        let mut presets = BTreeMap::new();
        let preset_obj = root
            .get("presets")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing `presets`"))?;
        for (name, entry) in preset_obj {
            let cfg = entry.get("config").ok_or_else(|| anyhow!("{name}: no config"))?;
            let u = |key: &str| -> Result<usize> {
                cfg.get(key)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("{name}: config.{key} missing"))
            };
            let file = |kind: &str| -> Result<PathBuf> {
                let f = entry
                    .get("artifacts")
                    .and_then(|a| a.get(kind))
                    .and_then(|k| k.get("file"))
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("{name}: artifacts.{kind}.file missing"))?;
                let path = dir.join(f);
                if !path.exists() {
                    bail!("{name}: artifact file {path:?} missing; re-run `make artifacts`");
                }
                Ok(path)
            };
            let layout = entry
                .get("param_layout")
                .and_then(Json::as_arr)
                .map(|arr| {
                    arr.iter()
                        .filter_map(|e| {
                            Some(ParamEntry {
                                name: e.get("name")?.as_str()?.to_string(),
                                offset: e.get("offset")?.as_usize()?,
                                shape: e
                                    .get("shape")?
                                    .as_arr()?
                                    .iter()
                                    .filter_map(Json::as_usize)
                                    .collect(),
                            })
                        })
                        .collect::<Vec<_>>()
                })
                .unwrap_or_default();
            presets.insert(
                name.clone(),
                PresetInfo {
                    name: name.clone(),
                    vocab: u("vocab")?,
                    d_model: u("d_model")?,
                    n_head: u("n_head")?,
                    n_layer: u("n_layer")?,
                    seq: u("seq")?,
                    batch: u("batch")?,
                    param_count: entry
                        .get("param_count")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("{name}: param_count missing"))?,
                    init_file: file("init")?,
                    train_file: file("train")?,
                    eval_file: file("eval")?,
                    layout,
                },
            );
        }

        let su = root
            .get("sign_update")
            .ok_or_else(|| anyhow!("manifest missing `sign_update`"))?;
        let sign_update_file = dir.join(
            su.get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("sign_update.file missing"))?,
        );
        let sign_update_chunk = su
            .get("chunk")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("sign_update.chunk missing"))?;

        Ok(Artifacts { dir: dir.to_path_buf(), presets, sign_update_file, sign_update_chunk })
    }

    /// Default artifacts dir: `$REPO/artifacts` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        let cand = PathBuf::from("artifacts");
        if cand.exists() {
            cand
        } else {
            PathBuf::from("../artifacts")
        }
    }

    pub fn preset(&self, name: &str) -> Result<&PresetInfo> {
        self.presets.get(name).ok_or_else(|| {
            anyhow!(
                "preset `{name}` not in manifest (have: {:?}); re-run `make artifacts`",
                self.presets.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Consistency invariant: layout offsets must tile [0, param_count).
    pub fn validate(&self) -> Result<()> {
        for (name, p) in &self.presets {
            let mut entries = p.layout.clone();
            entries.sort_by_key(|e| e.offset);
            let mut off = 0;
            for e in &entries {
                if e.offset != off {
                    bail!("{name}: layout gap at {off} (entry {} at {})", e.name, e.offset);
                }
                off += e.numel();
            }
            if off != p.param_count {
                bail!("{name}: layout covers {off} of {} params", p.param_count);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = Artifacts::default_dir();
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn loads_real_manifest_and_validates() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let arts = Artifacts::load(&dir).unwrap();
        arts.validate().unwrap();
        let nano = arts.preset("nano").unwrap();
        assert_eq!(nano.vocab, 256);
        assert_eq!(nano.seq, 64);
        assert!(nano.param_count > 100_000);
        assert!(nano.layout.iter().any(|e| e.name == "wte"));
        assert!(arts.sign_update_chunk >= 4096);
        assert!(arts.preset("nonexistent").is_err());
    }

    #[test]
    fn param_entry_numel() {
        let e = ParamEntry { name: "x".into(), offset: 0, shape: vec![3, 4, 5] };
        assert_eq!(e.numel(), 60);
    }
}
