//! Typed wrapper over one preset's init/train/eval executables.

use anyhow::{anyhow, Context, Result};

use super::{anyhow_xla, PresetInfo, Runtime, StepBackend};
use crate::data::dataset::Batch;

/// Result of one training step on one worker's minibatch.
pub struct StepOutput {
    pub loss: f32,
    pub grads: Vec<f32>,
}

/// Compiled init/train/eval for a model preset.
///
/// `Send + Sync`: the parallel worker fleet executes `train_step`
/// concurrently from several pool threads, one simulated rank per
/// thread, all sharing this bundle through an `Arc` (data-parallel
/// workers run the same program on different data — exactly how a real
/// cluster shares a compiled step function). PJRT loaded executables
/// are thread-safe (`execute` takes `&self` and the client serializes
/// device access internally), so sharing the compiled artifacts is the
/// cheap-replica strategy: zero copies, no recompilation per thread.
/// The `assert_threaded_fleet_contract` check below fails compilation
/// if a future binding swap silently loses this property.
pub struct ModelBundle {
    pub info: PresetInfo,
    init: xla::PjRtLoadedExecutable,
    train: xla::PjRtLoadedExecutable,
    eval: xla::PjRtLoadedExecutable,
}

/// Compile-time guard for the fleet threading contract (see the
/// [`ModelBundle`] docs): the trainer hands `Arc<dyn StepBackend>`
/// clones to pool threads, which requires `ModelBundle: Send + Sync`.
#[allow(dead_code)]
fn assert_threaded_fleet_contract() {
    fn requires_send_sync<T: Send + Sync>() {}
    requires_send_sync::<ModelBundle>();
}

impl ModelBundle {
    pub fn load(rt: &Runtime, info: &PresetInfo) -> Result<ModelBundle> {
        let compile = |p: &std::path::Path| {
            rt.compile_hlo_text(p).with_context(|| format!("compiling {p:?}"))
        };
        Ok(ModelBundle {
            info: info.clone(),
            init: compile(&info.init_file)?,
            train: compile(&info.train_file)?,
            eval: compile(&info.eval_file)?,
        })
    }

    /// Run the AOT'd GPT-2 initializer: seed -> flat f32[P].
    pub fn init_params(&self, seed: u32) -> Result<Vec<f32>> {
        let lit = xla::Literal::scalar(seed);
        let out = self.init.execute::<xla::Literal>(&[lit]).map_err(anyhow_xla)?;
        let tuple = out[0][0].to_literal_sync().map_err(anyhow_xla)?;
        let flat = tuple.to_tuple1().map_err(anyhow_xla)?;
        let params = flat.to_vec::<f32>().map_err(anyhow_xla)?;
        anyhow::ensure!(
            params.len() == self.info.param_count,
            "init returned {} params, manifest says {}",
            params.len(),
            self.info.param_count
        );
        Ok(params)
    }

    fn batch_literals(&self, batch: &Batch) -> Result<(xla::Literal, xla::Literal)> {
        anyhow::ensure!(
            batch.batch == self.info.batch && batch.seq == self.info.seq,
            "batch shape ({}, {}) does not match AOT shape ({}, {})",
            batch.batch,
            batch.seq,
            self.info.batch,
            self.info.seq
        );
        let dims = [batch.batch as i64, batch.seq as i64];
        let tok = xla::Literal::vec1(&batch.tokens).reshape(&dims).map_err(anyhow_xla)?;
        let tgt = xla::Literal::vec1(&batch.targets).reshape(&dims).map_err(anyhow_xla)?;
        Ok((tok, tgt))
    }

    /// One fwd+bwd: (params, batch) -> (loss, flat grads).
    pub fn train_step(&self, params: &[f32], batch: &Batch) -> Result<StepOutput> {
        anyhow::ensure!(params.len() == self.info.param_count, "param size mismatch");
        let p = xla::Literal::vec1(params);
        let (tok, tgt) = self.batch_literals(batch)?;
        let out = self.train.execute::<xla::Literal>(&[p, tok, tgt]).map_err(anyhow_xla)?;
        let tuple = out[0][0].to_literal_sync().map_err(anyhow_xla)?;
        let parts = tuple.to_tuple().map_err(anyhow_xla)?;
        let [loss_lit, grads_lit]: [xla::Literal; 2] = parts
            .try_into()
            .map_err(|v: Vec<_>| anyhow!("train artifact returned {}-tuple, expected 2", v.len()))?;
        let loss = loss_lit.to_vec::<f32>().map_err(anyhow_xla)?[0];
        let grads = grads_lit.to_vec::<f32>().map_err(anyhow_xla)?;
        Ok(StepOutput { loss, grads })
    }

    /// Loss-only forward pass (validation).
    pub fn eval_loss(&self, params: &[f32], batch: &Batch) -> Result<f32> {
        anyhow::ensure!(params.len() == self.info.param_count, "param size mismatch");
        let p = xla::Literal::vec1(params);
        let (tok, tgt) = self.batch_literals(batch)?;
        let out = self.eval.execute::<xla::Literal>(&[p, tok, tgt]).map_err(anyhow_xla)?;
        let tuple = out[0][0].to_literal_sync().map_err(anyhow_xla)?;
        let loss = tuple.to_tuple1().map_err(anyhow_xla)?;
        Ok(loss.to_vec::<f32>().map_err(anyhow_xla)?[0])
    }

}

// Batched eval (`eval_loss_many`) deliberately has no override or
// inherent twin: the trait default is the single copy of that serial
// loop. The trainer parallelizes ABOVE this interface — it fans the
// batches across the persistent pool and calls `eval_loss` per batch
// (`Trainer::evaluate`), so backends stay single-batch simple.
impl StepBackend for ModelBundle {
    fn info(&self) -> &PresetInfo {
        &self.info
    }

    fn init_params(&self, seed: u32) -> Result<Vec<f32>> {
        ModelBundle::init_params(self, seed)
    }

    fn train_step(&self, params: &[f32], batch: &Batch) -> Result<StepOutput> {
        ModelBundle::train_step(self, params, batch)
    }

    fn eval_loss(&self, params: &[f32], batch: &Batch) -> Result<f32> {
        ModelBundle::eval_loss(self, params, batch)
    }
}
