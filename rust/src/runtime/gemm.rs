//! Blocked f32 matmul microkernel shared by the native backends'
//! transformer forward and backward passes.
//!
//! # The fixed-reduction-order contract
//!
//! Every routine here computes each output element as a sum over the
//! contraction index in **ascending order, starting from 0.0** — the
//! exact per-element order the historical hand-rolled loops in
//! `runtime/native.rs` used. f32 addition is not associative, so this
//! order *is* the value: the parallel ≡ sequential differential tests
//! and the golden trajectories pin these bits, and any reordering (a
//! split accumulator, a pairwise tree, an FMA contraction) is a
//! correctness bug here, not an optimization.
//!
//! The speed therefore comes only from order-preserving structure:
//!
//! * [`axpy`] / [`axpy4`] walk rows of `B` contiguously (unit stride)
//!   instead of the naive dot's stride-`n` column walk, so the inner
//!   loop vectorizes;
//! * [`axpy4`] keeps the output element in a register across four
//!   consecutive contraction steps (register tiling) — it is bitwise
//!   identical to four sequential [`axpy`] calls by construction;
//! * [`matmul_blocked`] tiles the output into column blocks of
//!   [`NB`] elements so the accumulator row segment and the `B` panel
//!   stay cache-resident while the contraction streams over `k`.
//!
//! [`matmul_naive`] is the scalar reference: the differential tests
//! below require `matmul_blocked` ≡ `matmul_naive` **bitwise** on every
//! shape, and `benches/kernels.rs` records the speedup between them.

/// Output-column block width: `NB` f32 accumulators (1 KiB) per row
/// segment, small enough to stay in L1 across the `k` sweep.
pub const NB: usize = 256;

/// `acc[i] += a * x[i]` over the whole slice, ascending `i`.
///
/// Panics unless `x.len() == acc.len()` (the caller slices exactly).
pub fn axpy(acc: &mut [f32], a: f32, x: &[f32]) {
    assert_eq!(acc.len(), x.len(), "axpy: acc and x lengths must match");
    for (av, &xv) in acc.iter_mut().zip(x) {
        *av += a * xv;
    }
}

/// Four fused [`axpy`] steps: for each `i`,
/// `acc[i] = (((acc[i] + a[0]·x0[i]) + a[1]·x1[i]) + a[2]·x2[i]) + a[3]·x3[i]`
/// — left to right, so the result is bitwise identical to four
/// sequential `axpy` calls while the accumulator stays in a register.
pub fn axpy4(acc: &mut [f32], a: [f32; 4], x0: &[f32], x1: &[f32], x2: &[f32], x3: &[f32]) {
    let n = acc.len();
    assert!(
        x0.len() == n && x1.len() == n && x2.len() == n && x3.len() == n,
        "axpy4: all operand lengths must match the accumulator"
    );
    let (x0, x1, x2, x3) = (&x0[..n], &x1[..n], &x2[..n], &x3[..n]);
    for i in 0..n {
        let mut v = acc[i];
        v += a[0] * x0[i];
        v += a[1] * x1[i];
        v += a[2] * x2[i];
        v += a[3] * x3[i];
        acc[i] = v;
    }
}

/// Scalar reference matmul: `out[i,j] = Σ_kk a[i,kk]·b[kk,j]` with the
/// per-element sum running `kk`-ascending from 0.0 (row-major `m×k`
/// times `k×n` into `m×n`). The inner walk reads `b` at stride `n` —
/// this is the historical dot-product form the blocked kernel must
/// match bitwise and is expected to beat on throughput.
pub fn matmul_naive(out: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "matmul: a must be m×k");
    assert_eq!(b.len(), k * n, "matmul: b must be k×n");
    assert_eq!(out.len(), m * n, "matmul: out must be m×n");
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let mut acc = 0.0f32;
            for (kk, &av) in ar.iter().enumerate() {
                acc += av * b[kk * n + j];
            }
            out[i * n + j] = acc;
        }
    }
}

/// Blocked matmul, bitwise identical to [`matmul_naive`]: same shapes,
/// same per-element `kk`-ascending sums, restructured as column blocks
/// of [`NB`] with a `kk`-by-4 [`axpy4`] register tile and an [`axpy`]
/// tail. Zeroes `out` (so `k == 0` yields an all-zero product).
pub fn matmul_blocked(out: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "matmul: a must be m×k");
    assert_eq!(b.len(), k * n, "matmul: b must be k×n");
    assert_eq!(out.len(), m * n, "matmul: out must be m×n");
    if n == 0 {
        return;
    }
    let mut j0 = 0usize;
    while j0 < n {
        let jw = NB.min(n - j0);
        for i in 0..m {
            let or = &mut out[i * n + j0..i * n + j0 + jw];
            or.fill(0.0);
            let ar = &a[i * k..(i + 1) * k];
            let mut kk = 0usize;
            while kk + 4 <= k {
                axpy4(
                    or,
                    [ar[kk], ar[kk + 1], ar[kk + 2], ar[kk + 3]],
                    &b[kk * n + j0..kk * n + j0 + jw],
                    &b[(kk + 1) * n + j0..(kk + 1) * n + j0 + jw],
                    &b[(kk + 2) * n + j0..(kk + 2) * n + j0 + jw],
                    &b[(kk + 3) * n + j0..(kk + 3) * n + j0 + jw],
                );
                kk += 4;
            }
            while kk < k {
                axpy(or, ar[kk], &b[kk * n + j0..kk * n + j0 + jw]);
                kk += 1;
            }
        }
        j0 += NB;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn axpy4_is_bitwise_four_sequential_axpys() {
        let mut rng = Rng::new(71);
        for n in [0usize, 1, 3, 8, 257] {
            let a = [
                rng.normal_f32(0.0, 1.0),
                rng.normal_f32(0.0, 1.0),
                0.0,
                rng.normal_f32(0.0, 1e-20),
            ];
            let xs: Vec<Vec<f32>> = (0..4).map(|_| randn(&mut rng, n)).collect();
            let base = randn(&mut rng, n);
            let mut fused = base.clone();
            axpy4(&mut fused, a, &xs[0], &xs[1], &xs[2], &xs[3]);
            let mut seq = base.clone();
            for (av, x) in a.iter().zip(&xs) {
                axpy(&mut seq, *av, x);
            }
            for (f, s) in fused.iter().zip(&seq) {
                assert_eq!(f.to_bits(), s.to_bits(), "n={n}");
            }
        }
    }

    #[test]
    fn blocked_matches_naive_bitwise_on_every_shape_class() {
        // Shapes cross every structural case: k tail lengths 0..3, a
        // column count right at / above / far above one NB block, and
        // degenerate zero dims.
        let shapes = [
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (4, 4, 256),
            (2, 13, 300),
            (5, 64, 257),
            (7, 3, 512),
            (1, 2, 1000),
            (0, 3, 4),
            (3, 0, 4),
            (3, 4, 0),
        ];
        let mut rng = Rng::new(72);
        for (m, k, n) in shapes {
            let a = randn(&mut rng, m * k);
            let b = randn(&mut rng, k * n);
            let mut naive = vec![f32::NAN; m * n];
            let mut blocked = vec![f32::NAN; m * n];
            matmul_naive(&mut naive, &a, &b, m, k, n);
            matmul_blocked(&mut blocked, &a, &b, m, k, n);
            for (i, (x, y)) in naive.iter().zip(&blocked).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "({m},{k},{n}) elem {i}");
            }
        }
    }

    #[test]
    fn matmul_matches_a_hand_computed_product() {
        // [1 2; 3 4] · [5 6; 7 8] = [19 22; 43 50]
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [5.0f32, 6.0, 7.0, 8.0];
        let mut out = [0.0f32; 4];
        matmul_blocked(&mut out, &a, &b, 2, 2, 2);
        assert_eq!(out, [19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn identity_is_a_fixed_point() {
        let mut rng = Rng::new(73);
        let (m, d) = (3usize, 300usize);
        let a = randn(&mut rng, m * d);
        let mut eye = vec![0.0f32; d * d];
        for i in 0..d {
            eye[i * d + i] = 1.0;
        }
        let mut out = vec![0.0f32; m * d];
        matmul_blocked(&mut out, &a, &eye, m, d, d);
        for (x, y) in a.iter().zip(&out) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn zero_contraction_zeroes_the_output() {
        let mut out = vec![f32::NAN; 6];
        matmul_blocked(&mut out, &[], &[], 2, 0, 3);
        assert!(out.iter().all(|v| v.to_bits() == 0));
    }
}
