//! The parameter-layout contract: how one flat `f32[P]` vector tiles
//! into named tensor segments — and the validation that makes every
//! layer above the backend safe to trust it.
//!
//! The paper's method runs on a GPT-2 parameter vector whose blocks
//! (embeddings, attention, MLP, layernorm) have wildly different
//! difference magnitudes.  [`ParamLayout`] is the one place that fact
//! is represented: a **validated** list of [`ParamEntry`] segments that
//! must tile `[0, P)` contiguously, in offset order, with unique
//! non-empty names.  Construction is the proof — a `ParamLayout` in
//! hand means the invariants hold, so consumers index slices without
//! re-checking:
//!
//! * [`crate::runtime::StepBackend::layout`] — every backend advertises
//!   its layout (the manifest's `param_layout` for PJRT bundles, the
//!   built-in per-block segments for [`crate::runtime::NativeBundle`]);
//!   a manifest that omits the layout degrades to the documented
//!   [`ParamLayout::single`] fallback, a malformed one is a load error.
//! * [`crate::dist::WirePayload::QuantizedI8PerTensor`] — the `q8pt`
//!   wire format quantizes each segment against its own scale.
//! * [`crate::dist::Worker`] / [`crate::outer::WorkerView`] — per-rank
//!   state exposes per-segment slice views.
//! * [`crate::train::metrics::segment_norms`] — per-segment
//!   update/diff norms for the comm-savings tables.

use anyhow::{bail, Result};

/// One named tensor inside the flat parameter vector.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub offset: usize,
    pub shape: Vec<usize>,
}

impl ParamEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A validated parameter layout: named segments tiling `[0, P)`.
///
/// Invariants (checked by [`ParamLayout::from_entries`], assumed
/// everywhere else): entries are in offset order, each begins exactly
/// where the previous one ends, the first begins at 0, the total count
/// equals `param_count`, and names are unique and non-empty.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamLayout {
    entries: Vec<ParamEntry>,
    param_count: usize,
}

impl ParamLayout {
    /// Validate `entries` as a layout of a `param_count`-dimensional
    /// vector. Entries may arrive in any order (they are sorted by
    /// offset); any gap, overlap, total mismatch, duplicate or empty
    /// name is a real error — the silent-acceptance path this replaces
    /// let malformed manifests through as "no layout".
    pub fn from_entries(mut entries: Vec<ParamEntry>, param_count: usize) -> Result<ParamLayout> {
        entries.sort_by_key(|e| e.offset);
        let mut seen = std::collections::BTreeSet::new();
        let mut off = 0usize;
        for e in &entries {
            if e.name.is_empty() {
                bail!("layout entry at offset {} has an empty name", e.offset);
            }
            if !seen.insert(e.name.clone()) {
                bail!("duplicate layout entry `{}`", e.name);
            }
            if e.offset != off {
                bail!(
                    "layout gap/overlap at offset {off}: entry `{}` starts at {}",
                    e.name,
                    e.offset
                );
            }
            off += e.numel();
        }
        if off != param_count {
            bail!("layout covers {off} of {param_count} params");
        }
        Ok(ParamLayout { entries, param_count })
    }

    /// The degenerate one-segment layout — the documented fallback for
    /// manifests that omit `param_layout`, and the layout under which
    /// per-tensor quantization is definitionally identical to the
    /// per-message `q8` format.
    pub fn single(param_count: usize) -> ParamLayout {
        ParamLayout {
            entries: vec![ParamEntry {
                name: "params".to_string(),
                offset: 0,
                shape: vec![param_count],
            }],
            param_count,
        }
    }

    /// Total coordinates the layout tiles (the flat vector's P).
    pub fn param_count(&self) -> usize {
        self.param_count
    }

    /// Number of segments.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn entries(&self) -> &[ParamEntry] {
        &self.entries
    }

    pub fn iter(&self) -> std::slice::Iter<'_, ParamEntry> {
        self.entries.iter()
    }

    /// Coordinate range of segment `i` in the flat vector.
    pub fn range(&self, i: usize) -> std::ops::Range<usize> {
        let e = &self.entries[i];
        e.offset..e.offset + e.numel()
    }

    /// Segment `i` of a flat vector laid out by this layout.
    pub fn slice_of<'v>(&self, i: usize, v: &'v [f32]) -> &'v [f32] {
        &v[self.range(i)]
    }

    /// `(name, slice)` views of every segment of `v`, in offset order.
    /// `v.len()` must equal [`ParamLayout::param_count`].
    pub fn segments_of<'s, 'v>(&'s self, v: &'v [f32]) -> Vec<(&'s str, &'v [f32])> {
        assert_eq!(
            v.len(),
            self.param_count,
            "vector has {} coordinates, layout tiles {}",
            v.len(),
            self.param_count
        );
        self.entries
            .iter()
            .map(|e| (e.name.as_str(), &v[e.offset..e.offset + e.numel()]))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(name: &str, offset: usize, shape: &[usize]) -> ParamEntry {
        ParamEntry { name: name.into(), offset, shape: shape.to_vec() }
    }

    #[test]
    fn param_entry_numel() {
        assert_eq!(entry("x", 0, &[3, 4, 5]).numel(), 60);
        assert_eq!(entry("scalar-ish", 0, &[]).numel(), 1);
    }

    #[test]
    fn valid_layout_constructs_and_sorts() {
        // entries deliberately out of offset order
        let entries = vec![entry("b", 6, &[2, 2]), entry("a", 0, &[2, 3])];
        let l = ParamLayout::from_entries(entries, 10).unwrap();
        assert_eq!(l.len(), 2);
        assert_eq!(l.param_count(), 10);
        assert_eq!(l.entries()[0].name, "a");
        assert_eq!(l.range(0), 0..6);
        assert_eq!(l.range(1), 6..10);
        let v: Vec<f32> = (0..10).map(|i| i as f32).collect();
        assert_eq!(l.slice_of(1, &v), &v[6..10]);
        let segs = l.segments_of(&v);
        assert_eq!(segs.len(), 2);
        assert_eq!(segs[0].0, "a");
        assert_eq!(segs[1].1, &v[6..10]);
    }

    #[test]
    fn gaps_overlaps_and_totals_are_errors() {
        // gap: second entry starts at 7, first ends at 6
        let gap = vec![entry("a", 0, &[6]), entry("b", 7, &[3])];
        assert!(ParamLayout::from_entries(gap, 10).is_err());
        // overlap: second entry starts inside the first
        let overlap = vec![entry("a", 0, &[6]), entry("b", 4, &[6])];
        assert!(ParamLayout::from_entries(overlap, 10).is_err());
        // total mismatch
        assert!(ParamLayout::from_entries(vec![entry("a", 0, &[6])], 10).is_err());
        // first entry must start at zero
        assert!(ParamLayout::from_entries(vec![entry("a", 2, &[8])], 10).is_err());
        // declared-but-empty layout of a non-empty vector
        assert!(ParamLayout::from_entries(Vec::new(), 10).is_err());
    }

    #[test]
    fn names_must_be_unique_and_non_empty() {
        let dup = vec![entry("a", 0, &[4]), entry("a", 4, &[4])];
        assert!(ParamLayout::from_entries(dup, 8).is_err());
        assert!(ParamLayout::from_entries(vec![entry("", 0, &[8])], 8).is_err());
    }

    #[test]
    fn single_segment_fallback_tiles_everything() {
        let l = ParamLayout::single(37);
        assert_eq!(l.len(), 1);
        assert_eq!(l.param_count(), 37);
        assert_eq!(l.range(0), 0..37);
        assert_eq!(l.entries()[0].name, "params");
        // and it round-trips through the validator
        let rebuilt = ParamLayout::from_entries(l.entries().to_vec(), 37).unwrap();
        assert_eq!(rebuilt, l);
    }
}
