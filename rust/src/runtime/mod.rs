//! PJRT runtime: load AOT'd HLO-text artifacts and execute them from the
//! training hot path.  Python is never invoked here — `make artifacts`
//! produced the HLO text once; this module compiles it on the PJRT CPU
//! client and provides typed wrappers:
//!
//! * [`Artifacts`] — parses `artifacts/manifest.json` (shapes, parameter
//!   layout, file index) via the in-tree JSON substrate; the layout is
//!   **validated at load time** into a [`ParamLayout`].
//! * [`ParamLayout`] — the parameter-layout contract: named segments
//!   tiling the flat `f32[P]` vector, proven contiguous/sorted/complete
//!   at construction. Every backend advertises one
//!   ([`StepBackend::layout`]); the layout-aware wire format
//!   (`q8pt`, [`crate::dist::WirePayload::QuantizedI8PerTensor`]),
//!   per-segment worker views, and the per-segment metrics all consume
//!   it without re-checking.
//! * [`ModelBundle`] — init/train/eval executables for one model preset
//!   with `Vec<f32>`-level ergonomics (flat params ABI).
//! * [`SignUpdateKernel`] — the AOT'd fused Pallas sign-momentum kernel,
//!   applied chunk-wise over arbitrarily sized parameter vectors.
//! * [`StepBackend`] — the compute contract the trainer drives
//!   (`Send + Sync`: the parallel worker fleet shares one backend
//!   across pool threads); implemented by [`ModelBundle`] and by
//!   [`NativeBundle`], a pure-Rust backend (one-hidden-layer MLP LM, or
//!   a true multi-layer transformer via [`NativeBundle::transformer`])
//!   that needs no PJRT at all and whose transformer layout has
//!   per-block named segments.
//! * [`gemm`] — the blocked f32 matmul microkernel behind the native
//!   transformer's forward/backward: faster through unit-stride axpy
//!   rows, register tiling, and cache blocking, while preserving the
//!   per-element ascending-`k` reduction order bitwise (the golden
//!   trajectories pin those bits).
//!
//! Interchange is HLO *text*: jax ≥ 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1's proto path rejects; the text parser reassigns
//! ids (see python/compile/aot.py and /opt/xla-example/README.md).

mod artifacts;
mod bundle;
pub mod gemm;
mod layout;
mod native;
mod sign_kernel;

pub use artifacts::{Artifacts, PresetInfo};
pub use bundle::{ModelBundle, StepOutput};
pub use layout::{ParamEntry, ParamLayout};
pub use native::NativeBundle;
pub use sign_kernel::{SignUpdateKernel, SignUpdateScalars};

use anyhow::Result;

use crate::data::dataset::Batch;

/// The compute contract the trainer drives: init / fwd+bwd / eval over
/// the flat `f32[P]` parameter vector.
///
/// # Threading contract
///
/// `Send + Sync` is part of the trait: the parallel worker fleet
/// (`dist::pool::run_indexed_mut`) calls [`StepBackend::train_step`]
/// concurrently from several pool threads, one simulated rank per
/// thread, all sharing one backend through an `Arc`. Implementations
/// must therefore be safe to execute from any thread with `&self` —
/// PJRT loaded executables satisfy this (PJRT clients are thread-safe
/// and `execute` takes shared references); a binding that is not
/// thread-safe must synchronize internally rather than relying on the
/// coordinator thread, because there no longer is a single compute
/// thread.
pub trait StepBackend: Send + Sync {
    /// Static model description (shapes, parameter count, preset name).
    fn info(&self) -> &PresetInfo;

    /// The validated parameter layout the flat `f32[P]` vector follows
    /// — the contract consumed by the layout-aware wire format, the
    /// per-segment worker views, and the per-segment metrics. Already
    /// proven contiguous/sorted/complete at construction
    /// ([`ParamLayout::from_entries`]): `layout().param_count()` always
    /// equals `info().param_count`.
    fn layout(&self) -> &ParamLayout {
        &self.info().layout
    }

    /// Deterministic parameter initialization: seed -> flat f32[P].
    fn init_params(&self, seed: u32) -> Result<Vec<f32>>;

    /// One fwd+bwd pass: (params, batch) -> (loss, flat grads).
    fn train_step(&self, params: &[f32], batch: &Batch) -> Result<StepOutput>;

    /// Loss-only forward pass (validation).
    fn eval_loss(&self, params: &[f32], batch: &Batch) -> Result<f32>;

    /// Mean eval loss over several batches.
    fn eval_loss_many(&self, params: &[f32], batches: &[Batch]) -> Result<f64> {
        anyhow::ensure!(!batches.is_empty());
        let mut acc = 0.0f64;
        for b in batches {
            acc += self.eval_loss(params, b)? as f64;
        }
        Ok(acc / batches.len() as f64)
    }
}

/// Shared PJRT CPU client.  One per process; executables keep an internal
/// clone handle, so `Runtime` is cheap to pass around by reference.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(anyhow_xla)?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one HLO-text artifact into a loaded executable.
    pub fn compile_hlo_text(&self, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path).map_err(anyhow_xla)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).map_err(anyhow_xla)
    }
}

/// The xla crate's error type does not implement std::error::Error's
/// source chain the way anyhow wants; stringify at the boundary.
pub(crate) fn anyhow_xla(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_boots() {
        let rt = Runtime::cpu().unwrap();
        assert!(!rt.platform().is_empty());
    }
}
