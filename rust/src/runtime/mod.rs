//! PJRT runtime: load AOT'd HLO-text artifacts and execute them from the
//! training hot path.  Python is never invoked here — `make artifacts`
//! produced the HLO text once; this module compiles it on the PJRT CPU
//! client and provides typed wrappers:
//!
//! * [`Artifacts`] — parses `artifacts/manifest.json` (shapes, parameter
//!   layout, file index) via the in-tree JSON substrate.
//! * [`ModelBundle`] — init/train/eval executables for one model preset
//!   with `Vec<f32>`-level ergonomics (flat params ABI).
//! * [`SignUpdateKernel`] — the AOT'd fused Pallas sign-momentum kernel,
//!   applied chunk-wise over arbitrarily sized parameter vectors.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1's proto path rejects; the text parser reassigns
//! ids (see python/compile/aot.py and /opt/xla-example/README.md).

mod artifacts;
mod bundle;
mod sign_kernel;

pub use artifacts::{Artifacts, ParamEntry, PresetInfo};
pub use bundle::{ModelBundle, StepOutput};
pub use sign_kernel::{SignUpdateKernel, SignUpdateScalars};

use anyhow::Result;

/// Shared PJRT CPU client.  One per process; executables keep an internal
/// clone handle, so `Runtime` is cheap to pass around by reference.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(anyhow_xla)?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one HLO-text artifact into a loaded executable.
    pub fn compile_hlo_text(&self, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path).map_err(anyhow_xla)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).map_err(anyhow_xla)
    }
}

/// The xla crate's error type does not implement std::error::Error's
/// source chain the way anyhow wants; stringify at the boundary.
pub(crate) fn anyhow_xla(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_boots() {
        let rt = Runtime::cpu().unwrap();
        assert!(!rt.platform().is_empty());
    }
}
