//! Native (pure-Rust) [`StepBackend`]s with exact gradients and no PJRT
//! required: a one-hidden-layer MLP language model
//! ([`NativeBundle::new`]) and a true multi-layer transformer byte LM
//! ([`NativeBundle::transformer`]).
//!
//! The AOT'd GPT-2 artifacts need a real PJRT backend; these in-tree
//! fallbacks give every trainer-level code path — the parallel worker
//! fleet, checkpoint resume, the simulated clock, all outer optimizers,
//! every wire format — a fully deterministic compute engine that runs
//! anywhere the crate builds. Differential tests
//! (`rust/tests/parallel_fleet.rs`) and the trainer bench
//! (`benches/trainer.rs`) drive the trainer through them.
//!
//! # MLP architecture ([`NativeBundle::new`])
//!
//! Per position, a tanh hidden layer over a byte embedding followed by
//! a softmax over the 256-way vocabulary,
//!
//! ```text
//!     h = tanh(E[x])          E: 256 × D   (embedding)
//!     z = hᵀ W                W: D × 256   (output projection)
//!     loss = CE(softmax(z), y)
//! ```
//!
//! # Transformer architecture ([`NativeBundle::transformer`])
//!
//! A GPT-shaped byte LM: token + learned position embeddings, then
//! `n_layer` pre-norm-free residual blocks of single-head causal
//! attention and a tanh MLP, then a linear head:
//!
//! ```text
//!     X₀[t]   = Etok[x_t] + Epos[t]
//!     per block l:
//!       Q,K,V = X Wq, X Wk, X Wv                 (D × D each)
//!       A[t,·]= softmax(Q[t]·K[u≤t] / √D)        (causal)
//!       X     = X + (A V) Wo                     (attention + residual)
//!       X     = X + tanh(X W1) W2                (MLP + residual, F = 4D)
//!     logits[t] = X[t] · Wout                    (D × 256)
//! ```
//!
//! with exact hand-derived backward passes through the head, both
//! residual branches of every block (including the causal-softmax
//! attention), and both embedding tables — finite-difference-tested
//! across every segment in the unit tests below. Its [`ParamLayout`]
//! has per-block named segments (`block{l}.attn.wq`, `block{l}.mlp.w1`,
//! ...), which makes layouts non-trivial offline: the per-tensor `q8pt`
//! wire format and the per-segment metrics have something real to
//! resolve without PJRT artifacts.
//!
//! Every operation is scalar f32 with a fixed accumulation order (loss
//! accumulates in f64), so both architectures are bit-deterministic for
//! a given (params, batch) on a given host — the property the
//! parallel ≡ sequential differential tests pin. The transformer's
//! matrix products run through the blocked [`super::gemm`] microkernel,
//! which is bitwise identical to the historical hand-rolled dots
//! because it preserves each output element's ascending contraction
//! order (see the `gemm` module docs for the contract).

use anyhow::Result;

use super::{gemm, ParamEntry, ParamLayout, PresetInfo, StepBackend, StepOutput};
use crate::data::dataset::Batch;
use crate::util::rng::Rng;

const VOCAB: usize = 256;

/// Which forward/backward pair a [`NativeBundle`] runs.
enum Arch {
    /// The original 2-matrix tanh-MLP LM (bit-identical to the
    /// pre-transformer `NativeBundle` — existing presets and their
    /// golden trajectories are untouched).
    Mlp,
    /// `n_layer` blocks of single-head causal attention + tanh MLP with
    /// residual streams; `d_ff` is the MLP hidden width (4·D).
    Transformer { n_layer: usize, d_ff: usize },
}

/// Pure-Rust LM backend. Stateless across steps (all state lives in
/// the flat parameter vector), hence trivially `Send + Sync`.
pub struct NativeBundle {
    info: PresetInfo,
    d_model: usize,
    arch: Arch,
}

fn push_entry(entries: &mut Vec<ParamEntry>, off: &mut usize, name: String, shape: Vec<usize>) {
    let numel: usize = shape.iter().product();
    entries.push(ParamEntry { name, offset: *off, shape });
    *off += numel;
}

impl NativeBundle {
    /// Build the MLP backend whose [`PresetInfo`] advertises
    /// `param_count = 2 · 256 · d_model` (embedding + output matrices)
    /// over a two-segment layout (`native.embed`, `native.out`).
    pub fn new(name: &str, batch: usize, seq: usize, d_model: usize) -> NativeBundle {
        assert!(d_model >= 1 && batch >= 1 && seq >= 1);
        let param_count = 2 * VOCAB * d_model;
        let mut entries = Vec::new();
        let mut off = 0usize;
        push_entry(&mut entries, &mut off, "native.embed".into(), vec![VOCAB, d_model]);
        push_entry(&mut entries, &mut off, "native.out".into(), vec![d_model, VOCAB]);
        let layout = match ParamLayout::from_entries(entries, param_count) {
            Ok(l) => l,
            Err(e) => unreachable!("MLP layout is tiled by construction: {e}"),
        };
        NativeBundle {
            info: PresetInfo {
                name: name.to_string(),
                vocab: VOCAB,
                d_model,
                n_head: 1,
                n_layer: 1,
                seq,
                batch,
                param_count,
                init_file: std::path::PathBuf::new(),
                train_file: std::path::PathBuf::new(),
                eval_file: std::path::PathBuf::new(),
                layout,
            },
            d_model,
            arch: Arch::Mlp,
        }
    }

    /// Build the multi-layer transformer backend (see the module docs
    /// for the architecture). Its layout tiles
    ///
    /// ```text
    ///   embed.tok [256, D] | embed.pos [S, D]
    ///   | per block l: block{l}.attn.{wq,wk,wv,wo} [D, D],
    ///                  block{l}.mlp.w1 [D, 4D], block{l}.mlp.w2 [4D, D]
    ///   | head.out [D, 256]
    /// ```
    ///
    /// so `param_count = 256·D + S·D + n_layer·(4D² + 8D²) + 256·D`.
    pub fn transformer(
        name: &str,
        batch: usize,
        seq: usize,
        d_model: usize,
        n_layer: usize,
    ) -> NativeBundle {
        assert!(d_model >= 1 && batch >= 1 && seq >= 1 && n_layer >= 1);
        let d = d_model;
        let d_ff = 4 * d;
        let mut entries = Vec::new();
        let mut off = 0usize;
        push_entry(&mut entries, &mut off, "embed.tok".into(), vec![VOCAB, d]);
        push_entry(&mut entries, &mut off, "embed.pos".into(), vec![seq, d]);
        for l in 0..n_layer {
            for w in ["wq", "wk", "wv", "wo"] {
                push_entry(&mut entries, &mut off, format!("block{l}.attn.{w}"), vec![d, d]);
            }
            push_entry(&mut entries, &mut off, format!("block{l}.mlp.w1"), vec![d, d_ff]);
            push_entry(&mut entries, &mut off, format!("block{l}.mlp.w2"), vec![d_ff, d]);
        }
        push_entry(&mut entries, &mut off, "head.out".into(), vec![d, VOCAB]);
        let param_count = off;
        let layout = match ParamLayout::from_entries(entries, param_count) {
            Ok(l) => l,
            Err(e) => unreachable!("transformer layout is tiled by construction: {e}"),
        };
        NativeBundle {
            info: PresetInfo {
                name: name.to_string(),
                vocab: VOCAB,
                d_model,
                n_head: 1,
                n_layer,
                seq,
                batch,
                param_count,
                init_file: std::path::PathBuf::new(),
                train_file: std::path::PathBuf::new(),
                eval_file: std::path::PathBuf::new(),
                layout,
            },
            d_model,
            arch: Arch::Transformer { n_layer, d_ff },
        }
    }

    fn check_shapes(&self, params: &[f32], batch: &Batch) -> Result<()> {
        anyhow::ensure!(
            params.len() == self.info.param_count,
            "param size mismatch: {} vs {}",
            params.len(),
            self.info.param_count
        );
        anyhow::ensure!(
            batch.batch == self.info.batch && batch.seq == self.info.seq,
            "batch shape ({}, {}) does not match native shape ({}, {})",
            batch.batch,
            batch.seq,
            self.info.batch,
            self.info.seq
        );
        Ok(())
    }

    fn pass(&self, params: &[f32], batch: &Batch, grads: Option<&mut [f32]>) -> Result<f64> {
        match self.arch {
            Arch::Mlp => self.pass_mlp(params, batch, grads),
            Arch::Transformer { n_layer, d_ff } => {
                self.pass_transformer(params, batch, grads, n_layer, d_ff)
            }
        }
    }

    /// MLP forward (and optionally backward) over every position.
    /// Returns the mean cross-entropy; fills `grads` when given.
    fn pass_mlp(
        &self,
        params: &[f32],
        batch: &Batch,
        mut grads: Option<&mut [f32]>,
    ) -> Result<f64> {
        let d = self.d_model;
        let (embed, out_w) = params.split_at(VOCAB * d);
        let positions = batch.batch * batch.seq;
        let inv_pos = 1.0f32 / positions as f32;

        let mut h = vec![0.0f32; d];
        let mut logits = vec![0.0f32; VOCAB];
        let mut loss_acc = 0.0f64;

        for pos in 0..positions {
            let x = batch.tokens[pos];
            let y = batch.targets[pos];
            anyhow::ensure!(
                (0..VOCAB as i32).contains(&x) && (0..VOCAB as i32).contains(&y),
                "token {x}/{y} outside the byte vocabulary"
            );
            let (x, y) = (x as usize, y as usize);

            // h = tanh(E[x]);  z = hᵀ W
            for (hj, &e) in h.iter_mut().zip(&embed[x * d..(x + 1) * d]) {
                *hj = e.tanh();
            }
            logits.fill(0.0);
            for (j, &hj) in h.iter().enumerate() {
                for (zl, &w) in logits.iter_mut().zip(&out_w[j * VOCAB..(j + 1) * VOCAB]) {
                    *zl += hj * w;
                }
            }

            // stable softmax cross-entropy
            let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut z_sum = 0.0f32;
            for zl in logits.iter_mut() {
                *zl = (*zl - m).exp();
                z_sum += *zl;
            }
            // -ln p_y with logits[l] now holding exp(z_l - m)
            loss_acc += (z_sum.ln() - logits[y].ln()) as f64;
            let Some(g) = grads.as_deref_mut() else { continue };

            // dz = softmax(z) - onehot(y), scaled to the positional mean
            let inv_z = 1.0 / z_sum;
            for zl in logits.iter_mut() {
                *zl *= inv_z * inv_pos;
            }
            logits[y] -= inv_pos;

            let (g_embed, g_out) = g.split_at_mut(VOCAB * d);
            // dW[j, :] += h[j] · dz ;  dh[j] = Σ_l W[j, l] dz[l]
            for (j, &hj) in h.iter().enumerate() {
                let w_row = &out_w[j * VOCAB..(j + 1) * VOCAB];
                let gw_row = &mut g_out[j * VOCAB..(j + 1) * VOCAB];
                let mut dh = 0.0f32;
                for ((gw, &w), &dz) in gw_row.iter_mut().zip(w_row).zip(logits.iter()) {
                    *gw += hj * dz;
                    dh += w * dz;
                }
                // dE[x, j] = dh[j] · (1 - h[j]²)
                g_embed[x * d + j] += dh * (1.0 - hj * hj);
            }
        }
        Ok(loss_acc / positions as f64)
    }

    /// Transformer forward (and optionally backward) — see the module
    /// docs for the architecture and the gradient derivation sketch.
    /// Gradient offsets mirror the parameter offsets exactly (same flat
    /// layout), so every `g[off + ..] +=` below writes the segment the
    /// layout names.
    fn pass_transformer(
        &self,
        params: &[f32],
        batch: &Batch,
        mut grads: Option<&mut [f32]>,
        n_layer: usize,
        f: usize,
    ) -> Result<f64> {
        let d = self.d_model;
        let s = self.info.seq;
        let positions = batch.batch * s;
        let inv_pos = 1.0f32 / positions as f32;
        let att_scale = 1.0 / (d as f32).sqrt();

        for pos in 0..positions {
            let (x, y) = (batch.tokens[pos], batch.targets[pos]);
            anyhow::ensure!(
                (0..VOCAB as i32).contains(&x) && (0..VOCAB as i32).contains(&y),
                "token {x}/{y} outside the byte vocabulary"
            );
        }

        // flat parameter offsets (== gradient offsets)
        let tok0 = 0usize;
        let pos0 = VOCAB * d;
        let blocks0 = pos0 + s * d;
        let stride = 4 * d * d + 2 * d * f;
        let head0 = blocks0 + n_layer * stride;
        let offs = |l: usize| {
            let wq0 = blocks0 + l * stride;
            let wk0 = wq0 + d * d;
            let wv0 = wk0 + d * d;
            let wo0 = wv0 + d * d;
            let w10 = wo0 + d * d;
            let w20 = w10 + d * f;
            (wq0, wk0, wv0, wo0, w10, w20)
        };

        // activations saved for the backward pass, per block
        let mut x = vec![0.0f32; s * d];
        let mut xin = vec![vec![0.0f32; s * d]; n_layer];
        let mut qb = vec![vec![0.0f32; s * d]; n_layer];
        let mut kb = vec![vec![0.0f32; s * d]; n_layer];
        let mut vb = vec![vec![0.0f32; s * d]; n_layer];
        let mut ab = vec![vec![0.0f32; s * s]; n_layer];
        let mut ctxb = vec![vec![0.0f32; s * d]; n_layer];
        let mut xmidb = vec![vec![0.0f32; s * d]; n_layer];
        let mut hhb = vec![vec![0.0f32; s * f]; n_layer];
        // scratch
        let mut row = vec![0.0f32; s];
        let mut logits = vec![0.0f32; s * VOCAB];
        let mut resid = vec![0.0f32; s * d];
        let mut pre = vec![0.0f32; s * f];
        let mut dx = vec![0.0f32; s * d];
        let mut dxmid = vec![0.0f32; s * d];
        let mut dctx = vec![0.0f32; s * d];
        let mut dq = vec![0.0f32; s * d];
        let mut dk = vec![0.0f32; s * d];
        let mut dv = vec![0.0f32; s * d];
        let mut da = vec![0.0f32; s * s];
        let mut dpre = vec![0.0f32; s * f];

        let mut loss_acc = 0.0f64;
        for b in 0..batch.batch {
            let base = b * s;

            // ---- forward ----
            // X₀ = Etok[x_t] + Epos[t]
            for t in 0..s {
                let xt = batch.tokens[base + t] as usize;
                for j in 0..d {
                    x[t * d + j] = params[tok0 + xt * d + j] + params[pos0 + t * d + j];
                }
            }
            for l in 0..n_layer {
                let (wq0, wk0, wv0, wo0, w10, w20) = offs(l);
                xin[l].copy_from_slice(&x);
                // Q, K, V = X Wq, X Wk, X Wv — blocked GEMM, bitwise
                // equal to the historical per-element dots (same
                // j-ascending sum per output element)
                gemm::matmul_blocked(&mut qb[l], &x, &params[wq0..wq0 + d * d], s, d, d);
                gemm::matmul_blocked(&mut kb[l], &x, &params[wk0..wk0 + d * d], s, d, d);
                gemm::matmul_blocked(&mut vb[l], &x, &params[wv0..wv0 + d * d], s, d, d);
                // causal softmax attention + context
                for t in 0..s {
                    let mut m = f32::NEG_INFINITY;
                    for (u, r) in row.iter_mut().enumerate().take(t + 1) {
                        let mut sc = 0.0f32;
                        for j in 0..d {
                            sc += qb[l][t * d + j] * kb[l][u * d + j];
                        }
                        *r = sc * att_scale;
                        m = m.max(*r);
                    }
                    let mut z = 0.0f32;
                    for r in row.iter_mut().take(t + 1) {
                        *r = (*r - m).exp();
                        z += *r;
                    }
                    let inv = 1.0 / z;
                    for u in 0..=t {
                        ab[l][t * s + u] = row[u] * inv;
                    }
                    // context row: zero + one axpy per attended position
                    // (u-ascending — the historical per-element order)
                    let ctx_row = &mut ctxb[l][t * d..(t + 1) * d];
                    ctx_row.fill(0.0);
                    for u in 0..=t {
                        gemm::axpy(ctx_row, ab[l][t * s + u], &vb[l][u * d..(u + 1) * d]);
                    }
                }
                // attention residual: X += Ctx · Wo (compute the whole
                // product, then add — per element still "one dot, one
                // add", so the bits match the fused historical loop)
                gemm::matmul_blocked(&mut resid, &ctxb[l], &params[wo0..wo0 + d * d], s, d, d);
                for (xv, &r) in x.iter_mut().zip(resid.iter()) {
                    *xv += r;
                }
                xmidb[l].copy_from_slice(&x);
                // MLP residual: X += tanh(X W1) W2
                gemm::matmul_blocked(&mut pre, &xmidb[l], &params[w10..w10 + d * f], s, d, f);
                for (h, &p) in hhb[l].iter_mut().zip(pre.iter()) {
                    *h = p.tanh();
                }
                gemm::matmul_blocked(&mut resid, &hhb[l], &params[w20..w20 + f * d], s, f, d);
                for (xv, &r) in x.iter_mut().zip(resid.iter()) {
                    *xv += r;
                }
            }

            // ---- head: loss per position (+ dWout, dX when training) ----
            // one blocked GEMM for every position's logits, then the
            // softmax/CE runs per row exactly as before
            gemm::matmul_blocked(&mut logits, &x, &params[head0..head0 + d * VOCAB], s, d, VOCAB);
            for t in 0..s {
                let y = batch.targets[base + t] as usize;
                let zrow = &mut logits[t * VOCAB..(t + 1) * VOCAB];
                let m = zrow.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                let mut z_sum = 0.0f32;
                for zl in zrow.iter_mut() {
                    *zl = (*zl - m).exp();
                    z_sum += *zl;
                }
                loss_acc += (z_sum.ln() - zrow[y].ln()) as f64;
                let Some(g) = grads.as_deref_mut() else { continue };

                let inv_z = 1.0 / z_sum;
                for zl in zrow.iter_mut() {
                    *zl *= inv_z * inv_pos;
                }
                zrow[y] -= inv_pos;
                // the historical fused loop split: dWout rows become
                // axpys, the dX dot stays a serial c-ascending sum
                for j in 0..d {
                    let xv = x[t * d + j];
                    gemm::axpy(&mut g[head0 + j * VOCAB..head0 + (j + 1) * VOCAB], xv, zrow);
                    let mut acc = 0.0f32;
                    for (c, &dz) in zrow.iter().enumerate() {
                        acc += params[head0 + j * VOCAB + c] * dz;
                    }
                    dx[t * d + j] = acc;
                }
            }
            let Some(g) = grads.as_deref_mut() else { continue };

            // ---- backward through the blocks, top down ----
            for l in (0..n_layer).rev() {
                let (wq0, wk0, wv0, wo0, w10, w20) = offs(l);
                // MLP: x_out = xmid + tanh(xmid W1) W2. Each fused
                // weight-grad + input-grad loop below is split into an
                // axpy (the weight row) and a serial dot (the input
                // grad); per-element accumulation orders are unchanged.
                for t in 0..s {
                    for mth in 0..f {
                        let h = hhb[l][t * f + mth];
                        let gw2 = &mut g[w20 + mth * d..w20 + (mth + 1) * d];
                        gemm::axpy(gw2, h, &dx[t * d..(t + 1) * d]);
                        let mut dh = 0.0f32;
                        for j in 0..d {
                            dh += params[w20 + mth * d + j] * dx[t * d + j];
                        }
                        dpre[t * f + mth] = dh * (1.0 - h * h);
                    }
                }
                for t in 0..s {
                    for j in 0..d {
                        let xm = xmidb[l][t * d + j];
                        let gw1 = &mut g[w10 + j * f..w10 + (j + 1) * f];
                        gemm::axpy(gw1, xm, &dpre[t * f..(t + 1) * f]);
                        let mut acc = 0.0f32;
                        for mth in 0..f {
                            acc += params[w10 + j * f + mth] * dpre[t * f + mth];
                        }
                        dxmid[t * d + j] = dx[t * d + j] + acc;
                    }
                }
                // attention: xmid = xin + (A V) Wo
                for t in 0..s {
                    for j2 in 0..d {
                        let c = ctxb[l][t * d + j2];
                        let gwo = &mut g[wo0 + j2 * d..wo0 + (j2 + 1) * d];
                        gemm::axpy(gwo, c, &dxmid[t * d..(t + 1) * d]);
                        let mut acc = 0.0f32;
                        for j in 0..d {
                            acc += params[wo0 + j2 * d + j] * dxmid[t * d + j];
                        }
                        dctx[t * d + j2] = acc;
                    }
                }
                dv.fill(0.0);
                for t in 0..s {
                    for u in 0..=t {
                        let a_tu = ab[l][t * s + u];
                        gemm::axpy(&mut dv[u * d..(u + 1) * d], a_tu, &dctx[t * d..(t + 1) * d]);
                        let mut acc = 0.0f32;
                        for j in 0..d {
                            acc += dctx[t * d + j] * vb[l][u * d + j];
                        }
                        da[t * s + u] = acc;
                    }
                    // softmax backward, row t: ds = a ∘ (da − Σ a·da)
                    let mut dot = 0.0f32;
                    for u in 0..=t {
                        dot += ab[l][t * s + u] * da[t * s + u];
                    }
                    for u in 0..=t {
                        da[t * s + u] = ab[l][t * s + u] * (da[t * s + u] - dot);
                    }
                }
                // dQ rows accumulate u-ascending over K rows, dK rows
                // mirror as the outer product over Q rows — the same
                // per-element orders the fused historical loop produced
                dk.fill(0.0);
                for t in 0..s {
                    let dq_row = &mut dq[t * d..(t + 1) * d];
                    dq_row.fill(0.0);
                    for u in 0..=t {
                        gemm::axpy(dq_row, da[t * s + u], &kb[l][u * d..(u + 1) * d]);
                    }
                    for dqv in dq_row.iter_mut() {
                        *dqv *= att_scale;
                    }
                    let q_row = &qb[l][t * d..(t + 1) * d];
                    for u in 0..=t {
                        gemm::axpy(&mut dk[u * d..(u + 1) * d], da[t * s + u], q_row);
                    }
                }
                for dkv in dk.iter_mut() {
                    *dkv *= att_scale;
                }
                // projections + both residual paths into dX of this
                // block: the weight-grad rows become axpys; the dx
                // triple-dot keeps the fused form — its summand
                // grouping is part of the bit-identity contract
                for t in 0..s {
                    let dq_row = &dq[t * d..(t + 1) * d];
                    let dk_row = &dk[t * d..(t + 1) * d];
                    let dv_row = &dv[t * d..(t + 1) * d];
                    for j in 0..d {
                        let xi = xin[l][t * d + j];
                        gemm::axpy(&mut g[wq0 + j * d..wq0 + (j + 1) * d], xi, dq_row);
                        gemm::axpy(&mut g[wk0 + j * d..wk0 + (j + 1) * d], xi, dk_row);
                        gemm::axpy(&mut g[wv0 + j * d..wv0 + (j + 1) * d], xi, dv_row);
                        let mut acc = dxmid[t * d + j];
                        for j2 in 0..d {
                            acc += params[wq0 + j * d + j2] * dq_row[j2]
                                + params[wk0 + j * d + j2] * dk_row[j2]
                                + params[wv0 + j * d + j2] * dv_row[j2];
                        }
                        dx[t * d + j] = acc;
                    }
                }
            }
            // embeddings
            for t in 0..s {
                let xt = batch.tokens[base + t] as usize;
                for j in 0..d {
                    g[tok0 + xt * d + j] += dx[t * d + j];
                    g[pos0 + t * d + j] += dx[t * d + j];
                }
            }
        }
        Ok(loss_acc / positions as f64)
    }
}

impl StepBackend for NativeBundle {
    fn info(&self) -> &PresetInfo {
        &self.info
    }

    fn init_params(&self, seed: u32) -> Result<Vec<f32>> {
        let mut rng = Rng::new(seed as u64).substream("native-init", 0);
        let mut params = vec![0.0f32; self.info.param_count];
        rng.fill_normal(&mut params, 0.08);
        Ok(params)
    }

    fn train_step(&self, params: &[f32], batch: &Batch) -> Result<StepOutput> {
        self.check_shapes(params, batch)?;
        let mut grads = vec![0.0f32; self.info.param_count];
        let loss = self.pass(params, batch, Some(&mut grads))?;
        Ok(StepOutput { loss: loss as f32, grads })
    }

    fn eval_loss(&self, params: &[f32], batch: &Batch) -> Result<f32> {
        self.check_shapes(params, batch)?;
        Ok(self.pass(params, batch, None)? as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch_of(tokens: Vec<i32>, targets: Vec<i32>, b: usize, s: usize) -> Batch {
        Batch { tokens, targets, batch: b, seq: s }
    }

    fn tiny() -> (NativeBundle, Vec<f32>, Batch) {
        let nb = NativeBundle::new("native-test", 2, 3, 4);
        let params = nb.init_params(7).unwrap();
        let batch = batch_of(vec![1, 2, 3, 250, 0, 9], vec![2, 3, 4, 0, 9, 1], 2, 3);
        (nb, params, batch)
    }

    #[test]
    fn info_and_init_are_consistent() {
        let (nb, params, _) = tiny();
        assert_eq!(nb.info().param_count, 2 * 256 * 4);
        assert_eq!(params.len(), nb.info().param_count);
        let again = nb.init_params(7).unwrap();
        assert_eq!(params, again, "init must be deterministic in the seed");
        assert_ne!(params, nb.init_params(8).unwrap());
    }

    #[test]
    fn mlp_layout_is_validated_and_two_segment() {
        let (nb, _, _) = tiny();
        let layout = nb.layout();
        assert_eq!(layout.len(), 2);
        assert_eq!(layout.param_count(), nb.info().param_count);
        assert_eq!(layout.entries()[0].name, "native.embed");
        assert_eq!(layout.entries()[1].name, "native.out");
    }

    #[test]
    fn initial_loss_is_near_uniform() {
        let (nb, params, batch) = tiny();
        let loss = nb.eval_loss(&params, &batch).unwrap();
        let uniform = (256f32).ln();
        assert!((loss - uniform).abs() < 0.5, "loss {loss} vs uniform {uniform}");
    }

    #[test]
    fn train_step_is_bit_deterministic() {
        let (nb, params, batch) = tiny();
        let a = nb.train_step(&params, &batch).unwrap();
        let b = nb.train_step(&params, &batch).unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        for (x, y) in a.grads.iter().zip(&b.grads) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn gradients_match_finite_differences() {
        let nb = NativeBundle::new("native-fd", 1, 2, 3);
        let mut params = nb.init_params(3).unwrap();
        let batch = batch_of(vec![5, 6], vec![6, 7], 1, 2);
        let out = nb.train_step(&params, &batch).unwrap();
        // probe a handful of coordinates in both matrices, including the
        // embedding rows actually touched (tokens 5 and 6)
        let d = 3;
        let probes =
            [5 * d, 5 * d + 2, 6 * d + 1, 256 * d + 6, 256 * d + 3 * 256 / 2, 2 * 256 * d - 1];
        let h = 1e-3f32;
        for &i in &probes {
            let orig = params[i];
            params[i] = orig + h;
            let lp = nb.eval_loss(&params, &batch).unwrap();
            params[i] = orig - h;
            let lm = nb.eval_loss(&params, &batch).unwrap();
            params[i] = orig;
            let fd = (lp - lm) / (2.0 * h);
            assert!(
                (out.grads[i] - fd).abs() < 2e-2_f32.max(0.1 * fd.abs()),
                "coord {i}: analytic {} vs fd {fd}",
                out.grads[i]
            );
        }
    }

    #[test]
    fn sgd_on_repeated_batch_reduces_loss() {
        let nb = NativeBundle::new("native-sgd", 2, 4, 6);
        let mut params = nb.init_params(1).unwrap();
        let batch = batch_of(
            vec![10, 20, 30, 40, 50, 60, 70, 80],
            vec![20, 30, 40, 50, 60, 70, 80, 90],
            2,
            4,
        );
        let before = nb.eval_loss(&params, &batch).unwrap();
        for _ in 0..50 {
            let out = nb.train_step(&params, &batch).unwrap();
            for (p, g) in params.iter_mut().zip(&out.grads) {
                *p -= 0.5 * g;
            }
        }
        let after = nb.eval_loss(&params, &batch).unwrap();
        assert!(after < before - 0.5, "{before} -> {after}");
    }

    #[test]
    fn shape_mismatches_fail_loudly() {
        let (nb, params, batch) = tiny();
        assert!(nb.train_step(&params[1..], &batch).is_err());
        let bad = batch_of(vec![0; 4], vec![0; 4], 2, 2);
        assert!(nb.eval_loss(&params, &bad).is_err());
        let oob = batch_of(vec![999; 6], vec![0; 6], 2, 3);
        assert!(nb.train_step(&params, &oob).is_err());
    }

    // ---- transformer ----

    /// Two-block transformer at the given shape.
    fn transformer(name: &str, batch: usize, seq: usize, d: usize) -> NativeBundle {
        NativeBundle::transformer(name, batch, seq, d, 2)
    }

    fn tiny_tf() -> (NativeBundle, Vec<f32>, Batch) {
        let nb = transformer("tf-test", 2, 3, 4);
        let params = nb.init_params(11).unwrap();
        let batch = batch_of(vec![1, 2, 3, 250, 0, 9], vec![2, 3, 4, 0, 9, 1], 2, 3);
        (nb, params, batch)
    }

    #[test]
    fn transformer_layout_has_per_block_named_segments() {
        let nb = NativeBundle::transformer("tf-layout", 1, 8, 6, 3);
        let layout = nb.layout();
        // embed.tok, embed.pos, 6 per block × 3 blocks, head.out
        assert_eq!(layout.len(), 2 + 6 * 3 + 1);
        assert_eq!(layout.param_count(), nb.info().param_count);
        let names: Vec<&str> = layout.iter().map(|e| e.name.as_str()).collect();
        assert!(names.contains(&"embed.tok"));
        assert!(names.contains(&"embed.pos"));
        assert!(names.contains(&"block0.attn.wq"));
        assert!(names.contains(&"block2.mlp.w2"));
        assert!(names.contains(&"head.out"));
        let d = 6;
        let expected = 256 * d + 8 * d + 3 * (4 * d * d + 2 * d * 4 * d) + d * 256;
        assert_eq!(nb.info().param_count, expected);
        assert_eq!(nb.info().n_layer, 3);
    }

    #[test]
    fn transformer_initial_loss_is_near_uniform_and_deterministic() {
        let (nb, params, batch) = tiny_tf();
        let loss = nb.eval_loss(&params, &batch).unwrap();
        let uniform = (256f32).ln();
        assert!((loss - uniform).abs() < 0.5, "loss {loss} vs uniform {uniform}");
        let a = nb.train_step(&params, &batch).unwrap();
        let b = nb.train_step(&params, &batch).unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        for (x, y) in a.grads.iter().zip(&b.grads) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn transformer_attention_uses_token_order() {
        // same token multiset, same targets, different order: with
        // position embeddings + causal attention the loss must differ
        let nb = transformer("tf-order", 1, 3, 4);
        let params = nb.init_params(5).unwrap();
        let a = nb.eval_loss(&params, &batch_of(vec![5, 6, 7], vec![6, 7, 8], 1, 3)).unwrap();
        let b = nb.eval_loss(&params, &batch_of(vec![7, 6, 5], vec![6, 7, 8], 1, 3)).unwrap();
        assert_ne!(a.to_bits(), b.to_bits());
    }

    #[test]
    fn transformer_gradients_match_finite_differences_in_every_segment() {
        // n_layer = 2, d = 4, f = 16, seq = 3: probe every segment kind
        // — token embedding of a used token, position embedding, all
        // four attention projections, both MLP matrices (both blocks),
        // and the head.
        let nb = transformer("tf-fd", 1, 3, 4);
        let mut params = nb.init_params(9).unwrap();
        // scale the init up so gradients deep in the stack are well
        // above finite-difference noise (the relative check then has
        // teeth for every segment, not just the head)
        for p in params.iter_mut() {
            *p *= 5.0;
        }
        let batch = batch_of(vec![5, 6, 7], vec![6, 7, 8], 1, 3);
        let out = nb.train_step(&params, &batch).unwrap();

        let layout = nb.layout().clone();
        let mut probes: Vec<usize> = Vec::new();
        for e in layout.iter() {
            let r = e.offset..e.offset + e.numel();
            match e.name.as_str() {
                // rows of used tokens (5, 6, 7) and in-range positions
                "embed.tok" => probes.extend([e.offset + 5 * 4, e.offset + 6 * 4 + 2]),
                "embed.pos" => probes.extend([e.offset, e.offset + 2 * 4 + 1]),
                _ => probes.extend([r.start, r.start + (r.len() / 2), r.end - 1]),
            }
        }
        let h = 1e-2f32;
        for &i in &probes {
            let orig = params[i];
            params[i] = orig + h;
            let lp = nb.eval_loss(&params, &batch).unwrap();
            params[i] = orig - h;
            let lm = nb.eval_loss(&params, &batch).unwrap();
            params[i] = orig;
            let fd = (lp - lm) / (2.0 * h);
            assert!(
                (out.grads[i] - fd).abs() < 2e-3_f32.max(0.05 * fd.abs()),
                "coord {i} ({}): analytic {} vs fd {fd}",
                layout
                    .iter()
                    .find(|e| (e.offset..e.offset + e.numel()).contains(&i))
                    .map(|e| e.name.as_str())
                    .unwrap_or("?"),
                out.grads[i]
            );
        }
    }

    #[test]
    fn transformer_sgd_on_repeated_batch_reduces_loss() {
        let nb = transformer("tf-sgd", 2, 4, 6);
        let mut params = nb.init_params(1).unwrap();
        let batch = batch_of(
            vec![10, 20, 30, 40, 50, 60, 70, 80],
            vec![20, 30, 40, 50, 60, 70, 80, 90],
            2,
            4,
        );
        let before = nb.eval_loss(&params, &batch).unwrap();
        for _ in 0..60 {
            let out = nb.train_step(&params, &batch).unwrap();
            for (p, g) in params.iter_mut().zip(&out.grads) {
                *p -= 0.5 * g;
            }
        }
        let after = nb.eval_loss(&params, &batch).unwrap();
        assert!(after < before - 0.5, "{before} -> {after}");
    }

    #[test]
    fn transformer_shape_and_token_checks_fail_loudly() {
        let (nb, params, batch) = tiny_tf();
        assert!(nb.train_step(&params[1..], &batch).is_err());
        let bad = batch_of(vec![0; 4], vec![0; 4], 2, 2);
        assert!(nb.eval_loss(&params, &bad).is_err());
        let oob = batch_of(vec![999; 6], vec![0; 6], 2, 3);
        assert!(nb.train_step(&params, &oob).is_err());
    }
}
