//! Native (pure-Rust) [`StepBackend`]: a one-hidden-layer MLP language
//! model with exact gradients, no PJRT required.
//!
//! The AOT'd GPT-2 artifacts need a real PJRT backend; this in-tree
//! fallback gives every trainer-level code path — the parallel worker
//! fleet, checkpoint resume, the simulated clock, all outer optimizers —
//! a fully deterministic compute engine that runs anywhere the crate
//! builds. Differential tests (`rust/tests/parallel_fleet.rs`) and the
//! trainer bench (`benches/trainer.rs`, which records sequential- vs
//! parallel-fleet round wall-clock) drive the trainer through it.
//!
//! The model is deliberately simple but *real*: per position, a tanh
//! hidden layer over a byte embedding followed by a softmax over the
//! 256-way vocabulary,
//!
//! ```text
//!     h = tanh(E[x])          E: 256 × D   (embedding)
//!     z = hᵀ W                W: D × 256   (output projection)
//!     loss = CE(softmax(z), y)
//! ```
//!
//! with exact backward passes for both matrices. Compute per step is
//! O(B·S·D·256) — enough arithmetic that the per-round fleet fan-out
//! has something to parallelize. Every operation is scalar f32/f64
//! with a fixed accumulation order, so `train_step` is bit-deterministic
//! for a given (params, batch) on a given host — the property the
//! parallel ≡ sequential differential tests pin.

use anyhow::Result;

use super::{PresetInfo, StepBackend, StepOutput};
use crate::data::dataset::Batch;
use crate::util::rng::Rng;

const VOCAB: usize = 256;

/// Pure-Rust MLP LM backend. Stateless across steps (all state lives in
/// the flat parameter vector), hence trivially `Send + Sync`.
pub struct NativeBundle {
    info: PresetInfo,
    d_model: usize,
}

impl NativeBundle {
    /// Build a native backend whose [`PresetInfo`] advertises
    /// `param_count = 2 · 256 · d_model` (embedding + output matrices).
    pub fn new(name: &str, batch: usize, seq: usize, d_model: usize) -> NativeBundle {
        assert!(d_model >= 1 && batch >= 1 && seq >= 1);
        let param_count = 2 * VOCAB * d_model;
        let layout = vec![
            super::ParamEntry {
                name: "native.embed".into(),
                offset: 0,
                shape: vec![VOCAB, d_model],
            },
            super::ParamEntry {
                name: "native.out".into(),
                offset: VOCAB * d_model,
                shape: vec![d_model, VOCAB],
            },
        ];
        NativeBundle {
            info: PresetInfo {
                name: name.to_string(),
                vocab: VOCAB,
                d_model,
                n_head: 1,
                n_layer: 1,
                seq,
                batch,
                param_count,
                init_file: std::path::PathBuf::new(),
                train_file: std::path::PathBuf::new(),
                eval_file: std::path::PathBuf::new(),
                layout,
            },
            d_model,
        }
    }

    fn check_shapes(&self, params: &[f32], batch: &Batch) -> Result<()> {
        anyhow::ensure!(
            params.len() == self.info.param_count,
            "param size mismatch: {} vs {}",
            params.len(),
            self.info.param_count
        );
        anyhow::ensure!(
            batch.batch == self.info.batch && batch.seq == self.info.seq,
            "batch shape ({}, {}) does not match native shape ({}, {})",
            batch.batch,
            batch.seq,
            self.info.batch,
            self.info.seq
        );
        Ok(())
    }

    /// Forward (and optionally backward) over every position. Returns
    /// the mean cross-entropy; fills `grads` when given.
    fn pass(&self, params: &[f32], batch: &Batch, mut grads: Option<&mut [f32]>) -> Result<f64> {
        let d = self.d_model;
        let (embed, out_w) = params.split_at(VOCAB * d);
        let positions = batch.batch * batch.seq;
        let inv_pos = 1.0f32 / positions as f32;

        let mut h = vec![0.0f32; d];
        let mut logits = vec![0.0f32; VOCAB];
        let mut loss_acc = 0.0f64;

        for pos in 0..positions {
            let x = batch.tokens[pos];
            let y = batch.targets[pos];
            anyhow::ensure!(
                (0..VOCAB as i32).contains(&x) && (0..VOCAB as i32).contains(&y),
                "token {x}/{y} outside the byte vocabulary"
            );
            let (x, y) = (x as usize, y as usize);

            // h = tanh(E[x]);  z = hᵀ W
            for (hj, &e) in h.iter_mut().zip(&embed[x * d..(x + 1) * d]) {
                *hj = e.tanh();
            }
            logits.fill(0.0);
            for (j, &hj) in h.iter().enumerate() {
                for (zl, &w) in logits.iter_mut().zip(&out_w[j * VOCAB..(j + 1) * VOCAB]) {
                    *zl += hj * w;
                }
            }

            // stable softmax cross-entropy
            let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut z_sum = 0.0f32;
            for zl in logits.iter_mut() {
                *zl = (*zl - m).exp();
                z_sum += *zl;
            }
            // -ln p_y with logits[l] now holding exp(z_l - m)
            loss_acc += (z_sum.ln() - logits[y].ln()) as f64;
            let Some(g) = grads.as_deref_mut() else { continue };

            // dz = softmax(z) - onehot(y), scaled to the positional mean
            let inv_z = 1.0 / z_sum;
            for zl in logits.iter_mut() {
                *zl *= inv_z * inv_pos;
            }
            logits[y] -= inv_pos;

            let (g_embed, g_out) = g.split_at_mut(VOCAB * d);
            // dW[j, :] += h[j] · dz ;  dh[j] = Σ_l W[j, l] dz[l]
            for (j, &hj) in h.iter().enumerate() {
                let w_row = &out_w[j * VOCAB..(j + 1) * VOCAB];
                let gw_row = &mut g_out[j * VOCAB..(j + 1) * VOCAB];
                let mut dh = 0.0f32;
                for ((gw, &w), &dz) in gw_row.iter_mut().zip(w_row).zip(logits.iter()) {
                    *gw += hj * dz;
                    dh += w * dz;
                }
                // dE[x, j] = dh[j] · (1 - h[j]²)
                g_embed[x * d + j] += dh * (1.0 - hj * hj);
            }
        }
        Ok(loss_acc / positions as f64)
    }
}

impl StepBackend for NativeBundle {
    fn info(&self) -> &PresetInfo {
        &self.info
    }

    fn init_params(&self, seed: u32) -> Result<Vec<f32>> {
        let mut rng = Rng::new(seed as u64).substream("native-init", 0);
        let mut params = vec![0.0f32; self.info.param_count];
        rng.fill_normal(&mut params, 0.08);
        Ok(params)
    }

    fn train_step(&self, params: &[f32], batch: &Batch) -> Result<StepOutput> {
        self.check_shapes(params, batch)?;
        let mut grads = vec![0.0f32; self.info.param_count];
        let loss = self.pass(params, batch, Some(&mut grads))?;
        Ok(StepOutput { loss: loss as f32, grads })
    }

    fn eval_loss(&self, params: &[f32], batch: &Batch) -> Result<f32> {
        self.check_shapes(params, batch)?;
        Ok(self.pass(params, batch, None)? as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch_of(tokens: Vec<i32>, targets: Vec<i32>, b: usize, s: usize) -> Batch {
        Batch { tokens, targets, batch: b, seq: s }
    }

    fn tiny() -> (NativeBundle, Vec<f32>, Batch) {
        let nb = NativeBundle::new("native-test", 2, 3, 4);
        let params = nb.init_params(7).unwrap();
        let batch = batch_of(vec![1, 2, 3, 250, 0, 9], vec![2, 3, 4, 0, 9, 1], 2, 3);
        (nb, params, batch)
    }

    #[test]
    fn info_and_init_are_consistent() {
        let (nb, params, _) = tiny();
        assert_eq!(nb.info().param_count, 2 * 256 * 4);
        assert_eq!(params.len(), nb.info().param_count);
        let again = nb.init_params(7).unwrap();
        assert_eq!(params, again, "init must be deterministic in the seed");
        assert_ne!(params, nb.init_params(8).unwrap());
    }

    #[test]
    fn initial_loss_is_near_uniform() {
        let (nb, params, batch) = tiny();
        let loss = nb.eval_loss(&params, &batch).unwrap();
        let uniform = (256f32).ln();
        assert!((loss - uniform).abs() < 0.5, "loss {loss} vs uniform {uniform}");
    }

    #[test]
    fn train_step_is_bit_deterministic() {
        let (nb, params, batch) = tiny();
        let a = nb.train_step(&params, &batch).unwrap();
        let b = nb.train_step(&params, &batch).unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        for (x, y) in a.grads.iter().zip(&b.grads) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn gradients_match_finite_differences() {
        let nb = NativeBundle::new("native-fd", 1, 2, 3);
        let mut params = nb.init_params(3).unwrap();
        let batch = batch_of(vec![5, 6], vec![6, 7], 1, 2);
        let out = nb.train_step(&params, &batch).unwrap();
        // probe a handful of coordinates in both matrices, including the
        // embedding rows actually touched (tokens 5 and 6)
        let d = 3;
        let probes =
            [5 * d, 5 * d + 2, 6 * d + 1, 256 * d + 6, 256 * d + 3 * 256 / 2, 2 * 256 * d - 1];
        let h = 1e-3f32;
        for &i in &probes {
            let orig = params[i];
            params[i] = orig + h;
            let lp = nb.eval_loss(&params, &batch).unwrap();
            params[i] = orig - h;
            let lm = nb.eval_loss(&params, &batch).unwrap();
            params[i] = orig;
            let fd = (lp - lm) / (2.0 * h);
            assert!(
                (out.grads[i] - fd).abs() < 2e-2_f32.max(0.1 * fd.abs()),
                "coord {i}: analytic {} vs fd {fd}",
                out.grads[i]
            );
        }
    }

    #[test]
    fn sgd_on_repeated_batch_reduces_loss() {
        let nb = NativeBundle::new("native-sgd", 2, 4, 6);
        let mut params = nb.init_params(1).unwrap();
        let batch = batch_of(
            vec![10, 20, 30, 40, 50, 60, 70, 80],
            vec![20, 30, 40, 50, 60, 70, 80, 90],
            2,
            4,
        );
        let before = nb.eval_loss(&params, &batch).unwrap();
        for _ in 0..50 {
            let out = nb.train_step(&params, &batch).unwrap();
            for (p, g) in params.iter_mut().zip(&out.grads) {
                *p -= 0.5 * g;
            }
        }
        let after = nb.eval_loss(&params, &batch).unwrap();
        assert!(after < before - 0.5, "{before} -> {after}");
    }

    #[test]
    fn shape_mismatches_fail_loudly() {
        let (nb, params, batch) = tiny();
        assert!(nb.train_step(&params[1..], &batch).is_err());
        let bad = batch_of(vec![0; 4], vec![0; 4], 2, 2);
        assert!(nb.eval_loss(&params, &bad).is_err());
        let oob = batch_of(vec![999; 6], vec![0; 6], 2, 3);
        assert!(nb.train_step(&params, &oob).is_err());
    }
}
