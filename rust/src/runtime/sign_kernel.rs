//! The AOT'd fused Pallas sign-momentum kernel (Algorithm 1's global
//! step), applied chunk-wise over arbitrary-length parameter vectors.
//!
//! The production L3 hot path uses the native Rust implementation in
//! outer/sign_momentum.rs; this wrapper exists to (a) prove the paper's
//! update runs as ONE fused TPU-style kernel end-to-end through PJRT, and
//! (b) anchor a three-way equivalence test rust == pallas == jnp-ref
//! (rust/tests/runtime_roundtrip.rs).  `repro train --global-step=pallas`
//! switches the real trainer onto this path.

use anyhow::{Context, Result};

use super::{anyhow_xla, Artifacts, Runtime};

#[derive(Clone, Copy, Debug)]
pub struct SignUpdateScalars {
    pub gamma: f32,
    pub eta: f32,
    pub weight_decay: f32,
    pub beta1: f32,
    pub beta2: f32,
}

pub struct SignUpdateKernel {
    exe: xla::PjRtLoadedExecutable,
    chunk: usize,
}

impl SignUpdateKernel {
    pub fn load(rt: &Runtime, arts: &Artifacts) -> Result<SignUpdateKernel> {
        let exe = rt
            .compile_hlo_text(&arts.sign_update_file)
            .with_context(|| format!("compiling {:?}", arts.sign_update_file))?;
        Ok(SignUpdateKernel { exe, chunk: arts.sign_update_chunk })
    }

    pub fn chunk(&self) -> usize {
        self.chunk
    }

    /// Apply eqs. (6)-(8) in place over `x` and `m`, streaming CHUNK-sized
    /// windows through the kernel; the tail is zero-padded (sign(0) = 0 and
    /// x = m = 0 on the pad, so padding is exact, not approximate).
    pub fn apply(
        &self,
        x: &mut [f32],
        m: &mut [f32],
        diff: &[f32],
        s: SignUpdateScalars,
    ) -> Result<()> {
        assert_eq!(x.len(), m.len());
        assert_eq!(x.len(), diff.len());
        let scal =
            xla::Literal::vec1(&[s.gamma, s.eta, s.weight_decay, s.beta1, s.beta2, 0.0, 0.0, 0.0]);
        let mut xpad = vec![0.0f32; self.chunk];
        let mut mpad = vec![0.0f32; self.chunk];
        let mut dpad = vec![0.0f32; self.chunk];
        let mut off = 0;
        while off < x.len() {
            let len = (x.len() - off).min(self.chunk);
            let (xw, mw, dw): (&mut [f32], &mut [f32], &[f32]);
            if len == self.chunk {
                xw = &mut x[off..off + len];
                mw = &mut m[off..off + len];
                dw = &diff[off..off + len];
                self.apply_chunk(xw, mw, dw, &scal)?;
            } else {
                xpad[..len].copy_from_slice(&x[off..off + len]);
                mpad[..len].copy_from_slice(&m[off..off + len]);
                dpad[..len].copy_from_slice(&diff[off..off + len]);
                xpad[len..].fill(0.0);
                mpad[len..].fill(0.0);
                dpad[len..].fill(0.0);
                // split borrows: run on the scratch buffers
                let (xs, ms, ds) = (&mut xpad, &mut mpad, &dpad);
                Self::apply_chunk_static(&self.exe, xs, ms, ds, &scal)?;
                x[off..off + len].copy_from_slice(&xs[..len]);
                m[off..off + len].copy_from_slice(&ms[..len]);
            }
            off += len;
        }
        Ok(())
    }

    fn apply_chunk(
        &self,
        x: &mut [f32],
        m: &mut [f32],
        d: &[f32],
        scal: &xla::Literal,
    ) -> Result<()> {
        Self::apply_chunk_static(&self.exe, x, m, d, scal)
    }

    fn apply_chunk_static(
        exe: &xla::PjRtLoadedExecutable,
        x: &mut [f32],
        m: &mut [f32],
        d: &[f32],
        scal: &xla::Literal,
    ) -> Result<()> {
        let xl = xla::Literal::vec1(&*x);
        let ml = xla::Literal::vec1(&*m);
        let dl = xla::Literal::vec1(d);
        let out = exe.execute::<xla::Literal>(&[xl, ml, dl, scal.clone()]).map_err(anyhow_xla)?;
        let tuple = out[0][0].to_literal_sync().map_err(anyhow_xla)?;
        let parts = tuple.to_tuple().map_err(anyhow_xla)?;
        anyhow::ensure!(parts.len() == 2, "sign_update returned {}-tuple", parts.len());
        let xn = parts[0].to_vec::<f32>().map_err(anyhow_xla)?;
        let mn = parts[1].to_vec::<f32>().map_err(anyhow_xla)?;
        x.copy_from_slice(&xn);
        m.copy_from_slice(&mn);
        Ok(())
    }
}
