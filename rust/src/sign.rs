//! Sign operators: the deterministic `sign` used by Algorithm 1 and the
//! two *randomized* sign operators of the paper's §3.1 (eqs. (9), (10)).
//!
//! The randomized operators are the analytical device behind Theorems 1-2:
//! for ‖v‖ ≤ B they are unbiased up to scale, E[S_r(v)] = v / B, with
//! per-coordinate variance ≤ 1 (Lemma 1).  The theory-validation harness
//! (`sim/`, `experiments/theory.rs`) runs Algorithm 1 under all three
//! operators; `dist/collectives.rs` uses the ±1 variant for the
//! MV-sto-signSGD baseline's majority vote.

use crate::tensor::sign_f32;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SignOp {
    /// Deterministic sign (what Algorithm 1 actually deploys).
    Exact,
    /// Eq. (9): outputs ±sign(v_j), flipping with prob 1/2 - |v_j|/(2B).
    RandPm,
    /// Eq. (10): outputs sign(v_j) w.p. |v_j|/B, else 0.
    RandZero,
}

impl SignOp {
    pub fn parse(s: &str) -> Option<SignOp> {
        match s {
            "exact" | "sign" => Some(SignOp::Exact),
            "rand_pm" | "pm" => Some(SignOp::RandPm),
            "rand_zero" | "zero" => Some(SignOp::RandZero),
            _ => None,
        }
    }

    /// Stable config-facing name (inverse of [`SignOp::parse`]) — what
    /// [`crate::outer::OuterConfig::describe`] folds into the cache key.
    pub fn name(&self) -> &'static str {
        match self {
            SignOp::Exact => "exact",
            SignOp::RandPm => "rand_pm",
            SignOp::RandZero => "rand_zero",
        }
    }

    /// Apply the operator to `v` with scale bound `b`, writing into `out`.
    ///
    /// `b` must satisfy ‖v‖ ≥ ... the *caller* guarantees ‖v‖ ≤ b (the
    /// paper uses B = τR from Assumption 3); we debug-assert per
    /// coordinate, which is implied.
    pub fn apply_into(&self, out: &mut [f32], v: &[f32], b: f32, rng: &mut Rng) {
        assert_eq!(out.len(), v.len());
        match self {
            SignOp::Exact => {
                for (o, &x) in out.iter_mut().zip(v) {
                    *o = sign_f32(x);
                }
            }
            SignOp::RandPm => {
                debug_assert!(b > 0.0);
                for (o, &x) in out.iter_mut().zip(v) {
                    debug_assert!(x.abs() <= b * 1.0001, "|v_j|={} > B={}", x.abs(), b);
                    let p_keep = 0.5 + 0.5 * (x.abs() / b) as f64;
                    let s = sign_f32(x);
                    // sign(0) = 0: both branches yield 0, matching ±sign(0).
                    *o = if rng.f64() < p_keep { s } else { -s };
                }
            }
            SignOp::RandZero => {
                debug_assert!(b > 0.0);
                for (o, &x) in out.iter_mut().zip(v) {
                    debug_assert!(x.abs() <= b * 1.0001);
                    *o = if rng.f64() < (x.abs() / b) as f64 {
                        sign_f32(x)
                    } else {
                        0.0
                    };
                }
            }
        }
    }

    pub fn apply(&self, v: &[f32], b: f32, rng: &mut Rng) -> Vec<f32> {
        let mut out = vec![0.0; v.len()];
        self.apply_into(&mut out, v, b, rng);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Empirical check of Lemma 1: E[S_r(v)] = v/B for both randomized ops.
    #[test]
    fn lemma1_unbiasedness() {
        let v = vec![0.8, -0.5, 0.0, 0.3, -1.0];
        let b = 1.0;
        for op in [SignOp::RandPm, SignOp::RandZero] {
            let mut rng = Rng::new(17);
            let trials = 200_000;
            let mut acc = vec![0.0f64; v.len()];
            let mut out = vec![0.0f32; v.len()];
            for _ in 0..trials {
                op.apply_into(&mut out, &v, b, &mut rng);
                for (a, &o) in acc.iter_mut().zip(&out) {
                    *a += o as f64;
                }
            }
            for (j, a) in acc.iter().enumerate() {
                let mean = a / trials as f64;
                assert!(
                    (mean - v[j] as f64 / b as f64).abs() < 0.01,
                    "{op:?} coord {j}: mean {mean} vs {}",
                    v[j]
                );
            }
        }
    }

    /// Lemma 1 second part: E‖S_r(v) - v/B‖² ≤ d.
    #[test]
    fn lemma1_variance_bound() {
        let v = vec![0.7, -0.2, 0.9, -0.4];
        let b = 1.0;
        for op in [SignOp::RandPm, SignOp::RandZero] {
            let mut rng = Rng::new(29);
            let trials = 50_000;
            let mut acc = 0.0f64;
            let mut out = vec![0.0f32; v.len()];
            for _ in 0..trials {
                op.apply_into(&mut out, &v, b, &mut rng);
                acc += out
                    .iter()
                    .zip(&v)
                    .map(|(&o, &x)| {
                        let d = o as f64 - x as f64 / b as f64;
                        d * d
                    })
                    .sum::<f64>();
            }
            let var = acc / trials as f64;
            assert!(var <= v.len() as f64, "{op:?}: E-dist {var} > d {}", v.len());
        }
    }

    #[test]
    fn exact_matches_tensor_sign() {
        let v = vec![3.0, -2.0, 0.0];
        let mut rng = Rng::new(0);
        assert_eq!(SignOp::Exact.apply(&v, 1.0, &mut rng), vec![1.0, -1.0, 0.0]);
    }

    #[test]
    fn outputs_are_ternary() {
        let mut rng = Rng::new(5);
        let v: Vec<f32> = (0..256).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        for op in [SignOp::Exact, SignOp::RandPm, SignOp::RandZero] {
            let out = op.apply(&v, 2.0, &mut rng);
            assert!(out.iter().all(|&o| o == 0.0 || o == 1.0 || o == -1.0), "{op:?}");
        }
    }

    #[test]
    fn saturated_input_is_deterministic() {
        // |v_j| = B: RandPm keeps sign w.p. 1; RandZero emits sign w.p. 1.
        let v = vec![2.0, -2.0];
        let mut rng = Rng::new(1);
        for op in [SignOp::RandPm, SignOp::RandZero] {
            for _ in 0..100 {
                assert_eq!(op.apply(&v, 2.0, &mut rng), vec![1.0, -1.0], "{op:?}");
            }
        }
    }

    #[test]
    fn parse_names() {
        assert_eq!(SignOp::parse("exact"), Some(SignOp::Exact));
        assert_eq!(SignOp::parse("rand_pm"), Some(SignOp::RandPm));
        assert_eq!(SignOp::parse("rand_zero"), Some(SignOp::RandZero));
        assert_eq!(SignOp::parse("bogus"), None);
    }
}
