//! Pure-Rust stochastic-optimization testbed validating the paper's
//! theory (Theorems 1-3) at scales PJRT would make impractical.
//!
//! Problems implement a distributed gradient oracle with controllable
//! smoothness L, per-worker noise σ (Assumption in Thm 2a / 3), and
//! heterogeneity δ (Thm 2b).  [`run_sign_momentum`] runs Algorithm 1
//! with SGD base *natively* (no PJRT), recording the quantities the
//! theorems bound: mean ‖∇f‖² over all local iterates (Thms 1-2) and
//! mean ‖∇f(x_{t,0})‖₁ over outer iterates (Thm 3).

pub mod problems;

pub use problems::{HeterogeneousQuadratic, Problem, RastriginLike};

use crate::sign::SignOp;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct SimSpec {
    pub n_workers: usize,
    pub tau: usize,
    pub rounds: usize,
    pub gamma: f32,
    pub eta: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub sign_op: SignOp,
    /// B for randomized operators (Theorem 1 takes B = τR).
    pub sign_bound: f32,
    pub seed: u64,
}

#[derive(Clone, Debug)]
pub struct SimResult {
    /// (1/τT) Σ_{t,k} ‖∇f(x̄_{t,k})‖² — the Theorem 1/2 quantity.
    pub mean_sq_grad_norm: f64,
    /// (1/T) Σ_t ‖∇f(x_{t,0})‖₁ — the Theorem 3 quantity.
    pub mean_l1_grad_norm: f64,
    /// final f(x_{T,0})
    pub final_loss: f64,
    /// ‖∇f(x_{T,0})‖₂
    pub final_grad_norm: f64,
}

/// Algorithm 1 with SGD base optimizer on an analytic problem.
pub fn run_sign_momentum(problem: &dyn Problem, spec: &SimSpec) -> SimResult {
    let d = problem.dim();
    let root = Rng::new(spec.seed);
    let mut worker_rngs: Vec<Rng> =
        (0..spec.n_workers).map(|i| root.substream("sim-worker", i as u64)).collect();
    let mut sign_rng = root.substream("sim-sign", 0);

    let mut x = problem.init();
    let mut m = vec![0.0f32; d];
    let mut worker_x = vec![vec![0.0f32; d]; spec.n_workers];

    let mut sq_acc = 0.0f64;
    let mut sq_n = 0u64;
    let mut l1_acc = 0.0f64;

    let mut signs = vec![0.0f32; d];
    let mut grad_buf = vec![0.0f32; d];

    for _t in 0..spec.rounds {
        // Theorem 3 quantity at x_{t,0}
        problem.full_grad(&x, &mut grad_buf);
        l1_acc += grad_buf.iter().map(|g| g.abs() as f64).sum::<f64>();

        for wx in worker_x.iter_mut() {
            wx.copy_from_slice(&x);
        }
        for _k in 0..spec.tau {
            // Theorem 1/2 quantity at the virtual average x̄_{t,k}
            let mut avg = vec![0.0f32; d];
            for wx in &worker_x {
                for (a, &v) in avg.iter_mut().zip(wx) {
                    *a += v;
                }
            }
            for a in avg.iter_mut() {
                *a /= spec.n_workers as f32;
            }
            problem.full_grad(&avg, &mut grad_buf);
            sq_acc += grad_buf.iter().map(|g| (g * g) as f64).sum::<f64>();
            sq_n += 1;

            for (w, wx) in worker_x.iter_mut().enumerate() {
                problem.stoch_grad(wx, w, &mut worker_rngs[w], &mut grad_buf);
                for (xi, &g) in wx.iter_mut().zip(grad_buf.iter()) {
                    *xi -= spec.gamma * g;
                }
            }
        }

        // exact average + Algorithm 1 global step
        let mut avg_end = vec![0.0f32; d];
        for wx in &worker_x {
            for (a, &v) in avg_end.iter_mut().zip(wx) {
                *a += v;
            }
        }
        for a in avg_end.iter_mut() {
            *a /= spec.n_workers as f32;
        }
        let inv_gamma = 1.0 / spec.gamma;
        let mut u = vec![0.0f32; d];
        for i in 0..d {
            let pg = (x[i] - avg_end[i]) * inv_gamma;
            u[i] = spec.beta1 * m[i] + (1.0 - spec.beta1) * pg;
            m[i] = spec.beta2 * m[i] + (1.0 - spec.beta2) * pg;
        }
        spec.sign_op.apply_into(&mut signs, &u, spec.sign_bound, &mut sign_rng);
        for i in 0..d {
            x[i] -= spec.eta * spec.gamma * signs[i];
        }
    }

    problem.full_grad(&x, &mut grad_buf);
    let final_grad_norm = grad_buf.iter().map(|g| (g * g) as f64).sum::<f64>().sqrt();
    SimResult {
        mean_sq_grad_norm: sq_acc / sq_n.max(1) as f64,
        mean_l1_grad_norm: l1_acc / spec.rounds.max(1) as f64,
        final_loss: problem.loss(&x),
        final_grad_norm,
    }
}

/// Fit the slope of log(y) vs log(x) by least squares — used by the
/// theory experiments to estimate empirical convergence-rate exponents.
pub fn loglog_slope(points: &[(f64, f64)]) -> f64 {
    let pts: Vec<(f64, f64)> = points
        .iter()
        .filter(|(x, y)| *x > 0.0 && *y > 0.0)
        .map(|&(x, y)| (x.ln(), y.ln()))
        .collect();
    let n = pts.len() as f64;
    assert!(n >= 2.0);
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_spec() -> SimSpec {
        SimSpec {
            n_workers: 4,
            tau: 4,
            rounds: 300,
            gamma: 0.01,
            eta: 1.0,
            beta1: 0.9,
            beta2: 0.95,
            sign_op: SignOp::Exact,
            sign_bound: 1.0,
            seed: 11,
        }
    }

    #[test]
    fn sign_momentum_descends_on_quadratic() {
        let p = HeterogeneousQuadratic::new(16, 4, 0.1, 0.5, 7);
        let start_loss = p.loss(&p.init());
        let res = run_sign_momentum(&p, &base_spec());
        assert!(res.final_loss < start_loss * 0.5, "{} -> {}", start_loss, res.final_loss);
        assert!(res.mean_sq_grad_norm.is_finite());
    }

    #[test]
    fn more_rounds_means_smaller_average_gradient() {
        let p = HeterogeneousQuadratic::new(16, 4, 0.2, 0.2, 3);
        let short = run_sign_momentum(&p, &SimSpec { rounds: 30, ..base_spec() });
        let long = run_sign_momentum(&p, &SimSpec { rounds: 1000, ..base_spec() });
        assert!(
            long.mean_l1_grad_norm < short.mean_l1_grad_norm,
            "{} vs {}",
            long.mean_l1_grad_norm,
            short.mean_l1_grad_norm
        );
    }

    #[test]
    fn randomized_ops_also_descend() {
        let p = HeterogeneousQuadratic::new(8, 4, 0.1, 0.2, 5);
        let start_loss = p.loss(&p.init());
        for op in [SignOp::RandPm, SignOp::RandZero] {
            let res = run_sign_momentum(
                &p,
                &SimSpec { sign_op: op, sign_bound: 50.0, rounds: 800, ..base_spec() },
            );
            assert!(res.final_loss < start_loss, "{op:?}: {}", res.final_loss);
        }
    }

    #[test]
    fn loglog_slope_recovers_powers() {
        let pts: Vec<(f64, f64)> = (1..20).map(|i| (i as f64, (i as f64).powf(-0.5))).collect();
        assert!((loglog_slope(&pts) + 0.5).abs() < 1e-9);
        let pts: Vec<(f64, f64)> =
            (1..20).map(|i| (i as f64, 3.0 * (i as f64).powf(-0.25))).collect();
        assert!((loglog_slope(&pts) + 0.25).abs() < 1e-9);
    }
}
