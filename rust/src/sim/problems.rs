//! Analytic distributed test problems with controllable L, σ, δ.

use crate::util::rng::Rng;

/// A distributed optimization problem: n workers, each with its own
/// stochastic gradient oracle (heterogeneity δ enters through worker-
/// specific components; noise σ through the oracle).
pub trait Problem: Sync {
    fn dim(&self) -> usize;
    fn n_workers(&self) -> usize;
    fn init(&self) -> Vec<f32>;
    /// f(x) — the global average objective.
    fn loss(&self, x: &[f32]) -> f64;
    /// ∇f(x) into `out`.
    fn full_grad(&self, x: &[f32], out: &mut [f32]);
    /// Stochastic ∇f_w(x, ξ) into `out`.
    fn stoch_grad(&self, x: &[f32], worker: usize, rng: &mut Rng, out: &mut [f32]);
}

/// f_i(x) = 0.5 ‖x - a_i‖²_Q with per-worker minima a_i (heterogeneity δ
/// scales their spread), diagonal curvature Q in [0.5, L], and additive
/// Gaussian gradient noise of scale σ.  The global optimum is the Q-mean
/// of the a_i, and every Theorem-2 assumption holds by construction.
pub struct HeterogeneousQuadratic {
    dim: usize,
    n: usize,
    sigma: f32,
    minima: Vec<Vec<f32>>,
    curvature: Vec<f32>,
    init: Vec<f32>,
}

impl HeterogeneousQuadratic {
    pub fn new(dim: usize, n: usize, sigma: f32, delta: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed).substream("quad", 0);
        let curvature: Vec<f32> = (0..dim).map(|_| 0.5 + 1.5 * rng.f32()).collect();
        let minima: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.normal_f32(0.0, delta)).collect())
            .collect();
        let init: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        HeterogeneousQuadratic { dim, n, sigma, minima, curvature, init }
    }

    fn mean_minimum(&self, j: usize) -> f32 {
        self.minima.iter().map(|a| a[j]).sum::<f32>() / self.n as f32
    }
}

impl Problem for HeterogeneousQuadratic {
    fn dim(&self) -> usize {
        self.dim
    }

    fn n_workers(&self) -> usize {
        self.n
    }

    fn init(&self) -> Vec<f32> {
        self.init.clone()
    }

    fn loss(&self, x: &[f32]) -> f64 {
        let mut acc = 0.0f64;
        for a in &self.minima {
            for j in 0..self.dim {
                let d = (x[j] - a[j]) as f64;
                acc += 0.5 * self.curvature[j] as f64 * d * d;
            }
        }
        acc / self.n as f64
    }

    fn full_grad(&self, x: &[f32], out: &mut [f32]) {
        for j in 0..self.dim {
            out[j] = self.curvature[j] * (x[j] - self.mean_minimum(j));
        }
    }

    fn stoch_grad(&self, x: &[f32], worker: usize, rng: &mut Rng, out: &mut [f32]) {
        let a = &self.minima[worker];
        for j in 0..self.dim {
            out[j] = self.curvature[j] * (x[j] - a[j]) + rng.normal_f32(0.0, self.sigma);
        }
    }
}

/// Nonconvex benchmark: f_i(x) = Σ_j [ x_j²/2 + c·(1 - cos(x_j)) ] with a
/// per-worker phase shift — smooth (L = 1 + c) but non-convex, so the
/// ‖∇f‖ → 0 guarantees (not loss optimality) are what the theorems give.
pub struct RastriginLike {
    dim: usize,
    n: usize,
    sigma: f32,
    c: f32,
    phases: Vec<Vec<f32>>,
    init: Vec<f32>,
}

impl RastriginLike {
    pub fn new(dim: usize, n: usize, sigma: f32, c: f32, delta: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed).substream("rast", 0);
        let phases =
            (0..n).map(|_| (0..dim).map(|_| rng.normal_f32(0.0, delta)).collect()).collect();
        let init: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 3.0)).collect();
        RastriginLike { dim, n, sigma, c, phases, init }
    }
}

impl Problem for RastriginLike {
    fn dim(&self) -> usize {
        self.dim
    }

    fn n_workers(&self) -> usize {
        self.n
    }

    fn init(&self) -> Vec<f32> {
        self.init.clone()
    }

    fn loss(&self, x: &[f32]) -> f64 {
        let mut acc = 0.0f64;
        for ph in &self.phases {
            for j in 0..self.dim {
                let xj = x[j] as f64;
                acc += 0.5 * xj * xj + self.c as f64 * (1.0 - (xj - ph[j] as f64).cos());
            }
        }
        acc / self.n as f64
    }

    fn full_grad(&self, x: &[f32], out: &mut [f32]) {
        out.fill(0.0);
        for ph in &self.phases {
            for j in 0..self.dim {
                out[j] += x[j] + self.c * (x[j] - ph[j]).sin();
            }
        }
        for o in out.iter_mut() {
            *o /= self.n as f32;
        }
    }

    fn stoch_grad(&self, x: &[f32], worker: usize, rng: &mut Rng, out: &mut [f32]) {
        let ph = &self.phases[worker];
        for j in 0..self.dim {
            out[j] = x[j] + self.c * (x[j] - ph[j]).sin() + rng.normal_f32(0.0, self.sigma);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_full_grad_is_mean_of_worker_grads() {
        let p = HeterogeneousQuadratic::new(8, 4, 0.0, 1.0, 3);
        let x: Vec<f32> = (0..8).map(|i| i as f32 * 0.1).collect();
        let mut full = vec![0.0; 8];
        p.full_grad(&x, &mut full);
        let mut mean = vec![0.0f32; 8];
        let mut rng = Rng::new(0);
        let mut g = vec![0.0; 8];
        for w in 0..4 {
            p.stoch_grad(&x, w, &mut rng, &mut g); // σ=0 ⇒ deterministic
            for j in 0..8 {
                mean[j] += g[j] / 4.0;
            }
        }
        for j in 0..8 {
            assert!((full[j] - mean[j]).abs() < 1e-5);
        }
    }

    #[test]
    fn quadratic_optimum_has_zero_grad() {
        let p = HeterogeneousQuadratic::new(4, 3, 0.0, 0.7, 1);
        let opt: Vec<f32> = (0..4).map(|j| p.mean_minimum(j)).collect();
        let mut g = vec![0.0; 4];
        p.full_grad(&opt, &mut g);
        assert!(g.iter().all(|v| v.abs() < 1e-6));
    }

    #[test]
    fn stoch_grad_noise_has_requested_scale() {
        let p = HeterogeneousQuadratic::new(2, 2, 0.5, 0.0, 9);
        let x = vec![0.0f32; 2];
        let mut rng = Rng::new(4);
        let mut g = vec![0.0; 2];
        let mut mean_g = vec![0.0f64; 2];
        let trials = 20_000;
        let mut var = 0.0f64;
        let mut det = vec![0.0f32; 2];
        p.full_grad(&x, &mut det); // delta=0 ⇒ all workers share minima
        for _ in 0..trials {
            p.stoch_grad(&x, 0, &mut rng, &mut g);
            for j in 0..2 {
                mean_g[j] += g[j] as f64;
                let d = (g[j] - det[j]) as f64;
                var += d * d / 2.0;
            }
        }
        let var = var / trials as f64;
        assert!((var - 0.25).abs() < 0.02, "var {var}");
        for j in 0..2 {
            assert!((mean_g[j] / trials as f64 - det[j] as f64).abs() < 0.02);
        }
    }

    #[test]
    fn rastrigin_grad_is_consistent_with_finite_differences() {
        let p = RastriginLike::new(3, 2, 0.0, 2.0, 0.5, 7);
        let x = vec![0.3f32, -1.2, 0.8];
        let mut g = vec![0.0; 3];
        p.full_grad(&x, &mut g);
        let h = 1e-3f32;
        for j in 0..3 {
            let mut xp = x.clone();
            xp[j] += h;
            let mut xm = x.clone();
            xm[j] -= h;
            let fd = (p.loss(&xp) - p.loss(&xm)) / (2.0 * h as f64);
            assert!((g[j] as f64 - fd).abs() < 1e-2, "coord {j}: {} vs {fd}", g[j]);
        }
    }
}
