//! Flat-vector math over `[f32]` — the numeric substrate for every
//! optimizer in the system.
//!
//! The AOT'd model exposes parameters/gradients as ONE flat `f32[P]`
//! vector (see python/compile/model.py), so all of Algorithm 1, SlowMo,
//! AdamW, ... reduce to elementwise loops here.  Loops are written in
//! 8-wide chunks so LLVM autovectorizes them; the benches in
//! rust/benches/optim.rs verify these run at memory bandwidth.

/// y += alpha * x
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// y = alpha * y
pub fn scale(y: &mut [f32], alpha: f32) {
    for yi in y.iter_mut() {
        *yi *= alpha;
    }
}

/// out = a - b
pub fn sub(out: &mut [f32], a: &[f32], b: &[f32]) {
    assert_eq!(out.len(), a.len());
    assert_eq!(a.len(), b.len());
    for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
        *o = x - y;
    }
}

/// y = beta * y + (1 - beta) * x   (exponential moving average update)
pub fn ema(y: &mut [f32], beta: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi = beta * *yi + (1.0 - beta) * xi;
    }
}

/// y = beta * y + alpha * x  (general linear recurrence)
pub fn lincomb(y: &mut [f32], beta: f32, alpha: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi = beta * *yi + alpha * xi;
    }
}

pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

pub fn norm2(a: &[f32]) -> f64 {
    dot(a, a).sqrt()
}

pub fn norm2_sq(a: &[f32]) -> f64 {
    dot(a, a)
}

pub fn norm1(a: &[f32]) -> f64 {
    a.iter().map(|&x| x.abs() as f64).sum()
}

pub fn norm_inf(a: &[f32]) -> f64 {
    a.iter().fold(0.0f64, |m, &x| m.max(x.abs() as f64))
}

/// Mean of `vs` written into `out` — the arithmetic core of all-reduce.
pub fn mean_into(out: &mut [f32], vs: &[&[f32]]) {
    assert!(!vs.is_empty());
    let inv = 1.0 / vs.len() as f32;
    out.copy_from_slice(vs[0]);
    for v in &vs[1..] {
        axpy(out, 1.0, v);
    }
    scale(out, inv);
}

/// Elementwise sign with sign(0) = 0 (matches jnp.sign and the paper).
#[inline]
pub fn sign_f32(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

pub fn sign_into(out: &mut [f32], x: &[f32]) {
    assert_eq!(out.len(), x.len());
    for (o, &v) in out.iter_mut().zip(x) {
        *o = sign_f32(v);
    }
}

pub fn clip(y: &mut [f32], lo: f32, hi: f32) {
    for v in y.iter_mut() {
        *v = v.clamp(lo, hi);
    }
}

pub fn all_finite(a: &[f32]) -> bool {
    a.iter().all(|v| v.is_finite())
}

/// Max |a - b| — the workhorse of cross-implementation equivalence tests.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_scale_sub() {
        let mut y = vec![1.0, 2.0, 3.0];
        axpy(&mut y, 2.0, &[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![3.0, 4.0, 5.0]);
        scale(&mut y, 0.5);
        assert_eq!(y, vec![1.5, 2.0, 2.5]);
        let mut out = vec![0.0; 3];
        sub(&mut out, &y, &[0.5, 0.5, 0.5]);
        assert_eq!(out, vec![1.0, 1.5, 2.0]);
    }

    #[test]
    fn ema_endpoints() {
        let mut y = vec![10.0; 4];
        ema(&mut y, 1.0, &[0.0; 4]); // beta=1 keeps y
        assert_eq!(y, vec![10.0; 4]);
        ema(&mut y, 0.0, &[3.0; 4]); // beta=0 replaces y
        assert_eq!(y, vec![3.0; 4]);
    }

    #[test]
    fn norms() {
        let a = vec![3.0, -4.0];
        assert!((norm2(&a) - 5.0).abs() < 1e-12);
        assert!((norm1(&a) - 7.0).abs() < 1e-12);
        assert!((norm_inf(&a) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn dot_accumulates_in_f64() {
        // 1e8 + 1 repeated: f32 accumulation would lose the ones.
        let a = vec![1.0f32; 4096];
        let b = vec![1.0f32; 4096];
        assert_eq!(dot(&a, &b), 4096.0);
    }

    #[test]
    fn mean_into_averages() {
        let v1 = vec![1.0, 2.0];
        let v2 = vec![3.0, 4.0];
        let v3 = vec![5.0, 6.0];
        let mut out = vec![0.0; 2];
        mean_into(&mut out, &[&v1, &v2, &v3]);
        assert_eq!(out, vec![3.0, 4.0]);
    }

    #[test]
    fn sign_semantics_match_jnp() {
        assert_eq!(sign_f32(2.5), 1.0);
        assert_eq!(sign_f32(-0.1), -1.0);
        assert_eq!(sign_f32(0.0), 0.0);
        assert_eq!(sign_f32(-0.0), 0.0);
        let mut out = vec![0.0; 3];
        sign_into(&mut out, &[1e-30, -1e-30, 0.0]);
        assert_eq!(out, vec![1.0, -1.0, 0.0]);
    }

    #[test]
    fn max_abs_diff_and_finiteness() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
        assert!(all_finite(&[1.0, 0.0]));
        assert!(!all_finite(&[f32::NAN]));
        assert!(!all_finite(&[f32::INFINITY]));
    }
}
