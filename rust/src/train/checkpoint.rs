//! Checkpointing: global parameters + outer state + per-worker optimizer
//! state in a self-describing binary container.
//!
//! Format (little-endian):
//!   magic "DSMCKPT1" | u32 header_len | header JSON | buffers (raw f32)
//! The header records the run tag, round, and a (name, len) index of the
//! buffers so a checkpoint is loadable without the original config and
//! mismatches fail loudly instead of silently transposing state.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{num, obj, s, Json};

const MAGIC: &[u8; 8] = b"DSMCKPT1";

#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub tag: String,
    pub round: u64,
    /// Named flat buffers, in write order.
    pub buffers: Vec<(String, Vec<f32>)>,
}

impl Checkpoint {
    pub fn new(tag: &str, round: u64) -> Checkpoint {
        Checkpoint { tag: tag.to_string(), round, buffers: Vec::new() }
    }

    pub fn add(&mut self, name: &str, buf: &[f32]) {
        self.buffers.push((name.to_string(), buf.to_vec()));
    }

    pub fn get(&self, name: &str) -> Result<&[f32]> {
        self.buffers
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, b)| b.as_slice())
            .ok_or_else(|| anyhow!("checkpoint has no buffer `{name}`"))
    }

    /// All buffers whose name starts with `prefix`, in write order.
    pub fn with_prefix(&self, prefix: &str) -> Vec<Vec<f32>> {
        self.buffers
            .iter()
            .filter(|(n, _)| n.starts_with(prefix))
            .map(|(_, b)| b.clone())
            .collect()
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let index: Vec<Json> = self
            .buffers
            .iter()
            .map(|(n, b)| obj(vec![("name", s(n)), ("len", num(b.len() as f64))]))
            .collect();
        let header = obj(vec![
            ("tag", s(&self.tag)),
            ("round", num(self.round as f64)),
            ("buffers", Json::Arr(index)),
        ])
        .to_string_compact();

        let mut f = std::fs::File::create(path).with_context(|| format!("{path:?}"))?;
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u32).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for (_, buf) in &self.buffers {
            // SAFETY: any f32 bit pattern is valid as [u8; 4]; the
            // pointer and byte length cover exactly the live Vec<f32>
            // allocation, and u8 has no alignment requirement.
            let bytes: &[u8] =
                unsafe { std::slice::from_raw_parts(buf.as_ptr() as *const u8, buf.len() * 4) };
            f.write_all(bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::fs::File::open(path).with_context(|| format!("{path:?}"))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?}: not a DSM checkpoint (bad magic)");
        }
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4)?;
        let hlen = u32::from_le_bytes(len4) as usize;
        let mut hbytes = vec![0u8; hlen];
        f.read_exact(&mut hbytes)?;
        let header = Json::parse(std::str::from_utf8(&hbytes)?)
            .map_err(|e| anyhow!("{path:?}: bad header: {e}"))?;

        let tag = header.get("tag").and_then(Json::as_str).unwrap_or("").to_string();
        let round = header.get("round").and_then(Json::as_usize).unwrap_or(0) as u64;
        let mut buffers = Vec::new();
        for entry in header
            .get("buffers")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("header missing buffers"))?
        {
            let name = entry
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("buffer entry missing name"))?
                .to_string();
            let len = entry
                .get("len")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("buffer entry missing len"))?;
            let mut bytes = vec![0u8; len * 4];
            f.read_exact(&mut bytes)
                .with_context(|| format!("{path:?}: truncated buffer `{name}`"))?;
            let mut buf = vec![0f32; len];
            for (i, c) in bytes.chunks_exact(4).enumerate() {
                buf[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
            buffers.push((name, buf));
        }
        Ok(Checkpoint { tag, round, buffers })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join("dsm_ckpt_tests").join(name)
    }

    #[test]
    fn roundtrip_preserves_bits() {
        let mut ck = Checkpoint::new("run-1", 17);
        ck.add("global", &[1.0, -2.5, f32::MIN_POSITIVE, 3.4e38]);
        ck.add("outer.m", &[0.0; 100]);
        ck.add("worker0.opt0", &[0.5; 7]);
        let path = tmp("rt.ckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.tag, "run-1");
        assert_eq!(back.round, 17);
        assert_eq!(back.buffers.len(), 3);
        assert_eq!(back.get("global").unwrap(), ck.get("global").unwrap());
        assert_eq!(back.get("worker0.opt0").unwrap(), &[0.5; 7]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn prefix_query_preserves_order() {
        let mut ck = Checkpoint::new("t", 0);
        ck.add("w.opt0", &[0.0]);
        ck.add("w.opt1", &[1.0]);
        ck.add("other", &[9.0]);
        let bufs = ck.with_prefix("w.opt");
        assert_eq!(bufs, vec![vec![0.0], vec![1.0]]);
    }

    #[test]
    fn rejects_garbage_files() {
        let path = tmp("bad.ckpt");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, b"NOTACKPTxxxxxxxxxxxx").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_buffer_is_loud() {
        let ck = Checkpoint::new("t", 0);
        assert!(ck.get("nope").is_err());
    }
}
